//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! Pipeline per benchmark input (the paper's seven distributions):
//!   1. generate the distributed input with the paper's seeding,
//!   2. sort with SORT_DET_BSP and SORT_IRAN_BSP on the BSP machine
//!      substrate (L3) — once with the paper's quicksort backend and once
//!      with the **XLA backend**: the AOT-compiled Pallas bitonic network
//!      (L1) inside the JAX local-sort graph (L2), executed via PJRT from
//!      the Rust hot path,
//!   3. verify the global order, report the headline metrics (predicted
//!      T3D seconds, parallel efficiency, key imbalance).
//!
//! Run: `make artifacts && cargo run --release --example e2e_driver`
//! The results table is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};
use bsp_sort::metrics::RunReport;
use bsp_sort::runtime::XlaSorter;
use bsp_sort::seq::{QuickSorter, SeqSorter};
use bsp_sort::sort::{det, iran, SortConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let p = 8;
    let n = 1 << 20; // 1M keys
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default();

    // Layer-1/2 artifacts via PJRT; fall back with a clear message.
    let xla: Option<Arc<XlaSorter>> = match XlaSorter::from_default_artifacts() {
        Ok(s) => Some(Arc::new(s)),
        Err(e) => {
            eprintln!("warning: XLA backend unavailable ({e}); run `make artifacts`");
            None
        }
    };

    println!("end-to-end: n={n} keys, p={p}, predicted T3D seconds\n");
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>10} {:>12}",
        "input", "[DSQ]", "[RSQ]", "[DSX](xla)", "eff[DSQ]", "imbalance"
    );

    let mut checked = 0usize;
    for bench in ALL_BENCHMARKS {
        // [DSQ]
        let run_dsq = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        verify(&run_dsq.outputs, n);
        let rep = RunReport::new("[DSQ]", bench.tag(), n, &params, &run_dsq.ledger, &run_dsq.outputs);

        // [RSQ]
        let run_rsq = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            iran::sort_iran_bsp(ctx, &params, local, n, &cfg, 0xE2E)
        });
        verify(&run_rsq.outputs, n);
        let rsq_secs = run_rsq.ledger.predicted_secs(&params);

        // [DSX]: the same BSP program with the XLA local sort (L1+L2).
        let dsx_secs = match &xla {
            Some(sorter) => {
                let sorter = Arc::clone(sorter);
                let run = machine.run(|ctx| {
                    let mut local = generate_for_proc(bench, ctx.pid(), p, n / p);
                    det::sort_det_bsp_with(ctx, &params, &mut local, n, &cfg, sorter.as_ref() as &dyn SeqSorter)
                });
                verify(&run.outputs, n);
                // Also check the XLA path agrees with the quicksort path.
                let a: Vec<i32> = run.outputs.iter().flat_map(|r| r.keys.clone()).collect();
                let b: Vec<i32> = run_dsq.outputs.iter().flat_map(|r| r.keys.clone()).collect();
                assert_eq!(a, b, "XLA and quicksort backends must agree on {}", bench.tag());
                checked += 1;
                Some(run.ledger.predicted_secs(&params))
            }
            None => None,
        };

        println!(
            "{:<8} {:>12.3} {:>12.3} {:>12} {:>9.0}% {:>+11.1}%",
            bench.tag(),
            rep.predicted_secs,
            rsq_secs,
            dsx_secs.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
            100.0 * rep.efficiency(&params),
            100.0 * rep.imbalance.expansion,
        );
    }

    // Single-processor quicksort reference (the speedup denominator).
    let mut reference: Vec<i32> = (0..p).flat_map(|pid| generate_for_proc(Benchmark::Uniform, pid, p, n / p)).collect();
    let t0 = std::time::Instant::now();
    QuickSorter.sort(&mut reference);
    println!(
        "\nsequential quicksort of {n} keys on this host: {:.3} s (paper's T3D: ~3 s for 1M)",
        t0.elapsed().as_secs_f64()
    );
    if xla.is_some() {
        println!("XLA (L1 Pallas + L2 JAX via PJRT) agreed with quicksort on {checked}/7 inputs");
    }
    println!("end-to-end driver completed OK");
    Ok(())
}

fn verify(outputs: &[bsp_sort::sort::ProcResult], n: usize) {
    let mut last = i32::MIN;
    let mut total = 0usize;
    for r in outputs {
        for &k in &r.keys {
            assert!(k >= last, "not globally sorted");
            last = k;
        }
        total += r.keys.len();
    }
    assert_eq!(total, n);
}
