//! Scalability study: the Table 3 experiment as a library client.
//!
//! Sweeps p = 2..128 for all four variants on [U], printing predicted
//! T3D seconds, speedup and efficiency, and the ω-controlled imbalance.
//!
//! Run: `cargo run --release --example scalability_study [-- --n 1048576]`

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::seq::SeqSortKind;
use bsp_sort::sort::{det, iran, SortConfig};
use bsp_sort::theory;
use bsp_sort::util::cli::Args;

fn main() {
    let args = Args::from_env(&["n", "max-p"]).expect("args");
    let n: usize = args.get_parsed("n", 1 << 21).expect("--n");
    let max_p: usize = args.get_parsed("max-p", 128).expect("--max-p");

    println!("scalability of the four variants on [U], n = {n} keys");
    println!(
        "{:<8} {:>6} {:>12} {:>10} {:>10} {:>12}",
        "variant", "p", "pred secs", "speedup", "eff", "imbalance"
    );

    for (variant, seq, is_det) in [
        ("[DSR]", SeqSortKind::Radix, true),
        ("[DSQ]", SeqSortKind::Quick, true),
        ("[RSR]", SeqSortKind::Radix, false),
        ("[RSQ]", SeqSortKind::Quick, false),
    ] {
        let mut p = 2usize;
        while p <= max_p {
            if n % p != 0 {
                p *= 2;
                continue;
            }
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default().with_seq(seq);
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
                if is_det {
                    det::sort_det_bsp(ctx, &params, local, n, &cfg)
                } else {
                    iran::sort_iran_bsp(ctx, &params, local, n, &cfg, 0xCAFE)
                }
            });
            let secs = run.ledger.predicted_secs(&params);
            let t_seq = params.comp_us(theory::seq_charge(n)) / 1e6;
            let speedup = t_seq / secs;
            let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
            let expansion = max_recv as f64 / (n as f64 / p as f64) - 1.0;
            println!(
                "{:<8} {:>6} {:>12.3} {:>10.2} {:>9.0}% {:>+11.1}%",
                variant,
                p,
                secs,
                speedup,
                100.0 * speedup / p as f64,
                100.0 * expansion
            );
            p *= 2;
        }
        println!();
    }
}
