//! Duplicate-heavy workloads: the §5.1.1 story end to end.
//!
//! Sorts [DD] (deterministic duplicates) and an all-equal input with
//! SORT_DET_BSP under both duplicate policies and with PSRS, showing:
//!   * tagged handling keeps every processor's received keys within the
//!     Lemma 5.1 bound even when ALL keys are equal;
//!   * switching tags off (or using PSRS, which has none) collapses the
//!     entire input onto one processor;
//!   * the tagging overhead on duplicate-free [U] stays in single digits
//!     (the paper: 3–6 %).
//!
//! Run: `cargo run --release --example duplicate_workloads`

use bsp_sort::baselines::sort_psrs;
use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::sort::{det, DuplicatePolicy, SortConfig};

fn main() {
    let p = 8;
    let n = 1 << 19;
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);

    println!("duplicate handling on p={p}, n={n} keys\n");
    println!(
        "{:<26} {:>14} {:>14} {:>12}",
        "configuration", "max received", "bound/n", "pred secs"
    );

    let bound = det::nmax_bound(n, p, det::omega_det(&SortConfig::default(), n));

    for (name, bench, dup) in [
        ("[DD] tagged (ours)", Benchmark::DetDup, DuplicatePolicy::Tagged),
        ("[DD] tags OFF", Benchmark::DetDup, DuplicatePolicy::Off),
        ("all-equal tagged", Benchmark::Uniform, DuplicatePolicy::Tagged), // replaced below
    ] {
        let cfg = SortConfig::default().with_dup(dup);
        let all_equal = name.starts_with("all-equal");
        let run = machine.run(|ctx| {
            let local = if all_equal {
                vec![7i32; n / p]
            } else {
                generate_for_proc(bench, ctx.pid(), p, n / p)
            };
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
        println!(
            "{:<26} {:>14} {:>14} {:>12.3}",
            name,
            max_recv,
            format!("{:.2}×(n/p)", max_recv as f64 / (n as f64 / p as f64)),
            run.ledger.predicted_secs(&params),
        );
        if dup == DuplicatePolicy::Tagged {
            assert!(max_recv as f64 <= bound + 1.0, "Lemma 5.1 violated");
        }
    }

    // PSRS on all-equal input: no tags exist at all.
    let run = machine.run(|ctx| {
        let local = vec![7i32; n / p];
        sort_psrs(ctx, &params, local, &SortConfig::default())
    });
    let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
    println!(
        "{:<26} {:>14} {:>14} {:>12.3}",
        "PSRS [44] all-equal",
        max_recv,
        format!("{:.2}×(n/p)", max_recv as f64 / (n as f64 / p as f64)),
        run.ledger.predicted_secs(&params),
    );
    assert_eq!(max_recv, n, "PSRS collapses onto one processor");

    // The [U] overhead of tagging (paper: 3–6 %).
    let mut secs = [0.0f64; 2];
    for (i, dup) in [DuplicatePolicy::Tagged, DuplicatePolicy::Off].iter().enumerate() {
        let cfg = SortConfig::default().with_dup(*dup);
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        secs[i] = run.ledger.predicted_secs(&params);
    }
    println!(
        "\n[U] duplicate-tagging overhead: {:+.2}% (paper reports 3-6%)",
        100.0 * (secs[0] / secs[1] - 1.0)
    );
}
