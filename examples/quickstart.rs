//! Quickstart: sort 1M keys with SORT_DET_BSP on a simulated 16-processor
//! Cray T3D and print the predicted/measured times and the imbalance.
//!
//! Run: `cargo run --release --example quickstart`

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::metrics::RunReport;
use bsp_sort::sort::{det, SortConfig};

fn main() {
    let p = 16;
    let n = 1 << 20; // the paper's 1M = 1024×1024
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default(); // [DSQ]: quicksort + tagged duplicates

    let run = machine.run(|ctx| {
        let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
        det::sort_det_bsp(ctx, &params, local, n, &cfg)
    });

    // Verify and report.
    let mut last = i32::MIN;
    for r in &run.outputs {
        for &k in &r.keys {
            assert!(k >= last);
            last = k;
        }
    }
    let report = RunReport::new("[DSQ]", "[U]", n, &params, &run.ledger, &run.outputs);
    println!("sorted {n} keys on p={p} (SORT_DET_BSP, quicksort backend)");
    println!("predicted T3D time : {:.3} s", report.predicted_secs);
    println!("measured host time : {:.3} s", report.wall_secs);
    println!("parallel efficiency: {:.0}%", 100.0 * report.efficiency(&params));
    println!(
        "imbalance          : max {} keys vs mean {:.0} ({:+.1}%)",
        report.imbalance.max_received,
        report.imbalance.mean_received,
        100.0 * report.imbalance.expansion
    );
    println!("\nphase breakdown (predicted seconds):");
    for (ph, secs) in &report.phase_predicted {
        println!("  {ph:<16} {secs:.4}");
    }
}
