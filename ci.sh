#!/usr/bin/env bash
# Tier-1 gate plus hygiene checks.  Usage: ./ci.sh
#
# This is what .github/workflows/ci.yml runs; keep it the single source
# of truth for "does the repo pass".
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== hygiene: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all --check
else
    echo "rustfmt not installed; skipping format check"
fi

echo "CI OK"
