#!/usr/bin/env bash
# Tier-1 gate plus hygiene checks.
# Usage: ./ci.sh [--check-xla|--check-links|--conformance|--planner-smoke|--bench-baseline|--localsort-fuzz|--balance-audit|--extsort-smoke]
#
# This is what .github/workflows/ci.yml runs; keep it the single source
# of truth for "does the repo pass".
#
#   ./ci.sh               build + test + fmt + clippy + bench smoke-run
#   ./ci.sh --check-xla   verify the `xla` feature wiring (check-only):
#                         passes when the vendored crate is present, or
#                         when the only failure is the expected missing
#                         `xla` crate (the default offline setup).
#   ./ci.sh --check-links intra-repo markdown link check only (also part
#                         of the default run)
#   ./ci.sh --conformance release-mode run of the simulator-backend
#                         conformance suite (seeded property tests at
#                         p up to 4096 + backend equivalence), plus the
#                         topology-planner smoke and acceptance tests.
#                         The same suite also runs (debug) inside
#                         `cargo test`; this mode is the fast,
#                         large-p-focused CI job — single-threaded
#                         virtual processors, so its runtime does not
#                         depend on the host's core count.
#   ./ci.sh --planner-smoke
#                         just the planner smoke tests: flat at small
#                         p/cheap L, deeper topology under punishing L.
#   ./ci.sh --bench-baseline
#                         run the full throughput grid (engine pool vs
#                         per-job spin-up) and the full local-sort engine
#                         grid, rewriting BENCH_baseline.json and
#                         BENCH_hotpaths.json with this host's numbers +
#                         fingerprint, arming the >15% regression gates
#                         in the default run.
#   ./ci.sh --localsort-fuzz
#                         release-mode differential sweep of the IPS
#                         local-sort engine against quicksort/radixsort
#                         (all domains × distributions × adversarial
#                         shapes; also runs in the --conformance job).
#   ./ci.sh --balance-audit
#                         release-mode balance-envelope audit: all 11
#                         variants × full benchmark set (incl. the skew
#                         families) × p in {4,64,256,1024} on the
#                         simulator, asserting the guaranteed envelopes
#                         and rewriting docs/BALANCE.md with the
#                         measured max-received/(n/p) ratio tables
#                         (commit the file; also runs in --conformance).
#   ./ci.sh --extsort-smoke
#                         out-of-core smoke: a spill-backed external sort
#                         with a tiny --mem-budget into a private TMPDIR,
#                         asserting the sort completes and every
#                         bsp-ext-* spill directory is cleaned up
#                         afterwards (also runs in --conformance).
set -euo pipefail
cd "$(dirname "$0")"

# Check every [text](target) link in README.md and docs/*.md whose
# target is a repo-relative path (http/https/mailto and pure #anchors
# are skipped; a #fragment after a path is ignored).  Keeps the docs
# from drifting as modules move.
check_links() {
    echo "== docs: intra-repo markdown link check (README.md docs/*.md) =="
    local fail=0 f target resolved
    for f in README.md docs/*.md; do
        while IFS= read -r target; do
            [[ -z "$target" ]] && continue
            case "$target" in
                http://*|https://*|mailto:*|'#'*) continue ;;
            esac
            target="${target%%#*}"
            [[ -z "$target" ]] && continue
            resolved="$(dirname "$f")/$target"
            if [[ ! -e "$resolved" ]]; then
                echo "broken link in $f: $target (resolved $resolved)" >&2
                fail=1
            fi
        done < <(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//')
    done
    if [[ "$fail" -ne 0 ]]; then
        echo "markdown link check failed" >&2
        exit 1
    fi
    echo "markdown links OK"
}

if [[ "${1:-}" == "--check-links" ]]; then
    check_links
    exit 0
fi

planner_smoke() {
    echo "== planner smoke: flat at cheap L, deep under punishing L =="
    cargo test --release --lib planner_smoke -- --nocapture
}

if [[ "${1:-}" == "--planner-smoke" ]]; then
    planner_smoke
    exit 0
fi

localsort_fuzz() {
    echo "== localsort-fuzz: IPS vs quicksort/radixsort differential sweep (release) =="
    cargo test --release --test localsort_diff -- --nocapture
}

if [[ "${1:-}" == "--localsort-fuzz" ]]; then
    localsort_fuzz
    exit 0
fi

balance_audit() {
    echo "== balance-audit: envelope assertions + docs/BALANCE.md rewrite (release) =="
    BALANCE_AUDIT_WRITE="$(pwd)/docs/BALANCE.md" \
        cargo test --release --test balance_audit -- --nocapture
    echo "docs/BALANCE.md rewritten; commit it to record this sweep's ratios"
}

if [[ "${1:-}" == "--balance-audit" ]]; then
    balance_audit
    exit 0
fi

extsort_smoke() {
    echo "== extsort-smoke: spill-backed external sort + temp-dir hygiene (release) =="
    local spilldir leftovers
    spilldir=$(mktemp -d)
    # Budget far below n forces multiple spilled runs per processor; the
    # private TMPDIR means any leftover bsp-ext-* spill directory is ours.
    TMPDIR="$spilldir" cargo run --release --quiet -- \
        sort --external --mem-budget 1024 --n 65536 --p 4 --bench U
    leftovers=$(find "$spilldir" -mindepth 1 -maxdepth 1 -name 'bsp-ext-*' | wc -l)
    if [[ "$leftovers" -ne 0 ]]; then
        echo "extsort-smoke FAILED: $leftovers spill dir(s) left behind in $spilldir:" >&2
        find "$spilldir" -mindepth 1 -maxdepth 1 >&2
        rm -rf "$spilldir"
        exit 1
    fi
    rm -rf "$spilldir"
    echo "extsort smoke OK (sorted under a 1024-key budget; spill dirs cleaned up)"
}

if [[ "${1:-}" == "--extsort-smoke" ]]; then
    extsort_smoke
    exit 0
fi

if [[ "${1:-}" == "--conformance" ]]; then
    echo "== conformance: simulator-backend property suite (release) =="
    cargo test --release --test conformance -- --nocapture
    echo "== extsort conformance: external vs in-core bit-identity (release) =="
    cargo test --release --test extsort_conformance -- --nocapture
    planner_smoke
    echo "== planner acceptance: chosen topology within 10% of exhaustive minimum =="
    cargo test --release --test planner_acceptance -- --nocapture
    localsort_fuzz
    balance_audit
    extsort_smoke
    exit 0
fi

if [[ "${1:-}" == "--bench-baseline" ]]; then
    echo "== throughput: full grid, rewriting BENCH_baseline.json =="
    # cargo runs bench binaries with the package dir as cwd; hand it an
    # absolute path so the baseline lands at the repo root.
    cargo bench --bench throughput -- --json "$(pwd)/BENCH_baseline.json"
    echo "== hot_paths: full local-sort grid, rewriting BENCH_hotpaths.json =="
    cargo bench --bench hot_paths -- --json "$(pwd)/BENCH_hotpaths.json"
    echo "baselines refreshed for this host; commit both JSON files to arm the regression gates"
    exit 0
fi

if [[ "${1:-}" == "--check-xla" ]]; then
    echo "== check-only: cargo check --features xla =="
    log=$(mktemp)
    if cargo check --features xla 2>"$log"; then
        echo "xla feature checked clean (vendored xla crate present)"
    else
        # Accept the failure only when EVERY error is the expected
        # missing vendored `xla` crate (or the compile-summary lines it
        # causes) — any other error means the wiring itself is broken.
        expected="(can't find crate for .?xla|undeclared crate or module .?xla"
        expected+="|unresolved import .?xla|could not compile|aborting due to)"
        if ! grep -q "^error" "$log"; then
            cat "$log" >&2
            echo "check failed without compiler errors (?)" >&2
            exit 1
        fi
        if grep "^error" "$log" | grep -vqE "$expected"; then
            cat "$log" >&2
            echo "unexpected errors under --features xla (beyond the missing vendored crate)" >&2
            exit 1
        fi
        echo "xla feature wiring OK (vendored xla crate absent — expected offline)"
    fi
    rm -f "$log"
    exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== hygiene: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all --check
else
    echo "rustfmt not installed; skipping format check"
fi

echo "== hygiene: cargo clippy --all-targets -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed; skipping lint gate"
fi

echo "== hygiene: cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

check_links

# The >15% regression gates only bite when the committed baseline was
# measured on this host; the seed baselines ship with a
# "placeholder/unmeasured/0cpu" fingerprint, under which --compare
# schema-validates but never fails on a regression.  Make that state
# loud — an unarmed gate must not masquerade as a passing one.
warn_unarmed() {
    local baseline="$1" gate="$2"
    if grep -q '"fingerprint": "placeholder/unmeasured/0cpu"' "$baseline"; then
        echo "##############################################################"
        echo "## GATE UNARMED: $baseline carries the placeholder"
        echo "## fingerprint — the $gate regression gate is NOT enforcing."
        echo "## Run ./ci.sh --bench-baseline on this host and commit the"
        echo "## refreshed baseline to arm it."
        echo "##############################################################"
        # Surfaces as an annotation in GitHub Actions; harmless elsewhere.
        echo "::warning file=$baseline::GATE UNARMED: placeholder fingerprint — $gate regression gate is not enforcing"
    fi
}

echo "== bench smoke-run: hot_paths --quick-smoke + local-sort baseline gate =="
# Schema-validates BENCH_hotpaths.json and — when the committed baseline
# carries this host's fingerprint — fails on a >15% keys/sec regression
# in any shared local-sort grid cell.  The ips-vs-lsd-radix acceptance
# floor applies on full (non-smoke) runs, which measure the n=1e6 cells.
warn_unarmed "$(pwd)/BENCH_hotpaths.json" "local-sort"
cargo bench --bench hot_paths -- --quick-smoke --compare "$(pwd)/BENCH_hotpaths.json"

echo "== bench smoke-run: throughput --quick-smoke + baseline gate =="
# Schema-validates BENCH_baseline.json, enforces the pool-speedup floor
# on the acceptance cell (n=1e4, 16 submitters), and — when the
# committed baseline carries this host's fingerprint — fails on a >15%
# pool jobs/sec regression in any shared cell.
warn_unarmed "$(pwd)/BENCH_baseline.json" "throughput"
cargo bench --bench throughput -- --quick-smoke --compare "$(pwd)/BENCH_baseline.json"

echo "== smoke: experiment --quick writes a schema-valid BENCH json =="
smokedir=$(mktemp -d)
# The CLI itself re-reads and schema-validates the JSON it writes, so a
# zero exit already covers validity; the checks below additionally pin
# the file name and the schema tag CI consumers rely on.
cargo run --release --quiet -- experiment --quick --tag smoke --out "$smokedir"
test -s "$smokedir/BENCH_smoke.json" || {
    echo "BENCH_smoke.json missing or empty" >&2; exit 1; }
grep -q '"schema": "bsp-sort/experiment-report/v5"' "$smokedir/BENCH_smoke.json" || {
    echo "schema tag missing from BENCH_smoke.json" >&2; exit 1; }
# The quick preset rides one skew-benchmark cell (det @ [Z-100] @ p=8).
grep -q '"bench": "\[Z-100\]"' "$smokedir/BENCH_smoke.json" || {
    echo "zipf smoke cell missing from BENCH_smoke.json" >&2; exit 1; }
test -s "$smokedir/BENCH_smoke.md" || {
    echo "BENCH_smoke.md missing or empty" >&2; exit 1; }
rm -rf "$smokedir"

echo "CI OK"
