"""Layer-2 correctness: the hybrid block/cross-block local_sort graph."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import bitonic, ref


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    logn=st.integers(2, 12),
    logblk=st.integers(1, 8),
)
def test_local_sort_matches_ref(seed, logn, logblk):
    if logblk > logn:
        logblk = logn
    n, blk = 1 << logn, 1 << logblk
    rng = np.random.default_rng(seed)
    x = rng.integers(-(2**31), 2**31 - 1, size=n, dtype=np.int32)
    got = np.asarray(model.local_sort(jnp.asarray(x), blk))
    want = np.asarray(ref.local_sort_ref(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_local_sort_duplicate_heavy(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 3, size=1 << 10, dtype=np.int32)
    got = np.asarray(model.local_sort(jnp.asarray(x), 64))
    np.testing.assert_array_equal(got, np.sort(x))


def test_local_sort_with_pad_sentinels():
    """The Rust runtime pads partial inputs with PAD_MAX; sentinels must
    land at the tail."""
    x = np.concatenate(
        [
            np.array([5, -7, 3], dtype=np.int32),
            np.full(13, int(bitonic.PAD_MAX), dtype=np.int32),
        ]
    )
    got = np.asarray(model.local_sort(jnp.asarray(x), 8))
    np.testing.assert_array_equal(got[:3], [-7, 3, 5])
    assert (got[3:] == int(bitonic.PAD_MAX)).all()


def test_local_sort_blk_equals_n():
    x = np.array([4, 2, 9, 1], dtype=np.int32)
    got = np.asarray(model.local_sort(jnp.asarray(x), 4))
    np.testing.assert_array_equal(got, [1, 2, 4, 9])


def test_local_sort_rejects_oversized_blk():
    with pytest.raises(ValueError):
        model.local_sort(jnp.zeros(4, jnp.int32), 8)


def test_local_sort_rejects_non_pow2():
    with pytest.raises(ValueError):
        model.local_sort(jnp.zeros(6, jnp.int32), 2)


def test_jit_roundtrip_default_blk():
    """The exact unit aot.py lowers, under jit, on a realistic size."""
    n = 1 << 12
    rng = np.random.default_rng(0)
    x = rng.integers(-(2**31), 2**31 - 1, size=n, dtype=np.int32)
    fn = jax.jit(model.local_sort_fn(n, min(model.DEFAULT_BLK, n)))
    (got,) = fn(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.sort(x))
