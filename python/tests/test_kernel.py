"""Layer-1 correctness: Pallas bitonic kernels vs the pure-jnp oracle.

hypothesis sweeps shapes/dtypes/seeds; every case asserts exact equality
(integer sort — no tolerance needed).
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import bitonic, ref


def _rand_rows(seed: int, b: int, blk: int, lo=-(2**31), hi=2**31 - 1):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, size=(b, blk), dtype=np.int32)


def _dirs(seed: int, b: int):
    rng = np.random.default_rng(seed + 1)
    return rng.integers(0, 2, size=(b, 1), dtype=np.int32)


# ---------------------------------------------------------------- block_sort


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    logb=st.integers(0, 4),
    logblk=st.integers(1, 9),
)
def test_block_sort_matches_ref(seed, logb, logblk):
    b, blk = 1 << logb, 1 << logblk
    x = _rand_rows(seed, b, blk)
    d = _dirs(seed, b)
    got = np.asarray(bitonic.block_sort(jnp.asarray(x), jnp.asarray(d)))
    want = np.asarray(ref.sort_rows_ref(jnp.asarray(x), jnp.asarray(d)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), logblk=st.integers(1, 8))
def test_block_sort_duplicate_heavy(seed, logblk):
    """Duplicates are the paper's pathological case; sweep a tiny value set."""
    blk = 1 << logblk
    x = _rand_rows(seed, 4, blk, lo=0, hi=4)
    d = _dirs(seed, 4)
    got = np.asarray(bitonic.block_sort(jnp.asarray(x), jnp.asarray(d)))
    want = np.asarray(ref.sort_rows_ref(jnp.asarray(x), jnp.asarray(d)))
    np.testing.assert_array_equal(got, want)


def test_block_sort_all_equal():
    x = np.full((2, 64), 7, dtype=np.int32)
    d = np.array([[1], [0]], dtype=np.int32)
    got = np.asarray(bitonic.block_sort(jnp.asarray(x), jnp.asarray(d)))
    np.testing.assert_array_equal(got, x)


def test_block_sort_presorted_and_reversed():
    asc = np.arange(128, dtype=np.int32)[None, :]
    x = np.concatenate([asc, asc[:, ::-1]], axis=0)
    d = np.array([[1], [1]], dtype=np.int32)
    got = np.asarray(bitonic.block_sort(jnp.asarray(x), jnp.asarray(d)))
    np.testing.assert_array_equal(got, np.concatenate([asc, asc], axis=0))


def test_block_sort_extremes():
    """INT_MIN / INT_MAX / PAD_MAX sentinels must sort correctly."""
    x = np.array(
        [[2**31 - 1, -(2**31), 0, -1, 1, 2**31 - 1, -(2**31), 5]],
        dtype=np.int32,
    )
    d = np.ones((1, 1), dtype=np.int32)
    got = np.asarray(bitonic.block_sort(jnp.asarray(x), jnp.asarray(d)))
    np.testing.assert_array_equal(got, np.sort(x, axis=-1))


def test_block_sort_rejects_non_pow2():
    with pytest.raises(ValueError):
        bitonic.block_sort(jnp.zeros((1, 3), jnp.int32), jnp.ones((1, 1), jnp.int32))


# --------------------------------------------------------------- block_merge


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), logblk=st.integers(1, 9))
def test_block_merge_completes_bitonic_rows(seed, logblk):
    """Feed genuinely bitonic rows (asc run + desc run); the merge must
    produce the row fully sorted in its direction."""
    blk = 1 << logblk
    rng = np.random.default_rng(seed)
    rows = []
    for r in range(4):
        vals = np.sort(rng.integers(-(2**31), 2**31 - 1, size=blk, dtype=np.int32))
        cut = int(rng.integers(0, blk + 1))
        row = np.concatenate([vals[:cut], vals[cut:][::-1]])
        rows.append(row)
    x = np.stack(rows)
    d = _dirs(seed, 4)
    got = np.asarray(bitonic.block_merge(jnp.asarray(x), jnp.asarray(d)))
    want = np.asarray(ref.merge_stage_ref(jnp.asarray(x), jnp.asarray(d)))
    np.testing.assert_array_equal(got, want)


def test_compare_exchange_basic():
    x = jnp.asarray(np.array([3, 1, 2, 0], dtype=np.int32))
    asc = jnp.ones((1, 1), dtype=bool)
    y = np.asarray(bitonic._compare_exchange(x, 2, asc))
    np.testing.assert_array_equal(y, [2, 0, 3, 1])
