"""Layer-1 Pallas kernels: bitonic sorting network over VMEM-resident blocks.

The paper's hot spot is the per-processor local sort (50-65% of running
time, Tables 4-7).  On the paper's Cray T3D this was a tuned sequential
quicksort/radixsort.  The TPU-idiomatic rethink (DESIGN.md
section "Hardware adaptation") is an *oblivious* sorting network:

  * no data-dependent control flow -> perfect for the VPU's SIMD lanes;
  * each block of 2^m keys lives entirely in VMEM for the whole network
    (BlockSpec carves the (B, BLK) input into per-row blocks);
  * the compare-exchange of a bitonic substage is expressible as a
    reshape + minimum/maximum + select, i.e. pure vector ops, no gather.

Two kernels:

  ``block_sort``   -- full bitonic sort of each row, with a per-row
                      direction flag (rows must alternate asc/desc so the
                      result is stage-``BLK`` bitonic input for the
                      cross-block stages handled at Layer 2).
  ``block_merge``  -- the within-block tail (substages j = BLK/2 .. 1) of
                      a cross-block bitonic stage, again with a per-row
                      direction flag.

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness (vs ``ref.py``) is what we validate
here; TPU performance is estimated from the VMEM footprint in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel used to pad partial blocks: sorts after every real key.
PAD_MAX = jnp.iinfo(jnp.int32).max


def _compare_exchange(x: jax.Array, j: int, asc_groups: jax.Array) -> jax.Array:
    """One bitonic substage with partner distance ``j`` over a 1-D row.

    ``asc_groups`` has shape (n // (2*j), 1): the sort direction of each
    group of ``2*j`` adjacent lanes.  Implemented as reshape + min/max so
    it lowers to pure vector ops (no gather/scatter).
    """
    n = x.shape[-1]
    y = x.reshape(n // (2 * j), 2, j)
    a, b = y[:, 0, :], y[:, 1, :]
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    a2 = jnp.where(asc_groups, lo, hi)
    b2 = jnp.where(asc_groups, hi, lo)
    return jnp.stack([a2, b2], axis=1).reshape(n)


def _bitonic_sort_row(x: jax.Array) -> jax.Array:
    """Full ascending bitonic sort of a 1-D row of power-of-two length.

    Classic network: stage k (run length) = 2, 4, ..., n; substage j =
    k/2 ... 1.  The direction of lane i at stage k is ``(i & k) == 0``;
    within a group of 2*j adjacent lanes this is constant, so it becomes
    a per-group column vector.
    """
    n = x.shape[-1]
    lanes = jnp.arange(n, dtype=jnp.int32)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            group_base = lanes[:: 2 * j]  # first lane of each group
            asc = ((group_base & k) == 0)[:, None]
            if k == n:
                # Final merge stage: all-ascending.
                asc = jnp.ones_like(asc)
            x = _compare_exchange(x, j, asc)
            j //= 2
        k *= 2
    return x


def _block_sort_kernel(x_ref, dir_ref, o_ref):
    """Sort one row ascending, then flip if the row direction is desc."""
    row = x_ref[0, :]
    row = _bitonic_sort_row(row)
    asc = dir_ref[0, 0] != 0
    o_ref[0, :] = jnp.where(asc, row, row[::-1])


def _block_merge_kernel(x_ref, dir_ref, o_ref):
    """Within-block tail of a cross-block stage: substages j=BLK/2..1.

    The row is bitonic; the global stage k > BLK means the direction is
    constant across the whole row (bit k of the global index depends only
    on the row id), carried in ``dir_ref``.
    """
    row = x_ref[0, :]
    n = row.shape[-1]
    asc_scalar = (dir_ref[0, 0] != 0)
    j = n // 2
    while j >= 1:
        asc = jnp.full((n // (2 * j), 1), asc_scalar)
        row = _compare_exchange(row, j, asc)
        j //= 2
    o_ref[0, :] = row


def _row_grid_call(kernel, x: jax.Array, dirs: jax.Array) -> jax.Array:
    """Launch ``kernel`` over a (B, BLK) array, one grid step per row.

    BlockSpec pins one (1, BLK) row of keys plus its (1, 1) direction flag
    into VMEM per step -- this is the HBM<->VMEM schedule the paper
    expressed with per-processor local memory.
    """
    b, blk = x.shape
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, blk), x.dtype),
        interpret=True,
    )(x, dirs)


def block_sort(x: jax.Array, dirs: jax.Array) -> jax.Array:
    """Bitonic-sort each row of ``x`` (shape (B, BLK), BLK a power of 2).

    ``dirs`` is (B, 1) int32; nonzero = ascending, zero = descending.
    """
    _check_pow2(x.shape[-1])
    return _row_grid_call(_block_sort_kernel, x, dirs)


def block_merge(x: jax.Array, dirs: jax.Array) -> jax.Array:
    """Run the within-block substages of one cross-block bitonic stage."""
    _check_pow2(x.shape[-1])
    return _row_grid_call(_block_merge_kernel, x, dirs)


def _check_pow2(n: int) -> None:
    if n & (n - 1) or n == 0:
        raise ValueError(f"block length must be a power of two, got {n}")


@jax.jit
def bitonic_sort_jnp_row(x: jax.Array) -> jax.Array:  # pragma: no cover
    """Non-pallas row sort used in microbenchmarks (same network, pure jnp)."""
    return _bitonic_sort_row(x)
