"""Pure-jnp oracles for the Pallas kernels (correctness references).

Everything in this file is the "obviously correct" formulation; the pytest
suite asserts the Pallas kernels and the Layer-2 model agree with these on
randomized sweeps (see python/tests/).
"""

import jax
import jax.numpy as jnp


def sort_rows_ref(x: jax.Array, dirs: jax.Array) -> jax.Array:
    """Reference for kernels.bitonic.block_sort: per-row directed sort."""
    asc = jnp.sort(x, axis=-1)
    desc = asc[:, ::-1]
    return jnp.where(dirs != 0, asc, desc)


def local_sort_ref(x: jax.Array) -> jax.Array:
    """Reference for model.local_sort: a flat ascending sort."""
    return jnp.sort(x)


def merge_stage_ref(x: jax.Array, dirs: jax.Array) -> jax.Array:
    """Reference for block_merge: each row is bitonic, the result is the
    row sorted in its given direction (a bitonic merge completes a sort
    of a bitonic sequence)."""
    return sort_rows_ref(x, dirs)
