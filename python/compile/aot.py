"""AOT entrypoint: lower the Layer-2 local-sort graphs to HLO *text*.

HLO text (not ``lowered.compile().serialize()`` and not a serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Python runs ONLY here, at build time; the Rust coordinator loads the
emitted ``artifacts/local_sort_<n>.hlo.txt`` via PJRT and never touches
Python on the sort path.  ``make artifacts`` skips the rebuild when the
outputs are newer than their inputs.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_local_sort(n: int, blk: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    lowered = jax.jit(model.local_sort_fn(n, blk)).lower(spec)
    return to_hlo_text(lowered)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in model.ARTIFACT_SIZES),
        help="comma-separated power-of-two input sizes to lower",
    )
    ap.add_argument("--blk", type=int, default=model.DEFAULT_BLK)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    sizes = [int(s) for s in args.sizes.split(",") if s]
    manifest = {"blk": args.blk, "dtype": "s32", "artifacts": {}}
    for n in sizes:
        blk = min(args.blk, n)
        text = lower_local_sort(n, blk)
        name = model.artifact_name(n)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][str(n)] = f"{name}.hlo.txt"
        print(f"wrote {path} ({len(text)} chars, blk={blk})")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
