"""Layer-2 JAX compute graph: the full local-sort used by the coordinator.

The paper's per-processor local sort (Ph2 of Tables 4-7, 50-65% of total
running time) is rebuilt here as a hybrid bitonic network:

  1. the flat input of N = B * BLK int32 keys is reshaped to (B, BLK);
  2. the L1 Pallas kernel ``block_sort`` sorts every block in VMEM with
     alternating directions (completing the global stages k = 2 .. BLK);
  3. the remaining global stages k = 2*BLK .. N interleave
       - cross-block compare-exchanges (substages j >= BLK) expressed as
         pure jnp min/max over row pairs -- these are HBM-level data
         movements XLA fuses freely, and
       - the within-block tail (substages j = BLK/2 .. 1) via the L1
         ``block_merge`` kernel;
  4. the result is the flat ascending sort of the input.

Everything is static-shaped; `aot.py` lowers one executable per size so
the Rust coordinator (Layer 3) can load and run them with zero Python on
the sort path.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import bitonic


def _log2(n: int) -> int:
    if n & (n - 1) or n <= 0:
        raise ValueError(f"expected a power of two, got {n}")
    return n.bit_length() - 1


def _cross_block_exchange(x: jax.Array, row_dist: int, asc_rows: jax.Array) -> jax.Array:
    """Compare-exchange rows b and b ^ row_dist, direction per row.

    Because partner rows share the direction bit (the stage bit k is above
    the substage bit j), both sides of a pair see the same ``asc``.
    """
    b = x.shape[0]
    y = x.reshape(b // (2 * row_dist), 2, row_dist, x.shape[1])
    lo = jnp.minimum(y[:, 0], y[:, 1])
    hi = jnp.maximum(y[:, 0], y[:, 1])
    asc = asc_rows.reshape(b // (2 * row_dist), 2, row_dist, 1)[:, 0]
    top = jnp.where(asc, lo, hi)
    bot = jnp.where(asc, hi, lo)
    return jnp.stack([top, bot], axis=1).reshape(b, x.shape[1])


def local_sort(x: jax.Array, blk: int) -> jax.Array:
    """Ascending sort of a flat int32 array of power-of-two length.

    ``blk`` is the VMEM block length (power of two, <= len(x)).  The caller
    (aot.py / Rust runtime) pads partial inputs with ``bitonic.PAD_MAX``.
    """
    n = x.shape[-1]
    m = _log2(n)
    mb = _log2(blk)
    if blk > n:
        raise ValueError(f"blk {blk} exceeds input length {n}")

    nrows = n // blk
    x = x.reshape(nrows, blk)
    rows = jnp.arange(nrows, dtype=jnp.int32)[:, None]

    # Stages k = 2 .. BLK: direction of row b at the final within-block
    # stage is bit mb of the global index = bit 0 of b.
    if n == blk:
        dirs = jnp.ones((1, 1), jnp.int32)
    else:
        dirs = ((rows & 1) == 0).astype(jnp.int32)
    x = bitonic.block_sort(x, dirs)

    # Stages k = 2*BLK .. N.  Direction of element i at stage k is
    # (i & k) == 0; since k >= 2*BLK this is a per-row constant, and for
    # the final stage k = N it is identically ascending (i < N).
    for ks in range(mb + 1, m + 1):  # k = 1 << ks
        k_rows = 1 << (ks - mb)  # stage bit measured in rows
        asc_rows = ((rows & k_rows) == 0).astype(jnp.int32)
        # Cross-block substages j = k/2 .. BLK (in rows: k_rows/2 .. 1).
        jr = k_rows // 2
        while jr >= 1:
            x = _cross_block_exchange(x, jr, asc_rows)
            jr //= 2
        # Within-block tail j = BLK/2 .. 1.
        x = bitonic.block_merge(x, asc_rows)

    return x.reshape(n)


def local_sort_fn(n: int, blk: int):
    """A jit-able closure sorting int32[n]; the unit aot.py lowers."""

    def fn(x):
        return (local_sort(x, blk),)

    return fn


# Default block length: 1024 int32 keys = 4 KiB per row buffer; with the
# double-buffered in/out pair and the direction scalar this is ~8 KiB of
# VMEM per grid step, far under the ~16 MiB VMEM budget -- chosen small to
# keep the unrolled network per kernel shallow (lg^2(1024)/2 = 55 substages)
# and let the grid pipeline HBM<->VMEM transfers across rows.
DEFAULT_BLK = 1024

# Sizes lowered by `make artifacts`; the Rust XlaSort backend picks the
# smallest artifact >= its input and pads with PAD_MAX.
ARTIFACT_SIZES = (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20)


def artifact_name(n: int) -> str:
    return f"local_sort_{n}"
