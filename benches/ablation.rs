//! `cargo bench --bench ablation` — design-choice ablations DESIGN.md
//! calls out:
//!
//!   * [BSI] vs SORT_DET_BSP crossover: Batcher wins only at very small
//!     n/p (§6.2 item 3);
//!   * sample-sort method: parallel bitonic vs sequential-at-proc-0
//!     (§5.2 point 2);
//!   * oversampling ω sweep: imbalance vs sampling cost (the paper's
//!     "precise tuning of oversampling" claim);
//!   * duplicate policy: the 3–6 % overhead (§6.1).

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::sort::{bsi, det, det_iterative, DuplicatePolicy, SampleSortMethod, SortConfig};
use bsp_sort::util::bench::black_box;

fn predicted_det(p: usize, n: usize, cfg: &SortConfig) -> (f64, usize) {
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = *cfg;
    let run = machine.run(move |ctx| {
        let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
        det::sort_det_bsp(ctx, &params, local, n, &cfg)
    });
    let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
    (run.ledger.predicted_secs(&params), max_recv)
}

fn main() {
    let p = 8;

    // --- [BSI] crossover ---------------------------------------------------
    println!("== ablation: [BSI] vs SORT_DET_BSP (predicted T3D seconds) ==");
    println!("{:>10} {:>12} {:>12} {:>8}", "n", "[BSI]", "[DSQ]", "winner");
    for logn in [10usize, 12, 14, 17, 20] {
        let n = 1 << logn;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            bsi::sort_bsi(ctx, local, &cfg)
        });
        let bsi_secs = run.ledger.predicted_secs(&params);
        let (det_secs, _) = predicted_det(p, n, &cfg);
        println!(
            "{:>10} {:>12.4} {:>12.4} {:>8}",
            n,
            bsi_secs,
            det_secs,
            if bsi_secs < det_secs { "[BSI]" } else { "[DSQ]" }
        );
        black_box((bsi_secs, det_secs));
    }

    // --- sample sort method -------------------------------------------------
    println!("\n== ablation: parallel bitonic vs sequential sample sort ==");
    println!("{:>10} {:>14} {:>14}", "n", "bitonic", "sequential");
    for logn in [16usize, 20] {
        let n = 1 << logn;
        let (bit, _) = predicted_det(p, n, &SortConfig::default().with_sample_sort(SampleSortMethod::Bitonic));
        let (seqm, _) = predicted_det(p, n, &SortConfig::default().with_sample_sort(SampleSortMethod::Sequential));
        println!("{:>10} {:>14.4} {:>14.4}", n, bit, seqm);
    }

    // --- ω sweep --------------------------------------------------------------
    println!("\n== ablation: oversampling ω vs imbalance (n=1M, p=8) ==");
    println!("{:>6} {:>14} {:>14}", "ω", "pred secs", "max recv");
    let n = 1 << 20;
    for omega in [1.0f64, 2.0, 4.0, 8.0, 16.0, 32.0] {
        let cfg = SortConfig::default().with_omega(omega);
        let (secs, max_recv) = predicted_det(p, n, &cfg);
        println!("{:>6} {:>14.4} {:>14}", omega, secs, max_recv);
    }

    // --- rounds: one-round vs two-round deterministic sort -----------------
    println!("\n== ablation: one-round vs two-round SORT_DET_BSP (p=16) ==");
    println!("{:>10} {:>14} {:>14}", "n", "1 round", "2 rounds");
    for logn in [16usize, 20] {
        let n = 1 << logn;
        let p16 = 16;
        let (one, _) = {
            let params = cray_t3d(p16);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default();
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p16, n / p16);
                det::sort_det_bsp(ctx, &params, local, n, &cfg)
            });
            (run.ledger.predicted_secs(&params), 0)
        };
        let (two, _) = {
            let params = cray_t3d(p16);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default();
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p16, n / p16);
                det_iterative::sort_det_iterative(ctx, &params, local, n, &cfg)
            });
            (run.ledger.predicted_secs(&params), 0)
        };
        println!("{:>10} {:>14.4} {:>14.4}", n, one, two);
    }

    // --- duplicate policy --------------------------------------------------
    println!("\n== ablation: duplicate tagging overhead on [U] (n=1M, p=8) ==");
    let (tagged, _) = predicted_det(p, n, &SortConfig::default());
    let (off, _) = predicted_det(p, n, &SortConfig::default().with_dup(DuplicatePolicy::Off));
    println!(
        "tagged {tagged:.4}s vs off {off:.4}s  -> overhead {:+.2}% (paper: 3-6%)",
        100.0 * (tagged / off - 1.0)
    );
}
