//! `cargo bench --bench tables` — regenerate every paper table (scaled
//! grid by default; set BENCH_FULL=1 for the paper's full 64M/128p grid)
//! and print them, timing each regeneration.
//!
//! One bench per paper table (DESIGN.md §5), plus the three in-text
//! validations.  This is the canonical reproduction entry point; its
//! output is what EXPERIMENTS.md records.

use bsp_sort::tables::{self, validate, TableOpts};
use bsp_sort::util::bench::{bench_cfg, BenchConfig};

fn opts() -> TableOpts {
    if std::env::var("BENCH_FULL").is_ok() {
        TableOpts::full()
    } else {
        TableOpts {
            // Scaled default: 2M keys / 64 procs keeps the full 11-table
            // sweep tractable on a small host while preserving shape.
            max_n: std::env::var("BENCH_MAX_N")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(2 * tables::MEG),
            max_p: 64,
            seed: 0x0BEE,
            reps: 1,
        }
    }
}

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 0,
        measure_iters: 1,
        max_total: std::time::Duration::from_secs(3600),
    };
    let opts = opts();
    for num in 1..=11usize {
        let name = format!("table{num}");
        let mut rendered = String::new();
        bench_cfg(&name, &cfg, &mut |_| {
            let out = tables::run_table(num, &opts).unwrap();
            rendered = out.render();
            out.rows.len()
        });
        println!("{rendered}");
    }
    for (name, f) in [
        ("validate-g", validate::validate_g as fn(&TableOpts) -> tables::TableOutput),
        ("predict", validate::predict),
        ("ablate-dup", validate::ablate_duplicates),
    ] {
        let mut rendered = String::new();
        bench_cfg(name, &cfg, &mut |_| {
            let out = f(&opts);
            rendered = out.render();
            out.rows.len()
        });
        println!("{rendered}");
    }
}
