//! `cargo bench --bench hot_paths` — microbenchmarks of the performance-
//! critical substrates (the §Perf targets in EXPERIMENTS.md):
//!
//!   * sequential sorts (quicksort, radixsort) at 1M keys,
//!   * p-way loser-tree merge,
//!   * the engine's all-to-all routing superstep,
//!   * end-to-end SORT_DET_BSP / SORT_IRAN_BSP at 2M keys / 8 procs,
//!   * XLA local sort via PJRT when artifacts exist.
//!
//! `--quick-smoke` (the CI gate: `cargo bench --bench hot_paths --
//! --quick-smoke`) shrinks every size and iteration count so the whole
//! file runs in seconds — benchmark code can no longer rot silently.
//!
//! The local-sort engine grid (n ∈ {10⁴, 10⁵, 10⁶} × five key domains ×
//! {quicksort, lsd-radix, ips}) additionally supports:
//!   --json <path>       write the grid as a hotpaths-baseline JSON
//!   --compare <path>    validate a committed baseline: schema check
//!                       always; IPS-vs-radix acceptance floor at
//!                       n = 10⁶ on u64 when that cell ran; a >15%
//!                       keys/sec regression gate when the baseline was
//!                       recorded on this host (refresh with
//!                       ./ci.sh --bench-baseline)

use bsp_sort::bsp::{cray_t3d, BspMachine, Payload};
use bsp_sort::experiment::{calibrate_host, ProbePlan};
use bsp_sort::gen::{generate_for_proc, generate_typed_for_proc, Benchmark, GenKey};
use bsp_sort::key::{RadixKey, F64, Record, Str};
use bsp_sort::seq;
use bsp_sort::sort::{det, iran, LocalSortEngine, SortConfig, ALL_ENGINES};
use bsp_sort::util::bench::bench;
use bsp_sort::util::json::Json;
use bsp_sort::util::rng::SplitMix64;

const LOCALSORT_SCHEMA: &str = "bsp-sort/hotpaths-baseline/v1";
/// The acceptance cell (ROADMAP 5b / PR 8): IPS must be no slower than
/// LSD radix at n = 10⁶ on the widest fixed-width domain.
const ACCEPT_N: usize = 1_000_000;
const ACCEPT_DOMAIN: &str = "u64";

/// One measured cell of the local-sort engine grid.
struct GridCell {
    n: usize,
    domain: &'static str,
    engine: LocalSortEngine,
    keys_per_sec: f64,
}

fn fingerprint() -> String {
    format!("{}/{}/{}cpu", std::env::consts::OS, std::env::consts::ARCH, threads())
}

fn threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Measure every engine on one (domain, n) input; inputs are uniform
/// keys from the study generator so engines face identical data.
fn grid_domain<K: GenKey + RadixKey>(n: usize, cells: &mut Vec<GridCell>) {
    let base: Vec<K> = generate_typed_for_proc(Benchmark::Uniform, 0, 1, n);
    for engine in ALL_ENGINES {
        let sorter = seq::backend::<K>(engine.seq_kind());
        let name = format!("localsort/{}/{}/n{n}", engine.tag(), K::NAME);
        let Some(stats) = bench(&name, |_| {
            let mut keys = base.clone();
            sorter.sort(&mut keys);
            keys.len()
        }) else {
            continue; // filtered out by BENCH_FILTER
        };
        cells.push(GridCell {
            n,
            domain: K::NAME,
            engine,
            keys_per_sec: n as f64 / stats.mean.as_secs_f64().max(1e-12),
        });
    }
}

fn grid_to_json(cells: &[GridCell]) -> Json {
    let obj = |fields: Vec<(&str, Json)>| {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    };
    obj(vec![
        ("schema", Json::str(LOCALSORT_SCHEMA)),
        (
            "host",
            obj(vec![
                ("fingerprint", Json::str(fingerprint())),
                ("threads", Json::num(threads() as f64)),
            ]),
        ),
        ("bench", Json::str("uniform")),
        (
            "cells",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("n", Json::num(c.n as f64)),
                            ("domain", Json::str(c.domain)),
                            ("engine", Json::str(c.engine.tag())),
                            ("keys_per_sec", Json::num(c.keys_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Baseline gate.  Always: schema tag + structural validity, plus the
/// IPS-vs-radix acceptance floor on this run's cells when the n = 10⁶
/// u64 pair was measured.  Additionally, when the baseline's host
/// fingerprint matches this host: fail on a >15% keys/sec regression in
/// any cell present in both runs.
fn grid_compare(path: &str, cells: &[GridCell]) -> Result<(), String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read baseline {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("baseline {path}: {e}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some(LOCALSORT_SCHEMA) {
        return Err(format!("baseline {path}: schema tag is not {LOCALSORT_SCHEMA:?}"));
    }
    let base_cells = doc
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("baseline {path}: missing cells array"))?;
    for c in base_cells {
        if c.get("n").and_then(Json::as_f64).is_none()
            || c.get("domain").and_then(Json::as_str).is_none()
            || c.get("engine").and_then(Json::as_str).is_none()
            || c.get("keys_per_sec").and_then(Json::as_f64).is_none()
        {
            return Err(format!(
                "baseline {path}: cell lacks n/domain/engine/keys_per_sec"
            ));
        }
    }

    // Acceptance: IPS ≥ 0.95× LSD radix at n = 10⁶ on u64 (the 5%
    // tolerance absorbs run-to-run noise; "no slower" is the claim).
    let find = |engine: LocalSortEngine| {
        cells
            .iter()
            .find(|c| c.n == ACCEPT_N && c.domain == ACCEPT_DOMAIN && c.engine == engine)
    };
    if let (Some(ips), Some(radix)) =
        (find(LocalSortEngine::Ips), find(LocalSortEngine::LsdRadix))
    {
        if ips.keys_per_sec < 0.95 * radix.keys_per_sec {
            return Err(format!(
                "ips {:.0} keys/sec slower than lsd-radix {:.0} at n={ACCEPT_N} {ACCEPT_DOMAIN}",
                ips.keys_per_sec, radix.keys_per_sec
            ));
        }
        println!(
            "acceptance cell n={ACCEPT_N} {ACCEPT_DOMAIN}: ips {:.2}x lsd-radix",
            ips.keys_per_sec / radix.keys_per_sec
        );
    }

    let base_fp = doc
        .get("host")
        .and_then(|h| h.get("fingerprint"))
        .and_then(Json::as_str)
        .unwrap_or("<missing>");
    if base_fp != fingerprint() {
        println!(
            "baseline host {:?} differs from this host {:?}: schema-only validation \
             (refresh the numbers with ./ci.sh --bench-baseline)",
            base_fp,
            fingerprint()
        );
        return Ok(());
    }
    for bc in base_cells {
        let bn = bc.get("n").and_then(Json::as_u64).unwrap_or(0) as usize;
        let bd = bc.get("domain").and_then(Json::as_str).unwrap_or("");
        let be = bc.get("engine").and_then(Json::as_str).unwrap_or("");
        let Some(fresh) =
            cells.iter().find(|c| c.n == bn && c.domain == bd && c.engine.tag() == be)
        else {
            continue;
        };
        let base = bc.get("keys_per_sec").and_then(Json::as_f64).unwrap_or(0.0);
        if base > 0.0 && fresh.keys_per_sec < 0.85 * base {
            return Err(format!(
                "local-sort regression at n={bn} {bd}/{be}: \
                 {:.0} keys/sec vs baseline {base:.0} (>15% below)",
                fresh.keys_per_sec
            ));
        }
    }
    println!("local-sort baseline OK (host match, no cell regressed >15%)");
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--quick-smoke");
    let opt = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_out = opt("--json");
    let baseline = opt("--compare");
    if smoke {
        // Reuse the harness's fast profile (1 warm-up, 3 iterations).
        std::env::set_var("BENCH_FAST", "1");
        println!("quick-smoke mode: shrunken sizes, BENCH_FAST profile");
    }
    let n = if smoke { 1 << 14 } else { 1 << 20 };

    // --- sequential sorts ------------------------------------------------
    let base: Vec<i32> = {
        let mut rng = SplitMix64::new(1);
        (0..n).map(|_| rng.next_i32()).collect()
    };
    bench("seq/quicksort/1M", |_| {
        let mut keys = base.clone();
        seq::quicksort(&mut keys);
        keys[0]
    });
    bench("seq/radixsort/1M", |_| {
        let mut keys = base.clone();
        seq::radixsort(&mut keys);
        keys[0]
    });
    bench("seq/std_unstable/1M", |_| {
        let mut keys = base.clone();
        keys.sort_unstable();
        keys[0]
    });
    bench("seq/ipssort/1M", |_| {
        let mut keys = base.clone();
        seq::ipssort(&mut keys);
        keys[0]
    });

    // --- local-sort engine grid (ROADMAP 5b regression gate) -------------
    // Old-vs-new base case: every engine × every key domain × n, on the
    // identical uniform input per (domain, n).  `--json` snapshots the
    // grid; `--compare` arms the regression + acceptance gates against a
    // committed baseline.
    let grid_ns: &[usize] = if smoke { &[10_000] } else { &[10_000, 100_000, 1_000_000] };
    let mut grid_cells: Vec<GridCell> = Vec::new();
    for &gn in grid_ns {
        grid_domain::<i32>(gn, &mut grid_cells);
        grid_domain::<u64>(gn, &mut grid_cells);
        grid_domain::<F64>(gn, &mut grid_cells);
        grid_domain::<Record>(gn, &mut grid_cells);
        grid_domain::<Str>(gn, &mut grid_cells);
    }

    // --- p-way merge -------------------------------------------------------
    let runs: Vec<Vec<i32>> = (0..16)
        .map(|i| {
            let mut rng = SplitMix64::new(i as u64 + 10);
            let mut r: Vec<i32> = (0..n / 16).map(|_| rng.next_i32()).collect();
            r.sort_unstable();
            r
        })
        .collect();
    bench("seq/multiway_merge/16x64K", |_| {
        seq::multiway_merge(&runs).len()
    });

    // --- engine all-to-all ---------------------------------------------------
    let p = 8;
    let machine = BspMachine::new(cray_t3d(p));
    bench("engine/all_to_all/8x128K", |_| {
        let run = machine.run(|ctx| {
            let parts: Vec<Payload> = (0..ctx.nprocs())
                .map(|_| Payload::Keys(vec![1i32; 128 * 1024 / ctx.nprocs()]))
                .collect();
            let inbox = ctx.all_to_all(parts, "bench");
            inbox.len()
        });
        run.outputs.len()
    });

    // --- all-to-all routing: slot matrix vs mutex-mailbox baseline ----------
    // Fixed total volume (~1M words per round, 4 rounds) routed across
    // p ∈ {4, 16, 64}: the engine's contention-free single-writer slot
    // matrix against a reference of the previous design (one Mutex<Vec>
    // mailbox per destination + sort-by-sender on delivery).  The p = 16
    // pair is the acceptance comparison for the routing-superstep
    // overhead reduction.
    for p in [4usize, 16, 64] {
        let per_pair = (if smoke { 1 << 14 } else { 1 << 20 }) / (p * p);
        let rounds = 4;
        let machine = BspMachine::new(cray_t3d(p));
        bench(&format!("engine/all_to_all/slot_matrix/p{p}"), |_| {
            let run = machine.run(|ctx| {
                let mut got = 0usize;
                for _ in 0..rounds {
                    let parts: Vec<Payload> = (0..p)
                        .map(|_| Payload::Keys(vec![1i32; per_pair]))
                        .collect();
                    got += ctx.all_to_all(parts, "bench").len();
                }
                got
            });
            run.outputs.len()
        });
        bench(&format!("engine/all_to_all/mutex_baseline/p{p}"), |_| {
            mutex_all_to_all(p, per_pair, rounds)
        });
    }

    // --- end-to-end sorts ------------------------------------------------
    let n2 = if smoke { 1 << 15 } else { 2 << 20 };
    let params = cray_t3d(p);
    let cfg = SortConfig::default();
    bench("e2e/sort_det_bsp/2M/p8", |_| {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n2 / p);
            det::sort_det_bsp(ctx, &params, local, n2, &cfg)
        });
        run.outputs.iter().map(|r| r.keys.len()).sum::<usize>()
    });
    bench("e2e/sort_iran_bsp/2M/p8", |_| {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n2 / p);
            iran::sort_iran_bsp(ctx, &params, local, n2, &cfg, 77)
        });
        run.outputs.iter().map(|r| r.keys.len()).sum::<usize>()
    });

    // --- experiment (g, L) calibration probes --------------------------------
    // The probes run before every study; they must stay cheap enough to
    // re-run per processor count.  Full plan in real benches, the quick
    // plan under --quick-smoke.
    let plan = if smoke { ProbePlan::quick() } else { ProbePlan::default_plan() };
    for p in [4usize, 8] {
        bench(&format!("experiment/calibrate_host/p{p}"), |_| {
            let c = calibrate_host(p, &plan);
            (c.l_us, c.g_us_per_word, c.comps_per_us)
        });
    }

    // --- XLA local sort (optional) ------------------------------------------
    match bsp_sort::runtime::Runtime::from_default_artifacts() {
        Ok(rt) => {
            let keys: Vec<i32> = base[..base.len().min(1 << 16)].to_vec();
            bench("xla/local_sort/64K", |_| rt.sort(&keys).unwrap().len());
        }
        Err(e) => eprintln!("skipping xla bench: {e}"),
    }

    // --- local-sort baseline I/O ---------------------------------------------
    if let Some(path) = &json_out {
        std::fs::write(path, grid_to_json(&grid_cells).render())
            .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(path) = &baseline {
        if let Err(msg) = grid_compare(path, &grid_cells) {
            eprintln!("local-sort gate failed: {msg}");
            std::process::exit(1);
        }
    }
}

/// Reference all-to-all with the engine's *previous* mailbox design: one
/// `Mutex<Vec<(src, payload)>>` per destination, every send taking the
/// destination's lock, delivery sorting by sender.  Kept here as the
/// baseline the slot-matrix engine is measured against.
fn mutex_all_to_all(p: usize, per_pair: usize, rounds: usize) -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Barrier, Mutex};

    type Mailbox = Mutex<Vec<(usize, Vec<i32>)>>;
    let mailboxes: Vec<Mailbox> = (0..p).map(|_| Mutex::new(Vec::new())).collect();
    let barrier = Barrier::new(p);
    let total = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for pid in 0..p {
            let mailboxes = &mailboxes;
            let barrier = &barrier;
            let total = &total;
            scope.spawn(move || {
                let mut got = 0usize;
                for _ in 0..rounds {
                    for dst in 0..p {
                        mailboxes[dst].lock().unwrap().push((pid, vec![1i32; per_pair]));
                    }
                    barrier.wait();
                    let mut msgs = std::mem::take(&mut *mailboxes[pid].lock().unwrap());
                    msgs.sort_by_key(|(src, _)| *src);
                    got += msgs.len();
                    barrier.wait();
                }
                total.fetch_add(got, Ordering::Relaxed);
            });
        }
    });
    total.into_inner()
}
