//! `cargo bench --bench hot_paths` — microbenchmarks of the performance-
//! critical substrates (the §Perf targets in EXPERIMENTS.md):
//!
//!   * sequential sorts (quicksort, radixsort) at 1M keys,
//!   * p-way loser-tree merge,
//!   * the engine's all-to-all routing superstep,
//!   * end-to-end SORT_DET_BSP / SORT_IRAN_BSP at 2M keys / 8 procs,
//!   * XLA local sort via PJRT when artifacts exist.

use bsp_sort::bsp::{cray_t3d, BspMachine, Payload};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::seq;
use bsp_sort::sort::{det, iran, SortConfig};
use bsp_sort::util::bench::bench;
use bsp_sort::util::rng::SplitMix64;

fn main() {
    let n = 1 << 20;

    // --- sequential sorts ------------------------------------------------
    let base: Vec<i32> = {
        let mut rng = SplitMix64::new(1);
        (0..n).map(|_| rng.next_i32()).collect()
    };
    bench("seq/quicksort/1M", |_| {
        let mut keys = base.clone();
        seq::quicksort(&mut keys);
        keys[0]
    });
    bench("seq/radixsort/1M", |_| {
        let mut keys = base.clone();
        seq::radixsort(&mut keys);
        keys[0]
    });
    bench("seq/std_unstable/1M", |_| {
        let mut keys = base.clone();
        keys.sort_unstable();
        keys[0]
    });

    // --- p-way merge -------------------------------------------------------
    let runs: Vec<Vec<i32>> = (0..16)
        .map(|i| {
            let mut rng = SplitMix64::new(i as u64 + 10);
            let mut r: Vec<i32> = (0..n / 16).map(|_| rng.next_i32()).collect();
            r.sort_unstable();
            r
        })
        .collect();
    bench("seq/multiway_merge/16x64K", |_| {
        seq::multiway_merge(&runs).len()
    });

    // --- engine all-to-all ---------------------------------------------------
    let p = 8;
    let machine = BspMachine::new(cray_t3d(p));
    bench("engine/all_to_all/8x128K", |_| {
        let run = machine.run(|ctx| {
            let parts: Vec<Payload> = (0..ctx.nprocs())
                .map(|_| Payload::Keys(vec![1i32; 128 * 1024 / ctx.nprocs()]))
                .collect();
            let inbox = ctx.all_to_all(parts, "bench");
            inbox.len()
        });
        run.outputs.len()
    });

    // --- end-to-end sorts ------------------------------------------------
    let n2 = 2 << 20;
    let params = cray_t3d(p);
    let cfg = SortConfig::default();
    bench("e2e/sort_det_bsp/2M/p8", |_| {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n2 / p);
            det::sort_det_bsp(ctx, &params, local, n2, &cfg)
        });
        run.outputs.iter().map(|r| r.keys.len()).sum::<usize>()
    });
    bench("e2e/sort_iran_bsp/2M/p8", |_| {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n2 / p);
            iran::sort_iran_bsp(ctx, &params, local, n2, &cfg, 77)
        });
        run.outputs.iter().map(|r| r.keys.len()).sum::<usize>()
    });

    // --- XLA local sort (optional) ------------------------------------------
    match bsp_sort::runtime::Runtime::from_default_artifacts() {
        Ok(rt) => {
            let keys: Vec<i32> = base[..1 << 16].to_vec();
            bench("xla/local_sort/64K", |_| rt.sort(&keys).unwrap().len());
        }
        Err(e) => eprintln!("skipping xla bench: {e}"),
    }
}
