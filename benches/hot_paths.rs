//! `cargo bench --bench hot_paths` — microbenchmarks of the performance-
//! critical substrates (the §Perf targets in EXPERIMENTS.md):
//!
//!   * sequential sorts (quicksort, radixsort) at 1M keys,
//!   * p-way loser-tree merge,
//!   * the engine's all-to-all routing superstep,
//!   * end-to-end SORT_DET_BSP / SORT_IRAN_BSP at 2M keys / 8 procs,
//!   * XLA local sort via PJRT when artifacts exist.
//!
//! `--quick-smoke` (the CI gate: `cargo bench --bench hot_paths --
//! --quick-smoke`) shrinks every size and iteration count so the whole
//! file runs in seconds — benchmark code can no longer rot silently.

use bsp_sort::bsp::{cray_t3d, BspMachine, Payload};
use bsp_sort::experiment::{calibrate_host, ProbePlan};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::seq;
use bsp_sort::sort::{det, iran, SortConfig};
use bsp_sort::util::bench::bench;
use bsp_sort::util::rng::SplitMix64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--quick-smoke");
    if smoke {
        // Reuse the harness's fast profile (1 warm-up, 3 iterations).
        std::env::set_var("BENCH_FAST", "1");
        println!("quick-smoke mode: shrunken sizes, BENCH_FAST profile");
    }
    let n = if smoke { 1 << 14 } else { 1 << 20 };

    // --- sequential sorts ------------------------------------------------
    let base: Vec<i32> = {
        let mut rng = SplitMix64::new(1);
        (0..n).map(|_| rng.next_i32()).collect()
    };
    bench("seq/quicksort/1M", |_| {
        let mut keys = base.clone();
        seq::quicksort(&mut keys);
        keys[0]
    });
    bench("seq/radixsort/1M", |_| {
        let mut keys = base.clone();
        seq::radixsort(&mut keys);
        keys[0]
    });
    bench("seq/std_unstable/1M", |_| {
        let mut keys = base.clone();
        keys.sort_unstable();
        keys[0]
    });

    // --- p-way merge -------------------------------------------------------
    let runs: Vec<Vec<i32>> = (0..16)
        .map(|i| {
            let mut rng = SplitMix64::new(i as u64 + 10);
            let mut r: Vec<i32> = (0..n / 16).map(|_| rng.next_i32()).collect();
            r.sort_unstable();
            r
        })
        .collect();
    bench("seq/multiway_merge/16x64K", |_| {
        seq::multiway_merge(&runs).len()
    });

    // --- engine all-to-all ---------------------------------------------------
    let p = 8;
    let machine = BspMachine::new(cray_t3d(p));
    bench("engine/all_to_all/8x128K", |_| {
        let run = machine.run(|ctx| {
            let parts: Vec<Payload> = (0..ctx.nprocs())
                .map(|_| Payload::Keys(vec![1i32; 128 * 1024 / ctx.nprocs()]))
                .collect();
            let inbox = ctx.all_to_all(parts, "bench");
            inbox.len()
        });
        run.outputs.len()
    });

    // --- all-to-all routing: slot matrix vs mutex-mailbox baseline ----------
    // Fixed total volume (~1M words per round, 4 rounds) routed across
    // p ∈ {4, 16, 64}: the engine's contention-free single-writer slot
    // matrix against a reference of the previous design (one Mutex<Vec>
    // mailbox per destination + sort-by-sender on delivery).  The p = 16
    // pair is the acceptance comparison for the routing-superstep
    // overhead reduction.
    for p in [4usize, 16, 64] {
        let per_pair = (if smoke { 1 << 14 } else { 1 << 20 }) / (p * p);
        let rounds = 4;
        let machine = BspMachine::new(cray_t3d(p));
        bench(&format!("engine/all_to_all/slot_matrix/p{p}"), |_| {
            let run = machine.run(|ctx| {
                let mut got = 0usize;
                for _ in 0..rounds {
                    let parts: Vec<Payload> = (0..p)
                        .map(|_| Payload::Keys(vec![1i32; per_pair]))
                        .collect();
                    got += ctx.all_to_all(parts, "bench").len();
                }
                got
            });
            run.outputs.len()
        });
        bench(&format!("engine/all_to_all/mutex_baseline/p{p}"), |_| {
            mutex_all_to_all(p, per_pair, rounds)
        });
    }

    // --- end-to-end sorts ------------------------------------------------
    let n2 = if smoke { 1 << 15 } else { 2 << 20 };
    let params = cray_t3d(p);
    let cfg = SortConfig::default();
    bench("e2e/sort_det_bsp/2M/p8", |_| {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n2 / p);
            det::sort_det_bsp(ctx, &params, local, n2, &cfg)
        });
        run.outputs.iter().map(|r| r.keys.len()).sum::<usize>()
    });
    bench("e2e/sort_iran_bsp/2M/p8", |_| {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n2 / p);
            iran::sort_iran_bsp(ctx, &params, local, n2, &cfg, 77)
        });
        run.outputs.iter().map(|r| r.keys.len()).sum::<usize>()
    });

    // --- experiment (g, L) calibration probes --------------------------------
    // The probes run before every study; they must stay cheap enough to
    // re-run per processor count.  Full plan in real benches, the quick
    // plan under --quick-smoke.
    let plan = if smoke { ProbePlan::quick() } else { ProbePlan::default_plan() };
    for p in [4usize, 8] {
        bench(&format!("experiment/calibrate_host/p{p}"), |_| {
            let c = calibrate_host(p, &plan);
            (c.l_us, c.g_us_per_word, c.comps_per_us)
        });
    }

    // --- XLA local sort (optional) ------------------------------------------
    match bsp_sort::runtime::Runtime::from_default_artifacts() {
        Ok(rt) => {
            let keys: Vec<i32> = base[..base.len().min(1 << 16)].to_vec();
            bench("xla/local_sort/64K", |_| rt.sort(&keys).unwrap().len());
        }
        Err(e) => eprintln!("skipping xla bench: {e}"),
    }
}

/// Reference all-to-all with the engine's *previous* mailbox design: one
/// `Mutex<Vec<(src, payload)>>` per destination, every send taking the
/// destination's lock, delivery sorting by sender.  Kept here as the
/// baseline the slot-matrix engine is measured against.
fn mutex_all_to_all(p: usize, per_pair: usize, rounds: usize) -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Barrier, Mutex};

    type Mailbox = Mutex<Vec<(usize, Vec<i32>)>>;
    let mailboxes: Vec<Mailbox> = (0..p).map(|_| Mutex::new(Vec::new())).collect();
    let barrier = Barrier::new(p);
    let total = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for pid in 0..p {
            let mailboxes = &mailboxes;
            let barrier = &barrier;
            let total = &total;
            scope.spawn(move || {
                let mut got = 0usize;
                for _ in 0..rounds {
                    for dst in 0..p {
                        mailboxes[dst].lock().unwrap().push((pid, vec![1i32; per_pair]));
                    }
                    barrier.wait();
                    let mut msgs = std::mem::take(&mut *mailboxes[pid].lock().unwrap());
                    msgs.sort_by_key(|(src, _)| *src);
                    got += msgs.len();
                    barrier.wait();
                }
                total.fetch_add(got, Ordering::Relaxed);
            });
        }
    });
    total.into_inner()
}
