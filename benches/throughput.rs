//! `cargo bench --bench throughput` — jobs/sec under concurrent load:
//! the persistent engine pool against per-job machine spin-up.
//!
//! Grid: n ∈ {10⁴, 10⁵, 10⁶} × {1, 4, 16, 64} concurrent submitters,
//! SORT_DET_BSP on uniform i32 keys at p = 8.  Each submitter is a
//! thread in a submit-join loop, so concurrency comes from the number
//! of submitters — exactly the serving model the `Sorter` façade
//! exposes.  The pool side reuses parked lanes and slot-matrix scratch
//! and batches small jobs into shared supersteps; the spin-up side pays
//! thread creation and buffer allocation per job (the pre-service
//! `BspMachine::run` one-shot path).
//!
//! Flags:
//!   --quick-smoke       tiny grid, runs in seconds (the CI gate)
//!   --json <path>       write the results as a throughput-baseline JSON
//!   --compare <path>    validate a committed baseline: schema check,
//!                       pool-speedup floor on the acceptance cell, and
//!                       a >15% jobs/sec regression gate when the
//!                       baseline was recorded on this host (refresh
//!                       with ./ci.sh --bench-baseline)

use std::sync::Arc;
use std::time::Instant;

use bsp_sort::bsp::{cray_t3d, BspMachine, Engine, EngineConfig};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::sort::{det, SortConfig};
use bsp_sort::util::json::Json;

const P: usize = 8;
const SCHEMA: &str = "bsp-sort/throughput-baseline/v1";
/// The acceptance cell: pool vs spin-up at n = 10⁴, 16 submitters.
const ACCEPT_N: usize = 10_000;
const ACCEPT_SUBMITTERS: usize = 16;

struct Cell {
    n: usize,
    submitters: usize,
    jobs: usize,
    pool_jobs_per_sec: f64,
    spinup_jobs_per_sec: f64,
}

impl Cell {
    fn speedup(&self) -> f64 {
        self.pool_jobs_per_sec / self.spinup_jobs_per_sec
    }
}

fn fingerprint() -> String {
    format!("{}/{}/{}cpu", std::env::consts::OS, std::env::consts::ARCH, threads())
}

fn threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One pool-side cell: `submitters` threads, each submitting
/// `jobs_each` blocking jobs to the shared persistent engine.
fn pool_cell(engine: &Arc<Engine>, n: usize, submitters: usize, jobs_each: usize) -> f64 {
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..submitters {
            let engine = Arc::clone(engine);
            s.spawn(move || {
                let params = *engine.params();
                let cfg = SortConfig::default();
                for _ in 0..jobs_each {
                    let handle = engine
                        .submit_program_blocking::<i32, _, _>(n, move |ctx| {
                            let local =
                                generate_for_proc(Benchmark::Uniform, ctx.pid(), P, n / P);
                            det::sort_det_bsp(ctx, &params, local, n, &cfg)
                        })
                        .expect("blocking submission is admitted");
                    let run = handle.join().expect("pool job completes");
                    assert_eq!(run.outputs.iter().map(|r| r.keys.len()).sum::<usize>(), n);
                }
            });
        }
    });
    (submitters * jobs_each) as f64 / start.elapsed().as_secs_f64()
}

/// One spin-up-side cell: the same workload, but every job constructs a
/// fresh `BspMachine` (new threads, new mailboxes) like pre-service
/// callers did.
fn spinup_cell(n: usize, submitters: usize, jobs_each: usize) -> f64 {
    let params = cray_t3d(P);
    let cfg = SortConfig::default();
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..submitters {
            s.spawn(move || {
                for _ in 0..jobs_each {
                    let machine = BspMachine::new(params);
                    let run = machine.run(|ctx| {
                        let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), P, n / P);
                        det::sort_det_bsp(ctx, &params, local, n, &cfg)
                    });
                    assert_eq!(run.outputs.iter().map(|r| r.keys.len()).sum::<usize>(), n);
                }
            });
        }
    });
    (submitters * jobs_each) as f64 / start.elapsed().as_secs_f64()
}

fn to_json(cells: &[Cell]) -> Json {
    let obj = |fields: Vec<(&str, Json)>| {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    };
    obj(vec![
        ("schema", Json::str(SCHEMA)),
        (
            "host",
            obj(vec![
                ("fingerprint", Json::str(fingerprint())),
                ("threads", Json::num(threads() as f64)),
            ]),
        ),
        ("p", Json::num(P as f64)),
        ("algo", Json::str("det")),
        ("bench", Json::str("uniform")),
        (
            "cells",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("n", Json::num(c.n as f64)),
                            ("submitters", Json::num(c.submitters as f64)),
                            ("jobs", Json::num(c.jobs as f64)),
                            ("pool_jobs_per_sec", Json::num(c.pool_jobs_per_sec)),
                            ("spinup_jobs_per_sec", Json::num(c.spinup_jobs_per_sec)),
                            ("pool_speedup", Json::num(c.speedup())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Baseline gate.  Always: schema tag + structural validity + a pool
/// speedup floor on the acceptance cell of *this* run.  Additionally,
/// when the baseline's host fingerprint matches this host: fail on a
/// >15% pool jobs/sec regression in any cell present in both runs.
fn compare(path: &str, cells: &[Cell], smoke: bool) -> Result<(), String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read baseline {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("baseline {path}: {e}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some(SCHEMA) {
        return Err(format!("baseline {path}: schema tag is not {SCHEMA:?}"));
    }
    let base_cells = doc
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("baseline {path}: missing cells array"))?;
    for c in base_cells {
        for key in ["n", "submitters", "pool_jobs_per_sec", "spinup_jobs_per_sec"] {
            if c.get(key).and_then(Json::as_f64).is_none() {
                return Err(format!("baseline {path}: cell lacks numeric {key:?}"));
            }
        }
    }

    // The acceptance criterion is 1.5× on full-size runs; the smoke
    // grid's cells are small enough that scheduling noise matters, so
    // CI enforces a softer floor there (the full bench enforces 1.5×).
    let floor = if smoke { 1.1 } else { 1.5 };
    if let Some(c) = cells.iter().find(|c| c.n == ACCEPT_N && c.submitters == ACCEPT_SUBMITTERS) {
        if c.speedup() < floor {
            return Err(format!(
                "pool speedup {:.2}x below the {floor:.1}x floor at n={ACCEPT_N}/{ACCEPT_SUBMITTERS} submitters",
                c.speedup()
            ));
        }
        println!(
            "acceptance cell n={ACCEPT_N} submitters={ACCEPT_SUBMITTERS}: pool {:.2}x spin-up (floor {floor:.1}x)",
            c.speedup()
        );
    }

    let base_fp = doc
        .get("host")
        .and_then(|h| h.get("fingerprint"))
        .and_then(Json::as_str)
        .unwrap_or("<missing>");
    if base_fp != fingerprint() {
        println!(
            "baseline host {:?} differs from this host {:?}: schema-only validation \
             (refresh the numbers with ./ci.sh --bench-baseline)",
            base_fp,
            fingerprint()
        );
        return Ok(());
    }
    for bc in base_cells {
        let (bn, bs) = (
            bc.get("n").and_then(Json::as_u64).unwrap_or(0) as usize,
            bc.get("submitters").and_then(Json::as_u64).unwrap_or(0) as usize,
        );
        let Some(fresh) = cells.iter().find(|c| c.n == bn && c.submitters == bs) else {
            continue;
        };
        let base = bc.get("pool_jobs_per_sec").and_then(Json::as_f64).unwrap_or(0.0);
        if base > 0.0 && fresh.pool_jobs_per_sec < 0.85 * base {
            return Err(format!(
                "pool throughput regression at n={bn}/{bs} submitters: \
                 {:.1} jobs/sec vs baseline {base:.1} (>15% below)",
                fresh.pool_jobs_per_sec
            ));
        }
    }
    println!("baseline comparison OK (host match, no cell regressed >15%)");
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--quick-smoke");
    let opt = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_out = opt("--json");
    let baseline = opt("--compare");

    let (ns, subs): (Vec<usize>, Vec<usize>) = if smoke {
        println!("quick-smoke mode: shrunken grid");
        (vec![10_000], vec![1, ACCEPT_SUBMITTERS])
    } else {
        (vec![10_000, 100_000, 1_000_000], vec![1, 4, 16, 64])
    };

    // One persistent engine across every cell — the whole point of the
    // service: lanes stay parked and scratch stays warm between jobs.
    let engine = Arc::new(Engine::new(EngineConfig::new(cray_t3d(P)).with_crews(4)));
    pool_cell(&engine, ns[0], 2, 2); // warm the lanes and scratch pool
    spinup_cell(ns[0], 2, 1);

    let mut cells = Vec::new();
    for &n in &ns {
        for &submitters in &subs {
            // Scale the per-submitter job count down as n grows so no
            // cell dominates the wall-clock budget.
            let jobs_each = if smoke { 4 } else { (400_000 / n).clamp(1, 16) };
            let jobs = submitters * jobs_each;
            let pool = pool_cell(&engine, n, submitters, jobs_each);
            let spin = spinup_cell(n, submitters, jobs_each);
            println!(
                "throughput n={n} submitters={submitters} jobs={jobs}: \
                 pool {pool:.1} jobs/sec, spin-up {spin:.1} jobs/sec ({:.2}x)",
                pool / spin
            );
            cells.push(Cell {
                n,
                submitters,
                jobs,
                pool_jobs_per_sec: pool,
                spinup_jobs_per_sec: spin,
            });
        }
    }
    let stats = engine.stats();
    println!(
        "engine totals: {} jobs completed, {} batched into {} shared supersteps, {} scratch reuses",
        stats.completed, stats.batched_jobs, stats.shared_batches, stats.scratch_reuses
    );
    engine.shutdown();

    if let Some(path) = &json_out {
        std::fs::write(path, to_json(&cells).render())
            .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(path) = &baseline {
        if let Err(msg) = compare(path, &cells, smoke) {
            eprintln!("throughput gate failed: {msg}");
            std::process::exit(1);
        }
    }
}
