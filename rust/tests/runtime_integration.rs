//! Integration tests of the PJRT runtime path (Layer 1+2 from Layer 3).
//!
//! These require `make artifacts`; each test skips with a message when
//! the artifacts are absent so `cargo test` stays green pre-build.

use std::sync::Arc;

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_for_proc, Benchmark};
use bsp_sort::runtime::{Runtime, XlaSorter};
use bsp_sort::seq::SeqSorter;
use bsp_sort::sort::{det, iran, SortConfig};

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime test: {e:#}");
            None
        }
    }
}

#[test]
fn pjrt_sort_block_exact_sizes() {
    let Some(rt) = runtime() else { return };
    for &size in rt.registry().sizes() {
        if size > 1 << 16 {
            break; // keep the test fast; larger sizes covered elsewhere
        }
        let keys: Vec<i32> = (0..size as i32).rev().collect();
        let sorted = rt.sort_block(&keys).unwrap();
        assert_eq!(sorted, (0..size as i32).collect::<Vec<_>>());
    }
}

#[test]
fn pjrt_sort_partial_block_with_max_keys() {
    let Some(rt) = runtime() else { return };
    // i32::MAX keys in the input must survive the sentinel padding.
    let keys = vec![i32::MAX, 5, i32::MAX, -9, 0];
    let sorted = rt.sort(&keys).unwrap();
    assert_eq!(sorted, vec![-9, 0, 5, i32::MAX, i32::MAX]);
}

#[test]
fn pjrt_chunked_sort_beyond_max_artifact() {
    let Some(rt) = runtime() else { return };
    // Force the chunk+merge path with a synthetic small registry? The
    // registry always has >= 1024; use 3 chunks of the smallest size by
    // sorting just above 2× the largest size only if that stays small.
    // Instead: directly exercise `sort` on max_size + 7 keys.
    let n = rt.registry().max_size() + 7;
    if n > (1 << 21) {
        eprintln!("skipping chunked test: max artifact too large for CI budget");
        return;
    }
    let mut keys: Vec<i32> = (0..n as i64).map(|i| ((i * 2654435761) % 1000003) as i32).collect();
    let sorted = rt.sort(&keys).unwrap();
    keys.sort_unstable();
    assert_eq!(sorted, keys);
}

#[test]
fn det_bsp_with_xla_backend_matches_quicksort_backend() {
    let Ok(sorter) = XlaSorter::from_default_artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let sorter = Arc::new(sorter);
    let p = 4;
    let n = 1 << 12;
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default();

    let xla_out: Vec<i32> = {
        let sorter = Arc::clone(&sorter);
        let run = machine.run(|ctx| {
            let mut local = generate_for_proc(Benchmark::Staggered, ctx.pid(), p, n / p);
            det::sort_det_bsp_with(ctx, &params, &mut local, n, &cfg, sorter.as_ref() as &dyn SeqSorter)
        });
        run.outputs.iter().flat_map(|r| r.keys.clone()).collect()
    };
    let quick_out: Vec<i32> = {
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Staggered, ctx.pid(), p, n / p);
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        run.outputs.iter().flat_map(|r| r.keys.clone()).collect()
    };
    assert_eq!(xla_out, quick_out);
}

#[test]
fn iran_bsp_with_xla_backend_sorts() {
    let Ok(sorter) = XlaSorter::from_default_artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let sorter = Arc::new(sorter);
    let p = 4;
    let n = 1 << 12;
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default();
    let run = machine.run(|ctx| {
        let mut local = generate_for_proc(Benchmark::DetDup, ctx.pid(), p, n / p);
        iran::sort_iran_bsp_with(ctx, &params, &mut local, n, &cfg, 5, sorter.as_ref() as &dyn SeqSorter)
    });
    let mut last = i32::MIN;
    let mut total = 0;
    for r in &run.outputs {
        for &k in &r.keys {
            assert!(k >= last);
            last = k;
        }
        total += r.keys.len();
    }
    assert_eq!(total, n);
}
