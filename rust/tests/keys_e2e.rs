//! End-to-end runs of the generic sorting stack over every built-in key
//! domain: `i32` (the paper's experiments), `u64`, total-ordered `f64`,
//! `(u32 key, u32 payload)` records and variable-length strings
//! (8-byte-prefix radix image), at p ∈ {4, 8}.
//!
//! For each domain, SORT_DET_BSP and SORT_RAN_BSP must produce a
//! globally sorted permutation of the input, and the §5.1.1 duplicate
//! handling must stay *transparent*: heavy-duplicate inputs balance
//! within the analytical bounds while the routed data remains bare keys
//! (no per-key tagging — checked against the ledger's word counts).

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::gen::{generate_heavy_dup_for_proc, generate_typed_for_proc, Benchmark, GenKey};
use bsp_sort::key::{F64, Key, RadixKey, Record, Str};
use bsp_sort::seq::SeqSortKind;
use bsp_sort::sort::{det, ran, SortConfig};

const PROCS: [usize; 2] = [4, 8];
const N: usize = 1 << 12;

fn assert_sorted_permutation<K: Key>(inputs: &[Vec<K>], outputs: &[Vec<K>], label: &str) {
    let mut expect: Vec<K> = inputs.iter().flatten().copied().collect();
    expect.sort_unstable();
    let got: Vec<K> = outputs.iter().flatten().copied().collect();
    assert!(got.windows(2).all(|w| w[0] <= w[1]), "{label}: not globally sorted");
    assert_eq!(got, expect, "{label}: not a permutation of the input");
}

/// det + ran over one domain and benchmark, both sequential backends.
///
/// Drives the deprecated `run_keys` one-shot wrapper on purpose: this
/// suite is the compatibility contract that the wrapper keeps working.
#[allow(deprecated)]
fn run_domain<K: GenKey + RadixKey>(bench: Benchmark) {
    for p in PROCS {
        for seq in [SeqSortKind::Quick, SeqSortKind::Radix] {
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default().with_seq(seq);

            let det_run = machine.run_keys::<K, _, _>(|ctx| {
                let local: Vec<K> = generate_typed_for_proc(bench, ctx.pid(), p, N / p);
                let input = local.clone();
                let out = det::sort_det_bsp(ctx, &params, local, N, &cfg);
                (input, out.keys)
            });
            let (inputs, outputs): (Vec<_>, Vec<_>) = det_run.outputs.into_iter().unzip();
            assert_sorted_permutation(
                &inputs,
                &outputs,
                &format!("det {} p={p} {seq:?} {}", K::NAME, bench.tag()),
            );

            let ran_run = machine.run_keys::<K, _, _>(|ctx| {
                let local: Vec<K> = generate_typed_for_proc(bench, ctx.pid(), p, N / p);
                let input = local.clone();
                let out = ran::sort_ran_bsp(ctx, &params, local, N, &cfg, 0xBEE5);
                (input, out.keys)
            });
            let (inputs, outputs): (Vec<_>, Vec<_>) = ran_run.outputs.into_iter().unzip();
            assert_sorted_permutation(
                &inputs,
                &outputs,
                &format!("ran {} p={p} {seq:?} {}", K::NAME, bench.tag()),
            );
        }
    }
}

/// Heavy-duplicate transparency in one domain: DET stays within the
/// Lemma 5.1 bound with every processor fed, RAN spreads the load, and
/// the routing superstep moves *exactly* the input's bare-key words (no
/// per-key tags on the wire — the §5.1.1 selling point over [39]/[40]).
#[allow(deprecated)]
fn duplicate_transparency<K: GenKey + RadixKey>() {
    for p in PROCS {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();

        let det_run = machine.run_keys::<K, _, _>(|ctx| {
            let local: Vec<K> =
                generate_heavy_dup_for_proc(Benchmark::Uniform, ctx.pid(), p, N / p, 5);
            det::sort_det_bsp(ctx, &params, local, N, &cfg)
        });
        let bound = det::nmax_bound(N, p, det::omega_det(&cfg, N));
        for (pid, r) in det_run.outputs.iter().enumerate() {
            assert!(r.received > 0, "{} det p={p} pid={pid} starved", K::NAME);
            assert!(
                (r.received as f64) <= bound + 1.0,
                "{} det p={p} pid={pid}: received {} > bound {bound}",
                K::NAME,
                r.received
            );
        }
        let routed: u64 = det_run
            .ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "ph5:route")
            .map(|s| s.total_words)
            .sum();
        assert_eq!(
            routed,
            N as u64 * K::WORDS,
            "{}: routing must move bare keys only (no input tagging)",
            K::NAME
        );

        let ran_run = machine.run_keys::<K, _, _>(|ctx| {
            let local: Vec<K> =
                generate_heavy_dup_for_proc(Benchmark::Uniform, ctx.pid(), p, N / p, 5);
            ran::sort_ran_bsp(ctx, &params, local, N, &cfg, 0xD0D0)
        });
        let max_recv = ran_run.outputs.iter().map(|r| r.received).max().unwrap();
        assert!(
            max_recv < N / 2,
            "{} ran p={p}: heavy duplicates collapsed ({max_recv} of {N} on one proc)",
            K::NAME
        );
    }
}

#[test]
fn det_ran_sort_i32_domain() {
    run_domain::<i32>(Benchmark::Staggered);
}

#[test]
fn det_ran_sort_u64_domain() {
    run_domain::<u64>(Benchmark::Uniform);
}

#[test]
fn det_ran_sort_f64_domain() {
    run_domain::<F64>(Benchmark::Gaussian);
}

#[test]
fn det_ran_sort_record_domain() {
    run_domain::<Record>(Benchmark::Bucket);
}

#[test]
fn det_ran_sort_str_domain() {
    // Zipf concentrates draws on few ranks, so the string mapping's
    // aux-derived suffixes are zeroed (duplicate-defined) and the sort
    // faces massive shared-prefix equality — the tie-break pressure case.
    run_domain::<Str>(Benchmark::Zipf(100));
}

#[test]
fn duplicate_transparency_i32() {
    duplicate_transparency::<i32>();
}

#[test]
fn duplicate_transparency_u64() {
    duplicate_transparency::<u64>();
}

#[test]
fn duplicate_transparency_f64() {
    duplicate_transparency::<F64>();
}

#[test]
fn duplicate_transparency_record() {
    duplicate_transparency::<Record>();
}

#[test]
fn duplicate_transparency_str() {
    duplicate_transparency::<Str>();
}

#[test]
#[allow(deprecated)]
fn record_payloads_survive_the_sort() {
    // Every (key, payload) pair that goes in comes out exactly once —
    // satellite data rides the sort untouched.
    let p = 4;
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default();
    let run = machine.run_keys::<Record, _, _>(|ctx| {
        let local: Vec<Record> = (0..N / p)
            .map(|i| Record {
                key: ((i * 31 + ctx.pid() * 7) % 97) as u32,
                payload: (ctx.pid() * N + i) as u32,
            })
            .collect();
        let input = local.clone();
        (input, det::sort_det_bsp(ctx, &params, local, N, &cfg).keys)
    });
    let (inputs, outputs): (Vec<_>, Vec<_>) = run.outputs.into_iter().unzip();
    assert_sorted_permutation(&inputs, &outputs, "record payload survival");
    // Payloads are globally unique by construction, so a permutation
    // check on full records proves no payload was dropped or duplicated.
    let mut payloads: Vec<u32> = outputs.iter().flatten().map(|r| r.payload).collect();
    payloads.sort_unstable();
    payloads.dedup();
    assert_eq!(payloads.len(), N);
}
