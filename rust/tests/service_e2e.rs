//! End-to-end exercise of the sort-as-a-service surface.
//!
//! The contract under test: many seeded jobs — mixed key domains,
//! algorithm variants and backends — submitted to the process-wide pool
//! *simultaneously* each come back globally sorted and as a permutation
//! of their generated input (order-independent multiset signature); the
//! pooled path charges a ledger identical to the deprecated one-shot
//! `run_keys` wrapper (wall-clock excluded — that is the field pooling
//! is allowed to change); admission control rejects over-depth
//! submissions with the configured queue depth in the error; shutdown
//! fails queued jobs without wedging running ones; and a panicking job
//! poisons only itself, not the engine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use bsp_sort::bsp::{cray_t3d, BspCtx, BspMachine, Engine, EngineConfig, Ledger};
use bsp_sort::gen::{generate_typed_for_proc, Benchmark, GenKey};
use bsp_sort::key::{Key, Record, F64};
use bsp_sort::prelude::{
    AlgoVariant, Backend, DomainOutputs, KeyDomain, RuntimeError, SortJob, SortRun, Sorter,
};
use bsp_sort::sort::common::ProcResult;
use bsp_sort::sort::{det, ran, SortConfig};
use bsp_sort::util::check::multiset_sig;

fn out_sig<K: Key>(rs: &[ProcResult<K>]) -> (u64, u64, u64, usize) {
    multiset_sig(rs.iter().flat_map(|r| r.keys.iter().copied()))
}

/// The signature of the input a pooled job generated internally: the
/// generators are deterministic in `(bench, pid, p, n)`, so the input
/// multiset is reproducible without ever shipping it out of the job.
fn in_sig<K: GenKey>(bench: Benchmark, p: usize, n: usize) -> (u64, u64, u64, usize) {
    multiset_sig((0..p).flat_map(|pid| generate_typed_for_proc::<K>(bench, pid, p, n / p)))
}

fn assert_permutation(run: &SortRun, bench: Benchmark, n: usize, label: &str) {
    assert!(run.outputs.is_globally_sorted(), "{label}: not globally sorted");
    assert_eq!(run.outputs.total_keys(), n, "{label}: key count drifted");
    let p = run.outputs.procs();
    let ok = match &run.outputs {
        DomainOutputs::I32(rs) => out_sig(rs) == in_sig::<i32>(bench, p, n),
        DomainOutputs::U64(rs) => out_sig(rs) == in_sig::<u64>(bench, p, n),
        DomainOutputs::F64T(rs) => out_sig(rs) == in_sig::<F64>(bench, p, n),
        DomainOutputs::RecordU32(rs) => out_sig(rs) == in_sig::<Record>(bench, p, n),
    };
    assert!(ok, "{label}: output is not a permutation of the generated input");
}

#[test]
fn concurrent_mixed_jobs_all_sort_and_permute() {
    // One submission wave: every handle is taken before any join, so
    // the pool holds all of these in flight at once — threaded jobs on
    // the p=4 engine (batched where small), simulator jobs on the task
    // engine at virtual widths beyond it.
    let n = 1 << 11;
    let cases: Vec<(AlgoVariant, KeyDomain, Benchmark, Backend, usize)> = vec![
        (AlgoVariant::Det, KeyDomain::I32, Benchmark::Staggered, Backend::Threaded, 4),
        (AlgoVariant::Ran, KeyDomain::U64, Benchmark::Uniform, Backend::Threaded, 4),
        (AlgoVariant::Iran, KeyDomain::F64T, Benchmark::Gaussian, Backend::Threaded, 4),
        (AlgoVariant::Det2, KeyDomain::RecordU32, Benchmark::Bucket, Backend::Threaded, 4),
        (AlgoVariant::Bsi, KeyDomain::I32, Benchmark::DetDup, Backend::Threaded, 4),
        (AlgoVariant::DetK, KeyDomain::I32, Benchmark::Uniform, Backend::Sim, 16),
        (AlgoVariant::RanK, KeyDomain::U64, Benchmark::Staggered, Backend::Sim, 16),
        (AlgoVariant::Ran, KeyDomain::RecordU32, Benchmark::DetDup, Backend::Sim, 64),
        (AlgoVariant::Det, KeyDomain::F64T, Benchmark::WorstRegular, Backend::Sim, 64),
        (AlgoVariant::Psrs, KeyDomain::I32, Benchmark::Uniform, Backend::Threaded, 4),
    ];

    let handles: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, &(algo, domain, bench, backend, p))| {
            let job = SortJob::new(algo, n)
                .domain(domain)
                .bench(bench)
                .procs(p)
                .backend(backend)
                .seed(0xE2E0 + i as u64);
            Sorter::global().submit(job).expect("pool admits the wave")
        })
        .collect();

    for (handle, &(algo, domain, bench, backend, p)) in handles.into_iter().zip(&cases) {
        let label = format!(
            "algo={} domain={} bench={} backend={} p={p}",
            algo.tag(),
            domain.tag(),
            bench.tag(),
            backend.tag()
        );
        let run = handle.join().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(run.outputs.domain(), domain, "{label}: domain drifted");
        assert_permutation(&run, bench, n, &label);
    }
}

/// Charged-accounting equality, wall-clock skipped (mirrors the
/// conformance suite's backend-equivalence check).
fn assert_charged_eq(pooled: &Ledger, oneshot: &Ledger, label: &str) {
    assert_eq!(
        pooled.supersteps.len(),
        oneshot.supersteps.len(),
        "{label}: superstep count differs"
    );
    for (i, (a, b)) in pooled.supersteps.iter().zip(&oneshot.supersteps).enumerate() {
        assert_eq!(a.label, b.label, "{label} superstep {i}: label");
        assert_eq!(a.phase, b.phase, "{label} superstep {i}: phase");
        assert_eq!(a.max_ops, b.max_ops, "{label} superstep {i} ({}): max_ops", a.label);
        assert_eq!(a.h_words, b.h_words, "{label} superstep {i} ({}): h_words", a.label);
        assert_eq!(a.total_words, b.total_words, "{label} superstep {i}: total_words");
        assert_eq!(a.procs, b.procs, "{label} superstep {i}: procs");
        assert_eq!(a.round, b.round, "{label} superstep {i}: round");
    }
    let pp: Vec<&String> = pooled.phases.keys().collect();
    let op: Vec<&String> = oneshot.phases.keys().collect();
    assert_eq!(pp, op, "{label}: phase sets differ");
    for (name, a) in &pooled.phases {
        let b = &oneshot.phases[name];
        assert_eq!(a.max_ops, b.max_ops, "{label} phase {name}: charged ops");
        assert_eq!(a.h_words, b.h_words, "{label} phase {name}: h words");
        assert_eq!(a.supersteps, b.supersteps, "{label} phase {name}: superstep count");
    }
}

#[test]
#[allow(deprecated)] // the one-shot side *is* the deprecated wrapper under test
fn pooled_ledger_is_charged_identically_to_one_shot_run_keys() {
    // Same algorithm, input and seed through both submission styles:
    // the persistent pool (slot-matrix reuse, possibly batched) and a
    // fresh `BspMachine::run_keys` spin-up.  Charges are data-dependent,
    // so everything but wall-clock must match bit for bit.
    let (p, n, seed) = (4usize, 1 << 12, 0xFEED_F00Du64);
    let params = cray_t3d(p);
    let cfg = SortConfig::default();
    for algo in [AlgoVariant::Det, AlgoVariant::Ran] {
        let label = format!("pool-vs-oneshot algo={}", algo.tag());
        let pooled = Sorter::global()
            .run(
                SortJob::new(algo, n)
                    .procs(p)
                    .bench(Benchmark::Staggered)
                    .seed(seed),
            )
            .unwrap_or_else(|e| panic!("{label}: {e}"));

        let machine = BspMachine::new(params);
        let oneshot = machine.run_keys::<i32, _, _>(|ctx| {
            let local: Vec<i32> =
                generate_typed_for_proc(Benchmark::Staggered, ctx.pid(), p, n / p);
            match algo {
                AlgoVariant::Det => det::sort_det_bsp(ctx, &params, local, n, &cfg),
                _ => ran::sort_ran_bsp(ctx, &params, local, n, &cfg, seed),
            }
        });

        let pooled_rs = match &pooled.outputs {
            DomainOutputs::I32(rs) => rs,
            other => panic!("{label}: unexpected domain {:?}", other.domain()),
        };
        for (pid, (a, b)) in pooled_rs.iter().zip(&oneshot.outputs).enumerate() {
            assert_eq!(a.keys, b.keys, "{label} pid={pid}: outputs differ");
            assert_eq!(a.received, b.received, "{label} pid={pid}: received differs");
        }
        assert_charged_eq(&pooled.ledger, &oneshot.ledger, &label);
    }
}

/// A program that parks its crew until the gate opens — the lever for
/// filling the queue deterministically from outside the crate.
fn blocker(gate: &Arc<AtomicBool>) -> impl Fn(&mut BspCtx<i32>) -> usize + Send + Sync + 'static {
    let gate = Arc::clone(gate);
    move |ctx: &mut BspCtx<i32>| {
        while !gate.load(Ordering::Acquire) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        ctx.pid()
    }
}

#[test]
fn admission_control_rejects_with_the_configured_depth() {
    let engine = Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(1).with_queue_depth(2));
    let gate = Arc::new(AtomicBool::new(false));

    // The blocker is dispatched to the only crew at submit time, so the
    // next two submissions are queued and the third is over depth.
    let running = engine.submit_program::<i32, _, _>(1, blocker(&gate)).unwrap();
    let q1 = engine.submit_program::<i32, _, _>(1, |ctx| ctx.pid()).unwrap();
    let q2 = engine.submit_program::<i32, _, _>(1, |ctx| ctx.pid()).unwrap();
    assert_eq!(engine.queued(), 2);
    match engine.submit_program::<i32, _, _>(1, |ctx| ctx.pid()) {
        Err(RuntimeError::QueueFull { depth }) => assert_eq!(depth, 2),
        other => panic!("expected QueueFull {{ depth: 2 }}, got {other:?}"),
    }

    gate.store(true, Ordering::Release);
    for h in [running, q1, q2] {
        let run = h.join().expect("admitted jobs complete after the gate opens");
        assert_eq!(run.outputs, vec![0, 1]);
    }
    assert!(engine.stats().completed >= 3);
    engine.shutdown();
}

#[test]
fn shutdown_fails_queued_jobs_and_finishes_running_ones() {
    let engine = Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(1).with_queue_depth(8));
    let gate = Arc::new(AtomicBool::new(false));
    let running = engine.submit_program::<i32, _, _>(1, blocker(&gate)).unwrap();
    let pending = engine.submit_program::<i32, _, _>(1, |ctx| ctx.pid()).unwrap();
    assert_eq!(engine.queued(), 1);

    // `shutdown` fail-drains the queue synchronously before joining
    // lanes; the gate opens only once the drain is observable, so the
    // pending job can never sneak onto the crew first.
    std::thread::scope(|s| {
        s.spawn(|| engine.shutdown());
        while engine.queued() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        gate.store(true, Ordering::Release);
    });

    assert!(running.join().is_ok(), "running job must complete through shutdown");
    match pending.join() {
        Err(RuntimeError::EngineShutdown) => {}
        other => panic!("expected EngineShutdown for the queued job, got {other:?}"),
    }
}

#[test]
fn a_panicking_job_fails_alone_and_the_engine_keeps_serving() {
    let engine = Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(1));
    let bad = engine
        .submit_program::<i32, _, _>(1, |ctx| {
            if ctx.pid() == 1 {
                panic!("deliberate test panic");
            }
            ctx.pid()
        })
        .unwrap();
    match bad.join() {
        Err(RuntimeError::JobPanicked(msg)) => {
            assert!(msg.contains("deliberate"), "panic payload lost: {msg}")
        }
        other => panic!("expected JobPanicked, got {other:?}"),
    }

    let good = engine.submit_program::<i32, _, _>(1, |ctx| ctx.pid() * 10).unwrap();
    assert_eq!(good.join().expect("engine survives a job panic").outputs, vec![0, 10]);
    engine.shutdown();
}
