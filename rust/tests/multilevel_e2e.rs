//! End-to-end runs of the two-level sorts over every built-in key
//! domain at p = 8 (2 groups × 4 processors).
//!
//! For each domain, the multi-level det and ran variants must produce a
//! globally sorted permutation of the input; §5.1.1 duplicate handling
//! must stay transparent through *both* levels (heavy-duplicate inputs
//! balance, routed data remains bare keys); and the ledger must record
//! the level-2 exchanges as *group-local*: group-sized participant
//! counts, strictly fewer routed words per superstep than the one-level
//! equivalent on the same input, and half the input per group overall.

use bsp_sort::bsp::{cray_t3d, BspMachine, Communicator, Ledger};
use bsp_sort::gen::{generate_heavy_dup_for_proc, generate_typed_for_proc, Benchmark, GenKey};
use bsp_sort::key::{F64, Key, RadixKey, Record};
use bsp_sort::seq::SeqSortKind;
use bsp_sort::sort::common::ProcResult;
use bsp_sort::sort::{det, multilevel, SortConfig};

const P: usize = 8;
const GROUPS: usize = 2;
const N: usize = 1 << 12;

fn assert_sorted_permutation<K: Key>(inputs: &[Vec<K>], outputs: &[Vec<K>], label: &str) {
    let mut expect: Vec<K> = inputs.iter().flatten().copied().collect();
    expect.sort_unstable();
    let got: Vec<K> = outputs.iter().flatten().copied().collect();
    assert!(got.windows(2).all(|w| w[0] <= w[1]), "{label}: not globally sorted");
    assert_eq!(got, expect, "{label}: not a permutation of the input");
}

#[allow(deprecated)]
fn run_two_level<K: GenKey + RadixKey>(
    det_variant: bool,
    bench: Benchmark,
    seq: SeqSortKind,
    gen_dup: bool,
) -> (Vec<Vec<K>>, Vec<ProcResult<K>>, Ledger) {
    let params = cray_t3d(P);
    let machine = BspMachine::new(params);
    let comm = Communicator::split_even(P, GROUPS);
    let cfg = SortConfig::default().with_seq(seq);
    let run = machine.run_keys::<K, _, _>(|ctx| {
        let local: Vec<K> = if gen_dup {
            generate_heavy_dup_for_proc(bench, ctx.pid(), P, N / P, 5)
        } else {
            generate_typed_for_proc(bench, ctx.pid(), P, N / P)
        };
        let input = local.clone();
        let out = if det_variant {
            multilevel::sort_multilevel_det(ctx, &comm, &params, local, N, &cfg)
        } else {
            multilevel::sort_multilevel_ran(ctx, &comm, &params, local, N, &cfg, 0xA2E5)
        };
        (input, out)
    });
    let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
    let results = run.outputs.into_iter().map(|(_, r)| r).collect();
    (inputs, results, run.ledger)
}

/// det2 + ran2 over one domain and benchmark, every sequential backend.
fn run_domain<K: GenKey + RadixKey>(bench: Benchmark) {
    for seq in [SeqSortKind::Quick, SeqSortKind::Radix, SeqSortKind::Ips] {
        let (inputs, results, _) = run_two_level::<K>(true, bench, seq, false);
        let outputs: Vec<Vec<K>> = results.iter().map(|r| r.keys.clone()).collect();
        assert_sorted_permutation(
            &inputs,
            &outputs,
            &format!("det2 {} {seq:?} {}", K::NAME, bench.tag()),
        );

        let (inputs, results, _) = run_two_level::<K>(false, bench, seq, false);
        let outputs: Vec<Vec<K>> = results.iter().map(|r| r.keys.clone()).collect();
        assert_sorted_permutation(
            &inputs,
            &outputs,
            &format!("ran2 {} {seq:?} {}", K::NAME, bench.tag()),
        );
    }
}

/// Heavy-duplicate transparency through both levels, plus the ledger's
/// group-locality evidence for the level-2 exchange phases.
fn duplicate_transparency_and_group_locality<K: GenKey + RadixKey>() {
    let (inputs, results, ledger) =
        run_two_level::<K>(true, Benchmark::Uniform, SeqSortKind::Quick, true);
    let outputs: Vec<Vec<K>> = results.iter().map(|r| r.keys.clone()).collect();
    assert_sorted_permutation(&inputs, &outputs, &format!("det2 dup {}", K::NAME));
    for (pid, r) in results.iter().enumerate() {
        assert!(r.received > 0, "{} det2 pid={pid} starved", K::NAME);
    }

    // Level-1 routing is one whole-machine superstep moving every key
    // once, bare keys only (no per-key tagging on the wire).
    let l1: Vec<_> = ledger.supersteps.iter().filter(|s| s.label == "l1:route").collect();
    assert_eq!(l1.len(), 1, "{}", K::NAME);
    assert!(l1[0].round.is_none());
    assert_eq!(l1[0].procs, P);
    assert_eq!(l1[0].total_words, N as u64 * K::WORDS, "{}: level-1 tagged keys?", K::NAME);

    // Level-2 routing: one group record per group, group-sized procs,
    // each moving strictly less than the whole-machine route — and both
    // together moving every key exactly once (bare keys again).
    let l2: Vec<_> = ledger
        .supersteps
        .iter()
        .filter(|s| s.label == "ph5:route" && s.round.is_some())
        .collect();
    assert_eq!(l2.len(), GROUPS, "{}", K::NAME);
    for s in &l2 {
        assert_eq!(s.procs, P / GROUPS, "{}", K::NAME);
        assert_eq!(s.phase, "L2/Ph5:Routing");
        assert!(
            s.total_words < l1[0].total_words,
            "{}: level-2 route {} words must be under the one-level {}",
            K::NAME,
            s.total_words,
            l1[0].total_words
        );
        // h is bounded by what one group member can hold: the group's
        // whole share is an upper bound.
        assert!(s.h_words <= (N / GROUPS) as u64 * K::WORDS + P as u64);
    }
    let l2_total: u64 = l2.iter().map(|s| s.total_words).sum();
    assert_eq!(l2_total, N as u64 * K::WORDS, "{}: level-2 tagged keys?", K::NAME);
}

#[test]
fn det2_ran2_sort_i32_domain() {
    run_domain::<i32>(Benchmark::Staggered);
}

#[test]
fn det2_ran2_sort_u64_domain() {
    run_domain::<u64>(Benchmark::Uniform);
}

#[test]
fn det2_ran2_sort_f64_domain() {
    run_domain::<F64>(Benchmark::Gaussian);
}

#[test]
fn det2_ran2_sort_record_domain() {
    run_domain::<Record>(Benchmark::Bucket);
}

#[test]
fn duplicate_transparency_i32() {
    duplicate_transparency_and_group_locality::<i32>();
}

#[test]
fn duplicate_transparency_u64() {
    duplicate_transparency_and_group_locality::<u64>();
}

#[test]
fn duplicate_transparency_f64() {
    duplicate_transparency_and_group_locality::<F64>();
}

#[test]
fn duplicate_transparency_record() {
    duplicate_transparency_and_group_locality::<Record>();
}

#[test]
fn two_level_routes_fewer_words_per_superstep_than_one_level() {
    // The acceptance comparison: on the SAME input, the one-level det
    // sort's routing superstep moves all n words at once; every routing
    // superstep of the two-level run (level 1 aside, which is priced at
    // the same n but is the only whole-machine exchange) stays at the
    // group-local share.  The ledger's phase comparison prices L2
    // phases with the group-local machine.
    let params = cray_t3d(P);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default();

    let one = machine.run(|ctx| {
        let local = generate_typed_for_proc::<i32>(Benchmark::Uniform, ctx.pid(), P, N / P);
        det::sort_det_bsp(ctx, &params, local, N, &cfg)
    });
    let one_route = one
        .ledger
        .supersteps
        .iter()
        .find(|s| s.label == "ph5:route")
        .expect("one-level route present");
    assert_eq!(one_route.total_words, N as u64);

    let comm = Communicator::split_even(P, GROUPS);
    let two = machine.run(|ctx| {
        let local = generate_typed_for_proc::<i32>(Benchmark::Uniform, ctx.pid(), P, N / P);
        multilevel::sort_multilevel_det(ctx, &comm, &params, local, N, &cfg)
    });
    for s in two
        .ledger
        .supersteps
        .iter()
        .filter(|s| s.label == "ph5:route" && s.round.is_some())
    {
        assert!(
            s.total_words < one_route.total_words,
            "level-2 superstep words {} must be strictly under one-level {}",
            s.total_words,
            one_route.total_words
        );
    }

    // Phase pricing: the L2 routing phase exists and is priced with the
    // group-local machine — its per-round cost never exceeds what the
    // full machine would charge for the same exchange.
    let rows = two.ledger.phase_comparison(&params);
    let l2_route = rows
        .iter()
        .find(|r| r.phase == "L2/Ph5:Routing")
        .expect("L2 routing phase priced");
    assert!(l2_route.predicted_secs > 0.0);
    let l1_route = rows.iter().find(|r| r.phase == "Ph5:Routing").expect("L1 routing phase");
    assert!(l1_route.predicted_secs > 0.0);
}
