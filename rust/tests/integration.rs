//! Cross-module integration tests: full sorting runs over the engine,
//! all algorithms × all benchmarks, the paper's invariants end to end.

use bsp_sort::bsp::{cray_t3d, BspMachine};
use bsp_sort::experiment::ALL_ALGOS;
use bsp_sort::gen::{generate_all, generate_for_proc, Benchmark, ALL_BENCHMARKS};
use bsp_sort::metrics::Imbalance;
use bsp_sort::seq::SeqSortKind;
use bsp_sort::sort::{det, iran, DuplicatePolicy, SortConfig};
use bsp_sort::tables::runner::{execute, RunSpec};
use bsp_sort::util::check::{check_cfg, CheckConfig};

fn assert_globally_sorted(outputs: &[bsp_sort::sort::ProcResult], n: usize) {
    let mut last = i32::MIN;
    let mut total = 0;
    for r in outputs {
        for &k in &r.keys {
            assert!(k >= last, "global order violated");
            last = k;
        }
        total += r.keys.len();
    }
    assert_eq!(total, n);
}

#[test]
fn every_algorithm_sorts_every_benchmark() {
    // All eleven variants × the full benchmark set (§6.3 seven + the
    // five skew families) on the threaded backend.
    let n = 1 << 12;
    for algo in ALL_ALGOS {
        for bench in ALL_BENCHMARKS {
            let spec = RunSpec::new(algo, bench, 4, n);
            let report = execute(&spec); // panics internally if unsorted
            assert_eq!(report.n_total, n, "{algo:?} {}", bench.tag());
        }
    }
}

#[test]
fn multiset_preservation_randomized_property() {
    // The runner checks sortedness; here we check the multiset too.
    check_cfg(
        "multiset-preservation",
        CheckConfig { cases: 10, base_seed: 77 },
        |rng| {
            let p = 1 << (1 + rng.below(3)); // 2, 4, 8
            let n = (p * (64 + rng.below(512) as usize)).next_power_of_two();
            let bench = ALL_BENCHMARKS[rng.below(ALL_BENCHMARKS.len() as u64) as usize];
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default();
            let seed = rng.next_u64();
            let run = machine.run(|ctx| {
                let local = generate_for_proc(bench, ctx.pid(), p, n / p);
                let input = local.clone();
                let out = iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed);
                (input, out)
            });
            let mut expect: Vec<i32> = run.outputs.iter().flat_map(|(i, _)| i.clone()).collect();
            expect.sort_unstable();
            let got: Vec<i32> = run.outputs.iter().flat_map(|(_, r)| r.keys.clone()).collect();
            assert_eq!(got, expect, "{} p={p} n={n}", bench.tag());
        },
    );
}

#[test]
fn lemma_5_1_bound_holds_for_det_across_benchmarks_and_p() {
    for p in [2usize, 4, 8, 16] {
        let n = 1 << 14;
        for bench in ALL_BENCHMARKS {
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default();
            let run = machine.run(|ctx| {
                let local = generate_for_proc(bench, ctx.pid(), p, n / p);
                det::sort_det_bsp(ctx, &params, local, n, &cfg)
            });
            assert_globally_sorted(&run.outputs, n);
            let bound = det::nmax_bound(n, p, det::omega_det(&cfg, n));
            let imb = Imbalance::from_results(&run.outputs);
            assert!(
                imb.max_received as f64 <= bound + 1.0,
                "{} p={p}: {} > {bound}",
                bench.tag(),
                imb.max_received
            );
        }
    }
}

#[test]
fn paper_15pct_imbalance_claim_at_experiment_scale() {
    // §6.4: "In all runs ... maximum set imbalance was kept below 15%".
    // At the paper's scales ω ≈ 4.5-4.8 predicts ≤ ~22%; observed was
    // <15%.  We check the observed expansion at a scaled-down n.
    let p = 8;
    let n = 1 << 16;
    for bench in [Benchmark::Uniform, Benchmark::WorstRegular, Benchmark::Staggered] {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        let imb = Imbalance::from_results(&run.outputs);
        let expansion = imb.max_received as f64 / (n as f64 / p as f64) - 1.0;
        assert!(
            expansion < 0.25,
            "{}: expansion {:.1}% exceeds the analytical envelope",
            bench.tag(),
            100.0 * expansion
        );
    }
}

#[test]
fn stability_audit_with_tagged_payloads() {
    // Shadow run: sort (key, origin) pairs sequentially with the tagged
    // order and compare against the BSP output run boundaries — equal
    // keys must appear ordered by (origin proc, index), §5.1.1's rule.
    let p = 4;
    let n = 1 << 10;
    let params = cray_t3d(p);
    let machine = BspMachine::new(params);
    let cfg = SortConfig::default();
    // Duplicate-heavy input with traceable provenance.
    let inputs: Vec<Vec<i32>> = (0..p)
        .map(|pid| (0..n / p).map(|i| ((i * 7 + pid) % 5) as i32).collect())
        .collect();
    let inputs_ref = &inputs;
    let run = machine.run(|ctx| {
        let local = inputs_ref[ctx.pid()].clone();
        det::sort_det_bsp(ctx, &params, local, n, &cfg)
    });
    assert_globally_sorted(&run.outputs, n);
    // Every processor's received count is positive and bounded.
    for r in &run.outputs {
        assert!(r.received > 0);
        assert!(r.runs <= p);
    }
}

#[test]
fn radix_and_quick_variants_agree() {
    let p = 8;
    let n = 1 << 13;
    let outputs: Vec<Vec<i32>> = [SeqSortKind::Quick, SeqSortKind::Radix]
        .iter()
        .map(|&seq| {
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default().with_seq(seq);
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Gaussian, ctx.pid(), p, n / p);
                det::sort_det_bsp(ctx, &params, local, n, &cfg)
            });
            run.outputs.iter().flat_map(|r| r.keys.clone()).collect()
        })
        .collect();
    assert_eq!(outputs[0], outputs[1]);
}

#[test]
fn dup_off_matches_tagged_output_on_distinct_keys() {
    // With (almost) distinct keys the ablation must not change results.
    let p = 4;
    let n = 1 << 12;
    let outputs: Vec<Vec<i32>> = [DuplicatePolicy::Tagged, DuplicatePolicy::Off]
        .iter()
        .map(|&dup| {
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default().with_dup(dup);
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::WorstRegular, ctx.pid(), p, n / p);
                det::sort_det_bsp(ctx, &params, local, n, &cfg)
            });
            run.outputs.iter().flat_map(|r| r.keys.clone()).collect()
        })
        .collect();
    assert_eq!(outputs[0], outputs[1]);
}

#[test]
fn generate_all_matches_per_proc_generation() {
    let all = generate_all(Benchmark::Uniform, 4, 1 << 10);
    for (pid, keys) in all.iter().enumerate() {
        assert_eq!(keys, &generate_for_proc(Benchmark::Uniform, pid, 4, 1 << 8));
    }
}

#[test]
fn ledger_superstep_count_is_deterministic() {
    // Same run twice -> identical superstep structure (labels + h).
    let p = 4;
    let n = 1 << 12;
    let runs: Vec<Vec<(String, u64)>> = (0..2)
        .map(|_| {
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default();
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
                det::sort_det_bsp(ctx, &params, local, n, &cfg)
            });
            run.ledger
                .supersteps
                .iter()
                .map(|s| (s.label.clone(), s.h_words))
                .collect()
        })
        .collect();
    assert_eq!(runs[0], runs[1]);
}
