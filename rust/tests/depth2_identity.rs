//! Depth-2 bit-identity: the depth-k recursion reproduces the two-level
//! sorts exactly.
//!
//! `det2` / `ran2` predate the depth-k rewrite; their entry points
//! (`sort_multilevel_det` / `sort_multilevel_ran`) are now thin wrappers
//! over the recursive `sort_deep_*` with a single-communicator slice.
//! The acceptance bar for the refactor is that nothing observable moved:
//! at `p = 8`, `det2`/`ran2` and `det-k`/`ran-k` pinned to the matching
//! two-level topology `[k, p/k]` must produce
//!
//! * identical per-processor outputs (keys and received counts),
//! * identical charged ledgers — superstep labels, phases, ops, words,
//!   rounds — on both backends, and
//! * identical *virtual wall-clock* on the simulator (real wall-clock on
//!   the threaded engine is the one field allowed to differ).
//!
//! A second set of cases pins the topology explicitly on `det2`/`ran2`
//! themselves and checks it matches their default (`default_groups(p)`)
//! path, so the `--topology` plumbing cannot drift from the default.

use bsp_sort::bsp::{Backend, Ledger, Topology};
use bsp_sort::experiment::{execute_typed, AlgoVariant, RunSpec, SingleRun, StudyKey};
use bsp_sort::gen::Benchmark;
use bsp_sort::sort::multilevel;

const P: usize = 8;
const N: usize = 1 << 12;
const SEED: u64 = 0xD2D2_0006;

/// Full ledger equality on the charged side; `compare_wall` additionally
/// requires exact (virtual) wall-clock equality — valid only when both
/// runs came from the simulator.
fn assert_identical_ledgers(a: &Ledger, b: &Ledger, label: &str, compare_wall: bool) {
    assert_eq!(a.supersteps.len(), b.supersteps.len(), "{label}: superstep count");
    for (i, (x, y)) in a.supersteps.iter().zip(&b.supersteps).enumerate() {
        assert_eq!(x.label, y.label, "{label} superstep {i}: label");
        assert_eq!(x.phase, y.phase, "{label} superstep {i}: phase");
        assert_eq!(x.max_ops, y.max_ops, "{label} superstep {i} ({}): max_ops", x.label);
        assert_eq!(x.h_words, y.h_words, "{label} superstep {i} ({}): h_words", x.label);
        assert_eq!(
            x.total_words, y.total_words,
            "{label} superstep {i} ({}): total_words",
            x.label
        );
        assert_eq!(x.procs, y.procs, "{label} superstep {i}: procs");
        assert_eq!(x.reporters, y.reporters, "{label} superstep {i}: reporters");
        assert_eq!(x.round, y.round, "{label} superstep {i}: round");
        if compare_wall {
            assert_eq!(x.wall_us, y.wall_us, "{label} superstep {i} ({}): wall", x.label);
        }
    }
    let a_phases: Vec<&String> = a.phases.keys().collect();
    let b_phases: Vec<&String> = b.phases.keys().collect();
    assert_eq!(a_phases, b_phases, "{label}: phase sets");
    for (name, x) in &a.phases {
        let y = &b.phases[name];
        assert_eq!(x.max_ops, y.max_ops, "{label} phase {name}: max_ops");
        assert_eq!(x.h_words, y.h_words, "{label} phase {name}: h_words");
        assert_eq!(x.supersteps, y.supersteps, "{label} phase {name}: supersteps");
        if compare_wall {
            assert_eq!(x.wall_us, y.wall_us, "{label} phase {name}: wall");
        }
    }
    if compare_wall {
        assert_eq!(a.wall_us, b.wall_us, "{label}: total virtual wall");
    }
}

fn assert_identical_runs<K: StudyKey>(
    a: &SingleRun<K>,
    b: &SingleRun<K>,
    label: &str,
    compare_wall: bool,
) {
    assert_eq!(a.outputs.len(), b.outputs.len(), "{label}: output chunk count");
    for (pid, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
        assert_eq!(x.keys, y.keys, "{label} pid={pid}: output keys");
        assert_eq!(x.received, y.received, "{label} pid={pid}: received");
    }
    assert_identical_ledgers(&a.ledger, &b.ledger, label, compare_wall);
}

fn run<K: StudyKey>(
    algo: AlgoVariant,
    bench: Benchmark,
    backend: Backend,
    topology: Option<Topology>,
) -> SingleRun<K> {
    let mut spec = RunSpec::new(algo, bench, P, N).with_backend(backend);
    spec.topology = topology;
    spec.seed = SEED;
    execute_typed::<K>(&spec)
}

/// The two-level topology `[k, p/k]` matching `det2`/`ran2`'s default
/// grouping at `p`.
fn matching_two_level(p: usize) -> Topology {
    Topology::two_level(p, multilevel::default_groups(p))
}

#[test]
fn detk_reproduces_det2_bit_for_bit() {
    let t = matching_two_level(P);
    for bench in [Benchmark::Uniform, Benchmark::DetDup, Benchmark::Staggered] {
        for (backend, compare_wall) in [(Backend::Sim, true), (Backend::Threaded, false)] {
            let det2 = run::<i32>(AlgoVariant::Det2, bench, backend, None);
            let detk = run::<i32>(AlgoVariant::DetK, bench, backend, Some(t));
            let label = format!(
                "det2 vs det-k[{}] bench={} backend={backend:?}",
                t.label(),
                bench.tag(),
            );
            assert_identical_runs(&det2, &detk, &label, compare_wall);
        }
    }
}

#[test]
fn rank_reproduces_ran2_bit_for_bit() {
    let t = matching_two_level(P);
    for bench in [Benchmark::Uniform, Benchmark::DetDup] {
        for (backend, compare_wall) in [(Backend::Sim, true), (Backend::Threaded, false)] {
            let ran2 = run::<u64>(AlgoVariant::Ran2, bench, backend, None);
            let rank = run::<u64>(AlgoVariant::RanK, bench, backend, Some(t));
            let label = format!(
                "ran2 vs ran-k[{}] bench={} backend={backend:?}",
                t.label(),
                bench.tag(),
            );
            assert_identical_runs(&ran2, &rank, &label, compare_wall);
        }
    }
}

#[test]
fn pinned_two_level_topology_matches_the_default_grouping() {
    // `--topology 2x4` on det2/ran2 must be the same machine as their
    // default `default_groups(8) = 2` split — pinning is a no-op when
    // it names the default shape.
    let t = matching_two_level(P);
    for algo in [AlgoVariant::Det2, AlgoVariant::Ran2] {
        let default = run::<i32>(algo, Benchmark::Uniform, Backend::Sim, None);
        let pinned = run::<i32>(algo, Benchmark::Uniform, Backend::Sim, Some(t));
        let label = format!("{} default vs pinned {}", algo.tag(), t.label());
        assert_identical_runs(&default, &pinned, &label, true);
    }
}
