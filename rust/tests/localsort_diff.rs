//! Differential sweep of the IPS local-sort engine (PR 8 satellite).
//!
//! For every key domain × §6.3 input distribution × adversarial shape,
//! `seq::ipssort` must produce *byte-identical* output to both
//! reference base cases (`seq::quicksort`, `seq::radixsort`) and
//! preserve the input multiset fingerprint
//! ([`bsp_sort::util::check::multiset_sig`]).  Cases are driven by the
//! seeded `check` harness, so every failure message carries a
//! `replay seed 0x…` that reproduces the exact input via
//! [`bsp_sort::util::check::replay`].

use bsp_sort::gen::{generate_typed_for_proc, GenKey, ALL_BENCHMARKS};
use bsp_sort::key::{RadixKey, F64, Record, Str};
use bsp_sort::seq::{ips, ipssort, quicksort, radixsort};
use bsp_sort::util::check::{check, multiset_sig};
use bsp_sort::util::rng::SplitMix64;

/// Run all three engines on copies of `input`; IPS must match both
/// references exactly and leave the multiset fingerprint unchanged.
fn assert_engines_agree<K: RadixKey>(input: &[K], label: &str) {
    let sig_in = multiset_sig(input.iter().copied());
    let mut by_quick = input.to_vec();
    quicksort(&mut by_quick);
    let mut by_radix = input.to_vec();
    radixsort(&mut by_radix);
    let mut by_ips = input.to_vec();
    ipssort(&mut by_ips);
    assert_eq!(
        by_ips,
        by_quick,
        "{label}: ipssort differs from quicksort on {} keys",
        input.len()
    );
    assert_eq!(
        by_ips,
        by_radix,
        "{label}: ipssort differs from radixsort on {} keys",
        input.len()
    );
    assert_eq!(
        multiset_sig(by_ips.iter().copied()),
        sig_in,
        "{label}: ipssort changed the key multiset ({} keys)",
        input.len()
    );
}

/// A fresh domain key from the case RNG (payloads/aux vary too, so
/// `Record` exercises distinct-payload duplicates).
fn draw<K: GenKey>(rng: &mut SplitMix64) -> K {
    let d = rng.next_u64() as i32;
    let aux = rng.next_u64();
    K::from_draw(d, aux)
}

/// The adversarial shapes of the issue checklist, instantiated in one
/// domain.  `big` always exceeds the quicksort-fallback cutoff so the
/// block classification/permutation/cleanup machinery actually runs.
fn adversarial_shapes<K: GenKey>(rng: &mut SplitMix64) -> Vec<(&'static str, Vec<K>)> {
    let big = ips::FALLBACK_CUTOFF + 100 + rng.below(2400) as usize;
    let one: K = draw(rng);
    let two: K = draw(rng);
    let mut sorted: Vec<K> = (0..big).map(|_| draw(rng)).collect();
    sorted.sort_unstable();
    let mut reversed = sorted.clone();
    reversed.reverse();
    vec![
        ("empty", Vec::new()),
        ("single", vec![one]),
        ("all-equal", vec![one; big]),
        (
            "two-value",
            (0..big).map(|_| if rng.below(2) == 0 { one } else { two }).collect(),
        ),
        ("already-sorted", sorted),
        ("reverse-sorted", reversed),
    ]
}

/// §6.3 + skew distributions × all five key domains, with the processor slice
/// (`pid`, `p`) and the local size randomized per case.
#[test]
fn ips_matches_references_across_distributions() {
    check("localsort_diff::distributions", |rng| {
        let p = 1 + rng.below(8) as usize;
        let pid = rng.below(p as u64) as usize;
        let n = 1 + rng.below(3000) as usize;
        for bench in ALL_BENCHMARKS {
            let tag = bench.tag();
            let keys: Vec<i32> = generate_typed_for_proc(bench, pid, p, n);
            assert_engines_agree(&keys, &format!("i32/{tag}"));
            let keys: Vec<u64> = generate_typed_for_proc(bench, pid, p, n);
            assert_engines_agree(&keys, &format!("u64/{tag}"));
            let keys: Vec<F64> = generate_typed_for_proc(bench, pid, p, n);
            assert_engines_agree(&keys, &format!("f64/{tag}"));
            let keys: Vec<Record> = generate_typed_for_proc(bench, pid, p, n);
            assert_engines_agree(&keys, &format!("record/{tag}"));
            let keys: Vec<Str> = generate_typed_for_proc(bench, pid, p, n);
            assert_engines_agree(&keys, &format!("str/{tag}"));
        }
    });
}

/// Adversarial shapes (empty, single, all-equal, two-value, sorted,
/// reverse-sorted) in all five domains.
#[test]
fn ips_matches_references_on_adversarial_shapes() {
    check("localsort_diff::adversarial", |rng| {
        for (shape, input) in adversarial_shapes::<i32>(rng) {
            assert_engines_agree(&input, &format!("i32/{shape}"));
        }
        for (shape, input) in adversarial_shapes::<u64>(rng) {
            assert_engines_agree(&input, &format!("u64/{shape}"));
        }
        for (shape, input) in adversarial_shapes::<F64>(rng) {
            assert_engines_agree(&input, &format!("f64/{shape}"));
        }
        for (shape, input) in adversarial_shapes::<Record>(rng) {
            assert_engines_agree(&input, &format!("record/{shape}"));
        }
        for (shape, input) in adversarial_shapes::<Str>(rng) {
            assert_engines_agree(&input, &format!("str/{shape}"));
        }
    });
}
