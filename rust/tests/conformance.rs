//! Property-based conformance suite on the deterministic simulator
//! backend (`bsp::sim::SimMachine`).
//!
//! The paper's claims are statements about *any* BSP machine: the output
//! is the sorted permutation of the input, duplicate handling is
//! transparent (§5.1.1), and the routed sets are balanced — Lemma 5.1
//! bounds the keys received by any processor by `(1 + ε)·n/p` plus an
//! additive oversampling term, with `ε = 1/⌈ω⌉` from the configured
//! oversampling ratio.  The threaded engine can only check this up to
//! the host's thread budget; the simulator checks it at `p` up to 4096,
//! seeded and bit-for-bit replayable.
//!
//! ~390 seeded cases: every algorithm variant and baseline ×
//! benchmark distributions (the §6.3 seven plus the skew families
//! `[Z]`/`[X]`/`[AS]`/`[R]`/`[8D]`) × all five key domains (including
//! `str`, whose radix image is an inexact 8-byte prefix) ×
//! `p ∈ {4 .. 1024}`, plus a depth-3 tier pinning `4×4×4` / `8×8×8` /
//! `16×16×16` topology trees for det-k/ran-k at `p ∈ {64, 512, 4096}`.
//! Each case asserts:
//!
//! 1. **sortedness + size** (inside `execute_typed`, the harness gate),
//! 2. **permutation** — order-independent multiset hash of the output
//!    equals the regenerated input's,
//! 3. **balance** — `received ≤ bound(algo, n, p, ω)`: the exact
//!    Lemma 5.1 bound for SORT_DET_BSP, the exact `n/p` for \[BSI\], a
//!    slackened high-probability envelope for the randomized and
//!    two-level variants (no bound for the [39]/[40]/[44] baselines —
//!    [44] deliberately cannot handle duplicates),
//! 4. duplicate **transparency** — the `[DD]` cases run the same
//!    balance bound under massive key equality.
//!
//! On failure the panic message carries the case label and replay seed.
//!
//! The suite ends with the backend-equivalence test: the same program +
//! seed on `BspMachine` (p = 8) and `SimMachine` (p = 8) must produce
//! identical sorted output and identical per-phase/per-superstep
//! *charged* accounting (ops, words, superstep structure) — wall-clock
//! is real µs on one and virtual µs on the other, and is exactly the
//! field the comparison skips.

use std::panic::{catch_unwind, AssertUnwindSafe};

use bsp_sort::bsp::{Backend, Ledger, Topology};
use bsp_sort::experiment::{
    execute_typed, resolved_deep_topology, AlgoVariant, RunSpec, StudyKey, ALL_ALGOS,
};
use bsp_sort::gen::{generate_typed_for_proc, Benchmark};
use bsp_sort::key::{Record, Str, F64};
use bsp_sort::sort::{det, iran, LocalSortEngine, SampleSortMethod, SortConfig, ALL_ENGINES};
use bsp_sort::util::check::multiset_sig;

/// One SplitMix64 step (the crate's own RNG), used as a scrambler for
/// case seeds.
fn mix(z: u64) -> u64 {
    bsp_sort::util::rng::SplitMix64::new(z).next_u64()
}

/// The per-algorithm balance bound on keys received by any processor,
/// or `None` for baselines without a paper guarantee ([44]/PSRS is the
/// documented counter-example: it cannot handle duplicates at all).
fn balance_bound(
    algo: AlgoVariant,
    n: usize,
    p: usize,
    cfg: &SortConfig,
    topology: Option<Topology>,
) -> Option<f64> {
    let npp = n as f64 / p as f64;
    match algo {
        // Lemma 5.1, deterministic guarantee: (1 + 1/⌈ω⌉)·n/p + ⌈ω⌉·p.
        AlgoVariant::Det => Some(det::nmax_bound(n, p, det::omega_det(cfg, n))),
        // Claim 5.1 high-probability bound (1 + 1/ω)·n/p, slackened
        // (×1.5 + ω·p + 64) so fixed seeds at small n/p stay robust.
        AlgoVariant::Iran | AlgoVariant::Ran => {
            let w = iran::omega_ran(cfg, n);
            Some(1.5 * iran::nmax_bound(n, p, w) + w * p as f64 + 64.0)
        }
        // Bitonic merge-split preserves local sizes exactly.
        AlgoVariant::Bsi => Some(npp),
        // Two levels compose two oversampling slacks; a generous
        // envelope still catches any duplicate-collapse (which would
        // put Θ(n) keys on one processor).
        AlgoVariant::Det2 | AlgoVariant::Ran2 => {
            let r = det::omega_det(cfg, n).ceil().max(1.0);
            Some(3.0 * npp + 4.0 * r * p as f64 + 256.0)
        }
        // Depth-k: every routing level compounds one oversampling slack
        // (factor ≤ 2 at ω = 1), so the envelope scales with the actual
        // recursion depth — still far below the Θ(n) collapse a
        // duplicate-tagging bug would cause (2^d·n/p ≪ n for p ≫ 2^d).
        AlgoVariant::DetK | AlgoVariant::RanK => {
            let t = topology.unwrap_or_else(|| {
                let spec = RunSpec::new(algo, Benchmark::Uniform, p, n).with_cfg(*cfg);
                resolved_deep_topology(&spec)
            });
            let d = t.depth().max(1) as f64;
            let r = det::omega_det(cfg, n).ceil().max(1.0);
            Some(npp * 2.0f64.powf(d) + 4.0 * r * p as f64 * d + 512.0 * d)
        }
        AlgoVariant::HelmanDet | AlgoVariant::HelmanRan | AlgoVariant::Psrs => None,
    }
}

/// The configuration a case runs with.  Large-`p` cases use sequential
/// sample sorting and ω = 1: the p²·⌈ω⌉ sample is intrinsic to the
/// algorithms, and ω = 1 keeps it (and the suite's runtime) at its
/// minimum while Lemma 5.1 still holds exactly (with ε = 1).
fn case_cfg(p: usize) -> SortConfig {
    if p >= 256 {
        SortConfig::default()
            .with_sample_sort(SampleSortMethod::Sequential)
            .with_omega(1.0)
    } else {
        SortConfig::default()
    }
}

/// Run one seeded case on the simulator backend and check every
/// conformance property.  Panics carry the case label + replay seed.
/// A pinned `topology` (depth-k variants only) is part of the label, so
/// failures replay against the exact tree that was exercised.
fn check_case<K: StudyKey>(
    algo: AlgoVariant,
    bench: Benchmark,
    n: usize,
    p: usize,
    topology: Option<Topology>,
    seed: u64,
) {
    let cfg = case_cfg(p);
    let topo_label = topology.map(|t| format!(" topology={}", t.label())).unwrap_or_default();
    let label = format!(
        "algo={} bench={} domain={} n={n} p={p}{topo_label} backend=sim replay-seed={seed:#x}",
        algo.tag(),
        bench.tag(),
        K::NAME,
    );
    let mut spec = RunSpec::new(algo, bench, p, n).with_cfg(cfg).with_backend(Backend::Sim);
    spec.topology = topology;
    spec.seed = seed;

    let single = match catch_unwind(AssertUnwindSafe(|| execute_typed::<K>(&spec))) {
        Ok(s) => s,
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("non-string panic payload");
            panic!("[conformance] {label}: execution failed: {msg}");
        }
    };

    // Permutation: multiset fingerprint of output == regenerated input.
    let out_hash = multiset_sig(single.outputs.iter().flat_map(|r| r.keys.iter().copied()));
    let in_hash = multiset_sig(
        (0..p).flat_map(|pid| generate_typed_for_proc::<K>(bench, pid, p, n / p).into_iter()),
    );
    assert_eq!(
        in_hash, out_hash,
        "[conformance] {label}: output is not a permutation of the input"
    );

    // Balance / duplicate transparency: Lemma 5.1-style received bound.
    if let Some(bound) = balance_bound(algo, n, p, &cfg, topology) {
        for (pid, r) in single.outputs.iter().enumerate() {
            assert!(
                (r.received as f64) <= bound + 1.0,
                "[conformance] {label} pid={pid}: received {} keys > balance bound {bound:.1}",
                r.received
            );
        }
    }
}

/// Derive a distinct, fixed replay seed per case index.
fn case_seed(tier: u64, idx: u64) -> u64 {
    mix(0xC0F0_0000 ^ (tier << 32) ^ idx)
}

fn sweep_tier<K: StudyKey>(
    tier: u64,
    algos: &[AlgoVariant],
    benches: &[Benchmark],
    n: usize,
    p: usize,
) {
    let mut idx = 0u64;
    for &algo in algos {
        for &bench in benches {
            check_case::<K>(algo, bench, n, p, None, case_seed(tier, idx));
            idx += 1;
        }
    }
}

// --------------------------------------------------------------------
// Tier A: p = 4 — every algorithm × {U, DD, S} × every key domain
// (132 cases).
// --------------------------------------------------------------------

const TIER_A_BENCHES: [Benchmark; 3] =
    [Benchmark::Uniform, Benchmark::DetDup, Benchmark::Staggered];

#[test]
fn conformance_p4_i32_all_algos() {
    sweep_tier::<i32>(1, &ALL_ALGOS, &TIER_A_BENCHES, 1 << 12, 4);
}

#[test]
fn conformance_p4_u64_all_algos() {
    sweep_tier::<u64>(2, &ALL_ALGOS, &TIER_A_BENCHES, 1 << 12, 4);
}

#[test]
fn conformance_p4_f64_all_algos() {
    sweep_tier::<F64>(3, &ALL_ALGOS, &TIER_A_BENCHES, 1 << 12, 4);
}

#[test]
fn conformance_p4_record_all_algos() {
    sweep_tier::<Record>(4, &ALL_ALGOS, &TIER_A_BENCHES, 1 << 12, 4);
}

// --------------------------------------------------------------------
// Tier B: p = 64 — every algorithm × {U, WR} on i32 (22 cases); [WR]
// is the regular-sampling adversary of [39].
// --------------------------------------------------------------------

#[test]
fn conformance_p64_i32_uniform_and_adversarial() {
    sweep_tier::<i32>(
        5,
        &ALL_ALGOS,
        &[Benchmark::Uniform, Benchmark::WorstRegular],
        1 << 14,
        64,
    );
}

// --------------------------------------------------------------------
// Tier C: p = 256 — every algorithm × {U (i32 + u64), DD (i32)}
// (33 cases).
// --------------------------------------------------------------------

#[test]
fn conformance_p256_uniform_i32() {
    sweep_tier::<i32>(6, &ALL_ALGOS, &[Benchmark::Uniform], 1 << 16, 256);
}

#[test]
fn conformance_p256_uniform_u64() {
    sweep_tier::<u64>(7, &ALL_ALGOS, &[Benchmark::Uniform], 1 << 16, 256);
}

#[test]
fn conformance_p256_duplicates_i32() {
    sweep_tier::<i32>(8, &ALL_ALGOS, &[Benchmark::DetDup], 1 << 16, 256);
}

// --------------------------------------------------------------------
// Tier D: p = 1024 — the acceptance grid: all eight sort variants +
// both baseline families, for every key domain (44 cases), plus
// duplicate transparency at p = 1024 (9 cases).
// --------------------------------------------------------------------

const P1024_N: usize = 1 << 14; // 16 keys per virtual processor

#[test]
fn conformance_p1024_i32_all_algos() {
    sweep_tier::<i32>(9, &ALL_ALGOS, &[Benchmark::Uniform], P1024_N, 1024);
}

#[test]
fn conformance_p1024_u64_all_algos() {
    sweep_tier::<u64>(10, &ALL_ALGOS, &[Benchmark::Uniform], P1024_N, 1024);
}

#[test]
fn conformance_p1024_f64_all_algos() {
    sweep_tier::<F64>(11, &ALL_ALGOS, &[Benchmark::Uniform], P1024_N, 1024);
}

#[test]
fn conformance_p1024_record_all_algos() {
    sweep_tier::<Record>(12, &ALL_ALGOS, &[Benchmark::Uniform], P1024_N, 1024);
}

#[test]
fn conformance_p1024_duplicate_transparency() {
    // Massive key equality at p = 1024: the tagged algorithms stay
    // within their balance bounds; the tagging baselines ([39]/[40])
    // must still sort correctly (no bound is asserted for them).
    sweep_tier::<i32>(
        13,
        &[
            AlgoVariant::Det,
            AlgoVariant::Iran,
            AlgoVariant::Ran,
            AlgoVariant::Det2,
            AlgoVariant::Ran2,
            AlgoVariant::HelmanDet,
            AlgoVariant::HelmanRan,
            AlgoVariant::DetK,
            AlgoVariant::RanK,
        ],
        &[Benchmark::DetDup],
        P1024_N,
        1024,
    );
}

// --------------------------------------------------------------------
// Depth-3 tier: det-k / ran-k with pinned three-level topology trees on
// the simulator — `4×4×4` at p = 64, `8×8×8` at p = 512, `16×16×16` at
// p = 4096 — over four key domains (i32/u64/f64/record) × {U, DD}
// (48 cases).  Exercises
// the recursion one level past the paper's two-level experiments while
// asserting the same four properties, with the balance envelope scaled
// to depth 3.
// --------------------------------------------------------------------

const DEPTH3_BENCHES: [Benchmark; 2] = [Benchmark::Uniform, Benchmark::DetDup];

fn sweep_depth3<K: StudyKey>(tier: u64, n: usize, p: usize, dims: &[usize]) {
    let topology = Topology::new(dims);
    assert_eq!(topology.nprocs(), p, "depth-3 tier dims must multiply to p");
    let mut idx = 0u64;
    for &algo in &[AlgoVariant::DetK, AlgoVariant::RanK] {
        for &bench in &DEPTH3_BENCHES {
            check_case::<K>(algo, bench, n, p, Some(topology), case_seed(tier, idx));
            idx += 1;
        }
    }
}

#[test]
fn conformance_depth3_p64_i32() {
    sweep_depth3::<i32>(14, 1 << 13, 64, &[4, 4, 4]);
}

#[test]
fn conformance_depth3_p64_u64() {
    sweep_depth3::<u64>(15, 1 << 13, 64, &[4, 4, 4]);
}

#[test]
fn conformance_depth3_p64_f64() {
    sweep_depth3::<F64>(16, 1 << 13, 64, &[4, 4, 4]);
}

#[test]
fn conformance_depth3_p64_record() {
    sweep_depth3::<Record>(17, 1 << 13, 64, &[4, 4, 4]);
}

#[test]
fn conformance_depth3_p512_i32() {
    sweep_depth3::<i32>(18, 1 << 14, 512, &[8, 8, 8]);
}

#[test]
fn conformance_depth3_p512_u64() {
    sweep_depth3::<u64>(19, 1 << 14, 512, &[8, 8, 8]);
}

#[test]
fn conformance_depth3_p512_f64() {
    sweep_depth3::<F64>(20, 1 << 14, 512, &[8, 8, 8]);
}

#[test]
fn conformance_depth3_p512_record() {
    sweep_depth3::<Record>(21, 1 << 14, 512, &[8, 8, 8]);
}

#[test]
fn conformance_depth3_p4096_i32() {
    sweep_depth3::<i32>(22, 1 << 16, 4096, &[16, 16, 16]);
}

#[test]
fn conformance_depth3_p4096_u64() {
    sweep_depth3::<u64>(23, 1 << 16, 4096, &[16, 16, 16]);
}

#[test]
fn conformance_depth3_p4096_f64() {
    sweep_depth3::<F64>(24, 1 << 16, 4096, &[16, 16, 16]);
}

#[test]
fn conformance_depth3_p4096_record() {
    sweep_depth3::<Record>(25, 1 << 16, 4096, &[16, 16, 16]);
}

// --------------------------------------------------------------------
// Local-sort engine axis (tiers 26–29): det / ran / det-k at
// p ∈ {4, 64, 256} on the simulator, under all three engines
// (quicksort, lsd-radix, ips).  The engine is a *base-case* choice: it
// must never change what gets routed or when, only what the local sort
// charges.  So for a fixed (algo, bench, n, p, seed):
//
// 1. the sorted output is bit-identical under all three engines, and
// 2. the charged ledgers agree on superstep structure (count, labels,
//    phases, procs, rounds) and on every communication charge (h_words,
//    total_words) — only `max_ops` may differ, and across the engine
//    set at least one superstep's ops *must* differ (otherwise the
//    engine charge is not reaching the ledger at all).
//
// det-k pins its topology so the cost-model planner cannot resolve
// different trees for different engines.
// --------------------------------------------------------------------

/// Ledger equality modulo local-sort ops: everything but `max_ops`
/// must match; returns whether any superstep's ops differed.
fn assert_only_ops_differ(a: &Ledger, b: &Ledger, label: &str) -> bool {
    assert_eq!(a.supersteps.len(), b.supersteps.len(), "{label}: superstep count differs");
    let mut ops_differ = false;
    for (i, (x, y)) in a.supersteps.iter().zip(&b.supersteps).enumerate() {
        assert_eq!(x.label, y.label, "{label} superstep {i}: label");
        assert_eq!(x.phase, y.phase, "{label} superstep {i}: phase");
        assert_eq!(x.procs, y.procs, "{label} superstep {i}: procs");
        assert_eq!(x.round, y.round, "{label} superstep {i}: round");
        assert_eq!(x.h_words, y.h_words, "{label} superstep {i} ({}): h_words", x.label);
        assert_eq!(
            x.total_words, y.total_words,
            "{label} superstep {i} ({}): total_words",
            x.label
        );
        ops_differ |= x.max_ops != y.max_ops;
    }
    ops_differ
}

/// Run one (algo, n, p) cell under every engine and check output
/// identity + ledger invariance.  `dims` pins the depth-k topology.
fn sweep_engine_axis<K: StudyKey>(
    tier: u64,
    algos: &[(AlgoVariant, Option<&[usize]>)],
    benches: &[Benchmark],
    n: usize,
    p: usize,
) {
    let mut idx = 0u64;
    for &(algo, dims) in algos {
        for &bench in benches {
            let seed = case_seed(tier, idx);
            idx += 1;
            let topology = dims.map(Topology::new);
            let runs: Vec<(LocalSortEngine, _)> = ALL_ENGINES
                .iter()
                .map(|&engine| {
                    let mut spec = RunSpec::new(algo, bench, p, n)
                        .with_cfg(case_cfg(p).with_local_sort(engine))
                        .with_backend(Backend::Sim);
                    spec.topology = topology;
                    spec.seed = seed;
                    (engine, execute_typed::<K>(&spec))
                })
                .collect();
            let (base_engine, base) = &runs[0];
            let base_keys: Vec<K> =
                base.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
            let mut any_ops_differ = false;
            for (engine, run) in &runs[1..] {
                let label = format!(
                    "engine-axis algo={} bench={} domain={} n={n} p={p} {} vs {} replay-seed={seed:#x}",
                    algo.tag(),
                    bench.tag(),
                    K::NAME,
                    base_engine.tag(),
                    engine.tag(),
                );
                let keys: Vec<K> =
                    run.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
                assert_eq!(keys, base_keys, "{label}: outputs differ across engines");
                any_ops_differ |= assert_only_ops_differ(&base.ledger, &run.ledger, &label);
            }
            assert!(
                any_ops_differ,
                "engine-axis algo={} bench={} n={n} p={p}: every engine charged identical \
                 ops — the local-sort charge is not reaching the ledger",
                algo.tag(),
                bench.tag(),
            );
        }
    }
}

#[test]
fn conformance_engine_axis_p4_i32() {
    sweep_engine_axis::<i32>(
        26,
        &[
            (AlgoVariant::Det, None),
            (AlgoVariant::Ran, None),
            (AlgoVariant::DetK, Some(&[2, 2])),
        ],
        &[
            Benchmark::Uniform,
            Benchmark::DetDup,
            Benchmark::Zipf(100),
            Benchmark::EightDup,
        ],
        1 << 12,
        4,
    );
}

#[test]
fn conformance_engine_axis_p4_u64() {
    sweep_engine_axis::<u64>(
        27,
        &[
            (AlgoVariant::Det, None),
            (AlgoVariant::Ran, None),
            (AlgoVariant::DetK, Some(&[2, 2])),
        ],
        &[Benchmark::Uniform],
        1 << 12,
        4,
    );
}

#[test]
fn conformance_engine_axis_p64_i32() {
    sweep_engine_axis::<i32>(
        28,
        &[
            (AlgoVariant::Det, None),
            (AlgoVariant::Ran, None),
            (AlgoVariant::DetK, Some(&[8, 8])),
        ],
        &[Benchmark::Uniform],
        1 << 14,
        64,
    );
}

#[test]
fn conformance_engine_axis_p256_i32() {
    sweep_engine_axis::<i32>(
        29,
        &[
            (AlgoVariant::Det, None),
            (AlgoVariant::Ran, None),
            (AlgoVariant::DetK, Some(&[16, 16])),
        ],
        &[Benchmark::Uniform],
        1 << 16,
        256,
    );
}

// --------------------------------------------------------------------
// Skew-workload tier (30): every algorithm × the five adversarial
// distributions added beyond the paper's §6.3 set — zipf, exponential,
// almost-sorted, reverse, eight-dup — on i32 at p = 4 (55 cases).
// Zipf and eight-dup are duplicate-heavy, so this tier doubles as a
// §5.1.1 transparency check for the skew generators.
// --------------------------------------------------------------------

const SKEW_BENCHES: [Benchmark; 5] = [
    Benchmark::Zipf(100),
    Benchmark::Exponential,
    Benchmark::AlmostSorted(5),
    Benchmark::Reverse,
    Benchmark::EightDup,
];

#[test]
fn conformance_p4_i32_skew_benchmarks() {
    sweep_tier::<i32>(30, &ALL_ALGOS, &SKEW_BENCHES, 1 << 12, 4);
}

// --------------------------------------------------------------------
// String-domain tiers (31–32): the `str` domain's radix image is an
// *inexact* 8-byte prefix, so these tiers are the end-to-end proof that
// shared-prefix tie-breaking survives every variant's routing — tier A
// benches at p = 4 (33 cases) and {U, Z} at p = 64 (22 cases).
// --------------------------------------------------------------------

#[test]
fn conformance_p4_str_all_algos() {
    sweep_tier::<Str>(31, &ALL_ALGOS, &TIER_A_BENCHES, 1 << 12, 4);
}

#[test]
fn conformance_p64_str_uniform_and_zipf() {
    sweep_tier::<Str>(
        32,
        &ALL_ALGOS,
        &[Benchmark::Uniform, Benchmark::Zipf(100)],
        1 << 14,
        64,
    );
}

// --------------------------------------------------------------------
// Engine axis on strings (tier 33): the three local-sort engines must
// stay bit-identical on the prefix-image domain too — the radix engines
// re-sort equal-image runs by full `Ord`, so their output matches the
// comparison engine exactly.
// --------------------------------------------------------------------

#[test]
fn conformance_engine_axis_p4_str() {
    sweep_engine_axis::<Str>(
        33,
        &[(AlgoVariant::Det, None), (AlgoVariant::Ran, None)],
        &[Benchmark::Uniform, Benchmark::Zipf(100)],
        1 << 12,
        4,
    );
}

// --------------------------------------------------------------------
// Backend equivalence: threaded engine vs simulator at p = 8.
// --------------------------------------------------------------------

/// Charged-accounting equality between two ledgers: identical superstep
/// structure (labels, phases, procs, rounds) and identical charged
/// numbers (ops, h, total words), identical per-phase charge maxima —
/// wall-clock fields (real µs vs virtual µs) are exactly what may
/// differ between the backends, and are skipped.
fn assert_charged_equivalence(thr: &Ledger, sim: &Ledger, label: &str) {
    assert_eq!(
        thr.supersteps.len(),
        sim.supersteps.len(),
        "{label}: superstep count differs"
    );
    for (i, (a, b)) in thr.supersteps.iter().zip(&sim.supersteps).enumerate() {
        assert_eq!(a.label, b.label, "{label} superstep {i}: label");
        assert_eq!(a.phase, b.phase, "{label} superstep {i}: phase");
        assert_eq!(a.max_ops, b.max_ops, "{label} superstep {i} ({}): max_ops", a.label);
        assert_eq!(a.h_words, b.h_words, "{label} superstep {i} ({}): h_words", a.label);
        assert_eq!(
            a.total_words, b.total_words,
            "{label} superstep {i} ({}): total_words",
            a.label
        );
        assert_eq!(a.procs, b.procs, "{label} superstep {i}: procs");
        assert_eq!(a.reporters, b.reporters, "{label} superstep {i}: reporters");
        assert_eq!(a.round, b.round, "{label} superstep {i}: round");
    }
    let thr_phases: Vec<&String> = thr.phases.keys().collect();
    let sim_phases: Vec<&String> = sim.phases.keys().collect();
    assert_eq!(thr_phases, sim_phases, "{label}: phase sets differ");
    for (name, a) in &thr.phases {
        let b = &sim.phases[name];
        assert_eq!(a.max_ops, b.max_ops, "{label} phase {name}: charged ops");
        assert_eq!(a.h_words, b.h_words, "{label} phase {name}: h words");
        assert_eq!(a.supersteps, b.supersteps, "{label} phase {name}: superstep count");
    }
}

#[test]
fn backend_equivalence_identical_output_and_charges_p8() {
    // Same program + same seed on both backends: identical sorted
    // output, identical charged op counts per phase and per superstep.
    let (p, n, seed) = (8usize, 1 << 12, 0x5EED_CAFEu64);
    for algo in ALL_ALGOS {
        let mut spec = RunSpec::new(algo, Benchmark::Staggered, p, n);
        spec.seed = seed;
        let threaded = execute_typed::<i32>(&spec.with_backend(Backend::Threaded));
        let sim = execute_typed::<i32>(&spec.with_backend(Backend::Sim));
        let label = format!("equivalence algo={}", algo.tag());

        let thr_keys: Vec<i32> =
            threaded.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
        let sim_keys: Vec<i32> =
            sim.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
        assert_eq!(thr_keys, sim_keys, "{label}: outputs differ");
        for (pid, (a, b)) in threaded.outputs.iter().zip(&sim.outputs).enumerate() {
            assert_eq!(a.received, b.received, "{label} pid={pid}: received");
            assert_eq!(a.keys.len(), b.keys.len(), "{label} pid={pid}: chunk size");
        }

        assert_charged_equivalence(&threaded.ledger, &sim.ledger, &label);
    }
}

#[test]
fn backend_equivalence_heavy_duplicates_p8() {
    // The §5.1.1 pressure case: both backends agree under massive key
    // equality too (tag streams and all).
    let (p, n, seed) = (8usize, 1 << 12, 0x00D0_D0D0u64);
    for algo in [AlgoVariant::Det, AlgoVariant::Ran, AlgoVariant::Det2] {
        let mut spec = RunSpec::new(algo, Benchmark::DetDup, p, n);
        spec.seed = seed;
        let threaded = execute_typed::<u64>(&spec.with_backend(Backend::Threaded));
        let sim = execute_typed::<u64>(&spec.with_backend(Backend::Sim));
        let label = format!("dup-equivalence algo={}", algo.tag());
        let thr_keys: Vec<u64> =
            threaded.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
        let sim_keys: Vec<u64> =
            sim.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
        assert_eq!(thr_keys, sim_keys, "{label}: outputs differ");
        assert_charged_equivalence(&threaded.ledger, &sim.ledger, &label);
    }
}

#[test]
fn sim_replay_is_bit_for_bit_across_runs() {
    // The replay guarantee the failure messages rely on: running the
    // same spec twice gives identical outputs AND identical virtual
    // wall times (not just identical charges).
    let mut spec = RunSpec::new(AlgoVariant::Iran, Benchmark::Gaussian, 64, 1 << 13)
        .with_backend(Backend::Sim);
    spec.seed = 0x1234_5678;
    let a = execute_typed::<i32>(&spec);
    let b = execute_typed::<i32>(&spec);
    assert_eq!(a.ledger.wall_us, b.ledger.wall_us);
    assert_eq!(a.ledger.supersteps.len(), b.ledger.supersteps.len());
    for (x, y) in a.ledger.supersteps.iter().zip(&b.ledger.supersteps) {
        assert_eq!(x.wall_us, y.wall_us, "virtual wall must replay exactly");
        assert_eq!(x.max_ops, y.max_ops);
    }
}
