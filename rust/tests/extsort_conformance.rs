//! External-sort conformance tier: the out-of-core EM-BSP sort must be
//! **bit-identical** to the in-core path on the same deterministic
//! input stream.
//!
//! Both paths draw the same per-processor key stream
//! (`gen::generate_typed_for_proc` is seeded by pid alone), and both
//! end fully sorted under the domain's total `Ord`, so the
//! concatenated outputs must match element-for-element — not just as
//! multisets.  The suite asserts exactly that, across:
//!
//! * all five key domains (`i32`, `u64`, `f64`, `record`, `str`) ×
//!   the `[U]` / `[DD]` / `[Z-100]` benchmarks on the simulator
//!   backend (in-memory block store, virtual time, replayable);
//! * spill-forcing budgets (`mem_budget ≪ n/p`) on the threaded
//!   backend, where runs round-trip through real temp-file blocks;
//! * the merge edge cases: a single run (`q = 1` loser-tree
//!   degenerate), budgets larger than the input (no spill pressure),
//!   `p = 1` (no scatter), and massive duplication at tiny `n/p`
//!   (splitter ties → empty scatter segments).
//!
//! Every case also checks the order-independent multiset signature so
//! a sortedness-preserving key corruption cannot slip through the
//! element-wise comparison being vacuous.

use bsp_sort::bsp::Backend;
use bsp_sort::experiment::{execute_typed, AlgoVariant, RunSpec, StudyKey};
use bsp_sort::ext::{sort_external, ExtRun, ExtSortSpec};
use bsp_sort::gen::Benchmark;
use bsp_sort::key::{Record, Str, F64};
use bsp_sort::util::check::multiset_sig;

/// Run the external sort and return it with its concatenated output.
fn run_ext<K: StudyKey>(
    bench: Benchmark,
    n: usize,
    p: usize,
    budget: usize,
    backend: Backend,
) -> (ExtRun<K>, Vec<K>) {
    let mut spec = ExtSortSpec::new(bench, n, p, budget);
    spec.backend = backend;
    let run = sort_external::<K>(&spec).expect("external sort completes");
    let keys: Vec<K> = run.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect();
    (run, keys)
}

/// The in-core reference: the same cell through the DET BSP sort on
/// the simulator (deterministic, engine-independent — any fully sorted
/// permutation of the same input is the same sequence).
fn in_core_reference<K: StudyKey>(bench: Benchmark, n: usize, p: usize) -> Vec<K> {
    let spec = RunSpec::new(AlgoVariant::Det, bench, p, n).with_backend(Backend::Sim);
    let single = execute_typed::<K>(&spec);
    single.outputs.iter().flat_map(|r| r.keys.iter().copied()).collect()
}

/// One conformance case: external output ≡ in-core output, as a
/// sequence and as a multiset, with the expected store backend.
fn assert_conforms<K: StudyKey>(
    bench: Benchmark,
    n: usize,
    p: usize,
    budget: usize,
    backend: Backend,
) {
    let (run, ext) = run_ext::<K>(bench, n, p, budget, backend);
    let core = in_core_reference::<K>(bench, n, p);
    let label = format!(
        "{} n={n} p={p} budget={budget} backend={backend:?}",
        bench.tag()
    );
    assert_eq!(ext.len(), core.len(), "{label}: size");
    assert_eq!(
        multiset_sig(ext.iter().copied()),
        multiset_sig(core.iter().copied()),
        "{label}: multiset signature"
    );
    assert_eq!(ext, core, "{label}: bit-identity");
    let want_store = match backend {
        Backend::Sim => "mem",
        Backend::Threaded => "spill",
    };
    assert_eq!(run.store_kind, want_store, "{label}: store backend");
    assert_eq!(run.blocks_read, run.blocks_written, "{label}: block accounting");
}

const BENCHES: [Benchmark; 3] =
    [Benchmark::Uniform, Benchmark::DetDup, Benchmark::Zipf(100)];

// ------------------------------------------------------------------
// Domain × benchmark matrix on the simulator (spill-forcing budget:
// 256 keys against n/p = 1024).
// ------------------------------------------------------------------

#[test]
fn sim_external_matches_in_core_i32() {
    for bench in BENCHES {
        assert_conforms::<i32>(bench, 4096, 4, 256, Backend::Sim);
    }
}

#[test]
fn sim_external_matches_in_core_u64() {
    for bench in BENCHES {
        assert_conforms::<u64>(bench, 4096, 4, 256, Backend::Sim);
    }
}

#[test]
fn sim_external_matches_in_core_f64() {
    for bench in BENCHES {
        assert_conforms::<F64>(bench, 4096, 4, 256, Backend::Sim);
    }
}

#[test]
fn sim_external_matches_in_core_record() {
    for bench in BENCHES {
        assert_conforms::<Record>(bench, 4096, 4, 256, Backend::Sim);
    }
}

#[test]
fn sim_external_matches_in_core_str() {
    for bench in BENCHES {
        assert_conforms::<Str>(bench, 4096, 4, 256, Backend::Sim);
    }
}

// ------------------------------------------------------------------
// Threaded backend: the runs round-trip through real temp-file blocks.
// ------------------------------------------------------------------

#[test]
fn threaded_spill_forced_matches_in_core() {
    // budget 200 < n/p = 1024 forces 6 runs per processor to disk.
    assert_conforms::<i32>(Benchmark::Uniform, 4096, 4, 200, Backend::Threaded);
    assert_conforms::<u64>(Benchmark::DetDup, 4096, 4, 200, Backend::Threaded);
}

#[test]
fn threaded_spill_counts_runs() {
    let (run, _) = run_ext::<i32>(Benchmark::Uniform, 4096, 4, 200, Backend::Threaded);
    // ⌈1024 / 200⌉ = 6 runs on each of the 4 processors.
    assert_eq!(run.runs_formed, 24);
    assert!(run.blocks_written > 0);
}

// ------------------------------------------------------------------
// Merge edge cases: q = 1, oversized budgets, p = 1, duplicate floods.
// ------------------------------------------------------------------

#[test]
fn budget_at_least_n_local_forms_one_run_per_proc() {
    // No spill pressure: each processor sorts its whole input in core
    // and the merge consumes exactly p runs.
    let (run, ext) = run_ext::<i32>(Benchmark::Uniform, 4096, 4, 4096, Backend::Sim);
    assert_eq!(run.runs_formed, 4);
    assert_eq!(ext, in_core_reference::<i32>(Benchmark::Uniform, 4096, 4));
}

#[test]
fn p1_single_run_is_the_q1_degenerate_merge() {
    // One processor, budget ≥ n: a single run, no scatter, a q = 1
    // merge (the loser tree's buffer-reuse path).
    let (run, ext) = run_ext::<i32>(Benchmark::Uniform, 1024, 1, 2048, Backend::Sim);
    assert_eq!(run.runs_formed, 1);
    assert_eq!(ext, in_core_reference::<i32>(Benchmark::Uniform, 1024, 1));
}

#[test]
fn p1_many_runs_merge_without_scatter() {
    let (run, ext) = run_ext::<u64>(Benchmark::Zipf(100), 1024, 1, 100, Backend::Sim);
    assert_eq!(run.runs_formed, 11); // ⌈1024 / 100⌉
    assert_eq!(ext, in_core_reference::<u64>(Benchmark::Zipf(100), 1024, 1));
}

#[test]
fn duplicate_floods_with_tiny_budgets_survive_empty_segments() {
    // Massive key equality at n/p = 8 with budget 2: splitter ties
    // route whole runs to single processors, leaving other scatter
    // segments empty — the merge must not require one segment per
    // (run, processor) pair.
    for bench in [Benchmark::DetDup, Benchmark::EightDup] {
        assert_conforms::<i32>(bench, 64, 8, 2, Backend::Sim);
    }
}

#[test]
fn minimum_budget_of_one_key_still_sorts() {
    // The pathological floor: every key is its own run.
    let (run, ext) = run_ext::<i32>(Benchmark::Uniform, 64, 4, 1, Backend::Sim);
    assert_eq!(run.runs_formed, 64);
    assert_eq!(ext, in_core_reference::<i32>(Benchmark::Uniform, 64, 4));
}
