//! Planner acceptance: the cost-model-driven topology choice is
//! near-optimal *as measured*, not just as predicted.
//!
//! For each machine size the planner picks the divisor-tree topology
//! minimizing the closed-form prediction under the synthetic T3D
//! calibration (`cray_t3d(p)` — the same parameters that drive the
//! simulator's virtual clock).  This test replays the decision against
//! ground truth: it *runs* the candidate topologies on the simulator
//! and asserts the planner's choice lands within 10% of the measured
//! minimum (virtual wall-clock of the full sort).
//!
//! At `p ∈ {64, 256}` the candidate set is exhaustive.  At
//! `p ∈ {1024, 4096}` measuring all 512 / 2048 shapes is pointless
//! work, so the measured set is pruned to depth ≤ 3 shapes whose
//! *predicted* cost is within 5× of the planner's pick — with two
//! closed-form justification asserts: no depth ≥ 4 shape out-predicts
//! the best depth ≤ 3 shape, and the planner's own choice always stays
//! in the measured set (a pruned shape would need the model to
//! misprice by > 5.5× to measure under the 10% bar, which the
//! planner-smoke and measured-vs-predicted ratio tests bound far
//! tighter).
//!
//! Debug builds (plain `cargo test`) run the `p = 64` grid only;
//! `./ci.sh --conformance` runs the full release grid.

use bsp_sort::bsp::params::{cray_t3d, BspParams};
use bsp_sort::bsp::{Backend, Topology};
use bsp_sort::experiment::{execute_typed, AlgoVariant, RunSpec};
use bsp_sort::gen::Benchmark;
use bsp_sort::sort::{det, iran, plan, SampleSortMethod, SortConfig};
use bsp_sort::theory;

const SEED: u64 = 0xACCE_0001;

/// Sequential sample sorting + ω = 1 keeps the p²⌈ω⌉-sized one-level
/// samples at their minimum so the exhaustive grids stay fast; the
/// planner is resolved under the same config, so the comparison is
/// apples-to-apples.
fn case_cfg() -> SortConfig {
    SortConfig::default().with_sample_sort(SampleSortMethod::Sequential).with_omega(1.0)
}

/// Measured cost of one candidate: the simulator's virtual wall-clock
/// for the full sort pinned to topology `t` (depth 1 = the one-level
/// degrade path).
fn measured_us(algo: AlgoVariant, n: usize, p: usize, t: Topology) -> f64 {
    let mut spec = RunSpec::new(algo, Benchmark::Uniform, p, n)
        .with_cfg(case_cfg())
        .with_backend(Backend::Sim);
    spec.topology = Some(t);
    spec.seed = SEED;
    execute_typed::<i32>(&spec).ledger.wall_us
}

/// The measured candidate set for one grid point, with the large-`p`
/// pruning described in the module docs.  Always contains the planner's
/// chosen shape.
fn candidates(
    p: usize,
    chosen: Topology,
    chosen_predicted_secs: f64,
    predicted_secs: impl Fn(&Topology) -> f64,
) -> Vec<Topology> {
    let all = plan::enumerate_topologies(p);
    if p <= 256 {
        return all;
    }
    // Closed-form justification for the depth prune: under these
    // parameters no depth ≥ 4 shape out-predicts the best depth ≤ 3
    // shape, so the measured minimum cannot hide there.
    let best_shallow = all
        .iter()
        .filter(|t| t.depth() <= 3)
        .map(&predicted_secs)
        .fold(f64::INFINITY, f64::min);
    let best_deep = all
        .iter()
        .filter(|t| t.depth() >= 4)
        .map(&predicted_secs)
        .fold(f64::INFINITY, f64::min);
    assert!(
        best_deep >= best_shallow,
        "p={p}: a depth ≥ 4 shape out-predicts every depth ≤ 3 shape \
         ({best_deep:.6}s < {best_shallow:.6}s) — the pruned acceptance grid \
         would miss it; widen the depth cut"
    );
    all.into_iter()
        .filter(|t| {
            *t == chosen || (t.depth() <= 3 && predicted_secs(t) <= 5.0 * chosen_predicted_secs)
        })
        .collect()
}

fn assert_within_ten_percent(
    algo: AlgoVariant,
    n: usize,
    p: usize,
    chosen: Topology,
    cands: &[Topology],
) {
    assert!(
        cands.contains(&chosen),
        "p={p}: planner choice {} missing from its own candidate set",
        chosen.label()
    );
    let chosen_us = measured_us(algo, n, p, chosen);
    let mut min_us = f64::INFINITY;
    let mut min_label = String::new();
    for &t in cands {
        let us = measured_us(algo, n, p, t);
        if us < min_us {
            min_us = us;
            min_label = t.label();
        }
    }
    assert!(
        chosen_us <= 1.10 * min_us + 1e-6,
        "p={p} n={n} algo={algo:?}: planner chose {} measuring {chosen_us:.1}µs, \
         but {min_label} measures {min_us:.1}µs — more than 10% off the \
         measured minimum over {} candidate topologies (replay-seed={SEED:#x})",
        chosen.label(),
        cands.len()
    );
}

/// The acceptance grid: (p, n).  Debug builds stop after p = 64 so the
/// tier-1 `cargo test` stays fast; the release conformance job runs all
/// four machine sizes.
fn grid() -> &'static [(usize, usize)] {
    if cfg!(debug_assertions) {
        &[(64, 1 << 14)]
    } else {
        &[(64, 1 << 14), (256, 1 << 15), (1024, 1 << 16), (4096, 1 << 16)]
    }
}

#[test]
fn det_planner_choice_measures_within_ten_percent_of_minimum() {
    for &(p, n) in grid() {
        let params: BspParams = cray_t3d(p);
        let omega = det::omega_det(&case_cfg(), n);
        let chosen = plan::plan_det(n, &params, omega);
        let predicted = |t: &Topology| {
            theory::predict_det_topology(n, &params, omega, &t.dims())
                .prediction
                .total_secs(&params)
        };
        let cands = candidates(p, chosen.topology, chosen.predicted_secs, predicted);
        assert_within_ten_percent(AlgoVariant::DetK, n, p, chosen.topology, &cands);
    }
}

#[test]
fn ran_planner_choice_measures_within_ten_percent_of_minimum() {
    // One exhaustive grid point for the randomized twin: the det test
    // already sweeps the machine sizes; this pins the ran closed forms
    // to measured ground truth too.
    let (p, n) = (64usize, 1usize << 14);
    let params = cray_t3d(p);
    let omega = iran::omega_ran(&case_cfg(), n);
    let chosen = plan::plan_ran(n, &params, omega);
    let cands = plan::enumerate_topologies(p);
    assert_within_ten_percent(AlgoVariant::RanK, n, p, chosen.topology, &cands);
}
