//! Balance-envelope audit: every algorithm variant × the full benchmark
//! set (the paper's §6.3 seven plus the skew families
//! `[Z]`/`[X]`/`[AS]`/`[R]`/`[8D]`) × `p ∈ {4, 64, 256, 1024}` on the
//! deterministic simulator, measuring the balance ratio
//! `max_received / (n/p)` for every cell.
//!
//! Envelopes are asserted exactly where the paper guarantees them:
//!
//! * **\[DET\]** (Lemma 5.1, `(1 + 1/⌈ω⌉)·n/p + ⌈ω⌉·p`) and **\[BSI\]**
//!   (exact `n/p`) are *any-input* deterministic bounds — asserted on
//!   every benchmark, skew families included.
//! * The randomized (\[IRAN\]/\[RAN\]) and multi-level
//!   (det-2/ran-2/det-k/ran-k) variants carry high-probability or
//!   composed envelopes (slackened as in the conformance suite so fixed
//!   seeds stay robust): asserted on the seven §6.3 benchmarks,
//!   *recorded but not asserted* on the skew families, where zipf /
//!   eight-dup duplication can degrade random sampling.
//! * The [39]/[40]/[44] baselines have no balance guarantee: their
//!   ratios are recorded only, and a cell that fails outright (e.g.
//!   [44]/PSRS under massive duplication at tiny `n/p`) becomes a note
//!   rather than a test failure.
//!
//! `BALANCE_AUDIT_WRITE=<path> cargo test --release --test
//! balance_audit` — wired as `./ci.sh --balance-audit` — regenerates
//! the committed `docs/BALANCE.md` ratio tables from the same sweep;
//! with the variable unset the writer test is a no-op and only the
//! envelope assertions run.

use std::panic::{catch_unwind, AssertUnwindSafe};

use bsp_sort::bsp::{Backend, Topology};
use bsp_sort::experiment::{
    execute_typed, resolved_deep_topology, AlgoVariant, RunSpec, ALL_ALGOS,
};
use bsp_sort::gen::{Benchmark, ALL_BENCHMARKS};
use bsp_sort::sort::{det, iran, SampleSortMethod, SortConfig};

/// The audited grid: `(p, n)` per sweep, chosen so `n/p` spans three
/// orders of magnitude (1024 / 256 / 256 / 16 keys per processor).
const GRID: [(usize, usize); 4] = [(4, 1 << 12), (64, 1 << 14), (256, 1 << 16), (1024, 1 << 14)];

/// The paper's §6.3 distributions are the leading seven of
/// [`ALL_BENCHMARKS`]; everything after them is a skew family.
const PAPER_BENCHES: usize = 7;

fn is_paper_bench(bench: Benchmark) -> bool {
    ALL_BENCHMARKS[..PAPER_BENCHES].contains(&bench)
}

/// One SplitMix64 step, the case-seed scrambler (same scheme as the
/// conformance suite, different tag so the inputs are distinct).
fn case_seed(p: u64, idx: u64) -> u64 {
    bsp_sort::util::rng::SplitMix64::new(0xBA1A_5EED ^ (p << 32) ^ idx).next_u64()
}

/// Large-`p` cases use sequential sample sorting and ω = 1, exactly as
/// the conformance suite does: the p²·⌈ω⌉ sample is intrinsic to the
/// algorithms, and ω = 1 keeps the suite's runtime at its minimum while
/// Lemma 5.1 still holds exactly (with ε = 1).
fn case_cfg(p: usize) -> SortConfig {
    if p >= 256 {
        SortConfig::default()
            .with_sample_sort(SampleSortMethod::Sequential)
            .with_omega(1.0)
    } else {
        SortConfig::default()
    }
}

/// The per-algorithm balance envelope on keys received by any
/// processor, or `None` for baselines without a paper guarantee.
/// Mirrors the conformance suite's bound table.
fn balance_bound(algo: AlgoVariant, n: usize, p: usize, cfg: &SortConfig) -> Option<f64> {
    let npp = n as f64 / p as f64;
    match algo {
        // Lemma 5.1, deterministic: (1 + 1/⌈ω⌉)·n/p + ⌈ω⌉·p.
        AlgoVariant::Det => Some(det::nmax_bound(n, p, det::omega_det(cfg, n))),
        // Claim 5.1 high-probability bound, slackened ×1.5 + ω·p + 64.
        AlgoVariant::Iran | AlgoVariant::Ran => {
            let w = iran::omega_ran(cfg, n);
            Some(1.5 * iran::nmax_bound(n, p, w) + w * p as f64 + 64.0)
        }
        // Bitonic merge-split preserves local sizes exactly.
        AlgoVariant::Bsi => Some(npp),
        // Two composed oversampling slacks.
        AlgoVariant::Det2 | AlgoVariant::Ran2 => {
            let r = det::omega_det(cfg, n).ceil().max(1.0);
            Some(3.0 * npp + 4.0 * r * p as f64 + 256.0)
        }
        // Depth-k: one oversampling slack per routing level.
        AlgoVariant::DetK | AlgoVariant::RanK => {
            let spec = RunSpec::new(algo, Benchmark::Uniform, p, n).with_cfg(*cfg);
            let t: Topology = resolved_deep_topology(&spec);
            let d = t.depth().max(1) as f64;
            let r = det::omega_det(cfg, n).ceil().max(1.0);
            Some(npp * 2.0f64.powf(d) + 4.0 * r * p as f64 * d + 512.0 * d)
        }
        AlgoVariant::HelmanDet | AlgoVariant::HelmanRan | AlgoVariant::Psrs => None,
    }
}

/// Whether the cell's envelope is a hard assertion (see module doc).
fn envelope_is_asserted(algo: AlgoVariant, bench: Benchmark) -> bool {
    match algo {
        AlgoVariant::Det | AlgoVariant::Bsi => true,
        AlgoVariant::Iran
        | AlgoVariant::Ran
        | AlgoVariant::Det2
        | AlgoVariant::Ran2
        | AlgoVariant::DetK
        | AlgoVariant::RanK => is_paper_bench(bench),
        AlgoVariant::HelmanDet | AlgoVariant::HelmanRan | AlgoVariant::Psrs => false,
    }
}

/// One measured cell of the audit.
struct Cell {
    algo: AlgoVariant,
    bench: Benchmark,
    /// `max_received / (n/p)`; `None` when the run itself failed (only
    /// possible for unguaranteed baseline cells).
    ratio: Option<f64>,
    /// `envelope / (n/p)` when the variant has an envelope.
    envelope: Option<f64>,
    /// Envelope enforced by assertion for this (algo, bench).
    asserted: bool,
    /// Envelope present but not asserted, and the measured ratio rose
    /// above it — the documented degradation cases.
    exceeded: bool,
    note: Option<String>,
}

fn first_line(msg: &str) -> &str {
    msg.lines().next().unwrap_or("")
}

/// Run one cell on the simulator, assert its envelope where guaranteed,
/// and record the measured ratio either way.
fn audit_cell(algo: AlgoVariant, bench: Benchmark, n: usize, p: usize, seed: u64) -> Cell {
    let cfg = case_cfg(p);
    let npp = n as f64 / p as f64;
    let mut spec = RunSpec::new(algo, bench, p, n).with_cfg(cfg).with_backend(Backend::Sim);
    spec.seed = seed;
    let label = format!(
        "balance-audit algo={} bench={} n={n} p={p} backend=sim replay-seed={seed:#x}",
        algo.tag(),
        bench.tag(),
    );
    let asserted = envelope_is_asserted(algo, bench);
    let bound = balance_bound(algo, n, p, &cfg);
    let envelope = bound.map(|b| b / npp);

    let run = match catch_unwind(AssertUnwindSafe(|| execute_typed::<i32>(&spec))) {
        Ok(run) => run,
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("non-string panic payload")
                .to_string();
            assert!(!asserted, "[{label}] guaranteed cell failed to execute: {msg}");
            return Cell {
                algo,
                bench,
                ratio: None,
                envelope,
                asserted,
                exceeded: false,
                note: Some(format!("run failed: {}", first_line(&msg))),
            };
        }
    };

    let max_received = run.outputs.iter().map(|r| r.received).max().unwrap_or(0);
    let ratio = max_received as f64 / npp;
    let mut exceeded = false;
    if let Some(b) = bound {
        if max_received as f64 > b + 1.0 {
            assert!(
                !asserted,
                "[{label}] received {max_received} keys > guaranteed balance bound {b:.1}"
            );
            exceeded = true;
        }
    }
    Cell { algo, bench, ratio: Some(ratio), envelope, asserted, exceeded, note: None }
}

/// Sweep all 11 variants × all benchmarks at one `(p, n)`; cells come
/// back algo-major (chunks of `ALL_BENCHMARKS.len()` share a variant).
fn audit_p(p: usize, n: usize) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(ALL_ALGOS.len() * ALL_BENCHMARKS.len());
    let mut idx = 0u64;
    for algo in ALL_ALGOS {
        for bench in ALL_BENCHMARKS {
            let seed = case_seed(p as u64, idx);
            idx += 1;
            cells.push(audit_cell(algo, bench, n, p, seed));
        }
    }
    cells
}

/// Print the sweep summary: counts plus every recorded degradation or
/// baseline failure (the interesting rows of the table).
fn report(p: usize, n: usize, cells: &[Cell]) {
    let asserted = cells.iter().filter(|c| c.asserted).count();
    let exceeded = cells.iter().filter(|c| c.exceeded).count();
    let failed = cells.iter().filter(|c| c.note.is_some()).count();
    println!(
        "balance-audit p={p} n={n}: {} cells ({asserted} envelope-asserted, \
         {exceeded} recorded-exceeded, {failed} baseline failures)",
        cells.len()
    );
    for c in cells {
        if c.exceeded {
            println!(
                "  recorded exceedance: algo={} bench={} ratio {:.2} > envelope {:.2}",
                c.algo.tag(),
                c.bench.tag(),
                c.ratio.unwrap_or(f64::NAN),
                c.envelope.unwrap_or(f64::NAN),
            );
        }
        if let Some(note) = &c.note {
            println!("  baseline note: algo={} bench={}: {note}", c.algo.tag(), c.bench.tag());
        }
    }
}

#[test]
fn balance_envelopes_p4() {
    let (p, n) = GRID[0];
    report(p, n, &audit_p(p, n));
}

#[test]
fn balance_envelopes_p64() {
    let (p, n) = GRID[1];
    report(p, n, &audit_p(p, n));
}

#[test]
fn balance_envelopes_p256() {
    let (p, n) = GRID[2];
    report(p, n, &audit_p(p, n));
}

#[test]
fn balance_envelopes_p1024() {
    let (p, n) = GRID[3];
    report(p, n, &audit_p(p, n));
}

// --------------------------------------------------------------------
// docs/BALANCE.md writer (env-gated; a no-op in normal test runs).
// --------------------------------------------------------------------

fn render_table(p: usize, n: usize, cells: &[Cell]) -> String {
    let mut s = format!("## p = {p} (n = {n}, n/p = {})\n\n", n / p);
    s.push_str("| variant | envelope |");
    for bench in ALL_BENCHMARKS {
        s.push_str(&format!(" {} |", bench.tag()));
    }
    s.push('\n');
    s.push_str("|---|---|");
    for _ in ALL_BENCHMARKS {
        s.push_str("---|");
    }
    s.push('\n');
    for row in cells.chunks(ALL_BENCHMARKS.len()) {
        let env = match row[0].envelope {
            Some(e) => format!("{e:.2}"),
            None => "—".to_string(),
        };
        s.push_str(&format!("| {} | {env} |", row[0].algo.tag()));
        for c in row {
            let rendered = match c.ratio {
                Some(r) => format!(
                    " {r:.2}{}{} |",
                    if c.asserted { " †" } else { "" },
                    if c.exceeded { " ⚠" } else { "" }
                ),
                None => " ✗ |".to_string(),
            };
            s.push_str(&rendered);
        }
        s.push('\n');
    }
    s.push('\n');
    s
}

fn render_doc(sweeps: &[(usize, usize, Vec<Cell>)]) -> String {
    let mut md = String::from(
        "# Balance-envelope audit\n\n\
         Generated by `rust/tests/balance_audit.rs` (regenerate with\n\
         `./ci.sh --balance-audit`, which sets `BALANCE_AUDIT_WRITE`; the\n\
         simulator is deterministic, so the numbers are reproducible\n\
         constants for the committed seeds).\n\n\
         Each cell is the measured balance ratio `max_received / (n/p)` for\n\
         one algorithm × benchmark × machine size on the simulator backend.\n\
         The *envelope* column is the variant's bound in the same units:\n\
         Lemma 5.1 `(1 + 1/⌈ω⌉)·n/p + ⌈ω⌉·p` for [DET], exact `n/p` for\n\
         [BSI], the slackened high-probability / composed envelopes of the\n\
         conformance suite for the randomized and multi-level variants, and\n\
         none for the [39]/[40]/[44] baselines.\n\n\
         Markers: `†` the envelope is asserted for this cell (any-input\n\
         guarantees everywhere; model-dependent envelopes on the seven §6.3\n\
         benchmarks); `⚠` an unasserted envelope was exceeded — the\n\
         documented skew degradations; `✗` the run itself failed (recorded\n\
         for unguaranteed baselines only).\n\n",
    );
    for (p, n, cells) in sweeps {
        md.push_str(&render_table(*p, *n, cells));
    }

    md.push_str("## Where the randomized variants degrade\n\n");
    let mut any = false;
    for (p, _, cells) in sweeps {
        for c in cells.iter().filter(|c| c.exceeded) {
            any = true;
            md.push_str(&format!(
                "- `{}` on `{}` at p = {p}: ratio {:.2} exceeds its slackened \
                 envelope {:.2} (recorded, not asserted — the envelope is \
                 derived for the paper's input model, not for this skew).\n",
                c.algo.tag(),
                c.bench.tag(),
                c.ratio.unwrap_or(f64::NAN),
                c.envelope.unwrap_or(f64::NAN),
            ));
        }
    }
    if !any {
        md.push_str(
            "No randomized or multi-level variant exceeded its slackened \
             envelope on any skew benchmark in this sweep: the duplicate \
             tagging of §5.1.1 keeps even zipf/eight-dup inputs within the \
             recorded bounds at these machine sizes.\n",
        );
    }
    let failures: Vec<String> = sweeps
        .iter()
        .flat_map(|(p, _, cells)| {
            cells.iter().filter(|c| c.note.is_some()).map(move |c| {
                format!(
                    "- `{}` on `{}` at p = {p}: {}\n",
                    c.algo.tag(),
                    c.bench.tag(),
                    c.note.as_deref().unwrap_or(""),
                )
            })
        })
        .collect();
    if !failures.is_empty() {
        md.push_str("\n## Baseline failures\n\n");
        for f in failures {
            md.push_str(&f);
        }
    }
    md
}

#[test]
fn balance_audit_writes_table_when_armed() {
    let Ok(path) = std::env::var("BALANCE_AUDIT_WRITE") else {
        println!("BALANCE_AUDIT_WRITE unset; not regenerating docs/BALANCE.md");
        return;
    };
    let sweeps: Vec<(usize, usize, Vec<Cell>)> =
        GRID.iter().map(|&(p, n)| (p, n, audit_p(p, n))).collect();
    let md = render_doc(&sweeps);
    std::fs::write(&path, md).unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
    println!("wrote {path}");
}
