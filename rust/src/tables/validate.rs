//! In-text validations of §6.4 (DESIGN.md §5 "§6 text" rows) and the
//! experiment-report schema gate:
//!
//! * `validate_g` — back out the effective `g` from the Ph5 routing cost
//!   (the paper: 0.23–0.32 µs/int across p = 32..128, consistent with
//!   the measured 0.26/0.28/0.34);
//! * `predict` — theoretical efficiency from Props 5.1/5.3 next to the
//!   harness-predicted efficiency (the paper's "at least 66 %" check);
//! * `ablate_duplicates` — the 3–6 % duplicate-handling overhead;
//! * [`validate_report`] — structural validation of a parsed
//!   `BENCH_<tag>.json` against [`crate::experiment::report::SCHEMA`]
//!   (the one source of truth for the report/table shape; the CLI
//!   re-validates every file it writes, CI asserts it on the smoke run).

use crate::bsp::engine::BspMachine;
use crate::bsp::params::cray_t3d;
use crate::experiment::report::SCHEMA;
use crate::gen::{generate_for_proc, Benchmark};
use crate::sort::common::PH5;
use crate::sort::{det, iran, DuplicatePolicy, SortConfig};
use crate::theory;
use crate::util::json::Json;

use super::{TableOpts, TableOutput, MEG};

/// Back out g from the routing superstep: g_eff = comm_us / h.
pub fn validate_g(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Validate-g: effective g from Ph5 routing vs the machine's configured g".into(),
        ..Default::default()
    };
    out.header = vec!["p".into(), "n".into(), "h(words)".into(), "g_eff(us/int)".into(), "g_machine".into()];
    for &p in &[32usize, 64, 128] {
        if p > opts.max_p {
            continue;
        }
        let n = (8 * MEG).min(opts.max_n);
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let seed = opts.seed;
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed)
        });
        let route = run
            .ledger
            .supersteps
            .iter()
            .find(|s| s.phase == PH5 && s.label == "ph5:route")
            .expect("routing superstep present");
        // Back g out of the *communication* part of the routing superstep
        // (its cost is max{L, x + g·h}; the x term is the slice copy-out).
        let comm_us = (route.predicted_us(&params) - params.comp_us(route.max_ops)).max(0.0);
        let g_eff = comm_us / route.h_words.max(1) as f64;
        out.cells.push(((format!("p={p}"), "g_eff".into()), g_eff));
        out.rows.push(vec![
            p.to_string(),
            super::fmt_size(n),
            route.h_words.to_string(),
            format!("{g_eff:.3}"),
            format!("{:.2}", params.g_us_per_word),
        ]);
    }
    out
}

/// Theoretical (Props 5.1/5.3) vs harness-predicted efficiency.
pub fn predict(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Predict: Prop 5.1/5.3 efficiency vs harness-predicted efficiency (8M, [U])".into(),
        ..Default::default()
    };
    out.header = vec![
        "Algo".into(),
        "p".into(),
        "theory eff".into(),
        "harness eff".into(),
        "theory secs".into(),
        "harness secs".into(),
    ];
    let n = (8 * MEG).min(opts.max_n);
    for &p in &[16usize, 32, 64, 128] {
        if p > opts.max_p {
            continue;
        }
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let seed = opts.seed;

        // SORT_DET_BSP / [DSQ]
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        let harness_secs = run.ledger.predicted_secs(&params);
        let harness_eff =
            params.comp_us(theory::seq_charge(n)) / (p as f64 * harness_secs * 1e6);
        let pred = theory::predict_det(n, &params, det::omega_det(&cfg, n));
        out.cells.push(((format!("DSQ p={p}"), "harness_eff".into()), harness_eff));
        out.cells.push(((format!("DSQ p={p}"), "theory_eff".into()), pred.efficiency()));
        out.rows.push(vec![
            "[DSQ]".into(),
            p.to_string(),
            format!("{:.0}%", 100.0 * pred.efficiency()),
            format!("{:.0}%", 100.0 * harness_eff),
            format!("{:.3}", pred.total_secs(&params)),
            format!("{harness_secs:.3}"),
        ]);

        // SORT_IRAN_BSP / [RSQ]
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed)
        });
        let harness_secs = run.ledger.predicted_secs(&params);
        let harness_eff =
            params.comp_us(theory::seq_charge(n)) / (p as f64 * harness_secs * 1e6);
        let pred = theory::predict_iran(n, &params, iran::omega_ran(&cfg, n));
        out.cells.push(((format!("RSQ p={p}"), "harness_eff".into()), harness_eff));
        out.cells.push(((format!("RSQ p={p}"), "theory_eff".into()), pred.efficiency()));
        out.rows.push(vec![
            "[RSQ]".into(),
            p.to_string(),
            format!("{:.0}%", 100.0 * pred.efficiency()),
            format!("{:.0}%", 100.0 * harness_eff),
            format!("{:.3}", pred.total_secs(&params)),
            format!("{harness_secs:.3}"),
        ]);
    }
    out
}

/// Duplicate-handling ablation: Tagged vs Off on \[U\] (the paper's 3–6 %)
/// — and the balance collapse Off causes on \[DD\].
pub fn ablate_duplicates(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Ablation: duplicate handling Tagged vs Off (predicted seconds; max received keys)".into(),
        ..Default::default()
    };
    out.header = vec![
        "Input".into(),
        "p".into(),
        "tagged secs".into(),
        "off secs".into(),
        "overhead".into(),
        "tagged max-recv".into(),
        "off max-recv".into(),
    ];
    let n = (8 * MEG).min(opts.max_n);
    for bench in [Benchmark::Uniform, Benchmark::DetDup] {
        for &p in &[32usize, 128] {
            if p > opts.max_p {
                continue;
            }
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let mut secs = [0.0f64; 2];
            let mut maxrecv = [0usize; 2];
            for (i, dup) in [DuplicatePolicy::Tagged, DuplicatePolicy::Off].iter().enumerate() {
                let cfg = SortConfig::default().with_dup(*dup);
                let run = machine.run(|ctx| {
                    let local = generate_for_proc(bench, ctx.pid(), p, n / p);
                    det::sort_det_bsp(ctx, &params, local, n, &cfg)
                });
                secs[i] = run.ledger.predicted_secs(&params);
                maxrecv[i] = run.outputs.iter().map(|r| r.received).max().unwrap_or(0);
            }
            let overhead = 100.0 * (secs[0] / secs[1] - 1.0);
            out.cells.push(((format!("{} p={p}", bench.tag()), "overhead_pct".into()), overhead));
            out.rows.push(vec![
                bench.tag(),
                p.to_string(),
                format!("{:.3}", secs[0]),
                format!("{:.3}", secs[1]),
                format!("{overhead:+.1}%"),
                maxrecv[0].to_string(),
                maxrecv[1].to_string(),
            ]);
        }
    }
    out
}

// ---------------------------------------------------------------------
// Experiment-report schema validation.
// ---------------------------------------------------------------------

fn field<'a>(ctx: &str, doc: &'a Json, key: &str) -> Result<&'a Json, String> {
    doc.get(key).ok_or_else(|| format!("{ctx}: missing field '{key}'"))
}

fn req_str(ctx: &str, doc: &Json, key: &str) -> Result<(), String> {
    field(ctx, doc, key)?
        .as_str()
        .map(|_| ())
        .ok_or_else(|| format!("{ctx}: '{key}' must be a string"))
}

fn req_num(ctx: &str, doc: &Json, key: &str) -> Result<f64, String> {
    field(ctx, doc, key)?
        .as_f64()
        .ok_or_else(|| format!("{ctx}: '{key}' must be a finite number"))
}

fn req_nonneg(ctx: &str, doc: &Json, key: &str) -> Result<f64, String> {
    let v = req_num(ctx, doc, key)?;
    if v >= 0.0 {
        Ok(v)
    } else {
        Err(format!("{ctx}: '{key}' must be non-negative (got {v})"))
    }
}

fn req_positive(ctx: &str, doc: &Json, key: &str) -> Result<f64, String> {
    let v = req_num(ctx, doc, key)?;
    if v > 0.0 {
        Ok(v)
    } else {
        Err(format!("{ctx}: '{key}' must be positive (got {v})"))
    }
}

fn req_arr<'a>(ctx: &str, doc: &'a Json, key: &str) -> Result<&'a [Json], String> {
    field(ctx, doc, key)?
        .as_arr()
        .ok_or_else(|| format!("{ctx}: '{key}' must be an array"))
}

/// Validate a parsed experiment report against the
/// `bsp-sort/experiment-report/v5` schema: schema tag, non-empty
/// calibrations with positive (g, L, rate) and a non-negative EM-BSP
/// `g_io_us_per_block`, non-empty runs each carrying an
/// execution-backend tag (`threaded` | `sim`), a topology label
/// (`"2x4"`, `"8x4x4"`, … for multi-level runs; `null` otherwise) and a
/// `mem_budget` (≥ 1 keys per processor for external cells, `null` for
/// in-core ones), wall-clock statistics (virtual µs for `sim` runs), a
/// positive end-to-end measured-vs-predicted ratio, per-phase rows
/// (ratio positive or `null` for unpriced phases), balance metrics and
/// a superstep trace with non-negative `io_blocks`.  Returns the first
/// violation.
pub fn validate_report(doc: &Json) -> Result<(), String> {
    let schema = field("report", doc, "schema")?
        .as_str()
        .ok_or("report: 'schema' must be a string")?;
    if schema != SCHEMA {
        return Err(format!("report: schema mismatch (got '{schema}', want '{SCHEMA}')"));
    }
    req_str("report", doc, "tag")?;
    req_nonneg("report", doc, "created_unix_secs")?;
    req_str("report", doc, "os")?;
    req_str("report", doc, "arch")?;

    let calibs = req_arr("report", doc, "calibrations")?;
    if calibs.is_empty() {
        return Err("report: 'calibrations' must be non-empty".into());
    }
    for (i, c) in calibs.iter().enumerate() {
        let ctx = format!("calibrations[{i}]");
        req_positive(&ctx, c, "p")?;
        // v3: each calibration names the backend it prices (threaded =
        // host probes, sim = synthetic model parameters); consumers
        // join runs↔calibrations by (p, backend).
        let backend = field(&ctx, c, "backend")?
            .as_str()
            .ok_or_else(|| format!("{ctx}: 'backend' must be a string"))?;
        if crate::bsp::Backend::parse(backend).is_none() {
            return Err(format!(
                "{ctx}: unknown backend '{backend}' (expected 'threaded' or 'sim')"
            ));
        }
        req_positive(&ctx, c, "l_us")?;
        req_positive(&ctx, c, "g_us_per_word")?;
        req_positive(&ctx, c, "comps_per_us")?;
        // v5: the EM third parameter; 0 when the I/O probe was skipped.
        req_nonneg(&ctx, c, "g_io_us_per_block")?;
        req_num(&ctx, c, "fit_r2")?;
        let pts = req_arr(&ctx, c, "a2a_points")?;
        if pts.is_empty() {
            return Err(format!("{ctx}: 'a2a_points' must be non-empty"));
        }
    }

    let runs = req_arr("report", doc, "runs")?;
    if runs.is_empty() {
        return Err("report: 'runs' must be non-empty".into());
    }
    for (i, r) in runs.iter().enumerate() {
        let ctx = format!("runs[{i}]");
        for key in ["algo", "algo_label", "bench", "domain"] {
            req_str(&ctx, r, key)?;
        }
        // v3: every run names its execution backend.
        let backend = field(&ctx, r, "backend")?
            .as_str()
            .ok_or_else(|| format!("{ctx}: 'backend' must be a string"))?;
        if crate::bsp::Backend::parse(backend).is_none() {
            return Err(format!(
                "{ctx}: unknown backend '{backend}' (expected 'threaded' or 'sim')"
            ));
        }
        // v4: multi-level runs carry their topology tree as a shape
        // label whose factors multiply to p; one-level runs carry null.
        let topology = field(&ctx, r, "topology")?;
        if !topology.is_null() {
            let label = topology
                .as_str()
                .ok_or_else(|| format!("{ctx}: 'topology' must be a string or null"))?;
            let p = req_positive(&ctx, r, "p")? as usize;
            crate::sort::plan::parse_topology(label, p)
                .map_err(|e| format!("{ctx}: {e}"))?;
        }
        req_positive(&ctx, r, "n")?;
        req_positive(&ctx, r, "p")?;
        // v5: external cells record their per-processor key budget;
        // in-core cells record null.
        let mem_budget = field(&ctx, r, "mem_budget")?;
        if !mem_budget.is_null() {
            let v = mem_budget
                .as_f64()
                .ok_or_else(|| format!("{ctx}: 'mem_budget' must be a number or null"))?;
            if v < 1.0 {
                return Err(format!(
                    "{ctx}: 'mem_budget' must hold at least one key (got {v})"
                ));
            }
        }
        req_nonneg(&ctx, r, "warmup")?;
        req_positive(&ctx, r, "reps")?;

        let wall = field(&ctx, r, "wall_us")?;
        let wctx = format!("{ctx}.wall_us");
        req_positive(&wctx, wall, "n")?;
        let min = req_positive(&wctx, wall, "min")?;
        let mean = req_positive(&wctx, wall, "mean")?;
        let max = req_positive(&wctx, wall, "max")?;
        req_nonneg(&wctx, wall, "stddev")?;
        if !(min <= mean && mean <= max) {
            return Err(format!("{wctx}: min ≤ mean ≤ max violated ({min}, {mean}, {max})"));
        }

        req_positive(&ctx, r, "predicted_us")?;
        req_positive(&ctx, r, "ratio")?;

        let phases = req_arr(&ctx, r, "phases")?;
        if phases.is_empty() {
            return Err(format!("{ctx}: 'phases' must be non-empty"));
        }
        for (j, ph) in phases.iter().enumerate() {
            let pctx = format!("{ctx}.phases[{j}]");
            req_str(&pctx, ph, "name")?;
            req_nonneg(&pctx, ph, "predicted_us")?;
            req_nonneg(&pctx, ph, "wall_us")?;
            let ratio = field(&pctx, ph, "ratio")?;
            if !ratio.is_null() {
                let v = ratio
                    .as_f64()
                    .ok_or_else(|| format!("{pctx}: 'ratio' must be a number or null"))?;
                if v <= 0.0 {
                    return Err(format!("{pctx}: 'ratio' must be positive (got {v})"));
                }
            }
        }

        let bal = field(&ctx, r, "balance")?;
        let bctx = format!("{ctx}.balance");
        let recv_max = req_nonneg(&bctx, bal, "recv_max")?;
        req_nonneg(&bctx, bal, "recv_min")?;
        let recv_mean = req_nonneg(&bctx, bal, "recv_mean")?;
        req_num(&bctx, bal, "expansion")?;
        req_nonneg(&bctx, bal, "routed_words_total")?;
        req_nonneg(&bctx, bal, "routed_words_max")?;
        req_nonneg(&bctx, bal, "routed_words_avg")?;
        if recv_max < recv_mean.floor() {
            return Err(format!("{bctx}: recv_max {recv_max} below recv_mean {recv_mean}"));
        }

        let steps = req_arr(&ctx, r, "supersteps")?;
        if steps.is_empty() {
            return Err(format!("{ctx}: 'supersteps' must be non-empty"));
        }
        for (j, s) in steps.iter().enumerate() {
            let sctx = format!("{ctx}.supersteps[{j}]");
            req_str(&sctx, s, "label")?;
            req_str(&sctx, s, "phase")?;
            req_nonneg(&sctx, s, "max_ops")?;
            req_nonneg(&sctx, s, "h_words")?;
            req_nonneg(&sctx, s, "total_words")?;
            req_nonneg(&sctx, s, "wall_us")?;
            req_positive(&sctx, s, "predicted_us")?;
            req_positive(&sctx, s, "procs")?;
            // Group-round index of group-scoped supersteps (multi-level
            // sorts); null for whole-machine ones.
            let round = field(&sctx, s, "round")?;
            if !round.is_null() && round.as_f64().is_none() {
                return Err(format!("{sctx}: 'round' must be a number or null"));
            }
            // v5: charged external-I/O blocks at this sync.
            req_nonneg(&sctx, s, "io_blocks")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn g_eff_close_to_machine_g() {
        let opts = TableOpts { max_n: MEG, max_p: 32, seed: 1, reps: 1 };
        let out = validate_g(&opts);
        let g_eff = out.cell("p=32", "g_eff").unwrap();
        // Within 25 % of the configured 0.26 (L floors can inflate it at
        // small n).
        assert!((0.19..0.40).contains(&g_eff), "g_eff={g_eff}");
    }

    #[test]
    fn dd_collapses_without_tags() {
        let opts = TableOpts { max_n: 256 * 1024, max_p: 32, seed: 1, reps: 1 };
        let out = ablate_duplicates(&opts);
        // [DD] row at p=32: off max-recv must exceed tagged max-recv.
        let row = out
            .rows
            .iter()
            .find(|r| r[0] == "[DD]" && r[1] == "32")
            .expect("DD row");
        let tagged: usize = row[5].parse().unwrap();
        let off: usize = row[6].parse().unwrap();
        assert!(off > 2 * tagged, "tagged={tagged} off={off}");
    }

    #[test]
    fn tiny_sweep_roundtrips_serialize_parse_validate() {
        // The regression the schema gate exists for: a real (tiny)
        // sweep at n = 4096, p = 4 must survive serialize → parse →
        // validate without the validator and the writer drifting apart.
        use crate::bsp::Backend;
        use crate::experiment::{
            self, AlgoVariant, KeyDomain, ProbePlan, RunConfig, SweepSpec, TopologyChoice,
        };
        let mut spec = SweepSpec::quick();
        // det2 exercises the group-scoped superstep fields (procs,
        // non-null round); det-k exercises the v4 topology field
        // through the serializer and the validator.
        spec.algos = vec![AlgoVariant::Det, AlgoVariant::Det2, AlgoVariant::DetK];
        spec.benches = vec![Benchmark::Uniform];
        spec.domains = vec![KeyDomain::I32, KeyDomain::U64];
        spec.ns = vec![4096];
        spec.ps = vec![4];
        // A small sim-backend extra exercises the v3 backend field (and
        // the synthetic model calibration) through the round-trip; its
        // spill-forcing mem budget exercises the v5 external-sort
        // fields (mem_budget, io_blocks) the same way.
        spec.extras = vec![RunConfig {
            algo: AlgoVariant::Det,
            bench: Benchmark::Uniform,
            domain: KeyDomain::I32,
            n: 4096,
            p: 16,
            backend: Backend::Sim,
            topology: TopologyChoice::Default,
            local_sort: crate::sort::LocalSortEngine::Quicksort,
            mem_budget: Some(128),
        }];
        spec.warmup = 0;
        spec.reps = 2;
        spec.tag = "roundtrip".into();
        spec.probes = ProbePlan {
            barrier_reps: 4,
            a2a_h_words: vec![256, 1024],
            a2a_rounds: 2,
            comp_n: 1 << 10,
            io_blocks: 2,
        };
        let report = experiment::run_study(&spec);
        let text = report.to_json().render();
        let parsed = Json::parse(&text).expect("report must parse back");
        validate_report(&parsed).expect("report must validate against the schema");
        let runs = parsed.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 7, "det+det2+det-k × i32+u64, plus the sim extra");
        assert_eq!(runs[0].get("n").unwrap().as_u64(), Some(4096));
        assert_eq!(runs[0].get("backend").unwrap().as_str(), Some("threaded"));
        // v4: one-level runs carry a null topology, multi-level runs a
        // shape label that parses against their p.
        assert!(runs[0].get("topology").unwrap().is_null());
        let detk = runs
            .iter()
            .find(|r| r.get("algo").unwrap().as_str() == Some("det-k"))
            .expect("det-k run present");
        assert_eq!(detk.get("topology").unwrap().as_str(), Some("2x2"));
        // The det2 runs carry group-scoped supersteps: procs below the
        // machine p with a non-null round.
        let det2 = runs
            .iter()
            .find(|r| r.get("algo").unwrap().as_str() == Some("det2"))
            .expect("det2 run present");
        let steps = det2.get("supersteps").unwrap().as_arr().unwrap();
        assert!(steps.iter().any(|s| {
            s.get("procs").unwrap().as_u64() == Some(2)
                && !s.get("round").unwrap().is_null()
        }));
        // The sim extra survives the round-trip with its backend tag
        // and deterministic (virtual) wall statistics.
        let sim = runs
            .iter()
            .find(|r| r.get("backend").unwrap().as_str() == Some("sim"))
            .expect("sim run present");
        assert_eq!(sim.get("p").unwrap().as_u64(), Some(16));
        assert_eq!(sim.get("algo").unwrap().as_str(), Some("det"));
        // v5: the external extra records its budget and charged block
        // I/O; the in-core runs record a null budget and zero blocks.
        assert_eq!(sim.get("mem_budget").unwrap().as_u64(), Some(128));
        assert!(sim
            .get("supersteps")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .any(|s| s.get("io_blocks").unwrap().as_u64().unwrap_or(0) > 0));
        assert!(runs[0].get("mem_budget").unwrap().is_null());
        // And its pricing parameters are present, joinable by
        // (p, backend): a synthetic model calibration at p = 16 next to
        // the host calibration at p = 4.
        let calibs = parsed.get("calibrations").unwrap().as_arr().unwrap();
        assert!(calibs.iter().any(|c| {
            c.get("p").unwrap().as_u64() == Some(16)
                && c.get("backend").unwrap().as_str() == Some("sim")
        }));
        assert!(calibs.iter().any(|c| {
            c.get("p").unwrap().as_u64() == Some(4)
                && c.get("backend").unwrap().as_str() == Some("threaded")
        }));
    }

    #[test]
    fn validate_report_rejects_unknown_backend() {
        // Take a valid single-run shell and corrupt only the backend.
        let doc = Json::parse(&format!(
            r#"{{"schema": "{SCHEMA}", "tag": "t", "created_unix_secs": 1,
                 "os": "linux", "arch": "x86_64",
                 "calibrations": [{{"p": 4, "backend": "threaded", "l_us": 1.0,
                   "g_us_per_word": 0.1, "comps_per_us": 10.0,
                   "g_io_us_per_block": 327.0,
                   "fit_intercept_us": 1.0, "fit_r2": 1.0,
                   "a2a_points": [[64, 7.4]]}}],
                 "runs": [{{"algo": "det", "algo_label": "[DSQ]", "bench": "[U]",
                   "domain": "i32", "backend": "carrier-pigeon"}}]}}"#
        ))
        .unwrap();
        let err = validate_report(&doc).unwrap_err();
        assert!(err.contains("unknown backend"), "{err}");
        assert!(err.contains("carrier-pigeon"), "{err}");
        // The same gate covers calibrations.
        let doc = Json::parse(&format!(
            r#"{{"schema": "{SCHEMA}", "tag": "t", "created_unix_secs": 1,
                 "os": "linux", "arch": "x86_64",
                 "calibrations": [{{"p": 4, "backend": "abacus", "l_us": 1.0}}],
                 "runs": []}}"#
        ))
        .unwrap();
        let err = validate_report(&doc).unwrap_err();
        assert!(err.contains("calibrations[0]") && err.contains("abacus"), "{err}");
    }

    #[test]
    fn validate_report_rejects_empty_mem_budget() {
        // A run claiming an external budget of zero keys is malformed.
        let doc = Json::parse(&format!(
            r#"{{"schema": "{SCHEMA}", "tag": "t", "created_unix_secs": 1,
                 "os": "linux", "arch": "x86_64",
                 "calibrations": [{{"p": 4, "backend": "threaded", "l_us": 1.0,
                   "g_us_per_word": 0.1, "comps_per_us": 10.0,
                   "g_io_us_per_block": 327.0,
                   "fit_intercept_us": 1.0, "fit_r2": 1.0,
                   "a2a_points": [[64, 7.4]]}}],
                 "runs": [{{"algo": "det", "algo_label": "[DSQ]+EM", "bench": "[U]",
                   "domain": "i32", "backend": "sim", "topology": null,
                   "n": 4096, "p": 4, "mem_budget": 0}}]}}"#
        ))
        .unwrap();
        let err = validate_report(&doc).unwrap_err();
        assert!(err.contains("mem_budget"), "{err}");
        // And a calibration without the v5 G_io field no longer passes.
        let doc = Json::parse(&format!(
            r#"{{"schema": "{SCHEMA}", "tag": "t", "created_unix_secs": 1,
                 "os": "linux", "arch": "x86_64",
                 "calibrations": [{{"p": 4, "backend": "threaded", "l_us": 1.0,
                   "g_us_per_word": 0.1, "comps_per_us": 10.0}}],
                 "runs": []}}"#
        ))
        .unwrap();
        let err = validate_report(&doc).unwrap_err();
        assert!(err.contains("g_io_us_per_block"), "{err}");
    }

    #[test]
    fn validate_report_rejects_drift() {
        // Wrong schema tag.
        let doc = Json::parse(r#"{"schema": "bsp-sort/experiment-report/v0"}"#).unwrap();
        let err = validate_report(&doc).unwrap_err();
        assert!(err.contains("schema mismatch"), "{err}");
        // Right tag but nothing else.
        let doc = Json::parse(&format!(r#"{{"schema": "{}"}}"#, SCHEMA)).unwrap();
        let err = validate_report(&doc).unwrap_err();
        assert!(err.contains("missing field 'tag'"), "{err}");
    }
}
