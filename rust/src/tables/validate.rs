//! In-text validations of §6.4 (DESIGN.md §5 "§6 text" rows):
//!
//! * `validate_g` — back out the effective `g` from the Ph5 routing cost
//!   (the paper: 0.23–0.32 µs/int across p = 32..128, consistent with
//!   the measured 0.26/0.28/0.34);
//! * `predict` — theoretical efficiency from Props 5.1/5.3 next to the
//!   harness-predicted efficiency (the paper's "at least 66 %" check);
//! * `ablate_duplicates` — the 3–6 % duplicate-handling overhead.

use crate::bsp::engine::BspMachine;
use crate::bsp::params::cray_t3d;
use crate::gen::{generate_for_proc, Benchmark};
use crate::sort::common::PH5;
use crate::sort::{det, iran, DuplicatePolicy, SortConfig};
use crate::theory;

use super::{TableOpts, TableOutput, MEG};

/// Back out g from the routing superstep: g_eff = comm_us / h.
pub fn validate_g(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Validate-g: effective g from Ph5 routing vs the machine's configured g".into(),
        ..Default::default()
    };
    out.header = vec!["p".into(), "n".into(), "h(words)".into(), "g_eff(us/int)".into(), "g_machine".into()];
    for &p in &[32usize, 64, 128] {
        if p > opts.max_p {
            continue;
        }
        let n = (8 * MEG).min(opts.max_n);
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let seed = opts.seed;
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed)
        });
        let route = run
            .ledger
            .supersteps
            .iter()
            .find(|s| s.phase == PH5 && s.label == "ph5:route")
            .expect("routing superstep present");
        // Back g out of the *communication* part of the routing superstep
        // (its cost is max{L, x + g·h}; the x term is the slice copy-out).
        let comm_us = (route.predicted_us(&params) - params.comp_us(route.max_ops)).max(0.0);
        let g_eff = comm_us / route.h_words.max(1) as f64;
        out.cells.push(((format!("p={p}"), "g_eff".into()), g_eff));
        out.rows.push(vec![
            p.to_string(),
            super::fmt_size(n),
            route.h_words.to_string(),
            format!("{g_eff:.3}"),
            format!("{:.2}", params.g_us_per_word),
        ]);
    }
    out
}

/// Theoretical (Props 5.1/5.3) vs harness-predicted efficiency.
pub fn predict(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Predict: Prop 5.1/5.3 efficiency vs harness-predicted efficiency (8M, [U])".into(),
        ..Default::default()
    };
    out.header = vec![
        "Algo".into(),
        "p".into(),
        "theory eff".into(),
        "harness eff".into(),
        "theory secs".into(),
        "harness secs".into(),
    ];
    let n = (8 * MEG).min(opts.max_n);
    for &p in &[16usize, 32, 64, 128] {
        if p > opts.max_p {
            continue;
        }
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let seed = opts.seed;

        // SORT_DET_BSP / [DSQ]
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            det::sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        let harness_secs = run.ledger.predicted_secs(&params);
        let harness_eff =
            params.comp_us(theory::seq_charge(n)) / (p as f64 * harness_secs * 1e6);
        let pred = theory::predict_det(n, &params, det::omega_det(&cfg, n));
        out.cells.push(((format!("DSQ p={p}"), "harness_eff".into()), harness_eff));
        out.cells.push(((format!("DSQ p={p}"), "theory_eff".into()), pred.efficiency()));
        out.rows.push(vec![
            "[DSQ]".into(),
            p.to_string(),
            format!("{:.0}%", 100.0 * pred.efficiency()),
            format!("{:.0}%", 100.0 * harness_eff),
            format!("{:.3}", pred.total_secs(&params)),
            format!("{harness_secs:.3}"),
        ]);

        // SORT_IRAN_BSP / [RSQ]
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
            iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed)
        });
        let harness_secs = run.ledger.predicted_secs(&params);
        let harness_eff =
            params.comp_us(theory::seq_charge(n)) / (p as f64 * harness_secs * 1e6);
        let pred = theory::predict_iran(n, &params, iran::omega_ran(&cfg, n));
        out.cells.push(((format!("RSQ p={p}"), "harness_eff".into()), harness_eff));
        out.cells.push(((format!("RSQ p={p}"), "theory_eff".into()), pred.efficiency()));
        out.rows.push(vec![
            "[RSQ]".into(),
            p.to_string(),
            format!("{:.0}%", 100.0 * pred.efficiency()),
            format!("{:.0}%", 100.0 * harness_eff),
            format!("{:.3}", pred.total_secs(&params)),
            format!("{harness_secs:.3}"),
        ]);
    }
    out
}

/// Duplicate-handling ablation: Tagged vs Off on [U] (the paper's 3–6 %)
/// — and the balance collapse Off causes on [DD].
pub fn ablate_duplicates(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Ablation: duplicate handling Tagged vs Off (predicted seconds; max received keys)".into(),
        ..Default::default()
    };
    out.header = vec![
        "Input".into(),
        "p".into(),
        "tagged secs".into(),
        "off secs".into(),
        "overhead".into(),
        "tagged max-recv".into(),
        "off max-recv".into(),
    ];
    let n = (8 * MEG).min(opts.max_n);
    for bench in [Benchmark::Uniform, Benchmark::DetDup] {
        for &p in &[32usize, 128] {
            if p > opts.max_p {
                continue;
            }
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let mut secs = [0.0f64; 2];
            let mut maxrecv = [0usize; 2];
            for (i, dup) in [DuplicatePolicy::Tagged, DuplicatePolicy::Off].iter().enumerate() {
                let cfg = SortConfig::default().with_dup(*dup);
                let run = machine.run(|ctx| {
                    let local = generate_for_proc(bench, ctx.pid(), p, n / p);
                    det::sort_det_bsp(ctx, &params, local, n, &cfg)
                });
                secs[i] = run.ledger.predicted_secs(&params);
                maxrecv[i] = run.outputs.iter().map(|r| r.received).max().unwrap_or(0);
            }
            let overhead = 100.0 * (secs[0] / secs[1] - 1.0);
            out.cells.push(((format!("{} p={p}", bench.tag()), "overhead_pct".into()), overhead));
            out.rows.push(vec![
                bench.tag(),
                p.to_string(),
                format!("{:.3}", secs[0]),
                format!("{:.3}", secs[1]),
                format!("{overhead:+.1}%"),
                maxrecv[0].to_string(),
                maxrecv[1].to_string(),
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn g_eff_close_to_machine_g() {
        let opts = TableOpts { max_n: MEG, max_p: 32, seed: 1, reps: 1 };
        let out = validate_g(&opts);
        let g_eff = out.cell("p=32", "g_eff").unwrap();
        // Within 25 % of the configured 0.26 (L floors can inflate it at
        // small n).
        assert!((0.19..0.40).contains(&g_eff), "g_eff={g_eff}");
    }

    #[test]
    fn dd_collapses_without_tags() {
        let opts = TableOpts { max_n: 256 * 1024, max_p: 32, seed: 1, reps: 1 };
        let out = ablate_duplicates(&opts);
        // [DD] row at p=32: off max-recv must exceed tagged max-recv.
        let row = out
            .rows
            .iter()
            .find(|r| r[0] == "[DD]" && r[1] == "32")
            .expect("DD row");
        let tagged: usize = row[5].parse().unwrap();
        let off: usize = row[6].parse().unwrap();
        assert!(off > 2 * tagged, "tagged={tagged} off={off}");
    }
}
