//! Single-run executor shared by every table — now a thin façade over
//! the experiment subsystem's generic runner.
//!
//! [`AlgoVariant`], [`RunSpec`] and the verified executor moved to
//! [`crate::experiment`] (spec/run): the tables keep their paper grids
//! and drive every cell through `experiment::run`, so there is exactly
//! one place that executes, verifies and measures a sorting run.  The
//! re-exports below keep the historical `tables::runner::*` paths
//! working for the CLI, benches and tests.

pub use crate::experiment::run::{avg_predicted_secs, execute, execute_typed, SingleRun};
pub use crate::experiment::spec::{AlgoVariant, RunSpec};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Benchmark;

    #[test]
    fn facade_paths_still_execute() {
        // The historical entry point tables/benches/CLI rely on.
        let spec = RunSpec::new(AlgoVariant::Det, Benchmark::Uniform, 4, 1 << 10);
        let report = execute(&spec);
        assert!(report.predicted_secs > 0.0);
        assert_eq!(report.p, 4);
        // And the rep-averaged reduction the tables drive through.
        let avg = avg_predicted_secs(&spec, 2, 7);
        assert!(avg > 0.0);
    }
}
