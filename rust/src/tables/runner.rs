//! Single-run executor shared by every table: build the machine,
//! generate the benchmark input, run the requested algorithm variant,
//! verify the global order (the harness never reports an unverified
//! number), and produce a [`RunReport`].

use crate::baselines;
use crate::bsp::engine::BspMachine;
use crate::bsp::params::{cray_t3d, BspParams};
use crate::gen::{generate_for_proc, Benchmark};
use crate::metrics::RunReport;
use crate::sort::{bsi, det, iran, ran, SortConfig};

/// Every runnable algorithm variant in the study.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoVariant {
    /// SORT_DET_BSP ([DSQ]/[DSR] by config backend).
    Det,
    /// SORT_IRAN_BSP ([RSQ]/[RSR]).
    Iran,
    /// SORT_RAN_BSP (classic sample sort, design baseline).
    Ran,
    /// Full bitonic [BSI].
    Bsi,
    /// Helman–JaJa–Bader deterministic [39].
    HelmanDet,
    /// Helman–JaJa–Bader randomized [40].
    HelmanRan,
    /// PSRS [61]/[44].
    Psrs,
}

impl AlgoVariant {
    pub fn label(&self, cfg: &SortConfig) -> String {
        match self {
            AlgoVariant::Det => cfg.variant_name(true),
            AlgoVariant::Iran => cfg.variant_name(false),
            AlgoVariant::Ran => format!("[RAN-S{}]", cfg.seq.suffix()),
            AlgoVariant::Bsi => "[BSI]".into(),
            AlgoVariant::HelmanDet => "[39]".into(),
            AlgoVariant::HelmanRan => "[40]".into(),
            AlgoVariant::Psrs => "[44]".into(),
        }
    }
}

/// One experiment: algorithm × benchmark × (p, n) × config.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    pub algo: AlgoVariant,
    pub bench: Benchmark,
    pub p: usize,
    pub n_total: usize,
    pub cfg: SortConfig,
    pub seed: u64,
}

impl RunSpec {
    pub fn new(algo: AlgoVariant, bench: Benchmark, p: usize, n_total: usize) -> RunSpec {
        RunSpec {
            algo,
            bench,
            p,
            n_total,
            cfg: SortConfig::default(),
            seed: 0x0BEE,
        }
    }

    pub fn with_cfg(mut self, cfg: SortConfig) -> RunSpec {
        self.cfg = cfg;
        self
    }

    pub fn params(&self) -> BspParams {
        cray_t3d(self.p)
    }
}

/// Execute a spec and return the verified report.
///
/// Panics if the output is not globally sorted or not a permutation of
/// the input sizes — a harness-integrity guard, not a user error path.
pub fn execute(spec: &RunSpec) -> RunReport {
    let params = spec.params();
    let machine = BspMachine::new(params);
    let cfg = spec.cfg;
    let (algo, bench, p, n, seed) = (spec.algo, spec.bench, spec.p, spec.n_total, spec.seed);
    assert!(n % p == 0, "n must divide evenly (paper setup): n={n} p={p}");

    let run = machine.run(|ctx| {
        let local = generate_for_proc(bench, ctx.pid(), p, n / p);
        match algo {
            AlgoVariant::Det => det::sort_det_bsp(ctx, &params, local, n, &cfg),
            AlgoVariant::Iran => iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed),
            AlgoVariant::Ran => ran::sort_ran_bsp(ctx, &params, local, n, &cfg, seed),
            AlgoVariant::Bsi => bsi::sort_bsi(ctx, local, &cfg),
            AlgoVariant::HelmanDet => baselines::sort_helman_det(ctx, &params, local, &cfg),
            AlgoVariant::HelmanRan => {
                baselines::sort_helman_ran(ctx, &params, local, n, &cfg, seed)
            }
            AlgoVariant::Psrs => baselines::sort_psrs(ctx, &params, local, &cfg),
        }
    });

    // Verification: globally sorted, total size preserved.
    let mut total = 0usize;
    let mut last = i32::MIN;
    for r in &run.outputs {
        for &k in &r.keys {
            assert!(k >= last, "harness: output not globally sorted");
            last = k;
        }
        total += r.keys.len();
    }
    assert_eq!(total, n, "harness: output size mismatch");

    RunReport::new(
        spec.algo.label(&cfg),
        spec.bench.tag(),
        n,
        &params,
        &run.ledger,
        &run.outputs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_all_variants_small() {
        for algo in [
            AlgoVariant::Det,
            AlgoVariant::Iran,
            AlgoVariant::Ran,
            AlgoVariant::Bsi,
            AlgoVariant::HelmanDet,
            AlgoVariant::HelmanRan,
            AlgoVariant::Psrs,
        ] {
            let spec = RunSpec::new(algo, Benchmark::Uniform, 4, 1 << 10);
            let report = execute(&spec);
            assert!(report.predicted_secs > 0.0, "{algo:?}");
            assert!(report.wall_secs > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "n must divide evenly")]
    fn uneven_n_rejected() {
        execute(&RunSpec::new(AlgoVariant::Det, Benchmark::Uniform, 3, 100));
    }
}
