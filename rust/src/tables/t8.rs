//! Table 8: per-phase scalability comparison of \[DSR\] (on \[U\]) vs the
//! two-round deterministic algorithm of [39] (on \[WR\]): SeqSort, the
//! extra routing round "PhR", Routing, Merging.

use crate::bsp::params::cray_t3d;
use crate::gen::Benchmark;
use crate::seq::SeqSortKind;
use crate::sort::common::{PH2, PH5, PH6};
use crate::sort::SortConfig;

use super::runner::{self, AlgoVariant, RunSpec};
use super::{TableOpts, TableOutput, MEG};

const PROCS: [usize; 3] = [32, 64, 128];
const PHASE_ROWS: [(&str, &str); 4] = [
    ("Ph 2", PH2),
    ("Ph R", "PhR:Transpose"),
    ("Ph 5", PH5),
    ("Ph 6", PH6),
];

/// One verified run through the experiment runner, reduced to its
/// per-phase predicted seconds.
fn breakdown(
    algo: AlgoVariant,
    bench: Benchmark,
    n: usize,
    p: usize,
    opts: &TableOpts,
) -> std::collections::BTreeMap<String, f64> {
    let params = cray_t3d(p);
    let cfg = SortConfig::default().with_seq(SeqSortKind::Radix);
    let mut spec = RunSpec::new(algo, bench, p, n).with_cfg(cfg);
    spec.seed = opts.seed;
    let single = runner::execute_typed::<i32>(&spec);
    single.ledger.phase_predicted_secs(&params)
}

fn breakdown_dsr(n: usize, p: usize, opts: &TableOpts) -> std::collections::BTreeMap<String, f64> {
    breakdown(AlgoVariant::Det, Benchmark::Uniform, n, p, opts)
}

fn breakdown_helman(n: usize, p: usize, opts: &TableOpts) -> std::collections::BTreeMap<String, f64> {
    breakdown(AlgoVariant::HelmanDet, Benchmark::WorstRegular, n, p, opts)
}

pub fn table8(opts: &TableOpts) -> TableOutput {
    let n = super::t3_t9_t10_t11::effective_n(8 * MEG, opts);
    let mut out = TableOutput {
        title: "Table 8: phase comparison [DSR] on [U] vs [39] on [WR], 8M keys (predicted T3D seconds)".into(),
        ..Default::default()
    };
    out.header = std::iter::once("Phase".to_string())
        .chain(PROCS.iter().map(|p| format!("[DSR] p={p}")))
        .chain(PROCS.iter().map(|p| format!("[39] p={p}")))
        .collect();

    let dsr: Vec<Option<std::collections::BTreeMap<String, f64>>> = PROCS
        .iter()
        .map(|&p| {
            (n <= opts.max_n && p <= opts.max_p).then(|| breakdown_dsr(n, p, opts))
        })
        .collect();
    let helman: Vec<Option<std::collections::BTreeMap<String, f64>>> = PROCS
        .iter()
        .map(|&p| {
            (n <= opts.max_n && p <= opts.max_p).then(|| breakdown_helman(n, p, opts))
        })
        .collect();

    for (row_name, phase_key) in PHASE_ROWS {
        let mut row = vec![row_name.to_string()];
        for (cols, tag) in [(&dsr, "[DSR]"), (&helman, "[39]")] {
            for (i, col) in cols.iter().enumerate() {
                match col {
                    Some(map) => {
                        let v = map.get(phase_key).copied().unwrap_or(0.0);
                        if v > 0.0 || phase_key != "PhR:Transpose" {
                            row.push(format!("{v:.3}"));
                        } else {
                            row.push("-".into());
                        }
                        out.cells.push(((row_name.to_string(), format!("{tag} p={}", PROCS[i])), v));
                    }
                    None => row.push("-".into()),
                }
            }
        }
        out.rows.push(row);
    }

    // Totals.
    let mut row = vec!["Total".to_string()];
    for (cols, tag) in [(&dsr, "[DSR]"), (&helman, "[39]")] {
        for (i, col) in cols.iter().enumerate() {
            match col {
                Some(map) => {
                    let v: f64 = map.values().sum();
                    row.push(format!("{v:.3}"));
                    out.cells.push((("Total".to_string(), format!("{tag} p={}", PROCS[i])), v));
                }
                None => row.push("-".into()),
            }
        }
    }
    out.rows.push(row);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn helman_has_extra_round_dsr_does_not() {
        // Scaled: n = 512K, p = 8 exercises the structure.
        let opts = TableOpts { max_n: MEG, max_p: 8, seed: 7, reps: 1 };
        let d = breakdown_dsr(512 * 1024, 8, &opts);
        let h = breakdown_helman(512 * 1024, 8, &opts);
        assert!(!d.contains_key("PhR:Transpose"));
        assert!(h.get("PhR:Transpose").copied().unwrap_or(0.0) > 0.0);
        // And [39]'s total exceeds [DSR]'s (two tagged rounds).
        let dt: f64 = d.values().sum();
        let ht: f64 = h.values().sum();
        assert!(ht > dt, "helman={ht} dsr={dt}");
    }
}
