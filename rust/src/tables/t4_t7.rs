//! Tables 4–7: per-phase scalability of \[RSR\]/\[RSQ\]/\[DSR\]/\[DSQ\] on input
//! \[U\], sizes 8M and 32M, p ∈ {32, 64, 128}: absolute seconds per phase
//! and percentage of total, phases Ph1–Ph7.

use crate::bsp::params::cray_t3d;
use crate::gen::Benchmark;
use crate::seq::SeqSortKind;
use crate::sort::common::{PH1, PH2, PH3, PH4, PH5, PH6, PH7};
use crate::sort::SortConfig;

use super::runner::{self, AlgoVariant, RunSpec};
use super::{fmt_size, TableOpts, TableOutput, MEG};

/// Which of the four phase tables to produce.
#[derive(Clone, Copy, Debug)]
pub enum PhaseTable {
    Rsr,
    Rsq,
    Dsr,
    Dsq,
}

impl PhaseTable {
    fn is_det(&self) -> bool {
        matches!(self, PhaseTable::Dsr | PhaseTable::Dsq)
    }
    fn seq(&self) -> SeqSortKind {
        match self {
            PhaseTable::Rsr | PhaseTable::Dsr => SeqSortKind::Radix,
            PhaseTable::Rsq | PhaseTable::Dsq => SeqSortKind::Quick,
        }
    }
    fn name(&self) -> &'static str {
        match self {
            PhaseTable::Rsr => "[RSR]",
            PhaseTable::Rsq => "[RSQ]",
            PhaseTable::Dsr => "[DSR]",
            PhaseTable::Dsq => "[DSQ]",
        }
    }
    fn number(&self) -> usize {
        match self {
            PhaseTable::Rsr => 4,
            PhaseTable::Rsq => 5,
            PhaseTable::Dsr => 6,
            PhaseTable::Dsq => 7,
        }
    }
}

pub const PHASES: [&str; 7] = [PH1, PH2, PH3, PH4, PH5, PH6, PH7];

/// Per-phase predicted seconds for one (variant, n, p) cell — a single
/// verified run through the experiment runner, its ledger reduced by
/// phase.
pub fn phase_breakdown(which: PhaseTable, n: usize, p: usize, opts: &TableOpts) -> Vec<f64> {
    let params = cray_t3d(p);
    let cfg = SortConfig::default().with_seq(which.seq());
    let algo = if which.is_det() { AlgoVariant::Det } else { AlgoVariant::Iran };
    let mut spec = RunSpec::new(algo, Benchmark::Uniform, p, n).with_cfg(cfg);
    spec.seed = opts.seed;
    let single = runner::execute_typed::<i32>(&spec);
    let by_phase = single.ledger.phase_predicted_secs(&params);
    PHASES
        .iter()
        .map(|ph| by_phase.get(*ph).copied().unwrap_or(0.0))
        .collect()
}

pub fn table(opts: &TableOpts, which: PhaseTable) -> TableOutput {
    // Paper sizes 8M and 32M, clamped to the host budget (distinct where
    // possible: the smaller size halves when both clamp to the cap).
    let big = super::t3_t9_t10_t11::effective_n(32 * MEG, opts);
    let small = super::t3_t9_t10_t11::effective_n(8 * MEG, opts);
    let sizes = if small == big { [big / 4, big] } else { [small, big] };
    let procs = [32usize, 64, 128];
    let mut out = TableOutput {
        title: format!(
            "Table {}: scalability of phases of {} on [U] (predicted T3D seconds; % of total)",
            which.number(),
            which.name()
        ),
        ..Default::default()
    };
    out.header = std::iter::once("Phase".to_string())
        .chain(sizes.iter().flat_map(|&n| {
            procs.iter().map(move |&p| format!("{} p={p}", fmt_size(n)))
        }))
        .collect();

    // Gather per-column breakdowns (or None when over budget).
    let mut cols: Vec<Option<Vec<f64>>> = Vec::new();
    for &n in &sizes {
        for &p in &procs {
            if n > opts.max_n || p > opts.max_p || n % p != 0 {
                cols.push(None);
            } else {
                cols.push(Some(phase_breakdown(which, n, p, opts)));
            }
        }
    }

    let totals: Vec<Option<f64>> = cols
        .iter()
        .map(|c| c.as_ref().map(|v| v.iter().sum::<f64>()))
        .collect();

    for (pi, ph) in PHASES.iter().enumerate() {
        let mut row = vec![ph.to_string()];
        for (c, col) in cols.iter().enumerate() {
            match col {
                Some(v) => {
                    let pct = 100.0 * v[pi] / totals[c].unwrap().max(1e-12);
                    row.push(format!("{:.3} ({:4.1}%)", v[pi], pct));
                    out.cells.push(((ph.to_string(), out.header[c + 1].clone()), v[pi]));
                }
                None => row.push("-".into()),
            }
        }
        out.rows.push(row);
    }
    // Total row.
    let mut row = vec!["Total".to_string()];
    for (c, t) in totals.iter().enumerate() {
        match t {
            Some(v) => {
                row.push(format!("{v:.3}"));
                out.cells.push((("Total".to_string(), out.header[c + 1].clone()), *v));
            }
            None => row.push("-".into()),
        }
    }
    out.rows.push(row);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_breakdown_shape_matches_paper() {
        // Scaled-down: n = 256K, p = 8.  The paper's shape at 8M/32:
        // Ph2 (SeqSort) dominates (≈55-65 %), Ph6 (Merging) second
        // (≈30-35 %), Ph5 (Routing) ≈5-8 %.
        let opts = TableOpts { max_n: MEG, max_p: 8, seed: 3, reps: 1 };
        let v = phase_breakdown(PhaseTable::Rsr, MEG, 8, &opts);
        let total: f64 = v.iter().sum();
        let pct: Vec<f64> = v.iter().map(|x| 100.0 * x / total).collect();
        // Ph2 dominates:
        assert!(pct[1] > 35.0, "Ph2={:.1}% of {pct:?}", pct[1]);
        // Merging is the second-largest sequential phase:
        assert!(pct[5] > 15.0, "Ph6={:.1}% of {pct:?}", pct[5]);
        // Sequential work dominates overall (paper: 85-93 % at 8M/32p;
        // at this scaled size the L floors and sampling take more):
        assert!(pct[1] + pct[5] > 60.0, "seq={:.1}%", pct[1] + pct[5]);
    }

    #[test]
    fn table_renders_with_skips() {
        let opts = TableOpts { max_n: MEG, max_p: 8, seed: 3, reps: 1 };
        let out = table(&opts, PhaseTable::Dsq);
        assert_eq!(out.rows.len(), PHASES.len() + 1);
        // All paper columns exceed the tiny budget -> skipped.
        assert!(out.rows[0][1..].iter().all(|c| c == "-"));
    }
}
