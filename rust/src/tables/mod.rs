//! Regeneration harness for the paper's Tables 1–11 (DESIGN.md §5).
//!
//! Every table has a `table_N(&TableOpts) -> TableOutput` that runs the
//! exact algorithm × benchmark × (p, n) grid of the paper, prints rows in
//! the paper's layout, and reports the *predicted T3D seconds* (the BSP
//! cost ledger priced with the paper's `(p, L, g)`) as the primary
//! number — measured host wall-clock is shown alongside as a sanity
//! column where the layout permits.
//!
//! Paper sizes go up to 64M keys on 128 processors; on a small host the
//! default grid caps n at [`TableOpts::default`]'s `max_n` (override with
//! `--full` / `--max-n`).  Skipped rows are *printed as skipped*, never
//! silently dropped.

pub mod runner;
pub mod t1_t2;
pub mod t3_t9_t10_t11;
pub mod t4_t7;
pub mod t8;
pub mod validate;

use crate::util::fmt_secs;

pub const MEG: usize = 1024 * 1024; // the paper's 1M = 1024×1024

/// Options shared by all tables.
#[derive(Clone, Debug)]
pub struct TableOpts {
    /// Largest total input size to actually run (larger rows -> skipped).
    pub max_n: usize,
    /// Largest processor count to actually run.
    pub max_p: usize,
    /// Seed for randomized variants.
    pub seed: u64,
    /// Repetitions averaged per cell (paper: ≥ 4).
    pub reps: usize,
}

impl Default for TableOpts {
    fn default() -> Self {
        TableOpts {
            max_n: 8 * MEG,
            max_p: 128,
            seed: 0x0BEE,
            reps: 1,
        }
    }
}

impl TableOpts {
    pub fn full() -> Self {
        TableOpts {
            max_n: 64 * MEG,
            max_p: 128,
            seed: 0x0BEE,
            reps: 1,
        }
    }
}

/// A rendered table: a title, column headers and string rows, plus the
/// raw cell data for tests and EXPERIMENTS.md extraction.
#[derive(Clone, Debug, Default)]
pub struct TableOutput {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// (row-key, col-key) -> predicted seconds, for programmatic checks.
    pub cells: Vec<((String, String), f64)>,
}

impl TableOutput {
    pub fn cell(&self, row: &str, col: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|((r, c), _)| r == row && c == col)
            .map(|(_, v)| *v)
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(cell.len());
                } else {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a size as the paper does: "1M", "4M", ... (M = 1024²).
pub fn fmt_size(n: usize) -> String {
    if n % MEG == 0 {
        format!("{}M", n / MEG)
    } else if n >= 1024 && n % 1024 == 0 {
        format!("{}K", n / 1024)
    } else {
        format!("{n}")
    }
}

/// Seconds cell or "-" for skipped rows.
pub fn cell_secs(v: Option<f64>) -> String {
    v.map(fmt_secs).unwrap_or_else(|| "-".into())
}

/// Dispatch by table number (CLI entry).
pub fn run_table(num: usize, opts: &TableOpts) -> Option<TableOutput> {
    match num {
        1 => Some(t1_t2::table1(opts)),
        2 => Some(t1_t2::table2(opts)),
        3 => Some(t3_t9_t10_t11::table3(opts)),
        4 => Some(t4_t7::table(opts, t4_t7::PhaseTable::Rsr)),
        5 => Some(t4_t7::table(opts, t4_t7::PhaseTable::Rsq)),
        6 => Some(t4_t7::table(opts, t4_t7::PhaseTable::Dsr)),
        7 => Some(t4_t7::table(opts, t4_t7::PhaseTable::Dsq)),
        8 => Some(t8::table8(opts)),
        9 => Some(t3_t9_t10_t11::table9(opts)),
        10 => Some(t3_t9_t10_t11::table10(opts)),
        11 => Some(t3_t9_t10_t11::table11(opts)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_size_paper_style() {
        assert_eq!(fmt_size(MEG), "1M");
        assert_eq!(fmt_size(8 * MEG), "8M");
        assert_eq!(fmt_size(2048), "2K");
        assert_eq!(fmt_size(100), "100");
    }

    #[test]
    fn render_aligns_columns() {
        let t = TableOutput {
            title: "T".into(),
            header: vec!["a".into(), "bbbb".into()],
            rows: vec![vec!["xx".into(), "1".into()]],
            cells: vec![],
        };
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.lines().count() >= 4);
    }
}
