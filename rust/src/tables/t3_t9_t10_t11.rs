//! Tables 3, 9, 10, 11: scalability and cross-implementation comparisons.
//!
//! * Table 3 — \[RSR\]/\[RSQ\]/\[DSR\]/\[DSQ\] on \[U\] and \[WR\], 8M keys,
//!   p = 8..128, with parallel efficiency at p = 128.
//! * Table 9 — our four variants vs [39], [40], [41] at 8M.
//! * Table 10 — scalability of all four variants on \[U\] for 1M/4M/8M.
//! * Table 11 — \[DSQ\] vs the PSRS implementation of [44] at 1M \[U\].

use crate::gen::Benchmark;
use crate::seq::SeqSortKind;
use crate::sort::SortConfig;
use crate::theory;

use super::runner::{AlgoVariant, RunSpec};
use super::t1_t2::avg_predicted;
use super::{cell_secs, fmt_size, TableOpts, TableOutput, MEG};

const PROCS: [usize; 5] = [8, 16, 32, 64, 128];

fn variant_spec(v: &str, bench: Benchmark, p: usize, n: usize) -> RunSpec {
    let (algo, seq) = match v {
        "[RSR]" => (AlgoVariant::Iran, SeqSortKind::Radix),
        "[RSQ]" => (AlgoVariant::Iran, SeqSortKind::Quick),
        "[DSR]" => (AlgoVariant::Det, SeqSortKind::Radix),
        "[DSQ]" => (AlgoVariant::Det, SeqSortKind::Quick),
        "[39]" => (AlgoVariant::HelmanDet, SeqSortKind::Radix),
        "[40]" => (AlgoVariant::HelmanRan, SeqSortKind::Radix),
        "[41]" => (AlgoVariant::Psrs, SeqSortKind::Radix),
        "[44]" => (AlgoVariant::Psrs, SeqSortKind::Quick),
        other => panic!("unknown variant {other}"),
    };
    RunSpec::new(algo, bench, p, n).with_cfg(SortConfig::default().with_seq(seq))
}

fn run_cell(v: &str, bench: Benchmark, p: usize, n: usize, opts: &TableOpts) -> Option<f64> {
    if n > opts.max_n || p > opts.max_p || n % p != 0 {
        return None;
    }
    Some(avg_predicted(&variant_spec(v, bench, p, n), opts))
}

/// Clamp a paper size to the options budget (power-of-two): scaled runs
/// preserve every comparison on small hosts; titles carry the actual n
/// via `fmt_size` in the row keys.
pub fn effective_n(paper_n: usize, opts: &TableOpts) -> usize {
    let cap = if opts.max_n.is_power_of_two() {
        opts.max_n
    } else {
        opts.max_n.next_power_of_two() / 2
    };
    paper_n.min(cap.max(1024))
}

/// Efficiency of a run: `T_seq / (p · T_par)` with `T_seq = n lg n` at
/// the machine's comparison rate (§1.1's parallel efficiency).
fn efficiency(n: usize, p: usize, secs: f64) -> f64 {
    let params = crate::bsp::params::cray_t3d(p);
    params.comp_us(theory::seq_charge(n)) / (p as f64 * secs * 1e6)
}

pub fn table3(opts: &TableOpts) -> TableOutput {
    let n = effective_n(8 * MEG, opts);
    let mut out = TableOutput {
        title: "Table 3: scalability on ~8M keys (or --max-n) (predicted T3D seconds; p=128 parallel efficiency)".into(),
        ..Default::default()
    };
    out.header = std::iter::once("Variant/Input".to_string())
        .chain(PROCS.iter().map(|p| format!("p={p}")))
        .collect();
    for v in ["[RSR]", "[RSQ]", "[DSR]", "[DSQ]"] {
        for bench in [Benchmark::Uniform, Benchmark::WorstRegular] {
            let row_key = format!("{v} {}", bench.tag());
            let mut row = vec![row_key.clone()];
            for &p in &PROCS {
                let secs = run_cell(v, bench, p, n, opts);
                match secs {
                    Some(s) => {
                        out.cells.push(((row_key.clone(), format!("p={p}")), s));
                        if p == 128 {
                            row.push(format!("{} ({:.0}%)", cell_secs(Some(s)), 100.0 * efficiency(n, p, s)));
                        } else {
                            row.push(cell_secs(Some(s)));
                        }
                    }
                    None => row.push("-".into()),
                }
            }
            out.rows.push(row);
        }
    }
    out
}

pub fn table9(opts: &TableOpts) -> TableOutput {
    let n = effective_n(8 * MEG, opts);
    let mut out = TableOutput {
        title: "Table 9: comparison with other implementations, 8M keys (predicted T3D seconds)".into(),
        ..Default::default()
    };
    out.header = std::iter::once("Algorithm/Input".to_string())
        .chain(PROCS.iter().map(|p| format!("p={p}")))
        .collect();
    let rows: [(&str, Benchmark); 12] = [
        ("[RSR]", Benchmark::Uniform),
        ("[40]", Benchmark::Uniform),
        ("[RSR]", Benchmark::WorstRegular),
        ("[41]", Benchmark::WorstRegular),
        ("[DSR]", Benchmark::WorstRegular),
        ("[39]", Benchmark::WorstRegular),
        ("[DSQ]", Benchmark::WorstRegular),
        ("[RSQ]", Benchmark::WorstRegular),
        ("[DSQ]", Benchmark::Uniform),
        ("[RSQ]", Benchmark::Uniform),
        ("[DSR]", Benchmark::Uniform),
        ("[44]", Benchmark::Uniform),
    ];
    for (v, bench) in rows {
        let row_key = format!("{v} {}", bench.tag());
        let mut row = vec![row_key.clone()];
        for &p in &PROCS {
            let secs = run_cell(v, bench, p, n, opts);
            if let Some(s) = secs {
                out.cells.push(((row_key.clone(), format!("p={p}")), s));
            }
            row.push(cell_secs(secs));
        }
        out.rows.push(row);
    }
    out
}

pub fn table10(opts: &TableOpts) -> TableOutput {
    let mut out = TableOutput {
        title: "Table 10: scalability of [DSR]/[DSQ]/[RSR]/[RSQ] on [U] (predicted T3D seconds)".into(),
        ..Default::default()
    };
    out.header = std::iter::once("Variant Size".to_string())
        .chain(PROCS.iter().map(|p| format!("p={p}")))
        .collect();
    for v in ["[DSR]", "[DSQ]", "[RSR]", "[RSQ]"] {
        for n in [MEG, 4 * MEG, 8 * MEG].map(|n| effective_n(n, opts)) {
            let row_key = format!("{v} {}", fmt_size(n));
            let mut row = vec![row_key.clone()];
            for &p in &PROCS {
                let secs = run_cell(v, Benchmark::Uniform, p, n, opts);
                if let Some(s) = secs {
                    out.cells.push(((row_key.clone(), format!("p={p}")), s));
                }
                row.push(cell_secs(secs));
            }
            out.rows.push(row);
        }
    }
    out
}

pub fn table11(opts: &TableOpts) -> TableOutput {
    let n = effective_n(MEG, opts);
    let mut out = TableOutput {
        title: "Table 11: [DSQ] vs direct regular sampling [44], 1M [U] (predicted T3D seconds)".into(),
        ..Default::default()
    };
    out.header = std::iter::once("Algorithm".to_string())
        .chain(PROCS.iter().map(|p| format!("p={p}")))
        .collect();
    for v in ["[DSQ]", "[44]"] {
        let mut row = vec![v.to_string()];
        for &p in &PROCS {
            let secs = run_cell(v, Benchmark::Uniform, p, n, opts);
            if let Some(s) = secs {
                out.cells.push(((v.to_string(), format!("p={p}")), s));
            }
            row.push(cell_secs(secs));
        }
        out.rows.push(row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> TableOpts {
        TableOpts { max_n: MEG, max_p: 16, seed: 5, reps: 1 }
    }

    #[test]
    fn table10_time_decreases_with_p() {
        let out = table10(&small_opts());
        let t8 = out.cell("[DSQ] 1M", "p=8").unwrap();
        let t16 = out.cell("[DSQ] 1M", "p=16").unwrap();
        assert!(t16 < t8, "t8={t8} t16={t16}");
    }

    #[test]
    fn table9_det_beats_two_round_helman() {
        // The paper's headline: [DSR]'s single communication round beats
        // [39]'s two rounds at scale.
        let opts = small_opts();
        let out = table9(&opts);
        let dsr = out.cell("[DSR] [WR]", "p=16").unwrap();
        let helman = out.cell("[39] [WR]", "p=16").unwrap();
        assert!(dsr < helman, "dsr={dsr} helman={helman}");
    }

    #[test]
    fn table11_dsq_beats_psrs() {
        let out = table11(&small_opts());
        let dsq = out.cell("[DSQ]", "p=16").unwrap();
        let psrs = out.cell("[44]", "p=16").unwrap();
        // [44] lacks oversampling; on [U] both are close, DSQ no worse
        // than ~10 % slower and typically faster.
        assert!(dsq <= psrs * 1.1, "dsq={dsq} psrs={psrs}");
    }
}
