//! Tables 1 and 2: execution time of SORT_IRAN_BSP / SORT_DET_BSP on 64
//! processors, both sequential-sort variants, all seven benchmark inputs,
//! sizes 1M–64M.

use crate::gen::Benchmark;
use crate::seq::SeqSortKind;
use crate::sort::SortConfig;

use super::runner::{self, AlgoVariant, RunSpec};
use super::{cell_secs, fmt_size, TableOpts, TableOutput, MEG};

/// Paper column order for these tables.
const BENCH_COLS: [Benchmark; 7] = [
    Benchmark::Uniform,
    Benchmark::Gaussian,
    Benchmark::GGroup(2),
    Benchmark::Bucket,
    Benchmark::Staggered,
    Benchmark::DetDup,
    Benchmark::WorstRegular,
];

const SIZES: [usize; 6] = [MEG, 4 * MEG, 8 * MEG, 16 * MEG, 32 * MEG, 64 * MEG];

pub fn table1(opts: &TableOpts) -> TableOutput {
    variant_table(opts, AlgoVariant::Iran, "Table 1: SORT_IRAN_BSP on 64 procs (predicted T3D seconds)")
}

pub fn table2(opts: &TableOpts) -> TableOutput {
    variant_table(opts, AlgoVariant::Det, "Table 2: SORT_DET_BSP on 64 procs (predicted T3D seconds)")
}

fn variant_table(opts: &TableOpts, algo: AlgoVariant, title: &str) -> TableOutput {
    let p = 64.min(opts.max_p);
    let mut out = TableOutput {
        title: format!("{title} [p={p}]"),
        ..Default::default()
    };
    // Header: Size, then the [.SR] block over all benchmarks, then [.SQ].
    let v = variant_letter(algo);
    out.header = std::iter::once("Size".to_string())
        .chain(BENCH_COLS.iter().map(|b| format!("{v}SR {}", b.tag())))
        .chain(BENCH_COLS.iter().map(|b| format!("{v}SQ {}", b.tag())))
        .collect();

    for &n in &SIZES {
        let mut row = vec![fmt_size(n)];
        for seq in [SeqSortKind::Radix, SeqSortKind::Quick] {
            for &bench in &BENCH_COLS {
                let label = format!("{}S{}", variant_letter(algo), seq.suffix());
                if n > opts.max_n {
                    row.push("-".into());
                    continue;
                }
                let cfg = SortConfig::default().with_seq(seq);
                let spec = RunSpec::new(algo, bench, p, n).with_cfg(cfg);
                let secs = avg_predicted(&spec, opts);
                out.cells.push(((format!("{} {}", fmt_size(n), label), bench.tag()), secs));
                row.push(cell_secs(Some(secs)));
            }
        }
        out.rows.push(row);
    }
    out
}

fn variant_letter(algo: AlgoVariant) -> char {
    match algo {
        AlgoVariant::Det => 'D',
        AlgoVariant::Iran => 'R',
        _ => '?',
    }
}

/// Average predicted seconds over `opts.reps` runs (distinct seeds) —
/// one call into the experiment runner's rep-averaged reduction, the
/// same code path `bsp-sort experiment` measures through.
pub fn avg_predicted(spec: &RunSpec, opts: &TableOpts) -> f64 {
    runner::avg_predicted_secs(spec, opts.reps, opts.seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> TableOpts {
        TableOpts { max_n: MEG, max_p: 8, seed: 1, reps: 1 }
    }

    #[test]
    fn table1_runs_scaled_down() {
        let out = table1(&tiny_opts());
        assert_eq!(out.rows.len(), SIZES.len());
        // 1M row has values, larger rows are skipped.
        assert!(out.rows[0][1] != "-");
        assert!(out.rows[5][1] == "-");
    }

    #[test]
    fn table2_det_slower_or_close_to_iran_on_dd() {
        // Structural shape: [DD] (all-duplicate-ish) is the *fastest*
        // column for both algorithms (fewer distinct keys => cheaper
        // radix passes is not modeled; the speedup comes from smaller
        // routed volume imbalance... in the predicted model the DD rows
        // show <= [U] rows).
        let opts = tiny_opts();
        let t = table2(&opts);
        let u = t.cell("1M DSR", "[U]").unwrap();
        let dd = t.cell("1M DSR", "[DD]").unwrap();
        assert!(dd <= u * 1.2, "dd={dd} u={u}");
    }
}
