//! Run-level metrics: imbalance statistics, phase breakdowns, and the
//! efficiency computations the §6.4 tables report.

use crate::bsp::ledger::Ledger;
use crate::bsp::params::BspParams;
use crate::sort::common::ProcResult;
use crate::theory;

/// Key-imbalance statistics over the routing phase (Lemma 5.1 subject).
#[derive(Clone, Copy, Debug)]
pub struct Imbalance {
    pub max_received: usize,
    pub min_received: usize,
    pub mean_received: f64,
    /// max/mean − 1 — the paper's "maximum set imbalance" (kept < 15 %
    /// in all their runs).
    pub expansion: f64,
}

impl Imbalance {
    pub fn from_results(results: &[ProcResult]) -> Imbalance {
        let counts: Vec<usize> = results.iter().map(|r| r.received).collect();
        let max = counts.iter().copied().max().unwrap_or(0);
        let min = counts.iter().copied().min().unwrap_or(0);
        let mean = counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64;
        Imbalance {
            max_received: max,
            min_received: min,
            mean_received: mean,
            expansion: if mean > 0.0 { max as f64 / mean - 1.0 } else { 0.0 },
        }
    }
}

/// A complete measured+predicted account of one sorting run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algorithm: String,
    pub benchmark: String,
    pub n_total: usize,
    pub p: usize,
    /// Wall-clock seconds on the host (genuine execution).
    pub wall_secs: f64,
    /// Predicted Cray T3D seconds from the BSP cost ledger.
    pub predicted_secs: f64,
    /// Predicted seconds split by phase name.
    pub phase_predicted: Vec<(String, f64)>,
    /// Measured wall seconds split by phase name.
    pub phase_wall: Vec<(String, f64)>,
    pub imbalance: Imbalance,
}

impl RunReport {
    pub fn new(
        algorithm: impl Into<String>,
        benchmark: impl Into<String>,
        n_total: usize,
        params: &BspParams,
        ledger: &Ledger,
        results: &[ProcResult],
    ) -> RunReport {
        RunReport {
            algorithm: algorithm.into(),
            benchmark: benchmark.into(),
            n_total,
            p: params.p,
            wall_secs: ledger.wall_us / 1e6,
            predicted_secs: ledger.predicted_secs(params),
            phase_predicted: ledger.phase_predicted_secs(params).into_iter().collect(),
            phase_wall: ledger.phase_wall_secs().into_iter().collect(),
            imbalance: Imbalance::from_results(results),
        }
    }

    /// Parallel efficiency vs the `n lg n` sequential baseline at the
    /// machine's comparison rate: `T_seq / (p · T_par)` (§1.1).
    pub fn efficiency(&self, params: &BspParams) -> f64 {
        let t_seq_us = params.comp_us(theory::seq_charge(self.n_total));
        t_seq_us / (self.p as f64 * self.predicted_secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(received: usize) -> ProcResult {
        ProcResult { keys: Vec::new(), received, runs: 1 }
    }

    #[test]
    fn imbalance_expansion() {
        let imb = Imbalance::from_results(&[result(100), result(100), result(120), result(80)]);
        assert_eq!(imb.max_received, 120);
        assert_eq!(imb.min_received, 80);
        assert!((imb.expansion - 0.2).abs() < 1e-9);
    }

    #[test]
    fn imbalance_empty_is_zero() {
        let imb = Imbalance::from_results(&[]);
        assert_eq!(imb.max_received, 0);
        assert_eq!(imb.expansion, 0.0);
    }
}
