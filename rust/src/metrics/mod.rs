//! Run-level metrics: imbalance statistics, phase breakdowns, and the
//! efficiency computations the §6.4 tables report.

use crate::bsp::ledger::Ledger;
use crate::bsp::params::BspParams;
use crate::sort::common::ProcResult;
use crate::theory;

/// Key-imbalance statistics over the routing phase (Lemma 5.1 subject).
#[derive(Clone, Copy, Debug)]
pub struct Imbalance {
    pub max_received: usize,
    pub min_received: usize,
    pub mean_received: f64,
    /// max/mean − 1 — the paper's "maximum set imbalance" (kept < 15 %
    /// in all their runs).
    pub expansion: f64,
}

impl Imbalance {
    /// Reduce per-processor results (any key domain — only the received
    /// counts are read).
    pub fn from_results<K>(results: &[ProcResult<K>]) -> Imbalance {
        let counts: Vec<usize> = results.iter().map(|r| r.received).collect();
        let max = counts.iter().copied().max().unwrap_or(0);
        let min = counts.iter().copied().min().unwrap_or(0);
        let mean = counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64;
        Imbalance {
            max_received: max,
            min_received: min,
            mean_received: mean,
            expansion: if mean > 0.0 { max as f64 / mean - 1.0 } else { 0.0 },
        }
    }
}

/// Words moved in the Ph5 routing supersteps — the paper's
/// communication-regularity evidence ("routed words per processor" next
/// to the max/avg key balance of Lemma 5.1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoutedVolume {
    /// Total words sent across all processors in routing supersteps.
    pub total_words: u64,
    /// Largest per-processor h-relation of any routing superstep.
    pub max_words: u64,
    /// `total / p` — the perfectly regular per-processor share.
    pub avg_words: f64,
}

impl RoutedVolume {
    /// Scan `ledger` for supersteps whose phase is Ph5 (routing) —
    /// including the group-scoped `L2/Ph5:Routing` of the multi-level
    /// sorts — and reduce their volumes.  Algorithms that never enter
    /// Ph5 (e.g. the bitonic baseline) report zeros.
    pub fn from_ledger(ledger: &Ledger, p: usize) -> RoutedVolume {
        let mut total = 0u64;
        let mut max_words = 0u64;
        for s in &ledger.supersteps {
            if s.phase.ends_with(crate::sort::common::PH5) {
                total += s.total_words;
                max_words = max_words.max(s.h_words);
            }
        }
        RoutedVolume {
            total_words: total,
            max_words,
            avg_words: total as f64 / p.max(1) as f64,
        }
    }
}

/// A complete measured+predicted account of one sorting run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algorithm: String,
    pub benchmark: String,
    pub n_total: usize,
    pub p: usize,
    /// Wall-clock seconds on the host (genuine execution).
    pub wall_secs: f64,
    /// Predicted Cray T3D seconds from the BSP cost ledger.
    pub predicted_secs: f64,
    /// Predicted seconds split by phase name.
    pub phase_predicted: Vec<(String, f64)>,
    /// Measured wall seconds split by phase name.
    pub phase_wall: Vec<(String, f64)>,
    pub imbalance: Imbalance,
}

impl RunReport {
    pub fn new(
        algorithm: impl Into<String>,
        benchmark: impl Into<String>,
        n_total: usize,
        params: &BspParams,
        ledger: &Ledger,
        results: &[ProcResult],
    ) -> RunReport {
        RunReport {
            algorithm: algorithm.into(),
            benchmark: benchmark.into(),
            n_total,
            p: params.p,
            wall_secs: ledger.wall_us / 1e6,
            predicted_secs: ledger.predicted_secs(params),
            phase_predicted: ledger.phase_predicted_secs(params).into_iter().collect(),
            phase_wall: ledger.phase_wall_secs().into_iter().collect(),
            imbalance: Imbalance::from_results(results),
        }
    }

    /// Parallel efficiency vs the `n lg n` sequential baseline at the
    /// machine's comparison rate: `T_seq / (p · T_par)` (§1.1).
    pub fn efficiency(&self, params: &BspParams) -> f64 {
        let t_seq_us = params.comp_us(theory::seq_charge(self.n_total));
        t_seq_us / (self.p as f64 * self.predicted_secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(received: usize) -> ProcResult {
        ProcResult { keys: Vec::new(), received, runs: 1 }
    }

    #[test]
    fn imbalance_expansion() {
        let imb = Imbalance::from_results(&[result(100), result(100), result(120), result(80)]);
        assert_eq!(imb.max_received, 120);
        assert_eq!(imb.min_received, 80);
        assert!((imb.expansion - 0.2).abs() < 1e-9);
    }

    #[test]
    fn imbalance_empty_is_zero() {
        let imb = Imbalance::from_results::<i32>(&[]);
        assert_eq!(imb.max_received, 0);
        assert_eq!(imb.expansion, 0.0);
    }

    #[test]
    fn routed_volume_reduces_ph5_supersteps() {
        use crate::bsp::ledger::SuperstepRecord;
        use crate::sort::common::{PH2, PH5};
        let mut ledger = Ledger::default();
        let step = |phase: &str, h: u64, total: u64| SuperstepRecord {
            label: "s".into(),
            phase: phase.into(),
            max_ops: 0.0,
            h_words: h,
            total_words: total,
            wall_us: 1.0,
            reporters: 4,
            procs: 4,
            round: None,
            io_blocks: 0,
        };
        ledger.supersteps.push(step(PH2, 9, 9)); // not routing: ignored
        ledger.supersteps.push(step(PH5, 300, 1000));
        ledger.supersteps.push(step(PH5, 200, 600));
        let vol = RoutedVolume::from_ledger(&ledger, 4);
        assert_eq!(vol.total_words, 1600);
        assert_eq!(vol.max_words, 300);
        assert!((vol.avg_words - 400.0).abs() < 1e-12);
        assert_eq!(RoutedVolume::from_ledger(&Ledger::default(), 4), RoutedVolume::default());
    }
}
