//! Std-only error type for the runtime layer and the CLI surface.
//!
//! The workspace ships **zero third-party crates** (see `util/mod.rs`);
//! this layer previously pulled in `anyhow`, which broke offline builds.
//! A small enum covers the failure surfaces the runtime has — artifact
//! discovery, the XLA/PJRT backend, the offload service, and the sort
//! engine pool (`bsp::service`: admission control, shutdown, job
//! panics, job validation) — plus the compiled-out marker used when the
//! `xla` feature is off and the CLI's unknown-benchmark-tag error
//! (`gen::Benchmark::parse_strict`).  Every variant is structured (no
//! pre-rendered strings where the caller may need the pieces), and the
//! CLI prints all of them through this one `Display` path.

use std::fmt;

/// Errors from the PJRT runtime layer.
#[derive(Clone, Debug)]
pub enum RuntimeError {
    /// Artifact registry problems (missing directory, no artifacts, no
    /// artifact large enough for the request).
    Artifacts(String),
    /// XLA/PJRT backend failure (client startup, parse, compile,
    /// execute, transfer).
    Backend(String),
    /// Offload service lifecycle failure (spawn, startup, channel).
    Service(String),
    /// The crate was built without the `xla` feature: the PJRT path is
    /// compiled out and only the artifact registry is available.
    Disabled(&'static str),
    /// An unknown benchmark tag reached a user-facing entry point; the
    /// message names the offending tag and the accepted set.
    UnknownBenchmark {
        given: String,
        valid: &'static [&'static str],
    },
    /// Admission control of the sort engine pool rejected a submission:
    /// the bounded job queue was already at its configured depth.
    QueueFull { depth: usize },
    /// A job was submitted to — or still queued on — an engine that has
    /// been shut down.
    EngineShutdown,
    /// An SPMD processor of the job panicked; the message is the panic
    /// payload of the first processor that died.
    JobPanicked(String),
    /// A [`SortJob`](crate::sorter::SortJob) failed validation before it
    /// was queued (e.g. `n` not divisible by `p`).
    InvalidJob(String),
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Artifacts(msg) => write!(f, "artifacts: {msg}"),
            RuntimeError::Backend(msg) => write!(f, "xla backend: {msg}"),
            RuntimeError::Service(msg) => write!(f, "xla service: {msg}"),
            RuntimeError::Disabled(msg) => write!(f, "xla disabled: {msg}"),
            RuntimeError::UnknownBenchmark { given, valid } => {
                write!(f, "unknown benchmark tag {given:?}; valid tags: {}", valid.join(", "))
            }
            RuntimeError::QueueFull { depth } => {
                write!(
                    f,
                    "engine queue full: admission control rejected the job \
                     (queue depth {depth} reached)"
                )
            }
            RuntimeError::EngineShutdown => write!(f, "engine is shut down"),
            RuntimeError::JobPanicked(msg) => write!(f, "sort job panicked: {msg}"),
            RuntimeError::InvalidJob(msg) => write!(f, "invalid sort job: {msg}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_the_message() {
        assert!(RuntimeError::Artifacts("missing dir".into())
            .to_string()
            .contains("missing dir"));
        assert!(RuntimeError::Backend("compile".into()).to_string().contains("compile"));
        assert!(RuntimeError::Service("stopped".into()).to_string().contains("stopped"));
    }

    #[test]
    fn unknown_benchmark_lists_valid_tags() {
        let e = RuntimeError::UnknownBenchmark {
            given: "zzz".into(),
            valid: &["U", "DD"],
        };
        let msg = e.to_string();
        assert!(msg.contains("\"zzz\"") && msg.contains("U, DD"), "{msg}");
    }

    #[test]
    fn boxes_as_std_error() {
        let e: Box<dyn std::error::Error> = Box::new(RuntimeError::Disabled("feature off"));
        assert!(e.to_string().contains("feature off"));
    }

    #[test]
    fn queue_full_surfaces_the_depth() {
        // Regression: the admission-control error must tell the caller
        // *which* depth bound rejected them, not just "full".
        let msg = RuntimeError::QueueFull { depth: 17 }.to_string();
        assert!(msg.contains("17"), "{msg}");
        assert!(msg.contains("queue"), "{msg}");
    }

    #[test]
    fn engine_errors_are_structured_not_stringly() {
        // The service layer matches on variants; keep them patterns, not
        // pre-rendered strings.
        match (RuntimeError::QueueFull { depth: 4 }) {
            RuntimeError::QueueFull { depth } => assert_eq!(depth, 4),
            _ => unreachable!(),
        }
        assert!(RuntimeError::EngineShutdown.to_string().contains("shut down"));
        assert!(RuntimeError::JobPanicked("boom".into()).to_string().contains("boom"));
        assert!(RuntimeError::InvalidJob("n % p != 0".into())
            .to_string()
            .contains("n % p != 0"));
    }
}
