//! Std-only error type for the runtime layer and the CLI surface.
//!
//! The workspace ships **zero third-party crates** (see `util/mod.rs`);
//! this layer previously pulled in `anyhow`, which broke offline builds.
//! A small enum covers the three failure surfaces the runtime has —
//! artifact discovery, the XLA/PJRT backend, and the offload service —
//! plus the compiled-out marker used when the `xla` feature is off and
//! the CLI's unknown-benchmark-tag error (`gen::Benchmark::parse_strict`).

use std::fmt;

/// Errors from the PJRT runtime layer.
#[derive(Clone, Debug)]
pub enum RuntimeError {
    /// Artifact registry problems (missing directory, no artifacts, no
    /// artifact large enough for the request).
    Artifacts(String),
    /// XLA/PJRT backend failure (client startup, parse, compile,
    /// execute, transfer).
    Backend(String),
    /// Offload service lifecycle failure (spawn, startup, channel).
    Service(String),
    /// The crate was built without the `xla` feature: the PJRT path is
    /// compiled out and only the artifact registry is available.
    Disabled(&'static str),
    /// An unknown benchmark tag reached a user-facing entry point; the
    /// message names the offending tag and the accepted set.
    UnknownBenchmark {
        given: String,
        valid: &'static [&'static str],
    },
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Artifacts(msg) => write!(f, "artifacts: {msg}"),
            RuntimeError::Backend(msg) => write!(f, "xla backend: {msg}"),
            RuntimeError::Service(msg) => write!(f, "xla service: {msg}"),
            RuntimeError::Disabled(msg) => write!(f, "xla disabled: {msg}"),
            RuntimeError::UnknownBenchmark { given, valid } => {
                write!(f, "unknown benchmark tag {given:?}; valid tags: {}", valid.join(", "))
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_the_message() {
        assert!(RuntimeError::Artifacts("missing dir".into())
            .to_string()
            .contains("missing dir"));
        assert!(RuntimeError::Backend("compile".into()).to_string().contains("compile"));
        assert!(RuntimeError::Service("stopped".into()).to_string().contains("stopped"));
    }

    #[test]
    fn unknown_benchmark_lists_valid_tags() {
        let e = RuntimeError::UnknownBenchmark {
            given: "zzz".into(),
            valid: &["U", "DD"],
        };
        let msg = e.to_string();
        assert!(msg.contains("\"zzz\"") && msg.contains("U, DD"), "{msg}");
    }

    #[test]
    fn boxes_as_std_error() {
        let e: Box<dyn std::error::Error> = Box::new(RuntimeError::Disabled("feature off"));
        assert!(e.to_string().contains("feature off"));
    }
}
