//! PJRT runtime (DESIGN.md §4.8): load the AOT-compiled Layer-2 graphs
//! from `artifacts/*.hlo.txt` and execute them on the CPU PJRT client.
//!
//! Python never runs here — `make artifacts` lowered the JAX/Pallas
//! local-sort to HLO *text* at build time (see python/compile/aot.py for
//! why text, not serialized protos), and this module compiles + caches
//! one executable per input size.
//!
//! The PJRT path needs the `xla` crate and is compiled only with
//! `--features xla`; the default, dependency-free build keeps the same
//! API but reports [`error::RuntimeError::Disabled`], which every caller
//! treats like missing artifacts (skip + message).

pub mod client;
pub mod error;
pub mod service;
pub mod xla_sort;

pub use client::{ArtifactRegistry, Runtime};
pub use error::RuntimeError;
pub use service::XlaService;
pub use xla_sort::XlaSorter;
