//! PJRT CPU client wrapper and the artifact registry.
//!
//! Loads HLO text (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtLoadedExecutable`), caching compiled executables by artifact
//! size.  The Layer-2 graphs are lowered with `return_tuple=True`, so
//! results unwrap with `to_tuple1` (see /opt/xla-example/README.md).
//!
//! The PJRT path is feature-gated: without `--features xla` (which also
//! requires a vendored `xla` crate) the [`ArtifactRegistry`] still works
//! but [`Runtime`] construction reports [`RuntimeError::Disabled`], so
//! every caller falls back the same way it does when artifacts are
//! missing.  This keeps the default workspace build free of third-party
//! crates (util/mod.rs).

use std::path::{Path, PathBuf};

use super::error::{Result, RuntimeError};

/// Locates `local_sort_<n>.hlo.txt` artifacts on disk.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    /// Available power-of-two sizes, ascending.
    sizes: Vec<usize>,
}

impl ArtifactRegistry {
    /// Scan `dir` for `local_sort_*.hlo.txt`.
    pub fn scan(dir: impl AsRef<Path>) -> Result<ArtifactRegistry> {
        let dir = dir.as_ref().to_path_buf();
        let mut sizes = Vec::new();
        let entries = std::fs::read_dir(&dir).map_err(|e| {
            RuntimeError::Artifacts(format!(
                "artifact dir {} (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        for entry in entries {
            let name = entry
                .map_err(|e| {
                    RuntimeError::Artifacts(format!("reading {}: {e}", dir.display()))
                })?
                .file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name.strip_prefix("local_sort_") {
                if let Some(num) = rest.strip_suffix(".hlo.txt") {
                    if let Ok(n) = num.parse::<usize>() {
                        sizes.push(n);
                    }
                }
            }
        }
        sizes.sort_unstable();
        if sizes.is_empty() {
            return Err(RuntimeError::Artifacts(format!(
                "no local_sort_*.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            )));
        }
        Ok(ArtifactRegistry { dir, sizes })
    }

    /// Default location: `$BSP_SORT_ARTIFACTS` or `./artifacts`.
    pub fn default_location() -> Result<ArtifactRegistry> {
        let dir = std::env::var("BSP_SORT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::scan(dir)
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// The smallest artifact size >= `n`, if any.
    pub fn size_for(&self, n: usize) -> Option<usize> {
        self.sizes.iter().copied().find(|&s| s >= n)
    }

    /// Largest available size (chunking unit for oversize inputs).
    pub fn max_size(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    pub fn path_for(&self, size: usize) -> PathBuf {
        self.dir.join(format!("local_sort_{size}.hlo.txt"))
    }
}

/// A PJRT CPU client with a compile cache keyed by artifact size.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: std::sync::Mutex<std::collections::HashMap<usize, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "xla")]
impl Runtime {
    pub fn new(registry: ArtifactRegistry) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError::Backend(format!("PJRT cpu client: {e:?}")))?;
        Ok(Runtime {
            client,
            registry,
            cache: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    pub fn from_default_artifacts() -> Result<Runtime> {
        Runtime::new(ArtifactRegistry::default_location()?)
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Sort up to `max_size` i32 keys ascending via the AOT executable:
    /// pads to the smallest available artifact size with `i32::MAX`
    /// sentinels, executes, strips the padding.
    pub fn sort_block(&self, keys: &[i32]) -> Result<Vec<i32>> {
        let n = keys.len();
        let size = self.registry.size_for(n).ok_or_else(|| {
            RuntimeError::Artifacts(format!(
                "no artifact fits {n} keys (max {})",
                self.registry.max_size()
            ))
        })?;
        let mut padded = Vec::with_capacity(size);
        padded.extend_from_slice(keys);
        padded.resize(size, i32::MAX);

        // Compile (or fetch) the executable for this size.
        {
            let mut cache = self.cache.lock().unwrap();
            if !cache.contains_key(&size) {
                let path = self.registry.path_for(size);
                let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                    RuntimeError::Backend(format!("parse {}: {e:?}", path.display()))
                })?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp).map_err(|e| {
                    RuntimeError::Backend(format!("compile local_sort_{size}: {e:?}"))
                })?;
                cache.insert(size, exe);
            }
        }
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(&size).unwrap();

        let lit = xla::Literal::vec1(&padded);
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| RuntimeError::Backend(format!("execute local_sort_{size}: {e:?}")))?
            [0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::Backend(format!("fetch result: {e:?}")))?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| RuntimeError::Backend(format!("untuple: {e:?}")))?
            .to_vec::<i32>()
            .map_err(|e| RuntimeError::Backend(format!("to_vec: {e:?}")))?;
        debug_assert_eq!(out.len(), size);
        let mut out = out;
        out.truncate(n);
        Ok(out)
    }

    /// Sort arbitrarily many keys: chunk at the largest artifact size,
    /// sort each block on the PJRT executable, then multiway-merge.
    pub fn sort(&self, keys: &[i32]) -> Result<Vec<i32>> {
        let max = self.registry.max_size();
        if keys.len() <= max {
            return self.sort_block(keys);
        }
        let runs: Vec<Vec<i32>> = keys
            .chunks(max)
            .map(|c| self.sort_block(c))
            .collect::<Result<_>>()?;
        Ok(crate::seq::multiway_merge(&runs))
    }
}

/// Compiled-out stand-in: construction always reports
/// [`RuntimeError::Disabled`], so callers take the same skip path they
/// take when artifacts are missing.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    registry: ArtifactRegistry,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    const DISABLED: RuntimeError = RuntimeError::Disabled(
        "built without the `xla` feature; rebuild with `--features xla` and a vendored xla crate",
    );

    pub fn new(_registry: ArtifactRegistry) -> Result<Runtime> {
        Err(Self::DISABLED)
    }

    pub fn from_default_artifacts() -> Result<Runtime> {
        Runtime::new(ArtifactRegistry::default_location()?)
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn sort_block(&self, _keys: &[i32]) -> Result<Vec<i32>> {
        Err(Self::DISABLED)
    }

    pub fn sort(&self, _keys: &[i32]) -> Result<Vec<i32>> {
        Err(Self::DISABLED)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Option<ArtifactRegistry> {
        ArtifactRegistry::default_location().ok()
    }

    #[test]
    fn registry_scans_sizes() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        assert!(!reg.sizes().is_empty());
        assert!(reg.sizes().windows(2).all(|w| w[0] < w[1]));
        assert_eq!(reg.size_for(1), Some(reg.sizes()[0]));
        assert_eq!(reg.size_for(reg.max_size() + 1), None);
    }

    #[test]
    fn registry_missing_dir_errors() {
        assert!(ArtifactRegistry::scan("/nonexistent-dir-xyz").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_disabled() {
        let err = Runtime::new(ArtifactRegistry {
            dir: PathBuf::from("."),
            sizes: vec![1024],
        })
        .err()
        .expect("stub must not construct");
        assert!(matches!(err, RuntimeError::Disabled(_)));
    }
}
