//! The `SeqSorter` backend running the AOT-compiled Pallas bitonic
//! network through PJRT — the `[.SX]` variants (\[DSX\]/\[RSX\]).
//!
//! This is the three-layer composition point: the Rust BSP coordinator
//! (L3) calls into the XLA executable that the JAX graph (L2) and Pallas
//! kernel (L1) were lowered into at build time.  Because the PJRT client
//! is not `Send`, the executable lives on the [`XlaService`] thread and
//! BSP processors submit jobs over its queue.

use std::sync::Arc;

use crate::seq::{SeqSorter, SeqSortKind};

use super::error::Result;
use super::service::XlaService;

/// XLA-backed local sort (shareable across BSP processor threads).
pub struct XlaSorter {
    service: Arc<XlaService>,
}

impl XlaSorter {
    pub fn new(service: Arc<XlaService>) -> XlaSorter {
        XlaSorter { service }
    }

    pub fn from_default_artifacts() -> Result<XlaSorter> {
        Ok(XlaSorter {
            service: Arc::new(XlaService::start_default()?),
        })
    }
}

impl SeqSorter for XlaSorter {
    fn sort(&self, keys: &mut Vec<i32>) {
        match self.service.sort(keys) {
            Ok(sorted) => *keys = sorted,
            Err(e) => panic!("XlaSorter failed: {e}"),
        }
    }

    fn charge(&self, n: usize) -> f64 {
        SeqSortKind::Xla.charge(n)
    }

    fn name(&self) -> &'static str {
        "xla-bitonic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{arb_keys, check_cfg, CheckConfig};

    fn sorter() -> Option<XlaSorter> {
        match XlaSorter::from_default_artifacts() {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping XLA tests: {e}");
                None
            }
        }
    }

    #[test]
    fn xla_sort_matches_std_sort() {
        let Some(s) = sorter() else { return };
        let mut keys = vec![5, -1, 7, 7, 0, i32::MAX, i32::MIN, 3];
        let mut expect = keys.clone();
        expect.sort_unstable();
        s.sort(&mut keys);
        assert_eq!(keys, expect);
    }

    #[test]
    fn xla_sort_random_property() {
        let Some(s) = sorter() else { return };
        check_cfg(
            "xla-sort-random",
            CheckConfig { cases: 6, base_seed: 0x5A },
            |rng| {
                let mut keys = arb_keys(rng, 0, 3000, i32::MIN, i32::MAX - 1);
                let mut expect = keys.clone();
                expect.sort_unstable();
                s.sort(&mut keys);
                assert_eq!(keys, expect);
            },
        );
    }

    #[test]
    fn xla_sort_empty_input() {
        let Some(s) = sorter() else { return };
        let mut empty: Vec<i32> = vec![];
        s.sort(&mut empty);
        assert!(empty.is_empty());
    }
}
