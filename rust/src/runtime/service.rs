//! XLA offload service: a dedicated thread owning the (non-`Send`) PJRT
//! client, serving sort requests over a channel.
//!
//! The `xla` crate's client and executables hold `Rc` internals, so they
//! cannot be shared across the BSP processor threads.  Architecturally
//! this mirrors a real accelerator runtime anyway: the device has one
//! submission queue and the workers enqueue kernels.  Each BSP processor
//! sends `(keys, reply)` jobs; the service thread executes the artifact
//! and replies.

use std::sync::mpsc;
use std::sync::Mutex;
use std::thread::JoinHandle;

use super::client::{ArtifactRegistry, Runtime};
use super::error::{Result, RuntimeError};

struct Job {
    keys: Vec<i32>,
    reply: mpsc::Sender<std::result::Result<Vec<i32>, String>>,
}

/// Handle to the service; cloneable across threads via `Arc`.
pub struct XlaService {
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl XlaService {
    /// Spawn the service thread with the given artifact registry.
    pub fn start(registry: ArtifactRegistry) -> Result<XlaService> {
        let (tx, rx) = mpsc::channel::<Job>();
        // Probe the runtime on the service thread; report startup errors
        // through a handshake channel so `start` fails eagerly.
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let runtime = match Runtime::new(registry) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let result = runtime.sort(&job.keys).map_err(|e| e.to_string());
                    let _ = job.reply.send(result);
                }
            })
            .map_err(|e| RuntimeError::Service(format!("spawn xla-service: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| RuntimeError::Service("xla-service died during startup".into()))?
            .map_err(|e| RuntimeError::Service(format!("xla-service startup: {e}")))?;
        Ok(XlaService {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
        })
    }

    pub fn start_default() -> Result<XlaService> {
        XlaService::start(ArtifactRegistry::default_location()?)
    }

    /// Sort keys on the service thread (blocking).
    pub fn sort(&self, keys: &[i32]) -> Result<Vec<i32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let guard = self.tx.lock().unwrap();
            let tx = guard
                .as_ref()
                .ok_or_else(|| RuntimeError::Service("xla-service stopped".into()))?;
            tx.send(Job {
                keys: keys.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| RuntimeError::Service("xla-service channel closed".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| RuntimeError::Service("xla-service dropped the reply".into()))?
            .map_err(RuntimeError::Backend)
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        // Close the queue, then join the thread.
        self.tx.lock().unwrap().take();
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_sorts_from_multiple_threads() {
        let Ok(service) = XlaService::start_default() else {
            eprintln!("skipping: no artifacts (run `make artifacts`) or xla feature off");
            return;
        };
        let service = std::sync::Arc::new(service);
        std::thread::scope(|s| {
            for t in 0..4 {
                let service = std::sync::Arc::clone(&service);
                s.spawn(move || {
                    let keys: Vec<i32> =
                        (0..500).map(|i| ((i * 37 + t * 11) % 97) as i32).collect();
                    let mut expect = keys.clone();
                    expect.sort_unstable();
                    let got = service.sort(&keys).unwrap();
                    assert_eq!(got, expect);
                });
            }
        });
    }
}
