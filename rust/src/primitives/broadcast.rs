//! Broadcast primitives (Lemma 4.1).
//!
//! Two implementations, chosen by cost under the machine's `(p, L, g)`:
//!
//! * [`broadcast_direct`] — one superstep, the root sends `p−1` copies:
//!   cost `max{L, g·n·(p−1)}`.  Best when `n(p−1)` is small relative to L.
//! * [`broadcast_tree`] — the pipelined t-ary tree of Lemma 4.1: the
//!   message is cut into `⌈n/m⌉` segments pipelined down a tree of depth
//!   `h = ⌈log_t((t−1)p+1)⌉ − 1`; cost
//!   `(⌈n/⌈n/h⌉⌉ + h − 1) · max{L, g·t·⌈n/h⌉}`.
//!
//! [`broadcast_recs`] picks the cheaper by evaluating the Lemma 4.1
//! formula over `t ∈ {2..p}` — exactly the "architecture dependent choice
//! of primitive" the paper highlights in §5.1 (the same BSP program picks
//! different building blocks for different `(n, p, L, g)` tuples).

use crate::bsp::engine::BspScope;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::Key;

/// Cost (µs) of the one-superstep direct broadcast of `n` words.
pub fn direct_cost_us(params: &BspParams, n: u64) -> f64 {
    params.superstep_cost_us(0.0, n * (params.p as f64 - 1.0).max(0.0) as u64)
}

/// Cost (µs) of the Lemma 4.1 pipelined t-ary tree broadcast of `n` words.
pub fn tree_cost_us(params: &BspParams, n: u64, t: u64) -> f64 {
    let p = params.p as u64;
    if p <= 1 || n == 0 {
        return params.l_us;
    }
    // depth h = ceil(log_t((t-1)p + 1)) - 1
    let h = {
        let target = (t - 1) * p + 1;
        let mut depth = 0u64;
        let mut pow = 1u64;
        while pow < target {
            pow = pow.saturating_mul(t);
            depth += 1;
        }
        depth.saturating_sub(1).max(1)
    };
    let m = n.div_ceil(h); // segment size ⌈n/h⌉
    let supersteps = n.div_ceil(m) + h - 1;
    supersteps as f64 * params.superstep_cost_us(0.0, t * m)
}

/// The `t` minimizing the Lemma 4.1 cost, and that cost.
pub fn best_tree_t(params: &BspParams, n: u64) -> (u64, f64) {
    let mut best = (2u64, f64::INFINITY);
    for t in 2..=(params.p.max(2) as u64) {
        let c = tree_cost_us(params, n, t);
        if c < best.1 {
            best = (t, c);
        }
    }
    best
}

/// Choose the cheaper broadcast shape for an `n`-word message.
pub fn plan(params: &BspParams, n: u64) -> BroadcastPlan {
    let direct = direct_cost_us(params, n);
    let (t, tree) = best_tree_t(params, n);
    if tree < direct {
        BroadcastPlan::Tree { t }
    } else {
        BroadcastPlan::Direct
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BroadcastPlan {
    Direct,
    Tree { t: u64 },
}

/// Broadcast tagged records from `root` to all processors; every
/// processor returns the full message.  SPMD: all processors call this
/// with the same `expected_len` (the sorts broadcast `p−1` splitters, a
/// globally known length); only the root's `msg` is consulted.
pub fn broadcast_recs<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    root: usize,
    msg: Vec<SampleRec<K>>,
    expected_len: usize,
    label: &str,
) -> Vec<SampleRec<K>> {
    let n_words = (expected_len as u64) * SampleRec::<K>::WORDS;
    match plan(params, n_words.max(1)) {
        BroadcastPlan::Direct => broadcast_direct(ctx, root, msg, label),
        BroadcastPlan::Tree { t } => {
            broadcast_tree(ctx, root, msg, t as usize, expected_len, label)
        }
    }
}

/// One-superstep direct broadcast.
pub fn broadcast_direct<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    root: usize,
    msg: Vec<SampleRec<K>>,
    label: &str,
) -> Vec<SampleRec<K>> {
    let p = ctx.nprocs();
    if ctx.pid() == root {
        for dst in 0..p {
            if dst != root {
                ctx.send(dst, Payload::Recs(msg.clone()));
            }
        }
    }
    ctx.sync(label);
    let inbox = ctx.take_inbox();
    if ctx.pid() == root {
        msg
    } else {
        // Select by sender: a caller that staged unrelated sends before
        // the collective must not hand us the wrong payload.
        inbox
            .into_iter()
            .find(|(src, _)| *src == root)
            .map(|(_, payload)| payload.into_recs())
            .unwrap_or_default()
    }
}

/// Pipelined t-ary tree broadcast (Lemma 4.1).
///
/// Processors form an implicit t-ary tree rooted at `root` (root-relative
/// rank r's children are `t·r + 1 .. t·r + t`).  The message is cut into
/// `⌈len/m⌉` segments of `m = ⌈len/h⌉` records; in superstep `step` the
/// node at depth `d` forwards segment `step − d`, so segments pipeline
/// down in `⌈len/m⌉ + h − 1` supersteps — the Lemma 4.1 schedule.
///
/// `expected_len` must be identical on all processors (it determines the
/// superstep count); only the root's `msg` content matters.
pub fn broadcast_tree<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    root: usize,
    msg: Vec<SampleRec<K>>,
    t: usize,
    expected_len: usize,
    label: &str,
) -> Vec<SampleRec<K>> {
    let p = ctx.nprocs();
    if p == 1 || expected_len == 0 {
        return msg;
    }
    let t = t.max(2);
    let rank = |pid: usize| (pid + p - root) % p;
    let pid_of = |r: usize| (r + root) % p;
    let my_rank = rank(ctx.pid());
    let my_depth = depth_of(my_rank, t);

    // Tree depth covering p nodes.
    let mut h = 0usize;
    {
        let mut covered = 1u64;
        let mut level = 1u64;
        while covered < p as u64 {
            level = level.saturating_mul(t as u64);
            covered += level;
            h += 1;
        }
    }
    let h = h.max(1);
    let m = expected_len.div_ceil(h).max(1);
    let num_segments = expected_len.div_ceil(m);
    let total_steps = num_segments + h - 1;

    if my_rank == 0 {
        assert_eq!(msg.len(), expected_len, "root message length mismatch");
    }
    let mut received: Vec<Vec<SampleRec<K>>> = vec![Vec::new(); num_segments];
    if my_rank == 0 {
        for (seg, chunk) in msg.chunks(m).enumerate() {
            received[seg] = chunk.to_vec();
        }
    }

    for step in 0..total_steps {
        if step >= my_depth {
            let seg = step - my_depth;
            if seg < num_segments && !received[seg].is_empty() {
                for c in 1..=t {
                    let child_rank = my_rank * t + c;
                    if child_rank < p {
                        ctx.send(pid_of(child_rank), Payload::Recs(received[seg].clone()));
                    }
                }
            }
        }
        ctx.sync(label);
        for (_, payload) in ctx.take_inbox() {
            // A segment sent by the parent (depth my_depth − 1) during
            // superstep `step` arrives now; its index is step+1−my_depth.
            debug_assert!(step + 1 >= my_depth);
            let seg = step + 1 - my_depth;
            debug_assert!(seg < num_segments, "segment index out of range");
            received[seg] = payload.into_recs();
        }
    }

    if my_rank == 0 {
        msg
    } else {
        received.into_iter().flatten().collect()
    }
}

/// Depth of root-relative rank `r` in the implicit t-ary tree.
fn depth_of(r: usize, t: usize) -> usize {
    let mut depth = 0usize;
    let mut level_first = 0usize; // first rank at this depth
    let mut level_size = 1usize;
    loop {
        if r < level_first + level_size {
            return depth;
        }
        level_first += level_size;
        level_size *= t;
        depth += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;

    fn recs(range: std::ops::Range<i32>) -> Vec<SampleRec> {
        range.map(|k| SampleRec::new(k, 0, k as usize)).collect()
    }

    #[test]
    fn direct_broadcast_reaches_everyone() {
        let machine = BspMachine::new(cray_t3d(8));
        let msg = recs(0..17);
        let expect = msg.clone();
        let run = machine.run(|ctx| {
            let local = if ctx.pid() == 3 { msg.clone() } else { Vec::new() };
            broadcast_direct(ctx, 3, local, "bcast")
        });
        for out in run.outputs {
            assert_eq!(out, expect);
        }
    }

    #[test]
    fn tree_broadcast_matches_direct_for_various_t() {
        for p in [2usize, 4, 7, 8, 16] {
            for t in [2usize, 3, 4] {
                let machine = BspMachine::new(cray_t3d(p));
                let msg = recs(0..23);
                let expect = msg.clone();
                let run = machine.run(|ctx| {
                    let local = if ctx.pid() == 0 { msg.clone() } else { Vec::new() };
                    broadcast_tree(ctx, 0, local, t, 23, "tree")
                });
                for (pid, out) in run.outputs.iter().enumerate() {
                    assert_eq!(out, &expect, "p={p} t={t} pid={pid}");
                }
            }
        }
    }

    #[test]
    fn tree_broadcast_nonzero_root() {
        let machine = BspMachine::new(cray_t3d(8));
        let msg = recs(0..9);
        let expect = msg.clone();
        let run = machine.run(|ctx| {
            let local = if ctx.pid() == 5 { msg.clone() } else { Vec::new() };
            broadcast_tree(ctx, 5, local, 2, 9, "tree5")
        });
        for out in run.outputs {
            assert_eq!(out, expect);
        }
    }

    #[test]
    fn auto_plan_broadcast_works() {
        let params = cray_t3d(16);
        let machine = BspMachine::new(params);
        let msg = recs(0..15);
        let expect = msg.clone();
        let run = machine.run(|ctx| {
            let local = if ctx.pid() == 0 { msg.clone() } else { Vec::new() };
            broadcast_recs(ctx, &params, 0, local, 15, "auto")
        });
        for out in run.outputs {
            assert_eq!(out, expect);
        }
    }

    #[test]
    fn lemma41_cost_formula_sane() {
        let params = cray_t3d(64);
        // Small message: direct (one superstep at the L floor) wins.
        assert_eq!(plan(&params, 8), BroadcastPlan::Direct);
        // Huge message: the tree amortizes the per-copy g cost.
        let (t, tree) = best_tree_t(&params, 1 << 22);
        let direct = direct_cost_us(&params, 1 << 22);
        assert!(tree < direct, "tree={tree} direct={direct} t={t}");
    }

    #[test]
    fn depth_of_tary_tree() {
        // t=2: ranks 0 | 1,2 | 3..6 | ...
        assert_eq!(depth_of(0, 2), 0);
        assert_eq!(depth_of(1, 2), 1);
        assert_eq!(depth_of(2, 2), 1);
        assert_eq!(depth_of(3, 2), 2);
        assert_eq!(depth_of(6, 2), 2);
        assert_eq!(depth_of(7, 2), 3);
        // t=3: 0 | 1..3 | 4..12
        assert_eq!(depth_of(3, 3), 1);
        assert_eq!(depth_of(4, 3), 2);
    }
}
