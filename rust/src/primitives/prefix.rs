//! Parallel prefix (Lemma 4.2): `n` independent prefix-sum operations
//! across the `p` processors.
//!
//! The sorts use this in step 9/Ph4 to compute, for every destination
//! bucket, the offset at which each processor's contribution starts (the
//! paper: "p independent parallel prefix operations ... to determine how
//! to split the keys of each bucket as evenly as possible").
//!
//! Two shapes, as with broadcast:
//! * [`prefix_direct`] — two supersteps via processor 0 (gather/scatter),
//!   cost `2·max{L, g·n·p}` — best for the small vectors the sorts use;
//! * [`prefix_tree`] — the two-pass pipelined t-ary tree of Lemma 4.2
//!   (up-sweep then down-sweep), cost
//!   `2·(⌈n/⌈n/h⌉⌉ + h − 1)·max{L, g·2t·⌈n/h⌉}` with `h = ⌈log_t p⌉`.

use crate::bsp::engine::BspScope;
use crate::bsp::msg::Payload;
use crate::bsp::params::BspParams;
use crate::key::Key;

/// Cost (µs) of the Lemma 4.2 tree prefix of `n` values, parameter `t`.
pub fn tree_cost_us(params: &BspParams, n: u64, t: u64) -> f64 {
    let p = params.p as u64;
    if p <= 1 {
        return 0.0;
    }
    let h = (p as f64).log(t as f64).ceil().max(1.0) as u64;
    let m = n.div_ceil(h).max(1);
    let supersteps = 2 * (n.div_ceil(m) + h - 1);
    // Each superstep moves 2t·m words through an internal node and does
    // t·m associative operations (charged 1 each).
    supersteps as f64 * params.superstep_cost_us((t * m) as f64, 2 * t * m)
}

/// Cost (µs) of the two-superstep direct prefix.
pub fn direct_cost_us(params: &BspParams, n: u64) -> f64 {
    2.0 * params.superstep_cost_us((params.p as u64 * n) as f64, params.p as u64 * n)
}

/// Exclusive prefix sums of `n` independent values: processor `k` holds
/// `values[k][j]` for `j < n`; the result at `k` is
/// `Σ_{i<k} values[i][j]` per j, plus every processor also learns the
/// grand totals.  Returns `(prefix, totals)`.
///
/// Implementation is the direct two-superstep shape (the sorts call this
/// with `n = p` counters, where `g·p²` is far below `L` on the T3D; the
/// tree variant exists for the cost model and larger `n`).  Generic over
/// the [`BspScope`], so it runs whole-machine or group-local alike.
pub fn prefix_direct<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    values: &[u64],
    label: &str,
) -> (Vec<u64>, Vec<u64>) {
    let p = ctx.nprocs();
    let n = values.len();
    // Gather to processor 0.
    ctx.send(0, Payload::U64s(values.to_vec()));
    ctx.charge(1.0);
    ctx.sync(&format!("{label}:gather"));
    let inbox = ctx.take_inbox();

    if ctx.pid() == 0 {
        // Compute per-source exclusive prefixes.  The inbox arrives in
        // sender order (engine guarantee), one row per processor, so it
        // is consumed directly — no re-bucketing pass.
        debug_assert_eq!(inbox.len(), p, "prefix gather expects one row per processor");
        let mut running = vec![0u64; n];
        let mut prefixes: Vec<Vec<u64>> = Vec::with_capacity(p);
        for (src, payload) in inbox {
            debug_assert_eq!(src, prefixes.len(), "inbox must be sender-ordered");
            prefixes.push(running.clone());
            for (j, v) in payload.into_u64s().into_iter().enumerate() {
                running[j] += v;
            }
        }
        ctx.charge((p * n) as f64);
        for (dst, pre) in prefixes.into_iter().enumerate() {
            let mut msg = pre;
            msg.extend_from_slice(&running); // append grand totals
            ctx.send(dst, Payload::U64s(msg));
        }
    }
    ctx.sync(&format!("{label}:scatter"));
    let mut inbox = ctx.take_inbox();
    assert_eq!(inbox.len(), 1, "prefix scatter must deliver exactly one message");
    let msg = inbox.pop().unwrap().1.into_u64s();
    let (prefix, totals) = msg.split_at(n);
    (prefix.to_vec(), totals.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::util::check::check;
    use crate::util::rng::SplitMix64;

    #[test]
    fn prefix_direct_computes_exclusive_sums() {
        let machine = BspMachine::new(cray_t3d(4));
        let run = machine.run(|ctx| {
            let values = vec![ctx.pid() as u64 + 1, 10 * (ctx.pid() as u64 + 1)];
            prefix_direct(ctx, &values, "pfx")
        });
        // values per proc: [1,10], [2,20], [3,30], [4,40]
        let expect_prefix = [[0u64, 0], [1, 10], [3, 30], [6, 60]];
        let expect_total = [10u64, 100];
        for (pid, (prefix, totals)) in run.outputs.iter().enumerate() {
            assert_eq!(prefix.as_slice(), &expect_prefix[pid]);
            assert_eq!(totals.as_slice(), &expect_total);
        }
    }

    #[test]
    fn prefix_direct_random_property() {
        check("prefix-random", |rng| {
            let p = 2 + rng.below(6) as usize;
            let n = 1 + rng.below(16) as usize;
            let seed = rng.next_u64();
            let machine = BspMachine::new(cray_t3d(p));
            let run = machine.run(|ctx| {
                let mut local = SplitMix64::new(seed ^ ctx.pid() as u64);
                let values: Vec<u64> = (0..n).map(|_| local.below(1000)).collect();
                let out = prefix_direct(ctx, &values, "pfx");
                (values, out)
            });
            // Reconstruct and verify.
            let all: Vec<Vec<u64>> = run.outputs.iter().map(|(v, _)| v.clone()).collect();
            for (pid, (_, (prefix, totals))) in run.outputs.iter().enumerate() {
                for j in 0..n {
                    let expect: u64 = all[..pid].iter().map(|r| r[j]).sum();
                    assert_eq!(prefix[j], expect, "pid={pid} j={j}");
                    let total: u64 = all.iter().map(|r| r[j]).sum();
                    assert_eq!(totals[j], total);
                }
            }
        });
    }

    #[test]
    fn lemma42_cost_formula_monotone_in_n() {
        let params = cray_t3d(32);
        let c1 = tree_cost_us(&params, 32, 2);
        let c2 = tree_cost_us(&params, 1 << 20, 2);
        assert!(c2 > c1);
        assert!(direct_cost_us(&params, 32) >= 2.0 * params.l_us);
    }
}
