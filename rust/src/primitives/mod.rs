//! BSP primitive operations (paper §4): broadcast (Lemma 4.1), parallel
//! prefix (Lemma 4.2), and the distributed bitonic sort used for parallel
//! sample sorting and the \[BSI\] baseline.

pub mod bitonic;
pub mod broadcast;
pub mod prefix;

pub use bitonic::bitonic_sort;
pub use broadcast::{broadcast_direct, broadcast_recs, broadcast_tree};
pub use prefix::prefix_direct;
