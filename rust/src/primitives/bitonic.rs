//! Distributed Batcher bitonic sort (merge-split formulation).
//!
//! Used for (a) the *parallel sample sort* of step 5 in both algorithms
//! (sorting `p` sorted sample runs of length `s`, cost
//! `2s(lg²p + lg p)/2` computation and `(lg²p + lg p)(L + g·s)/2`
//! communication — §5.1 Proposition 5.1), and (b) the full \[BSI\] sort
//! baseline of §6.2.
//!
//! Each processor holds a locally *sorted ascending* run of equal length;
//! a compare-exchange of the network becomes a **merge-split**: partners
//! exchange runs, merge, and the "low" side keeps the lower half.  By the
//! 0-1 principle this block variant inherits the network's correctness.
//! Requires `p` a power of two (all the paper's configurations are).

use crate::bsp::engine::BspScope;
use crate::bsp::msg::{Payload, SampleRec};
use crate::key::{F64, Key, Record, Str};
use crate::seq::ops;

/// Items that can ride a [`Payload`] of key domain `K` through the
/// merge-split exchange: tagged sample records (any domain, via the
/// blanket impl) and the bare keys of each built-in domain.  A custom
/// [`Key`] type opts its bare keys into the \[BSI\] baseline with the same
/// three-line impl the macro below expands to.
pub trait BitonicItem<K>: Ord + Copy {
    fn pack(items: Vec<Self>) -> Payload<K>;
    fn unpack(payload: Payload<K>) -> Vec<Self>;
    /// Words per item for charge bookkeeping (diagnostics only; the
    /// engine charges from the payload itself).
    fn words() -> u64;
}

macro_rules! bitonic_bare_key {
    ($($t:ty),* $(,)?) => {$(
        impl BitonicItem<$t> for $t {
            fn pack(items: Vec<$t>) -> Payload<$t> {
                Payload::Keys(items)
            }
            fn unpack(payload: Payload<$t>) -> Vec<$t> {
                payload.into_keys()
            }
            fn words() -> u64 {
                <$t as Key>::WORDS
            }
        }
    )*};
}

bitonic_bare_key!(i32, u64, F64, Record, Str);

impl<K: Key> BitonicItem<K> for SampleRec<K> {
    fn pack(items: Vec<Self>) -> Payload<K> {
        Payload::Recs(items)
    }
    fn unpack(payload: Payload<K>) -> Vec<Self> {
        payload.into_recs()
    }
    fn words() -> u64 {
        SampleRec::<K>::WORDS
    }
}

/// Bitonic-sort equal-length sorted runs across all processors.
///
/// On return, processor `k` holds the `k`-th chunk of the global sorted
/// order (all chunks the same length as the input run).  `label` prefixes
/// the superstep labels.
pub fn bitonic_sort<K: Key, T: BitonicItem<K>, S: BspScope<K>>(
    ctx: &mut S,
    mut run: Vec<T>,
    label: &str,
) -> Vec<T> {
    let p = ctx.nprocs();
    assert!(p.is_power_of_two(), "bitonic sort requires p a power of two");
    debug_assert!(run.windows(2).all(|w| w[0] <= w[1]), "input run must be sorted");
    if p == 1 {
        return run;
    }
    let pid = ctx.pid();
    let lgp = p.trailing_zeros() as usize;
    // One scratch buffer reused by every merge-split round; the rounds
    // previously allocated a fresh output vector each.
    let mut scratch: Vec<T> = Vec::with_capacity(run.len());

    for stage in 0..lgp {
        // Direction bit: ascending iff bit (stage+1) of pid is 0; the
        // final stage's bit is >= lg p, i.e. always ascending.
        let asc = (pid >> (stage + 1)) & 1 == 0;
        for j in (0..=stage).rev() {
            let partner = pid ^ (1 << j);
            merge_split(ctx, &run, &mut scratch, partner, asc, &format!("{label}:s{stage}j{j}"));
            std::mem::swap(&mut run, &mut scratch);
        }
    }
    run
}

/// One merge-split with `partner`: exchange runs, merge `mine` with the
/// partner's run into `out` (cleared first), keeping the required half.
fn merge_split<K: Key, T: BitonicItem<K>, S: BspScope<K>>(
    ctx: &mut S,
    mine: &[T],
    out: &mut Vec<T>,
    partner: usize,
    asc: bool,
    label: &str,
) {
    let m = mine.len();
    let keep_low = (ctx.pid() < partner) == asc;
    ctx.send(partner, T::pack(mine.to_vec()));
    ctx.sync(label);
    let mut inbox = ctx.take_inbox();
    assert_eq!(inbox.len(), 1, "merge-split expects exactly the partner's run");
    let theirs = T::unpack(inbox.pop().unwrap().1);
    assert_eq!(theirs.len(), m, "merge-split requires equal-length runs");

    // Linear merge, keeping only the required half (2m comparisons max;
    // charged as a 2-way merge of 2m items).
    ctx.charge(ops::merge_charge(2 * m, 2));
    out.clear();
    out.reserve(m);
    if keep_low {
        let (mut i, mut j) = (0usize, 0usize);
        while out.len() < m {
            // Ties favour `mine` when this pid is the lower one — with the
            // tagged order of SampleRec ties cannot occur at all.
            if j >= m || (i < m && mine[i] <= theirs[j]) {
                out.push(mine[i]);
                i += 1;
            } else {
                out.push(theirs[j]);
                j += 1;
            }
        }
    } else {
        let (mut i, mut j) = (m as isize - 1, m as isize - 1);
        while out.len() < m {
            if j < 0 || (i >= 0 && mine[i as usize] > theirs[j as usize]) {
                out.push(mine[i as usize]);
                i -= 1;
            } else {
                out.push(theirs[j as usize]);
                j -= 1;
            }
        }
        out.reverse();
    }
}

/// Number of supersteps the distributed bitonic sort performs.
pub fn superstep_count(p: usize) -> usize {
    let lgp = p.trailing_zeros() as usize;
    lgp * (lgp + 1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::util::check::check;
    use crate::util::rng::SplitMix64;

    fn run_bitonic_keys(p: usize, m: usize, seed: u64) -> (Vec<Vec<i32>>, Vec<i32>) {
        let machine = BspMachine::new(cray_t3d(p));
        let run = machine.run(|ctx| {
            let mut rng = SplitMix64::new(seed ^ (ctx.pid() as u64) << 32);
            let mut local: Vec<i32> = (0..m).map(|_| rng.next_i32()).collect();
            local.sort_unstable();
            let input = local.clone();
            let out = bitonic_sort(ctx, local, "bsi");
            (input, out)
        });
        let inputs: Vec<Vec<i32>> = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let output: Vec<i32> = run.outputs.into_iter().flat_map(|(_, o)| o).collect();
        (inputs, output)
    }

    #[test]
    fn sorts_globally_across_procs() {
        for p in [2usize, 4, 8, 16] {
            let (inputs, output) = run_bitonic_keys(p, 33, 0xFEED + p as u64);
            let mut expect: Vec<i32> = inputs.into_iter().flatten().collect();
            expect.sort_unstable();
            assert_eq!(output, expect, "p={p}");
        }
    }

    #[test]
    fn sorts_random_property() {
        check("bitonic-global-sort", |rng| {
            let p = 1 << (1 + rng.below(3)); // 2,4,8
            let m = 1 + rng.below(40) as usize;
            let (inputs, output) = run_bitonic_keys(p, m, rng.next_u64());
            let mut expect: Vec<i32> = inputs.into_iter().flatten().collect();
            expect.sort_unstable();
            assert_eq!(output, expect);
        });
    }

    #[test]
    fn sorts_sample_recs_with_tag_order() {
        let machine = BspMachine::new(cray_t3d(4));
        let run = machine.run(|ctx| {
            // All-equal keys: the tagged order (key, proc, idx) must
            // produce a deterministic global order by (proc, idx).
            let local: Vec<SampleRec> =
                (0..8).map(|i| SampleRec::new(42, ctx.pid(), i)).collect();
            bitonic_sort(ctx, local, "recs")
        });
        let flat: Vec<SampleRec> = run.outputs.into_iter().flatten().collect();
        let mut expect = flat.clone();
        expect.sort();
        assert_eq!(flat, expect);
        // Proc 0's records come first.
        assert!(flat[..8].iter().all(|r| r.proc == 0));
    }

    #[test]
    #[allow(deprecated)]
    fn sorts_u64_domain() {
        // Bare keys of a non-default domain ride the generic payload.
        let machine = BspMachine::new(cray_t3d(4));
        let run = machine.run_keys::<u64, _, _>(|ctx| {
            let mut local: Vec<u64> =
                (0..8u64).map(|i| (i * 37 + ctx.pid() as u64 * 13) % 64).collect();
            local.sort_unstable();
            let inp = local.clone();
            (inp, bitonic_sort(ctx, local, "u64"))
        });
        let mut expect: Vec<u64> = run.outputs.iter().flat_map(|(i, _)| i.clone()).collect();
        expect.sort_unstable();
        let got: Vec<u64> = run.outputs.into_iter().flat_map(|(_, o)| o).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn p1_is_identity() {
        let machine = BspMachine::new(cray_t3d(1));
        let run = machine.run(|ctx| bitonic_sort(ctx, vec![3i32, 5, 9], "one"));
        assert_eq!(run.outputs[0], vec![3, 5, 9]);
    }

    #[test]
    fn superstep_count_formula() {
        assert_eq!(superstep_count(2), 1);
        assert_eq!(superstep_count(4), 3);
        assert_eq!(superstep_count(8), 6);
        assert_eq!(superstep_count(128), 28);
    }

    #[test]
    fn duplicate_heavy_keys() {
        check("bitonic-duplicates", |rng| {
            let p = 4usize;
            let m = 16usize;
            let seed = rng.next_u64();
            let machine = BspMachine::new(cray_t3d(p));
            let run = machine.run(|ctx| {
                let mut local_rng = SplitMix64::new(seed ^ ctx.pid() as u64);
                let mut local: Vec<i32> = (0..m).map(|_| local_rng.below(3) as i32).collect();
                local.sort_unstable();
                let inp = local.clone();
                (inp, bitonic_sort(ctx, local, "dup"))
            });
            let mut expect: Vec<i32> = run.outputs.iter().flat_map(|(i, _)| i.clone()).collect();
            expect.sort_unstable();
            let got: Vec<i32> = run.outputs.into_iter().flat_map(|(_, o)| o).collect();
            assert_eq!(got, expect);
        });
    }
}
