//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and a usage-error type.  Only what the
//! `bsp-sort` binary and the examples need.

use std::collections::HashMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Runtime failures (engine-pool admission control, shutdown, job
/// panics, job validation, backend errors) surface on the CLI through
/// the same `error: <Display>` path as usage errors — one rendering,
/// no stringly re-wrapping at call sites.
impl From<crate::runtime::RuntimeError> for CliError {
    fn from(e: crate::runtime::RuntimeError) -> CliError {
        CliError(e.to_string())
    }
}

/// Parsed argument bag.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    ///
    /// `value_opts` lists option names that consume a following value;
    /// anything else starting with `--` is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_opts: &[&str]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&stripped) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError(format!("--{stripped} requires a value")))?;
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env(value_opts: &[&str]) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), value_opts)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("invalid value for --{name}: {v}"))),
        }
    }

    /// Parse a comma-separated list, e.g. `--procs 8,16,32`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| CliError(format!("invalid list item for --{name}: {s}")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(sv(&["table", "--n", "8388608", "--full", "--procs=8,16"]), &["n", "procs"]).unwrap();
        assert_eq!(a.positional, vec!["table"]);
        assert_eq!(a.get("n"), Some("8388608"));
        assert!(a.flag("full"));
        assert_eq!(a.get_list::<u32>("procs", &[]).unwrap(), vec![8, 16]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(sv(&["--n"]), &["n"]).is_err());
    }

    #[test]
    fn typed_default() {
        let a = Args::parse(sv(&[]), &[]).unwrap();
        assert_eq!(a.get_parsed("n", 7usize).unwrap(), 7);
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = Args::parse(sv(&["--n", "xyz"]), &["n"]).unwrap();
        assert!(a.get_parsed::<usize>("n", 0).is_err());
    }

    #[test]
    fn runtime_errors_convert_through_the_display_path() {
        // A rejected job's CLI rendering carries the queue depth (the
        // admission-control regression contract).
        let e = CliError::from(crate::runtime::RuntimeError::QueueFull { depth: 12 });
        assert!(e.to_string().contains("12"), "{e}");
        assert!(e.to_string().contains("queue"), "{e}");
    }
}
