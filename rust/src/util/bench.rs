//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warm-up + timed iterations with mean / stddev / min reporting
//! in a stable text format consumed by `cargo bench` targets (which are
//! declared with `harness = false`).  Supports per-bench configuration and
//! `BENCH_FILTER` / `BENCH_FAST` environment overrides so CI can shrink
//! runs.

use std::time::{Duration, Instant};

/// Configuration for a benchmark run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Cap on total measurement time; iterations stop early past this.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        let fast = std::env::var("BENCH_FAST").is_ok();
        BenchConfig {
            warmup_iters: if fast { 1 } else { 2 },
            measure_iters: if fast { 3 } else { 10 },
            max_total: Duration::from_secs(if fast { 10 } else { 60 }),
        }
    }
}

/// Result statistics of a benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<48} iters={:<3} mean={:>12?} min={:>12?} max={:>12?} stddev={:>10?}",
            self.name, self.iters, self.mean, self.min, self.max, self.stddev
        );
    }
}

/// True if `name` passes the `BENCH_FILTER` substring filter (if any).
pub fn enabled(name: &str) -> bool {
    match std::env::var("BENCH_FILTER") {
        Ok(f) if !f.is_empty() => name.contains(&f),
        _ => true,
    }
}

/// Run `f` under the default configuration, printing stats.
///
/// `f` receives the iteration index and must return something observable
/// (its result is black-boxed to defeat dead-code elimination).
pub fn bench<T, F: FnMut(usize) -> T>(name: &str, mut f: F) -> Option<BenchStats> {
    bench_cfg(name, &BenchConfig::default(), &mut f)
}

/// Run `f` under an explicit configuration.
pub fn bench_cfg<T, F: FnMut(usize) -> T>(
    name: &str,
    cfg: &BenchConfig,
    f: &mut F,
) -> Option<BenchStats> {
    if !enabled(name) {
        return None;
    }
    for i in 0..cfg.warmup_iters {
        black_box(f(i));
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(cfg.measure_iters);
    let start_all = Instant::now();
    for i in 0..cfg.measure_iters {
        let t0 = Instant::now();
        black_box(f(i));
        samples.push(t0.elapsed());
        if start_all.elapsed() > cfg.max_total && samples.len() >= 3 {
            break;
        }
    }
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    let mean = total / n as u32;
    let var = samples
        .iter()
        .map(|s| {
            let d = s.as_secs_f64() - mean.as_secs_f64();
            d * d
        })
        .sum::<f64>()
        / n as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: *samples.iter().min().unwrap(),
        max: *samples.iter().max().unwrap(),
    };
    stats.report();
    Some(stats)
}

/// Opaque value barrier (stable std equivalent of `test::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aggregate statistics over raw `f64` samples — the experiment runner's
/// per-configuration wall-clock summary (min/mean/stddev/max, paper
/// style: the tables report means, the text quotes the spread).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SampleStats {
    /// Number of samples aggregated.
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation (0 for a single sample).
    pub stddev: f64,
}

impl SampleStats {
    /// Reduce raw samples; an empty slice yields the zero stats.
    pub fn from_samples(samples: &[f64]) -> SampleStats {
        if samples.is_empty() {
            return SampleStats::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let max = samples.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        SampleStats { n, min, max, mean, stddev: var.sqrt() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_stats() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            measure_iters: 4,
            max_total: Duration::from_secs(5),
        };
        let mut f = |i: usize| -> u64 { (0..1000u64).map(|x| x ^ i as u64).sum() };
        let stats = bench_cfg("selftest", &cfg, &mut f).unwrap();
        assert_eq!(stats.iters, 4);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max);
    }

    #[test]
    fn sample_stats_reduce() {
        let s = SampleStats::from_samples(&[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 8.0);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.stddev - 5.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(SampleStats::from_samples(&[]), SampleStats::default());
        assert_eq!(SampleStats::from_samples(&[3.0]).stddev, 0.0);
    }

    #[test]
    fn filter_skips() {
        std::env::set_var("BENCH_FILTER", "zzz-no-match");
        let out = bench("skipped-bench", |_| 1u32);
        std::env::remove_var("BENCH_FILTER");
        assert!(out.is_none());
    }
}
