//! Minimal property-based testing runner (proptest is unavailable offline).
//!
//! A property takes a [`SplitMix64`] test-case RNG and either passes or
//! panics.  The runner executes `cases` seeds derived from a base seed; on
//! failure it re-raises with the failing seed in the panic message so a
//! case can be replayed with [`replay`].  Used throughout the crate's unit
//! and integration tests for the paper's invariants (sortedness,
//! permutation, imbalance bounds, stability).

use super::rng::SplitMix64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct CheckConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for CheckConfig {
    fn default() -> Self {
        let cases = std::env::var("CHECK_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(32);
        CheckConfig {
            cases,
            base_seed: 0xB5_B5_B5,
        }
    }
}

/// Run `prop` across `cfg.cases` derived seeds; panic with seed on failure.
pub fn check_cfg<F: Fn(&mut SplitMix64) + std::panic::RefUnwindSafe>(
    name: &str,
    cfg: CheckConfig,
    prop: F,
) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(0x9E37_79B9u64.wrapping_mul(case as u64 + 1));
        let result = std::panic::catch_unwind(|| {
            let mut rng = SplitMix64::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property `{name}` failed at case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default configuration.
pub fn check<F: Fn(&mut SplitMix64) + std::panic::RefUnwindSafe>(name: &str, prop: F) {
    check_cfg(name, CheckConfig::default(), prop)
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F: Fn(&mut SplitMix64)>(seed: u64, prop: F) {
    let mut rng = SplitMix64::new(seed);
    prop(&mut rng);
}

/// Order-independent multiset fingerprint over a key stream: element
/// hashes combined with commutative reductions (sum, xor, sum of
/// squares) plus the count — a collision needs equal counts *and* three
/// simultaneous 64-bit coincidences.  Two streams with equal signatures
/// are (for testing purposes) permutations of each other, so a sorted
/// output can be checked against its input without materialising either
/// side in one vector.
pub fn multiset_sig<K: crate::key::Key>(keys: impl Iterator<Item = K>) -> (u64, u64, u64, usize) {
    let (mut sum, mut xor, mut sq, mut count) = (0u64, 0u64, 0u64, 0usize);
    let mut words: Vec<u64> = Vec::with_capacity(2);
    for k in keys {
        words.clear();
        k.encode(&mut words);
        let mut h = 0x6B73_6F72_7462_7370u64;
        for &w in &words {
            h = SplitMix64::new(h ^ w).next_u64();
        }
        sum = sum.wrapping_add(h);
        xor ^= h;
        sq = sq.wrapping_add(h.wrapping_mul(h));
        count += 1;
    }
    (sum, xor, sq, count)
}

/// Draw a random key vector of length in `[lo_len, hi_len]`, values in
/// `[lo, hi]` — the common input shape for sort properties.
pub fn arb_keys(rng: &mut SplitMix64, lo_len: usize, hi_len: usize, lo: i32, hi: i32) -> Vec<i32> {
    let len = lo_len + rng.below((hi_len - lo_len + 1) as u64) as usize;
    (0..len)
        .map(|_| lo.wrapping_add((rng.below((hi as i64 - lo as i64 + 1) as u64)) as i32))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", |rng| {
            let x = rng.next_u64();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check_cfg(
            "always-fails",
            CheckConfig {
                cases: 2,
                base_seed: 1,
            },
            |_| panic!("boom"),
        );
    }

    #[test]
    fn multiset_sig_is_order_independent_and_count_sensitive() {
        let a = multiset_sig([3i32, 1, 4, 1, 5].into_iter());
        let b = multiset_sig([1i32, 1, 3, 4, 5].into_iter());
        assert_eq!(a, b, "permutations must fingerprint identically");
        let c = multiset_sig([1i32, 3, 4, 5].into_iter());
        assert_ne!(a, c, "dropping a duplicate must change the signature");
    }

    #[test]
    fn arb_keys_respects_bounds() {
        check("arb-keys-bounds", |rng| {
            let keys = arb_keys(rng, 1, 100, -50, 50);
            assert!(!keys.is_empty() && keys.len() <= 100);
            assert!(keys.iter().all(|&k| (-50..=50).contains(&k)));
        });
    }
}
