//! Minimal JSON value, writer and parser (serde is unavailable offline).
//!
//! The experiment reports (`BENCH_<tag>.json`) are written and re-read
//! through this module, so the serializer and parser are kept strictly
//! round-trip compatible: objects preserve insertion order (fields are a
//! `Vec`, not a map), numbers render in Rust's shortest round-trip form,
//! and non-finite floats serialize as `null` (JSON has no NaN/∞).

use std::fmt;

/// A parsed or under-construction JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; field order is preserved (insertion order on build,
    /// document order on parse).
    Obj(Vec<(String, Json)>),
}

/// Error from [`Json::parse`]: a message plus the byte offset it refers
/// to.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input where the error was detected.
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// A number, mapping non-finite values to `Null` (JSON cannot
    /// represent NaN or ±∞; the report schema treats `null` as "not
    /// defined for this row").
    pub fn num(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is a finite [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The number as a `u64`, if it is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9.0e15 => Some(*v as u64),
            _ => None,
        }
    }

    /// The string, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The fields, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }

    /// True for `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Render as pretty-printed JSON (2-space indent, stable field
    /// order).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => out.push_str(&render_num(*v)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must contain exactly one value plus
    /// whitespace).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { text, bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let value = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after the document"));
        }
        Ok(value)
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

/// Shortest round-trip rendering; integers drop the fraction so counts
/// read naturally (`4096`, not `4096.0`).
fn render_num(v: f64) -> String {
    if !v.is_finite() {
        return "null".into(); // defensive; Json::num maps these already
    }
    if v.fract() == 0.0 && v.abs() <= 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected character '{}'", b as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..=0xDBFF).contains(&hi) {
                                // Surrogate pair: expect \uDC00..\uDFFF.
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let code =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("invalid surrogate pair"))?
                            } else {
                                char::from_u32(hi)
                                    .ok_or_else(|| self.err("invalid \\u escape"))?
                            };
                            out.push(c);
                            continue; // hex4 already advanced pos
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.  `pos` only ever advances
                    // by whole-char widths, so this O(1) slice is always
                    // on a char boundary — no per-char tail re-validation.
                    let c = self.text[self.pos..].chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let v: f64 = s.parse().map_err(|_| JsonError {
            msg: format!("invalid number '{s}'"),
            at: start,
        })?;
        if !v.is_finite() {
            return Err(JsonError { msg: format!("non-finite number '{s}'"), at: start });
        }
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    #[test]
    fn render_parse_roundtrip() {
        let doc = obj(vec![
            ("schema", Json::str("test/v1")),
            ("count", Json::num(4096.0)),
            ("g", Json::num(0.21)),
            ("ok", Json::Bool(true)),
            ("missing", Json::Null),
            (
                "runs",
                Json::Arr(vec![
                    obj(vec![("name", Json::str("a \"quoted\"\nline"))]),
                    Json::Arr(vec![Json::num(1.5), Json::num(-2.0)]),
                ]),
            ),
        ]);
        let text = doc.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(1.0), Json::Num(1.0));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(render_num(4096.0), "4096");
        assert_eq!(render_num(-3.0), "-3");
        assert_eq!(render_num(0.21), "0.21");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\tbé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\tbé😀"));
    }

    #[test]
    fn parse_errors_carry_position() {
        let err = Json::parse("{\"a\": }").unwrap_err();
        assert!(err.at >= 6, "at={}", err.at);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[] []").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessors() {
        let doc = Json::parse(r#"{"n": 8, "tag": "x", "xs": [1, 2], "f": 0.5}"#).unwrap();
        assert_eq!(doc.get("n").unwrap().as_u64(), Some(8));
        assert_eq!(doc.get("tag").unwrap().as_str(), Some("x"));
        assert_eq!(doc.get("xs").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(doc.get("f").unwrap().as_f64(), Some(0.5));
        assert_eq!(doc.get("f").unwrap().as_u64(), None);
        assert!(doc.get("nope").is_none());
    }

    #[test]
    fn nested_empty_containers() {
        let doc = Json::parse(r#"{"a": [], "b": {}}"#).unwrap();
        assert_eq!(doc.get("a").unwrap().as_arr(), Some(&[][..]));
        assert_eq!(doc.get("b").unwrap().as_obj(), Some(&[][..]));
        let text = doc.render();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }
}
