//! Shared utilities: PRNGs, the mini bench harness, the mini property
//! runner, and the CLI parser.  These exist because the offline crate set
//! ships no `rand`/`criterion`/`proptest`/`clap`; each is a small,
//! fully-tested substrate (see DESIGN.md §4).

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod rng;

/// `ceil(log2(n))` for n >= 1 (0 for n <= 1); the paper charges
/// `ceil(lg n)` comparisons for a binary search over n-1 keys.
pub fn ceil_log2(n: u64) -> u32 {
    if n <= 1 {
        0
    } else {
        64 - (n - 1).leading_zeros()
    }
}

/// `log2(n)` as f64 (0 for n = 0), used by the analytic charge policy.
pub fn lg(n: f64) -> f64 {
    if n <= 1.0 {
        0.0
    } else {
        n.log2()
    }
}

/// Format a duration in seconds with three significant decimals, matching
/// the paper's table style ("0.526", "1.03", "4.09").
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 10.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(0), 0);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1 << 20), 20);
        assert_eq!(ceil_log2((1 << 20) + 1), 21);
    }

    #[test]
    fn fmt_secs_matches_paper_style() {
        assert_eq!(fmt_secs(0.526), "0.526");
        assert_eq!(fmt_secs(1.034), "1.03");
        assert_eq!(fmt_secs(4.088), "4.09");
        assert_eq!(fmt_secs(12.34), "12.3");
    }
}
