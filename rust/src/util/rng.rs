//! Pseudo-random number generators for input generation and sampling.
//!
//! Two generators:
//!
//! * [`BsdRandom`] — a faithful re-implementation of the glibc
//!   `random()`/`srandom()` additive-feedback generator (TYPE_3, degree 31,
//!   separation 3).  The paper generates its `[U]` benchmark by "calling a
//!   pseudo random number generator, the C standard library function
//!   `random()`", with processor *i* seeded as `21 + 1001*i` (§6.3); using
//!   the same generator keeps our inputs distribution-faithful.
//! * [`SplitMix64`] — a fast, well-mixed 64-bit generator used for the
//!   randomized algorithm's sample selection and for test-case generation
//!   (not part of the paper's input definition, so fidelity is not
//!   required there — speed and independence are).
//!
//! The offline crate set has no `rand`, so these are first-class
//! substrates (tested in this module and exercised by every generator).

/// glibc `random()` (TYPE_3: x[i] = x[i-3] + x[i-31], output >> 1).
///
/// Matches glibc's output sequence exactly for any 32-bit seed: the
/// initialization uses the Park–Miller minimal standard generator on the
/// first 31 words and discards 310 warm-up outputs, as glibc does.
#[derive(Clone, Debug)]
pub struct BsdRandom {
    table: [i32; 31],
    f: usize, // front pointer index (starts at 3 = separation)
    r: usize, // rear pointer index
}

impl BsdRandom {
    /// Equivalent to `srandom(seed)` followed by no calls yet.
    pub fn new(seed: u32) -> Self {
        let seed = if seed == 0 { 1 } else { seed };
        let mut table = [0i32; 31];
        table[0] = seed as i32;
        for i in 1..31 {
            // 16807 * table[i-1] % 2147483647 without overflow
            // (Schrage's method, as in glibc).
            let prev = table[i - 1] as i64;
            let hi = prev / 127_773;
            let lo = prev % 127_773;
            let mut word = 16_807 * lo - 2_836 * hi;
            if word < 0 {
                word += 2_147_483_647;
            }
            table[i] = word as i32;
        }
        let mut rng = BsdRandom { table, f: 3, r: 0 };
        // glibc discards 10*31 outputs to decorrelate the state.
        for _ in 0..310 {
            rng.next_i32();
        }
        rng
    }

    /// Equivalent to `random()`: uniform in `[0, 2^31 - 1]`.
    pub fn next_i32(&mut self) -> i32 {
        let sum = self.table[self.f].wrapping_add(self.table[self.r]);
        self.table[self.f] = sum;
        self.f = if self.f + 1 >= 31 { 0 } else { self.f + 1 };
        self.r = if self.r + 1 >= 31 { 0 } else { self.r + 1 };
        ((sum as u32) >> 1) as i32
    }

    /// Uniform in `[0, bound)` (bound > 0), by modulo as 1990s C code did.
    pub fn below(&mut self, bound: i32) -> i32 {
        debug_assert!(bound > 0);
        self.next_i32() % bound
    }
}

/// SplitMix64: tiny, fast, passes BigCrush; used for sampling and tests.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` by Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn next_i32(&mut self) -> i32 {
        (self.next_u64() >> 32) as i32
    }

    /// Fisher–Yates sample of `k` distinct indices out of `n` (k <= n),
    /// in O(k) space via a sparse swap map.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        use std::collections::HashMap;
        assert!(k <= n);
        let mut swaps: HashMap<usize, usize> = HashMap::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            let vi = *swaps.get(&i).unwrap_or(&i);
            let vj = *swaps.get(&j).unwrap_or(&j);
            out.push(vj);
            swaps.insert(j, vi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference values computed with glibc random() after srandom(1):
    /// the canonical first three outputs.
    #[test]
    fn bsd_random_matches_glibc_seed1() {
        let mut r = BsdRandom::new(1);
        let got: Vec<i32> = (0..3).map(|_| r.next_i32()).collect();
        assert_eq!(got, vec![1_804_289_383, 846_930_886, 1_681_692_777]);
    }

    #[test]
    fn bsd_random_paper_seed_is_deterministic() {
        // seed = 21 + 1001*i for processor i (paper §6.3).
        let a: Vec<i32> = {
            let mut r = BsdRandom::new(21);
            (0..4).map(|_| r.next_i32()).collect()
        };
        let b: Vec<i32> = {
            let mut r = BsdRandom::new(21);
            (0..4).map(|_| r.next_i32()).collect()
        };
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| x >= 0));
    }

    #[test]
    fn bsd_random_seeds_differ() {
        let mut a = BsdRandom::new(21);
        let mut b = BsdRandom::new(21 + 1001);
        assert_ne!(a.next_i32(), b.next_i32());
    }

    #[test]
    fn splitmix_below_is_in_range() {
        let mut r = SplitMix64::new(42);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = SplitMix64::new(7);
        for (n, k) in [(10, 10), (100, 7), (5, 0), (1, 1), (1000, 500)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "indices must be distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn splitmix_distribution_rough_uniformity() {
        let mut r = SplitMix64::new(9);
        let mut buckets = [0usize; 16];
        for _ in 0..16_000 {
            buckets[r.below(16) as usize] += 1;
        }
        for &b in &buckets {
            assert!((700..1300).contains(&b), "bucket count {b} out of range");
        }
    }
}
