//! Out-of-core (external-memory) sorting under the EM-BSP model.
//!
//! When the per-processor input exceeds a memory budget `M`, the sort
//! becomes the classic two-phase external sort — form `⌈n_local/M⌉`
//! sorted runs, then merge — with both phases on this crate's existing
//! machinery: run formation pulls chunks through the persistent engine
//! pool and the selected [`crate::sort::LocalSortEngine`]; the run
//! merge is an SPMD program on the BSP engine using the loser tree of
//! [`crate::seq::merge`].  The cost model grows the EM-BSP third
//! parameter: each fixed-size block transferred to or from the
//! [`store::BlockStore`] is charged `G_io` µs
//! ([`crate::bsp::BspParams::io_us`]), calibrated on the host by the
//! experiment prober or priced synthetically on the simulator
//! ([`crate::bsp::params::T3D_IO_US_PER_BLOCK`]).
//!
//! Entry point: [`sort::sort_external`]; CLI surface:
//! `bsp-sort sort --external --mem-budget <n>`.

pub mod sort;
pub mod store;

pub use sort::{sort_external, ExtRun, ExtSortSpec, PHE1, PHE2, PHE3, PHE4};
pub use store::{BlockId, BlockStore, MemBlockStore, SpillBlockStore, DEFAULT_BLOCK_WORDS};
