//! Out-of-core EM-BSP sorting: streamed run formation + parallel
//! multi-way run merge over a [`BlockStore`].
//!
//! The classic external sort shaped for the BSP substrate:
//!
//! 1. **Run formation** (`PhE1:RunForm`) — each processor's input is
//!    pulled through the persistent engine pool as a closure task:
//!    generate, slice into chunks of at most `mem_budget` keys, sort
//!    each chunk with the selected [`LocalSortEngine`], and spill it to
//!    the block store as one sorted *run* (plus ≤ 32 evenly spaced
//!    samples per run for splitter selection).  Charges follow the
//!    engine's own pricing ([`crate::seq::SeqSorter::charge`]).
//! 2. **Parallel multi-way merge** (`PhE2..PhE4`) — an SPMD program on
//!    the BSP engine: runs are dealt across the `p` processors
//!    round-robin; each processor reads its runs back (`PhE2:MergeIO`,
//!    the block reads the EM term prices), partitions every run at the
//!    `p−1` sample splitters (`PhE3:Scatter`, one h-relation), and
//!    merges the received sorted segments with the loser tree of
//!    [`crate::seq::merge`] (`PhE4:Merge`, charged
//!    [`crate::seq::ops::merge_charge`]).
//!
//! The output is per-processor [`ProcResult`]s exactly like the in-core
//! sorts, so the conformance suite's sortedness and `multiset_sig`
//! checks carry over unchanged — and because the generators are
//! deterministic per `(bench, pid, p, n_local)`, an external run is
//! bit-identical to the in-core sort of the same cell.
//!
//! Costs land in the ordinary [`Ledger`] with the EM extension: block
//! transfers are recorded on the supersteps/phases that perform them
//! (`io_blocks`), priced at `G_io` per block by
//! [`BspParams::io_us`].  External jobs are submitted with
//! `n_hint = usize::MAX` so the service never batches a spilling job
//! onto a shared lane.

use std::sync::Arc;
use std::time::Instant;

use crate::bsp::ledger::{PhaseRecord, SuperstepRecord};
use crate::bsp::params::T3D_IO_US_PER_BLOCK;
use crate::bsp::{cray_t3d, Backend, BspParams, BspRun, BspScope, Ledger, Payload, SimMachine};
use crate::experiment::run::StudyKey;
use crate::ext::store::{
    read_blocks, write_blocks, BlockId, BlockStore, MemBlockStore, SpillBlockStore,
};
use crate::gen::{generate_typed_for_proc, Benchmark};
use crate::key::{self, Key};
use crate::runtime::RuntimeError;
use crate::seq::{self, multiway_merge_owned, ops};
use crate::sort::{LocalSortEngine, ProcResult};
use crate::sorter::Sorter;

/// External phase names (the in-core sorts own `Ph1..Ph7`).
pub const PHE1: &str = "PhE1:RunForm";
/// Reading runs back from the block store.
pub const PHE2: &str = "PhE2:MergeIO";
/// Partitioning runs at the splitters and routing the segments.
pub const PHE3: &str = "PhE3:Scatter";
/// Loser-tree merge of the received segments.
pub const PHE4: &str = "PhE4:Merge";

/// Superstep label of the block-read barrier — the driver attributes
/// the measured read transfers to this superstep's `io_blocks`.
const EXT_READ_LABEL: &str = "ext:read";

/// Samples kept per formed run for splitter selection (the paper's
/// regular-oversampling idea, shrunk to run granularity).
const RUN_SAMPLES: usize = 32;

/// One external-sort job description.
#[derive(Clone, Copy, Debug)]
pub struct ExtSortSpec {
    /// Input distribution (generated per processor, §6.3 seeding).
    pub bench: Benchmark,
    /// Total keys; must be divisible by `p`.
    pub n_total: usize,
    /// Processors.
    pub p: usize,
    /// Maximum keys resident per processor during run formation — the
    /// EM "M".  Budgets below `n_total / p` force spilling into
    /// multiple runs per processor.
    pub mem_budget: usize,
    /// `Threaded` spills to temp files; `Sim` uses the in-memory mock.
    pub backend: Backend,
    /// Local sort engine for run formation.
    pub engine: LocalSortEngine,
    /// Simulator machine parameters (`None`: Cray T3D with the
    /// synthetic `G_io`).  Ignored by the threaded backend, whose
    /// pricing is applied at report time.
    pub params: Option<BspParams>,
}

impl ExtSortSpec {
    /// A spec with the defaults the CLI exposes.
    pub fn new(bench: Benchmark, n_total: usize, p: usize, mem_budget: usize) -> ExtSortSpec {
        ExtSortSpec {
            bench,
            n_total,
            p,
            mem_budget,
            backend: Backend::Threaded,
            engine: LocalSortEngine::Quicksort,
            params: None,
        }
    }

    fn validate(&self) -> Result<(), RuntimeError> {
        let fail = |msg: String| Err(RuntimeError::InvalidJob(msg));
        if self.p == 0 {
            return fail("external sort needs p >= 1".into());
        }
        if self.n_total % self.p != 0 {
            return fail(format!(
                "n = {} is not divisible by p = {} (per-processor generation)",
                self.n_total, self.p
            ));
        }
        if self.mem_budget == 0 {
            return fail("mem-budget must be at least 1 key".into());
        }
        Ok(())
    }
}

/// Result of an external sort: the in-core result shape plus the EM
/// accounting the report surfaces.
#[derive(Debug)]
pub struct ExtRun<K = i32> {
    /// Per-processor chunks of the global sorted order.
    pub outputs: Vec<ProcResult<K>>,
    /// Superstep/phase ledger including the `PhE*` external phases and
    /// their `io_blocks`.
    pub ledger: Ledger,
    /// Sorted runs formed across all processors.
    pub runs_formed: usize,
    /// Blocks written to the store (run formation).
    pub blocks_written: u64,
    /// Blocks read back (merge).
    pub blocks_read: u64,
    /// `"mem"` or `"spill"`.
    pub store_kind: &'static str,
}

/// A spilled sorted run: its block sequence and key count.
#[derive(Clone, Debug)]
struct RunMeta {
    blocks: Vec<BlockId>,
    len: usize,
}

/// What one processor's formation task returns to the driver.
struct FormedRuns<K> {
    runs: Vec<RunMeta>,
    samples: Vec<K>,
    charge: f64,
    wall_us: f64,
}

/// Everything the merge program shares read-only across processors.
struct MergeShared<K> {
    store: Arc<dyn BlockStore>,
    runs: Vec<RunMeta>,
    splitters: Vec<K>,
}

/// Generate, chunk-sort and spill one processor's input (a pool
/// closure task — runs on one lane, off the SPMD engines).
fn form_runs<K: StudyKey>(
    store: &dyn BlockStore,
    bench: Benchmark,
    pid: usize,
    p: usize,
    n_local: usize,
    engine: LocalSortEngine,
    mem_budget: usize,
) -> FormedRuns<K> {
    let started = Instant::now();
    let sorter = seq::backend::<K>(engine.seq_kind());
    let input = generate_typed_for_proc::<K>(bench, pid, p, n_local);
    let mut runs = Vec::new();
    let mut samples = Vec::new();
    let mut charge = 0.0;
    for chunk in input.chunks(mem_budget) {
        let mut run = chunk.to_vec();
        sorter.sort(&mut run);
        charge += sorter.charge(run.len());
        let m = run.len();
        let s = RUN_SAMPLES.min(m);
        // The last key of each of s equal segments — evenly spaced and
        // including the run maximum.
        for i in 0..s {
            samples.push(run[(i + 1) * m / s - 1]);
        }
        // One encode pass to the wire image, then spill block by block.
        charge += ops::linear_charge(m);
        let blocks = write_blocks(store, &key::encode_all(&run));
        runs.push(RunMeta { blocks, len: m });
    }
    FormedRuns { runs, samples, charge, wall_us: started.elapsed().as_secs_f64() * 1e6 }
}

/// The SPMD merge: read owned runs, scatter splitter segments, merge.
/// Returns this processor's output and the blocks it read.
fn merge_program<K: StudyKey, S: BspScope<K>>(
    ctx: &mut S,
    shared: &MergeShared<K>,
) -> (ProcResult<K>, u64) {
    let p = ctx.nprocs();
    let pid = ctx.pid();

    // PhE2 — read this processor's deal of the runs (round-robin by
    // run index, so every processor pays a near-equal share of I/O).
    ctx.phase(PHE2);
    let mut blocks_read = 0u64;
    let mut my_runs: Vec<Vec<K>> = Vec::new();
    for (r, meta) in shared.runs.iter().enumerate() {
        if r % p != pid {
            continue;
        }
        let keys = key::decode_all::<K>(&read_blocks(shared.store.as_ref(), &meta.blocks));
        debug_assert_eq!(keys.len(), meta.len, "run {r} length drifted through the store");
        blocks_read += meta.blocks.len() as u64;
        ctx.charge(ops::linear_charge(keys.len())); // decode pass
        my_runs.push(keys);
    }
    ctx.sync(EXT_READ_LABEL);

    // PhE3 — partition each run at the global splitters and route
    // every segment to its destination.  Segments of one sorted run
    // are themselves sorted, so each arrives merge-ready.
    ctx.phase(PHE3);
    for run in &my_runs {
        ctx.charge((p as f64 - 1.0) * ops::bsearch_charge(run.len()));
    }
    for run in my_runs {
        let mut bounds = Vec::with_capacity(p + 1);
        bounds.push(0);
        for s in &shared.splitters {
            bounds.push(run.partition_point(|k| k < s));
        }
        bounds.push(run.len());
        for dst in 0..p {
            let seg = &run[bounds[dst]..bounds[dst + 1]];
            if !seg.is_empty() {
                ctx.send(dst, Payload::Keys(seg.to_vec()));
            }
        }
    }
    ctx.sync("ext:scatter");

    // PhE4 — loser-tree merge of the received segments.
    ctx.phase(PHE4);
    let segments: Vec<Vec<K>> = ctx
        .take_inbox()
        .into_iter()
        .map(|(_, payload)| match payload {
            Payload::Keys(keys) => keys,
            other => panic!("merge inbox expects keys, got {other:?}"),
        })
        .collect();
    let received: usize = segments.iter().map(Vec::len).sum();
    let q = segments.len();
    ctx.charge(ops::merge_charge(received, q));
    let keys = multiway_merge_owned(segments);
    ctx.sync("ext:merge");
    (ProcResult { keys, received, runs: q }, blocks_read)
}

/// The `p−1` splitters from the pooled run samples (driver side — the
/// sample is tiny, ≤ 32 per run).  Empty sample ⇒ sentinel splitters,
/// mirroring [`crate::sort::common::select_splitters`].
fn splitters_from_samples<K: Key>(mut samples: Vec<K>, p: usize) -> (Vec<K>, f64) {
    if p <= 1 {
        return (Vec::new(), 0.0);
    }
    let m = samples.len();
    if m == 0 {
        return (vec![K::max_key(); p - 1], 0.0);
    }
    samples.sort_unstable();
    let splitters =
        (1..p).map(|i| samples[(i * m / p).saturating_sub(1).min(m - 1)]).collect();
    (splitters, ops::sort_charge(m))
}

/// Run one external sort end to end.  See the module docs for the
/// phase structure; the returned ledger prices under any
/// [`BspParams`] whose `io_us_per_block` is set (e.g.
/// [`cray_t3d`]`(p).with_io(T3D_IO_US_PER_BLOCK)`).
pub fn sort_external<K: StudyKey>(spec: &ExtSortSpec) -> Result<ExtRun<K>, RuntimeError> {
    spec.validate()?;
    let p = spec.p;
    let n_local = spec.n_total / p;
    let store: Arc<dyn BlockStore> = match spec.backend {
        Backend::Sim => Arc::new(MemBlockStore::new()),
        Backend::Threaded => Arc::new(
            SpillBlockStore::new()
                .map_err(|e| RuntimeError::Service(format!("spill store: {e}")))?,
        ),
    };

    // PhE1 — run formation, one pool task per processor.  Submitted as
    // closure tasks so formation parallelism comes from pool lanes,
    // not from spinning up an SPMD team for sequential work.
    let pool = Sorter::global();
    let mut handles = Vec::with_capacity(p);
    for pid in 0..p {
        let store = Arc::clone(&store);
        let (bench, engine, budget) = (spec.bench, spec.engine, spec.mem_budget);
        handles.push(pool.closure_engine().submit_task(
            move || {
                let formed =
                    form_runs::<K>(store.as_ref(), bench, pid, p, n_local, engine, budget);
                BspRun { outputs: vec![formed], ledger: Ledger::default() }
            },
            true,
        )?);
    }
    let mut formed = Vec::with_capacity(p);
    for handle in handles {
        let mut run = handle.join()?;
        formed.push(run.outputs.pop().expect("one formation result per task"));
    }

    let mut all_runs = Vec::new();
    let mut samples = Vec::new();
    let mut form_wall: f64 = 0.0;
    let mut form_ops: f64 = 0.0;
    let mut written_max = 0u64;
    for f in &mut formed {
        written_max = written_max.max(f.runs.iter().map(|r| r.blocks.len() as u64).sum());
        all_runs.append(&mut f.runs);
        samples.append(&mut f.samples);
        form_wall = form_wall.max(f.wall_us);
        form_ops = form_ops.max(f.charge);
    }
    let runs_formed = all_runs.len();
    let (splitters, splitter_ops) = splitters_from_samples(samples, p);
    form_ops += splitter_ops;

    // PhE2–PhE4 — the SPMD merge, never batched (n_hint = usize::MAX).
    let shared =
        Arc::new(MergeShared { store: Arc::clone(&store), runs: all_runs, splitters });
    let run: BspRun<(ProcResult<K>, u64)> = match spec.backend {
        Backend::Threaded => {
            let shared = Arc::clone(&shared);
            pool.spmd_engine(p)
                .submit_program_blocking::<K, _, _>(usize::MAX, move |ctx| {
                    merge_program(ctx, &shared)
                })?
                .join()?
        }
        Backend::Sim => {
            let params =
                spec.params.unwrap_or_else(|| cray_t3d(p).with_io(T3D_IO_US_PER_BLOCK));
            let shared = Arc::clone(&shared);
            pool.closure_engine()
                .submit_task(
                    move || {
                        SimMachine::new(params)
                            .run_keys::<K, _, _>(|ctx| merge_program(ctx, &shared))
                    },
                    true,
                )?
                .join()?
        }
    };

    let BspRun { outputs: pairs, mut ledger } = run;
    let read_max = pairs.iter().map(|(_, b)| *b).max().unwrap_or(0);
    let outputs: Vec<ProcResult<K>> = pairs.into_iter().map(|(r, _)| r).collect();

    // Attribute the measured block transfers to the ledger: reads to
    // the PhE2 barrier, writes to a synthetic formation superstep
    // prepended ahead of the merge (formation ran outside the SPMD
    // engines, so the driver records it — like the in-core driver's
    // round-`None` supersteps).
    for s in &mut ledger.supersteps {
        if s.label == EXT_READ_LABEL {
            s.io_blocks = read_max;
        }
    }
    if let Some(phase) = ledger.phases.get_mut(PHE2) {
        phase.io_blocks = read_max;
    }
    ledger.supersteps.insert(
        0,
        SuperstepRecord {
            label: "ext:runform".into(),
            phase: PHE1.into(),
            max_ops: form_ops,
            h_words: 0,
            total_words: 0,
            wall_us: form_wall,
            reporters: p,
            procs: p,
            round: None,
            io_blocks: written_max,
        },
    );
    ledger.phases.insert(
        PHE1.into(),
        PhaseRecord {
            max_ops: form_ops,
            h_words: 0,
            supersteps: 1,
            wall_us: form_wall,
            io_blocks: written_max,
        },
    );
    ledger.wall_us += form_wall;

    Ok(ExtRun {
        outputs,
        ledger,
        runs_formed,
        blocks_written: store.blocks_written(),
        blocks_read: store.blocks_read(),
        store_kind: store.kind(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::multiset_sig;

    fn expected_sorted(bench: Benchmark, n: usize, p: usize) -> Vec<i32> {
        let mut all: Vec<i32> =
            (0..p).flat_map(|pid| generate_typed_for_proc::<i32>(bench, pid, p, n / p)).collect();
        all.sort_unstable();
        all
    }

    fn concat<K: Copy>(outputs: &[ProcResult<K>]) -> Vec<K> {
        outputs.iter().flat_map(|r| r.keys.iter().copied()).collect()
    }

    #[test]
    fn sim_external_sort_matches_the_in_core_order() {
        let (n, p) = (4096, 4);
        let mut spec = ExtSortSpec::new(Benchmark::Uniform, n, p, 256);
        spec.backend = Backend::Sim;
        let run = sort_external::<i32>(&spec).expect("sim external sort");
        assert_eq!(run.store_kind, "mem");
        assert_eq!(run.runs_formed, 4 * p); // 1024 local keys / 256 budget
        assert_eq!(concat(&run.outputs), expected_sorted(Benchmark::Uniform, n, p));
    }

    #[test]
    fn threaded_external_sort_spills_and_matches() {
        let (n, p) = (4096, 4);
        let spec = ExtSortSpec::new(Benchmark::DetDup, n, p, 200);
        let run = sort_external::<i32>(&spec).expect("threaded external sort");
        assert_eq!(run.store_kind, "spill");
        assert!(run.runs_formed > p, "budget 200 < 1024 must force spilling");
        assert!(run.blocks_written > 0 && run.blocks_read == run.blocks_written);
        let got = concat(&run.outputs);
        let expect = expected_sorted(Benchmark::DetDup, n, p);
        assert_eq!(multiset_sig(got.iter().copied()), multiset_sig(expect.iter().copied()));
        assert_eq!(got, expect);
    }

    #[test]
    fn ledger_carries_io_blocks_on_the_external_phases() {
        let mut spec = ExtSortSpec::new(Benchmark::Uniform, 8192, 4, 512);
        spec.backend = Backend::Sim;
        let run = sort_external::<i32>(&spec).expect("sim external sort");
        let form = &run.ledger.phases[PHE1];
        let io = &run.ledger.phases[PHE2];
        assert!(form.io_blocks > 0, "formation must charge block writes");
        assert!(io.io_blocks > 0, "merge must charge block reads");
        assert_eq!(run.ledger.supersteps[0].phase, PHE1);
        // Pricing with G_io strictly exceeds pricing without it.
        let flat = cray_t3d(4);
        let em = flat.with_io(T3D_IO_US_PER_BLOCK);
        assert!(run.ledger.predicted_us(&em) > run.ledger.predicted_us(&flat));
    }

    #[test]
    fn degenerate_budgets_and_shapes_still_sort() {
        // Budget of one key: every run is a singleton (merge fan-in is
        // maximal); p = 1: no splitters at all.
        for (p, budget) in [(4usize, 1usize), (1, 7)] {
            let mut spec = ExtSortSpec::new(Benchmark::Uniform, 256, p, budget);
            spec.backend = Backend::Sim;
            let run = sort_external::<i32>(&spec).expect("degenerate external sort");
            assert_eq!(concat(&run.outputs), expected_sorted(Benchmark::Uniform, 256, p));
        }
    }

    #[test]
    fn invalid_specs_are_rejected() {
        let bad_div = ExtSortSpec::new(Benchmark::Uniform, 100, 3, 8);
        assert!(matches!(
            sort_external::<i32>(&bad_div),
            Err(RuntimeError::InvalidJob(_))
        ));
        let bad_budget = ExtSortSpec::new(Benchmark::Uniform, 96, 3, 0);
        assert!(matches!(
            sort_external::<i32>(&bad_budget),
            Err(RuntimeError::InvalidJob(_))
        ));
    }
}
