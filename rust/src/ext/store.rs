//! Fixed-size block storage for the out-of-core sorter.
//!
//! The EM-BSP model (PAPERS.md: Dehne et al.'s external-memory BSP)
//! extends `(p, L, g)` with a per-block transfer charge `G_io`: every
//! disk access moves one fixed-size block of `B` words.  This module is
//! the storage substrate that makes the charge *countable*: a
//! [`BlockStore`] hands out opaque [`BlockId`]s for block-sized word
//! buffers and counts every `put`/`read` so the driver can attribute
//! `G_io·b` to the ledger ([`crate::bsp::ledger`]).
//!
//! Two backends mirror the in-core `Backend::{Threaded, Sim}` split:
//!
//! * [`MemBlockStore`] — a heap-backed mock for the simulator path
//!   (deterministic, no filesystem), still charging per block;
//! * [`SpillBlockStore`] — a real temp-file backend (one file per
//!   block under a private `bsp-ext-*` directory in
//!   `std::env::temp_dir()`), removed on drop.
//!
//! Both are `Sync`: run formation writes from several pool lanes and
//! the merge program reads from `p` SPMD processors concurrently.

use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Block capacity in 64-bit words.  4096 words = 32 KiB, the classic
/// external-memory page granularity; every `put` of up to this many
/// words costs exactly one block transfer.
pub const DEFAULT_BLOCK_WORDS: usize = 4096;

/// Opaque handle to one stored block, unique within its store.
pub type BlockId = u64;

/// A store of fixed-size word blocks with transfer accounting.
///
/// `put` and `read` each count one block transfer regardless of fill —
/// that is the EM model's point: a half-empty block costs a full block.
pub trait BlockStore: Send + Sync {
    /// Store up to [`DEFAULT_BLOCK_WORDS`] words as one block.
    fn put(&self, words: &[u64]) -> BlockId;
    /// Read a block back; panics on an unknown id (a driver bug, not a
    /// recoverable condition).
    fn read(&self, id: BlockId) -> Vec<u64>;
    /// Discard a block (uncounted — deletion is metadata, not transfer).
    fn delete(&self, id: BlockId);
    /// Cumulative blocks written through `put`.
    fn blocks_written(&self) -> u64;
    /// Cumulative blocks read through `read`.
    fn blocks_read(&self) -> u64;
    /// `"mem"` or `"spill"` — surfaced in reports.
    fn kind(&self) -> &'static str;
}

/// Slice `words` into block-sized chunks and store them all; the ids
/// come back in order, so `read_blocks` reassembles the exact buffer.
pub fn write_blocks(store: &dyn BlockStore, words: &[u64]) -> Vec<BlockId> {
    if words.is_empty() {
        return Vec::new();
    }
    words.chunks(DEFAULT_BLOCK_WORDS).map(|c| store.put(c)).collect()
}

/// Read and concatenate a block sequence written by [`write_blocks`].
pub fn read_blocks(store: &dyn BlockStore, ids: &[BlockId]) -> Vec<u64> {
    let mut out = Vec::with_capacity(ids.len() * DEFAULT_BLOCK_WORDS);
    for &id in ids {
        out.extend_from_slice(&store.read(id));
    }
    out
}

/// In-memory block store — the simulator backend's mock.  Transfers
/// are counted exactly as for the spill store, so predicted `G_io·b`
/// terms are identical across backends for the same plan.
#[derive(Default)]
pub struct MemBlockStore {
    blocks: Mutex<HashMap<BlockId, Vec<u64>>>,
    next: AtomicU64,
    written: AtomicU64,
    read: AtomicU64,
}

impl MemBlockStore {
    pub fn new() -> MemBlockStore {
        MemBlockStore::default()
    }
}

impl BlockStore for MemBlockStore {
    fn put(&self, words: &[u64]) -> BlockId {
        assert!(words.len() <= DEFAULT_BLOCK_WORDS, "block overflow: {} words", words.len());
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.blocks.lock().expect("block map poisoned").insert(id, words.to_vec());
        self.written.fetch_add(1, Ordering::Relaxed);
        id
    }

    fn read(&self, id: BlockId) -> Vec<u64> {
        self.read.fetch_add(1, Ordering::Relaxed);
        self.blocks
            .lock()
            .expect("block map poisoned")
            .get(&id)
            .unwrap_or_else(|| panic!("unknown block id {id}"))
            .clone()
    }

    fn delete(&self, id: BlockId) {
        self.blocks.lock().expect("block map poisoned").remove(&id);
    }

    fn blocks_written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    fn blocks_read(&self) -> u64 {
        self.read.load(Ordering::Relaxed)
    }

    fn kind(&self) -> &'static str {
        "mem"
    }
}

/// Process-wide nonce so concurrent spill stores in one process get
/// distinct directories (the pid alone does not disambiguate them).
static SPILL_NONCE: AtomicU64 = AtomicU64::new(0);

/// Temp-file block store — the threaded backend's real spill path.
/// Each block is one `block-<id>.bin` file (words as little-endian
/// bytes) under a fresh `bsp-ext-<pid>-<nonce>` directory in
/// [`std::env::temp_dir`]; the whole directory is removed on drop, so
/// an external sort leaves nothing behind (`ci.sh --extsort-smoke`
/// asserts exactly that).
pub struct SpillBlockStore {
    dir: PathBuf,
    next: AtomicU64,
    written: AtomicU64,
    read: AtomicU64,
}

impl SpillBlockStore {
    /// Create the spill directory; fails only on filesystem errors
    /// (unwritable temp dir).
    pub fn new() -> io::Result<SpillBlockStore> {
        let nonce = SPILL_NONCE.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!("bsp-ext-{}-{nonce}", std::process::id()));
        fs::create_dir_all(&dir)?;
        Ok(SpillBlockStore {
            dir,
            next: AtomicU64::new(0),
            written: AtomicU64::new(0),
            read: AtomicU64::new(0),
        })
    }

    /// The spill directory (tests assert its lifecycle).
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    fn path(&self, id: BlockId) -> PathBuf {
        self.dir.join(format!("block-{id}.bin"))
    }
}

impl Drop for SpillBlockStore {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.dir);
    }
}

impl BlockStore for SpillBlockStore {
    fn put(&self, words: &[u64]) -> BlockId {
        assert!(words.len() <= DEFAULT_BLOCK_WORDS, "block overflow: {} words", words.len());
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let mut bytes = Vec::with_capacity(words.len() * 8);
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        fs::write(self.path(id), bytes).expect("spill write failed");
        self.written.fetch_add(1, Ordering::Relaxed);
        id
    }

    fn read(&self, id: BlockId) -> Vec<u64> {
        self.read.fetch_add(1, Ordering::Relaxed);
        let bytes = fs::read(self.path(id)).expect("spill read failed");
        assert_eq!(bytes.len() % 8, 0, "truncated spill block {id}");
        bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
            .collect()
    }

    fn delete(&self, id: BlockId) {
        let _ = fs::remove_file(self.path(id));
    }

    fn blocks_written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    fn blocks_read(&self) -> u64 {
        self.read.load(Ordering::Relaxed)
    }

    fn kind(&self) -> &'static str {
        "spill"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(store: &dyn BlockStore) {
        let a = store.put(&[1, 2, 3]);
        let b = store.put(&[u64::MAX, 0]);
        assert_eq!(store.read(a), vec![1, 2, 3]);
        assert_eq!(store.read(b), vec![u64::MAX, 0]);
        assert_eq!(store.read(a), vec![1, 2, 3]); // re-read, recounted
        assert_eq!(store.blocks_written(), 2);
        assert_eq!(store.blocks_read(), 3);
        store.delete(a);
        store.delete(b);
    }

    #[test]
    fn mem_store_roundtrips_and_counts() {
        roundtrip(&MemBlockStore::new());
    }

    #[test]
    fn spill_store_roundtrips_and_counts() {
        let store = SpillBlockStore::new().expect("temp dir writable");
        roundtrip(&store);
    }

    #[test]
    fn spill_store_removes_its_directory_on_drop() {
        let store = SpillBlockStore::new().expect("temp dir writable");
        let dir = store.dir().to_path_buf();
        store.put(&[7; 100]);
        assert!(dir.is_dir());
        drop(store);
        assert!(!dir.exists(), "spill dir {} survived drop", dir.display());
    }

    #[test]
    fn write_blocks_slices_at_block_capacity() {
        let store = MemBlockStore::new();
        let words: Vec<u64> = (0..2 * DEFAULT_BLOCK_WORDS as u64 + 5).collect();
        let ids = write_blocks(&store, &words);
        assert_eq!(ids.len(), 3); // 4096 + 4096 + 5
        assert_eq!(store.blocks_written(), 3);
        assert_eq!(read_blocks(&store, &ids), words);
        assert!(write_blocks(&store, &[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "block overflow")]
    fn put_rejects_oversized_buffers() {
        MemBlockStore::new().put(&vec![0u64; DEFAULT_BLOCK_WORDS + 1]);
    }
}
