//! `bsp-sort` — CLI for the BSP sorting study.
//!
//! Subcommands:
//!
//! ```text
//! table <N>        regenerate paper Table N (1..11)
//! all-tables       regenerate every table
//! sort             run one sorting configuration and report
//! experiment       sweep + (g,L) calibration + measured-vs-predicted
//!                  report (BENCH_<tag>.json / .md)
//! predict          Prop 5.1/5.3 efficiency vs harness prediction
//! validate-g       back out g from the routing phase (§6.4)
//! ablate-dup       duplicate-handling overhead ablation (§6.1/§6.4)
//! selftest         tiny end-to-end sanity run (incl. PJRT if built)
//! ```
//!
//! Common flags: `--max-n <keys>`, `--max-p <procs>`, `--full`,
//! `--reps <k>`, `--seed <s>`; `sort` adds `--algo`, `--bench`, `--n`,
//! `--p`, `--domain`, `--jobs`, `--local-sort` (alias `--seq`),
//! `--no-dup`, the multi-level topology flags
//! `--groups`, `--topology`, `--levels auto`, and the out-of-core pair
//! `--external --mem-budget`; `experiment` adds
//! `--quick`, `--algos`, `--benches`, `--domains`, `--ns`, `--ps`,
//! `--topologies`, `--local-sorts`, `--mem-budgets`, `--warmup`,
//! `--tag`, `--out`.

use std::path::Path;

use bsp_sort::bsp::params::cray_t3d;
use bsp_sort::bsp::Backend;
use bsp_sort::experiment::{self, SweepSpec};
use bsp_sort::gen::Benchmark;
use bsp_sort::metrics::RunReport;
use bsp_sort::prelude::{KeyDomain, SortJob, SortRun, Sorter, TopologyChoice};
use bsp_sort::sort::{plan, DuplicatePolicy, LocalSortEngine, SortConfig};
use bsp_sort::tables::{self, runner, TableOpts};
use bsp_sort::util::cli::Args;
use bsp_sort::util::fmt_secs;
use bsp_sort::util::json::Json;

const VALUE_OPTS: &[&str] = &[
    "max-n", "max-p", "reps", "seed", "algo", "bench", "n", "p", "seq", "table",
    "algos", "benches", "domains", "ns", "ps", "warmup", "tag", "out",
    "backend", "backends", "groups", "topology", "levels", "topologies",
    "domain", "jobs", "local-sort", "local-sorts", "mem-budget", "mem-budgets",
];

fn main() {
    let args = match Args::from_env(VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn opts_from(args: &Args) -> Result<TableOpts, Box<dyn std::error::Error>> {
    let mut opts = if args.flag("full") {
        TableOpts::full()
    } else {
        TableOpts::default()
    };
    opts.max_n = args.get_parsed("max-n", opts.max_n)?;
    opts.max_p = args.get_parsed("max-p", opts.max_p)?;
    opts.reps = args.get_parsed("reps", opts.reps)?;
    opts.seed = args.get_parsed("seed", opts.seed)?;
    Ok(opts)
}

fn run(cmd: &str, args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    match cmd {
        "table" => {
            let opts = opts_from(args)?;
            let num: usize = args
                .positional
                .get(1)
                .ok_or("usage: bsp-sort table <1..11>")?
                .parse()?;
            let out = tables::run_table(num, &opts).ok_or("table number must be 1..=11")?;
            println!("{}", out.render());
        }
        "all-tables" => {
            let opts = opts_from(args)?;
            for num in 1..=11 {
                let out = tables::run_table(num, &opts).unwrap();
                println!("{}", out.render());
            }
            println!("{}", tables::validate::validate_g(&opts).render());
            println!("{}", tables::validate::predict(&opts).render());
            println!("{}", tables::validate::ablate_duplicates(&opts).render());
        }
        "predict" => {
            let opts = opts_from(args)?;
            println!("{}", tables::validate::predict(&opts).render());
        }
        "validate-g" => {
            let opts = opts_from(args)?;
            println!("{}", tables::validate::validate_g(&opts).render());
        }
        "ablate-dup" => {
            let opts = opts_from(args)?;
            println!("{}", tables::validate::ablate_duplicates(&opts).render());
        }
        "sort" => {
            let opts = opts_from(args)?;
            // One parser for every runnable variant (unknown tags list
            // the accepted set) — the same registry `experiment` sweeps.
            let algo = runner::AlgoVariant::parse(args.get("algo").unwrap_or("det"))?;
            // parse_strict: an unknown tag is a RuntimeError that lists
            // the valid tags (the old path silently dropped to a generic
            // message on `None`).
            let bench = Benchmark::parse_strict(args.get("bench").unwrap_or("U"))?;
            let domain = KeyDomain::parse(args.get("domain").unwrap_or("i32"))?;
            let n: usize = args.get_parsed("n", 1 << 20)?;
            let p: usize = args.get_parsed("p", 8)?;
            // --jobs N submits N seed-varied copies to the engine pool
            // concurrently (service mode) and reports throughput.
            let jobs: usize = args.get_parsed("jobs", 1)?;
            // --local-sort is the canonical spelling for the
            // per-processor base case (quicksort | lsd-radix | ips);
            // --seq remains as the historical alias.
            let engine_tag = args
                .get("local-sort")
                .or_else(|| args.get("seq"))
                .unwrap_or("quicksort");
            let engine = LocalSortEngine::parse(engine_tag).ok_or_else(|| {
                format!(
                    "unknown local-sort engine '{engine_tag}' \
                     (expected one of quicksort, lsd-radix, ips)"
                )
            })?;
            let mut cfg = SortConfig::default().with_local_sort(engine);
            if args.flag("no-dup") {
                cfg = cfg.with_dup(DuplicatePolicy::Off);
            }
            // --backend sim runs the same program on the deterministic
            // simulator: virtual processors (p beyond host threads),
            // virtual time, seeded replay.
            let backend_tag = args.get("backend").unwrap_or("threaded");
            let backend = Backend::parse(backend_tag).ok_or_else(|| {
                format!("unknown --backend '{backend_tag}' (expected threaded or sim)")
            })?;
            // --external --mem-budget <keys>: the out-of-core EM-BSP
            // sort — streamed run formation under the budget, then a
            // parallel multi-way merge of the spilled runs.  It has no
            // in-core algorithm or topology to pick, so it short-
            // circuits here.
            if args.flag("external") || args.get("mem-budget").is_some() {
                let budget: usize = args.get_parsed("mem-budget", 0)?;
                if budget == 0 {
                    return Err(
                        "--external needs --mem-budget <keys per processor> (≥ 1)".into()
                    );
                }
                let mut spec = bsp_sort::ext::ExtSortSpec::new(bench, n, p, budget);
                spec.backend = backend;
                spec.engine = engine;
                match domain {
                    KeyDomain::I32 => print_ext(&bsp_sort::ext::sort_external::<i32>(&spec)?, &spec),
                    KeyDomain::U64 => print_ext(&bsp_sort::ext::sort_external::<u64>(&spec)?, &spec),
                    KeyDomain::F64T => print_ext(
                        &bsp_sort::ext::sort_external::<bsp_sort::key::F64>(&spec)?,
                        &spec,
                    ),
                    KeyDomain::RecordU32 => print_ext(
                        &bsp_sort::ext::sort_external::<bsp_sort::key::Record>(&spec)?,
                        &spec,
                    ),
                    KeyDomain::Str => print_ext(
                        &bsp_sort::ext::sort_external::<bsp_sort::key::Str>(&spec)?,
                        &spec,
                    ),
                }
                return Ok(());
            }
            // Topology selection for the multi-level variants: --groups
            // pins a depth-2 split, --topology a full divisor tree
            // (strictly validated against p, invalid shapes list the
            // valid ones), --levels auto defers to the cost-model
            // planner.  At most one of the three; with none, the
            // planner resolves the depth-k variants (as before).
            if ["groups", "topology", "levels"]
                .iter()
                .filter(|k| args.get(k).is_some())
                .count()
                > 1
            {
                return Err("use at most one of --groups, --topology, --levels".into());
            }
            let mut choice = TopologyChoice::Auto;
            if let Some(v) = args.get("groups") {
                let k: usize = v
                    .parse()
                    .map_err(|_| format!("--groups '{v}' is not an integer"))?;
                choice = TopologyChoice::Fixed(plan::parse_groups(k, p)?);
            }
            if let Some(v) = args.get("topology") {
                choice = TopologyChoice::Fixed(plan::parse_topology(v, p)?);
            }
            if let Some(v) = args.get("levels") {
                match v {
                    "auto" | "plan" => choice = TopologyChoice::Auto,
                    other => {
                        return Err(format!(
                            "unknown --levels '{other}' (expected auto)"
                        )
                        .into())
                    }
                }
            }

            // Everything below routes through the sort service: one
            // SortJob builder, the persistent engine pool behind it.
            let job = SortJob::new(algo, n)
                .bench(bench)
                .domain(domain)
                .procs(p)
                .config(cfg)
                .seed(opts.seed)
                .backend(backend)
                .topology(choice);
            match algo {
                runner::AlgoVariant::DetK | runner::AlgoVariant::RanK => {
                    if let Some(t) = job.planned_topology() {
                        println!("topology        : {}", t.label());
                    }
                }
                runner::AlgoVariant::Det2 | runner::AlgoVariant::Ran2 => {
                    let shape = job
                        .planned_topology()
                        .unwrap_or_else(|| bsp_sort::sort::multilevel::default_topology(p));
                    println!("topology        : {}", shape.label());
                }
                _ => {}
            }

            if jobs > 1 {
                // Service mode: submit every job up front (admission
                // control applies — a full queue is a structured
                // RuntimeError printed by the one error path), then
                // join and report batch throughput.
                let started = std::time::Instant::now();
                let handles: Vec<_> = (0..jobs)
                    .map(|i| Sorter::global().submit(job.seed(opts.seed.wrapping_add(i as u64))))
                    .collect::<Result<_, _>>()?;
                let runs: Vec<SortRun> =
                    handles.into_iter().map(|h| h.join()).collect::<Result<_, _>>()?;
                let secs = started.elapsed().as_secs_f64();
                println!(
                    "{} jobs completed in {} s ({:.1} jobs/sec)",
                    jobs,
                    fmt_secs(secs),
                    jobs as f64 / secs.max(1e-9)
                );
                print_sort_run(&runs[0], p);
            } else if domain == KeyDomain::I32 {
                // The paper's domain keeps the full measured-vs-
                // predicted report (the runner routes through the same
                // engine pool).
                let mut spec = runner::RunSpec::new(algo, bench, p, n)
                    .with_cfg(cfg)
                    .with_backend(backend)
                    .with_seed(opts.seed);
                if let Some(t) = job.planned_topology() {
                    spec = spec.with_topology(t);
                }
                let report = runner::execute(&spec);
                print_report(&report);
            } else {
                let run = Sorter::global().run(job)?;
                print_sort_run(&run, p);
            }
        }
        "experiment" => {
            run_experiment(args)?;
        }
        "selftest" => {
            selftest()?;
        }
        _ => {
            println!("{}", HELP.trim());
        }
    }
    Ok(())
}

fn print_report(r: &RunReport) {
    let params = cray_t3d(r.p);
    println!("algorithm       : {} on {}", r.algorithm, r.benchmark);
    println!("n, p            : {} keys, {} procs", r.n_total, r.p);
    println!("predicted T3D   : {} s", fmt_secs(r.predicted_secs));
    println!("measured (host) : {} s", fmt_secs(r.wall_secs));
    println!("efficiency      : {:.0}%", 100.0 * r.efficiency(&params));
    println!(
        "imbalance       : max {} / mean {:.0} keys (expansion {:+.1}%)",
        r.imbalance.max_received,
        r.imbalance.mean_received,
        100.0 * r.imbalance.expansion
    );
    println!("phase breakdown (predicted seconds):");
    for (ph, secs) in &r.phase_predicted {
        println!("  {ph:<14} {}", fmt_secs(*secs));
    }
}

/// Compact per-job summary for service-mode and non-`i32` sorts (the
/// full measured-vs-predicted report is `i32`-domain only).
fn print_sort_run(run: &SortRun, p: usize) {
    let params = cray_t3d(p);
    println!("domain          : {}", run.outputs.domain().tag());
    println!(
        "keys            : {} across {} procs (globally sorted: {})",
        run.outputs.total_keys(),
        run.outputs.procs(),
        run.outputs.is_globally_sorted()
    );
    println!("predicted T3D   : {} s", fmt_secs(run.ledger.predicted_secs(&params)));
    println!("measured (host) : {} s", fmt_secs(run.ledger.wall_us / 1e6));
}

/// Summary for `sort --external`: conformance facts (keys, sortedness),
/// the external-memory evidence (runs, blocks, store backend) and the
/// EM-priced model seconds next to the measured wall.
fn print_ext<K: bsp_sort::experiment::StudyKey>(
    run: &bsp_sort::ext::ExtRun<K>,
    spec: &bsp_sort::ext::ExtSortSpec,
) {
    use bsp_sort::bsp::params::T3D_IO_US_PER_BLOCK;
    let params = cray_t3d(spec.p).with_io(T3D_IO_US_PER_BLOCK);
    let total: usize = run.outputs.iter().map(|r| r.keys.len()).sum();
    let sorted = run
        .outputs
        .iter()
        .flat_map(|r| r.keys.iter())
        .zip(run.outputs.iter().flat_map(|r| r.keys.iter()).skip(1))
        .all(|(a, b)| a <= b);
    println!("external sort   : mem budget {} keys/proc", spec.mem_budget);
    println!(
        "keys            : {} across {} procs (globally sorted: {})",
        total,
        run.outputs.len(),
        sorted
    );
    println!(
        "runs formed     : {} ({} blocks written, {} read, store: {})",
        run.runs_formed, run.blocks_written, run.blocks_read, run.store_kind
    );
    println!("G_io            : {T3D_IO_US_PER_BLOCK} µs/block (T3D model)");
    println!("predicted T3D   : {} s", fmt_secs(run.ledger.predicted_secs(&params)));
    println!("measured (host) : {} s", fmt_secs(run.ledger.wall_us / 1e6));
}

/// The `experiment` subcommand: build the sweep from flags, calibrate,
/// run, write `BENCH_<tag>.{json,md}`, then re-read and schema-validate
/// the JSON before declaring success.
fn run_experiment(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let spec = SweepSpec::from_args(args)?;
    let out_dir = args.get("out").unwrap_or(".");
    let configs = spec.configs().len();
    println!(
        "experiment '{}': {} configurations × ({} warmup + {} reps), p ∈ {:?}",
        spec.tag, configs, spec.warmup, spec.reps, spec.ps
    );
    let report = experiment::run_study(&spec);

    for c in &report.calibrations {
        println!(
            "calibrated p={:<3}  L = {:>8.2} µs   g = {:.4} µs/word   rate = {:.1} comps/µs   G_io = {:.1} µs/blk   (fit r² = {:.4})",
            c.p, c.l_us, c.g_us_per_word, c.comps_per_us, c.g_io_us_per_block, c.fit_r2
        );
    }
    for r in &report.runs {
        println!(
            "{:<10} {:<6} {:<7} n={:<9} p={:<4} measured {:>9} s  predicted {:>9} s  ratio {:>5.2}  max/avg {:>7}/{:.0}",
            r.algo_label,
            r.bench,
            r.domain,
            r.n,
            r.p,
            fmt_secs(r.wall_us.mean / 1e6),
            fmt_secs(r.predicted_us / 1e6),
            r.ratio,
            r.balance.recv_max,
            r.balance.recv_mean,
        );
    }

    let (json_path, md_path) = report.write_files(Path::new(out_dir))?;
    let text = std::fs::read_to_string(&json_path)?;
    let doc = Json::parse(&text)?;
    tables::validate::validate_report(&doc)
        .map_err(|e| format!("written report failed schema validation: {e}"))?;
    println!(
        "wrote {} (schema-valid {}) and {}",
        json_path.display(),
        experiment::SCHEMA,
        md_path.display()
    );
    Ok(())
}

fn selftest() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Engine pool + DET sort through the service façade.
    let p = 4;
    let n = 1 << 14;
    let run = Sorter::global().run(SortJob::new(runner::AlgoVariant::Det, n).procs(p))?;
    assert_eq!(run.outputs.total_keys(), n);
    assert!(run.outputs.is_globally_sorted());
    println!(
        "engine pool + SORT_DET_BSP    ok ({} keys, {} supersteps)",
        n,
        run.ledger.supersteps.len()
    );

    // 2. Concurrent submissions share the persistent worker team.
    let handles: Vec<_> = (0..4)
        .map(|s| {
            Sorter::global()
                .submit(SortJob::new(runner::AlgoVariant::Ran, 1 << 12).procs(p).seed(s))
        })
        .collect::<Result<_, _>>()?;
    for h in handles {
        assert!(h.join()?.outputs.is_globally_sorted());
    }
    println!("concurrent job submission     ok (4 async jobs, p = {p})");

    // 3. PJRT runtime (skipped gracefully when artifacts are absent).
    match bsp_sort::runtime::Runtime::from_default_artifacts() {
        Ok(rt) => {
            let mut keys: Vec<i32> = (0..4096).rev().collect();
            let sorted = rt.sort(&keys)?;
            keys.sort_unstable();
            assert_eq!(sorted, keys);
            println!("PJRT local_sort artifact      ok (4096 keys via XLA)");
        }
        Err(e) => println!("PJRT runtime                  skipped ({e})"),
    }
    println!("selftest passed");
    Ok(())
}

const HELP: &str = r#"
bsp-sort — BSP sorting study (Gerbessiotis & Siniolakis) reproduction

USAGE:
  bsp-sort table <1..11> [--full] [--max-n K] [--max-p P] [--reps R]
  bsp-sort all-tables [--full]
  bsp-sort sort --algo det|iran|ran|bsi|det2|ran2|det-k|ran-k|
                       helman-det|helman-ran|psrs
                --bench U|G|B|<g>-G|S|DD|WR|Z[-t]|X|AS[-f]|R|8D
                --n 8388608 --p 64
                [--domain i32|u64|f64|record|str] [--jobs N]
                [--local-sort quicksort|lsd-radix|ips] [--no-dup]
                [--backend threaded|sim]
                [--groups K | --topology K1xK2x... | --levels auto]
                [--external --mem-budget M]
  bsp-sort experiment [--quick] [--algos det,ran,...] [--benches U,DD,...]
                      [--domains i32,u64,f64,record,str] [--ns N1,N2] [--ps P1,P2]
                      [--backends threaded,sim]
                      [--topologies default,auto,8x4x4]
                      [--local-sorts quicksort,lsd-radix,ips]
                      [--mem-budgets none,65536]
                      [--warmup W] [--reps R] [--seed S]
                      [--tag T] [--out DIR]
  bsp-sort predict | validate-g | ablate-dup
  bsp-sort selftest

Tables report *predicted Cray T3D seconds* from the BSP cost model
(p, L, g as measured in the paper); host wall-clock is reported by
`sort`.  Default grid caps n at 8M; --full runs the paper's full 64M.

Every sort is served by a persistent engine pool (sorter::Sorter):
worker threads stay parked between jobs and slot-matrix scratch is
reused, so repeat sorts skip thread spin-up.  `sort --jobs N` submits
N seed-varied copies concurrently through the pool's bounded queue
(admission control rejects beyond the queue depth with a structured
error) and reports jobs/sec; `--domain` picks the key domain per job.

--local-sort picks the per-processor base case every BSP variant falls
back to once keys are routed: quicksort ([.SQ]), LSD radix ([.SR]), or
ips ([.SI]) — the in-place block-partitioning MSD engine (sampling →
classification → block permutation → cleanup, see docs/ALGORITHMS.md).
`experiment --local-sorts a,b` sweeps the engines as a grid axis, and
`--seq quick|radix|ips` is kept as the historical single-engine alias.

`experiment` calibrates the host's (g, L) and operation rate from
micro-probes, runs the sweep cross-product with warmup + repetitions,
and writes BENCH_<tag>.json (schema bsp-sort/experiment-report/v5,
validated after writing) plus BENCH_<tag>.md.  --quick is the CI-sized
preset: det+ran+det2 on [U]+[DD], i32+u64, 16K keys, p in {4,8}, plus
one skew-generator cell (det @ [Z] @ p=8) and one sim-backend cell
(det @ p=256).

Benchmarks: the paper's §6.3 set (U uniform, G gaussian, <g>-G group
for any g >= 2, B bucket, S staggered, DD duplicates, WR worst-case
regular) plus the skew families Z[-theta100] zipf, X exponential,
AS[-pct] almost-sorted, R reverse, 8D eight-dup.  --domain str sorts
variable-length strings (8-byte prefix radix image, two wire words).

sort --external --mem-budget M runs the out-of-core EM-BSP sort: each
processor pulls its input through the selected local-sort engine in
chunks of at most M keys, spills every sorted run to a block store
(real temp files on the threaded backend, an in-memory mock on sim),
then a parallel multi-way merge reads the runs back, splits them on
sampled splitters and loser-tree-merges per processor.  The ledger
charges block I/O under the EM third parameter G_io (calibrated by the
experiment's I/O probe on hosts; the T3D constant on sim), so
predictions price L, g and G_io together.  `experiment --mem-budgets
none,65536` rides external cells along the sweep grid; budgets smaller
than n/p force spilling.

--backend sim (sort) / --backends sim (experiment) runs on the
deterministic simulator: the identical SPMD programs on single-process
virtual processors with virtual time — bit-for-bit replayable, p up to
1024 and beyond.  Sim cells are priced under the model machine itself
(no host calibration), so their reports are fully deterministic.

det2/ran2 are the two-level sorts: coarse splitters route key ranges to
processor groups, then the one-level algorithm runs group-locally over
a communicator (p = 8 splits 2x4).  det-k/ran-k generalize them to any
divisor tree p = k1 x k2 x ... x kd: pin the shape with --topology (or
--groups for depth 2), or let the cost-model planner choose it from the
calibrated (p, g, L) with --levels auto / --topologies auto — see
docs/ALGORITHMS.md and sort/plan.rs.
"#;
