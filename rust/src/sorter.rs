//! Sort-as-a-service: the unified [`SortJob`] builder and the [`Sorter`]
//! façade over the persistent engine pool (`bsp::service`).
//!
//! Callers used to reach three different entry points with overlapping
//! knobs — `BspMachine::run_keys`, `SimMachine`, and the experiment
//! runner.  This module is the single front door: describe *what* to
//! sort with a [`SortJob`] (key domain, algorithm variant, input
//! distribution, `n`, `p`, backend, optional machine parameters and
//! topology choice), and the service resolves *how* — which engine, how
//! many crews, which topology tree (the cost-model planner behind
//! [`TopologyChoice::Auto`]) — in the spirit of Axtmann–Sanders:
//! machine-parameter-driven configuration belongs to the system, not
//! the caller.
//!
//! Two submission styles:
//!
//! * [`Sorter::run`] — submit-and-join, blocking politely if the queue
//!   is momentarily full (the one-shot path);
//! * [`Sorter::submit`] — asynchronous, returning a [`SortHandle`]
//!   immediately; admission control rejects with
//!   [`RuntimeError::QueueFull`] beyond the configured depth.
//!
//! [`Sorter::global`] keeps one process-wide pool: engines are created
//! per processor count on first use and parked between jobs, so repeat
//! submissions skip thread spin-up and reuse slot-matrix scratch.  The
//! experiment runner (`experiment::run::execute_typed`) routes through
//! the same pool, so every table, sweep and CLI sort is served — not
//! spun up.  Jobs on a specific self-managed [`Engine`] go through
//! [`Engine::submit`].

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::bsp::group::Communicator;
use crate::bsp::params::{cray_t3d, BspParams};
use crate::bsp::service::{Engine, EngineConfig, EngineStats, JobHandle};
use crate::bsp::sim::{SimCommunicator, SimMachine};
use crate::bsp::{Backend, BspCtx, BspRun, Ledger, Topology};
use crate::experiment::run::{build_comms, run_cell, StudyKey};
use crate::experiment::spec::{AlgoVariant, KeyDomain, RunSpec, TopologyChoice};
use crate::gen::Benchmark;
use crate::key::{Record, Str, F64};
use crate::runtime::RuntimeError;
use crate::sort::common::ProcResult;
use crate::sort::{det, iran, multilevel, plan, SortConfig};

/// One sort request: everything the service needs to run and price a
/// sort, behind a builder.  Defaults match the experiment runner's
/// ([`AlgoVariant`] and `n` are the two mandatory choices): uniform
/// `i32` keys, `p = 8`, threaded backend, default config and seed.
#[derive(Clone, Copy, Debug)]
pub struct SortJob {
    algo: AlgoVariant,
    bench: Benchmark,
    domain: KeyDomain,
    n_total: usize,
    p: usize,
    cfg: SortConfig,
    seed: u64,
    backend: Backend,
    topology: TopologyChoice,
    params: Option<BspParams>,
}

impl SortJob {
    /// A job sorting `n_total` keys with `algo` under the defaults.
    pub fn new(algo: AlgoVariant, n_total: usize) -> SortJob {
        SortJob {
            algo,
            bench: Benchmark::Uniform,
            domain: KeyDomain::I32,
            n_total,
            p: 8,
            cfg: SortConfig::default(),
            seed: 0x0BEE,
            backend: Backend::Threaded,
            topology: TopologyChoice::Default,
            params: None,
        }
    }

    /// Key domain to sort (`i32` by default).
    pub fn domain(mut self, domain: KeyDomain) -> SortJob {
        self.domain = domain;
        self
    }

    /// Input distribution (§6.3 benchmark; uniform by default).
    pub fn bench(mut self, bench: Benchmark) -> SortJob {
        self.bench = bench;
        self
    }

    /// Processor count (`n_total` must divide evenly by it).
    pub fn procs(mut self, p: usize) -> SortJob {
        self.p = p;
        self
    }

    /// Variant knobs: sequential backend, duplicate policy, ω.
    pub fn config(mut self, cfg: SortConfig) -> SortJob {
        self.cfg = cfg;
        self
    }

    /// Local-sort engine for the per-processor base case (shorthand
    /// for `config(cfg.with_local_sort(engine))` keeping the other
    /// knobs).
    pub fn local_sort(mut self, engine: crate::sort::LocalSortEngine) -> SortJob {
        self.cfg = self.cfg.with_local_sort(engine);
        self
    }

    /// Seed for the randomized variants.
    pub fn seed(mut self, seed: u64) -> SortJob {
        self.seed = seed;
        self
    }

    /// Execution backend: threaded engine pool or the deterministic
    /// simulator (closure jobs on the pool's task engine).
    pub fn backend(mut self, backend: Backend) -> SortJob {
        self.backend = backend;
        self
    }

    /// Topology choice for the depth-k variants: the depth-2 heuristic
    /// (default), the cost-model planner ([`TopologyChoice::Auto`]), or
    /// a pinned shape.
    pub fn topology(mut self, choice: TopologyChoice) -> SortJob {
        self.topology = choice;
        self
    }

    /// Plan and price under explicit machine parameters instead of the
    /// paper's T3D preset for `p` (tenants submit jobs shaped for
    /// *their* machine; `params.p` must equal the job's `p`).
    pub fn params(mut self, params: BspParams) -> SortJob {
        self.params = Some(params);
        self
    }

    /// Admission-time validation — every failure is a structured
    /// [`RuntimeError::InvalidJob`], never a panic inside the pool.
    fn validate(&self) -> Result<(), RuntimeError> {
        if self.p == 0 {
            return Err(RuntimeError::InvalidJob("p must be at least 1".into()));
        }
        if self.n_total == 0 || self.n_total % self.p != 0 {
            return Err(RuntimeError::InvalidJob(format!(
                "n must be a positive multiple of p (paper setup): n={} p={}",
                self.n_total, self.p
            )));
        }
        if let TopologyChoice::Fixed(t) = self.topology {
            if t.nprocs() != self.p {
                return Err(RuntimeError::InvalidJob(format!(
                    "topology {} has {} processors, but the job runs p={}",
                    t.label(),
                    t.nprocs(),
                    self.p
                )));
            }
        }
        if let Some(params) = self.params {
            if params.p != self.p {
                return Err(RuntimeError::InvalidJob(format!(
                    "machine parameters are for p={}, but the job runs p={}",
                    params.p, self.p
                )));
            }
        }
        Ok(())
    }

    /// The machine parameters this job plans under.
    fn machine_params(&self) -> BspParams {
        self.params.unwrap_or_else(|| cray_t3d(self.p))
    }

    /// The topology tree this job will run over, resolved the way the
    /// sweep harness resolves its topology axis: fixed shapes pin
    /// verbatim; for the depth-k variants, `Default` pins the depth-2
    /// heuristic and `Auto` asks the cost-model planner under the job's
    /// machine parameters; other variants carry no pin (`None` — their
    /// communicators don't read one unless fixed explicitly).
    pub fn planned_topology(&self) -> Option<Topology> {
        let deep = matches!(self.algo, AlgoVariant::DetK | AlgoVariant::RanK);
        match self.topology {
            TopologyChoice::Fixed(t) => Some(t),
            TopologyChoice::Default if deep => Some(multilevel::default_topology(self.p)),
            TopologyChoice::Auto if deep => {
                let params = self.machine_params();
                let n = self.n_total;
                Some(match self.algo {
                    AlgoVariant::RanK => {
                        plan::plan_ran(n, &params, iran::omega_ran(&self.cfg, n)).topology
                    }
                    _ => plan::plan_det(n, &params, det::omega_det(&self.cfg, n)).topology,
                })
            }
            _ => None,
        }
    }

    /// Lower the job to the experiment runner's [`RunSpec`] vocabulary
    /// (the SPMD cell body is shared with sweeps and tables).
    fn to_spec(&self) -> RunSpec {
        let mut spec = RunSpec::new(self.algo, self.bench, self.p, self.n_total)
            .with_cfg(self.cfg)
            .with_backend(self.backend)
            .with_seed(self.seed);
        if let Some(params) = self.params {
            spec = spec.with_params(params);
        }
        if let Some(t) = self.planned_topology() {
            spec = spec.with_topology(t);
        }
        spec
    }
}

/// Per-processor outputs of a completed job, tagged by key domain.
#[derive(Debug)]
pub enum DomainOutputs {
    /// `i32` keys (the paper's experiments).
    I32(Vec<ProcResult<i32>>),
    /// `u64` keys.
    U64(Vec<ProcResult<u64>>),
    /// Total-ordered `f64` keys.
    F64T(Vec<ProcResult<F64>>),
    /// `(u32 key, u32 payload)` records.
    RecordU32(Vec<ProcResult<Record>>),
    /// Fixed-capacity inline strings (`key::Str`).
    Str(Vec<ProcResult<Str>>),
}

fn globally_sorted<K: crate::key::Key>(outs: &[ProcResult<K>]) -> bool {
    let mut last: Option<K> = None;
    for r in outs {
        for &k in &r.keys {
            if let Some(prev) = last {
                if prev > k {
                    return false;
                }
            }
            last = Some(k);
        }
    }
    true
}

impl DomainOutputs {
    /// Which key domain the job ran over.
    pub fn domain(&self) -> KeyDomain {
        match self {
            DomainOutputs::I32(_) => KeyDomain::I32,
            DomainOutputs::U64(_) => KeyDomain::U64,
            DomainOutputs::F64T(_) => KeyDomain::F64T,
            DomainOutputs::RecordU32(_) => KeyDomain::RecordU32,
            DomainOutputs::Str(_) => KeyDomain::Str,
        }
    }

    /// Number of processors that reported output.
    pub fn procs(&self) -> usize {
        match self {
            DomainOutputs::I32(o) => o.len(),
            DomainOutputs::U64(o) => o.len(),
            DomainOutputs::F64T(o) => o.len(),
            DomainOutputs::RecordU32(o) => o.len(),
            DomainOutputs::Str(o) => o.len(),
        }
    }

    /// Total keys across all processors.
    pub fn total_keys(&self) -> usize {
        match self {
            DomainOutputs::I32(o) => o.iter().map(|r| r.keys.len()).sum(),
            DomainOutputs::U64(o) => o.iter().map(|r| r.keys.len()).sum(),
            DomainOutputs::F64T(o) => o.iter().map(|r| r.keys.len()).sum(),
            DomainOutputs::RecordU32(o) => o.iter().map(|r| r.keys.len()).sum(),
            DomainOutputs::Str(o) => o.iter().map(|r| r.keys.len()).sum(),
        }
    }

    /// True when the concatenation over processors (in pid order) is
    /// non-decreasing.
    pub fn is_globally_sorted(&self) -> bool {
        match self {
            DomainOutputs::I32(o) => globally_sorted(o),
            DomainOutputs::U64(o) => globally_sorted(o),
            DomainOutputs::F64T(o) => globally_sorted(o),
            DomainOutputs::RecordU32(o) => globally_sorted(o),
            DomainOutputs::Str(o) => globally_sorted(o),
        }
    }
}

/// A completed sort: domain-tagged per-processor outputs plus the job's
/// own cost [`Ledger`] (per-job accounting survives pooling — charges
/// are data-dependent, so the ledger matches a one-shot run of the same
/// spec bit for bit, modulo wall-clock).
#[derive(Debug)]
pub struct SortRun {
    /// Per-processor outputs in pid order.
    pub outputs: DomainOutputs,
    /// The job's superstep/phase cost ledger.
    pub ledger: Ledger,
}

/// Handle to an in-flight [`SortJob`] — the domain-erased counterpart
/// of [`JobHandle`].
#[derive(Debug)]
pub enum SortHandle {
    /// Handle for an `i32` job.
    I32(JobHandle<ProcResult<i32>>),
    /// Handle for a `u64` job.
    U64(JobHandle<ProcResult<u64>>),
    /// Handle for an `F64` job.
    F64T(JobHandle<ProcResult<F64>>),
    /// Handle for a record job.
    RecordU32(JobHandle<ProcResult<Record>>),
    /// Handle for a fixed-capacity string job.
    Str(JobHandle<ProcResult<Str>>),
}

impl SortHandle {
    /// Block until the job completes; its outputs and per-job ledger,
    /// or the structured [`RuntimeError`] that ended it.
    pub fn join(self) -> Result<SortRun, RuntimeError> {
        fn pack<K>(run: BspRun<ProcResult<K>>, wrap: fn(Vec<ProcResult<K>>) -> DomainOutputs) -> SortRun {
            SortRun { outputs: wrap(run.outputs), ledger: run.ledger }
        }
        match self {
            SortHandle::I32(h) => h.join().map(|r| pack(r, DomainOutputs::I32)),
            SortHandle::U64(h) => h.join().map(|r| pack(r, DomainOutputs::U64)),
            SortHandle::F64T(h) => h.join().map(|r| pack(r, DomainOutputs::F64T)),
            SortHandle::RecordU32(h) => h.join().map(|r| pack(r, DomainOutputs::RecordU32)),
            SortHandle::Str(h) => h.join().map(|r| pack(r, DomainOutputs::Str)),
        }
    }

    /// True once the job has completed: `join` will not block.
    pub fn is_done(&self) -> bool {
        match self {
            SortHandle::I32(h) => h.is_done(),
            SortHandle::U64(h) => h.is_done(),
            SortHandle::F64T(h) => h.is_done(),
            SortHandle::RecordU32(h) => h.is_done(),
            SortHandle::Str(h) => h.is_done(),
        }
    }
}

/// Submit one lowered spec to a specific engine: threaded specs as SPMD
/// jobs (the cell body shared with the experiment runner), simulator
/// specs as closure jobs running the whole `SimMachine` on one lane.
fn submit_spec_on<K: StudyKey>(
    engine: &Engine,
    spec: RunSpec,
    block: bool,
) -> Result<JobHandle<ProcResult<K>>, RuntimeError> {
    match spec.backend {
        Backend::Threaded => {
            let comms = build_comms::<Communicator>(&spec);
            let program = move |ctx: &mut BspCtx<K>| run_cell(ctx, &comms, &spec);
            if block {
                engine.submit_program_blocking::<K, _, _>(spec.n_total, program)
            } else {
                engine.submit_program::<K, _, _>(spec.n_total, program)
            }
        }
        Backend::Sim => engine.submit_task(
            move || {
                let machine = SimMachine::new(spec.params());
                let comms = build_comms::<SimCommunicator>(&spec);
                machine.run_keys::<K, _, _>(|ctx| run_cell(ctx, &comms, &spec))
            },
            block,
        ),
    }
}

/// Dispatch a validated job to its key domain's typed submission.
fn submit_domain(
    engine: &Engine,
    domain: KeyDomain,
    spec: RunSpec,
    block: bool,
) -> Result<SortHandle, RuntimeError> {
    Ok(match domain {
        KeyDomain::I32 => SortHandle::I32(submit_spec_on::<i32>(engine, spec, block)?),
        KeyDomain::U64 => SortHandle::U64(submit_spec_on::<u64>(engine, spec, block)?),
        KeyDomain::F64T => SortHandle::F64T(submit_spec_on::<F64>(engine, spec, block)?),
        KeyDomain::RecordU32 => {
            SortHandle::RecordU32(submit_spec_on::<Record>(engine, spec, block)?)
        }
        KeyDomain::Str => SortHandle::Str(submit_spec_on::<Str>(engine, spec, block)?),
    })
}

impl Engine {
    /// Submit a [`SortJob`] to *this* engine (asynchronous admission:
    /// beyond the queue depth the job is rejected with
    /// [`RuntimeError::QueueFull`]).  Threaded jobs must match the
    /// engine's processor count; the [`Sorter`] façade picks a matching
    /// engine automatically.
    pub fn submit(&self, job: SortJob) -> Result<SortHandle, RuntimeError> {
        job.validate()?;
        if job.backend == Backend::Threaded && job.p != self.params().p {
            return Err(RuntimeError::InvalidJob(format!(
                "job wants p={} but this engine runs p={} (use Sorter for \
                 automatic engine selection)",
                job.p,
                self.params().p
            )));
        }
        submit_domain(self, job.domain, job.to_spec(), false)
    }
}

/// The service façade: a pool of persistent [`Engine`]s keyed by
/// processor count (created on first use, threads parked between jobs)
/// plus one task engine for simulator jobs.  Cheap to share —
/// [`Sorter::global`] is the process-wide instance everything routes
/// through; separate instances give tests and tenants isolated pools.
pub struct Sorter {
    engines: Mutex<HashMap<usize, Arc<Engine>>>,
    tasks: OnceLock<Arc<Engine>>,
}

impl Sorter {
    /// An empty pool; engines materialize per `p` on first submission.
    pub fn new() -> Sorter {
        Sorter { engines: Mutex::new(HashMap::new()), tasks: OnceLock::new() }
    }

    /// The process-wide pool (the experiment runner, tables and CLI all
    /// route through it).  Its engines live until process exit.
    pub fn global() -> &'static Sorter {
        static GLOBAL: OnceLock<Sorter> = OnceLock::new();
        GLOBAL.get_or_init(Sorter::new)
    }

    /// The pool's engine for `p`-processor jobs.  Crew policy: about 32
    /// worker threads per engine (`32/p`, clamped to 1..=4 crews), so
    /// small-`p` engines serve several tenants concurrently while
    /// large-`p` engines don't oversubscribe the host.
    fn engine_for(&self, p: usize) -> Arc<Engine> {
        let mut engines = self.engines.lock().unwrap();
        Arc::clone(engines.entry(p).or_insert_with(|| {
            let crews = (32 / p.max(1)).clamp(1, 4);
            Arc::new(Engine::new(EngineConfig::new(cray_t3d(p)).with_crews(crews)))
        }))
    }

    /// The single-lane-per-crew engine that runs simulator jobs (each
    /// `SimMachine` occupies one lane regardless of its virtual `p`).
    fn task_engine(&self) -> Arc<Engine> {
        Arc::clone(self.tasks.get_or_init(|| {
            let crews = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            Arc::new(Engine::new(EngineConfig::new(cray_t3d(1)).with_crews(crews.max(4))))
        }))
    }

    /// Submit asynchronously; [`RuntimeError::QueueFull`] beyond the
    /// target engine's queue depth, [`RuntimeError::InvalidJob`] on a
    /// malformed job.
    pub fn submit(&self, job: SortJob) -> Result<SortHandle, RuntimeError> {
        job.validate()?;
        let engine = match job.backend {
            Backend::Threaded => self.engine_for(job.p),
            Backend::Sim => self.task_engine(),
        };
        submit_domain(&engine, job.domain, job.to_spec(), false)
    }

    /// Submit-and-join (the one-shot path): waits for queue room
    /// instead of rejecting, then blocks until the job completes.
    pub fn run(&self, job: SortJob) -> Result<SortRun, RuntimeError> {
        job.validate()?;
        let engine = match job.backend {
            Backend::Threaded => self.engine_for(job.p),
            Backend::Sim => self.task_engine(),
        };
        submit_domain(&engine, job.domain, job.to_spec(), true)?.join()
    }

    /// Typed submit-and-join used by the experiment runner
    /// (`execute_typed`): same pool, same engines, but the key domain
    /// is a compile-time parameter rather than a [`KeyDomain`] tag.
    pub(crate) fn run_spec<K: StudyKey>(
        &self,
        spec: &RunSpec,
    ) -> Result<BspRun<ProcResult<K>>, RuntimeError> {
        let engine = match spec.backend {
            Backend::Threaded => self.engine_for(spec.p),
            Backend::Sim => self.task_engine(),
        };
        submit_spec_on::<K>(&engine, *spec, true)?.join()
    }

    /// The pooled SPMD engine for `p`-processor jobs, for subsystems
    /// (the out-of-core driver) that submit whole BSP programs rather
    /// than [`SortJob`]s.  Callers must submit with `n_hint =
    /// usize::MAX` so the service never batches a spilling job.
    pub(crate) fn spmd_engine(&self, p: usize) -> Arc<Engine> {
        self.engine_for(p)
    }

    /// The pooled closure-task engine (one lane per task), for
    /// subsystems that run simulator machines or other opaque closures.
    pub(crate) fn closure_engine(&self) -> Arc<Engine> {
        self.task_engine()
    }

    /// Scheduling counters of the `p`-processor engine (`None` until a
    /// first job materializes it).
    pub fn engine_stats(&self, p: usize) -> Option<EngineStats> {
        self.engines.lock().unwrap().get(&p).map(|e| e.stats())
    }

    /// Shut down every engine in the pool: queued jobs fail with
    /// [`RuntimeError::EngineShutdown`], worker threads exit.  The
    /// global pool is never shut down; call this on owned pools.
    pub fn shutdown(&self) {
        for engine in self.engines.lock().unwrap().values() {
            engine.shutdown();
        }
        if let Some(tasks) = self.tasks.get() {
            tasks.shutdown();
        }
    }
}

impl Default for Sorter {
    fn default() -> Sorter {
        Sorter::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validation_is_structured() {
        // Uneven n.
        let err = Sorter::global().submit(SortJob::new(AlgoVariant::Det, 1000).procs(3));
        match err {
            Err(RuntimeError::InvalidJob(msg)) => {
                assert!(msg.contains("n=1000") && msg.contains("p=3"), "{msg}");
            }
            other => panic!("expected InvalidJob, got {other:?}"),
        }
        // Zero processors.
        assert!(matches!(
            Sorter::global().submit(SortJob::new(AlgoVariant::Det, 1024).procs(0)),
            Err(RuntimeError::InvalidJob(_))
        ));
        // Pinned topology with the wrong processor product.
        let job = SortJob::new(AlgoVariant::DetK, 1024)
            .procs(4)
            .topology(TopologyChoice::Fixed(Topology::new(&[2, 4])));
        assert!(matches!(Sorter::global().submit(job), Err(RuntimeError::InvalidJob(_))));
        // Machine parameters for a different width.
        let job = SortJob::new(AlgoVariant::Det, 1024).procs(4).params(cray_t3d(8));
        assert!(matches!(Sorter::global().submit(job), Err(RuntimeError::InvalidJob(_))));
    }

    #[test]
    fn run_sorts_every_domain_through_the_pool() {
        for domain in crate::experiment::spec::ALL_DOMAINS {
            let job = SortJob::new(AlgoVariant::Det, 2048).procs(4).domain(domain);
            let run = Sorter::global().run(job).expect("pool admits a blocking job");
            assert_eq!(run.outputs.domain(), domain);
            assert_eq!(run.outputs.procs(), 4);
            assert_eq!(run.outputs.total_keys(), 2048);
            assert!(run.outputs.is_globally_sorted(), "{domain:?} output unsorted");
            assert!(run.ledger.wall_us >= 0.0);
        }
    }

    #[test]
    fn submit_is_asynchronous_and_joinable() {
        let job = SortJob::new(AlgoVariant::Ran, 2048).procs(4).seed(42);
        let handle = Sorter::global().submit(job).expect("queue has room");
        let run = handle.join().expect("job completes");
        assert!(run.outputs.is_globally_sorted());
        assert_eq!(run.outputs.total_keys(), 2048);
    }

    #[test]
    fn sim_jobs_ride_the_task_engine() {
        // Virtual p far beyond sensible thread counts: one lane, one
        // SimMachine, same façade.
        let job = SortJob::new(AlgoVariant::Det, 1 << 12)
            .procs(64)
            .backend(Backend::Sim)
            .domain(KeyDomain::U64);
        let run = Sorter::global().run(job).expect("task engine admits");
        assert_eq!(run.outputs.procs(), 64);
        assert!(run.outputs.is_globally_sorted());
    }

    #[test]
    fn auto_topology_plans_a_deep_sort() {
        let job = SortJob::new(AlgoVariant::DetK, 1 << 12)
            .procs(8)
            .topology(TopologyChoice::Auto);
        let run = Sorter::global().run(job).expect("planned job runs");
        assert!(run.outputs.is_globally_sorted());
        assert_eq!(run.outputs.total_keys(), 1 << 12);
    }

    #[test]
    fn engine_submit_checks_the_width() {
        let engine = Engine::new(EngineConfig::new(cray_t3d(4)));
        let err = engine.submit(SortJob::new(AlgoVariant::Det, 1024).procs(8));
        match err {
            Err(RuntimeError::InvalidJob(msg)) => {
                assert!(msg.contains("p=8") && msg.contains("p=4"), "{msg}");
            }
            other => panic!("expected InvalidJob, got {other:?}"),
        }
        let run = engine
            .submit(SortJob::new(AlgoVariant::Det, 1024).procs(4))
            .expect("matching width admits")
            .join()
            .expect("job completes");
        assert!(run.outputs.is_globally_sorted());
        engine.shutdown();
    }

    #[test]
    fn the_global_pool_reuses_engines_across_jobs() {
        let before = Sorter::global().engine_stats(4).map(|s| s.completed).unwrap_or(0);
        for seed in 0..3 {
            let job = SortJob::new(AlgoVariant::Iran, 2048).procs(4).seed(seed);
            assert!(Sorter::global().run(job).is_ok());
        }
        let after = Sorter::global()
            .engine_stats(4)
            .expect("engine for p=4 exists")
            .completed;
        assert!(after >= before + 3, "before={before} after={after}");
    }

    #[test]
    fn owned_pools_shut_down_cleanly() {
        let pool = Sorter::new();
        let run = pool
            .run(SortJob::new(AlgoVariant::Det, 1024).procs(4))
            .expect("fresh pool serves a job");
        assert!(run.outputs.is_globally_sorted());
        pool.shutdown();
        assert!(matches!(
            pool.run(SortJob::new(AlgoVariant::Det, 1024).procs(4)),
            Err(RuntimeError::EngineShutdown)
        ));
    }
}
