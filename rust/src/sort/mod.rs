//! The paper's sorting algorithms (§5) and their configuration.
//!
//! * [`det`] — SORT_DET_BSP: deterministic regular oversampling (Fig. 1),
//! * [`det_iterative`] — the multi-round general algorithm of [28] (§5.1),
//! * [`iran`] — SORT_IRAN_BSP: the improved randomized algorithm (Fig. 3),
//! * [`ran`] — SORT_RAN_BSP: classic randomized sample-sort (Fig. 2),
//! * [`bsi`] — full Batcher bitonic sort (\[BSI\], §6.2 item 3),
//! * [`common`] — the shared sample-sort/partition/route/merge pipeline
//!   and the §5.1.1 tagged sampling,
//! * [`config`] — variant knobs (\[DSQ\]/\[DSR\]/\[RSQ\]/\[RSR\], duplicate
//!   policy ablation, ω overrides, sample-sort method).

pub mod bsi;
pub mod common;
pub mod det_iterative;
pub mod config;
pub mod det;
pub mod iran;
pub mod ran;

pub use common::ProcResult;
pub use config::{DuplicatePolicy, Oversampling, SampleSortMethod, SortConfig};

/// Which top-level algorithm to run (CLI / tables dispatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// SORT_DET_BSP.
    Det,
    /// SORT_IRAN_BSP.
    Iran,
    /// SORT_RAN_BSP (baseline).
    Ran,
    /// Full bitonic sort \[BSI\] (baseline).
    Bsi,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "det" | "sort_det_bsp" | "d" => Some(Algorithm::Det),
            "iran" | "sort_iran_bsp" | "r" => Some(Algorithm::Iran),
            "ran" | "sort_ran_bsp" => Some(Algorithm::Ran),
            "bsi" | "bitonic" => Some(Algorithm::Bsi),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Det => "SORT_DET_BSP",
            Algorithm::Iran => "SORT_IRAN_BSP",
            Algorithm::Ran => "SORT_RAN_BSP",
            Algorithm::Bsi => "BSI",
        }
    }
}
