//! The paper's sorting algorithms (§5) and their configuration.
//!
//! * [`det`] — SORT_DET_BSP: deterministic regular oversampling (Fig. 1),
//! * [`det_iterative`] — the multi-round general algorithm of [28] (§5.1),
//! * [`iran`] — SORT_IRAN_BSP: the improved randomized algorithm (Fig. 3),
//! * [`ran`] — SORT_RAN_BSP: classic randomized sample-sort (Fig. 2),
//! * [`bsi`] — full Batcher bitonic sort (\[BSI\], §6.2 item 3),
//! * [`multilevel`] — depth-k det/ran sample sorts over nested
//!   processor groups (coarse splitters route key ranges down a
//!   topology tree `p = k1 × … × kd`; the one-level algorithms then run
//!   inside the leaf machines through a
//!   [`Communicator`](crate::bsp::group::Communicator) refinement
//!   chain; det2/ran2 are the depth-2 special case),
//! * [`plan`] — the cost-model-driven topology planner: enumerate
//!   divisor trees of `p`, price each with the per-level closed forms,
//!   return the argmin for a calibrated `(p, g, L)`,
//! * [`common`] — the shared sample-sort/partition/route/merge pipeline
//!   and the §5.1.1 tagged sampling,
//! * [`config`] — variant knobs (\[DSQ\]/\[DSR\]/\[RSQ\]/\[RSR\], duplicate
//!   policy ablation, ω overrides, sample-sort method).
//!
//! Every algorithm is generic over the
//! [`BspScope`](crate::bsp::BspScope), so the same program text runs on
//! the whole machine or against one processor group of a split machine.
//! A two-level run through a 2×4 communicator:
//!
//! ```
//! use bsp_sort::bsp::{cray_t3d, BspMachine, Communicator};
//! use bsp_sort::gen::{generate_for_proc, Benchmark};
//! use bsp_sort::sort::{multilevel, SortConfig};
//!
//! let p = 8;
//! let n = 1 << 12;
//! let params = cray_t3d(p);
//! let machine = BspMachine::new(params);
//! let comm = Communicator::split_even(p, 2); // two groups of four
//! let cfg = SortConfig::default();
//! let run = machine.run(|ctx| {
//!     let keys = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
//!     multilevel::sort_multilevel_det(ctx, &comm, &params, keys, n, &cfg).keys
//! });
//! let sorted: Vec<i32> = run.outputs.concat();
//! assert_eq!(sorted.len(), n);
//! assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
//! // Level-2 phases are group-scoped in the ledger: half the input
//! // routed per group, priced with the 4-processor sub-machine.
//! assert!(run.ledger.phases.contains_key("L2/Ph5:Routing"));
//! ```

#![warn(missing_docs)]

pub mod bsi;
pub mod common;
pub mod det_iterative;
pub mod config;
pub mod det;
pub mod iran;
pub mod multilevel;
pub mod plan;
pub mod ran;

pub use common::ProcResult;
pub use config::{
    Backend, DuplicatePolicy, LocalSortEngine, Oversampling, SampleSortMethod, SortConfig,
    ALL_ENGINES,
};

/// Which top-level algorithm to run (CLI / tables dispatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// SORT_DET_BSP.
    Det,
    /// SORT_IRAN_BSP.
    Iran,
    /// SORT_RAN_BSP (baseline).
    Ran,
    /// Full bitonic sort \[BSI\] (baseline).
    Bsi,
}

impl Algorithm {
    /// Parse a CLI tag (`det`, `iran`, `ran`, `bsi` and their aliases).
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "det" | "sort_det_bsp" | "d" => Some(Algorithm::Det),
            "iran" | "sort_iran_bsp" | "r" => Some(Algorithm::Iran),
            "ran" | "sort_ran_bsp" => Some(Algorithm::Ran),
            "bsi" | "bitonic" => Some(Algorithm::Bsi),
            _ => None,
        }
    }

    /// The paper's name for the algorithm.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Det => "SORT_DET_BSP",
            Algorithm::Iran => "SORT_IRAN_BSP",
            Algorithm::Ran => "SORT_RAN_BSP",
            Algorithm::Bsi => "BSI",
        }
    }
}
