//! \[BSI\]: full Batcher bitonic sort of the input (§6.2 item 3).
//!
//! Local sort, then `lg p (lg p + 1)/2` merge-split rounds.  The paper
//! uses it for parallel sample sorting and notes its end-to-end
//! performance is worse than the sample-based sorts "in all but very
//! small problem and processor sizes (for such cases, Batcher's
//! algorithm is faster because of its low overhead)" — the crossover our
//! ablation bench (benches/ablation.rs) reproduces.

use crate::bsp::engine::BspScope;
use crate::key::{Key, RadixKey};
use crate::primitives::bitonic::{self, BitonicItem};
use crate::seq::{IpsSorter, QuickSorter, RadixSorter, SeqSortKind, SeqSorter};

use super::common::{ProcResult, PH2, PH5};
use super::config::SortConfig;

/// Run the full bitonic sort; every processor ends with its chunk of the
/// global order.  Requires equal local sizes and `p` a power of two.
/// The domain's bare keys must ride the payload (`K: BitonicItem<K>` —
/// provided for every built-in domain).
pub fn sort_bsi<K, S>(ctx: &mut S, mut local: Vec<K>, cfg: &SortConfig) -> ProcResult<K>
where
    K: RadixKey + BitonicItem<K>,
    S: BspScope<K>,
{
    let sorter: &dyn SeqSorter<K> = match cfg.seq {
        SeqSortKind::Quick => &QuickSorter,
        SeqSortKind::Radix => &RadixSorter,
        SeqSortKind::Ips => &IpsSorter,
        SeqSortKind::Xla => panic!("use sort_bsi_with for a custom backend"),
    };
    sort_bsi_with(ctx, &mut local, cfg, sorter)
}

/// As [`sort_bsi`] with an explicit sequential backend.
pub fn sort_bsi_with<K, S>(
    ctx: &mut S,
    local: &mut Vec<K>,
    _cfg: &SortConfig,
    sorter: &dyn SeqSorter<K>,
) -> ProcResult<K>
where
    K: Key + BitonicItem<K>,
    S: BspScope<K>,
{
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    let mut keys = std::mem::take(local);
    sorter.sort(&mut keys);

    ctx.phase(PH5);
    let n_local = keys.len();
    let out = bitonic::bitonic_sort(ctx, keys, "bsi");

    ProcResult {
        received: n_local, // every round exchanges the full run
        runs: ctx.nprocs(),
        keys: out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    #[test]
    fn bsi_sorts_every_benchmark() {
        for bench in ALL_BENCHMARKS {
            let p = 4usize;
            let n = 1 << 12;
            let params = cray_t3d(p);
            let machine = BspMachine::new(params);
            let cfg = SortConfig::default();
            let run = machine.run(|ctx| {
                let local = generate_for_proc(bench, ctx.pid(), p, n / p);
                let input = local.clone();
                (input, sort_bsi(ctx, local, &cfg))
            });
            let mut expect: Vec<i32> =
                run.outputs.iter().flat_map(|(i, _)| i.clone()).collect();
            expect.sort_unstable();
            let got: Vec<i32> = run.outputs.iter().flat_map(|(_, r)| r.keys.clone()).collect();
            assert_eq!(got, expect, "{}", bench.tag());
        }
    }

    #[test]
    fn bsi_superstep_count_is_quadratic_in_lgp() {
        let p = 8usize;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let run = machine.run(|ctx| {
            let local: Vec<i32> = (0..32).map(|i| (i * 7 + ctx.pid()) as i32 % 64).collect();
            let mut sorted = local;
            sorted.sort_unstable();
            sort_bsi(ctx, sorted, &SortConfig::default())
        });
        // 6 merge-split supersteps for p=8 (+1 final Ph-less sync none).
        let exchanges = run
            .ledger
            .supersteps
            .iter()
            .filter(|s| s.label.starts_with("bsi"))
            .count();
        assert_eq!(exchanges, bitonic::superstep_count(p));
    }
}
