//! SORT_IRAN_BSP (Figure 3): the improved randomized BSP sorting
//! algorithm of the paper's implementations.
//!
//! Unlike traditional sample-sort (SORT_RAN_BSP, Figure 2) it follows the
//! *deterministic* algorithm's pattern — local sort first, then sample /
//! splitter-select, one round of coarse-grained routing of contiguous
//! slices, and a final stable p-way merge — which removes the expensive
//! `D·n/p` integer-sort set formation of step 9 of SORT_RAN_BSP and makes
//! the communication a single balanced h-relation (§5.2).
//!
//! The oversampling factor is `s = 2·ω_n²·lg n` with `ω_n² = lg n` in the
//! experiments (§6.1); randomized oversampling admits a wider ω range
//! than deterministic regular oversampling, which is why the randomized
//! variant balances better at p = 128 (Tables 3–7).

use crate::bsp::engine::BspScope;
use crate::bsp::msg::SampleRec;
use crate::bsp::params::BspParams;
use crate::key::{Key, RadixKey};
use crate::seq::{IpsSorter, QuickSorter, RadixSorter, SeqSortKind, SeqSorter};
use crate::util::rng::SplitMix64;

use super::common::{self, ProcResult, PH2, PH3};
use super::config::{Oversampling, SortConfig};

/// ω_n for the randomized algorithm: experiments use ω² = lg n (§6.1).
pub fn omega_ran(cfg: &SortConfig, n_total: usize) -> f64 {
    cfg.oversampling.unwrap_or(Oversampling::RanDefault).omega(n_total)
}

/// Per-processor share of the global sample.  §6.1: "Total sample size
/// over all the processors ... for the randomized algorithm it is
/// `2pω_n²lg n`" — i.e. the oversampling factor `s = 2ω²lg n` keys on
/// *each* processor (global sample `s·p − 1`; we keep `s·p`).
pub fn sample_share(n_total: usize, _p: usize, omega: f64) -> usize {
    let lgn = crate::util::lg(n_total as f64).max(1.0);
    ((2.0 * omega * omega * lgn).ceil() as usize).max(1)
}

/// Claim 5.1 style high-probability bound on received keys:
/// `(1 + 1/ω)·n/p`.
pub fn nmax_bound(n_total: usize, p: usize, omega: f64) -> f64 {
    (1.0 + 1.0 / omega.max(1.0)) * (n_total as f64 / p as f64)
}

/// Run SORT_IRAN_BSP on this processor's share of the input.
///
/// `seed` decorrelates the random sample across runs (the experiments
/// average over ≥ 4 runs); the per-processor stream is derived from it.
pub fn sort_iran_bsp<K: RadixKey, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    mut local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
) -> ProcResult<K> {
    let sorter: &dyn SeqSorter<K> = match cfg.seq {
        SeqSortKind::Quick => &QuickSorter,
        SeqSortKind::Radix => &RadixSorter,
        SeqSortKind::Ips => &IpsSorter,
        SeqSortKind::Xla => panic!("use sort_iran_bsp_with for a custom backend"),
    };
    sort_iran_bsp_with(ctx, params, &mut local, n_total, cfg, seed, sorter)
}

/// As [`sort_iran_bsp`] with an explicit sequential backend.
pub fn sort_iran_bsp_with<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    local: &mut Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
    sorter: &dyn SeqSorter<K>,
) -> ProcResult<K> {
    let p = ctx.nprocs();

    // --- Ph2: local sort (BEFORE sampling — the IRAN signature) --------
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    let mut keys = std::mem::take(local);
    sorter.sort(&mut keys);

    // --- Ph3: random sample + parallel sample sort ----------------------
    ctx.phase(PH3);
    let omega = omega_ran(cfg, n_total);
    let share = sample_share(n_total, p, omega).min(keys.len().max(1));
    let mut rng = SplitMix64::new(seed ^ ((ctx.pid() as u64) << 24).wrapping_add(0xA5A5));
    let mut picks = if keys.is_empty() {
        Vec::new()
    } else {
        rng.sample_indices(keys.len(), share)
    };
    picks.sort_unstable();
    // Tagged records: (key, pid, sorted-array index) — §5.1.1 tags are
    // *already sorted-order consistent* because keys is sorted and picks
    // ascend, so the sample run is sorted under the tagged order.
    let sample: Vec<SampleRec<K>> = if picks.is_empty() {
        vec![SampleRec::new(K::max_key(), ctx.pid(), 0)]
    } else {
        picks.iter().map(|&i| SampleRec::new(keys[i], ctx.pid(), i)).collect()
    };
    ctx.charge(share as f64);
    let splitters =
        common::sample_sort_and_splitters(ctx, params, sample, cfg.sample_sort, "ph3");

    // --- Ph4..Ph7: shared pipeline --------------------------------------
    common::partition_route_merge(ctx, keys, &splitters, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn run_iran(
        p: usize,
        n_total: usize,
        bench: Benchmark,
        cfg: SortConfig,
        seed: u64,
    ) -> (Vec<Vec<i32>>, Vec<ProcResult>) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n_total / p);
            let input = local.clone();
            let out = sort_iran_bsp(ctx, &params, local, n_total, &cfg, seed);
            (input, out)
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results)
    }

    fn assert_sorted_permutation(inputs: &[Vec<i32>], results: &[ProcResult]) {
        let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn sorts_every_benchmark() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results) = run_iran(4, 1 << 12, bench, SortConfig::default(), 42);
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn sorts_various_p_and_seeds() {
        for (p, seed) in [(1usize, 1u64), (2, 2), (8, 3), (4, 0xDEAD)] {
            let (inputs, results) =
                run_iran(p, 1 << 13, Benchmark::Uniform, SortConfig::default(), seed);
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn imbalance_within_claim_bound_whp() {
        // Statistical test with fixed seeds: the (1 + 1/ω) bound of
        // Claim 5.1 should hold with slack across all benchmarks.
        for bench in ALL_BENCHMARKS {
            let p = 8usize;
            let n = 1 << 14;
            let cfg = SortConfig::default();
            let (_, results) = run_iran(p, n, bench, cfg, 7);
            let omega = omega_ran(&cfg, n);
            // ω·p floor gives head-room at these small test sizes (the
            // tagged all-equal case concentrates sampling noise).
            let bound = nmax_bound(n, p, omega) + (omega * p as f64);
            for (pid, r) in results.iter().enumerate() {
                assert!(
                    (r.received as f64) <= bound,
                    "{} pid={pid}: received {} > bound {bound}",
                    bench.tag(),
                    r.received
                );
            }
        }
    }

    #[test]
    fn all_equal_keys_stay_balanced() {
        let p = 8usize;
        let n = 1 << 13;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = vec![-3i32; n / p];
            sort_iran_bsp(ctx, &params, local, n, &cfg, 9)
        });
        let omega = omega_ran(&cfg, n);
        let bound = nmax_bound(n, p, omega) + omega * p as f64;
        for r in &run.outputs {
            assert!(r.received as f64 <= bound, "received={} bound={bound}", r.received);
            assert!(r.received > 0);
        }
    }

    #[test]
    fn radix_variant_sorts() {
        let cfg = SortConfig::default().with_seq(SeqSortKind::Radix);
        let (inputs, results) = run_iran(8, 1 << 13, Benchmark::DetDup, cfg, 5);
        assert_sorted_permutation(&inputs, &results);
    }

    #[test]
    fn different_seeds_both_sort() {
        for seed in [0u64, 1, 99, u64::MAX] {
            let (inputs, results) =
                run_iran(4, 1 << 10, Benchmark::WorstRegular, SortConfig::default(), seed);
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn sample_share_matches_paper_formula() {
        // n = 8M: lg n = 23, ω² = 23, total sample 2·23·23 = 1058.
        let n = 1 << 23;
        let omega = omega_ran(&SortConfig::default(), n);
        let per_proc = sample_share(n, 64, omega);
        assert_eq!(per_proc, (2.0 * omega * omega * 23.0).ceil() as usize);
        assert_eq!(per_proc, 1058);
    }
}
