//! The iterative (multi-round) SORT_DET_BSP of §5.1 / [28].
//!
//! The one-round algorithm (det.rs) needs `p² ω² ≤ n/lg n`; the general
//! algorithm of [28] runs `m = ⌈lg n / lg(n/p)⌉`-style *rounds*, each
//! partitioning the current key ranges into `k ≈ p^(1/m)` buckets, so
//! each round's sample is only `⌈ω⌉·k` per processor and the processor
//! range extends much closer to `n` (matching the Ω(lg n / lg(n/p))
//! round lower bound of [36]).
//!
//! This module implements the two-round case (`k = √p̃` buckets per
//! round), which is what the paper says suffices "in some extreme cases
//! at most 2" for all practical configurations:
//!
//!   round 1: local sort → global sample (k₁−1 splitters) → route bucket
//!            b to processor group b → group-local merge;
//!   round 2: within each group of p/k₁ processors — group sample,
//!            splitters selected at the group leader (the paper's point
//!            that primitive *shape* is chosen per (n, p, L, g); a
//!            group-local gather+broadcast costs 2 supersteps), route
//!            within the group, final merge.
//!
//! The final distribution assigns processor `g·(p/k) + j` the j-th chunk
//! of group g's key range — globally sorted in pid order.

use crate::bsp::engine::BspCtx;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::RadixKey;
use crate::seq::{ops, search, SeqSorter};

use super::common::{ProcResult, PH2, PH3, PH4, PH5, PH6, PH7};
use super::config::SortConfig;
use super::det::omega_det;

/// Number of buckets per round for the two-round schedule: √p rounded to
/// a power of two (p must be a power of two with an even exponent to
/// split perfectly; otherwise round 1 uses the larger factor).
pub fn round1_buckets(p: usize) -> usize {
    let lgp = p.trailing_zeros();
    1 << lgp.div_ceil(2)
}

/// Two-round deterministic sort.  Requires `p` a power of two; falls back
/// to the one-round algorithm when `p ≤ 2` (a group would be trivial).
pub fn sort_det_iterative<K: RadixKey>(
    ctx: &mut BspCtx<K>,
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
) -> ProcResult<K> {
    let p = ctx.nprocs();
    if p <= 2 {
        return super::det::sort_det_bsp(ctx, params, local, n_total, cfg);
    }
    assert!(p.is_power_of_two(), "iterative det sort requires p a power of two");
    let sorter: Box<dyn SeqSorter<K>> = crate::seq::backend(cfg.seq);
    let pid = ctx.pid();
    let k = round1_buckets(p); // groups / round-1 buckets
    let gsize = p / k;
    let group = pid / gsize;
    let rank_in_group = pid % gsize;
    let omega = omega_det(cfg, n_total);
    let r = omega.ceil().max(1.0) as usize;

    // ---- Round 1: Ph2 local sort + k-way global split ------------------
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    let mut keys = local;
    sorter.sort(&mut keys);

    ctx.phase(PH3);
    // Regular sample targeting k buckets: s = r·k per processor.
    let s = r * k;
    let sample = super::common::regular_sample(&keys, pid, s);
    ctx.charge(s as f64);
    // Parallel bitonic sample sort over all p processors, then the k−1
    // bucket splitters sit at global ranks i·(s·p/k): processor
    // i·(p/k)−1's last record, gathered at 0 and broadcast.
    let sorted_chunk = crate::primitives::bitonic::bitonic_sort(ctx, sample, "it1:bsi");
    if (pid + 1) % gsize == 0 && pid != p - 1 {
        let last = *sorted_chunk.last().expect("sample chunk");
        ctx.send(0, Payload::Recs(vec![last]));
    }
    ctx.sync("it1:gather-splitters");
    let splitters = if pid == 0 {
        let mut recs: Vec<(usize, SampleRec<K>)> = ctx
            .take_inbox()
            .into_iter()
            .map(|(src, payload)| (src, payload.into_recs()[0]))
            .collect();
        recs.sort_by_key(|(src, _)| *src);
        recs.into_iter().map(|(_, rec)| rec).collect()
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let splitters =
        crate::primitives::broadcast::broadcast_recs(ctx, params, 0, splitters, k - 1, "it1:bcast");

    // Partition into k buckets; bucket b goes to processor
    // b·gsize + (pid mod gsize) — spreading each bucket over its group.
    ctx.phase(PH5);
    let cuts = search::partition_points(&keys, pid, &splitters);
    ctx.charge((k as f64 - 1.0) * ops::bsearch_charge(keys.len().max(2)));
    for b in 0..k {
        let dst = b * gsize + rank_in_group;
        ctx.send(dst, Payload::Keys(keys[cuts[b]..cuts[b + 1]].to_vec()));
    }
    ctx.charge(ops::linear_charge(keys.len()));
    ctx.sync("it1:route");
    let runs: Vec<Vec<K>> = ctx
        .take_inbox()
        .into_iter()
        .map(|(_, payload)| payload.into_keys())
        .filter(|run| !run.is_empty())
        .collect();
    let received1: usize = runs.iter().map(|run| run.len()).sum();
    ctx.phase(PH6);
    ctx.charge(ops::merge_charge(received1, runs.len().max(2)));
    let keys = crate::seq::multiway_merge(&runs);

    // ---- Round 2: within the group ---------------------------------------
    // Group-local sample; splitters selected at the group leader
    // (sequential shape — the sample is tiny, 2 supersteps beat a
    // group-bitonic at these sizes per the Lemma 4.1/4.2 cost forms).
    ctx.phase(PH3);
    let leader = group * gsize;
    let s2 = r * gsize;
    let sample2 = super::common::regular_sample(&keys, pid, s2);
    ctx.charge(s2 as f64);
    ctx.send(leader, Payload::Recs(sample2));
    ctx.sync("it2:gather-sample");
    let group_splitters = if rank_in_group == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        let seg = (all.len() / gsize).max(1);
        let splitters: Vec<SampleRec<K>> =
            (1..gsize).map(|i| all[(i * seg - 1).min(all.len() - 1)]).collect();
        for j in 1..gsize {
            ctx.send(leader + j, Payload::Recs(splitters.clone()));
        }
        splitters
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    ctx.sync("it2:bcast");
    let group_splitters = if rank_in_group == 0 {
        ctx.take_inbox();
        group_splitters
    } else {
        ctx.take_inbox()
            .into_iter()
            .find(|(src, _)| *src == leader)
            .map(|(_, payload)| payload.into_recs())
            .unwrap_or_default()
    };

    ctx.phase(PH4);
    let cuts = search::partition_points(&keys, pid, &group_splitters);
    ctx.charge((gsize as f64 - 1.0) * ops::bsearch_charge(keys.len().max(2)));

    ctx.phase(PH5);
    for j in 0..gsize {
        ctx.send(leader + j, Payload::Keys(keys[cuts[j]..cuts[j + 1]].to_vec()));
    }
    ctx.charge(ops::linear_charge(keys.len()));
    ctx.sync("it2:route");
    let runs: Vec<Vec<K>> = ctx
        .take_inbox()
        .into_iter()
        .map(|(_, payload)| payload.into_keys())
        .filter(|run| !run.is_empty())
        .collect();
    let received: usize = runs.iter().map(|run| run.len()).sum();

    ctx.phase(PH6);
    ctx.charge(ops::merge_charge(received, runs.len().max(2)));
    let merged = crate::seq::multiway_merge(&runs);

    ctx.phase(PH7);
    ctx.sync("it:done");

    ProcResult {
        keys: merged,
        received: received.max(received1),
        runs: runs.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn run_it(p: usize, n: usize, bench: Benchmark) -> (Vec<Vec<i32>>, Vec<ProcResult>) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            let input = local.clone();
            (input, sort_det_iterative(ctx, &params, local, n, &cfg))
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results)
    }

    fn assert_sorted_permutation(inputs: &[Vec<i32>], results: &[ProcResult]) {
        let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn sorts_every_benchmark_two_rounds() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results) = run_it(8, 1 << 12, bench);
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn sorts_various_p() {
        for p in [1usize, 2, 4, 16] {
            let (inputs, results) = run_it(p, 1 << 12, Benchmark::Uniform);
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn round1_buckets_square_split() {
        assert_eq!(round1_buckets(4), 2);
        assert_eq!(round1_buckets(16), 4);
        assert_eq!(round1_buckets(64), 8);
        assert_eq!(round1_buckets(8), 4); // odd exponent: larger factor first
        assert_eq!(round1_buckets(128), 16);
    }

    #[test]
    fn all_equal_keys_balanced_two_rounds() {
        let p = 8usize;
        let n = 1 << 12;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = vec![5i32; n / p];
            sort_det_iterative(ctx, &params, local, n, &cfg)
        });
        for r in &run.outputs {
            assert!(r.received > 0, "no processor may starve on all-equal input");
            // Tagged splitters keep each round near-even.
            assert!(r.received <= n / 2, "received={}", r.received);
        }
    }

    #[test]
    fn per_round_sample_is_smaller_than_one_round() {
        // The point of iterating: round samples are r·k and r·(p/k)
        // instead of r·p.
        let p = 64;
        let k = round1_buckets(p);
        assert!(k + p / k < p);
    }
}
