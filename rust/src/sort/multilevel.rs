//! Two-level (multi-level) BSP sample sorting over processor groups.
//!
//! The paper's one-level sorts route one full h-relation across all `p`
//! processors: every superstep of Ph5 is a whole-machine exchange priced
//! `g·n_max` under the full machine's `(L, g)`.  Following the k-way
//! recursion of "Practical Massively Parallel Sorting" (AMS) and
//! "Robust Massively Parallel Sorting" (Axtmann & Sanders), the
//! two-level variants here:
//!
//! 1. **Level 1** — select `k − 1` *coarse* splitters (regular sample of
//!    the locally sorted run for the deterministic variant, random
//!    sample for the randomized one; §5.1.1 tagged either way, so
//!    duplicate-heavy inputs split across groups exactly), partition,
//!    and route each key range to one of `k` disjoint processor groups
//!    — a single whole-machine superstep moving each key once;
//! 2. **Level 2** — every group runs the *unmodified one-level
//!    algorithm* ([`super::det::sort_det_bsp`] /
//!    [`super::ran::sort_ran_bsp`]) against its
//!    [`GroupCtx`](crate::bsp::group::GroupCtx): group-scoped ranks,
//!    group-local barriers, group-local exchanges over `p/k` processors.
//!
//! Every level-2 superstep therefore realizes a *group-local*
//! h-relation — `n/k` total words instead of `n`, synchronized over
//! `p/k` processors — which the ledger prices with the group-scaled
//! machine and max-reduces across concurrently running sibling groups
//! (`bsp::ledger`).  Phases of level 2 appear under the `L2/` prefix
//! (`L2/Ph2:SeqSort`, `L2/Ph5:Routing`, …) next to the level-1 phases
//! with the paper's plain names.
//!
//! Concatenating the groups in order yields the global sorted order in
//! pid order because [`Communicator::split_even`] assigns contiguous
//! ascending pid blocks to ascending coarse key ranges.

use crate::bsp::engine::BspScope;
use crate::bsp::group::{GroupPartition, GroupedScope};
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::RadixKey;
use crate::primitives::{broadcast, prefix};
use crate::seq::{ops, search, QuickSorter, RadixSorter, SeqSortKind, SeqSorter};
use crate::util::rng::SplitMix64;

use super::common::{self, ProcResult, PH1, PH2, PH3, PH4, PH5};
use super::config::SortConfig;
use super::det::omega_det;
use super::iran::{omega_ran, sample_share};

/// The phase-label prefix under which level-2 (group-local) phases are
/// recorded in the ledger.
pub const LEVEL2_PREFIX: &str = "L2/";

/// Default group count for a `p`-processor machine: the largest divisor
/// of `p` not exceeding `√p` (so groups are at least as wide as they are
/// many, keeping the level-2 sub-machines the larger factor).  `1` for
/// `p < 4` — a two-level split needs at least two groups of two.
///
/// For the power-of-two configurations of the paper this is the
/// power-of-two `√p̃`: p = 4 → 2×2, p = 8 → 2×4, p = 16 → 4×4,
/// p = 64 → 8×8.
pub fn default_groups(p: usize) -> usize {
    let mut k = 1usize;
    let mut c = 2usize;
    while c * c <= p {
        if p % c == 0 {
            k = c;
        }
        c += 1;
    }
    k
}

/// Two-level deterministic sample sort (regular oversampling at both
/// levels).
///
/// SPMD over the *whole* machine: every processor calls this inside
/// `BspMachine::run` (or `SimMachine::run`) with the shared `comm` —
/// the scope's backend-matched communicator, constructed outside the
/// run, e.g.
/// [`Communicator::split_even`](crate::bsp::group::Communicator::split_even)`(p, `[`default_groups`]`(p))`
/// for the threaded engine or
/// [`SimCommunicator::split_even`](crate::bsp::sim::SimCommunicator::split_even)
/// for the simulator.  Generic over [`GroupedScope`], so the identical
/// program text runs on either backend.  With a single group this
/// degrades to the one-level algorithm.
pub fn sort_multilevel_det<K: RadixKey, S: GroupedScope<K>>(
    ctx: &mut S,
    comm: &S::Comm,
    params: &BspParams,
    mut local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
) -> ProcResult<K> {
    let k = comm.num_groups();
    if k <= 1 {
        return super::det::sort_det_bsp(ctx, params, local, n_total, cfg);
    }
    assert_eq!(
        comm.nprocs(),
        ctx.nprocs(),
        "communicator must cover the whole machine"
    );
    let pid = ctx.pid();
    let sorter: &dyn SeqSorter<K> = match cfg.seq {
        SeqSortKind::Quick => &QuickSorter,
        SeqSortKind::Radix => &RadixSorter,
        SeqSortKind::Xla => panic!("the multi-level sorts support the Quick/Radix backends"),
    };

    // --- Ph2: local sort (once; level 2 receives sorted runs) ---------
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    let mut keys = std::mem::take(&mut local);
    sorter.sort(&mut keys);

    // --- Ph3 (level 1): coarse regular sample → k−1 group splitters ---
    // The sample targets k buckets, so it is ⌈ω⌉·k records per
    // processor — a factor p/k smaller than the one-level sample; tiny,
    // so the sequential gather-sort-broadcast shape is the right
    // primitive (the paper's §5.1 point about choosing primitives per
    // (n, p, L, g)).
    ctx.phase(PH3);
    let r = omega_det(cfg, n_total).ceil().max(1.0) as usize;
    let s = r * k;
    let sample = common::regular_sample(&keys, pid, s);
    ctx.charge(s as f64);
    ctx.send(0, Payload::Recs(sample));
    ctx.sync("l1:gather-sample");
    let coarse = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        common::select_splitters(&all, k)
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let coarse = broadcast::broadcast_recs(ctx, params, 0, coarse, k - 1, "l1:bcast");

    // --- Ph4 (level 1): partition the sorted run at the coarse cuts ---
    ctx.phase(PH4);
    let effective = common::effective_splitters(&coarse, cfg);
    let cuts = search::partition_points(&keys, pid, &effective);
    ctx.charge((k as f64 - 1.0) * ops::bsearch_charge(keys.len().max(2)));

    // --- Ph5 (level 1): one superstep routes each range to its group --
    // Bucket j is a contiguous slice of the sorted run; it goes to ONE
    // member of group j (rotating by sender pid so every member is fed),
    // and level 2's own routing rebalances within the group.
    ctx.phase(PH5);
    let n_local = keys.len();
    let mut parts: Vec<Vec<K>> = Vec::with_capacity(k);
    let mut head = keys;
    for j in (1..k).rev() {
        parts.push(head.split_off(cuts[j]));
    }
    parts.push(head);
    parts.reverse();
    ctx.charge(ops::linear_charge(n_local));
    for (j, bucket) in parts.into_iter().enumerate() {
        let members = comm.members(j);
        ctx.send(members[pid % members.len()], Payload::Keys(bucket));
    }
    ctx.sync("l1:route");
    // Concatenate the received ranges without merging: the level-2
    // algorithm's own Ph2 local sort is about to run regardless (it is
    // the unmodified one-level sort), so a level-1 multiway merge would
    // be pure duplicated work — and a duplicated n·lg n charge that
    // would skew the measured-vs-predicted phase ratios.
    let mut received_keys: Vec<K> = Vec::new();
    for (_, payload) in ctx.take_inbox() {
        received_keys.extend_from_slice(&payload.into_keys());
    }
    let received = received_keys.len();
    ctx.charge(ops::linear_charge(received));

    // --- Level 2: the one-level algorithm, group-locally --------------
    let group_params = params.scaled_to(comm.group_size(comm.group_of(pid)));
    let mut g = ctx.enter_group(comm, LEVEL2_PREFIX);
    g.phase(PH1);
    let (_, totals) = prefix::prefix_direct(&mut g, &[received as u64], "l2:count");
    let group_n = totals[0] as usize;
    super::det::sort_det_bsp(&mut g, &group_params, received_keys, group_n, cfg)
}

/// Two-level randomized sample sort (coarse random splitters, then the
/// classic one-level SORT_RAN_BSP group-locally).
///
/// Same SPMD contract (and backend genericity) as
/// [`sort_multilevel_det`]; `seed` decorrelates the random samples
/// across runs and (internally) across groups.
pub fn sort_multilevel_ran<K: RadixKey, S: GroupedScope<K>>(
    ctx: &mut S,
    comm: &S::Comm,
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
) -> ProcResult<K> {
    let k = comm.num_groups();
    if k <= 1 {
        return super::ran::sort_ran_bsp(ctx, params, local, n_total, cfg, seed);
    }
    assert_eq!(
        comm.nprocs(),
        ctx.nprocs(),
        "communicator must cover the whole machine"
    );
    let pid = ctx.pid();

    // --- Ph3 (level 1): random coarse sample, sorted at processor 0 ---
    ctx.phase(PH3);
    let omega = omega_ran(cfg, n_total);
    let share = sample_share(n_total, k, omega).min(local.len().max(1));
    let mut rng = SplitMix64::new(seed ^ ((pid as u64) << 18).wrapping_add(0x2D2D));
    let sample: Vec<SampleRec<K>> = if local.is_empty() {
        vec![SampleRec::new(K::max_key(), pid, 0)]
    } else {
        rng.sample_indices(local.len(), share)
            .into_iter()
            .map(|i| SampleRec::new(local[i], pid, i))
            .collect()
    };
    ctx.charge(share as f64);
    ctx.send(0, Payload::Recs(sample));
    ctx.sync("l1:gather-sample");
    let coarse = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        common::select_splitters(&all, k)
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let coarse = broadcast::broadcast_recs(ctx, params, 0, coarse, k - 1, "l1:bcast");

    // --- Ph5 (level 1): key-wise set formation + one routing superstep
    // (the SORT_RAN_BSP step-9 shape, but over k buckets, so the binary
    // search is lg k instead of lg p per key).
    ctx.phase(PH5);
    let effective = common::effective_splitters(&coarse, cfg);
    let mut buckets: Vec<Vec<K>> = vec![Vec::new(); k];
    for (i, &key) in local.iter().enumerate() {
        buckets[common::splitter_rank(&effective, key, pid, i)].push(key);
    }
    ctx.charge(local.len() as f64 * (ops::bsearch_charge(k) + 1.0 + 2.0));
    for (j, bucket) in buckets.into_iter().enumerate() {
        let members = comm.members(j);
        ctx.send(members[pid % members.len()], Payload::Keys(bucket));
    }
    ctx.sync("l1:route");
    let mut received_keys: Vec<K> = Vec::new();
    for (_, payload) in ctx.take_inbox() {
        received_keys.extend_from_slice(&payload.into_keys());
    }
    let received = received_keys.len();
    ctx.charge(ops::linear_charge(received));

    // --- Level 2: the one-level algorithm, group-locally --------------
    let group = comm.group_of(pid);
    let group_params = params.scaled_to(comm.group_size(group));
    let mut g = ctx.enter_group(comm, LEVEL2_PREFIX);
    g.phase(PH1);
    let (_, totals) = prefix::prefix_direct(&mut g, &[received as u64], "l2:count");
    let group_n = totals[0] as usize;
    let group_seed = seed.wrapping_add((group as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    super::ran::sort_ran_bsp(&mut g, &group_params, received_keys, group_n, cfg, group_seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::group::Communicator;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn run_multilevel(
        det: bool,
        p: usize,
        groups: usize,
        n: usize,
        bench: Benchmark,
        cfg: SortConfig,
    ) -> (Vec<Vec<i32>>, Vec<ProcResult>, crate::bsp::Ledger) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let comm = Communicator::split_even(p, groups);
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            let input = local.clone();
            let out = if det {
                sort_multilevel_det(ctx, &comm, &params, local, n, &cfg)
            } else {
                sort_multilevel_ran(ctx, &comm, &params, local, n, &cfg, 0x2E11)
            };
            (input, out)
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results, run.ledger)
    }

    fn assert_sorted_permutation(inputs: &[Vec<i32>], results: &[ProcResult], label: &str) {
        let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
        assert_eq!(got, expect, "{label}");
    }

    #[test]
    fn default_groups_divides_and_caps_at_sqrt() {
        assert_eq!(default_groups(1), 1);
        assert_eq!(default_groups(2), 1);
        assert_eq!(default_groups(4), 2);
        assert_eq!(default_groups(8), 2);
        assert_eq!(default_groups(16), 4);
        assert_eq!(default_groups(64), 8);
        assert_eq!(default_groups(12), 3);
        for p in 1..=64usize {
            let k = default_groups(p);
            assert!(p % k == 0 && k * k <= p, "p={p} k={k}");
        }
    }

    #[test]
    fn det2_sorts_every_benchmark_p8() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results, _) =
                run_multilevel(true, 8, 2, 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results, &bench.tag());
        }
    }

    #[test]
    fn ran2_sorts_every_benchmark_p8() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results, _) =
                run_multilevel(false, 8, 2, 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results, &bench.tag());
        }
    }

    #[test]
    fn det2_various_splits() {
        for (p, groups) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4)] {
            let (inputs, results, _) = run_multilevel(
                true,
                p,
                groups,
                1 << 12,
                Benchmark::Staggered,
                SortConfig::default(),
            );
            assert_sorted_permutation(&inputs, &results, &format!("p={p} k={groups}"));
        }
    }

    #[test]
    fn single_group_degrades_to_one_level() {
        let (inputs, results, ledger) =
            run_multilevel(true, 4, 1, 1 << 10, Benchmark::Uniform, SortConfig::default());
        assert_sorted_permutation(&inputs, &results, "k=1");
        // No group-scoped records: the one-level algorithm ran.
        assert!(ledger.supersteps.iter().all(|s| s.round.is_none()));
    }

    #[test]
    fn radix_backend_sorts() {
        let cfg = SortConfig::default().with_seq(SeqSortKind::Radix);
        let (inputs, results, _) = run_multilevel(true, 8, 2, 1 << 12, Benchmark::DetDup, cfg);
        assert_sorted_permutation(&inputs, &results, "det2 radix");
        let (inputs, results, _) = run_multilevel(false, 8, 2, 1 << 12, Benchmark::DetDup, cfg);
        assert_sorted_permutation(&inputs, &results, "ran2 radix");
    }

    #[test]
    fn all_equal_keys_split_across_groups_via_tags() {
        // §5.1.1 transparency through the coarse level: tagged coarse
        // splitters cut the all-equal input between the groups instead
        // of collapsing it onto one.
        let p = 8usize;
        let n = 1 << 12;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let comm = Communicator::split_even(p, 2);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = vec![7i32; n / p];
            sort_multilevel_det(ctx, &comm, &params, local, n, &cfg)
        });
        let total: usize = run.outputs.iter().map(|r| r.keys.len()).sum();
        assert_eq!(total, n);
        for (pid, r) in run.outputs.iter().enumerate() {
            assert!(r.keys.iter().all(|&k| k == 7));
            assert!(r.received > 0, "pid={pid} starved");
        }
        // Both groups hold a comparable share (no group-level collapse).
        let g0: usize = run.outputs[..4].iter().map(|r| r.keys.len()).sum();
        let g1: usize = run.outputs[4..].iter().map(|r| r.keys.len()).sum();
        assert!(g0 > n / 4 && g1 > n / 4, "g0={g0} g1={g1}");
    }

    #[test]
    fn level2_phases_and_group_records_present() {
        let (_, _, ledger) =
            run_multilevel(true, 8, 2, 1 << 12, Benchmark::Uniform, SortConfig::default());
        for ph in ["Ph2:SeqSort", "Ph5:Routing", "L2/Ph2:SeqSort", "L2/Ph5:Routing"] {
            assert!(
                ledger.phases.contains_key(ph),
                "missing phase {ph}: {:?}",
                ledger.phases.keys().collect::<Vec<_>>()
            );
        }
        // The level-1 route is a whole-machine superstep; level-2 routes
        // are group records over 4 processors each, moving half the
        // input per group.
        let l1: Vec<_> = ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "l1:route")
            .collect();
        assert_eq!(l1.len(), 1);
        assert_eq!(l1[0].procs, 8);
        assert_eq!(l1[0].total_words, 1 << 12);
        let l2: Vec<_> = ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "ph5:route" && s.round.is_some())
            .collect();
        assert_eq!(l2.len(), 2, "one level-2 route per group");
        for s in &l2 {
            assert_eq!(s.procs, 4);
            assert_eq!(s.phase, "L2/Ph5:Routing");
            assert!(
                s.total_words < l1[0].total_words,
                "level-2 routing must be group-local: {} vs {}",
                s.total_words,
                l1[0].total_words
            );
        }
        let l2_total: u64 = l2.iter().map(|s| s.total_words).sum();
        assert_eq!(l2_total, 1 << 12, "level 2 moves every key exactly once overall");
    }
}
