//! Depth-k (multi-level) BSP sample sorting over nested processor
//! groups.
//!
//! The paper's one-level sorts route one full h-relation across all `p`
//! processors: every superstep of Ph5 is a whole-machine exchange priced
//! `g·n_max` under the full machine's `(L, g)`.  Following the k-way
//! recursion of "Practical Massively Parallel Sorting" (AMS) and
//! "Robust Massively Parallel Sorting" (Axtmann & Sanders), the
//! multi-level variants here run over a topology tree
//! `p = k1 × k2 × … × kd` ([`Topology`]):
//!
//! 1. **Routing level ℓ** (one per interior tree level) — select
//!    `k_ℓ − 1` *coarse* splitters (regular sample of the locally sorted
//!    run for the deterministic variant, random sample for the
//!    randomized one; §5.1.1 tagged either way, so duplicate-heavy
//!    inputs split across groups exactly), partition, and route each key
//!    range to one of `k_ℓ` disjoint sub-groups of the current cell — a
//!    single cell-wide superstep moving each key once;
//! 2. **Leaf level** — every `kd`-processor leaf machine runs the
//!    *unmodified one-level algorithm* ([`super::det::sort_det_bsp`] /
//!    [`super::ran::sort_ran_bsp`]) against its group scope:
//!    group-scoped ranks, group-local barriers, group-local exchanges.
//!
//! The levels are materialized as a *refinement chain* of communicators
//! over global pids ([`Topology::communicators`]): level ℓ's partition
//! refines level ℓ−1's, and the recursion is a loop that re-enters each
//! successive communicator from the root scope — no nested scopes, so
//! sibling cells never share barriers and a slow cell cannot stall its
//! cousins.  Each deeper superstep realizes a *cell-local* h-relation —
//! `n/(k1…kℓ)` total words, synchronized over `p/(k1…kℓ)` processors —
//! which the ledger prices with the cell-scaled machine and max-reduces
//! across concurrently running sibling cells (`bsp::ledger`).  Phases of
//! level ℓ ≥ 2 appear under the `L<level>/` prefix (`L2/Ph5:Routing`,
//! `L3/Ph2:SeqSort`, …) next to the level-1 phases with the paper's
//! plain names.
//!
//! [`sort_multilevel_det`]/[`sort_multilevel_ran`] are the historical
//! depth-2 entry points — thin wrappers over the same level loop, so
//! det2/ran2 are exactly the depth-2 special case.
//!
//! Concatenating the leaf machines in order yields the global sorted
//! order in pid order because [`GroupMap::split_even`]/[`GroupMap::refine`]
//! assign contiguous ascending pid blocks to ascending coarse key
//! ranges at every level.
//!
//! [`Topology`]: crate::bsp::group::Topology
//! [`Topology::communicators`]: crate::bsp::group::Topology::communicators
//! [`GroupMap::split_even`]: crate::bsp::group::GroupMap::split_even
//! [`GroupMap::refine`]: crate::bsp::group::GroupMap::refine

use crate::bsp::engine::BspScope;
use crate::bsp::group::{GroupPartition, GroupedScope, Topology};
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::RadixKey;
use crate::primitives::{broadcast, prefix};
use crate::seq::{ops, search, IpsSorter, QuickSorter, RadixSorter, SeqSortKind, SeqSorter};
use crate::util::rng::SplitMix64;

use super::common::{self, ProcResult, PH1, PH2, PH3, PH4, PH5};
use super::config::SortConfig;
use super::det::omega_det;
use super::iran::{omega_ran, sample_share};

/// The phase-label prefix under which level-2 (group-local) phases are
/// recorded in the ledger — [`level_prefix`]`(2)`.
pub const LEVEL2_PREFIX: &str = "L2/";

/// The phase-label prefix for (1-based) `level` ≥ 2: `"L<level>/"`.
/// Level 1 phases carry the paper's plain names (no prefix).
pub fn level_prefix(level: usize) -> String {
    format!("L{level}/")
}

/// Default group count for a `p`-processor machine: the largest divisor
/// of `p` not exceeding `√p` (so groups are at least as wide as they are
/// many, keeping the level-2 sub-machines the larger factor).  `1` for
/// `p < 4` — a two-level split needs at least two groups of two.
///
/// For the power-of-two configurations of the paper this is the
/// power-of-two `√p̃`: p = 4 → 2×2, p = 8 → 2×4, p = 16 → 4×4,
/// p = 64 → 8×8.
pub fn default_groups(p: usize) -> usize {
    let mut k = 1usize;
    let mut c = 2usize;
    while c * c <= p {
        if p % c == 0 {
            k = c;
        }
        c += 1;
    }
    k
}

/// The historical default topology: `[k, p/k]` with `k =`
/// [`default_groups`]`(p)`, degrading to the flat (one-level) topology
/// when no two-level split exists.
pub fn default_topology(p: usize) -> Topology {
    let k = default_groups(p);
    if k <= 1 {
        Topology::flat(p)
    } else {
        Topology::two_level(p, k)
    }
}

/// Every communicator must cover the whole machine, and each level must
/// refine the previous one (every child cell wholly inside one parent
/// cell) — the invariant that keeps deeper-level sends cell-local.
fn validate_levels<C: GroupPartition>(nprocs: usize, comms: &[&C]) {
    for c in comms {
        assert_eq!(c.nprocs(), nprocs, "communicator must cover the whole machine");
    }
    for w in comms.windows(2) {
        let (parent, child) = (w[0], w[1]);
        for g in 0..child.num_groups() {
            let members = child.members(g);
            let cell = parent.group_of(members[0]);
            assert!(
                members.iter().all(|&pid| parent.group_of(pid) == cell),
                "child group {g} straddles parent cells — levels must form a refinement chain"
            );
        }
    }
}

/// Destination of each of this processor's buckets at one routing
/// level, in the rank space of the scope the level runs in.
///
/// With no parent (level 1, whole machine) bucket `j` goes to one
/// member of `child` group `j`, rotated by sender pid so every member
/// is fed — global pids, matching the root scope.  With a parent, the
/// buckets are `child`'s sub-groups of this processor's parent cell,
/// rotated by the sender's parent rank, expressed as parent ranks —
/// the rank space of the entered group scope.
fn bucket_dsts<C: GroupPartition>(parent: Option<&C>, child: &C, gpid: usize) -> Vec<usize> {
    match parent {
        None => (0..child.num_groups())
            .map(|j| {
                let members = child.members(j);
                members[gpid % members.len()]
            })
            .collect(),
        Some(par) => {
            let cell = par.group_of(gpid);
            let rank = par.rank_of(gpid);
            let mut dsts = Vec::new();
            for j in 0..child.num_groups() {
                let members = child.members(j);
                if par.group_of(members[0]) == cell {
                    dsts.push(par.rank_of(members[rank % members.len()]));
                }
            }
            dsts
        }
    }
}

/// One deterministic routing level inside `scope`: regular-sample the
/// locally sorted `keys`, gather + select `k − 1` coarse tagged
/// splitters at scope rank 0, broadcast, partition the sorted run at
/// the cuts, and route bucket `j` to `dsts[j]`.  Returns the received
/// ranges concatenated (unsorted — the next level re-sorts regardless).
///
/// `level` is 1-based and names the sync labels (`l<level>:*`).
#[allow(clippy::too_many_arguments)]
fn det_route_level<K: RadixKey, B: BspScope<K>>(
    scope: &mut B,
    params: &BspParams,
    keys: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    dsts: &[usize],
    level: usize,
) -> Vec<K> {
    let k = dsts.len();
    let pid = scope.pid();

    // --- Ph3: coarse regular sample → k−1 group splitters -------------
    // The sample targets k buckets, so it is ⌈ω⌉·k records per
    // processor — a factor cell_p/k smaller than the one-level sample;
    // tiny, so the sequential gather-sort-broadcast shape is the right
    // primitive (the paper's §5.1 point about choosing primitives per
    // (n, p, L, g)).
    scope.phase(PH3);
    let r = omega_det(cfg, n_total).ceil().max(1.0) as usize;
    let s = r * k;
    let sample = common::regular_sample(&keys, pid, s);
    scope.charge(s as f64);
    scope.send(0, Payload::Recs(sample));
    scope.sync(&format!("l{level}:gather-sample"));
    let coarse = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = scope
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        scope.charge(ops::sort_charge(all.len()));
        all.sort();
        common::select_splitters(&all, k)
    } else {
        scope.take_inbox();
        Vec::new()
    };
    let coarse =
        broadcast::broadcast_recs(scope, params, 0, coarse, k - 1, &format!("l{level}:bcast"));

    // --- Ph4: partition the sorted run at the coarse cuts -------------
    scope.phase(PH4);
    let effective = common::effective_splitters(&coarse, cfg);
    let cuts = search::partition_points(&keys, pid, &effective);
    scope.charge((k as f64 - 1.0) * ops::bsearch_charge(keys.len().max(2)));

    // --- Ph5: one superstep routes each range to its sub-group --------
    // Bucket j is a contiguous slice of the sorted run; it goes to ONE
    // member of sub-group j (rotating by sender rank so every member is
    // fed), and the next level's own routing rebalances within it.
    scope.phase(PH5);
    let n_local = keys.len();
    let mut parts: Vec<Vec<K>> = Vec::with_capacity(k);
    let mut head = keys;
    for j in (1..k).rev() {
        parts.push(head.split_off(cuts[j]));
    }
    parts.push(head);
    parts.reverse();
    scope.charge(ops::linear_charge(n_local));
    for (j, bucket) in parts.into_iter().enumerate() {
        scope.send(dsts[j], Payload::Keys(bucket));
    }
    scope.sync(&format!("l{level}:route"));
    // Concatenate the received ranges without merging: the next level's
    // local sort is about to run regardless, so a multiway merge here
    // would be pure duplicated work — and a duplicated n·lg n charge
    // that would skew the measured-vs-predicted phase ratios.
    let mut received_keys: Vec<K> = Vec::new();
    for (_, payload) in scope.take_inbox() {
        received_keys.extend_from_slice(&payload.into_keys());
    }
    scope.charge(ops::linear_charge(received_keys.len()));
    received_keys
}

/// One randomized routing level inside `scope`: random sample of the
/// (unsorted) `local` keys, coarse tagged splitters at scope rank 0,
/// key-wise set formation (the SORT_RAN_BSP step-9 shape, but over `k`
/// buckets, so the binary search is `lg k` per key), one routing
/// superstep.  Returns the received keys, concatenated.
#[allow(clippy::too_many_arguments)]
fn ran_route_level<K: RadixKey, B: BspScope<K>>(
    scope: &mut B,
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    dsts: &[usize],
    level: usize,
    level_seed: u64,
) -> Vec<K> {
    let k = dsts.len();
    let pid = scope.pid();

    // --- Ph3: random coarse sample, sorted at scope rank 0 ------------
    scope.phase(PH3);
    let omega = omega_ran(cfg, n_total);
    let share = sample_share(n_total, k, omega).min(local.len().max(1));
    let mut rng = SplitMix64::new(level_seed ^ ((pid as u64) << 18).wrapping_add(0x2D2D));
    let sample: Vec<SampleRec<K>> = if local.is_empty() {
        vec![SampleRec::new(K::max_key(), pid, 0)]
    } else {
        rng.sample_indices(local.len(), share)
            .into_iter()
            .map(|i| SampleRec::new(local[i], pid, i))
            .collect()
    };
    scope.charge(share as f64);
    scope.send(0, Payload::Recs(sample));
    scope.sync(&format!("l{level}:gather-sample"));
    let coarse = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = scope
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        scope.charge(ops::sort_charge(all.len()));
        all.sort();
        common::select_splitters(&all, k)
    } else {
        scope.take_inbox();
        Vec::new()
    };
    let coarse =
        broadcast::broadcast_recs(scope, params, 0, coarse, k - 1, &format!("l{level}:bcast"));

    // --- Ph5: key-wise set formation + one routing superstep ----------
    scope.phase(PH5);
    let effective = common::effective_splitters(&coarse, cfg);
    let mut buckets: Vec<Vec<K>> = vec![Vec::new(); k];
    for (i, &key) in local.iter().enumerate() {
        buckets[common::splitter_rank(&effective, key, pid, i)].push(key);
    }
    scope.charge(local.len() as f64 * (ops::bsearch_charge(k) + 1.0 + 2.0));
    for (j, bucket) in buckets.into_iter().enumerate() {
        scope.send(dsts[j], Payload::Keys(bucket));
    }
    scope.sync(&format!("l{level}:route"));
    let mut received_keys: Vec<K> = Vec::new();
    for (_, payload) in scope.take_inbox() {
        received_keys.extend_from_slice(&payload.into_keys());
    }
    scope.charge(ops::linear_charge(received_keys.len()));
    received_keys
}

/// Depth-k deterministic sample sort (regular oversampling at every
/// level) over a refinement chain of communicators — typically
/// [`Topology::communicators`].
///
/// SPMD over the *whole* machine: every processor calls this inside
/// `BspMachine::run` (or `SimMachine::run`) with the shared `comms`
/// slice, constructed outside the run.  `comms[ℓ]` must cover the whole
/// machine and refine `comms[ℓ−1]`; communicators with a single group
/// are skipped, and with none left this degrades to the one-level
/// algorithm.  Generic over [`GroupedScope`], so the identical program
/// text runs on either backend.
pub fn sort_deep_det<K: RadixKey, S: GroupedScope<K>>(
    ctx: &mut S,
    comms: &[S::Comm],
    params: &BspParams,
    mut local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
) -> ProcResult<K> {
    let comms: Vec<&S::Comm> = comms.iter().filter(|c| c.num_groups() > 1).collect();
    if comms.is_empty() {
        return super::det::sort_det_bsp(ctx, params, local, n_total, cfg);
    }
    validate_levels(ctx.nprocs(), &comms);
    let gpid = ctx.pid();
    let sorter: &dyn SeqSorter<K> = match cfg.seq {
        SeqSortKind::Quick => &QuickSorter,
        SeqSortKind::Radix => &RadixSorter,
        SeqSortKind::Ips => &IpsSorter,
        SeqSortKind::Xla => panic!("the multi-level sorts support the Quick/Radix/Ips backends"),
    };

    // --- Ph2: local sort (deeper levels re-sort their received
    // concatenations inside their own cell scope) ----------------------
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    let mut keys = std::mem::take(&mut local);
    sorter.sort(&mut keys);

    let depth = comms.len() + 1;
    for level in 0..comms.len() {
        let dsts = bucket_dsts(level.checked_sub(1).map(|i| comms[i]), comms[level], gpid);
        if level == 0 {
            keys = det_route_level(ctx, params, keys, n_total, cfg, &dsts, 1);
        } else {
            let parent = comms[level - 1];
            let cell_params = params.scaled_to(parent.group_size(parent.group_of(gpid)));
            let mut g = ctx.enter_group(parent, &level_prefix(level + 1));
            // The received ranges arrive as an unsorted concatenation;
            // regular sampling needs a sorted run, so each deeper level
            // pays its own local sort (inside the cell scope, so the
            // charge lands in the prefixed phase).
            g.phase(PH2);
            g.charge(sorter.charge(keys.len()));
            sorter.sort(&mut keys);
            keys = det_route_level(&mut g, &cell_params, keys, n_total, cfg, &dsts, level + 1);
        }
    }

    // --- Leaf: the one-level algorithm, inside the finest cells -------
    let leaf = *comms.last().unwrap();
    let leaf_params = params.scaled_to(leaf.group_size(leaf.group_of(gpid)));
    let received = keys.len();
    let mut g = ctx.enter_group(leaf, &level_prefix(depth));
    g.phase(PH1);
    let (_, totals) =
        prefix::prefix_direct(&mut g, &[received as u64], &format!("l{depth}:count"));
    let group_n = totals[0] as usize;
    super::det::sort_det_bsp(&mut g, &leaf_params, keys, group_n, cfg)
}

/// Depth-k randomized sample sort (coarse random splitters at every
/// routing level, then the classic one-level SORT_RAN_BSP inside the
/// leaf machines).
///
/// Same SPMD contract (and backend genericity) as [`sort_deep_det`];
/// `seed` decorrelates the random samples across runs, and internally
/// across levels and cells (each routing level folds its cell index
/// into the seed chain).
pub fn sort_deep_ran<K: RadixKey, S: GroupedScope<K>>(
    ctx: &mut S,
    comms: &[S::Comm],
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
) -> ProcResult<K> {
    let comms: Vec<&S::Comm> = comms.iter().filter(|c| c.num_groups() > 1).collect();
    if comms.is_empty() {
        return super::ran::sort_ran_bsp(ctx, params, local, n_total, cfg, seed);
    }
    validate_levels(ctx.nprocs(), &comms);
    let gpid = ctx.pid();

    let depth = comms.len() + 1;
    let mut keys = local;
    let mut level_seed = seed;
    for level in 0..comms.len() {
        let dsts = bucket_dsts(level.checked_sub(1).map(|i| comms[i]), comms[level], gpid);
        if level == 0 {
            keys = ran_route_level(ctx, params, keys, n_total, cfg, &dsts, 1, level_seed);
        } else {
            let parent = comms[level - 1];
            let cell_params = params.scaled_to(parent.group_size(parent.group_of(gpid)));
            let mut g = ctx.enter_group(parent, &level_prefix(level + 1));
            keys =
                ran_route_level(&mut g, &cell_params, keys, n_total, cfg, &dsts, level + 1, level_seed);
        }
        // Decorrelate the next level's sampling across sibling cells.
        level_seed = level_seed
            .wrapping_add((comms[level].group_of(gpid) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    }

    // --- Leaf: the one-level algorithm, inside the finest cells -------
    let leaf = *comms.last().unwrap();
    let leaf_params = params.scaled_to(leaf.group_size(leaf.group_of(gpid)));
    let received = keys.len();
    let mut g = ctx.enter_group(leaf, &level_prefix(depth));
    g.phase(PH1);
    let (_, totals) =
        prefix::prefix_direct(&mut g, &[received as u64], &format!("l{depth}:count"));
    let group_n = totals[0] as usize;
    super::ran::sort_ran_bsp(&mut g, &leaf_params, keys, group_n, cfg, level_seed)
}

/// Two-level deterministic sample sort — the depth-2 special case of
/// [`sort_deep_det`], kept as the historical det2 entry point.  With a
/// single group this degrades to the one-level algorithm.
pub fn sort_multilevel_det<K: RadixKey, S: GroupedScope<K>>(
    ctx: &mut S,
    comm: &S::Comm,
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
) -> ProcResult<K> {
    sort_deep_det(ctx, std::slice::from_ref(comm), params, local, n_total, cfg)
}

/// Two-level randomized sample sort — the depth-2 special case of
/// [`sort_deep_ran`], kept as the historical ran2 entry point.  `seed`
/// decorrelates the random samples across runs and (internally) across
/// groups.
pub fn sort_multilevel_ran<K: RadixKey, S: GroupedScope<K>>(
    ctx: &mut S,
    comm: &S::Comm,
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
) -> ProcResult<K> {
    sort_deep_ran(ctx, std::slice::from_ref(comm), params, local, n_total, cfg, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::group::Communicator;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn run_multilevel(
        det: bool,
        p: usize,
        groups: usize,
        n: usize,
        bench: Benchmark,
        cfg: SortConfig,
    ) -> (Vec<Vec<i32>>, Vec<ProcResult>, crate::bsp::Ledger) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let comm = Communicator::split_even(p, groups);
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            let input = local.clone();
            let out = if det {
                sort_multilevel_det(ctx, &comm, &params, local, n, &cfg)
            } else {
                sort_multilevel_ran(ctx, &comm, &params, local, n, &cfg, 0x2E11)
            };
            (input, out)
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results, run.ledger)
    }

    fn run_deep(
        det: bool,
        dims: &[usize],
        n: usize,
        bench: Benchmark,
        cfg: SortConfig,
    ) -> (Vec<Vec<i32>>, Vec<ProcResult>, crate::bsp::Ledger) {
        let t = Topology::new(dims);
        let p = t.nprocs();
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let comms: Vec<Communicator> = t.communicators();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            let input = local.clone();
            let out = if det {
                sort_deep_det(ctx, &comms, &params, local, n, &cfg)
            } else {
                sort_deep_ran(ctx, &comms, &params, local, n, &cfg, 0x3E11)
            };
            (input, out)
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results, run.ledger)
    }

    fn assert_sorted_permutation(inputs: &[Vec<i32>], results: &[ProcResult], label: &str) {
        let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
        assert_eq!(got, expect, "{label}");
    }

    #[test]
    fn default_groups_divides_and_caps_at_sqrt() {
        assert_eq!(default_groups(1), 1);
        assert_eq!(default_groups(2), 1);
        assert_eq!(default_groups(4), 2);
        assert_eq!(default_groups(8), 2);
        assert_eq!(default_groups(16), 4);
        assert_eq!(default_groups(64), 8);
        assert_eq!(default_groups(12), 3);
        for p in 1..=64usize {
            let k = default_groups(p);
            assert!(p % k == 0 && k * k <= p, "p={p} k={k}");
        }
    }

    #[test]
    fn default_topology_two_level_or_flat() {
        assert_eq!(default_topology(2), Topology::flat(2));
        assert_eq!(default_topology(8), Topology::new(&[2, 4]));
        assert_eq!(default_topology(64), Topology::new(&[8, 8]));
    }

    #[test]
    fn det2_sorts_every_benchmark_p8() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results, _) =
                run_multilevel(true, 8, 2, 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results, &bench.tag());
        }
    }

    #[test]
    fn ran2_sorts_every_benchmark_p8() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results, _) =
                run_multilevel(false, 8, 2, 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results, &bench.tag());
        }
    }

    #[test]
    fn det2_various_splits() {
        for (p, groups) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4)] {
            let (inputs, results, _) = run_multilevel(
                true,
                p,
                groups,
                1 << 12,
                Benchmark::Staggered,
                SortConfig::default(),
            );
            assert_sorted_permutation(&inputs, &results, &format!("p={p} k={groups}"));
        }
    }

    #[test]
    fn depth3_sorts_every_benchmark_p8() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results, _) =
                run_deep(true, &[2, 2, 2], 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results, &format!("det3 {}", bench.tag()));
            let (inputs, results, _) =
                run_deep(false, &[2, 2, 2], 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results, &format!("ran3 {}", bench.tag()));
        }
    }

    #[test]
    fn depth4_uneven_topology_sorts() {
        // 16 = 2 × 2 × 2 × 2: three routing levels, leaf machines of 2.
        let (inputs, results, _) =
            run_deep(true, &[2, 2, 2, 2], 1 << 12, Benchmark::Staggered, SortConfig::default());
        assert_sorted_permutation(&inputs, &results, "det 2x2x2x2");
        // Non-uniform factors: 12 = 3 × 2 × 2.
        let (inputs, results, _) =
            run_deep(false, &[3, 2, 2], 12 << 7, Benchmark::Gaussian, SortConfig::default());
        assert_sorted_permutation(&inputs, &results, "ran 3x2x2");
    }

    #[test]
    fn single_group_degrades_to_one_level() {
        let (inputs, results, ledger) =
            run_multilevel(true, 4, 1, 1 << 10, Benchmark::Uniform, SortConfig::default());
        assert_sorted_permutation(&inputs, &results, "k=1");
        // No group-scoped records: the one-level algorithm ran.
        assert!(ledger.supersteps.iter().all(|s| s.round.is_none()));
    }

    #[test]
    fn flat_topology_degrades_to_one_level() {
        let (inputs, results, ledger) =
            run_deep(true, &[4], 1 << 10, Benchmark::Uniform, SortConfig::default());
        assert_sorted_permutation(&inputs, &results, "flat");
        assert!(ledger.supersteps.iter().all(|s| s.round.is_none()));
    }

    #[test]
    fn radix_backend_sorts() {
        let cfg = SortConfig::default().with_seq(SeqSortKind::Radix);
        let (inputs, results, _) = run_multilevel(true, 8, 2, 1 << 12, Benchmark::DetDup, cfg);
        assert_sorted_permutation(&inputs, &results, "det2 radix");
        let (inputs, results, _) = run_multilevel(false, 8, 2, 1 << 12, Benchmark::DetDup, cfg);
        assert_sorted_permutation(&inputs, &results, "ran2 radix");
    }

    #[test]
    fn all_equal_keys_split_across_groups_via_tags() {
        // §5.1.1 transparency through the coarse level: tagged coarse
        // splitters cut the all-equal input between the groups instead
        // of collapsing it onto one.
        let p = 8usize;
        let n = 1 << 12;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let comm = Communicator::split_even(p, 2);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = vec![7i32; n / p];
            sort_multilevel_det(ctx, &comm, &params, local, n, &cfg)
        });
        let total: usize = run.outputs.iter().map(|r| r.keys.len()).sum();
        assert_eq!(total, n);
        for (pid, r) in run.outputs.iter().enumerate() {
            assert!(r.keys.iter().all(|&k| k == 7));
            assert!(r.received > 0, "pid={pid} starved");
        }
        // Both groups hold a comparable share (no group-level collapse).
        let g0: usize = run.outputs[..4].iter().map(|r| r.keys.len()).sum();
        let g1: usize = run.outputs[4..].iter().map(|r| r.keys.len()).sum();
        assert!(g0 > n / 4 && g1 > n / 4, "g0={g0} g1={g1}");
    }

    #[test]
    fn level2_phases_and_group_records_present() {
        let (_, _, ledger) =
            run_multilevel(true, 8, 2, 1 << 12, Benchmark::Uniform, SortConfig::default());
        for ph in ["Ph2:SeqSort", "Ph5:Routing", "L2/Ph2:SeqSort", "L2/Ph5:Routing"] {
            assert!(
                ledger.phases.contains_key(ph),
                "missing phase {ph}: {:?}",
                ledger.phases.keys().collect::<Vec<_>>()
            );
        }
        // The level-1 route is a whole-machine superstep; level-2 routes
        // are group records over 4 processors each, moving half the
        // input per group.
        let l1: Vec<_> = ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "l1:route")
            .collect();
        assert_eq!(l1.len(), 1);
        assert_eq!(l1[0].procs, 8);
        assert_eq!(l1[0].total_words, 1 << 12);
        let l2: Vec<_> = ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "ph5:route" && s.round.is_some())
            .collect();
        assert_eq!(l2.len(), 2, "one level-2 route per group");
        for s in &l2 {
            assert_eq!(s.procs, 4);
            assert_eq!(s.phase, "L2/Ph5:Routing");
            assert!(
                s.total_words < l1[0].total_words,
                "level-2 routing must be group-local: {} vs {}",
                s.total_words,
                l1[0].total_words
            );
        }
        let l2_total: u64 = l2.iter().map(|s| s.total_words).sum();
        assert_eq!(l2_total, 1 << 12, "level 2 moves every key exactly once overall");
    }

    #[test]
    fn depth3_phases_and_cell_records_present() {
        // 2x2x2 on p=8: level-1 routing is whole-machine, level-2
        // routing is cell-scoped over 4 procs under L2/, the leaf runs
        // under L3/ over 2 procs.
        let (_, _, ledger) =
            run_deep(true, &[2, 2, 2], 1 << 12, Benchmark::Uniform, SortConfig::default());
        for ph in
            ["Ph2:SeqSort", "Ph5:Routing", "L2/Ph2:SeqSort", "L2/Ph5:Routing", "L3/Ph5:Routing"]
        {
            assert!(
                ledger.phases.contains_key(ph),
                "missing phase {ph}: {:?}",
                ledger.phases.keys().collect::<Vec<_>>()
            );
        }
        let l1: Vec<_> =
            ledger.supersteps.iter().filter(|s| s.label == "l1:route").collect();
        assert_eq!(l1.len(), 1);
        assert_eq!(l1[0].procs, 8);
        assert_eq!(l1[0].total_words, 1 << 12);
        // Level-2 routes: one per level-1 cell, over 4 procs each,
        // together moving every key exactly once.
        let l2: Vec<_> = ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "l2:route" && s.round.is_some())
            .collect();
        assert_eq!(l2.len(), 2, "one level-2 route per cell");
        for s in &l2 {
            assert_eq!(s.procs, 4);
            assert_eq!(s.phase, "L2/Ph5:Routing");
        }
        let l2_total: u64 = l2.iter().map(|s| s.total_words).sum();
        assert_eq!(l2_total, 1 << 12);
        // Leaf routes: one per leaf machine, over 2 procs each.
        let l3: Vec<_> = ledger
            .supersteps
            .iter()
            .filter(|s| s.label == "ph5:route" && s.round.is_some())
            .collect();
        assert_eq!(l3.len(), 4, "one leaf route per leaf machine");
        for s in &l3 {
            assert_eq!(s.procs, 2);
            assert_eq!(s.phase, "L3/Ph5:Routing");
        }
        let l3_total: u64 = l3.iter().map(|s| s.total_words).sum();
        assert_eq!(l3_total, 1 << 12);
    }

    #[test]
    fn deep_wrapper_depth2_matches_two_level_entry_point() {
        // sort_multilevel_det IS the depth-2 case of the level loop:
        // same outputs and same charged ledger through either entry.
        let p = 8usize;
        let n = 1 << 12;
        let params = cray_t3d(p);
        let cfg = SortConfig::default();
        let via_wrapper = {
            let machine = BspMachine::new(params);
            let comm = Communicator::split_even(p, 2);
            machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
                sort_multilevel_det(ctx, &comm, &params, local, n, &cfg).keys
            })
        };
        let via_deep = {
            let machine = BspMachine::new(params);
            let comms: Vec<Communicator> = Topology::new(&[2, 4]).communicators();
            machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
                sort_deep_det(ctx, &comms, &params, local, n, &cfg).keys
            })
        };
        assert_eq!(via_wrapper.outputs, via_deep.outputs);
    }
}
