//! Algorithm configuration: sequential backend, oversampling, duplicate
//! policy, and sample-sort method — the knobs §6.1/§6.2 describe.
//!
//! The *execution* backend selector ([`Backend`]: threaded engine pool
//! vs deterministic simulator) is re-exported here; it rides
//! `experiment::spec::RunSpec`/`RunConfig`, the `sorter::SortJob`
//! builder (and the CLI's `--backend`) rather than [`SortConfig`],
//! because the sorting algorithms themselves are backend-agnostic —
//! they only see a `BspScope`.
//!
//! [`SortConfig`] is likewise one *field* of a [`crate::sorter::SortJob`]
//! (`SortJob::config`): the job says what to sort and where, the config
//! says how the chosen variant behaves.

use crate::seq::SeqSortKind;

pub use crate::bsp::Backend;

/// Selectable local-sort engine for the per-processor base case —
/// the user-facing face of [`SeqSortKind`] (which additionally carries
/// the runtime-only `Xla` backend that cannot be chosen from a config
/// or the CLI).  Threaded through `SortJob::local_sort`, the CLI's
/// `sort --local-sort`, and the experiment sweep's `--local-sorts`
/// axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum LocalSortEngine {
    /// `seq::quicksort` — the paper's `[.SQ]` comparison base case.
    #[default]
    Quicksort,
    /// `seq::radixsort` — the paper's `[.SR]` LSD counting sort.
    LsdRadix,
    /// `seq::ips` — the in-place block-partitioning MSD engine
    /// (`[.SI]`, this repo's addition).
    Ips,
}

/// All selectable engines, in sweep order.
pub const ALL_ENGINES: [LocalSortEngine; 3] = [
    LocalSortEngine::Quicksort,
    LocalSortEngine::LsdRadix,
    LocalSortEngine::Ips,
];

impl LocalSortEngine {
    /// CLI/report tag (`quicksort` | `lsd-radix` | `ips`).
    pub fn tag(&self) -> &'static str {
        match self {
            LocalSortEngine::Quicksort => "quicksort",
            LocalSortEngine::LsdRadix => "lsd-radix",
            LocalSortEngine::Ips => "ips",
        }
    }

    /// Parse a CLI spelling; accepts the tags plus the historical
    /// `--seq` spellings (`quick`/`q`, `radix`/`r`, `i`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "quicksort" | "quick" | "q" => Some(LocalSortEngine::Quicksort),
            "lsd-radix" | "radix" | "r" => Some(LocalSortEngine::LsdRadix),
            "ips" | "i" => Some(LocalSortEngine::Ips),
            _ => None,
        }
    }

    /// The `SeqSortKind` this engine selects in [`SortConfig::seq`].
    pub fn seq_kind(&self) -> SeqSortKind {
        match self {
            LocalSortEngine::Quicksort => SeqSortKind::Quick,
            LocalSortEngine::LsdRadix => SeqSortKind::Radix,
            LocalSortEngine::Ips => SeqSortKind::Ips,
        }
    }

    /// Inverse of [`Self::seq_kind`]; `None` for the runtime-only
    /// `Xla` backend.
    pub fn from_seq(kind: SeqSortKind) -> Option<Self> {
        match kind {
            SeqSortKind::Quick => Some(LocalSortEngine::Quicksort),
            SeqSortKind::Radix => Some(LocalSortEngine::LsdRadix),
            SeqSortKind::Ips => Some(LocalSortEngine::Ips),
            SeqSortKind::Xla => None,
        }
    }
}

/// Transparent duplicate handling (§5.1.1) on or off.
///
/// `Off` reproduces the ablation of §6.4 ("Had we disabled the code for
/// handling duplicate keys..."): splitters are compared by key only, so
/// duplicate-heavy inputs may imbalance, but the 3–6 % tagging overhead
/// disappears.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DuplicatePolicy {
    /// §5.1.1 tagged splitters (the paper's implementations).
    #[default]
    Tagged,
    /// Tags stripped — the §6.4 ablation.
    Off,
}

/// How the sample gets sorted in step 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SampleSortMethod {
    /// Parallel Batcher bitonic sort (\[BSI\]) — the paper's choice.
    #[default]
    Bitonic,
    /// Ship the sample to processor 0 and sort sequentially
    /// (SORT_RAN_BSP's shape; also the right choice for tiny samples).
    Sequential,
}

/// Oversampling configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Oversampling {
    /// Deterministic regular oversampling with ω_n = lg lg n (§6.1:
    /// total sample p²⌈ω⌉).
    DetDefault,
    /// Randomized with ω_n² = lg n (§6.1: total sample 2pω² lg n).
    RanDefault,
    /// Explicit ω_n override (both algorithms accept it).
    Omega(f64),
}

impl Oversampling {
    /// Resolve ω_n for input size n.
    pub fn omega(&self, n: usize) -> f64 {
        let lgn = crate::util::lg(n as f64).max(1.0);
        match self {
            Oversampling::DetDefault => lgn.log2().max(1.0), // lg lg n
            Oversampling::RanDefault => lgn.sqrt().max(1.0), // ω² = lg n
            Oversampling::Omega(w) => w.max(1.0),
        }
    }
}

/// Full configuration of a sorting run.
#[derive(Clone, Copy, Debug)]
pub struct SortConfig {
    /// Sequential backend for the local sorts (\[.SQ\]/\[.SR\]/\[.SX\]).
    pub seq: SeqSortKind,
    /// Duplicate handling on (tagged) or off (the §6.4 ablation).
    pub dup: DuplicatePolicy,
    /// How the sample is sorted in step 5.
    pub sample_sort: SampleSortMethod,
    /// ω override; `None` uses each algorithm's §6.1 default.
    pub oversampling: Option<Oversampling>,
}

impl Default for SortConfig {
    fn default() -> Self {
        SortConfig {
            seq: SeqSortKind::Quick,
            dup: DuplicatePolicy::Tagged,
            sample_sort: SampleSortMethod::Bitonic,
            oversampling: None,
        }
    }
}

impl SortConfig {
    /// Replace the sequential backend.
    pub fn with_seq(mut self, seq: SeqSortKind) -> Self {
        self.seq = seq;
        self
    }

    /// Select the sequential backend by [`LocalSortEngine`] (the
    /// config-selectable subset of [`SeqSortKind`]).
    pub fn with_local_sort(self, engine: LocalSortEngine) -> Self {
        self.with_seq(engine.seq_kind())
    }

    /// Replace the duplicate policy.
    pub fn with_dup(mut self, dup: DuplicatePolicy) -> Self {
        self.dup = dup;
        self
    }

    /// Replace the sample-sort method.
    pub fn with_sample_sort(mut self, m: SampleSortMethod) -> Self {
        self.sample_sort = m;
        self
    }

    /// Override the oversampling factor ω.
    pub fn with_omega(mut self, w: f64) -> Self {
        self.oversampling = Some(Oversampling::Omega(w));
        self
    }

    /// Variant name in the paper's notation: \[DSQ\], \[DSR\], \[RSQ\], \[RSR\].
    pub fn variant_name(&self, deterministic: bool) -> String {
        format!(
            "[{}S{}]",
            if deterministic { 'D' } else { 'R' },
            self.seq.suffix()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omega_defaults_match_paper() {
        // n = 2^23 = 8M: lg n = 23, lg lg n ≈ 4.52, sqrt(lg n) ≈ 4.80.
        let n = 1usize << 23;
        let det = Oversampling::DetDefault.omega(n);
        assert!((det - 23.0f64.log2()).abs() < 1e-9);
        let ran = Oversampling::RanDefault.omega(n);
        assert!((ran - 23.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn variant_names() {
        let cfg = SortConfig::default();
        assert_eq!(cfg.variant_name(true), "[DSQ]");
        assert_eq!(
            cfg.with_seq(SeqSortKind::Radix).variant_name(false),
            "[RSR]"
        );
        assert_eq!(
            cfg.with_local_sort(LocalSortEngine::Ips).variant_name(true),
            "[DSI]"
        );
    }

    #[test]
    fn engine_tags_roundtrip_through_parse_and_seq_kind() {
        for engine in ALL_ENGINES {
            assert_eq!(LocalSortEngine::parse(engine.tag()), Some(engine));
            assert_eq!(LocalSortEngine::from_seq(engine.seq_kind()), Some(engine));
        }
        // Historical --seq spellings keep working.
        assert_eq!(LocalSortEngine::parse("quick"), Some(LocalSortEngine::Quicksort));
        assert_eq!(LocalSortEngine::parse("radix"), Some(LocalSortEngine::LsdRadix));
        assert_eq!(LocalSortEngine::parse("i"), Some(LocalSortEngine::Ips));
        assert_eq!(LocalSortEngine::parse("bogus"), None);
        assert_eq!(LocalSortEngine::from_seq(SeqSortKind::Xla), None);
    }
}
