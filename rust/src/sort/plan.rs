//! Cost-model-driven topology planning: choose the recursion depth and
//! group shape of the multi-level sorts from the machine parameters.
//!
//! The paper's closed forms exist precisely so algorithm shape can be
//! tuned to `(n, p, g, L)`; "Practical/Robust Massively Parallel
//! Sorting" (AMS) turn that into a recipe — pick the number of
//! recursion levels from the machine size and the relative cost of a
//! superstep.  This module is that recipe under the BSP model:
//! enumerate every divisor-tree topology `p = k1 × k2 × … × kd`
//! ([`enumerate_topologies`]), price each with the per-level closed
//! forms ([`crate::theory::predict_det_topology`] /
//! [`crate::theory::predict_ran_topology`]) under the calibrated
//! `(p, g, L)`, and return the argmin ([`plan_det`] / [`plan_ran`]).
//!
//! Intuition for the trade: an extra level pays one more `g·n/p`
//! routing pass and a coarse splitter round, and buys sample-sort and
//! synchronization terms that scale with the *cell* size instead of the
//! machine size.  Cheap-L machines at small `p` therefore plan flat;
//! high-L machines at large `p` plan deep — `ci.sh --planner-smoke`
//! asserts exactly that.

use crate::bsp::group::{Topology, MAX_TOPOLOGY_DEPTH};
use crate::bsp::params::BspParams;
use crate::theory::{self, MultilevelPrediction};

/// A planner decision: the chosen topology and the closed-form
/// prediction that won.  `predicted.effective` always equals the chosen
/// topology's factor vector — the planner never selects a shape whose
/// levels would degrade (those price identically to a shallower shape,
/// which the enumeration also contains and which wins the `<` tie-break
/// by coming first).
#[derive(Clone, Debug)]
pub struct Plan {
    /// The argmin topology.
    pub topology: Topology,
    /// Its closed-form prediction (total cost = `prediction.total_secs`).
    pub predicted: MultilevelPrediction,
    /// Predicted seconds under the planning parameters (the comparison
    /// key).
    pub predicted_secs: f64,
}

/// Every divisor-tree topology of `p`: the flat `[p]` plus all ordered
/// factorizations into factors ≥ 2 (depth-first, shallow shapes first
/// within a prefix).  For `p = 2^m` this is `2^(m−1)` shapes (2048 at
/// p = 4096) — cheap to price exhaustively with closed forms.
pub fn enumerate_topologies(p: usize) -> Vec<Topology> {
    fn rec(rem: usize, prefix: &mut Vec<usize>, out: &mut Vec<Topology>) {
        // Close here: `rem` becomes the leaf machine size.
        prefix.push(rem);
        out.push(Topology::new(prefix));
        prefix.pop();
        if prefix.len() + 2 > MAX_TOPOLOGY_DEPTH {
            return;
        }
        // Or split off one more routing level (factor < rem so the
        // remainder shrinks; factor ≥ 2 so the level is non-degenerate).
        for k in 2..rem {
            if rem % k == 0 {
                prefix.push(k);
                rec(rem / k, prefix, out);
                prefix.pop();
            }
        }
    }
    assert!(p >= 1, "need at least one processor");
    let mut out = Vec::new();
    rec(p, &mut Vec::new(), &mut out);
    out
}

fn argmin_plan(
    params: &BspParams,
    mut price: impl FnMut(&[usize]) -> MultilevelPrediction,
) -> Plan {
    let mut best: Option<Plan> = None;
    for topology in enumerate_topologies(params.p) {
        let predicted = price(&topology.dims());
        let predicted_secs = predicted.prediction.total_secs(params);
        // Strict `<`: ties keep the earliest (shallowest-first) shape,
        // so the planner is deterministic and never picks needless depth.
        let better = match &best {
            None => true,
            Some(b) => predicted_secs < b.predicted_secs,
        };
        if better {
            best = Some(Plan { topology, predicted, predicted_secs });
        }
    }
    best.expect("enumerate_topologies returns at least the flat topology")
}

/// Plan the deterministic multi-level sort: the divisor-tree topology
/// minimizing [`theory::predict_det_topology`] under `params` for an
/// `n`-key input with oversampling `omega`.
pub fn plan_det(n: usize, params: &BspParams, omega: f64) -> Plan {
    argmin_plan(params, |dims| theory::predict_det_topology(n, params, omega, dims))
}

/// Plan the randomized multi-level sort: the argmin of
/// [`theory::predict_ran_topology`].
pub fn plan_ran(n: usize, params: &BspParams, omega: f64) -> Plan {
    argmin_plan(params, |dims| theory::predict_ran_topology(n, params, omega, dims))
}

/// Strictly parse a `--topology` value (`"8x4x4"`) against machine size
/// `p`: every factor must be an integer ≥ 2 (or the single factor `p`
/// itself) and the factors must multiply to exactly `p`.  The error
/// lists valid shapes, mirroring the CLI's `UnknownBenchmark` style.
pub fn parse_topology(s: &str, p: usize) -> Result<Topology, String> {
    let err = |msg: &str| {
        Err(format!(
            "invalid topology {s:?} for p={p}: {msg}; valid topologies: {}",
            valid_topology_hint(p)
        ))
    };
    let mut factors = Vec::new();
    for part in s.split('x') {
        match part.trim().parse::<usize>() {
            Ok(k) if k >= 1 => factors.push(k),
            _ => return err(&format!("{part:?} is not a positive integer")),
        }
    }
    if factors.is_empty() || factors.len() > MAX_TOPOLOGY_DEPTH {
        return err(&format!("depth must be 1..={MAX_TOPOLOGY_DEPTH}"));
    }
    if factors.len() > 1 && factors.iter().any(|&k| k < 2) {
        return err("every factor of a multi-level shape must be at least 2");
    }
    let product: usize = factors.iter().product();
    if product != p {
        return err(&format!("factors multiply to {product}, not p"));
    }
    Ok(Topology::new(&factors))
}

/// Strictly parse a `--groups` value: `k` must divide `p` (yielding the
/// depth-2 topology `[k, p/k]`, or flat for `k = 1`).  The error lists
/// the divisors of `p`, mirroring `UnknownBenchmark`.
pub fn parse_groups(k: usize, p: usize) -> Result<Topology, String> {
    if k >= 1 && k <= p && p % k == 0 {
        if k == 1 {
            Ok(Topology::flat(p))
        } else {
            Ok(Topology::two_level(p, k))
        }
    } else {
        let divisors: Vec<String> =
            (1..=p).filter(|d| p % d == 0).map(|d| d.to_string()).collect();
        Err(format!(
            "invalid group count {k} for p={p}; valid group counts: {}",
            divisors.join(", ")
        ))
    }
}

/// A short human list of valid shapes for `p`: all of them when few,
/// otherwise the flat and depth-2 shapes with an ellipsis.
fn valid_topology_hint(p: usize) -> String {
    let all = enumerate_topologies(p);
    if all.len() <= 12 {
        all.iter().map(Topology::label).collect::<Vec<_>>().join(", ")
    } else {
        let two_level: Vec<String> = all
            .iter()
            .filter(|t| t.depth() <= 2)
            .map(Topology::label)
            .collect();
        format!("{}, … ({} deeper shapes)", two_level.join(", "), all.len() - two_level.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    #[test]
    fn enumerates_all_divisor_trees() {
        let labels = |p: usize| -> Vec<String> {
            enumerate_topologies(p).iter().map(Topology::label).collect()
        };
        assert_eq!(labels(1), vec!["1"]);
        assert_eq!(labels(4), vec!["4", "2x2"]);
        assert_eq!(labels(8), vec!["8", "2x4", "2x2x2", "4x2"]);
        assert_eq!(labels(12).len(), 8); // 12, 2x6, 2x2x3, 2x3x2, 3x4, 3x2x2, 4x3, 6x2
        // 2^m has 2^(m−1) ordered factorizations.
        assert_eq!(labels(64).len(), 32);
        assert_eq!(labels(4096).len(), 2048);
        for t in enumerate_topologies(4096) {
            assert_eq!(t.nprocs(), 4096, "{}", t.label());
        }
    }

    #[test]
    fn parse_topology_accepts_valid_shapes() {
        assert_eq!(parse_topology("8x4x4", 128).unwrap().dims(), vec![8, 4, 4]);
        assert_eq!(parse_topology("64", 64).unwrap(), Topology::flat(64));
        assert_eq!(parse_topology("2x4", 8).unwrap(), Topology::two_level(8, 2));
    }

    #[test]
    fn parse_topology_rejects_and_lists_valid() {
        let e = parse_topology("8x3", 64).unwrap_err();
        assert!(e.contains("multiply to 24"), "{e}");
        assert!(e.contains("valid topologies"), "{e}");
        assert!(e.contains("2x32"), "{e}");
        let e = parse_topology("4xfour", 16).unwrap_err();
        assert!(e.contains("not a positive integer"), "{e}");
        let e = parse_topology("1x16", 16).unwrap_err();
        assert!(e.contains("at least 2"), "{e}");
    }

    #[test]
    fn parse_groups_rejects_non_divisors() {
        assert_eq!(parse_groups(4, 16).unwrap(), Topology::two_level(16, 4));
        assert_eq!(parse_groups(1, 16).unwrap(), Topology::flat(16));
        let e = parse_groups(3, 16).unwrap_err();
        assert!(e.contains("valid group counts: 1, 2, 4, 8, 16"), "{e}");
    }

    #[test]
    fn planner_never_reports_a_degraded_topology() {
        // The winning plan's effective vector equals its factor vector:
        // a shape with degradable levels prices identically to the
        // shallower shape that enumerates first, so it can never win.
        for p in [4usize, 8, 64, 256] {
            let params = cray_t3d(p);
            for plan in [plan_det(1 << 20, &params, 4.0), plan_ran(1 << 20, &params, 4.5)] {
                assert_eq!(
                    plan.predicted.effective,
                    plan.topology.dims(),
                    "p={p} chose {}",
                    plan.topology.label()
                );
            }
        }
    }

    #[test]
    fn planner_smoke_small_p_cheap_l_picks_flat() {
        // Small machine, negligible synchronization cost: no routing
        // level can pay for itself, the planner must stay one-level.
        let params = BspParams::host(8, 1.0, 0.1, 10.0);
        let plan = plan_det(1 << 20, &params, 4.0);
        assert_eq!(plan.topology, Topology::flat(8), "chose {}", plan.topology.label());
    }

    #[test]
    fn planner_smoke_high_l_picks_deeper_topology() {
        // Large machine with a punishing L: the one-level bitonic
        // sample sort pays L·lg²p; recursion over smaller cells must
        // win, and the chosen shape must be a real (priced) one.
        let params = BspParams::host(1024, 200_000.0, 0.5, 10.0);
        let plan = plan_det(1 << 22, &params, 4.0);
        assert!(
            plan.topology.depth() >= 2,
            "expected a multi-level plan under high L, got {}",
            plan.topology.label()
        );
        assert_eq!(plan.predicted.effective, plan.topology.dims());
        // And the flat shape is strictly worse under these parameters.
        let flat = theory::predict_det_topology(1 << 22, &params, 4.0, &[1024]);
        assert!(plan.predicted_secs < flat.prediction.total_secs(&params));
    }
}
