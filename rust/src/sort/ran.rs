//! SORT_RAN_BSP (Figure 2): the classic randomized sample-sort of [21],
//! kept as a *design baseline* (the paper implements SORT_IRAN_BSP
//! instead, §5.2, because of this algorithm's two weaknesses):
//!
//! 1. step 9's set formation is an integer sort with a significant
//!    constant `D` (every key is binary-searched into the splitters and
//!    copied into its destination bucket);
//! 2. step 12 local sorting runs on `(1 + 1/ω)·n/p` keys — *after* the
//!    imbalanced routing — instead of exactly `n/p` before it.
//!
//! Pattern: sample → splitters (sequentially, at processor 0) → route →
//! local sort.  Tags are per-key implicit `(pid, original index)`; sample
//! records carry them so duplicate-heavy inputs still split evenly.

use crate::bsp::engine::BspScope;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::RadixKey;
use crate::primitives::broadcast;
use crate::seq::{ops, IpsSorter, QuickSorter, RadixSorter, SeqSortKind, SeqSorter};
use crate::util::rng::SplitMix64;

use super::common::{splitter_rank, ProcResult, PH3, PH5, PH6, PH7};
use super::config::SortConfig;
use super::iran::{omega_ran, sample_share};

/// Run SORT_RAN_BSP on this processor's share of the input.
///
/// Generic over the [`BspScope`], so the same program runs on the whole
/// machine or group-locally inside a multi-level sort.
pub fn sort_ran_bsp<K: RadixKey, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
) -> ProcResult<K> {
    let p = ctx.nprocs();
    let pid = ctx.pid();
    let sorter: &dyn SeqSorter<K> = match cfg.seq {
        SeqSortKind::Quick => &QuickSorter,
        SeqSortKind::Radix => &RadixSorter,
        SeqSortKind::Ips => &IpsSorter,
        SeqSortKind::Xla => panic!("SORT_RAN_BSP supports Quick/Radix/Ips backends"),
    };

    if p == 1 {
        let mut keys = local;
        ctx.phase(PH6);
        ctx.charge(sorter.charge(keys.len()));
        sorter.sort(&mut keys);
        return ProcResult { received: keys.len(), runs: 1, keys };
    }

    // --- Ph3: random sample, gathered and sorted at processor 0 --------
    ctx.phase(PH3);
    let omega = omega_ran(cfg, n_total);
    let share = sample_share(n_total, p, omega).min(local.len().max(1));
    let mut rng = SplitMix64::new(seed ^ ((pid as u64) << 20).wrapping_add(0x5A5A));
    let sample: Vec<SampleRec<K>> = if local.is_empty() {
        vec![SampleRec::new(K::max_key(), pid, 0)]
    } else {
        rng.sample_indices(local.len(), share)
            .into_iter()
            .map(|i| SampleRec::new(local[i], pid, i))
            .collect()
    };
    ctx.charge(share as f64);
    ctx.send(0, Payload::Recs(sample));
    ctx.sync("ph3:gather-sample");
    let splitters = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        let seg = (all.len() / p).max(1);
        (1..p).map(|i| all[(i * seg - 1).min(all.len() - 1)]).collect()
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let splitters = broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, "ph3:bcast");

    // --- step 9: bucket formation (the costly integer-sort step) -------
    ctx.phase(PH5);
    // Each key binary-searches the splitter set: (n/p)(lg p + 1) charges,
    // plus the D·n/p copy into buckets (D charged as 2: count + copy).
    let mut buckets: Vec<Vec<K>> = vec![Vec::new(); p];
    for (i, &k) in local.iter().enumerate() {
        let dst = splitter_rank(&splitters, k, pid, i);
        buckets[dst].push(k);
    }
    ctx.charge(local.len() as f64 * (ops::bsearch_charge(p) + 1.0 + 2.0));

    // --- step 11: routing ----------------------------------------------
    let parts: Vec<Payload<K>> = buckets.into_iter().map(Payload::Keys).collect();
    let inbox = ctx.all_to_all(parts, "ph5:route");

    // --- step 12: local sort of everything received ---------------------
    ctx.phase(PH6);
    let mut keys: Vec<K> = Vec::new();
    let mut runs = 0usize;
    for (_, payload) in inbox {
        let ks = payload.into_keys();
        if !ks.is_empty() {
            runs += 1;
        }
        keys.extend_from_slice(&ks);
    }
    let received = keys.len();
    ctx.charge(sorter.charge(received));
    sorter.sort(&mut keys);

    ctx.phase(PH7);
    ctx.sync("ph7:done");

    ProcResult { keys, received, runs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn run_ran(p: usize, n_total: usize, bench: Benchmark, seed: u64) -> (Vec<Vec<i32>>, Vec<ProcResult>) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n_total / p);
            let input = local.clone();
            let out = sort_ran_bsp(ctx, &params, local, n_total, &cfg, seed);
            (input, out)
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results)
    }

    #[test]
    fn sorts_every_benchmark() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results) = run_ran(4, 1 << 12, bench, 11);
            let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
            expect.sort_unstable();
            let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
            assert_eq!(got, expect, "{}", bench.tag());
        }
    }

    #[test]
    fn sorts_p1_and_p2() {
        for p in [1usize, 2] {
            let (inputs, results) = run_ran(p, 1 << 10, Benchmark::Uniform, 3);
            let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
            expect.sort_unstable();
            let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn all_equal_keys_balanced_via_tags() {
        let p = 8usize;
        let n = 1 << 13;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = vec![1i32; n / p];
            sort_ran_bsp(ctx, &params, local, n, &cfg, 13)
        });
        let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
        // With per-key implicit tags the all-equal input still spreads.
        assert!(max_recv < n / 2, "max_recv={max_recv}");
    }
}
