//! SORT_DET_BSP (Figure 1): one-optimal deterministic BSP sorting by
//! *regular oversampling* [22, 27, 28].
//!
//! Per processor: local sort (Ph2); form a regular sample of
//! `s = ⌈ω_n⌉·p` tagged records (Ph3, §5.1.1 tags); parallel bitonic
//! sample sort and splitter broadcast (steps 5–7); partition + prefix
//! (Ph4); one-round routing (Ph5); stable p-way merge (Ph6).
//!
//! Lemma 5.1 bounds the received keys per processor by
//! `(1 + 1/⌈ω⌉)·n/p + ⌈ω⌉·p` — the invariant our integration tests
//! check for every benchmark distribution.

use crate::bsp::engine::BspScope;
use crate::bsp::params::BspParams;
use crate::key::{Key, RadixKey};
use crate::seq::{IpsSorter, SeqSorter, SeqSortKind, QuickSorter, RadixSorter};

use super::common::{self, ProcResult, PH2, PH3};
use super::config::{Oversampling, SortConfig};

/// ω_n for the deterministic algorithm: the paper's experiments use
/// `ω_n = lg lg n` (§6.1), overridable via the config.
pub fn omega_det(cfg: &SortConfig, n_total: usize) -> f64 {
    cfg.oversampling.unwrap_or(Oversampling::DetDefault).omega(n_total)
}

/// Lemma 5.1 bound on keys per processor after routing.
pub fn nmax_bound(n_total: usize, p: usize, omega: f64) -> f64 {
    let r = omega.ceil().max(1.0);
    (1.0 + 1.0 / r) * (n_total as f64 / p as f64) + r * p as f64
}

/// Run SORT_DET_BSP on this processor's share `local` of the input.
///
/// SPMD: every processor calls this inside `BspMachine::run` (or
/// `run_keys` for a non-default key domain).  `n_total` is the global
/// input size (known to all, as in the paper).  Returns this processor's
/// chunk of the global sorted order plus routing stats.  `K: RadixKey`
/// because `cfg.seq` may select the radix backend; a quicksort-only
/// custom key type goes through [`sort_det_bsp_with`].
///
/// Generic over the [`BspScope`]: the identical program runs on the
/// whole machine (`BspCtx`) or group-locally (`bsp::group::GroupCtx`,
/// which is how `sort::multilevel` reuses it as its level-2 sort).
pub fn sort_det_bsp<K: RadixKey, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    mut local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
) -> ProcResult<K> {
    // Static backends need no boxing — keep the per-run setup
    // allocation-free like the rest of the hot path.
    let sorter: &dyn SeqSorter<K> = match cfg.seq {
        SeqSortKind::Quick => &QuickSorter,
        SeqSortKind::Radix => &RadixSorter,
        SeqSortKind::Ips => &IpsSorter,
        SeqSortKind::Xla => panic!("use sort_det_bsp_with for a custom backend"),
    };
    sort_det_bsp_with(ctx, params, &mut local, n_total, cfg, sorter)
}

/// As [`sort_det_bsp`] but with an explicit sequential backend (used by
/// the XLA-backed variant and by tests injecting instrumented sorters);
/// only the bare [`Key`] contract is required of the domain.
pub fn sort_det_bsp_with<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    local: &mut Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    sorter: &dyn SeqSorter<K>,
) -> ProcResult<K> {
    let p = ctx.nprocs();

    // --- Ph2: local sort ----------------------------------------------
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    let mut keys = std::mem::take(local);
    sorter.sort(&mut keys);

    // --- Ph3: regular oversampling + parallel sample sort --------------
    ctx.phase(PH3);
    let omega = omega_det(cfg, n_total);
    let r = omega.ceil().max(1.0) as usize;
    let s = r * p;
    let sample = common::regular_sample(&keys, ctx.pid(), s);
    ctx.charge(s as f64); // sample formation is O(s)
    let splitters =
        common::sample_sort_and_splitters(ctx, params, sample, cfg.sample_sort, "ph3");

    // --- Ph4..Ph7: shared pipeline --------------------------------------
    common::partition_route_merge(ctx, keys, &splitters, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn run_det(p: usize, n_total: usize, bench: Benchmark, cfg: SortConfig) -> (Vec<Vec<i32>>, Vec<ProcResult>) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n_total / p);
            let input = local.clone();
            let out = sort_det_bsp(ctx, &params, local, n_total, &cfg);
            (input, out)
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results)
    }

    fn assert_sorted_permutation(inputs: &[Vec<i32>], results: &[ProcResult]) {
        let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn sorts_uniform_various_p() {
        for p in [1usize, 2, 4, 8] {
            let (inputs, results) =
                run_det(p, 1 << 12, Benchmark::Uniform, SortConfig::default());
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn sorts_every_benchmark() {
        for bench in ALL_BENCHMARKS {
            let (inputs, results) = run_det(4, 1 << 12, bench, SortConfig::default());
            assert_sorted_permutation(&inputs, &results);
        }
    }

    #[test]
    fn radix_variant_sorts() {
        let cfg = SortConfig::default().with_seq(SeqSortKind::Radix);
        let (inputs, results) = run_det(8, 1 << 13, Benchmark::Staggered, cfg);
        assert_sorted_permutation(&inputs, &results);
    }

    #[test]
    fn imbalance_respects_lemma_5_1() {
        for bench in ALL_BENCHMARKS {
            let p = 8usize;
            let n = 1 << 14;
            let cfg = SortConfig::default();
            let (_, results) = run_det(p, n, bench, cfg);
            let omega = omega_det(&cfg, n);
            let bound = nmax_bound(n, p, omega);
            for (pid, r) in results.iter().enumerate() {
                assert!(
                    (r.received as f64) <= bound + 1.0,
                    "{} pid={pid}: received {} > bound {bound}",
                    bench.tag(),
                    r.received
                );
            }
        }
    }

    #[test]
    fn all_equal_keys_stay_balanced() {
        // The §5.1.1 headline: optimal performance even if all keys are
        // the same.  Without tags every key would land on one processor.
        let p = 8usize;
        let n = 1 << 13;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = vec![7i32; n / p];
            sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        let bound = nmax_bound(n, p, omega_det(&cfg, n));
        for (pid, r) in run.outputs.iter().enumerate() {
            assert_eq!(r.keys, vec![7i32; r.keys.len()]);
            assert!(
                (r.received as f64) <= bound + 1.0,
                "pid={pid} received={} bound={bound}",
                r.received
            );
            assert!(r.received > 0, "pid={pid} starved");
        }
    }

    #[test]
    fn duplicate_policy_off_degrades_on_all_equal() {
        use super::super::config::DuplicatePolicy;
        let p = 4usize;
        let n = 1 << 10;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default().with_dup(DuplicatePolicy::Off);
        let run = machine.run(|ctx| {
            let local = vec![7i32; n / p];
            sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        // Still sorted overall...
        let total: usize = run.outputs.iter().map(|r| r.keys.len()).sum();
        assert_eq!(total, n);
        // ...but maximally imbalanced: one processor got everything.
        let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
        assert_eq!(max_recv, n, "without tags all equal keys collapse onto one processor");
    }

    #[test]
    fn sequential_sample_sort_also_works() {
        use super::super::config::SampleSortMethod;
        let cfg = SortConfig::default().with_sample_sort(SampleSortMethod::Sequential);
        let (inputs, results) = run_det(4, 1 << 12, Benchmark::Gaussian, cfg);
        assert_sorted_permutation(&inputs, &results);
    }

    #[test]
    fn phase_ledger_contains_paper_phases() {
        let p = 4;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, 1 << 10);
            sort_det_bsp(ctx, &params, local, 4 << 10, &cfg)
        });
        for ph in [PH2, PH3, "Ph4:Prefix", "Ph5:Routing", "Ph6:Merging"] {
            assert!(
                run.ledger.phases.contains_key(ph),
                "missing phase {ph}: {:?}",
                run.ledger.phases.keys().collect::<Vec<_>>()
            );
        }
    }
}
