//! The pipeline shared by SORT_DET_BSP and SORT_IRAN_BSP after sampling:
//! parallel (or sequential) sample sort → splitter selection → broadcast
//! → partition (binary search with §5.1.1 tags) → prefix → one-round key
//! routing → stable p-way merge.
//!
//! Phase labels match Tables 4–7: Ph1 Init, Ph2 SeqSort, Ph3 Sampling,
//! Ph4 Prefix, Ph5 Routing, Ph6 Merging, Ph7 Termination.

use crate::bsp::engine::BspCtx;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::primitives::{bitonic, broadcast};
use crate::seq::{ops, search};

use super::config::{DuplicatePolicy, SampleSortMethod, SortConfig};

pub const PH1: &str = "Ph1:Init";
pub const PH2: &str = "Ph2:SeqSort";
pub const PH3: &str = "Ph3:Sampling";
pub const PH4: &str = "Ph4:Prefix";
pub const PH5: &str = "Ph5:Routing";
pub const PH6: &str = "Ph6:Merging";
pub const PH7: &str = "Ph7:Term";

/// Per-processor result of a sorting run.
#[derive(Clone, Debug)]
pub struct ProcResult {
    /// This processor's chunk of the global sorted order.
    pub keys: Vec<i32>,
    /// Keys received during routing (the Lemma 5.1 imbalance subject).
    pub received: usize,
    /// Number of non-empty runs merged in Ph6.
    pub runs: usize,
}

/// Sort the (locally sorted) sample runs and return the `p−1` splitters,
/// broadcast to every processor.
///
/// * `Bitonic` — the paper's parallel sample sort: distributed Batcher
///   bitonic over the tagged records, then processors `0..p−1` each
///   donate the last record of their chunk (= the evenly spaced
///   positions `s, 2s, …, (p−1)s` of the sorted sample) to processor 0,
///   which broadcasts the splitter set (steps 5–7 / Lemma 4.1).
/// * `Sequential` — gather the whole sample at processor 0, sort there,
///   select evenly spaced splitters, broadcast (SORT_RAN_BSP's shape).
pub fn sample_sort_and_splitters(
    ctx: &mut BspCtx,
    params: &BspParams,
    sample: Vec<SampleRec>,
    method: SampleSortMethod,
    label: &str,
) -> Vec<SampleRec> {
    let p = ctx.nprocs();
    if p == 1 {
        return Vec::new();
    }
    match method {
        SampleSortMethod::Bitonic => {
            let s = sample.len();
            let sorted_chunk = bitonic::bitonic_sort(ctx, sample, &format!("{label}:bsi"));
            debug_assert_eq!(sorted_chunk.len(), s);
            // Processor i < p−1 holds global positions [i·s, (i+1)·s); the
            // splitter at 1-indexed position (i+1)·s is its last record.
            if ctx.pid() < p - 1 {
                let last = *sorted_chunk.last().expect("nonempty sample chunk");
                ctx.send(0, Payload::Recs(vec![last]));
            }
            ctx.charge(1.0);
            ctx.sync(&format!("{label}:gather-splitters"));
            let splitters = if ctx.pid() == 0 {
                let mut recs: Vec<(usize, SampleRec)> = ctx
                    .take_inbox()
                    .into_iter()
                    .map(|(src, payload)| (src, payload.into_recs()[0]))
                    .collect();
                recs.sort_by_key(|(src, _)| *src);
                recs.into_iter().map(|(_, r)| r).collect()
            } else {
                ctx.take_inbox();
                Vec::new()
            };
            broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, &format!("{label}:bcast"))
        }
        SampleSortMethod::Sequential => {
            ctx.send(0, Payload::Recs(sample));
            ctx.sync(&format!("{label}:gather-sample"));
            let splitters = if ctx.pid() == 0 {
                let mut all: Vec<SampleRec> = ctx
                    .take_inbox()
                    .into_iter()
                    .flat_map(|(_, payload)| payload.into_recs())
                    .collect();
                ctx.charge(ops::sort_charge(all.len()));
                all.sort();
                // p−1 evenly spaced splitters over p segments.
                let seg = all.len() / p;
                (1..p).map(|i| all[i * seg - 1]).collect()
            } else {
                ctx.take_inbox();
                Vec::new()
            };
            broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, &format!("{label}:bcast"))
        }
    }
}

/// Steps 8–13 for the locally *sorted* algorithms (DET and IRAN):
/// partition the sorted local keys at the splitters (binary search with
/// tagged tie-break), run the Ph4 prefix over bucket counts, route each
/// contiguous slice in a single superstep, and stable-merge the received
/// runs.
pub fn partition_route_merge(
    ctx: &mut BspCtx,
    keys: Vec<i32>,
    splitters: &[SampleRec],
    cfg: &SortConfig,
) -> ProcResult {
    let p = ctx.nprocs();
    let pid = ctx.pid();
    let n_local = keys.len();

    if p == 1 {
        return ProcResult {
            received: keys.len(),
            runs: 1,
            keys,
        };
    }

    // --- Ph4: partition + parallel prefix over bucket counts ---------
    ctx.phase(PH4);
    // Binary search of the p−1 splitters into the local sorted keys
    // (the cheaper direction, as §5.2 notes): (p−1)·⌈lg(n/p)⌉ charges.
    let effective: Vec<SampleRec> = match cfg.dup {
        DuplicatePolicy::Tagged => splitters.to_vec(),
        // Ablation: strip tags so ties resolve by key only.
        DuplicatePolicy::Off => splitters
            .iter()
            .map(|s| SampleRec { key: s.key, proc: 0, idx: 0 })
            .collect(),
    };
    let cuts = search::partition_points(&keys, pid, &effective);
    ctx.charge((p as f64 - 1.0) * ops::bsearch_charge(n_local.max(2)));
    let counts: Vec<u64> = cuts.windows(2).map(|w| (w[1] - w[0]) as u64).collect();
    // p independent prefix operations over the bucket counts: the
    // offsets are where this processor's slice lands at each receiver —
    // the information the paper's step 9 computes (and our stability
    // audit checks); the sender-ordered delivery realizes the placement.
    let (offsets, totals) = crate::primitives::prefix::prefix_direct(ctx, &counts, "ph4:prefix");
    debug_assert_eq!(offsets.len(), p);
    let _expected_recv = totals[pid];

    // --- Ph5: one-round key routing -----------------------------------
    ctx.phase(PH5);
    let mut slices: Vec<Payload> = Vec::with_capacity(p);
    for i in 0..p {
        slices.push(Payload::Keys(keys[cuts[i]..cuts[i + 1]].to_vec()));
    }
    ctx.charge(ops::linear_charge(n_local)); // slice copy-out
    let inbox = ctx.all_to_all(slices, "ph5:route");

    // --- Ph6: stable multi-way merge ----------------------------------
    ctx.phase(PH6);
    let runs: Vec<Vec<i32>> = inbox
        .into_iter()
        .map(|(_, payload)| payload.into_keys())
        .filter(|r| !r.is_empty())
        .collect();
    let received: usize = runs.iter().map(|r| r.len()).sum();
    debug_assert_eq!(received as u64, totals[pid] , "prefix totals must match received keys");
    ctx.charge(ops::merge_charge(received, runs.len().max(2)));
    let merged = crate::seq::multiway_merge(&runs);

    // --- Ph7 ----------------------------------------------------------
    ctx.phase(PH7);
    ctx.sync("ph7:done");

    ProcResult {
        keys: merged,
        received,
        runs: runs.len(),
    }
}

/// Evenly spaced sample of a *sorted* local run (step 4 of SORT_DET_BSP):
/// `s−1` boundary keys of `s` equal segments plus the local maximum, as
/// tagged records.  Padding semantics: segment size is
/// `x = ⌈⌈n/p⌉/s⌉`; positions past the end read the local maximum with
/// their (virtual) padded index as the tag, keeping tags distinct.
pub fn regular_sample(keys: &[i32], pid: usize, s: usize) -> Vec<SampleRec> {
    debug_assert!(s >= 1);
    let n = keys.len();
    if n == 0 {
        return vec![SampleRec::new(i32::MAX, pid, 0); s];
    }
    let x = n.div_ceil(s).max(1);
    let mut out = Vec::with_capacity(s);
    for j in 1..s {
        let idx = j * x - 1;
        if idx < n {
            out.push(SampleRec::new(keys[idx], pid, idx));
        } else {
            // Padded position: key = local max, tag keeps the virtual
            // index so records stay distinct under the tagged order.
            out.push(SampleRec::new(keys[n - 1], pid, idx));
        }
    }
    // Append the maximum of the local run (paper step 4).
    out.push(SampleRec::new(keys[n - 1], pid, s * x - 1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regular_sample_even_spacing() {
        let keys: Vec<i32> = (0..100).collect();
        let sample = regular_sample(&keys, 2, 10);
        assert_eq!(sample.len(), 10);
        // x = 10; boundaries at indices 9, 19, ..., 89; then max.
        let expect: Vec<i32> = (1..10).map(|j| (j * 10 - 1) as i32).chain([99]).collect();
        let got: Vec<i32> = sample.iter().map(|r| r.key).collect();
        assert_eq!(got, expect);
        assert!(sample.iter().all(|r| r.proc == 2));
    }

    #[test]
    fn regular_sample_short_input_pads_with_max() {
        let keys = vec![5, 9];
        let sample = regular_sample(&keys, 0, 4);
        assert_eq!(sample.len(), 4);
        assert_eq!(sample.last().unwrap().key, 9);
        // All padded positions carry the max key.
        assert!(sample.iter().skip(1).all(|r| r.key == 9));
        // Tags stay strictly increasing (distinctness).
        assert!(sample.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn regular_sample_is_sorted_under_tag_order() {
        let keys = vec![3; 64];
        let sample = regular_sample(&keys, 1, 8);
        assert!(sample.windows(2).all(|w| w[0] < w[1]));
    }
}
