//! The pipeline shared by SORT_DET_BSP and SORT_IRAN_BSP after sampling:
//! parallel (or sequential) sample sort → splitter selection → broadcast
//! → partition (binary search with §5.1.1 tags) → prefix → one-round key
//! routing → stable p-way merge.
//!
//! Phase labels match Tables 4–7: Ph1 Init, Ph2 SeqSort, Ph3 Sampling,
//! Ph4 Prefix, Ph5 Routing, Ph6 Merging, Ph7 Termination.

use crate::bsp::engine::BspScope;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::Key;
use crate::primitives::{bitonic, broadcast};
use crate::seq::{ops, search};

use super::config::{DuplicatePolicy, SampleSortMethod, SortConfig};

/// Ph1 — initialization (the default phase before any `phase()` call).
pub const PH1: &str = "Ph1:Init";
/// Ph2 — sequential local sort.
pub const PH2: &str = "Ph2:SeqSort";
/// Ph3 — sample formation, sample sort and splitter broadcast.
pub const PH3: &str = "Ph3:Sampling";
/// Ph4 — partition at the splitters + parallel prefix over counts.
pub const PH4: &str = "Ph4:Prefix";
/// Ph5 — the one-round key routing (the h-relation the tables price).
pub const PH5: &str = "Ph5:Routing";
/// Ph6 — stable multi-way merge of the received runs.
pub const PH6: &str = "Ph6:Merging";
/// Ph7 — termination.
pub const PH7: &str = "Ph7:Term";

/// Per-processor result of a sorting run (key domain defaults to the
/// paper's `i32`).
#[derive(Clone, Debug)]
pub struct ProcResult<K = i32> {
    /// This processor's chunk of the global sorted order.
    pub keys: Vec<K>,
    /// Keys received during routing (the Lemma 5.1 imbalance subject).
    pub received: usize,
    /// Number of non-empty runs merged in Ph6.
    pub runs: usize,
}

/// `p−1` evenly spaced splitters from the gathered, *sorted* sample.
///
/// Uses the rank formula `⌊i·m/p⌋ − 1` (clamped into the sample) rather
/// than the segment-width shortcut `i·(m/p) − 1`: the shortcut
/// underflows when the gathered sample is smaller than `p` (`m/p == 0`).
/// For the regular case `m = s·p` the two agree exactly.  An empty
/// sample yields maximal sentinel splitters so every key stays in the
/// low buckets instead of panicking.
pub fn select_splitters<K: Key>(sorted: &[SampleRec<K>], p: usize) -> Vec<SampleRec<K>> {
    if p <= 1 {
        return Vec::new();
    }
    let m = sorted.len();
    if m == 0 {
        return vec![SampleRec::max_rec(); p - 1];
    }
    (1..p)
        .map(|i| sorted[((i * m) / p).saturating_sub(1).min(m - 1)])
        .collect()
}

/// Sort the (locally sorted) sample runs and return the `p−1` splitters,
/// broadcast to every processor.
///
/// * `Bitonic` — the paper's parallel sample sort: distributed Batcher
///   bitonic over the tagged records, then processors `0..p−1` each
///   donate the last record of their chunk (= the evenly spaced
///   positions `s, 2s, …, (p−1)s` of the sorted sample) to processor 0,
///   which broadcasts the splitter set (steps 5–7 / Lemma 4.1).
/// * `Sequential` — gather the whole sample at processor 0, sort there,
///   select evenly spaced splitters, broadcast (SORT_RAN_BSP's shape).
pub fn sample_sort_and_splitters<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    sample: Vec<SampleRec<K>>,
    method: SampleSortMethod,
    label: &str,
) -> Vec<SampleRec<K>> {
    let p = ctx.nprocs();
    if p == 1 {
        return Vec::new();
    }
    match method {
        SampleSortMethod::Bitonic => {
            let s = sample.len();
            let sorted_chunk = bitonic::bitonic_sort(ctx, sample, &format!("{label}:bsi"));
            debug_assert_eq!(sorted_chunk.len(), s);
            // Processor i < p−1 holds global positions [i·s, (i+1)·s); the
            // splitter at 1-indexed position (i+1)·s is its last record.
            if ctx.pid() < p - 1 {
                let last = *sorted_chunk.last().expect("nonempty sample chunk");
                ctx.send(0, Payload::Recs(vec![last]));
            }
            ctx.charge(1.0);
            ctx.sync(&format!("{label}:gather-splitters"));
            let splitters = if ctx.pid() == 0 {
                // The inbox arrives in sender order (engine guarantee),
                // so the donated records are already rank-ordered.
                ctx.take_inbox()
                    .into_iter()
                    .map(|(_, payload)| payload.into_recs()[0])
                    .collect()
            } else {
                ctx.take_inbox();
                Vec::new()
            };
            broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, &format!("{label}:bcast"))
        }
        SampleSortMethod::Sequential => {
            ctx.send(0, Payload::Recs(sample));
            ctx.sync(&format!("{label}:gather-sample"));
            let splitters = if ctx.pid() == 0 {
                let mut all: Vec<SampleRec<K>> = ctx
                    .take_inbox()
                    .into_iter()
                    .flat_map(|(_, payload)| payload.into_recs())
                    .collect();
                ctx.charge(ops::sort_charge(all.len()));
                all.sort();
                select_splitters(&all, p)
            } else {
                ctx.take_inbox();
                Vec::new()
            };
            broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, &format!("{label}:bcast"))
        }
    }
}

/// Steps 8–13 for the locally *sorted* algorithms (DET and IRAN):
/// partition the sorted local keys at the splitters (binary search with
/// tagged tie-break), run the Ph4 prefix over bucket counts, route each
/// contiguous slice in a single superstep, and stable-merge the received
/// runs.
pub fn partition_route_merge<K: Key, S: BspScope<K>>(
    ctx: &mut S,
    keys: Vec<K>,
    splitters: &[SampleRec<K>],
    cfg: &SortConfig,
) -> ProcResult<K> {
    let p = ctx.nprocs();
    let pid = ctx.pid();
    let n_local = keys.len();

    if p == 1 {
        return ProcResult {
            received: keys.len(),
            runs: 1,
            keys,
        };
    }

    // --- Ph4: partition + parallel prefix over bucket counts ---------
    ctx.phase(PH4);
    // Binary search of the p−1 splitters into the local sorted keys
    // (the cheaper direction, as §5.2 notes): (p−1)·⌈lg(n/p)⌉ charges.
    let effective = effective_splitters(splitters, cfg);
    let cuts = search::partition_points(&keys, pid, &effective);
    ctx.charge((p as f64 - 1.0) * ops::bsearch_charge(n_local.max(2)));
    let counts: Vec<u64> = cuts.windows(2).map(|w| (w[1] - w[0]) as u64).collect();
    // p independent prefix operations over the bucket counts: the
    // offsets are where this processor's slice lands at each receiver —
    // the information the paper's step 9 computes (and our stability
    // audit checks); the sender-ordered delivery realizes the placement.
    let (offsets, totals) = crate::primitives::prefix::prefix_direct(ctx, &counts, "ph4:prefix");
    debug_assert_eq!(offsets.len(), p);
    let _expected_recv = totals[pid];

    // --- Ph5: one-round key routing -----------------------------------
    ctx.phase(PH5);
    // Carve the local run into p contiguous slices by splitting off the
    // tail bucket by bucket: bucket 0 keeps `keys`' own allocation, so
    // each routed key is copied out at most once (and the payloads then
    // *move* through the slot matrix — routing is one copy, not two).
    let mut parts: Vec<Payload<K>> = Vec::with_capacity(p);
    let mut head = keys;
    for i in (1..p).rev() {
        parts.push(Payload::Keys(head.split_off(cuts[i])));
    }
    parts.push(Payload::Keys(head));
    parts.reverse();
    ctx.charge(ops::linear_charge(n_local)); // slice carve-out
    let inbox = ctx.all_to_all(parts, "ph5:route");

    // --- Ph6: stable multi-way merge ----------------------------------
    ctx.phase(PH6);
    let runs: Vec<Vec<K>> = inbox
        .into_iter()
        .filter(|(_, payload)| !payload.is_empty())
        .map(|(_, payload)| payload.into_keys())
        .collect();
    let received: usize = runs.iter().map(|r| r.len()).sum();
    let n_runs = runs.len();
    debug_assert_eq!(received as u64, totals[pid], "prefix totals must match received keys");
    ctx.charge(ops::merge_charge(received, n_runs.max(2)));
    // Owned merge: a single non-empty run is returned as-is, reusing the
    // buffer that travelled through the slot matrix.
    let merged = crate::seq::multiway_merge_owned(runs);

    // --- Ph7 ----------------------------------------------------------
    ctx.phase(PH7);
    ctx.sync("ph7:done");

    ProcResult {
        keys: merged,
        received,
        runs: n_runs,
    }
}

/// The splitter set actually compared against under the configured
/// duplicate policy: tagged records verbatim, or — for the §6.4
/// ablation — tags zeroed so ties resolve by key only.  Shared by the
/// one-level pipeline and the multi-level sorts' coarse partition.
pub fn effective_splitters<K: Key>(
    splitters: &[SampleRec<K>],
    cfg: &SortConfig,
) -> Vec<SampleRec<K>> {
    match cfg.dup {
        DuplicatePolicy::Tagged => splitters.to_vec(),
        // Ablation: strip tags so ties resolve by key only.
        DuplicatePolicy::Off => splitters
            .iter()
            .map(|s| SampleRec { key: s.key, proc: 0, idx: 0 })
            .collect(),
    }
}

/// Destination bucket of key `k` (owned by `pid` at index `i`) among the
/// tagged `splitters`: the first splitter the tagged key orders before;
/// ties use the §5.1.1 compound `(key, proc, idx)` order.  Used by the
/// key-wise set formation of SORT_RAN_BSP (step 9) and by the
/// multi-level sorts' coarse group routing.
pub fn splitter_rank<K: Key>(splitters: &[SampleRec<K>], k: K, pid: usize, i: usize) -> usize {
    let me = (k, pid as u32, i as u32);
    let mut lo = 0usize;
    let mut hi = splitters.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let s = &splitters[mid];
        if (s.key, s.proc, s.idx) <= me {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Evenly spaced sample of a *sorted* local run (step 4 of SORT_DET_BSP):
/// `s−1` boundary keys of `s` equal segments plus the local maximum, as
/// tagged records.  Padding semantics: segment size is
/// `x = ⌈⌈n/p⌉/s⌉`; positions past the end read the local maximum with
/// their (virtual) padded index as the tag, keeping tags distinct.
pub fn regular_sample<K: Key>(keys: &[K], pid: usize, s: usize) -> Vec<SampleRec<K>> {
    debug_assert!(s >= 1);
    let n = keys.len();
    if n == 0 {
        // Empty local run: pad with the maximal key but keep the virtual
        // indices distinct — the §5.1.1 tie-break depends on every
        // sample record having a distinct (proc, idx) tag.
        return (0..s).map(|j| SampleRec::new(K::max_key(), pid, j)).collect();
    }
    let x = n.div_ceil(s).max(1);
    let mut out = Vec::with_capacity(s);
    for j in 1..s {
        let idx = j * x - 1;
        if idx < n {
            out.push(SampleRec::new(keys[idx], pid, idx));
        } else {
            // Padded position: key = local max, tag keeps the virtual
            // index so records stay distinct under the tagged order.
            out.push(SampleRec::new(keys[n - 1], pid, idx));
        }
    }
    // Append the maximum of the local run (paper step 4).
    out.push(SampleRec::new(keys[n - 1], pid, s * x - 1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;

    #[test]
    fn regular_sample_even_spacing() {
        let keys: Vec<i32> = (0..100).collect();
        let sample = regular_sample(&keys, 2, 10);
        assert_eq!(sample.len(), 10);
        // x = 10; boundaries at indices 9, 19, ..., 89; then max.
        let expect: Vec<i32> = (1..10).map(|j| (j * 10 - 1) as i32).chain([99]).collect();
        let got: Vec<i32> = sample.iter().map(|r| r.key).collect();
        assert_eq!(got, expect);
        assert!(sample.iter().all(|r| r.proc == 2));
    }

    #[test]
    fn regular_sample_short_input_pads_with_max() {
        let keys = vec![5, 9];
        let sample = regular_sample(&keys, 0, 4);
        assert_eq!(sample.len(), 4);
        assert_eq!(sample.last().unwrap().key, 9);
        // All padded positions carry the max key.
        assert!(sample.iter().skip(1).all(|r| r.key == 9));
        // Tags stay strictly increasing (distinctness).
        assert!(sample.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn regular_sample_is_sorted_under_tag_order() {
        let keys = vec![3; 64];
        let sample = regular_sample(&keys, 1, 8);
        assert!(sample.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn regular_sample_empty_run_has_distinct_tags() {
        // Regression: the empty-run path used to emit `s` records all
        // tagged idx = 0, violating the §5.1.1 tag-distinctness
        // invariant the duplicate handling depends on.
        let sample = regular_sample(&[], 3, 8);
        assert_eq!(sample.len(), 8);
        assert!(sample.iter().all(|r| r.key == i32::MAX && r.proc == 3));
        assert!(sample.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn select_splitters_matches_legacy_formula_on_regular_samples() {
        // m = s·p: the safe rank formula must reproduce i·s − 1 exactly.
        let p = 8;
        let s = 5;
        let recs: Vec<SampleRec> =
            (0..(s * p) as i32).map(|k| SampleRec::new(k, 0, k as usize)).collect();
        let splitters = select_splitters(&recs, p);
        let expect: Vec<i32> = (1..p).map(|i| (i * s - 1) as i32).collect();
        let got: Vec<i32> = splitters.iter().map(|r| r.key).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn select_splitters_small_sample_does_not_underflow() {
        // Regression: `seg = m / p` is 0 when m < p and `i*seg - 1`
        // underflowed (panic in debug, wrap in release).
        let recs: Vec<SampleRec> =
            (0..3i32).map(|k| SampleRec::new(k, 0, k as usize)).collect();
        for p in [2usize, 4, 8, 64] {
            let splitters = select_splitters(&recs, p);
            assert_eq!(splitters.len(), p - 1, "p={p}");
            assert!(splitters.windows(2).all(|w| w[0] <= w[1]));
        }
        assert!(select_splitters(&[], 8).iter().all(|r| r.key == i32::MAX));
        assert!(select_splitters(&recs, 1).is_empty());
    }

    #[test]
    fn sequential_sample_sort_with_tiny_sample_regression() {
        // End to end: the gathered sample (one record total) is smaller
        // than p; the old splitter selection underflowed here.
        let p = 4;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let run = machine.run(|ctx| {
            let sample = if ctx.pid() == 0 {
                vec![SampleRec::new(42, 0, 0)]
            } else {
                Vec::new()
            };
            sample_sort_and_splitters(ctx, &params, sample, SampleSortMethod::Sequential, "tiny")
        });
        for out in run.outputs {
            assert_eq!(out.len(), p - 1);
            assert!(out.iter().all(|r| r.key == 42));
        }
    }

    #[test]
    fn det_sequential_sorts_tiny_n_large_p() {
        // Tiny n with comparatively large p through the full pipeline.
        use crate::sort::det::sort_det_bsp;
        let p = 8;
        let n = 16;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default().with_sample_sort(SampleSortMethod::Sequential);
        let run = machine.run(|ctx| {
            let local = vec![(p - ctx.pid()) as i32, ctx.pid() as i32];
            sort_det_bsp(ctx, &params, local, n, &cfg)
        });
        let got: Vec<i32> = run.outputs.iter().flat_map(|r| r.keys.clone()).collect();
        let mut expect: Vec<i32> = (0..p)
            .flat_map(|pid| [(p - pid) as i32, pid as i32])
            .collect();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }
}
