//! Study reports: the versioned `BENCH_<tag>.json` schema and the
//! paper-style markdown rendering.
//!
//! The JSON document is the single source of truth for the report shape
//! ([`SCHEMA`] names the version); `tables::validate::validate_report`
//! checks a parsed document against it, and the CLI re-reads and
//! validates every file it writes before declaring success.

use std::io;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::fmt_secs;
use crate::util::json::Json;

use super::calibrate::Calibration;
use super::run::RunRecord;

/// Schema identifier written into (and required from) every report.
/// v3 added the per-run `backend` field (`threaded` | `sim`); v4 added
/// the per-run `topology` field (the shape label of a multi-level
/// run's topology tree, e.g. `"8x4x4"`; `null` for one-level variants);
/// v5 added the EM-BSP block-I/O parameter — per-calibration
/// `g_io_us_per_block`, per-run `mem_budget` (`null` for in-core
/// cells), and per-superstep `io_blocks`.
pub const SCHEMA: &str = "bsp-sort/experiment-report/v5";

/// A complete study: calibrations for every probed `p` plus one
/// [`RunRecord`] per sweep cell.
#[derive(Clone, Debug)]
pub struct StudyReport {
    /// Sweep tag; outputs land in `BENCH_<tag>.json` / `.md`.
    pub tag: String,
    /// Unix seconds when the study finished.
    pub created_unix_secs: u64,
    /// `std::env::consts::OS` of the host.
    pub os: String,
    /// `std::env::consts::ARCH` of the host.
    pub arch: String,
    /// One calibration per distinct processor count in the sweep.
    pub calibrations: Vec<Calibration>,
    /// One record per sweep cell, in sweep order.
    pub runs: Vec<RunRecord>,
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl StudyReport {
    /// Current unix time in seconds (0 if the clock is before the
    /// epoch, which only happens on a misconfigured host).
    pub fn now_unix_secs() -> u64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0)
    }

    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> Json {
        let calibrations = self
            .calibrations
            .iter()
            .map(|c| {
                obj(vec![
                    ("p", Json::num(c.p as f64)),
                    // Which backend's runs this calibration prices;
                    // consumers join runs↔calibrations by (p, backend).
                    ("backend", Json::str(&c.backend)),
                    ("l_us", Json::num(c.l_us)),
                    ("g_us_per_word", Json::num(c.g_us_per_word)),
                    ("comps_per_us", Json::num(c.comps_per_us)),
                    // EM-BSP third parameter: charged µs per block of
                    // external I/O (0 when the probe was skipped).
                    ("g_io_us_per_block", Json::num(c.g_io_us_per_block)),
                    ("fit_intercept_us", Json::num(c.fit_intercept_us)),
                    ("fit_r2", Json::num(c.fit_r2)),
                    (
                        "a2a_points",
                        Json::Arr(
                            c.a2a_points
                                .iter()
                                .map(|&(h, t)| {
                                    Json::Arr(vec![Json::num(h as f64), Json::num(t)])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let runs = self.runs.iter().map(run_to_json).collect();
        obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("tag", Json::str(&self.tag)),
            ("created_unix_secs", Json::num(self.created_unix_secs as f64)),
            ("os", Json::str(&self.os)),
            ("arch", Json::str(&self.arch)),
            ("calibrations", Json::Arr(calibrations)),
            ("runs", Json::Arr(runs)),
        ])
    }

    /// Render the paper-style markdown companion: the calibration table
    /// and one measured-vs-predicted row per run.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("# BSP sorting experiment — `{}`\n\n", self.tag));
        out.push_str(&format!(
            "Host: {}/{} · schema `{}`\n\n",
            self.os, self.arch, SCHEMA
        ));
        out.push_str("## Calibrated machine parameters\n\n");
        out.push_str(
            "| p | L (µs) | g (µs/word) | comps/µs | fit r² | backend | G_io (µs/blk) |\n",
        );
        out.push_str("|---:|---:|---:|---:|---:|---|---:|\n");
        for c in &self.calibrations {
            out.push_str(&format!(
                "| {} | {:.2} | {:.4} | {:.1} | {:.4} | {} | {:.1} |\n",
                c.p, c.l_us, c.g_us_per_word, c.comps_per_us, c.fit_r2, c.backend,
                c.g_io_us_per_block
            ));
        }
        out.push_str("\n## Measured vs predicted (per configuration)\n\n");
        out.push_str(
            "| algo | bench | domain | backend | n | p | measured (s) | predicted (s) \
             | meas/pred | max/avg keys | routed max/avg words | mem budget |\n",
        );
        out.push_str("|---|---|---|---|---:|---:|---:|---:|---:|---:|---:|---:|\n");
        for r in &self.runs {
            let budget = match r.mem_budget {
                Some(m) => m.to_string(),
                None => "—".to_string(),
            };
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {:.2} | {}/{:.0} | {}/{:.0} | {} |\n",
                r.algo_label,
                r.bench,
                r.domain,
                r.backend,
                r.n,
                r.p,
                fmt_secs(r.wall_us.mean / 1e6),
                fmt_secs(r.predicted_us / 1e6),
                r.ratio,
                r.balance.recv_max,
                r.balance.recv_mean,
                r.balance.routed_words_max,
                r.balance.routed_words_avg,
                budget,
            ));
        }
        out.push_str("\n## Per-phase ratios\n\n");
        for r in &self.runs {
            out.push_str(&format!(
                "### {} {} {} n={} p={}\n\n",
                r.algo_label, r.bench, r.domain, r.n, r.p
            ));
            out.push_str("| phase | predicted (µs) | measured (µs) | meas/pred |\n");
            out.push_str("|---|---:|---:|---:|\n");
            for ph in &r.phases {
                let ratio = if ph.ratio.is_finite() {
                    format!("{:.2}", ph.ratio)
                } else {
                    "—".to_string()
                };
                out.push_str(&format!(
                    "| {} | {:.1} | {:.1} | {} |\n",
                    ph.name, ph.predicted_us, ph.wall_us, ratio
                ));
            }
            out.push('\n');
        }
        out
    }

    /// `BENCH_<tag>` — the stem both output files share.
    pub fn file_stem(&self) -> String {
        format!("BENCH_{}", self.tag)
    }

    /// Write `BENCH_<tag>.json` and `BENCH_<tag>.md` under `dir`;
    /// returns the two paths.
    pub fn write_files(&self, dir: &Path) -> io::Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(dir)?;
        let json_path = dir.join(format!("{}.json", self.file_stem()));
        let md_path = dir.join(format!("{}.md", self.file_stem()));
        std::fs::write(&json_path, self.to_json().render())?;
        std::fs::write(&md_path, self.to_markdown())?;
        Ok((json_path, md_path))
    }
}

fn run_to_json(r: &RunRecord) -> Json {
    let phases = r
        .phases
        .iter()
        .map(|ph| {
            obj(vec![
                ("name", Json::str(&ph.name)),
                ("predicted_us", Json::num(ph.predicted_us)),
                ("wall_us", Json::num(ph.wall_us)),
                ("ratio", Json::num(ph.ratio)), // NaN -> null (unpriced)
            ])
        })
        .collect();
    let supersteps = r
        .supersteps
        .iter()
        .map(|s| {
            obj(vec![
                ("label", Json::str(&s.label)),
                ("phase", Json::str(&s.phase)),
                ("max_ops", Json::num(s.max_ops)),
                ("h_words", Json::num(s.h_words as f64)),
                ("total_words", Json::num(s.total_words as f64)),
                ("wall_us", Json::num(s.wall_us)),
                ("predicted_us", Json::num(s.predicted_us)),
                ("procs", Json::num(s.procs as f64)),
                // Group-round index of the multi-level sorts' level-2
                // supersteps; null for whole-machine supersteps.
                (
                    "round",
                    s.round.map(|r| Json::num(r as f64)).unwrap_or(Json::Null),
                ),
                // Charged external-I/O blocks (max over processors);
                // non-zero only on the external-sort phases.
                ("io_blocks", Json::num(s.io_blocks as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("algo", Json::str(&r.algo)),
        ("algo_label", Json::str(&r.algo_label)),
        ("bench", Json::str(&r.bench)),
        ("domain", Json::str(&r.domain)),
        // Execution backend; `sim` wall statistics are virtual µs.
        ("backend", Json::str(&r.backend)),
        // Topology tree of the multi-level variants ("2x4", "8x4x4");
        // null for the one-level algorithms.
        (
            "topology",
            r.topology.as_deref().map(Json::str).unwrap_or(Json::Null),
        ),
        ("n", Json::num(r.n as f64)),
        ("p", Json::num(r.p as f64)),
        // External-memory budget in keys per processor; null marks an
        // in-core cell.
        (
            "mem_budget",
            r.mem_budget.map(|m| Json::num(m as f64)).unwrap_or(Json::Null),
        ),
        ("warmup", Json::num(r.warmup as f64)),
        ("reps", Json::num(r.reps as f64)),
        (
            "wall_us",
            obj(vec![
                ("n", Json::num(r.wall_us.n as f64)),
                ("min", Json::num(r.wall_us.min)),
                ("mean", Json::num(r.wall_us.mean)),
                ("stddev", Json::num(r.wall_us.stddev)),
                ("max", Json::num(r.wall_us.max)),
            ]),
        ),
        ("predicted_us", Json::num(r.predicted_us)),
        ("ratio", Json::num(r.ratio)),
        ("phases", Json::Arr(phases)),
        (
            "balance",
            obj(vec![
                ("recv_max", Json::num(r.balance.recv_max as f64)),
                ("recv_min", Json::num(r.balance.recv_min as f64)),
                ("recv_mean", Json::num(r.balance.recv_mean)),
                ("expansion", Json::num(r.balance.expansion)),
                ("routed_words_total", Json::num(r.balance.routed_words_total)),
                ("routed_words_max", Json::num(r.balance.routed_words_max as f64)),
                ("routed_words_avg", Json::num(r.balance.routed_words_avg)),
            ]),
        ),
        ("supersteps", Json::Arr(supersteps)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::run::{Balance, PhaseStat, SuperstepStat};
    use crate::util::bench::SampleStats;

    fn sample_report() -> StudyReport {
        StudyReport {
            tag: "unit".into(),
            created_unix_secs: 1_700_000_000,
            os: "linux".into(),
            arch: "x86_64".into(),
            calibrations: vec![Calibration {
                p: 4,
                l_us: 12.0,
                g_us_per_word: 0.02,
                comps_per_us: 150.0,
                g_io_us_per_block: 327.0,
                a2a_points: vec![(1024, 33.0), (4096, 95.0)],
                fit_intercept_us: 12.5,
                fit_r2: 0.998,
                backend: "threaded".into(),
            }],
            runs: vec![RunRecord {
                algo: "det".into(),
                algo_label: "[DSQ]".into(),
                bench: "[U]".into(),
                domain: "i32".into(),
                backend: "threaded".into(),
                topology: None,
                n: 4096,
                p: 4,
                mem_budget: None,
                warmup: 1,
                reps: 2,
                wall_us: SampleStats { n: 2, min: 900.0, max: 1100.0, mean: 1000.0, stddev: 100.0 },
                predicted_us: 800.0,
                ratio: 1.25,
                phases: vec![
                    PhaseStat {
                        name: "Ph2:SeqSort".into(),
                        predicted_us: 400.0,
                        wall_us: 500.0,
                        ratio: 1.25,
                    },
                    PhaseStat {
                        name: "Ph1:Init".into(),
                        predicted_us: 0.0,
                        wall_us: 1.0,
                        ratio: f64::NAN,
                    },
                ],
                balance: Balance {
                    recv_max: 1100,
                    recv_min: 950,
                    recv_mean: 1024.0,
                    expansion: 0.074,
                    routed_words_total: 4096.0,
                    routed_words_max: 1100,
                    routed_words_avg: 1024.0,
                },
                supersteps: vec![SuperstepStat {
                    label: "ph5:route".into(),
                    phase: "Ph5:Routing".into(),
                    max_ops: 10.0,
                    h_words: 1100,
                    total_words: 4096,
                    wall_us: 40.0,
                    predicted_us: 35.0,
                    procs: 4,
                    round: None,
                    io_blocks: 7,
                }],
            }],
        }
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let report = sample_report();
        let text = report.to_json().render();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
        let runs = doc.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].get("n").unwrap().as_u64(), Some(4096));
        assert_eq!(runs[0].get("backend").unwrap().as_str(), Some("threaded"));
        // The unpriced phase's NaN ratio serializes as null.
        let phases = runs[0].get("phases").unwrap().as_arr().unwrap();
        assert!(phases[1].get("ratio").unwrap().is_null());
        assert_eq!(phases[0].get("ratio").unwrap().as_f64(), Some(1.25));
        // v5 fields: calibration G_io, in-core null budget, superstep
        // block-I/O counts.
        let calib = &doc.get("calibrations").unwrap().as_arr().unwrap()[0];
        assert_eq!(calib.get("g_io_us_per_block").unwrap().as_f64(), Some(327.0));
        assert!(runs[0].get("mem_budget").unwrap().is_null());
        let steps = runs[0].get("supersteps").unwrap().as_arr().unwrap();
        assert_eq!(steps[0].get("io_blocks").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn markdown_contains_all_runs_and_calibration() {
        let md = sample_report().to_markdown();
        assert!(md.contains("# BSP sorting experiment — `unit`"));
        assert!(md.contains("| 4 | 12.00 | 0.0200 | 150.0 |"));
        // The EM third parameter rides the end of the calibration row.
        assert!(md.contains("| threaded | 327.0 |"));
        assert!(md.contains("[DSQ]"));
        assert!(md.contains("Ph2:SeqSort"));
        assert!(md.contains("| Ph1:Init | 0.0 | 1.0 | — |"));
    }

    #[test]
    fn write_files_creates_both_outputs() {
        let dir = std::env::temp_dir().join("bsp_sort_report_test");
        let report = sample_report();
        let (json_path, md_path) = report.write_files(&dir).unwrap();
        assert!(json_path.ends_with("BENCH_unit.json"));
        let text = std::fs::read_to_string(&json_path).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert!(std::fs::read_to_string(&md_path).unwrap().contains("experiment"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
