//! The experiment-study subsystem: one command from sweep spec to the
//! paper's measured-vs-predicted evidence.
//!
//! The source paper is an *experimental study* — its contribution is
//! tables of measured times, predicted BSP costs, and load-balance /
//! communication-regularity evidence.  This module makes that claim
//! executable:
//!
//! 1. **Calibrate** ([`calibrate`]): dedicated barrier / all-to-all /
//!    compute micro-probes measure the host's `(g, L)` and operation
//!    rate, so predictions are in host microseconds rather than abstract
//!    T3D units.
//! 2. **Sweep** ([`spec`], [`run`]): any cross-product of
//!    {algorithm, benchmark distribution, key domain, n, p} runs with
//!    warm-up + repetitions; every run is verified (globally sorted,
//!    size-preserving) before it is reported.
//! 3. **Report** ([`report`]): per-run min/mean/stddev wall-clock,
//!    end-to-end and per-phase measured-vs-predicted ratios, and the
//!    paper's balance metrics (max/avg keys per processor, routed words
//!    per processor), serialized to a schema-versioned `BENCH_<tag>.json`
//!    plus a paper-style markdown table.
//!
//! The CLI front-end is `bsp-sort experiment` (`--quick` for the
//! CI-sized preset); `tables::validate::validate_report` checks any
//! report document against the [`report::SCHEMA`] shape.
//!
//! A complete miniature study, end to end:
//!
//! ```
//! use bsp_sort::experiment::{self, ProbePlan, SweepSpec};
//!
//! let mut spec = SweepSpec::quick(); // det + ran + det2, [U] + [DD], i32 + u64
//! spec.ns = vec![2048];              // shrink the preset for the doctest
//! spec.ps = vec![4];
//! spec.reps = 1;
//! spec.warmup = 0;
//! spec.probes = ProbePlan::quick();
//!
//! let report = experiment::run_study(&spec);
//! assert_eq!(report.runs.len(), spec.configs().len());
//! let calib = &report.calibrations[0];       // host (g, L), not the T3D's
//! assert!(calib.g_us_per_word > 0.0 && calib.l_us > 0.0);
//! let run = &report.runs[0];
//! assert!(run.predicted_us > 0.0 && run.wall_us.mean > 0.0);
//! assert!(run.ratio.is_finite() && run.ratio > 0.0);
//! ```

#![warn(missing_docs)]

pub mod calibrate;
pub mod report;
pub mod run;
pub mod spec;

pub use calibrate::{
    calibrate_host, calibrate_with, fit_line, Calibration, HostProber, ProbePlan, Prober,
    SyntheticProber,
};
pub use report::{StudyReport, SCHEMA};
pub use run::{
    avg_predicted_secs, execute, execute_typed, measure_config, measure_typed, Balance,
    PhaseStat, RunRecord, SingleRun, StudyKey, SuperstepStat,
};
pub use spec::{
    AlgoVariant, KeyDomain, RunConfig, RunSpec, SweepSpec, ALL_ALGOS, ALL_DOMAINS,
};

/// Execute a sweep: calibrate once per distinct processor count, then
/// measure every cell of the cross-product, in spec order.
pub fn run_study(spec: &SweepSpec) -> StudyReport {
    spec.validate().expect("invalid sweep spec");
    let mut ps: Vec<usize> = spec.ps.clone();
    ps.sort_unstable();
    ps.dedup();
    let calibrations: Vec<Calibration> =
        ps.iter().map(|&p| calibrate_host(p, &spec.probes)).collect();
    let runs = spec
        .configs()
        .iter()
        .map(|cfg| {
            let calib = calibrations
                .iter()
                .find(|c| c.p == cfg.p)
                .expect("calibration exists for every p in the sweep");
            measure_config(cfg, spec, calib)
        })
        .collect();
    StudyReport {
        tag: spec.tag.clone(),
        created_unix_secs: StudyReport::now_unix_secs(),
        os: std::env::consts::OS.to_string(),
        arch: std::env::consts::ARCH.to_string(),
        calibrations,
        runs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Benchmark;

    #[test]
    fn run_study_covers_the_cross_product() {
        let mut spec = SweepSpec::quick();
        spec.algos = vec![AlgoVariant::Det];
        spec.benches = vec![Benchmark::Uniform];
        spec.domains = vec![KeyDomain::I32, KeyDomain::U64];
        spec.ns = vec![1 << 11];
        spec.ps = vec![2];
        spec.reps = 1;
        spec.warmup = 0;
        spec.probes = ProbePlan {
            barrier_reps: 4,
            a2a_h_words: vec![256, 1024],
            a2a_rounds: 2,
            comp_n: 1 << 10,
        };
        let report = run_study(&spec);
        assert_eq!(report.calibrations.len(), 1);
        assert_eq!(report.runs.len(), 2);
        let domains: Vec<&str> = report.runs.iter().map(|r| r.domain.as_str()).collect();
        assert_eq!(domains, vec!["i32", "u64"]);
        assert!(report.created_unix_secs > 0);
    }

    #[test]
    #[should_panic(expected = "invalid sweep spec")]
    fn run_study_rejects_invalid_specs() {
        let mut spec = SweepSpec::quick();
        spec.ns = vec![1000];
        spec.ps = vec![3];
        run_study(&spec);
    }
}
