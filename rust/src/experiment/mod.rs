//! The experiment-study subsystem: one command from sweep spec to the
//! paper's measured-vs-predicted evidence.
//!
//! The source paper is an *experimental study* — its contribution is
//! tables of measured times, predicted BSP costs, and load-balance /
//! communication-regularity evidence.  This module makes that claim
//! executable:
//!
//! 1. **Calibrate** ([`calibrate`]): dedicated barrier / all-to-all /
//!    compute micro-probes measure the host's `(g, L)` and operation
//!    rate, so predictions are in host microseconds rather than abstract
//!    T3D units.
//! 2. **Sweep** ([`spec`], [`run`]): any cross-product of
//!    {algorithm, benchmark distribution, key domain, n, p} runs with
//!    warm-up + repetitions; every run is verified (globally sorted,
//!    size-preserving) before it is reported.
//! 3. **Report** ([`report`]): per-run min/mean/stddev wall-clock,
//!    end-to-end and per-phase measured-vs-predicted ratios, and the
//!    paper's balance metrics (max/avg keys per processor, routed words
//!    per processor), serialized to a schema-versioned `BENCH_<tag>.json`
//!    plus a paper-style markdown table.
//!
//! The CLI front-end is `bsp-sort experiment` (`--quick` for the
//! CI-sized preset); `tables::validate::validate_report` checks any
//! report document against the [`report::SCHEMA`] shape.
//!
//! A complete miniature study, end to end:
//!
//! ```
//! use bsp_sort::experiment::{self, ProbePlan, SweepSpec};
//!
//! let mut spec = SweepSpec::quick(); // det + ran + det2, [U] + [DD], i32 + u64
//! spec.ns = vec![2048];              // shrink the preset for the doctest
//! spec.ps = vec![4];
//! spec.extras.clear();               // drop the preset's sim @ p=256 cell too
//! spec.reps = 1;
//! spec.warmup = 0;
//! spec.probes = ProbePlan::quick();
//!
//! let report = experiment::run_study(&spec);
//! assert_eq!(report.runs.len(), spec.configs().len());
//! let calib = &report.calibrations[0];       // host (g, L), not the T3D's
//! assert!(calib.g_us_per_word > 0.0 && calib.l_us > 0.0);
//! let run = &report.runs[0];
//! assert!(run.predicted_us > 0.0 && run.wall_us.mean > 0.0);
//! assert!(run.ratio.is_finite() && run.ratio > 0.0);
//! ```

#![warn(missing_docs)]

pub mod calibrate;
pub mod report;
pub mod run;
pub mod spec;

pub use calibrate::{
    calibrate_host, calibrate_with, fit_line, Calibration, HostProber, ProbePlan, Prober,
    SyntheticProber,
};
pub use report::{StudyReport, SCHEMA};
pub use run::{
    avg_predicted_secs, execute, execute_external_typed, execute_typed, measure_config,
    measure_typed, resolved_deep_topology, Balance, PhaseStat, RunRecord, SingleRun, StudyKey,
    SuperstepStat,
};
pub use spec::{
    AlgoVariant, KeyDomain, RunConfig, RunSpec, SweepSpec, TopologyChoice, ALL_ALGOS,
    ALL_DOMAINS,
};

/// Execute a sweep: host-calibrate once per distinct processor count of
/// the *threaded* cells, price *sim* cells under synthetic model
/// calibrations (the simulator's virtual clock is driven by the model
/// machine — host micro-probes would be meaningless and would break the
/// sim cells' determinism), then measure every cell in spec order.
pub fn run_study(spec: &SweepSpec) -> StudyReport {
    use crate::bsp::{cray_t3d, Backend};

    spec.validate().expect("invalid sweep spec");
    let configs = spec.configs();
    let distinct_ps = |backend: Backend| -> Vec<usize> {
        let mut ps: Vec<usize> =
            configs.iter().filter(|c| c.backend == backend).map(|c| c.p).collect();
        ps.sort_unstable();
        ps.dedup();
        ps
    };
    let host_calibs: Vec<Calibration> = distinct_ps(Backend::Threaded)
        .into_iter()
        .map(|p| calibrate_host(p, &spec.probes))
        .collect();
    let sim_calibs: Vec<Calibration> = distinct_ps(Backend::Sim)
        .into_iter()
        .map(|p| Calibration::from_params(&cray_t3d(p)))
        .collect();
    let runs = configs
        .iter()
        .map(|cfg| {
            let pool = match cfg.backend {
                Backend::Threaded => &host_calibs,
                Backend::Sim => &sim_calibs,
            };
            let calib = pool
                .iter()
                .find(|c| c.p == cfg.p)
                .expect("calibration exists for every cell in the sweep");
            measure_config(cfg, spec, calib)
        })
        .collect();
    // The report lists every calibration actually used for pricing:
    // host points for the threaded cells, synthetic model points for
    // the sim cells.  Both can appear at the same `p` in a
    // mixed-backend sweep; each entry's `backend` field says which runs
    // it priced, so consumers join by `(p, backend)`.
    let mut calibrations = host_calibs;
    calibrations.extend(sim_calibs);
    StudyReport {
        tag: spec.tag.clone(),
        created_unix_secs: StudyReport::now_unix_secs(),
        os: std::env::consts::OS.to_string(),
        arch: std::env::consts::ARCH.to_string(),
        calibrations,
        runs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Benchmark;

    #[test]
    fn run_study_covers_the_cross_product() {
        let mut spec = SweepSpec::quick();
        spec.algos = vec![AlgoVariant::Det];
        spec.benches = vec![Benchmark::Uniform];
        spec.domains = vec![KeyDomain::I32, KeyDomain::U64];
        spec.ns = vec![1 << 11];
        spec.ps = vec![2];
        spec.extras.clear();
        spec.reps = 1;
        spec.warmup = 0;
        spec.probes = ProbePlan {
            barrier_reps: 4,
            a2a_h_words: vec![256, 1024],
            a2a_rounds: 2,
            comp_n: 1 << 10,
            io_blocks: 2,
        };
        let report = run_study(&spec);
        assert_eq!(report.calibrations.len(), 1);
        assert_eq!(report.runs.len(), 2);
        let domains: Vec<&str> = report.runs.iter().map(|r| r.domain.as_str()).collect();
        assert_eq!(domains, vec!["i32", "u64"]);
        assert!(report.created_unix_secs > 0);
    }

    #[test]
    fn sim_only_sweeps_carry_synthetic_model_calibrations() {
        use crate::bsp::Backend;
        let mut spec = SweepSpec::quick();
        spec.algos = vec![AlgoVariant::Det];
        spec.benches = vec![Benchmark::Uniform];
        spec.domains = vec![KeyDomain::I32];
        spec.ns = vec![1 << 11];
        spec.ps = vec![8];
        spec.backends = vec![Backend::Sim];
        spec.extras.clear();
        spec.reps = 1;
        spec.warmup = 0;
        let report = run_study(&spec);
        assert_eq!(report.runs.len(), 1);
        assert_eq!(report.runs[0].backend, "sim");
        // No threaded cells, yet the report still carries its pricing
        // parameters: the synthetic model calibration for p = 8,
        // tagged with the backend it prices.
        assert_eq!(report.calibrations.len(), 1);
        assert_eq!(report.calibrations[0].p, 8);
        assert_eq!(report.calibrations[0].fit_r2, 1.0);
        assert_eq!(report.calibrations[0].backend, "sim");
    }

    #[test]
    #[should_panic(expected = "invalid sweep spec")]
    fn run_study_rejects_invalid_specs() {
        let mut spec = SweepSpec::quick();
        spec.ns = vec![1000];
        spec.ps = vec![3];
        run_study(&spec);
    }
}
