//! What a sweep runs: algorithm variants, key domains, the single-run
//! spec, and the cross-product [`SweepSpec`] with its CLI parsing.
//!
//! [`AlgoVariant`] and [`RunSpec`] moved here from `tables::runner` (which
//! re-exports them): the tables are now one consumer of the experiment
//! runner among several, not the owner of the run vocabulary.

use crate::bsp::{Backend, BspParams, Topology, MAX_TOPOLOGY_DEPTH};
use crate::gen::Benchmark;
use crate::sort::{LocalSortEngine, SortConfig};
use crate::util::cli::{Args, CliError};

use super::calibrate::ProbePlan;

/// Every runnable algorithm variant in the study.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoVariant {
    /// SORT_DET_BSP (\[DSQ\]/\[DSR\] by config backend).
    Det,
    /// SORT_IRAN_BSP (\[RSQ\]/\[RSR\]).
    Iran,
    /// SORT_RAN_BSP (classic sample sort, design baseline).
    Ran,
    /// Full bitonic \[BSI\].
    Bsi,
    /// Two-level deterministic sample sort over processor groups
    /// (`sort::multilevel`, AMS-style recursion).
    Det2,
    /// Two-level randomized sample sort over processor groups.
    Ran2,
    /// Depth-k deterministic sample sort over a topology tree
    /// (`sort::multilevel::sort_deep_det`; the topology comes from the
    /// sweep's topology axis, the planner, or `default_topology`).
    DetK,
    /// Depth-k randomized sample sort over a topology tree.
    RanK,
    /// Helman–JaJa–Bader deterministic [39].
    HelmanDet,
    /// Helman–JaJa–Bader randomized [40].
    HelmanRan,
    /// PSRS [61]/[44].
    Psrs,
}

/// Every variant, in report order.
pub const ALL_ALGOS: [AlgoVariant; 11] = [
    AlgoVariant::Det,
    AlgoVariant::Iran,
    AlgoVariant::Ran,
    AlgoVariant::Bsi,
    AlgoVariant::Det2,
    AlgoVariant::Ran2,
    AlgoVariant::DetK,
    AlgoVariant::RanK,
    AlgoVariant::HelmanDet,
    AlgoVariant::HelmanRan,
    AlgoVariant::Psrs,
];

impl AlgoVariant {
    /// Paper-notation label under a configuration (\[DSQ\], \[RSR\], …).
    pub fn label(&self, cfg: &SortConfig) -> String {
        match self {
            AlgoVariant::Det => cfg.variant_name(true),
            AlgoVariant::Iran => cfg.variant_name(false),
            AlgoVariant::Ran => format!("[RAN-S{}]", cfg.seq.suffix()),
            AlgoVariant::Bsi => "[BSI]".into(),
            AlgoVariant::Det2 => format!("[2L-DS{}]", cfg.seq.suffix()),
            AlgoVariant::Ran2 => format!("[2L-RAN-S{}]", cfg.seq.suffix()),
            AlgoVariant::DetK => format!("[KL-DS{}]", cfg.seq.suffix()),
            AlgoVariant::RanK => format!("[KL-RAN-S{}]", cfg.seq.suffix()),
            AlgoVariant::HelmanDet => "[39]".into(),
            AlgoVariant::HelmanRan => "[40]".into(),
            AlgoVariant::Psrs => "[44]".into(),
        }
    }

    /// Stable CLI/report tag (`det`, `iran`, `helman-det`, …).
    pub fn tag(&self) -> &'static str {
        match self {
            AlgoVariant::Det => "det",
            AlgoVariant::Iran => "iran",
            AlgoVariant::Ran => "ran",
            AlgoVariant::Bsi => "bsi",
            AlgoVariant::Det2 => "det2",
            AlgoVariant::Ran2 => "ran2",
            AlgoVariant::DetK => "det-k",
            AlgoVariant::RanK => "ran-k",
            AlgoVariant::HelmanDet => "helman-det",
            AlgoVariant::HelmanRan => "helman-ran",
            AlgoVariant::Psrs => "psrs",
        }
    }

    /// Parse a CLI tag; unknown tags list the accepted set.
    pub fn parse(s: &str) -> Result<AlgoVariant, CliError> {
        ALL_ALGOS
            .iter()
            .find(|a| a.tag() == s.to_ascii_lowercase())
            .copied()
            .ok_or_else(|| {
                let tags: Vec<&str> = ALL_ALGOS.iter().map(|a| a.tag()).collect();
                CliError(format!("unknown algorithm '{s}' (expected one of {})", tags.join(", ")))
            })
    }
}

/// The built-in key domains a sweep can run over (`key::Key`
/// instantiations with generators).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KeyDomain {
    /// `i32` — the paper's experiments (default).
    I32,
    /// `u64` — full 64-bit communication words.
    U64,
    /// Total-ordered `f64` (`key::F64`).
    F64T,
    /// `(u32 key, u32 payload)` records (`key::Record`).
    RecordU32,
    /// Variable-length strings with an 8-byte prefix radix image
    /// (`key::Str`, two wire words).
    Str,
}

/// Every built-in domain, in report order.
pub const ALL_DOMAINS: [KeyDomain; 5] = [
    KeyDomain::I32,
    KeyDomain::U64,
    KeyDomain::F64T,
    KeyDomain::RecordU32,
    KeyDomain::Str,
];

impl KeyDomain {
    /// Stable CLI/report tag.
    pub fn tag(&self) -> &'static str {
        match self {
            KeyDomain::I32 => "i32",
            KeyDomain::U64 => "u64",
            KeyDomain::F64T => "f64",
            KeyDomain::RecordU32 => "record",
            KeyDomain::Str => "str",
        }
    }

    /// Parse a CLI tag; unknown tags list the accepted set.
    pub fn parse(s: &str) -> Result<KeyDomain, CliError> {
        ALL_DOMAINS
            .iter()
            .find(|d| d.tag() == s.to_ascii_lowercase())
            .copied()
            .ok_or_else(|| {
                let tags: Vec<&str> = ALL_DOMAINS.iter().map(|d| d.tag()).collect();
                CliError(format!("unknown key domain '{s}' (expected one of {})", tags.join(", ")))
            })
    }
}

/// How a depth-k run picks its topology tree (the sweep's topology
/// axis; ignored by every variant except [`AlgoVariant::DetK`] /
/// [`AlgoVariant::RanK`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TopologyChoice {
    /// `sort::multilevel::default_topology(p)` — the depth-2 heuristic.
    Default,
    /// Ask the planner (`sort::plan`) under the run's calibrated
    /// parameters, per cell.
    Auto,
    /// A user-pinned shape; [`SweepSpec::validate`] checks its product
    /// against every `p` on the grid.
    Fixed(Topology),
}

impl TopologyChoice {
    /// Stable CLI/report tag (`default`, `auto`, or the shape label).
    pub fn label(&self) -> String {
        match self {
            TopologyChoice::Default => "default".into(),
            TopologyChoice::Auto => "auto".into(),
            TopologyChoice::Fixed(t) => t.label(),
        }
    }

    /// Parse a CLI tag: `default`, `auto`, or a shape like `8x4x4`
    /// (structurally validated; the product is checked against the
    /// grid's `p` values by [`SweepSpec::validate`]).
    pub fn parse(s: &str) -> Result<TopologyChoice, CliError> {
        match s.to_ascii_lowercase().as_str() {
            "default" => Ok(TopologyChoice::Default),
            "auto" | "plan" => Ok(TopologyChoice::Auto),
            other => {
                let err = || {
                    CliError(format!(
                        "unknown topology '{s}' (expected default, auto, or a \
                         shape like 8x4x4 whose factors multiply to p)"
                    ))
                };
                let mut factors = Vec::new();
                for part in other.split('x') {
                    match part.trim().parse::<usize>() {
                        Ok(k) if k >= 1 => factors.push(k),
                        _ => return Err(err()),
                    }
                }
                if factors.is_empty()
                    || factors.len() > MAX_TOPOLOGY_DEPTH
                    || (factors.len() > 1 && factors.iter().any(|&k| k < 2))
                {
                    return Err(err());
                }
                Ok(TopologyChoice::Fixed(Topology::new(&factors)))
            }
        }
    }
}

/// One experiment: algorithm × benchmark × (p, n) × config × backend.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    /// Which algorithm to run.
    pub algo: AlgoVariant,
    /// Input distribution (§6.3).
    pub bench: Benchmark,
    /// Processor count.
    pub p: usize,
    /// Total keys across all processors (must divide by `p`).
    pub n_total: usize,
    /// Variant knobs (sequential backend, duplicate policy, ω).
    pub cfg: SortConfig,
    /// Seed for randomized variants.
    pub seed: u64,
    /// Execution backend: threaded engine (default) or the
    /// deterministic simulator (`p` beyond host threads, seeded replay).
    pub backend: Backend,
    /// Pinned topology tree for the multi-level variants (`None` =
    /// `default_topology(p)` for det2/ran2, planner for det-k/ran-k).
    pub topology: Option<Topology>,
    /// Machine parameters to price and plan under (`None` = the paper's
    /// T3D preset for `p`, `crate::bsp::params::cray_t3d`).  Set via
    /// [`RunSpec::with_params`] — the `sorter::SortJob` builder uses it
    /// so a service tenant can submit jobs planned for its own machine.
    pub params_override: Option<BspParams>,
}

impl RunSpec {
    /// A spec with the default configuration, seed and backend.
    pub fn new(algo: AlgoVariant, bench: Benchmark, p: usize, n_total: usize) -> RunSpec {
        RunSpec {
            algo,
            bench,
            p,
            n_total,
            cfg: SortConfig::default(),
            seed: 0x0BEE,
            backend: Backend::Threaded,
            topology: None,
            params_override: None,
        }
    }

    /// Replace the configuration.
    pub fn with_cfg(mut self, cfg: SortConfig) -> RunSpec {
        self.cfg = cfg;
        self
    }

    /// Replace the execution backend.
    pub fn with_backend(mut self, backend: Backend) -> RunSpec {
        self.backend = backend;
        self
    }

    /// Pin the multi-level topology tree.
    pub fn with_topology(mut self, topology: Topology) -> RunSpec {
        self.topology = Some(topology);
        self
    }

    /// Replace the seed for the randomized variants.
    pub fn with_seed(mut self, seed: u64) -> RunSpec {
        self.seed = seed;
        self
    }

    /// Price and plan under explicit machine parameters instead of the
    /// paper's T3D preset (`params.p` should equal the spec's `p`).
    pub fn with_params(mut self, params: BspParams) -> RunSpec {
        self.params_override = Some(params);
        self
    }

    /// The machine parameters this spec prices and plans under: the
    /// override if one was set, else the paper's T3D parameters for the
    /// spec's `p` (table pricing).
    pub fn params(&self) -> BspParams {
        self.params_override.unwrap_or_else(|| crate::bsp::params::cray_t3d(self.p))
    }
}

/// One cell of a sweep's cross-product (a [`RunSpec`] plus the key
/// domain it runs over).
#[derive(Clone, Copy, Debug)]
pub struct RunConfig {
    /// Which algorithm.
    pub algo: AlgoVariant,
    /// Input distribution.
    pub bench: Benchmark,
    /// Key domain.
    pub domain: KeyDomain,
    /// Total keys.
    pub n: usize,
    /// Processor count.
    pub p: usize,
    /// Execution backend for this cell.
    pub backend: Backend,
    /// Topology choice for this cell (only the depth-k variants read it).
    pub topology: TopologyChoice,
    /// Local-sort engine for the per-processor base case.
    pub local_sort: LocalSortEngine,
    /// Out-of-core memory budget in keys per processor.  `None` runs
    /// the cell's algorithm in core; `Some(m)` runs the EM-BSP
    /// external sort ([`crate::ext::sort_external`]) with that budget
    /// instead — the cell's `local_sort` picks the run-formation
    /// engine, and its `algo`/`topology` are not consulted.
    pub mem_budget: Option<usize>,
}

/// A full sweep: the cross-product of algorithms × benchmarks × key
/// domains × n × p, with warmup + repetition counts and the calibration
/// probe plan.  `experiment::run_study` executes it into a `StudyReport`.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Algorithms to run.
    pub algos: Vec<AlgoVariant>,
    /// Input distributions.
    pub benches: Vec<Benchmark>,
    /// Key domains.
    pub domains: Vec<KeyDomain>,
    /// Total input sizes.
    pub ns: Vec<usize>,
    /// Processor counts.
    pub ps: Vec<usize>,
    /// Execution backends to cross with the grid (`[Threaded]` by
    /// default; `--backends sim` runs the whole sweep on the
    /// deterministic simulator, where `p ∈ {64, 256, 1024}` is fair
    /// game because virtual processors cost no OS threads' worth of
    /// contention).
    pub backends: Vec<Backend>,
    /// Topology choices crossed with the grid for the depth-k variants
    /// (`[Default]` by default; other variants always get one cell with
    /// [`TopologyChoice::Default`], so this axis never multiplies them).
    pub topologies: Vec<TopologyChoice>,
    /// Extra cells appended verbatim after the cross-product — the
    /// `--quick` preset uses one to ride a sim-backend `det @ p = 256`
    /// configuration along with its threaded grid.
    pub extras: Vec<RunConfig>,
    /// Local-sort engines crossed with the grid (`[Quicksort]` by
    /// default; `--local-sorts quicksort,lsd-radix,ips` sweeps the
    /// base case, which shows up in each record's `algo_label` suffix).
    pub local_sorts: Vec<LocalSortEngine>,
    /// Memory budgets crossed with the grid (`[None]` by default — all
    /// in-core; `--mem-budgets none,65536` rides external-sort cells
    /// along every configuration).
    pub mem_budgets: Vec<Option<usize>>,
    /// Unrecorded warm-up runs per configuration.
    pub warmup: usize,
    /// Recorded repetitions per configuration (distinct seeds).
    pub reps: usize,
    /// Base seed; rep `r` runs with `seed + r`.
    pub seed: u64,
    /// Report tag: outputs land in `BENCH_<tag>.json` / `.md`.
    pub tag: String,
    /// Calibration probe sizes.
    pub probes: ProbePlan,
}

impl SweepSpec {
    /// The CI/acceptance preset: det + ran + the two-level det2 on `[U]`
    /// and `[DD]`, the `i32` and `u64` key domains, p ∈ {4, 8}, 16K
    /// keys, 1 warmup + 2 recorded reps — a complete miniature of the
    /// study (including one multi-level configuration) that finishes in
    /// seconds.  Two extra cells ride along: `det @ [Z] @ p = 8` so the
    /// skew generators can't silently rot out of the smoke path, and
    /// `det @ p = 256` on the deterministic simulator so every CI smoke
    /// also exercises the sim backend far beyond sensible thread counts.
    pub fn quick() -> SweepSpec {
        SweepSpec {
            algos: vec![AlgoVariant::Det, AlgoVariant::Ran, AlgoVariant::Det2],
            benches: vec![Benchmark::Uniform, Benchmark::DetDup],
            domains: vec![KeyDomain::I32, KeyDomain::U64],
            ns: vec![1 << 14],
            ps: vec![4, 8],
            backends: vec![Backend::Threaded],
            topologies: vec![TopologyChoice::Default],
            extras: vec![
                RunConfig {
                    algo: AlgoVariant::Det,
                    bench: Benchmark::Zipf(crate::gen::DEFAULT_ZIPF_THETA100),
                    domain: KeyDomain::I32,
                    n: 1 << 14,
                    p: 8,
                    backend: Backend::Threaded,
                    topology: TopologyChoice::Default,
                    local_sort: LocalSortEngine::Quicksort,
                    mem_budget: None,
                },
                RunConfig {
                    algo: AlgoVariant::Det,
                    bench: Benchmark::Uniform,
                    domain: KeyDomain::I32,
                    n: 1 << 14,
                    p: 256,
                    backend: Backend::Sim,
                    topology: TopologyChoice::Default,
                    local_sort: LocalSortEngine::Quicksort,
                    mem_budget: None,
                },
            ],
            local_sorts: vec![LocalSortEngine::Quicksort],
            mem_budgets: vec![None],
            warmup: 1,
            reps: 2,
            seed: 0x0BEE,
            tag: "quick".into(),
            probes: ProbePlan::quick(),
        }
    }

    /// The default full study: both one-optimal algorithms over the
    /// full benchmark set (§6.3 + skew families) at the paper's
    /// smaller grid.
    pub fn default_study() -> SweepSpec {
        SweepSpec {
            algos: vec![AlgoVariant::Det, AlgoVariant::Iran],
            benches: crate::gen::ALL_BENCHMARKS.to_vec(),
            domains: vec![KeyDomain::I32],
            ns: vec![1 << 20, 1 << 22],
            ps: vec![16, 64],
            backends: vec![Backend::Threaded],
            topologies: vec![TopologyChoice::Default],
            extras: Vec::new(),
            local_sorts: vec![LocalSortEngine::Quicksort],
            mem_budgets: vec![None],
            warmup: 1,
            reps: 3,
            seed: 0x0BEE,
            tag: "study".into(),
            probes: ProbePlan::default_plan(),
        }
    }

    /// Build a sweep from CLI arguments: `--quick` selects the preset,
    /// otherwise the full study; list options (`--algos det,ran`,
    /// `--benches U,DD`, `--domains i32,u64`, `--local-sorts
    /// quicksort,ips`, `--ns`, `--ps`) and the scalar knobs
    /// (`--warmup`, `--reps`, `--seed`, `--tag`, `--seq`) override
    /// either base.
    pub fn from_args(args: &Args) -> Result<SweepSpec, CliError> {
        let mut spec = if args.flag("quick") {
            SweepSpec::quick()
        } else {
            SweepSpec::default_study()
        };
        if let Some(v) = args.get("algos") {
            spec.algos = split_list(v).map(AlgoVariant::parse).collect::<Result<_, _>>()?;
        }
        if let Some(v) = args.get("benches") {
            spec.benches = split_list(v)
                .map(|s| {
                    Benchmark::parse_strict(s).map_err(|e| CliError(e.to_string()))
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = args.get("domains") {
            spec.domains = split_list(v).map(KeyDomain::parse).collect::<Result<_, _>>()?;
        }
        if let Some(v) = args.get("backends") {
            spec.backends = split_list(v)
                .map(|s| {
                    Backend::parse(s).ok_or_else(|| {
                        CliError(format!(
                            "unknown backend '{s}' (expected one of threaded, sim)"
                        ))
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = args.get("topologies") {
            spec.topologies =
                split_list(v).map(TopologyChoice::parse).collect::<Result<_, _>>()?;
        }
        if let Some(v) = args.get("local-sorts") {
            spec.local_sorts = split_list(v)
                .map(|s| {
                    LocalSortEngine::parse(s).ok_or_else(|| {
                        CliError(format!(
                            "unknown local-sort engine '{s}' (expected one of \
                             quicksort, lsd-radix, ips)"
                        ))
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = args.get("mem-budgets") {
            spec.mem_budgets = split_list(v)
                .map(|s| {
                    if s.eq_ignore_ascii_case("none") || s == "0" {
                        Ok(None)
                    } else {
                        s.parse::<usize>().map(Some).map_err(|_| {
                            CliError(format!(
                                "bad --mem-budgets entry '{s}' (expected a key count, \
                                 or 'none' for in-core)"
                            ))
                        })
                    }
                })
                .collect::<Result<_, _>>()?;
        }
        // Any explicit grid override replaces the preset's extra cells:
        // the user asked for exactly this cross-product.
        if [
            "algos", "benches", "domains", "backends", "topologies", "local-sorts",
            "mem-budgets", "ns", "ps",
        ]
            .iter()
            .any(|k| args.get(k).is_some())
        {
            spec.extras.clear();
        }
        spec.ns = args.get_list("ns", &spec.ns)?;
        spec.ps = args.get_list("ps", &spec.ps)?;
        spec.warmup = args.get_parsed("warmup", spec.warmup)?;
        spec.reps = args.get_parsed("reps", spec.reps)?;
        spec.seed = args.get_parsed("seed", spec.seed)?;
        if let Some(t) = args.get("tag") {
            spec.tag = t.to_string();
        }
        // Historical single-engine spelling: `--seq radix` pins the
        // whole sweep to one engine (now including `ips`).
        if let Some(s) = args.get("seq") {
            let engine = LocalSortEngine::parse(s)
                .ok_or_else(|| CliError(format!("unknown --seq {s}")))?;
            spec.local_sorts = vec![engine];
        }
        spec.validate().map_err(CliError)?;
        Ok(spec)
    }

    /// Structural validation: non-empty axes, divisible sizes, a sane
    /// tag (it becomes a file name), reps ≥ 1.
    pub fn validate(&self) -> Result<(), String> {
        if self.algos.is_empty() || self.benches.is_empty() || self.domains.is_empty() {
            return Err("sweep axes must be non-empty".into());
        }
        if self.ns.is_empty() || self.ps.is_empty() {
            return Err("--ns and --ps must be non-empty".into());
        }
        if self.backends.is_empty() {
            return Err("--backends must be non-empty".into());
        }
        if self.topologies.is_empty() {
            return Err("--topologies must be non-empty".into());
        }
        if self.local_sorts.is_empty() {
            return Err("--local-sorts must be non-empty".into());
        }
        if self.mem_budgets.is_empty() {
            return Err("--mem-budgets must be non-empty".into());
        }
        if self.mem_budgets.contains(&Some(0)) {
            return Err("--mem-budgets entries must hold at least one key".into());
        }
        for choice in &self.topologies {
            if let TopologyChoice::Fixed(t) = choice {
                for &p in &self.ps {
                    if t.nprocs() != p {
                        return Err(format!(
                            "topology {} has {} processors, but the grid runs p={p}",
                            t.label(),
                            t.nprocs()
                        ));
                    }
                }
            }
        }
        if self.reps == 0 {
            return Err("--reps must be at least 1".into());
        }
        for &n in &self.ns {
            for &p in &self.ps {
                if p == 0 || n % p != 0 {
                    return Err(format!("n={n} does not divide evenly over p={p}"));
                }
            }
        }
        for extra in &self.extras {
            if extra.p == 0 || extra.n % extra.p != 0 {
                return Err(format!(
                    "extra cell n={} does not divide evenly over p={}",
                    extra.n, extra.p
                ));
            }
        }
        if self.tag.is_empty()
            || !self
                .tag
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Err(format!("invalid --tag '{}' (alphanumeric, '-', '_')", self.tag));
        }
        Ok(())
    }

    /// The cross-product, in deterministic
    /// (algo, bench, domain, n, p, backend, topology, local_sort,
    /// mem_budget) nesting order, followed by the
    /// [`SweepSpec::extras`] cells verbatim.  The topology axis only
    /// multiplies the depth-k variants; every other algorithm gets
    /// exactly one cell with [`TopologyChoice::Default`].  The
    /// local-sort axis multiplies every variant — all eleven share the
    /// Ph2 base case.  The mem-budget axis defaults to the single
    /// in-core cell (`None`); any `Some(m)` entry rides an
    /// external-sort cell along each configuration.
    pub fn configs(&self) -> Vec<RunConfig> {
        let mut out = Vec::new();
        for &algo in &self.algos {
            let topologies: &[TopologyChoice] =
                if matches!(algo, AlgoVariant::DetK | AlgoVariant::RanK) {
                    &self.topologies
                } else {
                    &[TopologyChoice::Default]
                };
            for &bench in &self.benches {
                for &domain in &self.domains {
                    for &n in &self.ns {
                        for &p in &self.ps {
                            for &backend in &self.backends {
                                for &topology in topologies {
                                    for &local_sort in &self.local_sorts {
                                        for &mem_budget in &self.mem_budgets {
                                            out.push(RunConfig {
                                                algo,
                                                bench,
                                                domain,
                                                n,
                                                p,
                                                backend,
                                                topology,
                                                local_sort,
                                                mem_budget,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out.extend(self.extras.iter().copied());
        out
    }
}

fn split_list(v: &str) -> impl Iterator<Item = &str> {
    v.split(',').map(str::trim).filter(|s| !s.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn algo_and_domain_tags_roundtrip() {
        for a in ALL_ALGOS {
            assert_eq!(AlgoVariant::parse(a.tag()).unwrap(), a);
        }
        for d in ALL_DOMAINS {
            assert_eq!(KeyDomain::parse(d.tag()).unwrap(), d);
        }
        assert!(AlgoVariant::parse("nope").is_err());
        assert!(KeyDomain::parse("i33").is_err());
    }

    #[test]
    fn quick_preset_covers_acceptance_grid() {
        let spec = SweepSpec::quick();
        spec.validate().unwrap();
        assert!(spec.algos.contains(&AlgoVariant::Det) && spec.algos.contains(&AlgoVariant::Ran));
        // One multi-level configuration rides the CI smoke.
        assert!(spec.algos.contains(&AlgoVariant::Det2));
        assert_eq!(spec.ps, vec![4, 8]);
        assert_eq!(spec.domains.len(), 2);
        // 3 algos × 2 benches × 2 domains × 1 n × 2 p × 1 backend, plus
        // the det @ [Z] @ p=8 skew-generator cell and the sim-backend
        // det @ p=256 extra cell.
        assert_eq!(spec.configs().len(), 26);
        let configs = spec.configs();
        let zipf = configs[configs.len() - 2];
        assert_eq!(zipf.bench, Benchmark::Zipf(crate::gen::DEFAULT_ZIPF_THETA100));
        assert_eq!(zipf.p, 8);
        let last = *configs.last().unwrap();
        assert_eq!(last.backend, Backend::Sim);
        assert_eq!(last.p, 256);
        assert_eq!(last.algo, AlgoVariant::Det);
    }

    #[test]
    fn backends_axis_crosses_and_overrides_clear_extras() {
        let args = Args::parse(
            sv(&["experiment", "--quick", "--backends", "threaded,sim"]),
            &["backends"],
        )
        .unwrap();
        let spec = SweepSpec::from_args(&args).unwrap();
        // 24 base cells × 2 backends; the preset's extra is dropped
        // because the grid was explicitly overridden.
        assert_eq!(spec.configs().len(), 48);
        assert!(spec.configs().iter().any(|c| c.backend == Backend::Sim));
        assert!(spec.configs().iter().any(|c| c.backend == Backend::Threaded));

        let args = Args::parse(
            sv(&["experiment", "--quick", "--backends", "warp-drive"]),
            &["backends"],
        )
        .unwrap();
        assert!(SweepSpec::from_args(&args).is_err());
    }

    #[test]
    fn topology_axis_multiplies_only_depth_k_variants() {
        let mut spec = SweepSpec::quick();
        spec.algos = vec![AlgoVariant::Det, AlgoVariant::DetK];
        spec.topologies = vec![
            TopologyChoice::Default,
            TopologyChoice::Auto,
            TopologyChoice::Fixed(Topology::new(&[2, 4])),
        ];
        spec.ps = vec![8];
        spec.extras.clear();
        spec.validate().unwrap();
        // det: 2 benches × 2 domains × 1 topology; det-k: same grid × 3.
        assert_eq!(spec.configs().len(), 4 + 12);
        assert!(spec
            .configs()
            .iter()
            .all(|c| c.algo == AlgoVariant::DetK || c.topology == TopologyChoice::Default));

        // A fixed shape must match every p on the grid.
        spec.ps = vec![8, 4];
        let err = spec.validate().unwrap_err();
        assert!(err.contains("2x4"), "{err}");
    }

    #[test]
    fn local_sort_axis_multiplies_every_cell() {
        let mut spec = SweepSpec::quick();
        spec.extras.clear();
        let base = spec.configs().len();
        spec.local_sorts = crate::sort::ALL_ENGINES.to_vec();
        assert_eq!(spec.configs().len(), 3 * base);
        for engine in crate::sort::ALL_ENGINES {
            assert!(spec.configs().iter().any(|c| c.local_sort == engine));
        }
        spec.local_sorts.clear();
        assert!(spec.validate().is_err());
    }

    #[test]
    fn local_sorts_cli_axis_and_seq_alias() {
        let args = Args::parse(
            sv(&["experiment", "--quick", "--local-sorts", "quicksort,ips"]),
            &["local-sorts"],
        )
        .unwrap();
        let spec = SweepSpec::from_args(&args).unwrap();
        assert_eq!(
            spec.local_sorts,
            vec![LocalSortEngine::Quicksort, LocalSortEngine::Ips]
        );
        // Grid override drops the preset's extra cell: 24 base × 2.
        assert_eq!(spec.configs().len(), 48);

        let args =
            Args::parse(sv(&["experiment", "--quick", "--seq", "ips"]), &["seq"]).unwrap();
        let spec = SweepSpec::from_args(&args).unwrap();
        assert_eq!(spec.local_sorts, vec![LocalSortEngine::Ips]);

        let args =
            Args::parse(sv(&["experiment", "--quick", "--seq", "bogo"]), &["seq"]).unwrap();
        assert!(SweepSpec::from_args(&args).is_err());
    }

    #[test]
    fn mem_budget_axis_crosses_and_parses() {
        let mut spec = SweepSpec::quick();
        spec.extras.clear();
        let base = spec.configs().len();
        spec.mem_budgets = vec![None, Some(512)];
        spec.validate().unwrap();
        assert_eq!(spec.configs().len(), 2 * base);
        assert!(spec.configs().iter().any(|c| c.mem_budget == Some(512)));
        assert!(spec.configs().iter().any(|c| c.mem_budget.is_none()));
        spec.mem_budgets = vec![Some(0)];
        assert!(spec.validate().is_err());
        spec.mem_budgets.clear();
        assert!(spec.validate().is_err());

        let args = Args::parse(
            sv(&["experiment", "--quick", "--mem-budgets", "none,4096"]),
            &["mem-budgets"],
        )
        .unwrap();
        let spec = SweepSpec::from_args(&args).unwrap();
        assert_eq!(spec.mem_budgets, vec![None, Some(4096)]);
        // Explicit grid override drops the preset extras: 24 base × 2.
        assert_eq!(spec.configs().len(), 48);

        let args = Args::parse(
            sv(&["experiment", "--quick", "--mem-budgets", "lots"]),
            &["mem-budgets"],
        )
        .unwrap();
        assert!(SweepSpec::from_args(&args).is_err());
    }

    #[test]
    fn topology_choice_parses_and_rejects() {
        assert_eq!(TopologyChoice::parse("default").unwrap(), TopologyChoice::Default);
        assert_eq!(TopologyChoice::parse("auto").unwrap(), TopologyChoice::Auto);
        assert_eq!(
            TopologyChoice::parse("8x4x4").unwrap(),
            TopologyChoice::Fixed(Topology::new(&[8, 4, 4]))
        );
        assert!(TopologyChoice::parse("8x0x4").is_err());
        assert!(TopologyChoice::parse("1x8").is_err());
        assert!(TopologyChoice::parse("deep").is_err());
    }

    #[test]
    fn params_override_reprices_a_spec() {
        let spec = RunSpec::new(AlgoVariant::Det, Benchmark::Uniform, 4, 1 << 10);
        assert_eq!(spec.params(), crate::bsp::params::cray_t3d(4));
        let host = BspParams::host(4, 5.0, 0.01, 100.0);
        let spec = spec.with_params(host).with_seed(7);
        assert_eq!(spec.params(), host);
        assert_eq!(spec.seed, 7);
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            sv(&[
                "experiment", "--quick", "--algos", "det", "--benches", "U",
                "--domains", "i32", "--ns", "4096", "--ps", "4", "--reps", "1",
                "--tag", "t1",
            ]),
            &["algos", "benches", "domains", "ns", "ps", "reps", "tag"],
        )
        .unwrap();
        let spec = SweepSpec::from_args(&args).unwrap();
        assert_eq!(spec.configs().len(), 1);
        assert_eq!(spec.tag, "t1");
        assert_eq!(spec.reps, 1);
    }

    #[test]
    fn from_args_rejects_uneven_grid_and_bad_tag() {
        let args = Args::parse(
            sv(&["experiment", "--quick", "--ns", "1000", "--ps", "3"]),
            &["ns", "ps"],
        )
        .unwrap();
        assert!(SweepSpec::from_args(&args).is_err());
        let mut spec = SweepSpec::quick();
        spec.tag = "../evil".into();
        assert!(spec.validate().is_err());
    }
}
