//! Host (g, L) calibration from dedicated micro-probes (§6's "the T3D
//! behaves as a BSP machine with these parameters", re-done for whatever
//! machine runs the study).
//!
//! Three probes, mirroring how the paper's parameters were measured:
//!
//! * **barrier** — `L`: mean wall time of an empty superstep (two-barrier
//!   sync with no staged data);
//! * **all-to-all** — `g`: wall time of balanced all-to-all supersteps at
//!   several h-relation sizes, least-squares slope of `t(h)` (the
//!   intercept re-estimates `L` and is kept as a fit diagnostic);
//! * **compute** — the operation rate: a sequential quicksort of `n`
//!   random keys, priced at the ledger's own charge policy
//!   (`ops::sort_charge`, `n lg n`), exactly how the paper derives its
//!   "7 comparisons per microsecond";
//! * **block I/O** — the EM-BSP `G_io`: write-then-read a batch of
//!   fixed-size blocks through a temp-file [`SpillBlockStore`], mean
//!   wall µs per block transfer.  Simulator-backend calibrations carry
//!   the synthetic model constant instead
//!   ([`Calibration::from_params`]).
//!
//! Measurement is abstracted behind [`Prober`] so the arithmetic is
//! testable on a deterministic fake clock ([`SyntheticProber`]): feeding
//! the probes an exact `t = L + g·h` model must return the injected
//! `(g, L)` — see the tests.

use std::time::Instant;

use crate::bsp::engine::BspMachine;
use crate::bsp::ledger::Ledger;
use crate::bsp::params::BspParams;
use crate::bsp::Payload;
use crate::ext::store::{BlockStore, SpillBlockStore, DEFAULT_BLOCK_WORDS};
use crate::seq::{self, ops};
use crate::util::bench::black_box;
use crate::util::rng::SplitMix64;

/// Probe sizes for one calibration pass.
#[derive(Clone, Debug)]
pub struct ProbePlan {
    /// Empty supersteps timed for the barrier (L) probe.
    pub barrier_reps: usize,
    /// Target h-relation sizes (words per processor) for the g fit.
    pub a2a_h_words: Vec<u64>,
    /// All-to-all rounds per h point (first round is warm-up, excluded).
    pub a2a_rounds: usize,
    /// Keys sorted by the operation-rate probe.
    pub comp_n: usize,
    /// Blocks written-then-read by the `G_io` probe (0 skips it).
    pub io_blocks: usize,
}

impl ProbePlan {
    /// Full-precision plan for real studies.
    pub fn default_plan() -> ProbePlan {
        ProbePlan {
            barrier_reps: 32,
            a2a_h_words: vec![1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18],
            a2a_rounds: 8,
            comp_n: 1 << 16,
            io_blocks: 64,
        }
    }

    /// Shrunken plan for smoke runs, doctests and CI.
    pub fn quick() -> ProbePlan {
        ProbePlan {
            barrier_reps: 16,
            a2a_h_words: vec![1 << 10, 1 << 12, 1 << 14],
            a2a_rounds: 4,
            comp_n: 1 << 13,
            io_blocks: 16,
        }
    }
}

/// A calibrated machine point: the (g, L) pair in host microseconds, the
/// operation rate, and the fit diagnostics behind them.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Processor (thread) count this calibration is for.
    pub p: usize,
    /// Barrier latency L, µs (mean empty-superstep wall time).
    pub l_us: f64,
    /// Communication gap g, µs per 64-bit word (all-to-all slope).
    pub g_us_per_word: f64,
    /// Operation rate, comparisons per µs (sequential-sort probe).
    pub comps_per_us: f64,
    /// EM-BSP block-transfer charge `G_io`, µs per block (temp-file
    /// probe on the threaded backend, model constant on sim; 0 when
    /// the probe is skipped — in-core pricing is unaffected).
    pub g_io_us_per_block: f64,
    /// The (h_words, mean µs) points behind the g fit.
    pub a2a_points: Vec<(u64, f64)>,
    /// Intercept of the t(h) fit, µs — should land near `l_us`.
    pub fit_intercept_us: f64,
    /// Coefficient of determination of the t(h) fit (1 = perfect line).
    pub fit_r2: f64,
    /// Which execution backend's cells this calibration prices:
    /// `"threaded"` (host micro-probes) or `"sim"` (synthetic model
    /// parameters, [`Calibration::from_params`]).  Report consumers
    /// join runs to calibrations by `(p, backend)` — a mixed-backend
    /// sweep can legitimately carry both kinds at the same `p`.
    pub backend: String,
}

impl Calibration {
    /// The calibrated [`BspParams`]: predictions priced under these are
    /// in host microseconds, comparable to measured wall-clock.
    pub fn params(&self) -> BspParams {
        BspParams::host(self.p, self.l_us, self.g_us_per_word, self.comps_per_us)
            .with_io(self.g_io_us_per_block)
    }

    /// A *synthetic* calibration carrying exactly `params` — no probes
    /// run.  Used for simulator-backend sweep cells (`backend = sim`),
    /// whose virtual clock is driven by the model machine itself: host
    /// micro-probes would be meaningless there, and pricing under the
    /// model parameters keeps sim reports fully deterministic.  The fit
    /// diagnostics are the exact-model values (`r² = 1`, intercept = L)
    /// and the `a2a_points` are two points on the exact `L + g·h` line.
    pub fn from_params(params: &BspParams) -> Calibration {
        let line = |h: u64| params.l_us + params.g_us_per_word * h as f64;
        Calibration {
            p: params.p,
            l_us: params.l_us,
            g_us_per_word: params.g_us_per_word,
            comps_per_us: params.comps_per_us,
            g_io_us_per_block: params.io_us_per_block,
            a2a_points: vec![(1 << 10, line(1 << 10)), (1 << 14, line(1 << 14))],
            fit_intercept_us: params.l_us,
            fit_r2: 1.0,
            backend: crate::bsp::Backend::Sim.tag().to_string(),
        }
    }
}

/// A source of probe measurements: the host engine in production,
/// a synthetic model in tests.
pub trait Prober {
    /// Mean wall µs of one empty (barrier-only) superstep over `reps`
    /// supersteps.
    fn barrier_us(&mut self, reps: usize) -> f64;
    /// One balanced all-to-all superstep targeting an `h_words`-relation:
    /// returns `(actual h realized, mean µs per superstep)`.
    fn a2a_us(&mut self, h_words: u64, rounds: usize) -> (u64, f64);
    /// Sequential-sort probe over `n` keys: `(charged ops, wall µs)`.
    fn comp_probe(&mut self, n: usize) -> (f64, f64);
    /// Mean wall µs per block transfer over `blocks` block writes plus
    /// `blocks` reads.  Defaults to 0 (no external store measured) so
    /// pre-EM probers stay valid implementations.
    fn io_us_per_block(&mut self, _blocks: usize) -> f64 {
        0.0
    }
}

/// The real prober: runs micro-programs on the threaded BSP engine.
pub struct HostProber {
    /// Processor count to probe at.
    pub p: usize,
}

/// Mean superstep wall time, skipping the first `skip` supersteps
/// (thread-spawn and cache warm-up pollute them).
fn mean_superstep_wall(ledger: &Ledger, skip: usize) -> f64 {
    let len = ledger.supersteps.len();
    if len == 0 {
        return 0.0;
    }
    let skip = skip.min(len - 1);
    let steps = &ledger.supersteps[skip..];
    steps.iter().map(|s| s.wall_us).sum::<f64>() / steps.len() as f64
}

impl Prober for HostProber {
    fn barrier_us(&mut self, reps: usize) -> f64 {
        let machine = BspMachine::new(BspParams::unit(self.p));
        let run = machine.run(|ctx| {
            for _ in 0..reps.max(2) {
                ctx.sync("probe:barrier");
            }
        });
        mean_superstep_wall(&run.ledger, 2)
    }

    fn a2a_us(&mut self, h_words: u64, rounds: usize) -> (u64, f64) {
        let p = self.p;
        let per = (h_words as usize / p).max(1);
        let machine = BspMachine::new(BspParams::unit(p));
        let run = machine.run(|ctx| {
            for _ in 0..rounds.max(2) {
                let parts: Vec<Payload> =
                    (0..p).map(|_| Payload::Keys(vec![0i32; per])).collect();
                let inbox = ctx.all_to_all(parts, "probe:a2a");
                black_box(inbox.len());
            }
        });
        ((per * p) as u64, mean_superstep_wall(&run.ledger, 1))
    }

    fn comp_probe(&mut self, n: usize) -> (f64, f64) {
        let mut rng = SplitMix64::new(0xCA11B);
        let base: Vec<i32> = (0..n.max(2)).map(|_| rng.next_i32()).collect();
        // Best-of-3: the rate probe wants the machine's speed, not its
        // scheduling noise.
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let mut keys = base.clone();
            let t0 = Instant::now();
            seq::quicksort(&mut keys);
            let us = t0.elapsed().as_secs_f64() * 1e6;
            black_box(keys[0]);
            best = best.min(us);
        }
        (ops::sort_charge(base.len()), best)
    }

    fn io_us_per_block(&mut self, blocks: usize) -> f64 {
        if blocks == 0 {
            return 0.0;
        }
        // An unwritable temp dir means no spill backend exists on this
        // host: calibrate I/O-free rather than fail the whole study.
        let Ok(store) = SpillBlockStore::new() else { return 0.0 };
        let block = vec![0x10AD_B10Cu64; DEFAULT_BLOCK_WORDS];
        let t0 = Instant::now();
        let ids: Vec<_> = (0..blocks).map(|_| store.put(&block)).collect();
        for id in ids {
            black_box(store.read(id).len());
        }
        t0.elapsed().as_secs_f64() * 1e6 / (2 * blocks) as f64
    }
}

/// A deterministic fake clock implementing the exact BSP model
/// `t = L + g·h` at a fixed operation rate — the calibration tests
/// inject known parameters through it and require them back.
pub struct SyntheticProber {
    /// Injected L, µs.
    pub l_us: f64,
    /// Injected g, µs/word.
    pub g_us_per_word: f64,
    /// Injected rate, comparisons/µs.
    pub comps_per_us: f64,
    /// Injected `G_io`, µs/block.
    pub io_us_per_block: f64,
}

impl Prober for SyntheticProber {
    fn barrier_us(&mut self, _reps: usize) -> f64 {
        self.l_us
    }

    fn a2a_us(&mut self, h_words: u64, _rounds: usize) -> (u64, f64) {
        (h_words, self.l_us + self.g_us_per_word * h_words as f64)
    }

    fn comp_probe(&mut self, n: usize) -> (f64, f64) {
        let ops = ops::sort_charge(n);
        (ops, ops / self.comps_per_us)
    }

    fn io_us_per_block(&mut self, _blocks: usize) -> f64 {
        self.io_us_per_block
    }
}

/// Least-squares line fit `y = slope·x + intercept` over `points`;
/// returns `(slope, intercept, r²)`.  Fewer than two distinct x values
/// yield a degenerate fit (slope 0, intercept = mean y, r² 0).
pub fn fit_line(points: &[(f64, f64)]) -> (f64, f64, f64) {
    let n = points.len() as f64;
    if points.len() < 2 {
        let y = points.first().map(|&(_, y)| y).unwrap_or(0.0);
        return (0.0, y, 0.0);
    }
    let mean_x = points.iter().map(|&(x, _)| x).sum::<f64>() / n;
    let mean_y = points.iter().map(|&(_, y)| y).sum::<f64>() / n;
    let sxx: f64 = points.iter().map(|&(x, _)| (x - mean_x) * (x - mean_x)).sum();
    let sxy: f64 = points.iter().map(|&(x, y)| (x - mean_x) * (y - mean_y)).sum();
    if sxx == 0.0 {
        return (0.0, mean_y, 0.0);
    }
    let slope = sxy / sxx;
    let intercept = mean_y - slope * mean_x;
    let ss_tot: f64 = points.iter().map(|&(_, y)| (y - mean_y) * (y - mean_y)).sum();
    let ss_res: f64 = points
        .iter()
        .map(|&(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (slope, intercept, r2)
}

/// Run the full calibration pass through any [`Prober`].
pub fn calibrate_with<P: Prober>(p: usize, prober: &mut P, plan: &ProbePlan) -> Calibration {
    let l_us = prober.barrier_us(plan.barrier_reps).max(1e-3);
    let mut a2a_points: Vec<(u64, f64)> = Vec::with_capacity(plan.a2a_h_words.len());
    for &h in &plan.a2a_h_words {
        a2a_points.push(prober.a2a_us(h, plan.a2a_rounds));
    }
    let pts: Vec<(f64, f64)> = a2a_points.iter().map(|&(h, t)| (h as f64, t)).collect();
    let (slope, intercept, r2) = fit_line(&pts);
    let (ops, us) = prober.comp_probe(plan.comp_n);
    let g_io = prober.io_us_per_block(plan.io_blocks);
    Calibration {
        p,
        l_us,
        // Probe noise can push a tiny grid's slope to ≤ 0; clamp to keep
        // the calibrated parameters a valid pricing model.
        g_us_per_word: slope.max(1e-6),
        comps_per_us: (ops / us.max(1e-9)).max(1e-3),
        g_io_us_per_block: g_io.max(0.0),
        a2a_points,
        fit_intercept_us: intercept,
        fit_r2: r2,
        backend: crate::bsp::Backend::Threaded.tag().to_string(),
    }
}

/// Calibrate on this host at `p` processors (threads).
pub fn calibrate_host(p: usize, plan: &ProbePlan) -> Calibration {
    calibrate_with(p, &mut HostProber { p }, plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_line_exact() {
        let pts: Vec<(f64, f64)> = (1..=5).map(|i| (i as f64, 130.0 + 0.21 * i as f64)).collect();
        let (slope, intercept, r2) = fit_line(&pts);
        assert!((slope - 0.21).abs() < 1e-9, "slope={slope}");
        assert!((intercept - 130.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fit_line_degenerate() {
        assert_eq!(fit_line(&[]), (0.0, 0.0, 0.0));
        assert_eq!(fit_line(&[(3.0, 7.0)]), (0.0, 7.0, 0.0));
        let (s, i, _) = fit_line(&[(2.0, 5.0), (2.0, 9.0)]);
        assert_eq!((s, i), (0.0, 7.0));
    }

    #[test]
    fn synthetic_prober_returns_injected_g_and_l() {
        // The satellite requirement: a deterministic fake clock feeding
        // the exact model t = L + g·h must calibrate back to the
        // injected parameters within tolerance.
        let (l, g, rate, g_io) = (130.0, 0.21, 7.0, 327.0);
        let mut prober = SyntheticProber {
            l_us: l,
            g_us_per_word: g,
            comps_per_us: rate,
            io_us_per_block: g_io,
        };
        let calib = calibrate_with(16, &mut prober, &ProbePlan::default_plan());
        assert!((calib.l_us - l).abs() / l < 1e-9, "L={}", calib.l_us);
        assert!((calib.g_us_per_word - g).abs() / g < 1e-9, "g={}", calib.g_us_per_word);
        assert!((calib.comps_per_us - rate).abs() / rate < 1e-9);
        assert_eq!(calib.g_io_us_per_block, g_io);
        assert_eq!(calib.params().io_us_per_block, g_io);
        assert!((calib.fit_intercept_us - l).abs() / l < 1e-6);
        assert!(calib.fit_r2 > 0.999999);
        let params = calib.params();
        assert_eq!(params.p, 16);
        assert_eq!(params.l_us, calib.l_us);
    }

    #[test]
    fn noisy_synthetic_prober_stays_within_tolerance() {
        // ±2 % deterministic alternating noise on the a2a probe: the
        // least-squares fit must still land within 10 % of the truth.
        struct Noisy {
            inner: SyntheticProber,
            flip: bool,
        }
        impl Prober for Noisy {
            fn barrier_us(&mut self, reps: usize) -> f64 {
                self.inner.barrier_us(reps)
            }
            fn a2a_us(&mut self, h: u64, rounds: usize) -> (u64, f64) {
                let (h, t) = self.inner.a2a_us(h, rounds);
                self.flip = !self.flip;
                (h, t * if self.flip { 1.02 } else { 0.98 })
            }
            fn comp_probe(&mut self, n: usize) -> (f64, f64) {
                self.inner.comp_probe(n)
            }
        }
        let mut prober = Noisy {
            inner: SyntheticProber {
                l_us: 80.0,
                g_us_per_word: 0.3,
                comps_per_us: 50.0,
                io_us_per_block: 0.0,
            },
            flip: false,
        };
        let calib = calibrate_with(8, &mut prober, &ProbePlan::default_plan());
        assert!((calib.g_us_per_word - 0.3).abs() / 0.3 < 0.1, "g={}", calib.g_us_per_word);
        assert!((calib.l_us - 80.0).abs() / 80.0 < 1e-9);
    }

    #[test]
    fn host_calibration_is_finite_and_positive() {
        let plan = ProbePlan {
            barrier_reps: 8,
            a2a_h_words: vec![256, 1024, 4096],
            a2a_rounds: 3,
            comp_n: 1 << 11,
            io_blocks: 4,
        };
        let calib = calibrate_host(2, &plan);
        assert!(calib.l_us.is_finite() && calib.l_us > 0.0, "L={}", calib.l_us);
        assert!(calib.g_us_per_word.is_finite() && calib.g_us_per_word > 0.0);
        assert!(calib.comps_per_us.is_finite() && calib.comps_per_us > 0.0);
        assert!(
            calib.g_io_us_per_block.is_finite() && calib.g_io_us_per_block >= 0.0,
            "G_io={}",
            calib.g_io_us_per_block
        );
        assert_eq!(calib.a2a_points.len(), 3);
        assert!(calib.a2a_points.iter().all(|&(h, t)| h > 0 && t >= 0.0));
    }

    #[test]
    fn mean_superstep_wall_skips_warmup() {
        use crate::bsp::ledger::SuperstepRecord;
        let mut ledger = Ledger::default();
        for (i, w) in [100.0, 50.0, 10.0, 12.0].iter().enumerate() {
            ledger.supersteps.push(SuperstepRecord {
                label: format!("s{i}"),
                wall_us: *w,
                ..Default::default()
            });
        }
        assert!((mean_superstep_wall(&ledger, 2) - 11.0).abs() < 1e-12);
        // skip clamps when there are fewer steps than the skip count.
        assert!((mean_superstep_wall(&ledger, 10) - 12.0).abs() < 1e-12);
        assert_eq!(mean_superstep_wall(&Ledger::default(), 2), 0.0);
    }
}
