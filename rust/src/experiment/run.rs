//! Execute sweep cells: the generic verified single-run executor (shared
//! with the table harness) and the warmup + repetition measurement that
//! turns one [`RunConfig`] into a [`RunRecord`] of measured-vs-predicted
//! statistics.

use crate::baselines;
use crate::bsp::group::{GroupPartition, GroupedScope};
use crate::bsp::ledger::{ratio_or_nan, Ledger};
use crate::bsp::{Backend, Topology};
use crate::gen::{generate_typed_for_proc, GenKey};
use crate::key::{F64, RadixKey, Record, Str};
use crate::metrics::{Imbalance, RoutedVolume, RunReport};
use crate::primitives::bitonic::BitonicItem;
use crate::sort::common::ProcResult;
use crate::sort::{bsi, det, iran, multilevel, plan, ran, SortConfig};
use crate::util::bench::SampleStats;

use super::calibrate::Calibration;
use super::spec::{AlgoVariant, KeyDomain, RunConfig, RunSpec, SweepSpec, TopologyChoice};

/// Everything the full study demands of a key domain: generation
/// ([`GenKey`]), the radix backend ([`RadixKey`]) and bitonic exchange
/// ([`BitonicItem`]).  Blanket-implemented — all five built-in domains
/// qualify automatically.
pub trait StudyKey: GenKey + RadixKey + BitonicItem<Self> {}

impl<K: GenKey + RadixKey + BitonicItem<K>> StudyKey for K {}

/// The raw outcome of one verified run: per-processor results plus the
/// superstep/phase cost ledger.
#[derive(Debug)]
pub struct SingleRun<K> {
    /// Per-processor outputs in pid order.
    pub outputs: Vec<ProcResult<K>>,
    /// The run's cost ledger.
    pub ledger: Ledger,
}

/// One sweep cell's SPMD body, generic over the execution scope: the
/// *same* program text runs on the threaded engine (`BspCtx`) and the
/// deterministic simulator (`SimCtx`), each paired with its own
/// communicator type through [`GroupedScope`].
pub(crate) fn run_cell<K, S>(ctx: &mut S, comms: &[S::Comm], spec: &RunSpec) -> ProcResult<K>
where
    K: StudyKey,
    S: GroupedScope<K>,
{
    let params = spec.params();
    let cfg = spec.cfg;
    let (algo, bench, p, n, seed) = (spec.algo, spec.bench, spec.p, spec.n_total, spec.seed);
    let local: Vec<K> = generate_typed_for_proc(bench, ctx.pid(), p, n / p);
    match algo {
        AlgoVariant::Det => det::sort_det_bsp(ctx, &params, local, n, &cfg),
        AlgoVariant::Iran => iran::sort_iran_bsp(ctx, &params, local, n, &cfg, seed),
        AlgoVariant::Ran => ran::sort_ran_bsp(ctx, &params, local, n, &cfg, seed),
        AlgoVariant::Bsi => bsi::sort_bsi(ctx, local, &cfg),
        AlgoVariant::Det2 => multilevel::sort_multilevel_det(
            ctx,
            comms.first().expect("communicator built for det2"),
            &params,
            local,
            n,
            &cfg,
        ),
        AlgoVariant::Ran2 => multilevel::sort_multilevel_ran(
            ctx,
            comms.first().expect("communicator built for ran2"),
            &params,
            local,
            n,
            &cfg,
            seed,
        ),
        AlgoVariant::DetK => multilevel::sort_deep_det(ctx, comms, &params, local, n, &cfg),
        AlgoVariant::RanK => {
            multilevel::sort_deep_ran(ctx, comms, &params, local, n, &cfg, seed)
        }
        AlgoVariant::HelmanDet => baselines::sort_helman_det(ctx, &params, local, &cfg),
        AlgoVariant::HelmanRan => baselines::sort_helman_ran(ctx, &params, local, n, &cfg, seed),
        AlgoVariant::Psrs => baselines::sort_psrs(ctx, &params, local, &cfg),
    }
}

/// The topology tree a det-k/ran-k spec runs over: the pinned
/// `spec.topology` when set, otherwise the cost-model planner under the
/// spec's T3D parameters (the sweep harness resolves its topology axis
/// *before* this point by pinning, so the planner here only serves
/// direct [`RunSpec`] entries — tables and the CLI).
pub fn resolved_deep_topology(spec: &RunSpec) -> Topology {
    spec.topology.unwrap_or_else(|| {
        let params = spec.params();
        match spec.algo {
            AlgoVariant::RanK => {
                plan::plan_ran(spec.n_total, &params, iran::omega_ran(&spec.cfg, spec.n_total))
                    .topology
            }
            _ => plan::plan_det(spec.n_total, &params, det::omega_det(&spec.cfg, spec.n_total))
                .topology,
        }
    })
}

/// Build the communicator chain a spec's variant runs over (empty for
/// the one-level variants).  The two-level variants get exactly one
/// communicator — `default_groups(p)` groups, or the first factor of a
/// pinned topology; the depth-k variants get the full refinement chain
/// of their resolved topology.
pub(crate) fn build_comms<C: GroupPartition>(spec: &RunSpec) -> Vec<C> {
    match spec.algo {
        AlgoVariant::Det2 | AlgoVariant::Ran2 => {
            let k = match spec.topology {
                Some(t) if t.depth() > 1 => t.factor(0),
                Some(_) => 1,
                None => multilevel::default_groups(spec.p),
            };
            vec![C::split_even(spec.p, k)]
        }
        AlgoVariant::DetK | AlgoVariant::RanK => {
            resolved_deep_topology(spec).communicators::<C>()
        }
        _ => Vec::new(),
    }
}

/// Execute a spec over key domain `K` on the spec's backend and verify
/// the result (globally sorted, total size preserved) before returning
/// it — the harness never reports an unverified number.
///
/// Panics on an unsorted output or a size mismatch: that is a
/// harness-integrity guard, not a user error path.
pub fn execute_typed<K: StudyKey>(spec: &RunSpec) -> SingleRun<K> {
    let (p, n) = (spec.p, spec.n_total);
    assert!(n % p == 0, "n must divide evenly (paper setup): n={n} p={p}");

    // Both backends route through the persistent engine pool
    // (`sorter::Sorter::global()`): threaded specs run as SPMD jobs on
    // the pool's engine for this `p` (parked worker crews, recycled
    // slot-matrix scratch), simulator specs as closure jobs on its task
    // engine.  Charges are data-dependent, not timing-dependent, so the
    // pooled ledger is identical to the old spin-up-per-run path — the
    // conformance suite's charged-equivalence checks gate this.
    let run = crate::sorter::Sorter::global()
        .run_spec::<K>(spec)
        .unwrap_or_else(|e| panic!("BSP processor thread panicked: {e}"));

    verify_outputs(&run.outputs, n);
    SingleRun { outputs: run.outputs, ledger: run.ledger }
}

/// Harness-integrity guard shared by the in-core and external
/// executors: the concatenated per-processor outputs must be globally
/// sorted and total exactly `n` keys.
fn verify_outputs<K: StudyKey>(outputs: &[ProcResult<K>], n: usize) {
    let mut total = 0usize;
    let mut last: Option<K> = None;
    for r in outputs {
        for &k in &r.keys {
            if let Some(prev) = last {
                assert!(prev <= k, "harness: output not globally sorted");
            }
            last = Some(k);
        }
        total += r.keys.len();
    }
    assert_eq!(total, n, "harness: output size mismatch");
}

/// Execute one external-memory cell (`mem_budget = Some(budget)`)
/// through [`crate::ext::sort_external`] and verify it under the same
/// guards as the in-core path.  Input generation is the same
/// deterministic per-processor stream the in-core cells draw, so the
/// two paths are directly comparable key-for-key.
pub fn execute_external_typed<K: StudyKey>(cfg: &RunConfig, budget: usize) -> SingleRun<K> {
    let mut spec = crate::ext::ExtSortSpec::new(cfg.bench, cfg.n, cfg.p, budget);
    spec.backend = cfg.backend;
    spec.engine = cfg.local_sort;
    let run = crate::ext::sort_external::<K>(&spec)
        .unwrap_or_else(|e| panic!("external sort failed: {e}"));
    verify_outputs(&run.outputs, cfg.n);
    SingleRun { outputs: run.outputs, ledger: run.ledger }
}

/// Execute a spec in the paper's `i32` domain and reduce it to the
/// table harness's [`RunReport`] (T3D-priced).  This is the single-run
/// entry every table drives through.
pub fn execute(spec: &RunSpec) -> RunReport {
    let single = execute_typed::<i32>(spec);
    let params = spec.params();
    RunReport::new(
        spec.algo.label(&spec.cfg),
        spec.bench.tag(),
        spec.n_total,
        &params,
        &single.ledger,
        &single.outputs,
    )
}

/// Mean predicted T3D seconds over `reps` runs with distinct seeds —
/// the per-cell reduction every table uses.
pub fn avg_predicted_secs(spec: &RunSpec, reps: usize, base_seed: u64) -> f64 {
    let reps = reps.max(1);
    let mut total = 0.0;
    for r in 0..reps {
        let mut s = *spec;
        s.seed = base_seed.wrapping_add(r as u64 * 0x9E37);
        total += execute(&s).predicted_secs;
    }
    total / reps as f64
}

/// One phase row of a [`RunRecord`]: predicted (host-calibrated) µs,
/// measured µs, and their ratio (`NaN` when the model prices the phase
/// at zero; serialized as `null`).
#[derive(Clone, Debug)]
pub struct PhaseStat {
    /// Phase name.
    pub name: String,
    /// Predicted µs under the host calibration, mean over reps.
    pub predicted_us: f64,
    /// Measured wall µs (max over processors), mean over reps.
    pub wall_us: f64,
    /// `wall / predicted` (`NaN` if unpriced).
    pub ratio: f64,
}

/// Load-balance and communication-regularity metrics of one
/// configuration — the paper's max/avg keys per processor and routed
/// words per processor, aggregated over the recorded reps.
#[derive(Clone, Copy, Debug)]
pub struct Balance {
    /// Largest keys-received count of any processor in any rep.
    pub recv_max: usize,
    /// Smallest keys-received count of any processor in any rep.
    pub recv_min: usize,
    /// Mean keys received per processor (`n / p` when sizes balance).
    pub recv_mean: f64,
    /// `recv_max / recv_mean − 1` (the paper keeps this under 15 %).
    pub expansion: f64,
    /// Total words routed in Ph5, mean over reps.
    pub routed_words_total: f64,
    /// Largest per-processor routed h-relation of any rep.
    pub routed_words_max: u64,
    /// Routed words per processor (total / p), mean over reps.
    pub routed_words_avg: f64,
}

/// One superstep of the last recorded rep, exported for the report.
#[derive(Clone, Debug)]
pub struct SuperstepStat {
    /// Sync label.
    pub label: String,
    /// Phase active at the sync.
    pub phase: String,
    /// Max charged ops over processors.
    pub max_ops: f64,
    /// Realized h-relation, words.
    pub h_words: u64,
    /// Total words sent, all processors.
    pub total_words: u64,
    /// Measured wall µs (max over processors).
    pub wall_us: f64,
    /// Predicted µs under the host calibration (group-scoped records
    /// are priced with the group-local effective machine).
    pub predicted_us: f64,
    /// Participating processors (the group size for group-scoped
    /// supersteps of the multi-level sorts, `p` otherwise).
    pub procs: usize,
    /// Group-round index for group-scoped supersteps; `None` for
    /// whole-machine ones.
    pub round: Option<usize>,
    /// Blocks of external I/O charged at this sync (max over
    /// processors); zero everywhere except the external-sort phases.
    pub io_blocks: u64,
}

/// A fully measured sweep cell: wall-clock statistics over the recorded
/// reps, the host-calibrated prediction, per-phase ratios, balance
/// metrics and the last rep's superstep trace.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Algorithm tag (`det`, `ran`, …).
    pub algo: String,
    /// Paper-notation label (\[DSQ\], [RAN-SQ], …).
    pub algo_label: String,
    /// Benchmark tag (`[U]`, `[DD]`, …).
    pub bench: String,
    /// Key-domain tag (`i32`, `u64`, …).
    pub domain: String,
    /// Execution-backend tag (`threaded`, `sim`).  For `sim` cells the
    /// wall statistics are deterministic *virtual* microseconds.
    pub backend: String,
    /// The topology tree the cell ran over (`"2x4"`, `"8x4x4"`, …) —
    /// `Some` for the multi-level variants, `None` otherwise.
    pub topology: Option<String>,
    /// Total keys.
    pub n: usize,
    /// Processors.
    pub p: usize,
    /// External-memory budget in keys per processor; `None` for
    /// in-core cells.  `Some` cells ran [`crate::ext::sort_external`]
    /// instead of the cell's `algo`.
    pub mem_budget: Option<usize>,
    /// Warm-up runs that preceded the recorded reps.
    pub warmup: usize,
    /// Recorded repetitions.
    pub reps: usize,
    /// Measured end-to-end wall µs over the reps.
    pub wall_us: SampleStats,
    /// Predicted end-to-end µs (host calibration), mean over reps.
    pub predicted_us: f64,
    /// `wall_us.mean / predicted_us`.
    pub ratio: f64,
    /// Per-phase measured-vs-predicted rows.
    pub phases: Vec<PhaseStat>,
    /// Balance and routing-volume metrics.
    pub balance: Balance,
    /// Superstep trace of the last recorded rep.
    pub supersteps: Vec<SuperstepStat>,
}

/// Measure one sweep cell over a concrete key domain: `warmup`
/// unrecorded runs, then `reps` recorded runs with distinct seeds,
/// reduced into a [`RunRecord`] priced under `calib`.
pub fn measure_typed<K: StudyKey>(
    cfg: &RunConfig,
    sweep: &SweepSpec,
    calib: &Calibration,
) -> RunRecord {
    assert_eq!(cfg.p, calib.p, "calibration/config processor mismatch");
    let sort_cfg = SortConfig::default().with_local_sort(cfg.local_sort);
    let host = calib.params();

    // Resolve the cell's topology choice up front so every warmup and
    // rep runs the same tree: `default` pins the depth-2 heuristic,
    // `auto` asks the planner under the *calibrated* machine (this is
    // where the topology axis meets the cost model), fixed shapes pass
    // through (validated against `p` by `SweepSpec::validate`).
    let planned = if cfg.mem_budget.is_some() {
        // External cells run the two-phase EM sort, not the cell's
        // algorithm — no topology tree to resolve.
        None
    } else {
        match cfg.topology {
        TopologyChoice::Default => match cfg.algo {
            AlgoVariant::DetK | AlgoVariant::RanK => {
                Some(multilevel::default_topology(cfg.p))
            }
            _ => None,
        },
        TopologyChoice::Auto => match cfg.algo {
            AlgoVariant::RanK => {
                Some(plan::plan_ran(cfg.n, &host, iran::omega_ran(&sort_cfg, cfg.n)).topology)
            }
            _ => Some(plan::plan_det(cfg.n, &host, det::omega_det(&sort_cfg, cfg.n)).topology),
        },
        TopologyChoice::Fixed(t) => Some(t),
        }
    };
    let mut spec = RunSpec::new(cfg.algo, cfg.bench, cfg.p, cfg.n)
        .with_cfg(sort_cfg)
        .with_backend(cfg.backend);
    spec.topology = planned;
    let topology = if cfg.mem_budget.is_some() {
        None
    } else {
        match cfg.algo {
            AlgoVariant::Det2 | AlgoVariant::Ran2 | AlgoVariant::DetK | AlgoVariant::RanK => {
                Some(planned.unwrap_or_else(|| multilevel::default_topology(cfg.p)).label())
            }
            _ => None,
        }
    };

    // One rep of this cell: external cells route through the EM-BSP
    // external sort (deterministic inputs — the seed only matters to
    // the in-core randomized variants).
    let run_once = |seed: u64| -> SingleRun<K> {
        match cfg.mem_budget {
            Some(budget) => execute_external_typed::<K>(cfg, budget),
            None => {
                let mut s = spec;
                s.seed = seed;
                execute_typed::<K>(&s)
            }
        }
    };

    // Warmup exists to heat caches and thread pools for the threaded
    // backend; simulator cells are bit-for-bit deterministic, so warming
    // them would only re-run the sweep's most expensive cells for
    // byte-identical results.
    if cfg.backend == Backend::Threaded {
        for w in 0..sweep.warmup {
            let _ = run_once(sweep.seed.wrapping_sub(1 + w as u64));
        }
    }

    let reps = sweep.reps.max(1);
    let mut wall_samples = Vec::with_capacity(reps);
    let mut predicted_sum = 0.0;
    // Phase accumulators: (predicted µs sum, wall µs sum) by name.
    let mut phase_acc: Vec<(String, f64, f64)> = Vec::new();
    let mut recv_max = 0usize;
    let mut recv_min = usize::MAX;
    let mut recv_mean = 0.0f64;
    let mut routed_total_sum = 0.0f64;
    let mut routed_max = 0u64;
    let mut last_ledger: Option<Ledger> = None;

    for r in 0..reps {
        let single = run_once(sweep.seed.wrapping_add(r as u64));
        wall_samples.push(single.ledger.wall_us);
        predicted_sum += single.ledger.predicted_us(&host);
        for row in single.ledger.phase_comparison(&host) {
            match phase_acc.iter().position(|(name, _, _)| *name == row.phase) {
                Some(i) => {
                    phase_acc[i].1 += row.predicted_secs * 1e6;
                    phase_acc[i].2 += row.wall_secs * 1e6;
                }
                None => phase_acc.push((
                    row.phase,
                    row.predicted_secs * 1e6,
                    row.wall_secs * 1e6,
                )),
            }
        }
        let imb = Imbalance::from_results(&single.outputs);
        recv_max = recv_max.max(imb.max_received);
        recv_min = recv_min.min(imb.min_received);
        recv_mean += imb.mean_received / reps as f64;
        let vol = RoutedVolume::from_ledger(&single.ledger, cfg.p);
        routed_total_sum += vol.total_words as f64;
        routed_max = routed_max.max(vol.max_words);
        last_ledger = Some(single.ledger);
    }

    let wall_us = SampleStats::from_samples(&wall_samples);
    let predicted_us = predicted_sum / reps as f64;
    let phases: Vec<PhaseStat> = phase_acc
        .into_iter()
        .map(|(name, pred_sum, wall_sum)| {
            let predicted = pred_sum / reps as f64;
            let wall = wall_sum / reps as f64;
            PhaseStat {
                name,
                predicted_us: predicted,
                wall_us: wall,
                ratio: ratio_or_nan(wall, predicted),
            }
        })
        .collect();
    let routed_total = routed_total_sum / reps as f64;
    let balance = Balance {
        recv_max,
        recv_min: if recv_min == usize::MAX { 0 } else { recv_min },
        recv_mean,
        expansion: if recv_mean > 0.0 { recv_max as f64 / recv_mean - 1.0 } else { 0.0 },
        routed_words_total: routed_total,
        routed_words_max: routed_max,
        routed_words_avg: routed_total / cfg.p.max(1) as f64,
    };
    let ledger = last_ledger.expect("at least one rep ran");
    let supersteps = ledger
        .supersteps
        .iter()
        .map(|s| SuperstepStat {
            label: s.label.clone(),
            phase: s.phase.clone(),
            max_ops: s.max_ops,
            h_words: s.h_words,
            total_words: s.total_words,
            wall_us: s.wall_us,
            predicted_us: s.predicted_us(&host),
            procs: s.procs,
            round: s.round,
            io_blocks: s.io_blocks,
        })
        .collect();

    // External cells carry a label suffix so the tables never read an
    // EM run as the in-core algorithm it displaced.
    let algo_label = match cfg.mem_budget {
        Some(_) => format!("{}+EM", cfg.algo.label(&sort_cfg)),
        None => cfg.algo.label(&sort_cfg),
    };
    RunRecord {
        algo: cfg.algo.tag().to_string(),
        algo_label,
        bench: cfg.bench.tag(),
        domain: cfg.domain.tag().to_string(),
        backend: cfg.backend.tag().to_string(),
        topology,
        n: cfg.n,
        p: cfg.p,
        mem_budget: cfg.mem_budget,
        // Sim cells skip warmup (deterministic; nothing to warm).
        warmup: if cfg.backend == Backend::Threaded { sweep.warmup } else { 0 },
        reps,
        wall_us,
        predicted_us,
        ratio: ratio_or_nan(wall_us.mean, predicted_us),
        phases,
        balance,
        supersteps,
    }
}

/// Measure one sweep cell, dispatching on its key domain.
pub fn measure_config(cfg: &RunConfig, sweep: &SweepSpec, calib: &Calibration) -> RunRecord {
    match cfg.domain {
        KeyDomain::I32 => measure_typed::<i32>(cfg, sweep, calib),
        KeyDomain::U64 => measure_typed::<u64>(cfg, sweep, calib),
        KeyDomain::F64T => measure_typed::<F64>(cfg, sweep, calib),
        KeyDomain::RecordU32 => measure_typed::<Record>(cfg, sweep, calib),
        KeyDomain::Str => measure_typed::<Str>(cfg, sweep, calib),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::calibrate::{calibrate_with, ProbePlan, SyntheticProber};
    use crate::gen::Benchmark;

    fn t3d_like_calibration(p: usize) -> Calibration {
        let mut prober = SyntheticProber {
            l_us: 130.0,
            g_us_per_word: 0.21,
            comps_per_us: 7.0,
            io_us_per_block: 327.0,
        };
        calibrate_with(p, &mut prober, &ProbePlan::quick())
    }

    fn quick_sweep() -> SweepSpec {
        let mut sweep = SweepSpec::quick();
        sweep.ns = vec![1 << 12];
        sweep.ps = vec![4];
        sweep.warmup = 0;
        sweep.reps = 2;
        sweep
    }

    #[test]
    fn executes_all_variants_small() {
        for algo in super::super::spec::ALL_ALGOS {
            let spec = RunSpec::new(algo, Benchmark::Uniform, 4, 1 << 10);
            let report = execute(&spec);
            assert!(report.predicted_secs > 0.0, "{algo:?}");
            assert!(report.wall_secs > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "n must divide evenly")]
    fn uneven_n_rejected() {
        execute(&RunSpec::new(AlgoVariant::Det, Benchmark::Uniform, 3, 100));
    }

    #[test]
    fn typed_execution_sorts_u64() {
        let spec = RunSpec::new(AlgoVariant::Ran, Benchmark::DetDup, 4, 1 << 10);
        let single = execute_typed::<u64>(&spec);
        let total: usize = single.outputs.iter().map(|r| r.keys.len()).sum();
        assert_eq!(total, 1 << 10);
        assert!(!single.ledger.supersteps.is_empty());
    }

    #[test]
    fn sim_backend_executes_all_variants_and_is_deterministic() {
        // Every variant runs on the simulator through the same
        // execute_typed entry, and two executions of the same spec are
        // identical down to the virtual wall clock.
        for algo in super::super::spec::ALL_ALGOS {
            let spec = RunSpec::new(algo, Benchmark::Uniform, 8, 1 << 10)
                .with_backend(Backend::Sim);
            let a = execute_typed::<i32>(&spec);
            let b = execute_typed::<i32>(&spec);
            let ka: Vec<i32> = a.outputs.iter().flat_map(|r| r.keys.clone()).collect();
            let kb: Vec<i32> = b.outputs.iter().flat_map(|r| r.keys.clone()).collect();
            assert_eq!(ka, kb, "{algo:?} outputs must replay identically");
            assert_eq!(
                a.ledger.wall_us, b.ledger.wall_us,
                "{algo:?} virtual wall must replay identically"
            );
            assert!(a.ledger.wall_us > 0.0);
        }
    }

    #[test]
    fn sim_cell_measures_with_synthetic_calibration() {
        let sweep = quick_sweep();
        // Simulator cells price under the model machine itself.
        let calib = Calibration::from_params(&crate::bsp::params::cray_t3d(64));
        let cfg = RunConfig {
            algo: AlgoVariant::Det,
            bench: Benchmark::Uniform,
            domain: KeyDomain::I32,
            n: 1 << 12,
            p: 64,
            backend: Backend::Sim,
            topology: TopologyChoice::Default,
            local_sort: crate::sort::LocalSortEngine::Quicksort,
            mem_budget: None,
        };
        let rec = measure_typed::<i32>(&cfg, &sweep, &calib);
        assert_eq!(rec.backend, "sim");
        assert_eq!(rec.p, 64);
        assert!(rec.wall_us.mean > 0.0 && rec.predicted_us > 0.0);
        assert!(rec.ratio.is_finite() && rec.ratio > 0.0);
        // Deterministic virtual time: re-measuring reproduces the wall
        // statistics exactly.
        let rec2 = measure_typed::<i32>(&cfg, &sweep, &calib);
        assert_eq!(rec.wall_us.mean, rec2.wall_us.mean);
        assert_eq!(rec.wall_us.stddev, rec2.wall_us.stddev);
    }

    #[test]
    fn ips_cell_runs_and_labels_with_engine_suffix() {
        let sweep = quick_sweep();
        let calib = Calibration::from_params(&crate::bsp::params::cray_t3d(4));
        let cfg = RunConfig {
            algo: AlgoVariant::Det,
            bench: Benchmark::Uniform,
            domain: KeyDomain::U64,
            n: 1 << 12,
            p: 4,
            backend: Backend::Sim,
            topology: TopologyChoice::Default,
            local_sort: crate::sort::LocalSortEngine::Ips,
            mem_budget: None,
        };
        let rec = measure_typed::<u64>(&cfg, &sweep, &calib);
        // The engine rides the record's paper label: [DSI].
        assert_eq!(rec.algo_label, "[DSI]");
        assert!(rec.wall_us.mean > 0.0 && rec.predicted_us > 0.0);
    }

    #[test]
    fn detk_cell_resolves_and_records_its_topology() {
        let mut sweep = quick_sweep();
        sweep.reps = 1;
        let calib = Calibration::from_params(&crate::bsp::params::cray_t3d(64));
        let cfg = RunConfig {
            algo: AlgoVariant::DetK,
            bench: Benchmark::Uniform,
            domain: KeyDomain::I32,
            n: 1 << 12,
            p: 64,
            backend: Backend::Sim,
            topology: TopologyChoice::Auto,
            local_sort: crate::sort::LocalSortEngine::Quicksort,
            mem_budget: None,
        };
        let rec = measure_typed::<i32>(&cfg, &sweep, &calib);
        let label = rec.topology.expect("depth-k cells record their topology");
        let t = plan::parse_topology(&label, 64).expect("recorded label is a valid shape");
        assert_eq!(t.nprocs(), 64);

        // Fixed shapes are honored verbatim and replayed exactly.
        let cfg =
            RunConfig { topology: TopologyChoice::Fixed(Topology::new(&[4, 4, 4])), ..cfg };
        let rec = measure_typed::<i32>(&cfg, &sweep, &calib);
        assert_eq!(rec.topology.as_deref(), Some("4x4x4"));
        assert!(rec.wall_us.mean > 0.0 && rec.predicted_us > 0.0);

        // One-level variants carry no topology.
        let cfg = RunConfig { algo: AlgoVariant::Det, topology: TopologyChoice::Default, ..cfg };
        assert_eq!(measure_typed::<i32>(&cfg, &sweep, &calib).topology, None);
    }

    #[test]
    fn det_run_phase_ratios_are_finite_and_positive() {
        // The satellite requirement: in a small det run, every *priced*
        // phase must carry a finite, positive measured-vs-predicted
        // ratio.
        let sweep = quick_sweep();
        let calib = t3d_like_calibration(4);
        let cfg = RunConfig {
            algo: AlgoVariant::Det,
            bench: Benchmark::Uniform,
            domain: KeyDomain::I32,
            n: 1 << 12,
            p: 4,
            backend: Backend::Threaded,
            topology: TopologyChoice::Default,
            local_sort: crate::sort::LocalSortEngine::Quicksort,
            mem_budget: None,
        };
        let rec = measure_typed::<i32>(&cfg, &sweep, &calib);
        let priced: Vec<&PhaseStat> =
            rec.phases.iter().filter(|ph| ph.predicted_us > 0.0).collect();
        assert!(priced.len() >= 4, "expected several priced phases, got {:?}", rec.phases);
        for ph in priced {
            assert!(
                ph.ratio.is_finite() && ph.ratio > 0.0,
                "phase {} ratio={} (wall={} pred={})",
                ph.name,
                ph.ratio,
                ph.wall_us,
                ph.predicted_us
            );
        }
        assert!(rec.ratio.is_finite() && rec.ratio > 0.0);
        assert!(rec.predicted_us > 0.0 && rec.wall_us.mean > 0.0);
        assert_eq!(rec.wall_us.n, 2);
    }

    #[test]
    fn external_cell_measures_routes_through_the_em_sort() {
        let mut sweep = quick_sweep();
        sweep.reps = 1;
        let calib = t3d_like_calibration(4);
        let cfg = RunConfig {
            algo: AlgoVariant::Det,
            bench: Benchmark::Uniform,
            domain: KeyDomain::I32,
            n: 1 << 12,
            p: 4,
            backend: Backend::Sim,
            topology: TopologyChoice::Default,
            local_sort: crate::sort::LocalSortEngine::Quicksort,
            mem_budget: Some(256),
        };
        let rec = measure_typed::<i32>(&cfg, &sweep, &calib);
        assert_eq!(rec.mem_budget, Some(256));
        assert_eq!(rec.algo_label, "[DSQ]+EM");
        assert_eq!(rec.topology, None);
        assert!(rec.wall_us.mean > 0.0 && rec.predicted_us > 0.0);
        // The trace carries the charged block I/O of the external
        // phases — the EM third parameter is visible in the record.
        assert!(rec.supersteps.iter().any(|s| s.io_blocks > 0));
        let in_core = RunConfig { mem_budget: None, ..cfg };
        let rec2 = measure_typed::<i32>(&in_core, &sweep, &calib);
        assert_eq!(rec2.mem_budget, None);
        assert!(rec2.supersteps.iter().all(|s| s.io_blocks == 0));
    }

    #[test]
    fn balance_metrics_track_routing() {
        let sweep = quick_sweep();
        let calib = t3d_like_calibration(4);
        let cfg = RunConfig {
            algo: AlgoVariant::Det,
            bench: Benchmark::Uniform,
            domain: KeyDomain::U64,
            n: 1 << 12,
            p: 4,
            backend: Backend::Threaded,
            topology: TopologyChoice::Default,
            local_sort: crate::sort::LocalSortEngine::Quicksort,
            mem_budget: None,
        };
        let rec = measure_config(&cfg, &sweep, &calib);
        assert_eq!(rec.domain, "u64");
        assert!(rec.balance.recv_max >= rec.balance.recv_mean as usize);
        assert!(rec.balance.recv_mean > 0.0);
        // Routing moves every key exactly once: total routed words equal
        // n (bare keys on the wire, §5.1.1 transparency).
        assert!(rec.balance.routed_words_total > 0.0);
        assert!(rec.balance.routed_words_max > 0);
        assert!(
            rec.balance.routed_words_avg <= rec.balance.routed_words_max as f64 + 1e-9
        );
        assert!(!rec.supersteps.is_empty());
    }
}
