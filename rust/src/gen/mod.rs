//! Sorting benchmark input generators (paper §6.3 + skew expansion).
//!
//! The paper's seven distributions, faithful to their definitions, each
//! generated *per processor* with the paper's seeding (`21 + 1001·i` for
//! processor `i`, glibc `random()`), plus five skew families beyond the
//! paper (the robustness question of Axtmann–Sanders, and the benchmark
//! set of the bachelorthesis sorting benches):
//!
//! | tag     | name                              |
//! |---------|-----------------------------------|
//! | \[U\]     | Uniform                           |
//! | \[G\]     | Gaussian (4-call average)         |
//! | \[B\]     | Bucket sorted                     |
//! | [g-G]   | g-Group (g = 2 default, any g ≥ 2)|
//! | \[S\]     | Staggered                         |
//! | \[DD\]    | Deterministic duplicates          |
//! | \[WR\]    | Worst-case regular [39]           |
//! | [Z-θ]   | Zipf, exponent θ/100              |
//! | \[X\]     | Exponential                       |
//! | [AS-f]  | Almost sorted, f % perturbed      |
//! | \[R\]     | Reverse (globally descending)     |
//! | \[8D\]    | Eight-dup, `(i⁸ + n/2) mod n`     |
//!
//! `INT_MAX` below is the paper's "maximum integer value plus one ... in
//! a 32-bit signed arithmetic data type", i.e. 2³¹.

use crate::key::{F64, Key, Record, Str};
use crate::runtime::error::RuntimeError;
use crate::util::rng::{BsdRandom, SplitMix64};

/// `INT_MAX` of the paper: 2³¹ (as i64 to avoid overflow in range math).
pub const INT_MAX_P1: i64 = 1 << 31;

/// Default Zipf exponent in hundredths: θ = 1.0, the classic harmonic
/// head (~13 % of the mass on the top rank over [`ZIPF_RANKS`] ranks).
pub const DEFAULT_ZIPF_THETA100: u32 = 100;

/// Default \[AS-f\] perturbation: 5 % of each processor's keys displaced.
pub const DEFAULT_ALMOST_SORTED_PCT: u32 = 5;

/// Number of distinct ranks a [Z-θ] stream draws from.
const ZIPF_RANKS: usize = 1024;

/// The seven benchmark distributions of §6.3 plus the five skew
/// families (zipf, exponential, almost-sorted, reverse, eight-dup).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Benchmark {
    /// \[U\] uniform over [0, 2³¹−1].
    Uniform,
    /// \[G\] Gaussian approximation: mean of four `random()` calls.
    Gaussian,
    /// \[B\] bucket sorted: p per-proc buckets of n/p² uniform keys each.
    Bucket,
    /// [g-G] g-group with this g (paper tables use 2-G).
    GGroup(usize),
    /// \[S\] staggered.
    Staggered,
    /// \[DD\] deterministic duplicates.
    DetDup,
    /// \[WR\] worst-case-regular (the [39] adversary for regular sampling).
    WorstRegular,
    /// [Z-θ] Zipf over [`ZIPF_RANKS`] ranks with exponent θ = `.0`/100:
    /// rank k drawn with probability ∝ 1/k^θ, head rank = smallest key,
    /// massively duplicated — adversarial for sampled splitters.
    Zipf(u32),
    /// \[X\] exponential: −ln(u)·INT_MAX/16, long sparse upper tail.
    Exponential,
    /// [AS-f] almost sorted: the globally sorted block deal with f % of
    /// each processor's keys displaced by random transpositions.
    AlmostSorted(u32),
    /// \[R\] reverse: the globally sorted sequence, descending.
    Reverse,
    /// \[8D\] eight-dup: global index i ↦ `(i⁸ + n/2) mod n` — heavy,
    /// irregular duplication (most eighth-power residues collapse).
    EightDup,
}

/// Every benchmark in table order: the paper's U, G, 2-G, B, S, DD, WR,
/// then the skew families Z, X, AS, R, 8D (default parameters).
pub const ALL_BENCHMARKS: [Benchmark; 12] = [
    Benchmark::Uniform,
    Benchmark::Gaussian,
    Benchmark::GGroup(2),
    Benchmark::Bucket,
    Benchmark::Staggered,
    Benchmark::DetDup,
    Benchmark::WorstRegular,
    Benchmark::Zipf(DEFAULT_ZIPF_THETA100),
    Benchmark::Exponential,
    Benchmark::AlmostSorted(DEFAULT_ALMOST_SORTED_PCT),
    Benchmark::Reverse,
    Benchmark::EightDup,
];

impl Benchmark {
    pub fn tag(&self) -> String {
        match self {
            Benchmark::Uniform => "[U]".into(),
            Benchmark::Gaussian => "[G]".into(),
            Benchmark::Bucket => "[B]".into(),
            Benchmark::GGroup(g) => format!("[{g}-G]"),
            Benchmark::Staggered => "[S]".into(),
            Benchmark::DetDup => "[DD]".into(),
            Benchmark::WorstRegular => "[WR]".into(),
            Benchmark::Zipf(t) => format!("[Z-{t}]"),
            Benchmark::Exponential => "[X]".into(),
            Benchmark::AlmostSorted(f) => format!("[AS-{f}]"),
            Benchmark::Reverse => "[R]".into(),
            Benchmark::EightDup => "[8D]".into(),
        }
    }

    /// Parse a benchmark tag (brackets optional, case insensitive).
    ///
    /// The parameterized families accept any in-range parameter, not
    /// just the table defaults: `<g>-G` for g ≥ 2 (divides-n is
    /// validated at generation time, not here), `Z-<θ·100>` for
    /// θ ∈ (0, 4], `AS-<f>` for f ∈ [0, 100].  Friendly aliases
    /// (`zipf`, `exp`, `almost-sorted`, `reverse`, `eight-dup`) map to
    /// the default parameters.
    pub fn parse(s: &str) -> Option<Benchmark> {
        let t = s.trim_matches(|c| c == '[' || c == ']').to_ascii_uppercase();
        match t.as_str() {
            "U" => Some(Benchmark::Uniform),
            "G" => Some(Benchmark::Gaussian),
            "B" => Some(Benchmark::Bucket),
            "S" => Some(Benchmark::Staggered),
            "DD" => Some(Benchmark::DetDup),
            "WR" => Some(Benchmark::WorstRegular),
            "Z" | "ZIPF" => Some(Benchmark::Zipf(DEFAULT_ZIPF_THETA100)),
            "X" | "EXP" | "EXPONENTIAL" => Some(Benchmark::Exponential),
            "AS" | "ALMOST-SORTED" => {
                Some(Benchmark::AlmostSorted(DEFAULT_ALMOST_SORTED_PCT))
            }
            "R" | "REV" | "REVERSE" => Some(Benchmark::Reverse),
            "8D" | "8-DUP" | "EIGHT-DUP" => Some(Benchmark::EightDup),
            other => {
                if let Some(g) = other.strip_suffix("-G") {
                    let g: usize = g.parse().ok()?;
                    return (g >= 2).then_some(Benchmark::GGroup(g));
                }
                if let Some(t) = other.strip_prefix("Z-") {
                    let t: u32 = t.parse().ok()?;
                    return ((1..=400).contains(&t)).then_some(Benchmark::Zipf(t));
                }
                if let Some(f) = other.strip_prefix("AS-") {
                    let f: u32 = f.parse().ok()?;
                    return (f <= 100).then_some(Benchmark::AlmostSorted(f));
                }
                None
            }
        }
    }

    /// As [`Benchmark::parse`], but an unknown tag surfaces a proper
    /// [`RuntimeError`] naming the accepted set instead of a silent
    /// `None` — the CLI's error path.
    pub fn parse_strict(s: &str) -> Result<Benchmark, RuntimeError> {
        Benchmark::parse(s).ok_or_else(|| RuntimeError::UnknownBenchmark {
            given: s.to_string(),
            valid: VALID_BENCH_TAGS,
        })
    }
}

/// Tags [`Benchmark::parse`] accepts (brackets optional, case
/// insensitive).  The `<g>-G` / `Z-<θ100>` / `AS-<pct>` entries are
/// exemplars of the parameterized forms: any g ≥ 2, θ100 ∈ [1, 400]
/// and pct ∈ [0, 100] parse.
pub const VALID_BENCH_TAGS: &[&str] = &[
    "U", "G", "B", "2-G", "4-G", "8-G", "16-G", "S", "DD", "WR", "Z", "Z-75", "X", "AS",
    "AS-10", "R", "8D",
];

/// The paper's per-processor seed: `21 + 1001·i` (§6.3).
pub fn paper_seed(pid: usize) -> u32 {
    21 + 1001 * pid as u32
}

/// Generate processor `pid`'s share (`n_local = n_total/p` keys) of the
/// benchmark.  `n_total` must be divisible by `p` (the paper's sizes are
/// powers of two and p ∈ {8..128}).
pub fn generate_for_proc(bench: Benchmark, pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let mut rng = BsdRandom::new(paper_seed(pid));
    match bench {
        Benchmark::Uniform => (0..n_local).map(|_| rng.next_i32()).collect(),
        Benchmark::Gaussian => (0..n_local)
            .map(|_| {
                let s = rng.next_i32() as i64
                    + rng.next_i32() as i64
                    + rng.next_i32() as i64
                    + rng.next_i32() as i64;
                (s / 4) as i32
            })
            .collect(),
        Benchmark::Bucket => {
            // p buckets of n_local/p keys; bucket i uniform in
            // [i·INT_MAX/p, (i+1)·INT_MAX/p).
            let per_bucket = n_local / p;
            let width = INT_MAX_P1 / p as i64;
            let mut out = Vec::with_capacity(n_local);
            for i in 0..p {
                let base = i as i64 * width;
                let cnt = if i == p - 1 {
                    n_local - per_bucket * (p - 1)
                } else {
                    per_bucket
                };
                for _ in 0..cnt {
                    out.push((base + uniform_below(&mut rng, width)) as i32);
                }
            }
            out
        }
        Benchmark::GGroup(g) => {
            // Processors form p/g groups of g; within group j, bucket i of
            // each processor is uniform in the window
            // ((jg + p/2 + i) mod p) · INT_MAX/p.
            let g = g.max(1).min(p);
            let j = pid / g;
            let per_bucket = n_local / g;
            let width = INT_MAX_P1 / p as i64;
            let mut out = Vec::with_capacity(n_local);
            for i in 0..g {
                let window = (j * g + p / 2 + i) % p;
                let base = window as i64 * width;
                let cnt = if i == g - 1 {
                    n_local - per_bucket * (g - 1)
                } else {
                    per_bucket
                };
                for _ in 0..cnt {
                    out.push((base + uniform_below(&mut rng, width)) as i32);
                }
            }
            out
        }
        Benchmark::Staggered => {
            let width = INT_MAX_P1 / p as i64;
            let window = if pid < p / 2 { 2 * pid + 1 } else { pid - p / 2 };
            let base = window as i64 * width;
            (0..n_local)
                .map(|_| (base + uniform_below(&mut rng, width)) as i32)
                .collect()
        }
        Benchmark::DetDup => det_dup(pid, p, n_local),
        Benchmark::WorstRegular => worst_regular(pid, p, n_local),
        Benchmark::Zipf(t) => zipf(&mut rng, t, n_local),
        Benchmark::Exponential => exponential(&mut rng, n_local),
        Benchmark::AlmostSorted(f) => almost_sorted(&mut rng, f, pid, p, n_local),
        Benchmark::Reverse => reverse_sorted(pid, p, n_local),
        Benchmark::EightDup => eight_dup(pid, p, n_local),
    }
}

/// Generate the whole input (all processors), mostly for tests/examples.
pub fn generate_all(bench: Benchmark, p: usize, n_total: usize) -> Vec<Vec<i32>> {
    let n_local = n_total / p;
    (0..p).map(|pid| generate_for_proc(bench, pid, p, n_local)).collect()
}

/// A key domain the benchmark generators can target: maps one 31-bit
/// draw of the paper's distributions (always non-negative) into the
/// domain.  The draw carries the distribution's *shape*; `aux` supplies
/// extra entropy for the domain's remaining bits and must only break
/// ties (`from_draw(a, _) < from_draw(b, _)` whenever `a < b`), so every
/// distribution property of §6.3 survives the mapping.
pub trait GenKey: Key {
    fn from_draw(draw: i32, aux: u64) -> Self;
}

impl GenKey for i32 {
    fn from_draw(draw: i32, _aux: u64) -> i32 {
        draw
    }
}

impl GenKey for u64 {
    /// The draw fills the top 31 bits (below the sign), `aux` the low 33
    /// — genuinely 64-bit keys with the draw's distribution shape.
    fn from_draw(draw: i32, aux: u64) -> u64 {
        ((draw.max(0) as u64) << 33) | (aux & ((1u64 << 33) - 1))
    }
}

impl GenKey for F64 {
    /// Integer part = the draw (exact in an f64), fraction from `aux`.
    /// The fraction lives in [0, 0.5) so `draw + fraction` can never
    /// round up into the next integer (for draws near 2³¹ the f64 ulp is
    /// ~2⁻²², and a fraction arbitrarily close to 1.0 would carry) —
    /// keeping the strict `from_draw(a, _) < from_draw(b, _)` law for
    /// `a < b` and `floor() == draw` exactly.
    fn from_draw(draw: i32, aux: u64) -> F64 {
        F64(draw as f64 + (aux >> 11) as f64 / (1u64 << 54) as f64)
    }
}

impl GenKey for Record {
    /// The draw is the record key; `aux` becomes satellite payload.
    fn from_draw(draw: i32, aux: u64) -> Record {
        Record { key: draw.max(0) as u32, payload: aux as u32 }
    }
}

impl GenKey for Str {
    /// Seven base-26 uppercase characters encode the draw (26⁷ > 2³¹,
    /// most significant first, so the mapping is strictly monotone in
    /// the draw regardless of what follows), then an aux-derived
    /// six-character lowercase suffix makes the strings variable-beyond-
    /// prefix: equal draws share the full 7-byte head, so their 8-byte
    /// radix image may collide while the keys differ — exactly the tie
    /// case the prefix encoding must break.  `aux = 0` (the
    /// duplicate-defined benchmarks) appends nothing, so equal draws
    /// stay *equal* strings.
    fn from_draw(draw: i32, aux: u64) -> Str {
        let mut b = [0u8; Str::MAX_LEN];
        let mut v = draw.max(0) as u64;
        for slot in (0..7).rev() {
            b[slot] = b'A' + (v % 26) as u8;
            v /= 26;
        }
        if aux != 0 {
            for (k, slot) in (7..13).enumerate() {
                b[slot] = b'a' + ((aux >> (10 * k as u32)) % 26) as u8;
            }
        }
        Str(b)
    }
}

/// Typed variant of [`generate_for_proc`]: the same §6.3 distributions,
/// mapped into key domain `K` (deterministic per `(bench, pid)` like the
/// `i32` generators — the aux stream is seeded from the paper seed).
///
/// For duplicate-defined benchmarks (\[DD\], [Z-θ] and \[8D\], whose
/// *point* is massive key equality) the aux bits are zeroed: entropy in
/// the domain's low bits would turn every equal draw into a distinct
/// key and silently destroy the property §5.1.1 is stressed by.
pub fn generate_typed_for_proc<K: GenKey>(
    bench: Benchmark,
    pid: usize,
    p: usize,
    n_local: usize,
) -> Vec<K> {
    let mut aux = SplitMix64::new(0x6B65_7973 ^ ((paper_seed(pid) as u64) << 17));
    let dup_defined =
        matches!(bench, Benchmark::DetDup | Benchmark::Zipf(_) | Benchmark::EightDup);
    generate_for_proc(bench, pid, p, n_local)
        .into_iter()
        .map(|draw| K::from_draw(draw, if dup_defined { 0 } else { aux.next_u64() }))
        .collect()
}

/// Heavy-duplicate workload in domain `K`: draws collapse onto at most
/// `distinct` values *before* mapping and the aux bits are zeroed, so
/// equal draws become **equal keys** — maximal pressure on the §5.1.1
/// transparent duplicate handling in any domain (for [`Record`] this
/// means fully equal records, key and payload).
pub fn generate_heavy_dup_for_proc<K: GenKey>(
    bench: Benchmark,
    pid: usize,
    p: usize,
    n_local: usize,
    distinct: usize,
) -> Vec<K> {
    let m = distinct.max(1).min(i32::MAX as usize) as i32;
    generate_for_proc(bench, pid, p, n_local)
        .into_iter()
        .map(|draw| K::from_draw(draw.rem_euclid(m), 0))
        .collect()
}

fn uniform_below(rng: &mut BsdRandom, bound: i64) -> i64 {
    debug_assert!(bound > 0 && bound <= i32::MAX as i64 + 1);
    if bound > i32::MAX as i64 {
        rng.next_i32() as i64
    } else {
        rng.below(bound as i32) as i64
    }
}

/// \[DD\] Deterministic duplicates (§6.3 item 6): the keys of the first
/// p/2 processors are all `lg n`, of the next p/4 `lg(n/p)`, and so on;
/// the last processor repeats the halving pattern *within* its own keys.
fn det_dup(pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let n_total = (n_local * p) as i64;
    let lg = |x: i64| -> i32 {
        if x <= 1 {
            0
        } else {
            (63 - (x as u64).leading_zeros() as i64) as i32
        }
    };
    if pid < p - 1 || p == 1 {
        // Find the group: processors [p - p/2^(i-1), ...) style halving —
        // equivalently the largest i >= 1 with pid < p - p/2^i gives the
        // later groups; simplest is a forward scan of the halving blocks.
        let mut start = 0usize;
        let mut block = p / 2;
        let mut i = 1usize;
        let value = loop {
            if block == 0 || pid < start + block.max(1) {
                // value for group i: lg(n / p^{i-1}); clamp the power.
                let mut denom: i64 = 1;
                for _ in 0..i.saturating_sub(1) {
                    denom = denom.saturating_mul(p as i64);
                }
                break lg(n_total / denom.max(1));
            }
            start += block;
            block /= 2;
            i += 1;
        };
        if p == 1 {
            // single processor: fall through to the intra-proc pattern
            return intra_dd(n_local, n_total, p);
        }
        vec![value; n_local]
    } else {
        intra_dd(n_local, n_total, p)
    }
}

/// The last processor's \[DD\] share: n/(p·2^i) keys of value
/// `lg(n/(p·2^{i-1}))`, halving until exhausted.
fn intra_dd(n_local: usize, n_total: i64, p: usize) -> Vec<i32> {
    let lg = |x: i64| -> i32 {
        if x <= 1 {
            0
        } else {
            (63 - (x as u64).leading_zeros() as i64) as i32
        }
    };
    let mut out = Vec::with_capacity(n_local);
    let mut chunk = n_local / 2;
    let mut denom: i64 = p as i64;
    while out.len() < n_local {
        let value = lg(n_total / denom.max(1));
        let take = chunk.max(1).min(n_local - out.len());
        out.extend(std::iter::repeat(value).take(take));
        chunk /= 2;
        denom = denom.saturating_mul(2);
    }
    out
}

/// \[WR\] Worst-case for regular sampling, following [39]'s construction:
/// the globally sorted sequence is dealt to processors cyclically, so
/// every processor's regular sample is (nearly) the same and the induced
/// buckets are maximally imbalanced for plain regular sampling (s = p).
fn worst_regular(pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let scale = INT_MAX_P1 / (n_local as i64 * p as i64).max(1);
    (0..n_local)
        .map(|j| ((j as i64 * p as i64 + pid as i64) * scale.max(1)) as i32)
        .collect()
}

/// Process-wide cache of Zipf CDFs keyed by θ·100.  A sweep touches a
/// handful of θ values but calls [`zipf`] once per processor per rep, so
/// without the cache a p = 128, 5-rep cell rebuilds the 1024-rank `powf`
/// table 640 times.  The cached table is built with the *identical*
/// accumulation order as before, so draws stay bit-identical.
fn zipf_cdf(theta100: u32) -> std::sync::Arc<Vec<f64>> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<u32, Arc<Vec<f64>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = cache.lock().expect("zipf CDF cache poisoned");
    Arc::clone(map.entry(theta100).or_insert_with(|| {
        let theta = theta100 as f64 / 100.0;
        let mut cdf = Vec::with_capacity(ZIPF_RANKS);
        let mut acc = 0.0f64;
        for k in 1..=ZIPF_RANKS {
            acc += (k as f64).powf(-theta);
            cdf.push(acc);
        }
        Arc::new(cdf)
    }))
}

/// [Z-θ] Zipf over [`ZIPF_RANKS`] ranks: rank k ∈ {1..R} is drawn with
/// probability ∝ 1/k^θ (inverse-CDF over the cumulative weights) and
/// maps to key `(k−1)·INT_MAX/R` — the head rank is a massively
/// duplicated *smallest* key, so sampled splitters see a few huge
/// equivalence classes instead of a smooth value range.
fn zipf(rng: &mut BsdRandom, theta100: u32, n_local: usize) -> Vec<i32> {
    let cdf = zipf_cdf(theta100);
    let total = *cdf.last().expect("ZIPF_RANKS > 0");
    let scale = INT_MAX_P1 / ZIPF_RANKS as i64;
    (0..n_local)
        .map(|_| {
            let u = rng.next_i32() as f64 / INT_MAX_P1 as f64 * total;
            let rank = cdf.partition_point(|&c| c <= u);
            (rank.min(ZIPF_RANKS - 1) as i64 * scale) as i32
        })
        .collect()
}

/// \[X\] Exponential: `−ln(u)·INT_MAX/16`, clipped to the 31-bit range —
/// ~86 % of the mass below INT_MAX/8 and a long sparse upper tail, the
/// opposite pressure of \[G\]'s central bulge.
fn exponential(rng: &mut BsdRandom, n_local: usize) -> Vec<i32> {
    let scale = (INT_MAX_P1 / 16) as f64;
    (0..n_local)
        .map(|_| {
            let u = (rng.next_i32() as f64 + 1.0) / INT_MAX_P1 as f64; // (0, 1]
            let v = (-u.ln() * scale) as i64;
            v.min(INT_MAX_P1 - 1) as i32
        })
        .collect()
}

/// [AS-f] Almost sorted: the globally sorted sequence dealt to
/// processors in contiguous blocks (processor 0 gets the smallest keys,
/// so the untouched input is globally sorted), then `f` % of each
/// processor's keys displaced by random transpositions — each swap
/// moves two keys, so `f·n_local/100 / 2` swaps of distinct positions.
fn almost_sorted(rng: &mut BsdRandom, pct: u32, pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let n_total = (n_local * p) as i64;
    let scale = (INT_MAX_P1 / n_total.max(1)).max(1);
    let mut out: Vec<i32> =
        (0..n_local).map(|j| (((pid * n_local + j) as i64) * scale) as i32).collect();
    if n_local > 1 {
        let swaps = n_local * pct.min(100) as usize / 200;
        for _ in 0..swaps {
            let i = rng.below(n_local as i32) as usize;
            let j = (i + 1 + rng.below(n_local as i32 - 1) as usize) % n_local;
            out.swap(i, j);
        }
    }
    out
}

/// \[R\] Reverse: the globally sorted sequence in descending order, dealt
/// in contiguous blocks — processor 0 holds the largest keys, every
/// local run is strictly descending.
fn reverse_sorted(pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let n_total = (n_local * p) as i64;
    let scale = (INT_MAX_P1 / n_total.max(1)).max(1);
    (0..n_local)
        .map(|j| ((n_total - 1 - (pid * n_local + j) as i64) * scale) as i32)
        .collect()
}

/// \[8D\] Eight-dup (the bachelorthesis benchmark): global index i maps
/// to `(i⁸ + n/2) mod n`.  For power-of-two n most eighth-power residues
/// collapse, leaving a few hundred distinct values with wildly unequal
/// multiplicities — duplication that, unlike \[DD\], is not block-aligned
/// with processors.
fn eight_dup(pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let n_total = ((n_local * p) as u64).max(1);
    (0..n_local)
        .map(|j| {
            let x = (pid * n_local + j) as u64 % n_total;
            let sq = |v: u64| v * v % n_total;
            let v8 = sq(sq(sq(x)));
            ((v8 + n_total / 2) % n_total) as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: usize = 8;
    const N_LOCAL: usize = 1 << 10;

    #[test]
    fn all_benchmarks_produce_requested_sizes() {
        for b in ALL_BENCHMARKS {
            for pid in 0..P {
                let keys = generate_for_proc(b, pid, P, N_LOCAL);
                assert_eq!(keys.len(), N_LOCAL, "{} pid={pid}", b.tag());
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for b in ALL_BENCHMARKS {
            let a = generate_for_proc(b, 3, P, N_LOCAL);
            let c = generate_for_proc(b, 3, P, N_LOCAL);
            assert_eq!(a, c, "{}", b.tag());
        }
    }

    #[test]
    fn uniform_keys_are_nonnegative_31bit() {
        let keys = generate_for_proc(Benchmark::Uniform, 0, P, N_LOCAL);
        assert!(keys.iter().all(|&k| k >= 0));
        // And they vary.
        assert!(keys.iter().collect::<std::collections::HashSet<_>>().len() > N_LOCAL / 2);
    }

    #[test]
    fn gaussian_concentrates_toward_center() {
        let keys = generate_for_proc(Benchmark::Gaussian, 0, P, 1 << 14);
        let center = (INT_MAX_P1 / 2) as i32;
        let near = keys
            .iter()
            .filter(|&&k| (k as i64 - center as i64).abs() < INT_MAX_P1 / 4)
            .count();
        // Mean-of-4 keeps ~95% within ±INT_MAX/4 of the center.
        assert!(near as f64 > 0.9 * keys.len() as f64, "near={near}");
    }

    #[test]
    fn bucket_keys_live_in_their_windows() {
        let keys = generate_for_proc(Benchmark::Bucket, 2, P, N_LOCAL);
        let width = INT_MAX_P1 / P as i64;
        let per = N_LOCAL / P;
        for (i, chunk) in keys.chunks(per).take(P).enumerate() {
            for &k in chunk {
                let lo = i as i64 * width;
                assert!(
                    (lo..lo + width).contains(&(k as i64)),
                    "bucket {i} key {k} outside [{lo}, {})",
                    lo + width
                );
            }
        }
    }

    #[test]
    fn staggered_windows_cover_distinct_ranges() {
        let width = INT_MAX_P1 / P as i64;
        for pid in 0..P {
            let keys = generate_for_proc(Benchmark::Staggered, pid, P, 128);
            let window = if pid < P / 2 { 2 * pid + 1 } else { pid - P / 2 };
            let lo = window as i64 * width;
            assert!(keys.iter().all(|&k| (lo..lo + width).contains(&(k as i64))), "pid={pid}");
        }
    }

    #[test]
    fn det_dup_is_massively_duplicated() {
        let mut all: Vec<i32> = Vec::new();
        for pid in 0..P {
            all.extend(generate_for_proc(Benchmark::DetDup, pid, P, N_LOCAL));
        }
        let distinct: std::collections::HashSet<_> = all.iter().collect();
        assert!(distinct.len() <= 64, "distinct={}", distinct.len());
    }

    #[test]
    fn worst_regular_is_cyclic_sorted_deal() {
        let a = generate_for_proc(Benchmark::WorstRegular, 0, P, 64);
        let b = generate_for_proc(Benchmark::WorstRegular, 1, P, 64);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "per-proc runs sorted");
        assert!(a[0] < b[0] && b[0] < a[1], "interleaving holds");
    }

    #[test]
    fn ggroup_windows_wrap_mod_p() {
        let keys = generate_for_proc(Benchmark::GGroup(2), 0, P, 128);
        let width = INT_MAX_P1 / P as i64;
        // group j=0, buckets i=0,1 -> windows (p/2), (p/2+1) = 4,5.
        let lo = 4 * width;
        assert!(keys[..64].iter().all(|&k| (lo..lo + width).contains(&(k as i64))));
        let lo2 = 5 * width;
        assert!(keys[64..].iter().all(|&k| (lo2..lo2 + width).contains(&(k as i64))));
    }

    #[test]
    fn parse_tags_roundtrip() {
        for b in ALL_BENCHMARKS {
            assert_eq!(Benchmark::parse(&b.tag()), Some(b), "{}", b.tag());
        }
    }

    #[test]
    fn parse_strict_accepts_every_valid_tag() {
        for b in ALL_BENCHMARKS {
            assert_eq!(Benchmark::parse_strict(&b.tag()).unwrap(), b, "{}", b.tag());
        }
        for tag in VALID_BENCH_TAGS {
            assert!(Benchmark::parse_strict(tag).is_ok(), "{tag}");
        }
    }

    #[test]
    fn parse_strict_unknown_tag_lists_valid_tags() {
        // Regression: the CLI used to surface a silent `None` for
        // unknown tags; the error must now name the tag and the set.
        let err = Benchmark::parse_strict("XYZ").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("XYZ"), "{msg}");
        for tag in ["U", "2-G", "DD", "WR"] {
            assert!(msg.contains(tag), "missing {tag} in: {msg}");
        }
        assert!(Benchmark::parse_strict("").is_err());
    }

    #[test]
    fn parse_any_g_group_with_range_check() {
        // Regression: parse used to hardcode 2-G/4-G/8-G only.
        assert_eq!(Benchmark::parse("16-G"), Some(Benchmark::GGroup(16)));
        assert_eq!(Benchmark::parse("[32-g]"), Some(Benchmark::GGroup(32)));
        assert_eq!(Benchmark::parse_strict("16-G").unwrap(), Benchmark::GGroup(16));
        assert_eq!(Benchmark::GGroup(16).tag(), "[16-G]");
        // g < 2 and non-numeric prefixes are rejected…
        for bad in ["1-G", "0-G", "-G", "X-G", "2.5-G"] {
            assert_eq!(Benchmark::parse(bad), None, "{bad}");
        }
        // …and the strict path's error names the accepted forms.
        let msg = Benchmark::parse_strict("1-G").unwrap_err().to_string();
        assert!(msg.contains("1-G") && msg.contains("16-G"), "{msg}");
    }

    #[test]
    fn parse_skew_tags_and_aliases() {
        assert_eq!(Benchmark::parse("zipf"), Some(Benchmark::Zipf(DEFAULT_ZIPF_THETA100)));
        assert_eq!(Benchmark::parse("Z-75"), Some(Benchmark::Zipf(75)));
        assert_eq!(Benchmark::parse("exp"), Some(Benchmark::Exponential));
        assert_eq!(
            Benchmark::parse("almost-sorted"),
            Some(Benchmark::AlmostSorted(DEFAULT_ALMOST_SORTED_PCT))
        );
        assert_eq!(Benchmark::parse("AS-10"), Some(Benchmark::AlmostSorted(10)));
        assert_eq!(Benchmark::parse("reverse"), Some(Benchmark::Reverse));
        assert_eq!(Benchmark::parse("8d"), Some(Benchmark::EightDup));
        assert_eq!(Benchmark::parse("eight-dup"), Some(Benchmark::EightDup));
        // Out-of-range parameters are rejected.
        assert_eq!(Benchmark::parse("Z-0"), None);
        assert_eq!(Benchmark::parse("Z-401"), None);
        assert_eq!(Benchmark::parse("AS-101"), None);
    }

    #[test]
    fn zipf_concentrates_on_the_head_rank() {
        use std::collections::HashMap;
        let keys = generate_for_proc(Benchmark::Zipf(100), 0, P, 1 << 14);
        let mut freq: HashMap<i32, usize> = HashMap::new();
        for &k in &keys {
            *freq.entry(k).or_default() += 1;
        }
        let (&top_key, &top) = freq.iter().max_by_key(|e| *e.1).unwrap();
        // θ = 1 over 1024 ranks puts ~13 % of the mass on rank 1.
        assert!(top as f64 > 0.08 * keys.len() as f64, "top={top}");
        assert_eq!(top_key, 0, "the head rank maps to the smallest key");
        assert!(freq.len() <= ZIPF_RANKS);
    }

    #[test]
    fn zipf_cache_is_bit_identical_to_the_uncached_generator() {
        // Reference: the pre-cache generator body, rebuilding the CDF
        // inline.  Any change to the cached accumulation order (e.g.
        // summing in reverse or normalising) would break bit-identity
        // with historical streams; this pins it.
        fn zipf_reference(rng: &mut BsdRandom, theta100: u32, n_local: usize) -> Vec<i32> {
            let theta = theta100 as f64 / 100.0;
            let mut cdf = Vec::with_capacity(ZIPF_RANKS);
            let mut acc = 0.0f64;
            for k in 1..=ZIPF_RANKS {
                acc += (k as f64).powf(-theta);
                cdf.push(acc);
            }
            let total = acc;
            let scale = INT_MAX_P1 / ZIPF_RANKS as i64;
            (0..n_local)
                .map(|_| {
                    let u = rng.next_i32() as f64 / INT_MAX_P1 as f64 * total;
                    let rank = cdf.partition_point(|&c| c <= u);
                    (rank.min(ZIPF_RANKS - 1) as i64 * scale) as i32
                })
                .collect()
        }
        for theta100 in [25, 75, 100, 150, 300] {
            for pid in [0, 3, P - 1] {
                let mut rng = BsdRandom::new(paper_seed(pid));
                let expect = zipf_reference(&mut rng, theta100, N_LOCAL);
                let got = generate_for_proc(Benchmark::Zipf(theta100), pid, P, N_LOCAL);
                assert_eq!(got, expect, "θ·100={theta100} pid={pid}");
                // Second call hits the cache; streams must still agree.
                let again = generate_for_proc(Benchmark::Zipf(theta100), pid, P, N_LOCAL);
                assert_eq!(again, expect, "cached θ·100={theta100} pid={pid}");
            }
        }
    }

    #[test]
    fn exponential_mass_sits_in_the_low_range() {
        let keys = generate_for_proc(Benchmark::Exponential, 0, P, 1 << 14);
        assert!(keys.iter().all(|&k| k >= 0));
        let low = keys.iter().filter(|&&k| (k as i64) < INT_MAX_P1 / 8).count();
        // P(X < 2·mean) = 1 − e⁻² ≈ 0.86 with scale = INT_MAX/16.
        assert!(low as f64 > 0.75 * keys.len() as f64, "low={low}");
    }

    #[test]
    fn almost_sorted_is_mostly_sorted() {
        let n_local = 1 << 12;
        let descents = |keys: &[i32]| keys.windows(2).filter(|w| w[0] > w[1]).count();
        // f = 0 is exactly the sorted block deal…
        let clean = generate_for_proc(Benchmark::AlmostSorted(0), 1, P, n_local);
        assert_eq!(descents(&clean), 0);
        // …whose pid blocks tile the global order.
        let next = generate_for_proc(Benchmark::AlmostSorted(0), 2, P, n_local);
        assert!(clean[n_local - 1] < next[0], "blocks are globally ordered");
        // f = 50 perturbs, but each transposition breaks at most 4
        // adjacencies, so the stream stays mostly sorted.
        let noisy = generate_for_proc(Benchmark::AlmostSorted(50), 1, P, n_local);
        let swaps = n_local * 50 / 200;
        let d = descents(&noisy);
        assert!(d >= 1, "perturbation must actually perturb");
        assert!(d <= 4 * swaps, "descents={d}");
        // The multiset is the untouched deal.
        let mut resorted = noisy.clone();
        resorted.sort_unstable();
        assert_eq!(resorted, clean);
    }

    #[test]
    fn reverse_is_globally_descending() {
        let a = generate_for_proc(Benchmark::Reverse, 0, P, 64);
        let b = generate_for_proc(Benchmark::Reverse, 1, P, 64);
        assert!(a.windows(2).all(|w| w[0] > w[1]), "per-proc strictly descending");
        assert!(a[63] > b[0], "processor blocks descend too");
        assert!(a.iter().all(|&k| k >= 0));
    }

    #[test]
    fn eight_dup_is_duplicate_heavy() {
        use std::collections::HashSet;
        let mut all: Vec<i32> = Vec::new();
        for pid in 0..P {
            all.extend(generate_for_proc(Benchmark::EightDup, pid, P, N_LOCAL));
        }
        let n_total = P * N_LOCAL;
        let distinct: HashSet<_> = all.iter().collect();
        assert!(distinct.len() < n_total / 4, "distinct={}", distinct.len());
        assert!(all.iter().all(|&k| k >= 0 && (k as usize) < n_total));
    }

    #[test]
    fn typed_skew_benchmarks_keep_their_duplicates() {
        // [Z-θ] and [8D] are duplicate-defined like [DD]: aux entropy
        // must not split their equal draws into distinct wide keys.
        use std::collections::HashSet;
        for bench in [Benchmark::Zipf(100), Benchmark::EightDup] {
            let draws: HashSet<i32> =
                generate_for_proc(bench, 0, P, N_LOCAL).into_iter().collect();
            let typed: HashSet<u64> =
                generate_typed_for_proc::<u64>(bench, 0, P, N_LOCAL).into_iter().collect();
            assert_eq!(
                typed.len(),
                draws.len(),
                "{}: aux entropy split the duplicates",
                bench.tag()
            );
        }
    }

    #[test]
    fn str_mapping_is_monotone_and_dup_preserving() {
        use crate::key::Str;
        let draws = generate_for_proc(Benchmark::Staggered, 1, P, 256);
        let typed: Vec<Str> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 256);
        for (i, a) in draws.iter().enumerate() {
            for (j, b) in draws.iter().enumerate() {
                if a < b {
                    assert!(typed[i] < typed[j], "draw order must survive the Str mapping");
                }
            }
        }
        // Duplicate-defined benchmarks map equal draws to equal strings.
        let dd: Vec<Str> = generate_typed_for_proc(Benchmark::DetDup, 0, P, 256);
        let distinct: std::collections::HashSet<_> = dd.iter().collect();
        let dd_draws: std::collections::HashSet<_> =
            generate_for_proc(Benchmark::DetDup, 0, P, 256).into_iter().collect();
        assert_eq!(distinct.len(), dd_draws.len());
    }

    #[test]
    fn typed_generation_is_deterministic_and_shaped() {
        use crate::key::{F64, Record};
        for b in ALL_BENCHMARKS {
            let a: Vec<u64> = generate_typed_for_proc(b, 3, P, 256);
            let c: Vec<u64> = generate_typed_for_proc(b, 3, P, 256);
            assert_eq!(a, c, "{}", b.tag());
            assert_eq!(a.len(), 256);
        }
        // The draw rides the top bits: recovering it reproduces the i32
        // stream, so every §6.3 distribution property carries over.
        let draws = generate_for_proc(Benchmark::Staggered, 1, P, 128);
        let typed: Vec<u64> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 128);
        let recovered: Vec<i32> = typed.iter().map(|&k| (k >> 33) as i32).collect();
        assert_eq!(recovered, draws);
        // Records keep the draw as the key field.
        let recs: Vec<Record> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 128);
        assert!(recs.iter().zip(&draws).all(|(r, &d)| r.key == d as u32));
        // f64 keys keep the draw as the integer part.
        let floats: Vec<F64> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 128);
        assert!(floats.iter().zip(&draws).all(|(f, &d)| f.0.floor() == d as f64));
    }

    #[test]
    fn typed_dd_benchmark_keeps_its_duplicates() {
        // Regression: aux entropy must not break [DD]'s defining key
        // equality in wider domains — equal draws stay equal keys.
        use std::collections::HashSet;
        let mut all_u: Vec<u64> = Vec::new();
        let mut all_r: Vec<crate::key::Record> = Vec::new();
        for pid in 0..P {
            all_u.extend(generate_typed_for_proc::<u64>(Benchmark::DetDup, pid, P, N_LOCAL));
            all_r.extend(generate_typed_for_proc::<crate::key::Record>(
                Benchmark::DetDup,
                pid,
                P,
                N_LOCAL,
            ));
        }
        assert!(all_u.iter().collect::<HashSet<_>>().len() <= 64);
        assert!(all_r.iter().collect::<HashSet<_>>().len() <= 64);
    }

    #[test]
    fn heavy_dup_collapses_to_few_distinct_keys() {
        use std::collections::HashSet;
        let mut all: Vec<u64> = Vec::new();
        for pid in 0..P {
            all.extend(generate_heavy_dup_for_proc::<u64>(
                Benchmark::Uniform,
                pid,
                P,
                N_LOCAL,
                5,
            ));
        }
        let distinct: HashSet<_> = all.iter().collect();
        assert!(distinct.len() <= 5, "distinct={}", distinct.len());
        // Equal draws become *equal records* (payload zeroed too).
        let recs = generate_heavy_dup_for_proc::<crate::key::Record>(
            Benchmark::Uniform,
            0,
            P,
            N_LOCAL,
            3,
        );
        let distinct_recs: HashSet<_> = recs.iter().collect();
        assert!(distinct_recs.len() <= 3);
        assert!(recs.iter().all(|r| r.payload == 0));
    }
}
