//! Sorting benchmark input generators (paper §6.3).
//!
//! Seven distributions, faithful to the paper's definitions, each
//! generated *per processor* with the paper's seeding (`21 + 1001·i` for
//! processor `i`, glibc `random()`):
//!
//! | tag    | name                      |
//! |--------|---------------------------|
//! | \[U\]    | Uniform                   |
//! | \[G\]    | Gaussian (4-call average) |
//! | \[B\]    | Bucket sorted             |
//! | [g-G]  | g-Group (g = 2 default)   |
//! | \[S\]    | Staggered                 |
//! | \[DD\]   | Deterministic duplicates  |
//! | \[WR\]   | Worst-case regular [39]   |
//!
//! `INT_MAX` below is the paper's "maximum integer value plus one ... in
//! a 32-bit signed arithmetic data type", i.e. 2³¹.

use crate::key::{F64, Key, Record};
use crate::runtime::error::RuntimeError;
use crate::util::rng::{BsdRandom, SplitMix64};

/// `INT_MAX` of the paper: 2³¹ (as i64 to avoid overflow in range math).
pub const INT_MAX_P1: i64 = 1 << 31;

/// The seven benchmark distributions of §6.3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Benchmark {
    /// \[U\] uniform over [0, 2³¹−1].
    Uniform,
    /// \[G\] Gaussian approximation: mean of four `random()` calls.
    Gaussian,
    /// \[B\] bucket sorted: p per-proc buckets of n/p² uniform keys each.
    Bucket,
    /// [g-G] g-group with this g (paper tables use 2-G).
    GGroup(usize),
    /// \[S\] staggered.
    Staggered,
    /// \[DD\] deterministic duplicates.
    DetDup,
    /// \[WR\] worst-case-regular (the [39] adversary for regular sampling).
    WorstRegular,
}

/// The table order used throughout the paper: U, G, 2-G, B, S, DD, WR.
pub const ALL_BENCHMARKS: [Benchmark; 7] = [
    Benchmark::Uniform,
    Benchmark::Gaussian,
    Benchmark::GGroup(2),
    Benchmark::Bucket,
    Benchmark::Staggered,
    Benchmark::DetDup,
    Benchmark::WorstRegular,
];

impl Benchmark {
    pub fn tag(&self) -> String {
        match self {
            Benchmark::Uniform => "[U]".into(),
            Benchmark::Gaussian => "[G]".into(),
            Benchmark::Bucket => "[B]".into(),
            Benchmark::GGroup(g) => format!("[{g}-G]"),
            Benchmark::Staggered => "[S]".into(),
            Benchmark::DetDup => "[DD]".into(),
            Benchmark::WorstRegular => "[WR]".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Benchmark> {
        match s.trim_matches(|c| c == '[' || c == ']').to_ascii_uppercase().as_str() {
            "U" => Some(Benchmark::Uniform),
            "G" => Some(Benchmark::Gaussian),
            "B" => Some(Benchmark::Bucket),
            "2-G" => Some(Benchmark::GGroup(2)),
            "4-G" => Some(Benchmark::GGroup(4)),
            "8-G" => Some(Benchmark::GGroup(8)),
            "S" => Some(Benchmark::Staggered),
            "DD" => Some(Benchmark::DetDup),
            "WR" => Some(Benchmark::WorstRegular),
            _ => None,
        }
    }

    /// As [`Benchmark::parse`], but an unknown tag surfaces a proper
    /// [`RuntimeError`] naming the accepted set instead of a silent
    /// `None` — the CLI's error path.
    pub fn parse_strict(s: &str) -> Result<Benchmark, RuntimeError> {
        Benchmark::parse(s).ok_or_else(|| RuntimeError::UnknownBenchmark {
            given: s.to_string(),
            valid: VALID_BENCH_TAGS,
        })
    }
}

/// Every tag [`Benchmark::parse`] accepts (brackets optional, case
/// insensitive).
pub const VALID_BENCH_TAGS: &[&str] = &["U", "G", "B", "2-G", "4-G", "8-G", "S", "DD", "WR"];

/// The paper's per-processor seed: `21 + 1001·i` (§6.3).
pub fn paper_seed(pid: usize) -> u32 {
    21 + 1001 * pid as u32
}

/// Generate processor `pid`'s share (`n_local = n_total/p` keys) of the
/// benchmark.  `n_total` must be divisible by `p` (the paper's sizes are
/// powers of two and p ∈ {8..128}).
pub fn generate_for_proc(bench: Benchmark, pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let mut rng = BsdRandom::new(paper_seed(pid));
    match bench {
        Benchmark::Uniform => (0..n_local).map(|_| rng.next_i32()).collect(),
        Benchmark::Gaussian => (0..n_local)
            .map(|_| {
                let s = rng.next_i32() as i64
                    + rng.next_i32() as i64
                    + rng.next_i32() as i64
                    + rng.next_i32() as i64;
                (s / 4) as i32
            })
            .collect(),
        Benchmark::Bucket => {
            // p buckets of n_local/p keys; bucket i uniform in
            // [i·INT_MAX/p, (i+1)·INT_MAX/p).
            let per_bucket = n_local / p;
            let width = INT_MAX_P1 / p as i64;
            let mut out = Vec::with_capacity(n_local);
            for i in 0..p {
                let base = i as i64 * width;
                let cnt = if i == p - 1 {
                    n_local - per_bucket * (p - 1)
                } else {
                    per_bucket
                };
                for _ in 0..cnt {
                    out.push((base + uniform_below(&mut rng, width)) as i32);
                }
            }
            out
        }
        Benchmark::GGroup(g) => {
            // Processors form p/g groups of g; within group j, bucket i of
            // each processor is uniform in the window
            // ((jg + p/2 + i) mod p) · INT_MAX/p.
            let g = g.max(1).min(p);
            let j = pid / g;
            let per_bucket = n_local / g;
            let width = INT_MAX_P1 / p as i64;
            let mut out = Vec::with_capacity(n_local);
            for i in 0..g {
                let window = (j * g + p / 2 + i) % p;
                let base = window as i64 * width;
                let cnt = if i == g - 1 {
                    n_local - per_bucket * (g - 1)
                } else {
                    per_bucket
                };
                for _ in 0..cnt {
                    out.push((base + uniform_below(&mut rng, width)) as i32);
                }
            }
            out
        }
        Benchmark::Staggered => {
            let width = INT_MAX_P1 / p as i64;
            let window = if pid < p / 2 { 2 * pid + 1 } else { pid - p / 2 };
            let base = window as i64 * width;
            (0..n_local)
                .map(|_| (base + uniform_below(&mut rng, width)) as i32)
                .collect()
        }
        Benchmark::DetDup => det_dup(pid, p, n_local),
        Benchmark::WorstRegular => worst_regular(pid, p, n_local),
    }
}

/// Generate the whole input (all processors), mostly for tests/examples.
pub fn generate_all(bench: Benchmark, p: usize, n_total: usize) -> Vec<Vec<i32>> {
    let n_local = n_total / p;
    (0..p).map(|pid| generate_for_proc(bench, pid, p, n_local)).collect()
}

/// A key domain the benchmark generators can target: maps one 31-bit
/// draw of the paper's distributions (always non-negative) into the
/// domain.  The draw carries the distribution's *shape*; `aux` supplies
/// extra entropy for the domain's remaining bits and must only break
/// ties (`from_draw(a, _) < from_draw(b, _)` whenever `a < b`), so every
/// distribution property of §6.3 survives the mapping.
pub trait GenKey: Key {
    fn from_draw(draw: i32, aux: u64) -> Self;
}

impl GenKey for i32 {
    fn from_draw(draw: i32, _aux: u64) -> i32 {
        draw
    }
}

impl GenKey for u64 {
    /// The draw fills the top 31 bits (below the sign), `aux` the low 33
    /// — genuinely 64-bit keys with the draw's distribution shape.
    fn from_draw(draw: i32, aux: u64) -> u64 {
        ((draw.max(0) as u64) << 33) | (aux & ((1u64 << 33) - 1))
    }
}

impl GenKey for F64 {
    /// Integer part = the draw (exact in an f64), fraction from `aux`.
    /// The fraction lives in [0, 0.5) so `draw + fraction` can never
    /// round up into the next integer (for draws near 2³¹ the f64 ulp is
    /// ~2⁻²², and a fraction arbitrarily close to 1.0 would carry) —
    /// keeping the strict `from_draw(a, _) < from_draw(b, _)` law for
    /// `a < b` and `floor() == draw` exactly.
    fn from_draw(draw: i32, aux: u64) -> F64 {
        F64(draw as f64 + (aux >> 11) as f64 / (1u64 << 54) as f64)
    }
}

impl GenKey for Record {
    /// The draw is the record key; `aux` becomes satellite payload.
    fn from_draw(draw: i32, aux: u64) -> Record {
        Record { key: draw.max(0) as u32, payload: aux as u32 }
    }
}

/// Typed variant of [`generate_for_proc`]: the same §6.3 distributions,
/// mapped into key domain `K` (deterministic per `(bench, pid)` like the
/// `i32` generators — the aux stream is seeded from the paper seed).
///
/// For duplicate-defined benchmarks (\[DD\], whose *point* is massive key
/// equality) the aux bits are zeroed: entropy in the domain's low bits
/// would turn every equal draw into a distinct key and silently destroy
/// the property §5.1.1 is stressed by.
pub fn generate_typed_for_proc<K: GenKey>(
    bench: Benchmark,
    pid: usize,
    p: usize,
    n_local: usize,
) -> Vec<K> {
    let mut aux = SplitMix64::new(0x6B65_7973 ^ ((paper_seed(pid) as u64) << 17));
    let dup_defined = matches!(bench, Benchmark::DetDup);
    generate_for_proc(bench, pid, p, n_local)
        .into_iter()
        .map(|draw| K::from_draw(draw, if dup_defined { 0 } else { aux.next_u64() }))
        .collect()
}

/// Heavy-duplicate workload in domain `K`: draws collapse onto at most
/// `distinct` values *before* mapping and the aux bits are zeroed, so
/// equal draws become **equal keys** — maximal pressure on the §5.1.1
/// transparent duplicate handling in any domain (for [`Record`] this
/// means fully equal records, key and payload).
pub fn generate_heavy_dup_for_proc<K: GenKey>(
    bench: Benchmark,
    pid: usize,
    p: usize,
    n_local: usize,
    distinct: usize,
) -> Vec<K> {
    let m = distinct.max(1).min(i32::MAX as usize) as i32;
    generate_for_proc(bench, pid, p, n_local)
        .into_iter()
        .map(|draw| K::from_draw(draw.rem_euclid(m), 0))
        .collect()
}

fn uniform_below(rng: &mut BsdRandom, bound: i64) -> i64 {
    debug_assert!(bound > 0 && bound <= i32::MAX as i64 + 1);
    if bound > i32::MAX as i64 {
        rng.next_i32() as i64
    } else {
        rng.below(bound as i32) as i64
    }
}

/// \[DD\] Deterministic duplicates (§6.3 item 6): the keys of the first
/// p/2 processors are all `lg n`, of the next p/4 `lg(n/p)`, and so on;
/// the last processor repeats the halving pattern *within* its own keys.
fn det_dup(pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let n_total = (n_local * p) as i64;
    let lg = |x: i64| -> i32 {
        if x <= 1 {
            0
        } else {
            (63 - (x as u64).leading_zeros() as i64) as i32
        }
    };
    if pid < p - 1 || p == 1 {
        // Find the group: processors [p - p/2^(i-1), ...) style halving —
        // equivalently the largest i >= 1 with pid < p - p/2^i gives the
        // later groups; simplest is a forward scan of the halving blocks.
        let mut start = 0usize;
        let mut block = p / 2;
        let mut i = 1usize;
        let value = loop {
            if block == 0 || pid < start + block.max(1) {
                // value for group i: lg(n / p^{i-1}); clamp the power.
                let mut denom: i64 = 1;
                for _ in 0..i.saturating_sub(1) {
                    denom = denom.saturating_mul(p as i64);
                }
                break lg(n_total / denom.max(1));
            }
            start += block;
            block /= 2;
            i += 1;
        };
        if p == 1 {
            // single processor: fall through to the intra-proc pattern
            return intra_dd(n_local, n_total, p);
        }
        vec![value; n_local]
    } else {
        intra_dd(n_local, n_total, p)
    }
}

/// The last processor's \[DD\] share: n/(p·2^i) keys of value
/// `lg(n/(p·2^{i-1}))`, halving until exhausted.
fn intra_dd(n_local: usize, n_total: i64, p: usize) -> Vec<i32> {
    let lg = |x: i64| -> i32 {
        if x <= 1 {
            0
        } else {
            (63 - (x as u64).leading_zeros() as i64) as i32
        }
    };
    let mut out = Vec::with_capacity(n_local);
    let mut chunk = n_local / 2;
    let mut denom: i64 = p as i64;
    while out.len() < n_local {
        let value = lg(n_total / denom.max(1));
        let take = chunk.max(1).min(n_local - out.len());
        out.extend(std::iter::repeat(value).take(take));
        chunk /= 2;
        denom = denom.saturating_mul(2);
    }
    out
}

/// \[WR\] Worst-case for regular sampling, following [39]'s construction:
/// the globally sorted sequence is dealt to processors cyclically, so
/// every processor's regular sample is (nearly) the same and the induced
/// buckets are maximally imbalanced for plain regular sampling (s = p).
fn worst_regular(pid: usize, p: usize, n_local: usize) -> Vec<i32> {
    let scale = INT_MAX_P1 / (n_local as i64 * p as i64).max(1);
    (0..n_local)
        .map(|j| ((j as i64 * p as i64 + pid as i64) * scale.max(1)) as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: usize = 8;
    const N_LOCAL: usize = 1 << 10;

    #[test]
    fn all_benchmarks_produce_requested_sizes() {
        for b in ALL_BENCHMARKS {
            for pid in 0..P {
                let keys = generate_for_proc(b, pid, P, N_LOCAL);
                assert_eq!(keys.len(), N_LOCAL, "{} pid={pid}", b.tag());
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for b in ALL_BENCHMARKS {
            let a = generate_for_proc(b, 3, P, N_LOCAL);
            let c = generate_for_proc(b, 3, P, N_LOCAL);
            assert_eq!(a, c, "{}", b.tag());
        }
    }

    #[test]
    fn uniform_keys_are_nonnegative_31bit() {
        let keys = generate_for_proc(Benchmark::Uniform, 0, P, N_LOCAL);
        assert!(keys.iter().all(|&k| k >= 0));
        // And they vary.
        assert!(keys.iter().collect::<std::collections::HashSet<_>>().len() > N_LOCAL / 2);
    }

    #[test]
    fn gaussian_concentrates_toward_center() {
        let keys = generate_for_proc(Benchmark::Gaussian, 0, P, 1 << 14);
        let center = (INT_MAX_P1 / 2) as i32;
        let near = keys
            .iter()
            .filter(|&&k| (k as i64 - center as i64).abs() < INT_MAX_P1 / 4)
            .count();
        // Mean-of-4 keeps ~95% within ±INT_MAX/4 of the center.
        assert!(near as f64 > 0.9 * keys.len() as f64, "near={near}");
    }

    #[test]
    fn bucket_keys_live_in_their_windows() {
        let keys = generate_for_proc(Benchmark::Bucket, 2, P, N_LOCAL);
        let width = INT_MAX_P1 / P as i64;
        let per = N_LOCAL / P;
        for (i, chunk) in keys.chunks(per).take(P).enumerate() {
            for &k in chunk {
                let lo = i as i64 * width;
                assert!(
                    (lo..lo + width).contains(&(k as i64)),
                    "bucket {i} key {k} outside [{lo}, {})",
                    lo + width
                );
            }
        }
    }

    #[test]
    fn staggered_windows_cover_distinct_ranges() {
        let width = INT_MAX_P1 / P as i64;
        for pid in 0..P {
            let keys = generate_for_proc(Benchmark::Staggered, pid, P, 128);
            let window = if pid < P / 2 { 2 * pid + 1 } else { pid - P / 2 };
            let lo = window as i64 * width;
            assert!(keys.iter().all(|&k| (lo..lo + width).contains(&(k as i64))), "pid={pid}");
        }
    }

    #[test]
    fn det_dup_is_massively_duplicated() {
        let mut all: Vec<i32> = Vec::new();
        for pid in 0..P {
            all.extend(generate_for_proc(Benchmark::DetDup, pid, P, N_LOCAL));
        }
        let distinct: std::collections::HashSet<_> = all.iter().collect();
        assert!(distinct.len() <= 64, "distinct={}", distinct.len());
    }

    #[test]
    fn worst_regular_is_cyclic_sorted_deal() {
        let a = generate_for_proc(Benchmark::WorstRegular, 0, P, 64);
        let b = generate_for_proc(Benchmark::WorstRegular, 1, P, 64);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "per-proc runs sorted");
        assert!(a[0] < b[0] && b[0] < a[1], "interleaving holds");
    }

    #[test]
    fn ggroup_windows_wrap_mod_p() {
        let keys = generate_for_proc(Benchmark::GGroup(2), 0, P, 128);
        let width = INT_MAX_P1 / P as i64;
        // group j=0, buckets i=0,1 -> windows (p/2), (p/2+1) = 4,5.
        let lo = 4 * width;
        assert!(keys[..64].iter().all(|&k| (lo..lo + width).contains(&(k as i64))));
        let lo2 = 5 * width;
        assert!(keys[64..].iter().all(|&k| (lo2..lo2 + width).contains(&(k as i64))));
    }

    #[test]
    fn parse_tags_roundtrip() {
        for b in ALL_BENCHMARKS {
            assert_eq!(Benchmark::parse(&b.tag()), Some(b), "{}", b.tag());
        }
    }

    #[test]
    fn parse_strict_accepts_every_valid_tag() {
        for b in ALL_BENCHMARKS {
            assert_eq!(Benchmark::parse_strict(&b.tag()).unwrap(), b, "{}", b.tag());
        }
        for tag in VALID_BENCH_TAGS {
            assert!(Benchmark::parse_strict(tag).is_ok(), "{tag}");
        }
    }

    #[test]
    fn parse_strict_unknown_tag_lists_valid_tags() {
        // Regression: the CLI used to surface a silent `None` for
        // unknown tags; the error must now name the tag and the set.
        let err = Benchmark::parse_strict("XYZ").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("XYZ"), "{msg}");
        for tag in ["U", "2-G", "DD", "WR"] {
            assert!(msg.contains(tag), "missing {tag} in: {msg}");
        }
        assert!(Benchmark::parse_strict("").is_err());
    }

    #[test]
    fn typed_generation_is_deterministic_and_shaped() {
        use crate::key::{F64, Record};
        for b in ALL_BENCHMARKS {
            let a: Vec<u64> = generate_typed_for_proc(b, 3, P, 256);
            let c: Vec<u64> = generate_typed_for_proc(b, 3, P, 256);
            assert_eq!(a, c, "{}", b.tag());
            assert_eq!(a.len(), 256);
        }
        // The draw rides the top bits: recovering it reproduces the i32
        // stream, so every §6.3 distribution property carries over.
        let draws = generate_for_proc(Benchmark::Staggered, 1, P, 128);
        let typed: Vec<u64> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 128);
        let recovered: Vec<i32> = typed.iter().map(|&k| (k >> 33) as i32).collect();
        assert_eq!(recovered, draws);
        // Records keep the draw as the key field.
        let recs: Vec<Record> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 128);
        assert!(recs.iter().zip(&draws).all(|(r, &d)| r.key == d as u32));
        // f64 keys keep the draw as the integer part.
        let floats: Vec<F64> = generate_typed_for_proc(Benchmark::Staggered, 1, P, 128);
        assert!(floats.iter().zip(&draws).all(|(f, &d)| f.0.floor() == d as f64));
    }

    #[test]
    fn typed_dd_benchmark_keeps_its_duplicates() {
        // Regression: aux entropy must not break [DD]'s defining key
        // equality in wider domains — equal draws stay equal keys.
        use std::collections::HashSet;
        let mut all_u: Vec<u64> = Vec::new();
        let mut all_r: Vec<crate::key::Record> = Vec::new();
        for pid in 0..P {
            all_u.extend(generate_typed_for_proc::<u64>(Benchmark::DetDup, pid, P, N_LOCAL));
            all_r.extend(generate_typed_for_proc::<crate::key::Record>(
                Benchmark::DetDup,
                pid,
                P,
                N_LOCAL,
            ));
        }
        assert!(all_u.iter().collect::<HashSet<_>>().len() <= 64);
        assert!(all_r.iter().collect::<HashSet<_>>().len() <= 64);
    }

    #[test]
    fn heavy_dup_collapses_to_few_distinct_keys() {
        use std::collections::HashSet;
        let mut all: Vec<u64> = Vec::new();
        for pid in 0..P {
            all.extend(generate_heavy_dup_for_proc::<u64>(
                Benchmark::Uniform,
                pid,
                P,
                N_LOCAL,
                5,
            ));
        }
        let distinct: HashSet<_> = all.iter().collect();
        assert!(distinct.len() <= 5, "distinct={}", distinct.len());
        // Equal draws become *equal records* (payload zeroed too).
        let recs = generate_heavy_dup_for_proc::<crate::key::Record>(
            Benchmark::Uniform,
            0,
            P,
            N_LOCAL,
            3,
        );
        let distinct_recs: HashSet<_> = recs.iter().collect();
        assert!(distinct_recs.len() <= 3);
        assert!(recs.iter().all(|r| r.payload == 0));
    }
}
