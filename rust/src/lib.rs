//! # bsp-sort
//!
//! A production-grade reproduction of *"BSP Sorting: An Experimental
//! Study"* (Gerbessiotis & Siniolakis): one-optimal deterministic
//! (`SORT_DET_BSP`) and randomized (`SORT_IRAN_BSP`) BSP sorting with
//! regular/randomized oversampling and transparent duplicate-key
//! handling, executed on a threaded BSP machine substrate and priced
//! under the paper's Cray T3D `(p, L, g)` parameters.
//!
//! Three layers (DESIGN.md §3):
//!
//! * **L3 (this crate)** — the BSP substrate, primitives, the sorting
//!   algorithms, baselines, generators, theory model, the table
//!   harness regenerating the paper's Tables 1–11, and the
//!   sort-as-a-service façade ([`sorter`]) over a persistent engine
//!   pool;
//! * **L2 (python/compile/model.py)** — the JAX local-sort graph, AOT
//!   lowered to `artifacts/*.hlo.txt`;
//! * **L1 (python/compile/kernels/bitonic.py)** — the Pallas bitonic
//!   network kernel, loaded from Rust via PJRT ([`runtime`]).
//!
//! The whole stack is generic over the [`key::Key`] trait (total order +
//! fixed-width wire encoding), with `i32` as the default instantiation:
//! the same SPMD programs sort `u64`, total-ordered `f64` ([`key::F64`])
//! and `(u32 key, u32 payload)` records ([`key::Record`]), selected per
//! job through the [`sorter::SortJob`] builder.
//!
//! ## The BSP cost model
//!
//! A BSP machine is the triple `(p, L, g)`: `p` processors, a
//! synchronization latency `L` (µs), and a communication gap `g` (µs per
//! 64-bit word).  A program is a sequence of *supersteps* — compute on
//! local data, stage messages, synchronize — and one superstep costs
//!
//! ```text
//! max { L,  x/rate + g·h }
//! ```
//!
//! where `x` is the maximum basic operations (comparisons, at `rate`
//! comparisons/µs) charged on any processor and `h` the maximum words
//! into or out of any processor (the *h-relation*).  Predicted run time
//! is the sum over supersteps ([`bsp::Ledger::predicted_us`]).
//!
//! *Slackness* `n/p` is what makes the one-optimality claims work: for
//! `n/p` large enough, the `(n/p)·lg(n/p)` local-sort term dominates
//! both `L·lg²p` synchronization and `g·n_max` routing, so the parallel
//! efficiency approaches 1 (Props 5.1/5.3, [`theory`]).  The paper's
//! tables price runs under the Cray T3D's measured parameters
//! ([`bsp::params::cray_t3d`]); the [`experiment`] subsystem instead
//! *calibrates* `(g, L, rate)` on the host with micro-probes so
//! predictions land in host microseconds, directly comparable to
//! measured wall-clock.
//!
//! ## Running the experiment study
//!
//! One call sweeps a cross-product of {algorithm, distribution, key
//! domain, n, p}, calibrates the host, and reports measured-vs-predicted
//! ratios plus balance metrics (the CLI front-end is
//! `bsp-sort experiment`):
//!
//! ```
//! use bsp_sort::experiment::{self, ProbePlan, SweepSpec};
//!
//! let mut spec = SweepSpec::quick();   // the CI-sized preset…
//! spec.ns = vec![2048];                // …shrunk further for a doctest
//! spec.ps = vec![4];
//! spec.extras.clear();                 // …and without its sim @ p=256 cell
//! spec.reps = 1;
//! spec.warmup = 0;
//! spec.probes = ProbePlan::quick();
//! let report = experiment::run_study(&spec);
//! let run = &report.runs[0];
//! assert!(run.ratio.is_finite() && run.ratio > 0.0);   // measured / predicted
//! assert!(report.calibrations[0].l_us > 0.0);          // host L, µs
//! println!("{}", report.to_markdown());
//! ```
//!
//! Quickstart (a compiling, running doctest — `cargo test` executes it):
//!
//! ```
//! use bsp_sort::prelude::*;
//!
//! // One-shot: submit-and-join on the process-wide engine pool.  The
//! // pool keeps worker threads parked between jobs, so repeat sorts
//! // skip thread spin-up and reuse slot-matrix scratch.
//! let run = Sorter::global()
//!     .run(SortJob::new(AlgoVariant::Det, 1 << 12).procs(4))
//!     .expect("pool admits the job");
//! assert!(run.outputs.is_globally_sorted());
//! assert_eq!(run.outputs.total_keys(), 1 << 12);
//! println!("predicted T3D time: {:.3}s", run.ledger.predicted_secs(&cray_t3d(4)));
//!
//! // Asynchronous submission: a different key domain, a randomized
//! // variant and the deterministic simulator backend at a virtual `p`
//! // far beyond host threads — one façade, one builder.
//! let job = SortJob::new(AlgoVariant::Ran, 1 << 12)
//!     .domain(KeyDomain::RecordU32)
//!     .procs(64)
//!     .backend(Backend::Sim)
//!     .seed(7);
//! let handle = Sorter::global().submit(job).expect("queue has room");
//! let run = handle.join().expect("job completes");
//! assert_eq!(run.outputs.domain(), KeyDomain::RecordU32);
//! assert!(run.outputs.is_globally_sorted());
//! ```
//!
//! Direct SPMD programming against the substrate (custom supersteps,
//! raw message staging) remains available through [`bsp::BspMachine`];
//! sorting workloads should prefer the service surface above.

pub mod baselines;
pub mod bsp;
pub mod experiment;
pub mod ext;
pub mod gen;
pub mod key;
pub mod metrics;
pub mod primitives;
pub mod runtime;
pub mod seq;
pub mod sort;
pub mod sorter;
pub mod tables;
pub mod theory;
pub mod util;

/// One-import surface of the service API: `use bsp_sort::prelude::*;`
/// brings in the [`sorter::Sorter`] façade, the [`sorter::SortJob`]
/// builder and every vocabulary type a job mentions — no deep module
/// paths required.
pub mod prelude {
    pub use crate::bsp::service::{Engine, EngineConfig, EngineStats, JobHandle};
    pub use crate::bsp::{cray_t3d, Backend, BspParams, Ledger};
    pub use crate::experiment::spec::{AlgoVariant, KeyDomain, TopologyChoice};
    pub use crate::gen::Benchmark;
    pub use crate::runtime::RuntimeError;
    pub use crate::sort::{LocalSortEngine, SortConfig};
    pub use crate::sorter::{DomainOutputs, SortHandle, SortJob, SortRun, Sorter};
}
