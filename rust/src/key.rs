//! The key domain abstraction: everything the sorting stack needs from a
//! key type, plus the built-in domains of the study.
//!
//! The paper's experiments are over 32-bit signed integers, but the §5.1.1
//! duplicate handling and the oversampling analysis are *domain-agnostic*:
//! nothing in the algorithms depends on what a key is beyond a total order
//! and a fixed wire width.  [`Key`] captures exactly that contract, so the
//! same SPMD programs sort `i32` (the default instantiation everywhere),
//! `u64`, total-ordered `f64` ([`F64`]) and `(u32 key, u32 payload)`
//! records ([`Record`]).
//!
//! Wire format: the engine's communication word is the T3D's 64-bit
//! integer (§6), so a key encodes into a fixed number of `u64` words
//! ([`Key::WORDS`], all built-in domains fit one word) and the engine
//! charges `h` from that width.  [`RadixKey`] additionally provides an
//! order-preserving unsigned image for the LSD radix backend (`[.SR]`
//! variants).

#![warn(missing_docs)]

use std::cmp::Ordering;
use std::fmt;

/// A sortable key domain: total order, thread mobility, and a fixed-width
/// encoding into the engine's 64-bit wire words.
///
/// In-process the engine moves payloads as typed vectors (shared memory
/// needs no serialization); the encoding defines the *wire image* that
/// `Payload::encode_wire` produces and that the `h`-relation charging
/// (`Payload::words`, [`Key::WORDS`] words per key) prices.
///
/// Laws (checked by the round-trip property tests below):
/// * `decode(encode(k)) == k` for every key `k`;
/// * `encode` appends exactly [`Key::WORDS`] words;
/// * `k <= max_key()` for every key `k` (the padding sentinel used for
///   empty or short sample runs).
pub trait Key: Copy + Send + Sync + Ord + fmt::Debug + 'static {
    /// Fixed wire width of one key, in 64-bit communication words.
    const WORDS: u64;
    /// Short domain name for reports and workload labels.
    const NAME: &'static str;

    /// The greatest value of the domain (sample-padding sentinel).
    fn max_key() -> Self;
    /// Append this key's fixed-width wire encoding to `out`.
    fn encode(self, out: &mut Vec<u64>);
    /// Decode one key from exactly [`Key::WORDS`] wire words.
    fn decode(words: &[u64]) -> Self;
}

/// A key domain with an order-preserving unsigned image, enabling the LSD
/// radix backend: `a < b` iff `a.radix_image() < b.radix_image()`.
pub trait RadixKey: Key {
    /// Number of 8-bit LSD counting passes covering the image.
    const RADIX_PASSES: u32;
    /// The order-preserving unsigned image.
    fn radix_image(self) -> u64;
}

/// Encode a whole slice into wire words (`keys.len() * K::WORDS` words).
pub fn encode_all<K: Key>(keys: &[K]) -> Vec<u64> {
    let mut out = Vec::with_capacity(keys.len() * K::WORDS as usize);
    for &k in keys {
        k.encode(&mut out);
    }
    out
}

/// Decode a wire-word buffer back into keys; `words.len()` must be a
/// multiple of `K::WORDS`.
pub fn decode_all<K: Key>(words: &[u64]) -> Vec<K> {
    let stride = K::WORDS as usize;
    assert_eq!(words.len() % stride.max(1), 0, "truncated wire buffer");
    words.chunks_exact(stride).map(K::decode).collect()
}

impl Key for i32 {
    const WORDS: u64 = 1;
    const NAME: &'static str = "i32";

    fn max_key() -> i32 {
        i32::MAX
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(self as u32 as u64);
    }
    fn decode(words: &[u64]) -> i32 {
        words[0] as u32 as i32
    }
}

impl RadixKey for i32 {
    const RADIX_PASSES: u32 = 4;

    /// Bias map: flipping the sign bit of the 32-bit image orders the
    /// unsigned image identically to signed order.
    fn radix_image(self) -> u64 {
        ((self as u32) ^ 0x8000_0000) as u64
    }
}

impl Key for u64 {
    const WORDS: u64 = 1;
    const NAME: &'static str = "u64";

    fn max_key() -> u64 {
        u64::MAX
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(self);
    }
    fn decode(words: &[u64]) -> u64 {
        words[0]
    }
}

impl RadixKey for u64 {
    const RADIX_PASSES: u32 = 8;

    fn radix_image(self) -> u64 {
        self
    }
}

/// `f64` under the IEEE-754 *total order* (`f64::total_cmp`): every bit
/// pattern — including NaNs and the two zeros — has a well-defined rank,
/// so the sorting invariants (and the radix image) stay exact.
///
/// Order: `-NaN < -∞ < … < -0.0 < +0.0 < … < +∞ < +NaN`.
#[derive(Clone, Copy, Debug, Default)]
pub struct F64(
    /// The raw IEEE-754 value (every bit pattern is a valid key).
    pub f64,
);

impl PartialEq for F64 {
    fn eq(&self, other: &F64) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for F64 {}

impl PartialOrd for F64 {
    fn partial_cmp(&self, other: &F64) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F64 {
    fn cmp(&self, other: &F64) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl Key for F64 {
    const WORDS: u64 = 1;
    const NAME: &'static str = "f64";

    /// The total-order maximum: the positive NaN with an all-ones
    /// payload (greater than `+∞` under `total_cmp`).
    fn max_key() -> F64 {
        F64(f64::from_bits(0x7FFF_FFFF_FFFF_FFFF))
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(self.0.to_bits());
    }
    fn decode(words: &[u64]) -> F64 {
        F64(f64::from_bits(words[0]))
    }
}

impl RadixKey for F64 {
    const RADIX_PASSES: u32 = 8;

    /// The classical total-order bit trick: negative patterns flip all
    /// bits, non-negative ones flip only the sign — monotone in
    /// `total_cmp` across the whole bit space.
    fn radix_image(self) -> u64 {
        let bits = self.0.to_bits();
        if bits & (1u64 << 63) != 0 {
            !bits
        } else {
            bits ^ (1u64 << 63)
        }
    }
}

/// A `(u32 key, u32 payload)` record: the satellite-data scenario.  The
/// total order is lexicographic `(key, payload)` (field order), so
/// records with equal `key` fields still have well-defined ranks — the
/// sorting stack needs no awareness that a payload is riding along.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Record {
    /// The sort key.
    pub key: u32,
    /// Satellite data riding along (never examined by the sorts).
    pub payload: u32,
}

impl Key for Record {
    const WORDS: u64 = 1;
    const NAME: &'static str = "record(u32,u32)";

    fn max_key() -> Record {
        Record { key: u32::MAX, payload: u32::MAX }
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(((self.key as u64) << 32) | self.payload as u64);
    }
    fn decode(words: &[u64]) -> Record {
        Record { key: (words[0] >> 32) as u32, payload: words[0] as u32 }
    }
}

impl RadixKey for Record {
    const RADIX_PASSES: u32 = 8;

    /// The packed encoding is already the lexicographic order image.
    fn radix_image(self) -> u64 {
        ((self.key as u64) << 32) | self.payload as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::SplitMix64;

    fn roundtrip<K: Key>(k: K) {
        let mut words = Vec::new();
        k.encode(&mut words);
        assert_eq!(words.len() as u64, K::WORDS, "{}: encode width", K::NAME);
        assert_eq!(K::decode(&words), k, "{}: decode(encode) != id", K::NAME);
    }

    fn image_matches_order<K: RadixKey>(a: K, b: K) {
        assert_eq!(
            a.cmp(&b),
            a.radix_image().cmp(&b.radix_image()),
            "{}: radix image order mismatch for {a:?} vs {b:?}",
            K::NAME
        );
    }

    #[test]
    fn roundtrip_all_domains_property() {
        check("key-roundtrip", |rng| {
            roundtrip(rng.next_u64() as i32);
            roundtrip(rng.next_u64());
            roundtrip(F64(f64::from_bits(rng.next_u64())));
            roundtrip(Record {
                key: rng.next_u64() as u32,
                payload: rng.next_u64() as u32,
            });
        });
    }

    #[test]
    fn roundtrip_f64_special_values() {
        for f in [
            f64::NAN,
            -f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            0.0,
            -0.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
        ] {
            roundtrip(F64(f));
        }
    }

    #[test]
    fn f64_total_order_handles_nan_and_signed_zero() {
        let neg_nan = F64(f64::from_bits(0xFFF8_0000_0000_0001));
        let pos_nan = F64(f64::NAN);
        let order = [
            neg_nan,
            F64(f64::NEG_INFINITY),
            F64(-1.5),
            F64(-0.0),
            F64(0.0),
            F64(1.5),
            F64(f64::INFINITY),
            pos_nan,
            F64::max_key(),
        ];
        for w in order.windows(2) {
            assert!(w[0] < w[1], "{:?} must order before {:?}", w[0], w[1]);
        }
        // -0.0 and +0.0 are *distinct* under the total order…
        assert_ne!(F64(-0.0), F64(0.0));
        // …and a NaN equals itself (same bit pattern), unlike IEEE `==`.
        assert_eq!(pos_nan, pos_nan);
    }

    #[test]
    fn radix_image_is_order_preserving_property() {
        check("key-radix-image-order", |rng| {
            image_matches_order(rng.next_u64() as i32, rng.next_u64() as i32);
            image_matches_order(rng.next_u64(), rng.next_u64());
            image_matches_order(
                F64(f64::from_bits(rng.next_u64())),
                F64(f64::from_bits(rng.next_u64())),
            );
            image_matches_order(
                Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 },
                Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 },
            );
        });
    }

    #[test]
    fn max_key_dominates_property() {
        check("key-max-dominates", |rng| {
            assert!(rng.next_u64() as i32 <= i32::max_key());
            assert!(rng.next_u64() <= u64::max_key());
            assert!(F64(f64::from_bits(rng.next_u64())) <= F64::max_key());
            let r = Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 };
            assert!(r <= Record::max_key());
        });
    }

    #[test]
    fn bulk_encode_decode_roundtrip() {
        let mut rng = SplitMix64::new(0xC0DE);
        let keys: Vec<Record> = (0..257)
            .map(|_| Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 })
            .collect();
        let words = encode_all(&keys);
        assert_eq!(words.len(), keys.len() * Record::WORDS as usize);
        assert_eq!(decode_all::<Record>(&words), keys);
    }

    #[test]
    fn record_orders_by_key_then_payload() {
        let a = Record { key: 1, payload: 9 };
        let b = Record { key: 2, payload: 0 };
        let c = Record { key: 2, payload: 1 };
        assert!(a < b && b < c);
    }
}
