//! The key domain abstraction: everything the sorting stack needs from a
//! key type, plus the built-in domains of the study.
//!
//! The paper's experiments are over 32-bit signed integers, but the §5.1.1
//! duplicate handling and the oversampling analysis are *domain-agnostic*:
//! nothing in the algorithms depends on what a key is beyond a total order
//! and a fixed wire width.  [`Key`] captures exactly that contract, so the
//! same SPMD programs sort `i32` (the default instantiation everywhere),
//! `u64`, total-ordered `f64` ([`F64`]), `(u32 key, u32 payload)`
//! records ([`Record`]) and variable-length strings ([`Str`]).
//!
//! Wire format: the engine's communication word is the T3D's 64-bit
//! integer (§6), so a key encodes into a fixed number of `u64` words
//! ([`Key::WORDS`] — one for the scalar domains, two for [`Str`]) and
//! the engine charges `h` from that width.  [`RadixKey`] additionally
//! provides an order-preserving unsigned image for the radix backends
//! (`[.SR]` variants and the IPS engine); [`Str`]'s image is its 8-byte
//! prefix, with shared-prefix ties broken by a secondary comparison
//! pass in the engines (see `RadixKey::IMAGE_EXACT`).

#![warn(missing_docs)]

use std::cmp::Ordering;
use std::fmt;

/// A sortable key domain: total order, thread mobility, and a fixed-width
/// encoding into the engine's 64-bit wire words.
///
/// In-process the engine moves payloads as typed vectors (shared memory
/// needs no serialization); the encoding defines the *wire image* that
/// `Payload::encode_wire` produces and that the `h`-relation charging
/// (`Payload::words`, [`Key::WORDS`] words per key) prices.
///
/// Laws (checked by the round-trip property tests below):
/// * `decode(encode(k)) == k` for every key `k`;
/// * `encode` appends exactly [`Key::WORDS`] words;
/// * `k <= max_key()` for every key `k` (the padding sentinel used for
///   empty or short sample runs).
pub trait Key: Copy + Send + Sync + Ord + fmt::Debug + 'static {
    /// Fixed wire width of one key, in 64-bit communication words.
    const WORDS: u64;
    /// Short domain name for reports and workload labels.
    const NAME: &'static str;

    /// The greatest value of the domain (sample-padding sentinel).
    fn max_key() -> Self;
    /// Append this key's fixed-width wire encoding to `out`.
    fn encode(self, out: &mut Vec<u64>);
    /// Decode one key from exactly [`Key::WORDS`] wire words.
    fn decode(words: &[u64]) -> Self;
}

/// A key domain with an order-preserving unsigned image, enabling the
/// radix backends.
///
/// For most domains the image is *exact* — `a < b` iff
/// `a.radix_image() < b.radix_image()` — and radix passes alone produce
/// the fully sorted order.  A domain may instead provide a *prefix*
/// image ([`IMAGE_EXACT`](RadixKey::IMAGE_EXACT)` = false`, e.g.
/// [`Str`]'s first eight bytes): then only the weak laws hold
///
/// * `a <= b`  ⇒  `image(a) <= image(b)` (never order-reversing), and
/// * `image(a) < image(b)`  ⇒  `a < b`,
///
/// so equal-image keys may still be unequal.  Radix engines handle this
/// with a tie-break pass (`seq::break_image_ties`): after the passes,
/// equal-image keys sit in one contiguous run, which is re-sorted by
/// the full `Ord` order.
pub trait RadixKey: Key {
    /// Number of 8-bit LSD counting passes covering the image.
    const RADIX_PASSES: u32;
    /// Whether the image is exact (`a < b` iff image < image).  Prefix
    /// images set `false` and rely on the engines' tie-break pass.
    const IMAGE_EXACT: bool = true;
    /// The order-preserving unsigned image.
    fn radix_image(self) -> u64;
}

/// Encode a whole slice into wire words (`keys.len() * K::WORDS` words).
pub fn encode_all<K: Key>(keys: &[K]) -> Vec<u64> {
    let mut out = Vec::with_capacity(keys.len() * K::WORDS as usize);
    for &k in keys {
        k.encode(&mut out);
    }
    out
}

/// Decode a wire-word buffer back into keys; `words.len()` must be a
/// multiple of `K::WORDS`.
pub fn decode_all<K: Key>(words: &[u64]) -> Vec<K> {
    let stride = K::WORDS as usize;
    assert_eq!(words.len() % stride.max(1), 0, "truncated wire buffer");
    words.chunks_exact(stride).map(K::decode).collect()
}

impl Key for i32 {
    const WORDS: u64 = 1;
    const NAME: &'static str = "i32";

    fn max_key() -> i32 {
        i32::MAX
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(self as u32 as u64);
    }
    fn decode(words: &[u64]) -> i32 {
        words[0] as u32 as i32
    }
}

impl RadixKey for i32 {
    const RADIX_PASSES: u32 = 4;

    /// Bias map: flipping the sign bit of the 32-bit image orders the
    /// unsigned image identically to signed order.
    fn radix_image(self) -> u64 {
        ((self as u32) ^ 0x8000_0000) as u64
    }
}

impl Key for u64 {
    const WORDS: u64 = 1;
    const NAME: &'static str = "u64";

    fn max_key() -> u64 {
        u64::MAX
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(self);
    }
    fn decode(words: &[u64]) -> u64 {
        words[0]
    }
}

impl RadixKey for u64 {
    const RADIX_PASSES: u32 = 8;

    fn radix_image(self) -> u64 {
        self
    }
}

/// `f64` under the IEEE-754 *total order* (`f64::total_cmp`): every bit
/// pattern — including NaNs and the two zeros — has a well-defined rank,
/// so the sorting invariants (and the radix image) stay exact.
///
/// Order: `-NaN < -∞ < … < -0.0 < +0.0 < … < +∞ < +NaN`.
#[derive(Clone, Copy, Debug, Default)]
pub struct F64(
    /// The raw IEEE-754 value (every bit pattern is a valid key).
    pub f64,
);

impl PartialEq for F64 {
    fn eq(&self, other: &F64) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for F64 {}

impl PartialOrd for F64 {
    fn partial_cmp(&self, other: &F64) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F64 {
    fn cmp(&self, other: &F64) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl Key for F64 {
    const WORDS: u64 = 1;
    const NAME: &'static str = "f64";

    /// The total-order maximum: the positive NaN with an all-ones
    /// payload (greater than `+∞` under `total_cmp`).
    fn max_key() -> F64 {
        F64(f64::from_bits(0x7FFF_FFFF_FFFF_FFFF))
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(self.0.to_bits());
    }
    fn decode(words: &[u64]) -> F64 {
        F64(f64::from_bits(words[0]))
    }
}

impl RadixKey for F64 {
    const RADIX_PASSES: u32 = 8;

    /// The classical total-order bit trick: negative patterns flip all
    /// bits, non-negative ones flip only the sign — monotone in
    /// `total_cmp` across the whole bit space.
    fn radix_image(self) -> u64 {
        let bits = self.0.to_bits();
        if bits & (1u64 << 63) != 0 {
            !bits
        } else {
            bits ^ (1u64 << 63)
        }
    }
}

/// A `(u32 key, u32 payload)` record: the satellite-data scenario.  The
/// total order is lexicographic `(key, payload)` (field order), so
/// records with equal `key` fields still have well-defined ranks — the
/// sorting stack needs no awareness that a payload is riding along.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Record {
    /// The sort key.
    pub key: u32,
    /// Satellite data riding along (never examined by the sorts).
    pub payload: u32,
}

impl Key for Record {
    const WORDS: u64 = 1;
    const NAME: &'static str = "record(u32,u32)";

    fn max_key() -> Record {
        Record { key: u32::MAX, payload: u32::MAX }
    }
    fn encode(self, out: &mut Vec<u64>) {
        out.push(((self.key as u64) << 32) | self.payload as u64);
    }
    fn decode(words: &[u64]) -> Record {
        Record { key: (words[0] >> 32) as u32, payload: words[0] as u32 }
    }
}

impl RadixKey for Record {
    const RADIX_PASSES: u32 = 8;

    /// The packed encoding is already the lexicographic order image.
    fn radix_image(self) -> u64 {
        ((self.key as u64) << 32) | self.payload as u64
    }
}

/// A variable-length string key, inline and fixed-capacity: up to
/// [`Str::MAX_LEN`] non-NUL bytes, zero-padded.  Because `0` is reserved
/// for padding (shorter strings sort before their extensions, exactly
/// like byte-string order), the derived array-lexicographic `Ord` *is*
/// variable-length byte-string order.
///
/// Wire format: the 16 bytes as two big-endian `u64` words — big-endian
/// makes word-lexicographic order equal byte-lexicographic order, so
/// the encoding is order-preserving (and exact, since it is the whole
/// key).  The *radix image* is only the first word (the 8-byte prefix):
/// keys sharing a prefix collide in the image and are separated by the
/// engines' tie-break pass ([`RadixKey::IMAGE_EXACT`]` = false`).
#[derive(Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Str(
    /// The bytes: content up to the first NUL, NUL-padded to 16.
    pub [u8; 16],
);

impl Str {
    /// Maximum string length (the fixed inline capacity).
    pub const MAX_LEN: usize = 16;

    /// Build from a byte string; `s` must be at most [`Str::MAX_LEN`]
    /// bytes and contain no NUL (NUL is the padding sentinel).
    pub fn from_bytes(s: &[u8]) -> Str {
        assert!(s.len() <= Str::MAX_LEN, "Str holds at most 16 bytes, got {}", s.len());
        debug_assert!(!s.contains(&0), "NUL is reserved for padding");
        let mut b = [0u8; 16];
        b[..s.len()].copy_from_slice(s);
        Str(b)
    }

    /// The string length (bytes before the first NUL).
    pub fn len(&self) -> usize {
        self.0.iter().position(|&b| b == 0).unwrap_or(Str::MAX_LEN)
    }

    /// Whether this is the empty string.
    pub fn is_empty(&self) -> bool {
        self.0[0] == 0
    }

    /// The content bytes (padding stripped).
    pub fn as_bytes(&self) -> &[u8] {
        &self.0[..self.len()]
    }
}

impl fmt::Debug for Str {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Str({:?})", String::from_utf8_lossy(self.as_bytes()))
    }
}

impl Key for Str {
    const WORDS: u64 = 2;
    const NAME: &'static str = "str";

    fn max_key() -> Str {
        Str([0xFF; 16])
    }
    fn encode(self, out: &mut Vec<u64>) {
        let hi: [u8; 8] = self.0[..8].try_into().expect("8-byte half");
        let lo: [u8; 8] = self.0[8..].try_into().expect("8-byte half");
        out.push(u64::from_be_bytes(hi));
        out.push(u64::from_be_bytes(lo));
    }
    fn decode(words: &[u64]) -> Str {
        let mut b = [0u8; 16];
        b[..8].copy_from_slice(&words[0].to_be_bytes());
        b[8..].copy_from_slice(&words[1].to_be_bytes());
        Str(b)
    }
}

impl RadixKey for Str {
    const RADIX_PASSES: u32 = 8;
    /// The 8-byte prefix is only a *prefix* image: keys sharing it may
    /// differ in bytes 8..16.
    const IMAGE_EXACT: bool = false;

    /// The first eight bytes, big-endian — weakly monotone in the
    /// byte-lexicographic order.
    fn radix_image(self) -> u64 {
        let hi: [u8; 8] = self.0[..8].try_into().expect("8-byte prefix");
        u64::from_be_bytes(hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::SplitMix64;

    fn roundtrip<K: Key>(k: K) {
        let mut words = Vec::new();
        k.encode(&mut words);
        assert_eq!(words.len() as u64, K::WORDS, "{}: encode width", K::NAME);
        assert_eq!(K::decode(&words), k, "{}: decode(encode) != id", K::NAME);
    }

    fn image_matches_order<K: RadixKey>(a: K, b: K) {
        assert_eq!(
            a.cmp(&b),
            a.radix_image().cmp(&b.radix_image()),
            "{}: radix image order mismatch for {a:?} vs {b:?}",
            K::NAME
        );
    }

    /// A random [`Str`]: printable ASCII (never NUL), any length 0..=16.
    fn arb_str(rng: &mut SplitMix64) -> Str {
        let len = (rng.next_u64() % 17) as usize;
        let mut b = [0u8; 16];
        for slot in b.iter_mut().take(len) {
            *slot = b'!' + (rng.next_u64() % 94) as u8;
        }
        Str(b)
    }

    /// A random [`Str`] sharing a fixed 8-byte prefix (image collisions
    /// guaranteed), with a random short suffix.
    fn arb_shared_prefix_str(rng: &mut SplitMix64) -> Str {
        let mut s = *b"prefix!!\0\0\0\0\0\0\0\0";
        let suffix = (rng.next_u64() % 9) as usize;
        for slot in s.iter_mut().skip(8).take(suffix) {
            *slot = b'a' + (rng.next_u64() % 26) as u8;
        }
        Str(s)
    }

    #[test]
    fn roundtrip_all_domains_property() {
        check("key-roundtrip", |rng| {
            roundtrip(rng.next_u64() as i32);
            roundtrip(rng.next_u64());
            roundtrip(F64(f64::from_bits(rng.next_u64())));
            roundtrip(Record {
                key: rng.next_u64() as u32,
                payload: rng.next_u64() as u32,
            });
            roundtrip(arb_str(rng));
        });
    }

    #[test]
    fn roundtrip_f64_special_values() {
        for f in [
            f64::NAN,
            -f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            0.0,
            -0.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
        ] {
            roundtrip(F64(f));
        }
    }

    #[test]
    fn f64_total_order_handles_nan_and_signed_zero() {
        let neg_nan = F64(f64::from_bits(0xFFF8_0000_0000_0001));
        let pos_nan = F64(f64::NAN);
        let order = [
            neg_nan,
            F64(f64::NEG_INFINITY),
            F64(-1.5),
            F64(-0.0),
            F64(0.0),
            F64(1.5),
            F64(f64::INFINITY),
            pos_nan,
            F64::max_key(),
        ];
        for w in order.windows(2) {
            assert!(w[0] < w[1], "{:?} must order before {:?}", w[0], w[1]);
        }
        // -0.0 and +0.0 are *distinct* under the total order…
        assert_ne!(F64(-0.0), F64(0.0));
        // …and a NaN equals itself (same bit pattern), unlike IEEE `==`.
        assert_eq!(pos_nan, pos_nan);
    }

    #[test]
    fn radix_image_is_order_preserving_property() {
        check("key-radix-image-order", |rng| {
            image_matches_order(rng.next_u64() as i32, rng.next_u64() as i32);
            image_matches_order(rng.next_u64(), rng.next_u64());
            image_matches_order(
                F64(f64::from_bits(rng.next_u64())),
                F64(f64::from_bits(rng.next_u64())),
            );
            image_matches_order(
                Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 },
                Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 },
            );
        });
    }

    #[test]
    fn max_key_dominates_property() {
        check("key-max-dominates", |rng| {
            assert!(rng.next_u64() as i32 <= i32::max_key());
            assert!(rng.next_u64() <= u64::max_key());
            assert!(F64(f64::from_bits(rng.next_u64())) <= F64::max_key());
            let r = Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 };
            assert!(r <= Record::max_key());
            assert!(arb_str(rng) <= Str::max_key());
        });
    }

    #[test]
    fn str_wire_encoding_is_order_exact() {
        // The full two-word big-endian encoding is order-*exact*:
        // word-lexicographic order == byte-string order, both ways
        // (encode(a) < encode(b) ⇒ a < b is the order-preservation law;
        // the converse follows from injectivity).
        check("key-str-encoding-order", |rng| {
            let (a, b) = (arb_str(rng), arb_str(rng));
            let mut wa = Vec::new();
            let mut wb = Vec::new();
            a.encode(&mut wa);
            b.encode(&mut wb);
            assert_eq!(
                a.cmp(&b),
                wa.cmp(&wb),
                "wire order must equal key order for {a:?} vs {b:?}"
            );
        });
    }

    #[test]
    fn str_prefix_image_is_weakly_monotone() {
        // The 8-byte prefix image satisfies only the weak laws — the
        // strict `image_matches_order` does not apply to `Str`.
        check("key-str-image-weak-order", |rng| {
            let (a, b) = (arb_str(rng), arb_str(rng));
            if a < b {
                assert!(a.radix_image() <= b.radix_image(), "{a:?} vs {b:?}");
            }
            if a.radix_image() < b.radix_image() {
                assert!(a < b, "{a:?} vs {b:?}");
            }
            // Shared-prefix keys collide in the image while remaining
            // distinct — the case the tie-break pass exists for.
            let (c, d) = (arb_shared_prefix_str(rng), arb_shared_prefix_str(rng));
            assert_eq!(c.radix_image(), d.radix_image());
        });
        assert!(!<Str as RadixKey>::IMAGE_EXACT);
        assert!(<i32 as RadixKey>::IMAGE_EXACT);
    }

    #[test]
    fn str_shared_prefix_ties_break_by_full_order_in_both_radix_engines() {
        // A corpus dominated by image collisions (every key shares one
        // 8-byte prefix, plus duplicates), big enough that `ipssort`
        // leaves its quicksort fallback: both radix engines must agree
        // with the comparison sort exactly.
        use crate::seq::{ipssort, quicksort, radixsort};
        let mut rng = SplitMix64::new(0x5741_5254);
        let mut corpus: Vec<Str> = (0..2000).map(|_| arb_shared_prefix_str(&mut rng)).collect();
        let dup = corpus[7];
        corpus.extend(std::iter::repeat(dup).take(100));
        let mut expect = corpus.clone();
        quicksort(&mut expect);
        let mut by_radix = corpus.clone();
        radixsort(&mut by_radix);
        assert_eq!(by_radix, expect, "radixsort must break shared-prefix ties");
        let mut by_ips = corpus.clone();
        ipssort(&mut by_ips);
        assert_eq!(by_ips, expect, "ipssort must break shared-prefix ties");
    }

    #[test]
    fn str_from_bytes_len_and_order_basics() {
        let empty = Str::from_bytes(b"");
        let a = Str::from_bytes(b"app");
        let b = Str::from_bytes(b"apple");
        let c = Str::from_bytes(b"applesauce!!!!!!");
        assert!(empty.is_empty());
        assert_eq!(empty.len(), 0);
        assert_eq!(b.len(), 5);
        assert_eq!(c.len(), 16);
        // Shorter strings sort before their extensions (NUL padding).
        assert!(empty < a && a < b && b < c);
        assert_eq!(b.as_bytes(), b"apple");
        assert_eq!(format!("{b:?}"), "Str(\"apple\")");
        assert_eq!(Str::default(), empty);
    }

    #[test]
    fn bulk_encode_decode_roundtrip() {
        let mut rng = SplitMix64::new(0xC0DE);
        let keys: Vec<Record> = (0..257)
            .map(|_| Record { key: rng.next_u64() as u32, payload: rng.next_u64() as u32 })
            .collect();
        let words = encode_all(&keys);
        assert_eq!(words.len(), keys.len() * Record::WORDS as usize);
        assert_eq!(decode_all::<Record>(&words), keys);
    }

    #[test]
    fn record_orders_by_key_then_payload() {
        let a = Record { key: 1, payload: 9 };
        let b = Record { key: 2, payload: 0 };
        let c = Record { key: 2, payload: 1 };
        assert!(a < b && b < c);
    }
}
