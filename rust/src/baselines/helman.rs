//! Helman–JaJa–Bader comparators: the deterministic sorting algorithm of
//! [39] and the randomized one of [40]/[41], rebuilt on our substrate for
//! the Table 8/9 comparisons.
//!
//! **[39] deterministic** — sorting by regular sampling with *two* data
//! communication rounds:
//!   1. local sort; round 1 deterministically deals each processor's
//!      sorted run into `p` blocks routed by position (a transpose),
//!   2. each processor merges what it received, selects a regular sample,
//!      the samples elect splitters,
//!   3. round 2 routes by splitter, final merge.
//! Duplicate keys are handled by tagging **every** key (key, origin) —
//! the paper (§5.1.1, §6.4): "[39] ... handles duplicate keys by
//! performing twice as much communication"; we charge 2 words per key in
//! both routing rounds.
//!
//! **[40] randomized** — one sample round + one data round, but again
//! with per-key tags doubling the routed words.

use crate::bsp::engine::BspScope;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::{Key, RadixKey};
use crate::primitives::broadcast;
use crate::seq::{ops, search, SeqSorter};
use crate::util::rng::SplitMix64;

use super::super::sort::common::{ProcResult, PH2, PH3, PH4, PH5, PH6, PH7};
use super::super::sort::config::SortConfig;

/// Extra communication factor for per-key duplicate tagging: every routed
/// key carries its origin tag, doubling the words on the wire.
const TAG_WORDS_PER_KEY: usize = 2;

/// Route `parts[i]` to processor `i`, charging `TAG_WORDS_PER_KEY` words
/// per key (the tagged-communication model of [39]/[40]).  Generic over
/// the [`BspScope`], like the sorts themselves, so the baselines run on
/// the threaded engine and the deterministic simulator alike.
fn route_tagged<K: Key, S: BspScope<K>>(ctx: &mut S, parts: Vec<Vec<K>>, label: &str) -> Vec<Vec<K>> {
    let p = ctx.nprocs();
    assert_eq!(parts.len(), p);
    for (dst, mut part) in parts.into_iter().enumerate() {
        // Model the (key, tag) pair stream: duplicate each payload's word
        // count by sending the tag words as a sibling U64 payload.  The
        // engine charges h from actual payload words, so the tag stream
        // doubles h exactly as [39] describes.
        let tags: Vec<u64> = vec![0u64; part.len() * (TAG_WORDS_PER_KEY - 1)];
        ctx.send(dst, Payload::Keys(std::mem::take(&mut part)));
        if !tags.is_empty() {
            ctx.send(dst, Payload::U64s(tags));
        }
    }
    ctx.sync(label);
    let mut runs: Vec<Vec<K>> = vec![Vec::new(); p];
    for (src, payload) in ctx.take_inbox() {
        if let Payload::Keys(ks) = payload {
            runs[src] = ks;
        }
    }
    runs
}

/// The deterministic algorithm of [39] (two communication rounds).
pub fn sort_helman_det<K: RadixKey, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    mut local: Vec<K>,
    cfg: &SortConfig,
) -> ProcResult<K> {
    let p = ctx.nprocs();
    let pid = ctx.pid();
    let sorter: Box<dyn SeqSorter<K>> = crate::seq::backend(cfg.seq);

    // Step 1: local sort.
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    sorter.sort(&mut local);
    let keys = local;

    if p == 1 {
        return ProcResult { received: keys.len(), runs: 1, keys };
    }

    // Step 2 (round 1, "PhR" of Table 8): deterministic transpose — the
    // sorted run is dealt into p position blocks, block i to processor i.
    ctx.phase("PhR:Transpose");
    let n_local = keys.len();
    let block = n_local.div_ceil(p);
    let parts: Vec<Vec<K>> = (0..p)
        .map(|i| keys[(i * block).min(n_local)..((i + 1) * block).min(n_local)].to_vec())
        .collect();
    ctx.charge(ops::linear_charge(n_local));
    let round1 = route_tagged(ctx, parts, "helman:round1");

    // Step 3: merge the received runs; take a regular sample.
    let runs1: Vec<Vec<K>> = round1.into_iter().filter(|r| !r.is_empty()).collect();
    let total1: usize = runs1.iter().map(|r| r.len()).sum();
    ctx.charge(ops::merge_charge(total1, runs1.len().max(2)));
    let merged1 = crate::seq::multiway_merge(&runs1);

    ctx.phase(PH3);
    let step = (merged1.len() / p).max(1);
    let sample: Vec<SampleRec<K>> = (0..p)
        .map(|j| {
            let idx = (j * step).min(merged1.len().saturating_sub(1));
            SampleRec::new(merged1.get(idx).copied().unwrap_or(K::max_key()), pid, idx)
        })
        .collect();
    ctx.charge(p as f64);
    ctx.send(0, Payload::Recs(sample));
    ctx.sync("helman:gather-sample");
    let splitters = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        let seg = (all.len() / p).max(1);
        (1..p).map(|i| all[(i * seg - 1).min(all.len() - 1)]).collect()
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let splitters = broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, "helman:bcast");

    // Step 4 (round 2): partition the merged run, route, final merge.
    ctx.phase(PH4);
    let cuts = search::partition_points(&merged1, pid, &splitters);
    ctx.charge((p as f64 - 1.0) * ops::bsearch_charge(merged1.len().max(2)));

    ctx.phase(PH5);
    let parts: Vec<Vec<K>> = (0..p).map(|i| merged1[cuts[i]..cuts[i + 1]].to_vec()).collect();
    ctx.charge(ops::linear_charge(merged1.len()));
    let round2 = route_tagged(ctx, parts, "helman:round2");

    ctx.phase(PH6);
    let runs2: Vec<Vec<K>> = round2.into_iter().filter(|r| !r.is_empty()).collect();
    let received: usize = runs2.iter().map(|r| r.len()).sum();
    ctx.charge(ops::merge_charge(received, runs2.len().max(2)));
    let merged = crate::seq::multiway_merge(&runs2);

    ctx.phase(PH7);
    ctx.sync("helman:done");

    ProcResult { keys: merged, received, runs: runs2.len() }
}

/// The randomized algorithm of [40]: random sample → splitters → one
/// tagged data round → local sort of the received keys.
pub fn sort_helman_ran<K: RadixKey, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    mut local: Vec<K>,
    n_total: usize,
    cfg: &SortConfig,
    seed: u64,
) -> ProcResult<K> {
    let p = ctx.nprocs();
    let pid = ctx.pid();
    let sorter: Box<dyn SeqSorter<K>> = crate::seq::backend(cfg.seq);

    if p == 1 {
        ctx.phase(PH6);
        ctx.charge(sorter.charge(local.len()));
        sorter.sort(&mut local);
        return ProcResult { received: local.len(), runs: 1, keys: local };
    }

    // Sample: s = p·lg n keys per processor ([40] uses s = Θ(p lg n)).
    ctx.phase(PH3);
    let lgn = crate::util::lg(n_total as f64).max(1.0) as usize;
    let share = (p * lgn).min(local.len().max(1));
    let mut rng = SplitMix64::new(seed ^ ((pid as u64) << 16).wrapping_add(0x4040));
    let sample: Vec<SampleRec<K>> = if local.is_empty() {
        vec![SampleRec::new(K::max_key(), pid, 0)]
    } else {
        rng.sample_indices(local.len(), share)
            .into_iter()
            .map(|i| SampleRec::new(local[i], pid, i))
            .collect()
    };
    ctx.charge(share as f64);
    ctx.send(0, Payload::Recs(sample));
    ctx.sync("helmanr:gather");
    let splitters = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        let seg = (all.len() / p).max(1);
        (1..p).map(|i| all[(i * seg - 1).min(all.len() - 1)]).collect()
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let splitters = broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, "helmanr:bcast");

    // Bucket formation on the unsorted input + one tagged data round.
    ctx.phase(PH5);
    let mut buckets: Vec<Vec<K>> = vec![Vec::new(); p];
    for (i, &k) in local.iter().enumerate() {
        let me = (k, pid as u32, i as u32);
        let mut lo = 0usize;
        let mut hi = splitters.len();
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let s = &splitters[mid];
            if (s.key, s.proc, s.idx) <= me {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        buckets[lo].push(k);
    }
    ctx.charge(local.len() as f64 * (ops::bsearch_charge(p) + 3.0));
    let inbox = route_tagged(ctx, buckets, "helmanr:route");

    // Local sort of everything received.
    ctx.phase(PH6);
    let mut keys: Vec<K> = Vec::new();
    let mut nruns = 0usize;
    for r in inbox {
        if !r.is_empty() {
            nruns += 1;
        }
        keys.extend_from_slice(&r);
    }
    let received = keys.len();
    ctx.charge(sorter.charge(received));
    sorter.sort(&mut keys);

    ctx.phase(PH7);
    ctx.sync("helmanr:done");

    ProcResult { keys, received, runs: nruns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark, ALL_BENCHMARKS};

    fn check_sorted(p: usize, n: usize, bench: Benchmark, ran: bool) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            let input = local.clone();
            let out = if ran {
                sort_helman_ran(ctx, &params, local, n, &cfg, 21)
            } else {
                sort_helman_det(ctx, &params, local, &cfg)
            };
            (input, out)
        });
        let mut expect: Vec<i32> = run.outputs.iter().flat_map(|(i, _)| i.clone()).collect();
        expect.sort_unstable();
        let got: Vec<i32> = run.outputs.iter().flat_map(|(_, r)| r.keys.clone()).collect();
        assert_eq!(got, expect, "{} ran={ran}", bench.tag());
    }

    #[test]
    fn helman_det_sorts_every_benchmark() {
        for bench in ALL_BENCHMARKS {
            check_sorted(4, 1 << 12, bench, false);
        }
    }

    #[test]
    fn helman_ran_sorts_every_benchmark() {
        for bench in ALL_BENCHMARKS {
            check_sorted(4, 1 << 12, bench, true);
        }
    }

    #[test]
    fn helman_det_communicates_twice_as_much_as_dsr() {
        // The Table 8/9 structural claim: [39] routes the data twice AND
        // tags every key, so its total routed words exceed [DSR]'s by >2×.
        let p = 4usize;
        let n = 1 << 12;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();

        let helman_words: u64 = {
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
                sort_helman_det(ctx, &params, local, &cfg)
            });
            run.ledger
                .supersteps
                .iter()
                .filter(|s| s.label.starts_with("helman:round"))
                .map(|s| s.total_words)
                .sum()
        };
        let det_words: u64 = {
            let run = machine.run(|ctx| {
                let local = generate_for_proc(Benchmark::Uniform, ctx.pid(), p, n / p);
                crate::sort::det::sort_det_bsp(ctx, &params, local, n, &cfg)
            });
            run.ledger
                .supersteps
                .iter()
                .filter(|s| s.label.starts_with("ph5"))
                .map(|s| s.total_words)
                .sum()
        };
        assert!(
            helman_words as f64 >= 2.0 * det_words as f64,
            "helman={helman_words} det={det_words}"
        );
    }
}
