//! Comparator implementations from the literature (DESIGN.md §4.5):
//! Helman–JaJa–Bader deterministic [39] and randomized [40]/[41], and
//! PSRS [61]/[44].  Used by the Table 8/9/11 harnesses.

pub mod helman;
pub mod psrs;

pub use helman::{sort_helman_det, sort_helman_ran};
pub use psrs::sort_psrs;
