//! PSRS — Parallel Sorting by Regular Sampling (Shi & Schaeffer [61]),
//! as implemented directly in [44] and (equivalently) the deterministic
//! algorithm of [41].
//!
//! The un-oversampled ancestor of SORT_DET_BSP: each processor takes a
//! regular sample of exactly `p` keys (no oversampling factor), the
//! sample is gathered and sorted *sequentially* at processor 0, and no
//! duplicate tagging exists — the paper notes "the algorithm in [44] as
//! well as the algorithm in [41] can not handle duplicate keys", and the
//! \[WR\] adversary drives its bucket expansion toward the 2·n/p regular
//! sampling worst case.  Table 11 compares \[DSQ\] against this.

use crate::bsp::engine::BspScope;
use crate::bsp::msg::{Payload, SampleRec};
use crate::bsp::params::BspParams;
use crate::key::RadixKey;
use crate::primitives::broadcast;
use crate::seq::{ops, search, SeqSorter};

use super::super::sort::common::{ProcResult, PH2, PH3, PH4, PH5, PH6, PH7};
use super::super::sort::config::SortConfig;

/// Run PSRS on this processor's share of the input.  Generic over the
/// [`BspScope`], so it runs on either execution backend.
pub fn sort_psrs<K: RadixKey, S: BspScope<K>>(
    ctx: &mut S,
    params: &BspParams,
    mut local: Vec<K>,
    cfg: &SortConfig,
) -> ProcResult<K> {
    let p = ctx.nprocs();
    let pid = ctx.pid();
    let sorter: Box<dyn SeqSorter<K>> = crate::seq::backend(cfg.seq);

    // Phase 1: local sort.
    ctx.phase(PH2);
    ctx.charge(sorter.charge(local.len()));
    sorter.sort(&mut local);
    let keys = local;

    if p == 1 {
        return ProcResult { received: keys.len(), runs: 1, keys };
    }

    // Phase 2: regular sample of exactly p keys (positions 1, 1+n/p², …
    // in [61]'s formulation — evenly spaced block heads).
    ctx.phase(PH3);
    let n_local = keys.len();
    let step = (n_local / p).max(1);
    let sample: Vec<SampleRec<K>> = (0..p)
        .map(|j| {
            let idx = (j * step).min(n_local.saturating_sub(1));
            // NO duplicate tags: key-only records (proc/idx zeroed) —
            // this is exactly why PSRS breaks on duplicate-heavy input.
            SampleRec { key: keys.get(idx).copied().unwrap_or(K::max_key()), proc: 0, idx: 0 }
        })
        .collect();
    ctx.charge(p as f64);
    ctx.send(0, Payload::Recs(sample));
    ctx.sync("psrs:gather-sample");
    let splitters = if pid == 0 {
        let mut all: Vec<SampleRec<K>> = ctx
            .take_inbox()
            .into_iter()
            .flat_map(|(_, payload)| payload.into_recs())
            .collect();
        ctx.charge(ops::sort_charge(all.len()));
        all.sort();
        // p−1 splitters at positions p + ρ, 2p + ρ, … ([61] uses the
        // medians of the p² sample; evenly spaced is equivalent).
        (1..p).map(|i| all[i * p + p / 2 - 1]).collect()
    } else {
        ctx.take_inbox();
        Vec::new()
    };
    let splitters = broadcast::broadcast_recs(ctx, params, 0, splitters, p - 1, "psrs:bcast");

    // Phase 3: partition at the splitters (key-only comparison).
    ctx.phase(PH4);
    let mut cuts = Vec::with_capacity(p + 1);
    cuts.push(0usize);
    for s in &splitters {
        cuts.push(search::upper_bound(&keys, s.key));
    }
    cuts.push(keys.len());
    debug_assert!(cuts.windows(2).all(|w| w[0] <= w[1]));
    ctx.charge((p as f64 - 1.0) * ops::bsearch_charge(n_local.max(2)));

    // Phase 4: route + merge.
    ctx.phase(PH5);
    let parts: Vec<Payload<K>> = (0..p)
        .map(|i| Payload::Keys(keys[cuts[i]..cuts[i + 1]].to_vec()))
        .collect();
    ctx.charge(ops::linear_charge(n_local));
    let inbox = ctx.all_to_all(parts, "psrs:route");

    ctx.phase(PH6);
    let runs: Vec<Vec<K>> = inbox
        .into_iter()
        .map(|(_, payload)| payload.into_keys())
        .filter(|r| !r.is_empty())
        .collect();
    let received: usize = runs.iter().map(|r| r.len()).sum();
    ctx.charge(ops::merge_charge(received, runs.len().max(2)));
    let merged = crate::seq::multiway_merge(&runs);

    ctx.phase(PH7);
    ctx.sync("psrs:done");

    ProcResult { keys: merged, received, runs: runs.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;
    use crate::gen::{generate_for_proc, Benchmark};

    fn run_psrs(p: usize, n: usize, bench: Benchmark) -> (Vec<Vec<i32>>, Vec<ProcResult>) {
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let cfg = SortConfig::default();
        let run = machine.run(|ctx| {
            let local = generate_for_proc(bench, ctx.pid(), p, n / p);
            let input = local.clone();
            (input, sort_psrs(ctx, &params, local, &cfg))
        });
        let inputs = run.outputs.iter().map(|(i, _)| i.clone()).collect();
        let results = run.outputs.into_iter().map(|(_, r)| r).collect();
        (inputs, results)
    }

    #[test]
    fn sorts_distinct_key_benchmarks() {
        for bench in [Benchmark::Uniform, Benchmark::Gaussian, Benchmark::WorstRegular] {
            let (inputs, results) = run_psrs(4, 1 << 12, bench);
            let mut expect: Vec<i32> = inputs.iter().flatten().copied().collect();
            expect.sort_unstable();
            let got: Vec<i32> = results.iter().flat_map(|r| r.keys.clone()).collect();
            assert_eq!(got, expect, "{}", bench.tag());
        }
    }

    #[test]
    fn duplicates_still_sort_but_imbalance() {
        // PSRS has no tags: all-equal inputs sort correctly but pile onto
        // one processor — the deficiency Table 11 alludes to.
        let p = 4usize;
        let n = 1 << 10;
        let params = cray_t3d(p);
        let machine = BspMachine::new(params);
        let run = machine.run(|ctx| {
            let local = vec![9i32; n / p];
            sort_psrs(ctx, &params, local, &SortConfig::default())
        });
        let total: usize = run.outputs.iter().map(|r| r.keys.len()).sum();
        assert_eq!(total, n);
        let max_recv = run.outputs.iter().map(|r| r.received).max().unwrap();
        assert_eq!(max_recv, n, "PSRS collapses all-equal input onto one processor");
    }

    #[test]
    fn dd_imbalance_exceeds_det_bound() {
        // PSRS's missing duplicate handling is its Achilles heel (the
        // paper: "[44] ... can not handle duplicate keys"): on [DD] its
        // bucket expansion blows past SORT_DET_BSP's (1 + 1/⌈ω⌉) bound,
        // which the tagged DET algorithm never exceeds (det.rs tests).
        let p = 8usize;
        let n = 1 << 13;
        let (_, results) = run_psrs(p, n, Benchmark::DetDup);
        let max_recv = results.iter().map(|r| r.received).max().unwrap();
        let det_bound = crate::sort::det::nmax_bound(
            n,
            p,
            crate::sort::det::omega_det(&SortConfig::default(), n),
        );
        assert!(
            max_recv as f64 > det_bound,
            "expected PSRS [DD] imbalance {max_recv} above the DET bound {det_bound}"
        );
    }
}
