//! Superstep and phase cost ledger.
//!
//! Every `sync` records a [`SuperstepRecord`]: the max compute charge `x`
//! (in comparisons, per the paper's charging policy), the realized
//! h-relation, wall-clock, and the predicted BSP cost `max{L, x + g·h}`
//! under the machine's parameters.  Phase accounting (Ph1–Ph7 of
//! Tables 4–7) runs in parallel: compute charges and communication costs
//! are attributed to the phase active when they occur.
//!
//! During a run the engine tracks phases by *interned id* (no strings on
//! the charge hot path); the name-keyed records below are materialized
//! once, when `BspMachine::run` finalizes the ledger.

#![warn(missing_docs)]

use std::collections::BTreeMap;

use super::params::BspParams;

/// One superstep's accounting, reduced over all participating
/// processors.
#[derive(Clone, Debug, Default)]
pub struct SuperstepRecord {
    /// The `sync` label (SPMD discipline: identical on every processor).
    pub label: String,
    /// Name of the phase active at this superstep's `sync`.
    pub phase: String,
    /// max over processors of charged ops (comparisons).
    pub max_ops: f64,
    /// h-relation: max over processors of max(sent, received) words.
    pub h_words: u64,
    /// total words sent (sum over processors) — volume diagnostics.
    pub total_words: u64,
    /// max over processors of wall-clock since previous sync, µs.
    pub wall_us: f64,
    /// Processors that reported (for SPMD sanity checking).
    pub reporters: usize,
    /// Participating processors: the whole machine for global
    /// supersteps, the group size for group-scoped ones.
    pub procs: usize,
    /// `None` for a whole-machine superstep.  `Some(i)` marks a
    /// group-scoped superstep (`bsp::group`): records of *disjoint*
    /// groups that share the index `i` executed concurrently, so the
    /// ledger prices a round as the max over its sibling records, and
    /// each record is priced with its group-local effective machine
    /// ([`BspParams::scaled_to`]) rather than the full p.
    pub round: Option<usize>,
    /// EM-BSP block transfers: max over processors of blocks moved
    /// to/from the local store during this superstep.  Zero for every
    /// in-core superstep; only the out-of-core driver (`ext/`) records
    /// nonzero values, priced at `G_io` per block.
    pub io_blocks: u64,
}

impl SuperstepRecord {
    /// Predicted cost under `params`: `max{L, x + g·h} + G_io·b`, in µs
    /// (the EM-BSP `G·b` term is zero for in-core supersteps, which
    /// carry `io_blocks = 0`).
    ///
    /// Group-scoped records (`round.is_some()`) price against the
    /// group-local effective machine `params.scaled_to(procs)` — a
    /// group barrier synchronizes `procs < p` processors, so its
    /// latency floor is the smaller machine's L, not the full
    /// machine's.
    pub fn predicted_us(&self, params: &BspParams) -> f64 {
        let pricing = self.pricing_params(params);
        pricing.superstep_cost_us(self.max_ops, self.h_words) + pricing.io_us(self.io_blocks)
    }

    /// The parameters this record is priced with: `params` itself for
    /// whole-machine supersteps, the group-scaled view for group ones.
    pub fn pricing_params(&self, params: &BspParams) -> BspParams {
        if self.round.is_some() && self.procs > 0 {
            params.scaled_to(self.procs)
        } else {
            *params
        }
    }
}

/// Per-phase accumulation (max-over-processors semantics like supersteps).
#[derive(Clone, Debug, Default)]
pub struct PhaseRecord {
    /// max over processors of charged ops in this phase.
    pub max_ops: f64,
    /// sum of h-relations of supersteps whose sync fell in this phase.
    pub h_words: u64,
    /// number of supersteps ending in this phase.
    pub supersteps: usize,
    /// max over processors of wall time spent in the phase, µs.
    pub wall_us: f64,
    /// EM-BSP block transfers attributed to this phase (0 in-core).
    pub io_blocks: u64,
}

impl PhaseRecord {
    /// Predicted phase time: compute at the machine rate plus the
    /// communication (incl. L floors) of its supersteps, plus the
    /// EM-BSP `G_io·b` term for phases that touch the block store.
    pub fn predicted_us(&self, params: &BspParams) -> f64 {
        let comm = self.supersteps as f64 * params.l_us.max(0.0);
        // Each superstep floors at L; approximate the phase as
        // compute + max(L·steps, g·h) — h already summed across steps.
        let comm_gh = params.comm_us(self.h_words);
        params.comp_us(self.max_ops) + comm_gh.max(comm) + params.io_us(self.io_blocks)
    }
}

/// The full ledger of a BSP run.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    /// Every superstep in execution order.
    pub supersteps: Vec<SuperstepRecord>,
    /// Per-phase accumulation, keyed by phase name.
    pub phases: BTreeMap<String, PhaseRecord>,
    /// End-to-end wall time of the run (µs), measured by the driver.
    pub wall_us: f64,
}

/// The report convention for measured-vs-predicted quotients, in one
/// place: `measured / predicted` when the model prices the denominator,
/// `NaN` (serialized as `null`) when it prices it at zero.  Used by
/// [`Ledger::phase_comparison`] and the experiment runner's aggregated
/// records alike.
pub fn ratio_or_nan(measured: f64, predicted: f64) -> f64 {
    if predicted > 0.0 {
        measured / predicted
    } else {
        f64::NAN
    }
}

/// One row of the per-phase measured-vs-predicted comparison
/// ([`Ledger::phase_comparison`]) — the experiment reports' phase table.
#[derive(Clone, Debug)]
pub struct PhaseComparison {
    /// Phase name (Ph1–Ph7 in the sorting pipeline).
    pub phase: String,
    /// Predicted seconds under the pricing parameters.
    pub predicted_secs: f64,
    /// Measured wall seconds (max over processors).
    pub wall_secs: f64,
    /// `wall / predicted`; `NaN` when the model prices the phase at zero
    /// (e.g. Ph1 before any charge or sync) — serialized as `null`.
    pub ratio: f64,
}

impl Ledger {
    /// The concurrency-aware reduction shared by every total: sum the
    /// whole-machine records' `cost`, and for group-scoped records sum
    /// the per-`(round, phase)` *max* over siblings — disjoint groups
    /// sharing a round index ran concurrently, so their costs overlap
    /// instead of adding (the multi-level sorts' level-2 phases run one
    /// sort per group in parallel).
    ///
    /// The reduction keys on `(round, phase)` rather than the round
    /// alone, for two reasons.  Siblings of one round that are in the
    /// *same* phase genuinely overlap and max-reduce.  Siblings in
    /// *different* phases (uneven group sizes drift apart: a smaller
    /// group can already be routing while its sibling still sample-sorts
    /// at the same group-superstep index) are conservatively added —
    /// which aligns the totals with [`Ledger::phase_predicted_secs`],
    /// whose per-`(round, phase)` communication attribution cannot
    /// overlap across phases.  The old round-only keying silently
    /// assumed every round's siblings share a phase — an assumption a
    /// single-threaded backend (`bsp::sim`), which reports every record
    /// from one thread under virtual round indices, makes easy to
    /// violate and to regression-test (see
    /// `mixed_phase_rounds_price_consistently_from_one_thread`).
    fn fold_concurrent(&self, cost: impl Fn(&SuperstepRecord) -> f64) -> f64 {
        let mut total = 0.0;
        let mut rounds: BTreeMap<(usize, &str), f64> = BTreeMap::new();
        for s in &self.supersteps {
            let c = cost(s);
            match s.round {
                None => total += c,
                Some(r) => {
                    let e = rounds.entry((r, s.phase.as_str())).or_default();
                    *e = e.max(c);
                }
            }
        }
        total + rounds.values().sum::<f64>()
    }

    /// Total predicted time in µs: superstep costs reduced by
    /// [`Ledger::fold_concurrent`] (group records priced group-locally
    /// via [`SuperstepRecord::predicted_us`]).
    pub fn predicted_us(&self, params: &BspParams) -> f64 {
        self.fold_concurrent(|s| s.predicted_us(params))
    }

    /// Total predicted time in seconds.
    pub fn predicted_secs(&self, params: &BspParams) -> f64 {
        self.predicted_us(params) / 1e6
    }

    /// Predicted pure-computation time (µs): Σ x / rate, with
    /// concurrent group rounds max-reduced like [`Ledger::predicted_us`].
    pub fn predicted_comp_us(&self, params: &BspParams) -> f64 {
        self.fold_concurrent(|s| params.comp_us(s.max_ops))
    }

    /// Predicted pure-communication time (µs): Σ max{L, g·h} − comp? No —
    /// the paper separates computation and communication supersteps; we
    /// report Σ g·h plus the L floors of communication-dominated steps.
    pub fn predicted_comm_us(&self, params: &BspParams) -> f64 {
        self.predicted_us(params) - self.predicted_comp_us(params)
    }

    /// Total charged ops (max-per-superstep summed).
    pub fn total_ops(&self) -> f64 {
        self.supersteps.iter().map(|s| s.max_ops).sum()
    }

    /// Total h-relation volume (Σ per-superstep h).
    pub fn total_h(&self) -> u64 {
        self.supersteps.iter().map(|s| s.h_words).sum()
    }

    /// Per-phase predicted seconds, in phase-name order.
    ///
    /// Compute time is attributed to the phase active when the ops were
    /// *charged* (tracked per processor in `phases[].max_ops`), while a
    /// superstep's communication remainder — `max{L, x + g·h} − x/rate` —
    /// is attributed to the phase active at its `sync`.  This separation
    /// matters: a phase like Ph2 (local sort) charges heavily but never
    /// syncs; its compute must not leak into the next phase's superstep.
    pub fn phase_predicted_secs(&self, params: &BspParams) -> BTreeMap<String, f64> {
        let mut by_phase: BTreeMap<String, f64> = BTreeMap::new();
        // Concurrent group-round communication max-reduces per
        // (round, phase) before it is attributed — two sibling groups
        // routing at once cost one group's time, priced group-locally
        // (`SuperstepRecord::predicted_us` applies `scaled_to`).
        let mut round_comm: BTreeMap<(usize, String), f64> = BTreeMap::new();
        for s in &self.supersteps {
            let comm_us = (s.predicted_us(params) - params.comp_us(s.max_ops)).max(0.0);
            match s.round {
                None => *by_phase.entry(s.phase.clone()).or_default() += comm_us / 1e6,
                Some(r) => {
                    let e = round_comm.entry((r, s.phase.clone())).or_default();
                    *e = e.max(comm_us);
                }
            }
        }
        for ((_, phase), comm_us) in round_comm {
            *by_phase.entry(phase).or_default() += comm_us / 1e6;
        }
        for (name, rec) in &self.phases {
            if rec.max_ops > 0.0 {
                *by_phase.entry(name.clone()).or_default() +=
                    params.comp_us(rec.max_ops) / 1e6;
            }
        }
        by_phase
    }

    /// Measured wall seconds per phase.
    pub fn phase_wall_secs(&self) -> BTreeMap<String, f64> {
        self.phases
            .iter()
            .map(|(k, v)| (k.clone(), v.wall_us / 1e6))
            .collect()
    }

    /// Per-phase measured-vs-predicted rows under `params`, in phase-name
    /// order: the union of every phase the model prices
    /// ([`Ledger::phase_predicted_secs`]) and every phase wall-clock was
    /// attributed to.  When `params` comes from the host calibration
    /// (`experiment::calibrate`), `ratio` ≈ 1 is the paper's
    /// "the BSP model predicts the observed behavior" claim.
    pub fn phase_comparison(&self, params: &BspParams) -> Vec<PhaseComparison> {
        let predicted = self.phase_predicted_secs(params);
        let wall = self.phase_wall_secs();
        let mut names: Vec<&String> = predicted.keys().chain(wall.keys()).collect();
        names.sort();
        names.dedup();
        names
            .into_iter()
            .map(|name| {
                let p = predicted.get(name).copied().unwrap_or(0.0);
                let w = wall.get(name).copied().unwrap_or(0.0);
                PhaseComparison {
                    phase: name.clone(),
                    predicted_secs: p,
                    wall_secs: w,
                    ratio: ratio_or_nan(w, p),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    fn mk(label: &str, phase: &str, ops: f64, h: u64) -> SuperstepRecord {
        SuperstepRecord {
            label: label.into(),
            phase: phase.into(),
            max_ops: ops,
            h_words: h,
            total_words: h,
            wall_us: 1.0,
            reporters: 4,
            procs: 4,
            round: None,
            io_blocks: 0,
        }
    }

    fn mk_group(round: usize, phase: &str, ops: f64, h: u64, procs: usize) -> SuperstepRecord {
        SuperstepRecord {
            round: Some(round),
            procs,
            ..mk("group", phase, ops, h)
        }
    }

    #[test]
    fn predicted_cost_sums_supersteps() {
        let params = cray_t3d(16);
        let mut ledger = Ledger::default();
        ledger.supersteps.push(mk("a", "Ph2", 7_000_000.0, 0)); // 1e6 µs
        ledger.supersteps.push(mk("b", "Ph5", 0.0, 1_000_000)); // g·h = 210000 µs
        let t = ledger.predicted_us(&params);
        assert!((t - (1_000_000.0 + 210_000.0)).abs() < 1.0, "t={t}");
    }

    #[test]
    fn io_blocks_price_at_g_io_on_top_of_the_superstep_cost() {
        // An external superstep pays max{L, x + g·h} + G_io·b; in-core
        // records (b = 0) are untouched by the EM term.
        let params = cray_t3d(16); // L = 130, G_io = T3D synthetic
        let g_io = params.io_us_per_block;
        assert!(g_io > 0.0);
        let mut s = mk("ext:runform", "PhE1:RunForm", 0.0, 0);
        s.io_blocks = 10;
        assert!((s.predicted_us(&params) - (130.0 + 10.0 * g_io)).abs() < 1e-9);
        let in_core = mk("a", "Ph2", 7_000_000.0, 0);
        assert!((in_core.predicted_us(&params) - 1_000_000.0).abs() < 1.0);
        // Phase records carry the same term.
        let ph = PhaseRecord { max_ops: 0.0, h_words: 0, supersteps: 1, wall_us: 1.0, io_blocks: 4 };
        assert!((ph.predicted_us(&params) - (130.0 + 4.0 * g_io)).abs() < 1e-9);
    }

    #[test]
    fn l_floor_applies_to_empty_supersteps() {
        let params = cray_t3d(128);
        let mut ledger = Ledger::default();
        for _ in 0..3 {
            ledger.supersteps.push(mk("sync", "Ph4", 0.0, 0));
        }
        assert!((ledger.predicted_us(&params) - 3.0 * 762.0).abs() < 1e-9);
    }

    #[test]
    fn phase_breakdown_covers_all_supersteps() {
        let params = cray_t3d(16);
        let mut ledger = Ledger::default();
        ledger.supersteps.push(mk("a", "Ph2", 7000.0, 10));
        ledger.supersteps.push(mk("b", "Ph2", 7000.0, 10));
        ledger.supersteps.push(mk("c", "Ph5", 0.0, 500_000));
        // Mirror the per-phase compute the engine would have recorded.
        ledger.phases.insert(
            "Ph2".into(),
            PhaseRecord { max_ops: 14_000.0, h_words: 20, supersteps: 2, wall_us: 1.0, io_blocks: 0 },
        );
        ledger.phases.insert(
            "Ph5".into(),
            PhaseRecord { max_ops: 0.0, h_words: 500_000, supersteps: 1, wall_us: 1.0, io_blocks: 0 },
        );
        let by_phase = ledger.phase_predicted_secs(&params);
        let total: f64 = by_phase.values().sum();
        assert!(
            (total - ledger.predicted_secs(&params)).abs() < 1e-9,
            "total={total} predicted={}",
            ledger.predicted_secs(&params)
        );
        // Compute lands in Ph2, communication remainder in Ph5.
        assert!(by_phase["Ph2"] > by_phase["Ph5"] * 0.001);
    }

    #[test]
    fn group_rounds_are_priced_concurrently_with_group_local_l() {
        // Two sibling groups (p = 16 split 2×8) each run one empty
        // group superstep in the same round: the round costs ONE
        // group-local L floor — not two, and not the full machine's L.
        let params = cray_t3d(128); // L = 762 µs
        let mut ledger = Ledger::default();
        ledger.supersteps.push(mk_group(0, "L2/Ph4", 0.0, 0, 8));
        ledger.supersteps.push(mk_group(0, "L2/Ph4", 0.0, 0, 8));
        let scaled_l = params.scaled_to(8).l_us;
        assert!(scaled_l < params.l_us, "group L must shrink: {scaled_l}");
        let t = ledger.predicted_us(&params);
        assert!((t - scaled_l).abs() < 1e-9, "t={t} scaled_l={scaled_l}");
        // Distinct rounds add up again (they run one after the other).
        ledger.supersteps.push(mk_group(1, "L2/Ph5", 0.0, 0, 8));
        let t2 = ledger.predicted_us(&params);
        assert!((t2 - 2.0 * scaled_l).abs() < 1e-9, "t2={t2}");
    }

    #[test]
    fn group_phase_comm_max_reduces_per_round() {
        let params = cray_t3d(16);
        let mut ledger = Ledger::default();
        // One global routing step plus a concurrent pair of group
        // routing steps (round 0): the phase table shows the global
        // comm in Ph5 and only the larger sibling's comm in L2/Ph5.
        ledger.supersteps.push(mk("route", "Ph5", 0.0, 1_000_000));
        ledger.supersteps.push(mk_group(0, "L2/Ph5", 0.0, 400_000, 8));
        ledger.supersteps.push(mk_group(0, "L2/Ph5", 0.0, 500_000, 8));
        let by_phase = ledger.phase_predicted_secs(&params);
        let g = params.g_us_per_word;
        assert!((by_phase["Ph5"] - g * 1_000_000.0 / 1e6).abs() < 1e-9);
        let scaled = params.scaled_to(8);
        let expect = scaled.superstep_cost_us(0.0, 500_000) / 1e6;
        assert!(
            (by_phase["L2/Ph5"] - expect).abs() < 1e-12,
            "L2/Ph5={} expect={expect}",
            by_phase["L2/Ph5"]
        );
    }

    #[test]
    fn mixed_phase_rounds_price_consistently_from_one_thread() {
        // Regression for the single-thread (simulator) record shape:
        // every record arrives from one thread carrying *virtual* round
        // indices, interleaved with whole-machine records rather than
        // appended after them, and one round's siblings sit in
        // different phases (uneven groups drift apart).  The old
        // round-only max-reduction priced that round as one maximum,
        // while the phase table attributed both phases — the totals and
        // the phase breakdown disagreed.
        let params = cray_t3d(16);
        let mut ledger = Ledger::default();
        // Interleaved arrival order: global, sibling A, global, sibling B.
        ledger.supersteps.push(mk("g1", "Ph4", 0.0, 0));
        ledger.supersteps.push(mk_group(0, "L2/Ph4", 0.0, 200_000, 8));
        ledger.supersteps.push(mk("g2", "Ph4", 0.0, 0));
        ledger.supersteps.push(mk_group(0, "L2/Ph5", 0.0, 300_000, 8));
        let scaled = params.scaled_to(8);
        let expect_round = scaled.superstep_cost_us(0.0, 200_000)
            + scaled.superstep_cost_us(0.0, 300_000);
        let expect_total = 2.0 * params.l_us + expect_round;
        let t = ledger.predicted_us(&params);
        assert!(
            (t - expect_total).abs() < 1e-9,
            "mixed-phase siblings must add, not max-reduce: t={t} expect={expect_total}"
        );
        // The phase table attributes each sibling's communication to its
        // own phase, and the two views agree on the total.
        let by_phase = ledger.phase_predicted_secs(&params);
        let table_total: f64 = by_phase.values().sum::<f64>() * 1e6;
        assert!(
            (table_total - expect_total).abs() < 1e-9,
            "phase table {table_total} vs total {expect_total}"
        );
        assert!(by_phase["L2/Ph4"] > 0.0 && by_phase["L2/Ph5"] > 0.0);
        // Same-phase siblings still overlap (max-reduce), regardless of
        // where they sit in the record stream.
        ledger.supersteps.insert(1, mk_group(0, "L2/Ph4", 0.0, 150_000, 8));
        let t2 = ledger.predicted_us(&params);
        assert!(
            (t2 - expect_total).abs() < 1e-9,
            "a smaller same-phase sibling must be absorbed by the max: t2={t2}"
        );
        // And phase_comparison stays well-formed on this shape.
        for row in ledger.phase_comparison(&params) {
            assert!(row.predicted_secs >= 0.0 && row.wall_secs >= 0.0);
        }
    }

    #[test]
    fn phase_comparison_unions_priced_and_walled_phases() {
        let params = cray_t3d(16);
        let mut ledger = Ledger::default();
        ledger.supersteps.push(mk("a", "Ph5", 0.0, 1000));
        ledger.phases.insert(
            "Ph5".into(),
            PhaseRecord { max_ops: 0.0, h_words: 1000, supersteps: 1, wall_us: 500.0, io_blocks: 0 },
        );
        // A wall-only phase the model never priced (no ops, no sync).
        ledger.phases.insert(
            "Ph1:Init".into(),
            PhaseRecord { max_ops: 0.0, h_words: 0, supersteps: 0, wall_us: 3.0, io_blocks: 0 },
        );
        let rows = ledger.phase_comparison(&params);
        assert_eq!(rows.len(), 2);
        let ph1 = rows.iter().find(|r| r.phase == "Ph1:Init").unwrap();
        assert!(ph1.ratio.is_nan(), "unpriced phase must carry a NaN ratio");
        let ph5 = rows.iter().find(|r| r.phase == "Ph5").unwrap();
        assert!(ph5.predicted_secs > 0.0);
        let expect = ph5.wall_secs / ph5.predicted_secs;
        assert!((ph5.ratio - expect).abs() < 1e-12 && ph5.ratio > 0.0);
    }
}
