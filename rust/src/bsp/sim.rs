//! Deterministic single-process BSP simulator backend ([`SimMachine`]).
//!
//! The threaded engine (`bsp::engine`) executes SPMD programs *really*:
//! `p` OS threads, barriers, genuine contention.  That is the right
//! default for measurement, but it caps the testable `p` at what the
//! host can schedule and makes failures timing-dependent.  The simulator
//! runs the *same* SPMD programs against the same [`BspScope`] contract
//! with **virtual processors driven one at a time**: each virtual
//! processor is advanced to its next `sync` boundary, then the scheduler
//! delivers the staged mailboxes in sender-rank order and advances the
//! superstep.  There are no barriers and no concurrency anywhere in the
//! schedule — exactly one virtual processor is ever runnable, handed the
//! baton in ascending pid order — so a run is **bit-for-bit
//! deterministic** given its seeds, at any `p` (the conformance suite
//! drives every sort variant to `p = 1024`).
//!
//! Mechanically, each virtual processor's program frame lives on a
//! parked carrier thread that is used purely as a coroutine stack: a
//! carrier runs only while it holds the baton, parks at every `sync`,
//! and the commit of a superstep (performed by its last arriver) wakes
//! the lowest-pid participant next.  The OS never gets to make a
//! scheduling decision that is observable by the program.
//!
//! **Time is virtual.**  `charge` advances a per-processor virtual clock
//! at the machine's calibrated rate, a superstep boundary advances every
//! participant to `max(arrival clocks) + max{L, g·h}`, and all
//! `wall_us` fields of the resulting [`Ledger`](crate::bsp::Ledger) are virtual
//! microseconds — deterministic, replayable, and still shaped like a
//! real execution.  Charged-op and word accounting is byte-identical to
//! the threaded engine: the simulator fills the same ledger builder and
//! runs the same finalization (`bsp::engine::finalize_ledger`), which
//! the backend-equivalence test in `tests/conformance.rs` pins.
//!
//! **Fault/skew injection.**  [`SimMachine::with_skew`] installs seeded
//! per-processor virtual-time multipliers: processor `i` computes
//! `skew_i ∈ [1, 1 + max_skew]` times slower than the machine rate.
//! Charges (and therefore predictions) are untouched — only the virtual
//! wall clock stretches — so the ledger's measured-vs-predicted ratios
//! and per-phase imbalance can be exercised under controlled,
//! reproducible skew.
//!
//! Group story: [`SimCommunicator`] is the simulator's communicator —
//! the same validated [`GroupMap`] partition as the threaded
//! [`Communicator`](crate::bsp::group::Communicator), minus the barriers
//! (the scheduler itself synchronizes a group when all members arrive).
//! `SimCtx` implements [`GroupedScope`], so the two-level sorts
//! (`sort::multilevel`) run unmodified.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard};

use crate::key::Key;
use crate::util::rng::SplitMix64;

use super::engine::{finalize_ledger, BspRun, BspScope, LedgerBuilder, PhaseInterner};
use super::group::{next_comm_id, GroupMap, GroupPartition, GroupedScope};
use super::msg::Payload;
use super::params::BspParams;

/// Panic payload used by virtual processors halted because a *sibling*
/// panicked first; the machine re-raises the original cause instead.
const SECONDARY_HALT: &str = "SimMachine: halted after a sibling virtual processor panicked";

/// Seeded per-processor virtual-time skew: processor `i` runs its
/// compute `m_i ∈ [1, 1 + max_skew]` times slower than the machine
/// rate, with `m_i` drawn deterministically from `seed`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SkewSpec {
    /// Seed of the multiplier stream (one draw per processor).
    pub seed: u64,
    /// Upper bound of the extra slowdown; `0.0` disables skew.
    pub max_skew: f64,
}

/// The deterministic simulator machine: same parameters and run API as
/// `BspMachine`, single-threaded semantics, virtual time.
pub struct SimMachine {
    /// The machine parameters: `p` virtual processors, and the
    /// `(L, g, rate)` used both for pricing and for the virtual clock.
    pub params: BspParams,
    skew: Option<SkewSpec>,
}

impl SimMachine {
    /// A simulator for the given machine parameters, no skew.
    pub fn new(params: BspParams) -> SimMachine {
        SimMachine { params, skew: None }
    }

    /// Install seeded per-processor virtual-time multipliers.
    pub fn with_skew(mut self, skew: SkewSpec) -> SimMachine {
        self.skew = Some(skew);
        self
    }

    /// The per-processor virtual-time multipliers this machine runs
    /// with (all `1.0` without [`SimMachine::with_skew`]).
    pub fn skew_multipliers(&self) -> Vec<f64> {
        let p = self.params.p;
        match self.skew {
            None => vec![1.0; p],
            Some(s) => (0..p)
                .map(|pid| {
                    let mut rng = SplitMix64::new(
                        s.seed ^ (pid as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let u01 = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
                    1.0 + s.max_skew.max(0.0) * u01
                })
                .collect(),
        }
    }

    /// Execute `program` on `p` *virtual* processors with the default
    /// `i32` key domain; returns outputs in pid order plus the
    /// superstep/phase ledger (wall fields in virtual µs).
    pub fn run<T, F>(&self, program: F) -> BspRun<T>
    where
        T: Send,
        F: Fn(&mut SimCtx) -> T + Sync,
    {
        self.run_keys::<i32, T, F>(program)
    }

    /// As [`SimMachine::run`] with an explicit payload key domain `K` —
    /// the simulator twin of `BspMachine::run_keys`.
    pub fn run_keys<K, T, F>(&self, program: F) -> BspRun<T>
    where
        K: Key,
        T: Send,
        F: Fn(&mut SimCtx<K>) -> T + Sync,
    {
        let p = self.params.p;
        assert!(p >= 1, "a machine needs at least one processor");
        let world = SimWorld::<K> {
            p,
            params: self.params,
            skew: self.skew_multipliers(),
            phases: PhaseInterner::new(),
            parked: (0..p).map(|_| ParkSlot::new()).collect(),
            state: Mutex::new(SimState::new(p)),
        };
        let mut outputs: Vec<Option<T>> = (0..p).map(|_| None).collect();
        let mut panics: Vec<Box<dyn std::any::Any + Send>> = Vec::new();

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(p);
            for pid in 0..p {
                let world_ref = &world;
                let program_ref = &program;
                handles.push(scope.spawn(move || carrier(world_ref, program_ref, pid)));
            }
            // Hand the first baton to virtual processor 0; everything
            // after this is the deterministic cooperative schedule.
            world.parked[0].wake();
            for (pid, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(out) => outputs[pid] = Some(out),
                    Err(e) => panics.push(e),
                }
            }
        });

        if !panics.is_empty() {
            // Re-raise the original cause, not a secondary halt.
            let primary = panics
                .iter()
                .position(|e| e.downcast_ref::<&'static str>() != Some(&SECONDARY_HALT))
                .unwrap_or(0);
            resume_unwind(panics.swap_remove(primary));
        }

        let st = world.state.into_inner().unwrap_or_else(|e| e.into_inner());
        let names = world.phases.into_names();
        let ledger = finalize_ledger(st.builder, names, st.final_vt);
        BspRun {
            outputs: outputs.into_iter().map(|o| o.unwrap()).collect(),
            ledger,
        }
    }
}

/// One virtual processor's carrier-thread body: wait for the first
/// baton, run the program to completion, hand the baton on.
fn carrier<K, T, F>(world: &SimWorld<K>, program: &F, pid: usize) -> T
where
    K: Key,
    T: Send,
    F: Fn(&mut SimCtx<K>) -> T + Sync,
{
    world.parked[pid].wait();
    let result = catch_unwind(AssertUnwindSafe(|| {
        check_poison(world);
        let p = world.p;
        let mut ctx = SimCtx {
            pid,
            world,
            staged: (0..p).map(|_| Vec::new()).collect(),
            staged_dsts: Vec::new(),
            sent_words: 0,
            inbox: Vec::new(),
            superstep: 0,
            ops: 0.0,
            vt_us: 0.0,
            sync_vt: 0.0,
            phase_id: 0,
            phase_ops: vec![0.0],
            phase_vt: vec![0.0],
            phase_mark_vt: 0.0,
        };
        let out = program(&mut ctx);
        ctx.finish();
        out
    }));
    match result {
        Ok(out) => {
            retire(world, pid);
            out
        }
        Err(e) => {
            // Poison the machine so parked siblings halt instead of
            // waiting forever, then re-raise the original panic.
            poison_and_wake(
                world,
                format!("virtual processor {pid} panicked; see its panic message"),
            );
            resume_unwind(e);
        }
    }
}

/// Mark `pid` finished and pass the baton to the lowest runnable
/// processor; detect the structural SPMD violation where unfinished
/// processors remain but none can ever run again.
fn retire<K: Key>(world: &SimWorld<K>, pid: usize) {
    let mut st = world.lock_state();
    st.proc[pid] = ProcState::Finished;
    match next_runnable(&st) {
        Some(q) => {
            drop(st);
            world.parked[q].wake();
        }
        None => {
            if !st.proc.iter().all(|s| *s == ProcState::Finished) {
                let diag = describe_stall(&st, world.p);
                st.poison.get_or_insert(diag.clone());
                drop(st);
                wake_all(world);
                panic!("SPMD structural violation: {diag}");
            }
        }
    }
}

fn next_runnable<K: Key>(st: &SimState<K>) -> Option<usize> {
    st.proc.iter().position(|s| *s == ProcState::Runnable)
}

fn check_poison<K: Key>(world: &SimWorld<K>) {
    let st = world.lock_state();
    if st.poison.is_some() {
        drop(st);
        std::panic::panic_any(SECONDARY_HALT);
    }
}

fn poison_and_wake<K: Key>(world: &SimWorld<K>, msg: String) {
    {
        let mut st = world.lock_state();
        st.poison.get_or_insert(msg);
    }
    wake_all(world);
}

fn wake_all<K: Key>(world: &SimWorld<K>) {
    for slot in &world.parked {
        slot.wake();
    }
}

fn describe_stall<K: Key>(st: &SimState<K>, p: usize) -> String {
    let mut parts: Vec<String> = Vec::new();
    for (key, pend) in &st.pending {
        let arrived: Vec<usize> = pend.arrivals.iter().map(|a| a.pid).collect();
        let missing: Vec<usize> = pend
            .members
            .iter()
            .copied()
            .filter(|m| !arrived.contains(m))
            .collect();
        parts.push(format!(
            "sync {:?} (scope {key:?}) is waiting for processors {missing:?}",
            pend.label
        ));
    }
    if parts.is_empty() {
        parts.push(format!(
            "processors {:?} neither finished nor reached a sync",
            (0..p).filter(|&q| st.proc[q] != ProcState::Finished).collect::<Vec<_>>()
        ));
    }
    parts.join("; ")
}

/// A targeted wakeup slot: the baton.  `wake` never loses a wakeup even
/// when it lands before the matching `wait`.
struct ParkSlot {
    go: Mutex<bool>,
    cv: Condvar,
}

impl ParkSlot {
    fn new() -> ParkSlot {
        ParkSlot { go: Mutex::new(false), cv: Condvar::new() }
    }

    fn wake(&self) {
        let mut go = self.go.lock().unwrap_or_else(|e| e.into_inner());
        *go = true;
        self.cv.notify_one();
    }

    fn wait(&self) {
        let mut go = self.go.lock().unwrap_or_else(|e| e.into_inner());
        while !*go {
            go = self.cv.wait(go).unwrap_or_else(|e| e.into_inner());
        }
        *go = false;
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ProcState {
    /// May run when handed the baton (includes the current holder).
    Runnable,
    /// Parked at an incomplete sync.
    Blocked,
    /// Program returned.
    Finished,
}

/// Scope identity of a pending sync.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum ScopeKey {
    /// Whole-machine superstep.
    World,
    /// One group of a [`SimCommunicator`].
    Group { comm: usize, gidx: usize },
}

struct Arrival {
    pid: usize,
    ops: f64,
    sent_words: u64,
    wall_us: f64,
    vt: f64,
}

struct Pending {
    members: Vec<usize>,
    leader: usize,
    label: String,
    phase_id: usize,
    /// Global superstep index (world scope) or group superstep index
    /// (group scope) — the ledger key, read once at first arrival.
    superstep: usize,
    arrivals: Vec<Arrival>,
}

struct Delivery<K: Key> {
    inbox: Vec<(usize, Payload<K>)>,
    vt: f64,
}

struct SimState<K: Key> {
    proc: Vec<ProcState>,
    /// Staged payloads, `outbox[src][dst]`, moved in at each sender's
    /// sync arrival and drained at commit in src-ascending order — the
    /// simulator twin of the engine's slot matrix.
    outbox: Vec<Vec<Vec<Payload<K>>>>,
    /// Per-sender list of destinations whose `outbox[src][dst]` is
    /// currently non-empty.  Commit iterates these instead of all
    /// `members²` slot pairs, so an empty superstep at p = 1024 costs
    /// O(p), not O(p²) drains of empty vectors.
    pending_dsts: Vec<Vec<usize>>,
    /// Syncs awaiting arrivals, by scope.
    pending: BTreeMap<ScopeKey, Pending>,
    /// Per-processor inbox + clock to pick up when resuming from a
    /// committed sync.
    delivery: Vec<Option<Delivery<K>>>,
    /// The same accounting structure the threaded engine fills.
    builder: LedgerBuilder,
    /// Per-`(communicator, group)` superstep counters, advanced at each
    /// group commit (the simulator twin of the threaded communicator's
    /// leader-advanced counters).
    group_steps: BTreeMap<(usize, usize), usize>,
    /// First failure; parked processors halt on it instead of waiting.
    poison: Option<String>,
    /// Max final virtual clock over processors — the run's wall time.
    final_vt: f64,
}

impl<K: Key> SimState<K> {
    fn new(p: usize) -> SimState<K> {
        SimState {
            proc: vec![ProcState::Runnable; p],
            outbox: (0..p).map(|_| (0..p).map(|_| Vec::new()).collect()).collect(),
            pending_dsts: (0..p).map(|_| Vec::new()).collect(),
            pending: BTreeMap::new(),
            delivery: (0..p).map(|_| None).collect(),
            builder: LedgerBuilder::default(),
            group_steps: BTreeMap::new(),
            poison: None,
            final_vt: 0.0,
        }
    }
}

struct SimWorld<K: Key> {
    p: usize,
    params: BspParams,
    skew: Vec<f64>,
    phases: PhaseInterner,
    parked: Vec<ParkSlot>,
    state: Mutex<SimState<K>>,
}

impl<K: Key> SimWorld<K> {
    /// Lock the shared state, shrugging off mutex poisoning: the
    /// simulator's own `poison` flag governs failure propagation, and a
    /// panicking carrier must not wedge its siblings behind a
    /// `PoisonError`.
    fn lock_state(&self) -> MutexGuard<'_, SimState<K>> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Group-scope descriptor passed from [`SimGroupCtx::sync`] into the
/// shared sync path.
struct SimGroupScope<'a> {
    comm_id: usize,
    gidx: usize,
    members: &'a [usize],
    leader: usize,
}

/// Per-virtual-processor handle passed to the SPMD closure — the
/// simulator twin of `BspCtx`, implementing the same [`BspScope`]
/// contract (and [`GroupedScope`] via [`SimCommunicator`]).
pub struct SimCtx<'w, K: Key = i32> {
    pid: usize,
    world: &'w SimWorld<K>,
    /// Locally staged payloads by destination pid; moved into the shared
    /// outbox at the next sync (so `send` takes no lock at all).
    staged: Vec<Vec<Payload<K>>>,
    /// Destinations with non-empty `staged` entries, in first-send
    /// order — the sync arrival walks only these instead of all `p`.
    staged_dsts: Vec<usize>,
    sent_words: u64,
    inbox: Vec<(usize, Payload<K>)>,
    superstep: usize,
    ops: f64,
    /// This processor's virtual clock, µs.
    vt_us: f64,
    /// Virtual clock at the end of the last sync.
    sync_vt: f64,
    phase_id: usize,
    phase_ops: Vec<f64>,
    phase_vt: Vec<f64>,
    phase_mark_vt: f64,
}

impl<'w, K: Key> SimCtx<'w, K> {
    /// This virtual processor's identifier in `[0, nprocs)`.
    pub fn pid(&self) -> usize {
        self.pid
    }

    /// Number of virtual processors.
    pub fn nprocs(&self) -> usize {
        self.world.p
    }

    /// This processor's current virtual clock, µs (deterministic).
    pub fn virtual_now_us(&self) -> f64 {
        self.vt_us
    }

    /// Charge `ops` basic operations; advances the virtual clock by
    /// `ops / rate · skew_pid` µs.
    #[inline]
    pub fn charge(&mut self, ops: f64) {
        self.ops += ops;
        self.phase_ops[self.phase_id] += ops;
        self.vt_us += ops / self.world.params.comps_per_us * self.world.skew[self.pid];
    }

    /// Stage a message for `dst`; delivered at the next `sync`.
    #[inline]
    pub fn send(&mut self, dst: usize, payload: Payload<K>) {
        debug_assert!(dst < self.world.p, "send to invalid pid {dst}");
        self.sent_words += payload.words();
        if self.staged[dst].is_empty() {
            self.staged_dsts.push(dst);
        }
        self.staged[dst].push(payload);
    }

    /// Enter a named phase; virtual wall-clock and op charges accrue to
    /// the active phase exactly as on the threaded engine.
    pub fn phase(&mut self, name: &str) {
        let elapsed = self.vt_us - self.phase_mark_vt;
        self.phase_vt[self.phase_id] += elapsed;
        self.phase_mark_vt = self.vt_us;
        self.phase_id = self.world.phases.intern(name);
        if self.phase_ops.len() <= self.phase_id {
            self.phase_ops.resize(self.phase_id + 1, 0.0);
            self.phase_vt.resize(self.phase_id + 1, 0.0);
        }
    }

    /// Superstep boundary: park this virtual processor until every
    /// participant arrives, then pick up the sender-ordered inbox.
    pub fn sync(&mut self, label: &str) {
        self.sync_scoped(label, None);
    }

    /// The messages delivered at the last `sync`, ordered by sender id.
    pub fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)> {
        std::mem::take(&mut self.inbox)
    }

    /// Convenience: exchange one payload with every processor.
    pub fn all_to_all(&mut self, parts: Vec<Payload<K>>, label: &str) -> Vec<(usize, Payload<K>)> {
        assert_eq!(parts.len(), self.nprocs());
        for (dst, payload) in parts.into_iter().enumerate() {
            self.send(dst, payload);
        }
        self.sync(label);
        self.take_inbox()
    }

    /// Shared whole-machine / group-scoped sync path.
    fn sync_scoped(&mut self, label: &str, scope: Option<&SimGroupScope<'_>>) {
        let p = self.world.p;
        let arrival = Arrival {
            pid: self.pid,
            ops: self.ops,
            sent_words: self.sent_words,
            wall_us: self.vt_us - self.sync_vt,
            vt: self.vt_us,
        };

        let mut st = self.world.lock_state();
        // Move locally staged payloads into the shared outbox (append
        // keeps the local buffers' capacity for the next superstep);
        // only destinations actually sent to are touched.
        {
            let SimState { outbox, pending_dsts, .. } = &mut *st;
            for &dst in &self.staged_dsts {
                let staged = &mut self.staged[dst];
                if !staged.is_empty() {
                    let slot = &mut outbox[self.pid][dst];
                    if slot.is_empty() {
                        pending_dsts[self.pid].push(dst);
                    }
                    slot.append(staged);
                }
            }
        }
        self.staged_dsts.clear();

        let key = match scope {
            None => ScopeKey::World,
            Some(s) => ScopeKey::Group { comm: s.comm_id, gidx: s.gidx },
        };
        let scope_ids = scope.map(|s| (s.comm_id, s.gidx));
        let mismatch: Option<String> = {
            // Split-borrow the state so the group-step counters can seed
            // a fresh pending entry.
            let SimState { pending, group_steps, .. } = &mut *st;
            let pend = pending.entry(key).or_insert_with(|| Pending {
                members: match scope {
                    None => (0..p).collect(),
                    Some(s) => s.members.to_vec(),
                },
                leader: match scope {
                    None => 0,
                    Some(s) => s.leader,
                },
                label: label.to_string(),
                phase_id: self.phase_id,
                superstep: match scope_ids {
                    None => self.superstep,
                    Some(ids) => group_steps.get(&ids).copied().unwrap_or(0),
                },
                arrivals: Vec::new(),
            });
            debug_assert!(
                pend.members.contains(&self.pid),
                "processor {} synced a scope it is not a member of",
                self.pid
            );
            if pend.label != label {
                Some(format!(
                    "superstep {}: processor {} reported label {:?}, \
                     another processor reported {:?}",
                    pend.superstep, self.pid, label, pend.label
                ))
            } else {
                pend.arrivals.push(arrival);
                None
            }
        };
        if let Some(msg) = mismatch {
            let full = format!("SPMD sync label mismatch: {msg}");
            st.poison.get_or_insert(full.clone());
            drop(st);
            wake_all(self.world);
            panic!("{full}");
        }

        let complete =
            st.pending[&key].arrivals.len() == st.pending[&key].members.len();
        if complete {
            let pend = st.pending.remove(&key).expect("pending sync present");
            commit(self.world, &mut st, scope_ids, pend);
        } else {
            st.proc[self.pid] = ProcState::Blocked;
        }

        match next_runnable(&st) {
            Some(q) if q == self.pid => {
                let d = st.delivery[self.pid].take().expect("delivery for resumed processor");
                drop(st);
                self.absorb(d, scope.is_none());
            }
            Some(q) => {
                drop(st);
                self.world.parked[q].wake();
                self.world.parked[self.pid].wait();
                let mut st = self.world.lock_state();
                if st.poison.is_some() {
                    drop(st);
                    std::panic::panic_any(SECONDARY_HALT);
                }
                let d = st.delivery[self.pid].take().expect("delivery for resumed processor");
                drop(st);
                self.absorb(d, scope.is_none());
            }
            None => {
                let diag = describe_stall(&st, p);
                st.poison.get_or_insert(diag.clone());
                drop(st);
                wake_all(self.world);
                panic!("SPMD structural violation: {diag}");
            }
        }
    }

    /// Pick up a committed sync's delivery: inbox, advanced clock, and
    /// per-superstep counter resets.
    fn absorb(&mut self, d: Delivery<K>, whole_machine: bool) {
        self.inbox = d.inbox;
        self.vt_us = d.vt;
        self.sync_vt = d.vt;
        self.ops = 0.0;
        self.sent_words = 0;
        if whole_machine {
            self.superstep += 1;
        }
    }

    /// Flush end-of-run phase accounting into the shared builder
    /// (virtual-time twin of the engine's per-thread `finish`).
    fn finish(&mut self) {
        let elapsed = self.vt_us - self.phase_mark_vt;
        self.phase_vt[self.phase_id] += elapsed;
        self.phase_mark_vt = self.vt_us;
        let mut st = self.world.lock_state();
        st.final_vt = st.final_vt.max(self.vt_us);
        let builder = &mut st.builder;
        if builder.phases.len() < self.phase_ops.len() {
            builder.phases.resize_with(self.phase_ops.len(), Default::default);
        }
        for (id, (&ops, &vt)) in self.phase_ops.iter().zip(self.phase_vt.iter()).enumerate() {
            let rec = &mut builder.phases[id];
            rec.max_ops = rec.max_ops.max(ops);
            rec.wall_us = rec.wall_us.max(vt);
        }
    }
}

/// Commit one superstep: assemble every member's inbox in sender order,
/// reduce the ledger record, advance all participants' virtual clocks
/// to `max(arrivals) + max{L_scope, g·h}`, and mark them runnable.
fn commit<K: Key>(
    world: &SimWorld<K>,
    st: &mut SimState<K>,
    scope: Option<(usize, usize)>,
    pend: Pending,
) {
    let mut max_ops = 0.0f64;
    let mut total_words = 0u64;
    let mut wall_max = 0.0f64;
    let mut vt_max = 0.0f64;
    for a in &pend.arrivals {
        max_ops = max_ops.max(a.ops);
        total_words += a.sent_words;
        wall_max = wall_max.max(a.wall_us);
        vt_max = vt_max.max(a.vt);
    }

    // Per-member inbox assembly, walking only the non-empty sender
    // slots (`pending_dsts`) in member-ascending sender order, so the
    // per-destination inboxes come out sender-ordered and an empty
    // superstep at p = 1024 costs O(p) rather than O(p²) empty drains.
    let m = pend.members.len();
    let mut sent = vec![0u64; m];
    for a in &pend.arrivals {
        if let Ok(i) = pend.members.binary_search(&a.pid) {
            sent[i] = a.sent_words;
        }
    }
    let mut inbox_for: Vec<Vec<(usize, Payload<K>)>> = (0..m).map(|_| Vec::new()).collect();
    {
        let SimState { outbox, pending_dsts, .. } = &mut *st;
        for &src in &pend.members {
            let dsts = std::mem::take(&mut pending_dsts[src]);
            for dst in dsts {
                match pend.members.binary_search(&dst) {
                    Ok(i) => {
                        for payload in outbox[src][dst].drain(..) {
                            inbox_for[i].push((src, payload));
                        }
                    }
                    // A slot addressed outside this scope (only possible
                    // when the group communication discipline is
                    // violated): leave it staged, and keep tracking it.
                    Err(_) => pending_dsts[src].push(dst),
                }
            }
        }
    }

    // The h-relation: max over members of max(sent, received) words —
    // identical to the threaded engine.
    let mut h_words = 0u64;
    for (i, inbox) in inbox_for.iter().enumerate() {
        let recv: u64 = inbox.iter().map(|(_, p)| p.words()).sum();
        h_words = h_words.max(sent[i].max(recv));
    }

    // Virtual clock advance: every member resumes at the superstep's
    // end, `max(arrival clocks) + max{L_scope, g·h}` — the group-local
    // effective machine prices a group barrier, like the ledger does.
    let pricing = match scope {
        None => world.params,
        Some(_) => world.params.scaled_to(pend.members.len()),
    };
    let comm_us = (pricing.g_us_per_word * h_words as f64).max(pricing.l_us.max(0.0));
    let end_vt = vt_max + comm_us;
    for (&dst, inbox) in pend.members.iter().zip(inbox_for) {
        st.delivery[dst] = Some(Delivery { inbox, vt: end_vt });
        st.proc[dst] = ProcState::Runnable;
    }

    // Ledger record — the same builder slots the threaded engine fills.
    let builder = &mut st.builder;
    if builder.phases.len() <= pend.phase_id {
        builder.phases.resize_with(pend.phase_id + 1, Default::default);
    }
    let rec = match scope {
        None => {
            if builder.supersteps.len() <= pend.superstep {
                builder.supersteps.resize_with(pend.superstep + 1, Default::default);
            }
            &mut builder.supersteps[pend.superstep]
        }
        Some((comm, _gidx)) => builder
            .group_steps
            .entry((comm, pend.superstep, pend.leader))
            .or_default(),
    };
    rec.label = pend.label.clone();
    rec.phase_id = pend.phase_id;
    rec.procs = pend.members.len();
    rec.reporters = pend.arrivals.len();
    rec.max_ops = max_ops;
    rec.h_words = h_words;
    rec.total_words = total_words;
    rec.wall_us = wall_max;
    builder.phases[pend.phase_id].supersteps += 1;

    // Advance the group's superstep counter (the simulator twin of the
    // threaded communicator's leader-advanced counter).
    if let Some(ids) = scope {
        *st.group_steps.entry(ids).or_insert(0) += 1;
    }
}

impl<K: Key> BspScope<K> for SimCtx<'_, K> {
    fn pid(&self) -> usize {
        SimCtx::pid(self)
    }
    fn nprocs(&self) -> usize {
        SimCtx::nprocs(self)
    }
    fn charge(&mut self, ops: f64) {
        SimCtx::charge(self, ops)
    }
    fn phase(&mut self, name: &str) {
        SimCtx::phase(self, name)
    }
    fn send(&mut self, dst: usize, payload: Payload<K>) {
        SimCtx::send(self, dst, payload)
    }
    fn sync(&mut self, label: &str) {
        SimCtx::sync(self, label)
    }
    fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)> {
        SimCtx::take_inbox(self)
    }
    fn all_to_all(&mut self, parts: Vec<Payload<K>>, label: &str) -> Vec<(usize, Payload<K>)> {
        SimCtx::all_to_all(self, parts, label)
    }
}

/// The simulator's communicator: the same validated partition as the
/// threaded `Communicator`, with no barriers — the scheduler itself
/// synchronizes a group when all members arrive at its sync.
pub struct SimCommunicator {
    id: usize,
    map: GroupMap,
}

impl SimCommunicator {
    /// Split `p` virtual processors into contiguous near-even groups
    /// ([`GroupMap::split_even`]).
    pub fn split_even(p: usize, num_groups: usize) -> SimCommunicator {
        SimCommunicator::from_map(GroupMap::split_even(p, num_groups))
    }

    /// Build from explicit member lists ([`GroupMap::from_groups`]
    /// validation applies).
    pub fn from_groups(groups: Vec<Vec<usize>>) -> SimCommunicator {
        SimCommunicator::from_map(GroupMap::from_groups(groups))
    }

    /// Wrap a validated partition.
    pub fn from_map(map: GroupMap) -> SimCommunicator {
        SimCommunicator { id: next_comm_id(), map }
    }

    /// Enter this processor's group: wrap `ctx` into a group-scoped
    /// [`BspScope`].  `phase_prefix` is prepended to phase labels
    /// entered through the group context (`sort::multilevel` passes
    /// `"L2/"`); pass `""` to keep labels unchanged.
    pub fn enter<'c, 'w, K: Key>(
        &'c self,
        ctx: &'c mut SimCtx<'w, K>,
        phase_prefix: &str,
    ) -> SimGroupCtx<'c, 'w, K> {
        let pid = SimCtx::pid(ctx);
        assert!(
            pid < self.map.nprocs(),
            "pid {pid} outside the communicator's {} processors",
            self.map.nprocs()
        );
        SimGroupCtx {
            group: self.map.group_of(pid),
            rank: self.map.rank_of(pid),
            prefix: phase_prefix.to_string(),
            comm: self,
            ctx,
        }
    }
}

impl GroupPartition for SimCommunicator {
    fn split_even(p: usize, num_groups: usize) -> SimCommunicator {
        SimCommunicator::split_even(p, num_groups)
    }

    fn from_map(map: GroupMap) -> SimCommunicator {
        SimCommunicator::from_map(map)
    }

    fn map(&self) -> &GroupMap {
        &self.map
    }
}

/// A group-scoped [`BspScope`] over the simulator — the twin of the
/// threaded `GroupCtx`: ranks, phase prefixes and message delivery all
/// restricted to one group of a [`SimCommunicator`].
pub struct SimGroupCtx<'c, 'w, K: Key> {
    comm: &'c SimCommunicator,
    group: usize,
    rank: usize,
    prefix: String,
    ctx: &'c mut SimCtx<'w, K>,
}

impl<K: Key> SimGroupCtx<'_, '_, K> {
    /// This processor's global pid (its rank is [`BspScope::pid`]).
    pub fn global_pid(&self) -> usize {
        SimCtx::pid(self.ctx)
    }

    /// The index of the group this context is scoped to.
    pub fn group_index(&self) -> usize {
        self.group
    }
}

impl<K: Key> BspScope<K> for SimGroupCtx<'_, '_, K> {
    fn pid(&self) -> usize {
        self.rank
    }

    fn nprocs(&self) -> usize {
        self.comm.map.group_size(self.group)
    }

    fn charge(&mut self, ops: f64) {
        self.ctx.charge(ops);
    }

    fn phase(&mut self, name: &str) {
        if self.prefix.is_empty() {
            self.ctx.phase(name);
        } else {
            self.ctx.phase(&format!("{}{}", self.prefix, name));
        }
    }

    fn send(&mut self, dst: usize, payload: Payload<K>) {
        let members = self.comm.map.members(self.group);
        debug_assert!(dst < members.len(), "group send to invalid rank {dst}");
        self.ctx.send(members[dst], payload);
    }

    fn sync(&mut self, label: &str) {
        let members = self.comm.map.members(self.group);
        let scope = SimGroupScope {
            comm_id: self.comm.id,
            gidx: self.group,
            members,
            leader: members[0],
        };
        self.ctx.sync_scoped(label, Some(&scope));
    }

    fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)> {
        // Group commits only deliver member-written payloads, so the
        // global sender pid always maps to a group rank.
        self.ctx
            .take_inbox()
            .into_iter()
            .map(|(src, payload)| (self.comm.map.rank_of(src), payload))
            .collect()
    }
}

impl<'w, K: Key> GroupedScope<K> for SimCtx<'w, K> {
    type Comm = SimCommunicator;
    type Group<'a>
        = SimGroupCtx<'a, 'w, K>
    where
        Self: 'a;

    fn enter_group<'a>(
        &'a mut self,
        comm: &'a SimCommunicator,
        phase_prefix: &str,
    ) -> SimGroupCtx<'a, 'w, K> {
        comm.enter(self, phase_prefix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    fn machine(p: usize) -> SimMachine {
        SimMachine::new(cray_t3d(p))
    }

    #[test]
    fn pid_and_nprocs() {
        let run = machine(4).run(|ctx| (ctx.pid(), ctx.nprocs()));
        assert_eq!(run.outputs, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn single_processor_machine_runs() {
        let run = machine(1).run(|ctx| {
            ctx.send(0, Payload::Keys(vec![7i32]));
            ctx.sync("self");
            ctx.take_inbox().pop().unwrap().1.into_keys()[0]
        });
        assert_eq!(run.outputs, vec![7]);
    }

    #[test]
    fn ring_exchange_delivers_in_sender_order() {
        let run = machine(8).run(|ctx| {
            let p = ctx.nprocs();
            let dst = (ctx.pid() + 1) % p;
            ctx.send(dst, Payload::Keys(vec![ctx.pid() as i32]));
            ctx.sync("ring");
            let inbox = ctx.take_inbox();
            assert_eq!(inbox.len(), 1);
            let (src, payload) = &inbox[0];
            (*src, payload.clone().into_keys()[0])
        });
        for (pid, (src, val)) in run.outputs.iter().enumerate() {
            let expect = (pid + 8 - 1) % 8;
            assert_eq!(*src, expect);
            assert_eq!(*val, expect as i32);
        }
    }

    #[test]
    fn all_to_all_is_complete_and_ordered() {
        let run = machine(5).run(|ctx| {
            let parts = (0..5)
                .map(|dst| Payload::Keys(vec![(ctx.pid() * 10 + dst) as i32]))
                .collect();
            let recv = ctx.all_to_all(parts, "a2a");
            recv.into_iter()
                .map(|(src, p)| (src, p.into_keys()[0]))
                .collect::<Vec<_>>()
        });
        for (pid, inbox) in run.outputs.iter().enumerate() {
            assert_eq!(inbox.len(), 5);
            for (i, (src, val)) in inbox.iter().enumerate() {
                assert_eq!(*src, i, "inbox must be sorted by sender");
                assert_eq!(*val as usize, i * 10 + pid);
            }
        }
    }

    #[test]
    fn multiple_sends_to_one_dst_keep_order() {
        let run = machine(3).run(|ctx| {
            ctx.send(0, Payload::Keys(vec![ctx.pid() as i32]));
            ctx.send(0, Payload::U64s(vec![ctx.pid() as u64 + 100]));
            ctx.sync("pairs");
            ctx.take_inbox()
        });
        let inbox = &run.outputs[0];
        assert_eq!(inbox.len(), 6);
        for src in 0..3usize {
            let (s0, first) = &inbox[2 * src];
            let (s1, second) = &inbox[2 * src + 1];
            assert_eq!((*s0, *s1), (src, src));
            assert!(matches!(first, Payload::Keys(v) if v[0] == src as i32));
            assert!(matches!(second, Payload::U64s(v) if v[0] == src as u64 + 100));
        }
    }

    #[test]
    fn ledger_records_match_engine_semantics() {
        let run = machine(4).run(|ctx| {
            ctx.send(0, Payload::Keys(vec![1; 100]));
            ctx.sync("fan-in");
            ctx.take_inbox().len()
        });
        assert_eq!(run.ledger.supersteps.len(), 1);
        let s = &run.ledger.supersteps[0];
        assert_eq!(s.h_words, 400);
        assert_eq!(s.total_words, 400);
        assert_eq!(s.reporters, 4);
        assert_eq!(s.procs, 4);
    }

    #[test]
    fn charges_are_max_reduced_and_phases_attributed() {
        let run = machine(4).run(|ctx| {
            ctx.phase("Ph2:SeqSort");
            ctx.charge((ctx.pid() as f64 + 1.0) * 1000.0);
            ctx.sync("compute");
        });
        assert_eq!(run.ledger.supersteps[0].max_ops, 4000.0);
        assert_eq!(run.ledger.phases["Ph2:SeqSort"].max_ops, 4000.0);
    }

    #[test]
    fn predicted_cost_uses_machine_params() {
        let m = SimMachine::new(cray_t3d(16));
        let run = m.run(|ctx| {
            ctx.charge(7_000.0);
            ctx.sync("c");
        });
        let us = run.ledger.predicted_us(&m.params);
        assert!((us - 1000.0).abs() < 1e-9, "us={us}");
    }

    #[test]
    fn empty_superstep_floors_at_l_in_virtual_time_too() {
        let m = SimMachine::new(cray_t3d(128));
        let run = m.run(|ctx| ctx.sync("noop"));
        assert_eq!(run.ledger.predicted_us(&m.params), 762.0);
        // The virtual clock paid the barrier latency as well.
        assert!((run.ledger.wall_us - 762.0).abs() < 1e-9, "{}", run.ledger.wall_us);
    }

    #[test]
    fn runs_are_bit_for_bit_deterministic() {
        let once = || {
            machine(8).run(|ctx| {
                let p = ctx.nprocs();
                let mut acc: u64 = ctx.pid() as u64;
                for round in 0..4u64 {
                    let parts = (0..p)
                        .map(|dst| Payload::U64s(vec![acc + round + dst as u64]))
                        .collect();
                    let inbox = ctx.all_to_all(parts, "mix");
                    acc = inbox.into_iter().map(|(_, pl)| pl.into_u64s()[0]).sum();
                    ctx.charge(acc as f64 % 97.0);
                }
                acc
            })
        };
        let a = once();
        let b = once();
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.ledger.wall_us, b.ledger.wall_us);
        assert_eq!(a.ledger.supersteps.len(), b.ledger.supersteps.len());
        for (x, y) in a.ledger.supersteps.iter().zip(&b.ledger.supersteps) {
            assert_eq!(x.max_ops, y.max_ops);
            assert_eq!(x.h_words, y.h_words);
            assert_eq!(x.wall_us, y.wall_us, "virtual wall must be deterministic");
        }
    }

    #[test]
    fn skew_stretches_virtual_wall_but_not_charges() {
        let program = |ctx: &mut SimCtx| {
            ctx.charge(7_000.0);
            ctx.sync("c");
        };
        let plain = SimMachine::new(cray_t3d(16)).run(program);
        let skewed = SimMachine::new(cray_t3d(16))
            .with_skew(SkewSpec { seed: 0xBAD5EED, max_skew: 1.0 })
            .run(program);
        assert_eq!(
            plain.ledger.supersteps[0].max_ops,
            skewed.ledger.supersteps[0].max_ops,
            "skew must not alter charges"
        );
        assert!(
            skewed.ledger.wall_us > plain.ledger.wall_us,
            "skewed {} vs plain {}",
            skewed.ledger.wall_us,
            plain.ledger.wall_us
        );
        // Multipliers are a pure function of the seed.
        let m1 = SimMachine::new(cray_t3d(16))
            .with_skew(SkewSpec { seed: 42, max_skew: 0.5 })
            .skew_multipliers();
        let m2 = SimMachine::new(cray_t3d(16))
            .with_skew(SkewSpec { seed: 42, max_skew: 0.5 })
            .skew_multipliers();
        assert_eq!(m1, m2);
        assert!(m1.iter().all(|&m| (1.0..=1.5).contains(&m)));
        assert!(m1.iter().any(|&m| m > 1.0));
    }

    #[test]
    fn group_all_to_all_stays_group_local_with_group_records() {
        let comm = SimCommunicator::split_even(8, 2);
        let run = machine(8).run(|ctx| {
            ctx.sync("global");
            let mut g = comm.enter(ctx, "L2/");
            g.phase("Ph5:Routing");
            let me = g.pid();
            let group = g.group_index();
            let parts = (0..g.nprocs())
                .map(|dst| Payload::Keys(vec![(group * 100 + me * 10 + dst) as i32]))
                .collect();
            let inbox = g.all_to_all(parts, "l2:route");
            g.sync("l2:done");
            inbox
                .into_iter()
                .map(|(src, p)| (src, p.into_keys()[0]))
                .collect::<Vec<_>>()
        });
        for (pid, inbox) in run.outputs.iter().enumerate() {
            let (group, rank) = (pid / 4, pid % 4);
            assert_eq!(inbox.len(), 4, "pid={pid}");
            for (i, &(src, val)) in inbox.iter().enumerate() {
                assert_eq!(src, i, "inbox must be rank-ordered");
                assert_eq!(val as usize, group * 100 + src * 10 + rank);
            }
        }
        let global: Vec<_> =
            run.ledger.supersteps.iter().filter(|s| s.round.is_none()).collect();
        assert_eq!(global.len(), 1);
        assert_eq!(global[0].procs, 8);
        let grouped: Vec<_> =
            run.ledger.supersteps.iter().filter(|s| s.round.is_some()).collect();
        assert_eq!(grouped.len(), 4, "2 group supersteps x 2 groups");
        assert!(grouped.iter().all(|s| s.procs == 4 && s.reporters == 4));
        let routes: Vec<_> = grouped.iter().filter(|s| s.label == "l2:route").collect();
        assert_eq!(routes.len(), 2);
        for s in &routes {
            assert_eq!(s.phase, "L2/Ph5:Routing");
            assert_eq!(s.h_words, 4);
            assert_eq!(s.total_words, 16);
        }
    }

    #[test]
    fn stalled_sibling_group_does_not_block_group_syncs() {
        // Group 0 supersteps on its own while group 1 only computes —
        // group syncs must not involve non-members.
        let comm = SimCommunicator::split_even(8, 2);
        let run = machine(8).run(|ctx| {
            let pid = ctx.pid();
            if pid < 4 {
                let mut g = comm.enter(ctx, "");
                let mut sum = 0i32;
                for round in 0..3 {
                    let dst = (g.pid() + 1) % g.nprocs();
                    g.send(dst, Payload::Keys(vec![round as i32 + g.pid() as i32]));
                    g.sync("ring");
                    sum += g.take_inbox().pop().unwrap().1.into_keys()[0];
                }
                sum
            } else {
                (0..1000).sum::<i32>() % 7
            }
        });
        for (pid, &out) in run.outputs.iter().enumerate() {
            if pid < 4 {
                let prev = (pid + 4 - 1) % 4;
                let expect: i32 = (0..3).map(|r| r + prev as i32).sum();
                assert_eq!(out, expect, "pid={pid}");
            }
        }
    }

    #[test]
    fn large_p_smoke_p256() {
        // The point of the simulator: p far beyond sensible thread
        // counts, still exact and deterministic.
        let p = 256usize;
        let run = machine(p).run(|ctx| {
            let dst = (ctx.pid() + 1) % p;
            ctx.send(dst, Payload::U64s(vec![ctx.pid() as u64]));
            ctx.sync("big-ring");
            ctx.take_inbox().pop().unwrap().1.into_u64s()[0]
        });
        for (pid, &got) in run.outputs.iter().enumerate() {
            assert_eq!(got as usize, (pid + p - 1) % p);
        }
        assert_eq!(run.ledger.supersteps[0].reporters, p);
    }

    #[test]
    #[should_panic(expected = "SPMD sync label mismatch")]
    fn spmd_label_mismatch_is_detected() {
        machine(2).run(|ctx| {
            let label = if ctx.pid() == 0 { "left" } else { "right" };
            ctx.sync(label);
        });
    }

    #[test]
    #[should_panic(expected = "SPMD structural violation")]
    fn missing_sync_participant_is_detected() {
        machine(2).run(|ctx| {
            if ctx.pid() == 0 {
                ctx.sync("lonely");
            }
        });
    }

    #[test]
    #[should_panic(expected = "deliberate test panic")]
    fn program_panic_propagates_as_the_primary_cause() {
        machine(4).run(|ctx| {
            ctx.sync("s1");
            if ctx.pid() == 2 {
                panic!("deliberate test panic");
            }
            ctx.sync("s2");
        });
    }
}
