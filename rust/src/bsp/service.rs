//! Sort-as-a-service: the persistent engine pool behind the unified
//! [`crate::sorter::Sorter`] façade.
//!
//! The one-shot path (`BspMachine::run_keys`, now deprecated) spins up
//! `p` OS threads per sort and tears them down again — fine for one
//! experiment run, hostile to the ROADMAP's serving scenario.  An
//! [`Engine`] instead keeps a persistent SPMD worker team: `crews` crews
//! of `p` parked lanes each, woken per job, with the p×p slot-matrix
//! buffers of finished jobs recycled into the next job of the same key
//! domain (the scratch pool).
//!
//! Job lifecycle (ARCHITECTURE.md, "Engine pool & job lifecycle"):
//!
//! 1. **Queue** — `submit` pushes a type-erased job onto a bounded FIFO.
//! 2. **Admission** — beyond `queue_depth` pending jobs a submission is
//!    rejected with [`RuntimeError::QueueFull`] (the error carries the
//!    depth); `submit_program_blocking` instead waits for room.
//! 3. **Batch** — the dispatcher peels consecutive *small* jobs
//!    (`n_hint ≤ batch_max_n`, at most `max_batch`) off the queue front
//!    and gives each its own crew but one **shared** barrier sized to
//!    the whole batch, so the tenants' supersteps run in lockstep and
//!    one barrier release serves them all.
//! 4. **Run** — every lane executes the same `run_proc_body` as the
//!    one-shot path.  Charges are data-dependent, never
//!    timing-dependent, so a job's charged ledger is identical pooled
//!    or solo (only `wall_us` differs) — conformance-tested.
//! 5. **Finalize** — the last lane to finish a job materializes its
//!    [`Ledger`](super::ledger::Ledger) through the same
//!    `finalize_ledger` path as the one-shot engine, recycles the slot
//!    buffers, and fulfills the [`JobHandle`].
//!
//! There is **no scheduler thread**: dispatch runs under the scheduler
//! mutex from whoever has work to give away — a submitter, or the lane
//! that just completed a job and freed its crew.
//!
//! Known limitation: a panic inside a *flat* job is recovered (the dead
//! processor leaves the run barrier, peers finish or die, the handle
//! reports [`RuntimeError::JobPanicked`]).  A panic inside a job that
//! synchronizes over `Communicator` *group* barriers (`std::sync`
//! barriers with a fixed count) can strand its crew mid-group-sync; the
//! pool does not try to recover those, matching the one-shot engine,
//! which aborts the process in that case.

use std::any::{Any, TypeId};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::key::Key;
use crate::runtime::RuntimeError;

use super::engine::{run_proc_body, BspCtx, BspRun, SharedBarrier, World};
use super::msg::Payload;
use super::params::BspParams;

/// Tuning knobs of one [`Engine`].
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// BSP machine parameters; `params.p` is the lane count per crew,
    /// and every SPMD job submitted to this engine runs on exactly `p`
    /// processors.
    pub params: BspParams,
    /// Worker crews — jobs that can run concurrently.
    pub crews: usize,
    /// Admission bound: maximum *queued* (not yet dispatched) jobs
    /// before `submit` rejects with [`RuntimeError::QueueFull`].
    pub queue_depth: usize,
    /// Jobs with `n_hint` at most this are "small" and eligible for
    /// shared-superstep batching.
    pub batch_max_n: usize,
    /// Maximum small jobs dispatched as one shared-barrier batch (also
    /// bounded by the free crews at dispatch time).
    pub max_batch: usize,
}

impl EngineConfig {
    /// Defaults sized for the serving scenario: two crews, queue depth
    /// 64, batches of up to 4 jobs of n ≤ 32768.
    pub fn new(params: BspParams) -> EngineConfig {
        EngineConfig {
            params,
            crews: 2,
            queue_depth: 64,
            batch_max_n: 32_768,
            max_batch: 4,
        }
    }

    pub fn with_crews(mut self, crews: usize) -> EngineConfig {
        self.crews = crews.max(1);
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> EngineConfig {
        self.queue_depth = depth.max(1);
        self
    }

    pub fn with_batching(mut self, batch_max_n: usize, max_batch: usize) -> EngineConfig {
        self.batch_max_n = batch_max_n;
        self.max_batch = max_batch.max(1);
        self
    }
}

/// Cumulative scheduling counters — observability for the service layer
/// and the throughput bench.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Jobs whose handle has been fulfilled (success or panic).
    pub completed: usize,
    /// Dispatches that grouped at least two jobs over one shared
    /// barrier.
    pub shared_batches: usize,
    /// Jobs that ran as part of a shared batch.
    pub batched_jobs: usize,
    /// Jobs whose slot matrix was built from recycled buffers.
    pub scratch_reuses: usize,
}

/// One queued unit of work, type-erased over key domain and output type
/// so the scheduler holds mixed jobs in one FIFO.
trait TeamJob: Send + Sync {
    /// Processors this job occupies (the engine's `p` for SPMD jobs,
    /// 1 for closure jobs).
    fn procs(&self) -> usize;
    /// Problem-size hint driving the batching policy.
    fn n_hint(&self) -> usize;
    /// Attach the (possibly batch-shared) run barrier and build the
    /// job's world from pool scratch.  Called once, before any lane is
    /// woken.
    fn prepare(&self, barrier: Arc<SharedBarrier>, scratch: &ScratchPool);
    /// Run processor `proc`; returns `true` iff this call completed the
    /// job (last processor to finish).
    fn run_proc(&self, proc: usize) -> bool;
    /// Finalize after the last processor: ledger, outputs, scratch
    /// return, handle fulfillment.  Called exactly once, by the lane
    /// whose `run_proc` returned `true`.
    fn finish(&self, scratch: &ScratchPool);
    /// Abort a job that will never run (engine shut down while it was
    /// queued): fail its handle.
    fn fail(&self, err: RuntimeError);
}

/// A shelf of recycled slot-buffer sets for one `(key domain, p)` pair.
type Shelf = Vec<Box<dyn Any + Send>>;

/// Recycled slot-matrix buffers, keyed by key domain and `p`.  The
/// `TypeId` key is sound because `Key: 'static`; the value stored under
/// `(TypeId::of::<K>(), p)` is always a `Vec<Vec<Payload<K>>>`.
struct ScratchPool {
    shelves: Mutex<HashMap<(TypeId, usize), Shelf>>,
    /// Max recycled buffer sets kept per shelf (≈ crews: more can never
    /// be in flight at once).
    cap: usize,
    reuses: AtomicUsize,
}

impl ScratchPool {
    fn new(cap: usize) -> ScratchPool {
        ScratchPool {
            shelves: Mutex::new(HashMap::new()),
            cap,
            reuses: AtomicUsize::new(0),
        }
    }

    fn take<K: Key>(&self, p: usize) -> Vec<Vec<Payload<K>>> {
        let recycled = self
            .shelves
            .lock()
            .unwrap()
            .get_mut(&(TypeId::of::<K>(), p))
            .and_then(|shelf| shelf.pop());
        match recycled {
            Some(boxed) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                *boxed
                    .downcast::<Vec<Vec<Payload<K>>>>()
                    .expect("scratch shelf holds a foreign type")
            }
            None => Vec::new(),
        }
    }

    fn put<K: Key>(&self, p: usize, bufs: Vec<Vec<Payload<K>>>) {
        let mut shelves = self.shelves.lock().unwrap();
        let shelf = shelves.entry((TypeId::of::<K>(), p)).or_default();
        if shelf.len() < self.cap {
            shelf.push(Box::new(bufs));
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Completion slot shared between a running job and its [`JobHandle`].
struct HandleShared<R> {
    slot: Mutex<Option<Result<R, RuntimeError>>>,
    done: Condvar,
}

impl<R> HandleShared<R> {
    fn new() -> Arc<HandleShared<R>> {
        Arc::new(HandleShared {
            slot: Mutex::new(None),
            done: Condvar::new(),
        })
    }

    fn fulfill(&self, result: Result<R, RuntimeError>) {
        let mut slot = self.slot.lock().unwrap();
        debug_assert!(slot.is_none(), "job fulfilled twice");
        *slot = Some(result);
        self.done.notify_all();
    }

    fn join(&self) -> Result<R, RuntimeError> {
        let mut slot = self.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.done.wait(slot).unwrap();
        }
    }

    fn is_done(&self) -> bool {
        self.slot.lock().unwrap().is_some()
    }
}

/// Handle to a submitted job: `join` for the [`BspRun`] — outputs in
/// pid order plus the job's own charged [`Ledger`](super::ledger::Ledger),
/// exactly as the one-shot path returns them.
pub struct JobHandle<T> {
    shared: Arc<HandleShared<BspRun<T>>>,
}

impl<T> JobHandle<T> {
    /// Block until the job completes; returns its outputs and per-job
    /// ledger, or the structured [`RuntimeError`] that ended it.
    pub fn join(self) -> Result<BspRun<T>, RuntimeError> {
        self.shared.join()
    }

    /// True once the job has completed (either way): `join` will not
    /// block.
    pub fn is_done(&self) -> bool {
        self.shared.is_done()
    }
}

impl<T> std::fmt::Debug for JobHandle<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle").field("done", &self.is_done()).finish()
    }
}

/// The concrete job behind the erased [`TeamJob`]: an SPMD program over
/// key domain `K` producing one `T` per processor.
struct SpmdJob<K: Key, T, F> {
    p: usize,
    n_hint: usize,
    program: F,
    /// Built at `prepare`; read by every lane.  `OnceLock` provides the
    /// happens-before edge from the preparing thread to the lanes.
    world: OnceLock<World<K>>,
    started: OnceLock<Instant>,
    outputs: Mutex<Vec<Option<T>>>,
    /// Panic payload of the first processor that died.
    poison: Mutex<Option<String>>,
    /// Processors still running; the lane that takes it to zero
    /// finalizes.  `AcqRel` so the finalizer observes every lane's
    /// writes (outputs, slot buffers).
    remaining: AtomicUsize,
    handle: Arc<HandleShared<BspRun<T>>>,
}

impl<K, T, F> TeamJob for SpmdJob<K, T, F>
where
    K: Key,
    T: Send + 'static,
    F: Fn(&mut BspCtx<K>) -> T + Send + Sync + 'static,
{
    fn procs(&self) -> usize {
        self.p
    }

    fn n_hint(&self) -> usize {
        self.n_hint
    }

    fn prepare(&self, barrier: Arc<SharedBarrier>, scratch: &ScratchPool) {
        let world = World::with_scratch(self.p, barrier, scratch.take::<K>(self.p));
        if self.world.set(world).is_err() {
            panic!("job prepared twice");
        }
        let _ = self.started.set(Instant::now());
    }

    fn run_proc(&self, proc: usize) -> bool {
        let world = self.world.get().expect("job run before prepare");
        let result = catch_unwind(AssertUnwindSafe(|| run_proc_body(world, proc, &self.program)));
        // This processor will never arrive at the run barrier again —
        // finished or dead, let batch peers stop waiting for it.
        world.barrier.leave();
        match result {
            Ok(out) => self.outputs.lock().unwrap()[proc] = Some(out),
            Err(payload) => {
                let mut poison = self.poison.lock().unwrap();
                if poison.is_none() {
                    *poison = Some(panic_message(payload.as_ref()));
                }
            }
        }
        self.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }

    fn finish(&self, scratch: &ScratchPool) {
        let world = self.world.get().expect("job finished before prepare");
        let poison = self.poison.lock().unwrap().take();
        let result = match poison {
            Some(msg) => Err(RuntimeError::JobPanicked(msg)),
            None => {
                let wall_us = self
                    .started
                    .get()
                    .map(|s| s.elapsed().as_secs_f64() * 1e6)
                    .unwrap_or(0.0);
                let ledger = world.finalize(wall_us);
                let outputs = self
                    .outputs
                    .lock()
                    .unwrap()
                    .iter_mut()
                    .map(|slot| slot.take().expect("processor finished without output"))
                    .collect();
                Ok(BspRun { outputs, ledger })
            }
        };
        // SAFETY: `remaining` hit zero with AcqRel ordering — every
        // processor of this job is done with the slot matrix.
        scratch.put(self.p, unsafe { world.reclaim_buffers() });
        self.handle.fulfill(result);
    }

    fn fail(&self, err: RuntimeError) {
        self.handle.fulfill(Err(err));
    }
}

/// A one-lane closure job: how the `Sorter` runs simulator-backend
/// sorts (whose virtual `p` can far exceed any crew's lane count)
/// through the same queue / admission / handle machinery.  Never
/// batched (`n_hint = usize::MAX`), so its unused run barrier involves
/// nobody else.
struct ClosureJob<T, G> {
    task: Mutex<Option<G>>,
    result: Mutex<Option<Result<BspRun<T>, RuntimeError>>>,
    handle: Arc<HandleShared<BspRun<T>>>,
}

impl<T, G> TeamJob for ClosureJob<T, G>
where
    T: Send + 'static,
    G: FnOnce() -> BspRun<T> + Send + 'static,
{
    fn procs(&self) -> usize {
        1
    }

    fn n_hint(&self) -> usize {
        usize::MAX
    }

    fn prepare(&self, _barrier: Arc<SharedBarrier>, _scratch: &ScratchPool) {}

    fn run_proc(&self, proc: usize) -> bool {
        debug_assert_eq!(proc, 0, "closure jobs run on one lane");
        let task = self.task.lock().unwrap().take().expect("closure job run twice");
        let result = catch_unwind(AssertUnwindSafe(task))
            .map_err(|payload| RuntimeError::JobPanicked(panic_message(payload.as_ref())));
        *self.result.lock().unwrap() = Some(result);
        true
    }

    fn finish(&self, _scratch: &ScratchPool) {
        let result = self
            .result
            .lock()
            .unwrap()
            .take()
            .expect("closure job finished before running");
        self.handle.fulfill(result);
    }

    fn fail(&self, err: RuntimeError) {
        self.handle.fulfill(Err(err));
    }
}

struct LaneOrder {
    job: Arc<dyn TeamJob>,
    proc: usize,
}

/// A parked worker lane: a mailbox holding at most one order, and the
/// condvar its thread sleeps on.
struct Lane {
    order: Mutex<Option<LaneOrder>>,
    ready: Condvar,
}

struct SchedState {
    queue: VecDeque<Arc<dyn TeamJob>>,
    /// Crews with no job assigned (indices into `0..crews`).
    free_crews: Vec<usize>,
    shutdown: bool,
    /// Test hook: suspend dispatch so jobs pile up in the queue.
    hold: bool,
    completed: usize,
    shared_batches: usize,
    batched_jobs: usize,
}

struct EngineInner {
    cfg: EngineConfig,
    sched: Mutex<SchedState>,
    /// Signaled when the queue loses an element — room for blocked
    /// submitters.
    space: Condvar,
    lanes: Vec<Lane>,
    scratch: ScratchPool,
    /// Read by idle lanes to exit; published before the per-lane
    /// mutex-held wakeup in `shutdown`.
    stop: AtomicBool,
}

impl EngineInner {
    fn enqueue(&self, job: Arc<dyn TeamJob>, block: bool) -> Result<(), RuntimeError> {
        let mut sched = self.sched.lock().unwrap();
        loop {
            if sched.shutdown {
                return Err(RuntimeError::EngineShutdown);
            }
            if sched.queue.len() < self.cfg.queue_depth {
                break;
            }
            if !block {
                return Err(RuntimeError::QueueFull {
                    depth: self.cfg.queue_depth,
                });
            }
            sched = self.space.wait(sched).unwrap();
        }
        sched.queue.push_back(job);
        self.dispatch_locked(&mut sched);
        Ok(())
    }

    /// Hand queued jobs to free crews: FIFO, with consecutive small
    /// jobs at the queue front grouped into one shared-barrier batch
    /// (one crew each).  Runs under the scheduler lock, invoked by a
    /// submitter or by the lane that just freed a crew — there is no
    /// scheduler thread to context-switch through.
    fn dispatch_locked(&self, sched: &mut SchedState) {
        if sched.shutdown {
            while let Some(job) = sched.queue.pop_front() {
                job.fail(RuntimeError::EngineShutdown);
            }
            self.space.notify_all();
            return;
        }
        if sched.hold {
            return;
        }
        let p = self.cfg.params.p;
        while !sched.queue.is_empty() && !sched.free_crews.is_empty() {
            let mut take = 1;
            if sched.queue[0].n_hint() <= self.cfg.batch_max_n {
                let cap = self.cfg.max_batch.min(sched.free_crews.len()).min(sched.queue.len());
                while take < cap && sched.queue[take].n_hint() <= self.cfg.batch_max_n {
                    take += 1;
                }
            }
            if take > 1 {
                sched.shared_batches += 1;
                sched.batched_jobs += take;
            }
            let jobs: Vec<Arc<dyn TeamJob>> = sched.queue.drain(..take).collect();
            let participants: usize = jobs.iter().map(|j| j.procs()).sum();
            let barrier = Arc::new(SharedBarrier::new(participants));
            for job in jobs {
                job.prepare(Arc::clone(&barrier), &self.scratch);
                let crew = sched.free_crews.pop().expect("batch sized to free crews");
                let procs = job.procs();
                assert!(procs <= p, "job wider than a crew");
                for proc in 0..procs {
                    let lane = &self.lanes[crew * p + proc];
                    *lane.order.lock().unwrap() = Some(LaneOrder {
                        job: Arc::clone(&job),
                        proc,
                    });
                    lane.ready.notify_one();
                }
            }
        }
        // The queue shrank — wake any submitter blocked on admission.
        self.space.notify_all();
    }
}

fn lane_main(inner: Arc<EngineInner>, lane_idx: usize) {
    let p = inner.cfg.params.p;
    loop {
        let order = {
            let lane = &inner.lanes[lane_idx];
            let mut slot = lane.order.lock().unwrap();
            loop {
                if let Some(order) = slot.take() {
                    break order;
                }
                if inner.stop.load(Ordering::Acquire) {
                    return;
                }
                slot = lane.ready.wait(slot).unwrap();
            }
        };
        let last = order.job.run_proc(order.proc);
        if last {
            order.job.finish(&inner.scratch);
            let mut sched = inner.sched.lock().unwrap();
            sched.completed += 1;
            sched.free_crews.push(lane_idx / p);
            inner.dispatch_locked(&mut sched);
        }
    }
}

/// A persistent sort engine: `crews × p` parked worker lanes fed by a
/// bounded FIFO job queue.  Submissions return a [`JobHandle`]
/// immediately; `join` blocks for the result.  The `Sorter` façade
/// (`crate::sorter`) keeps one global engine per machine width and
/// routes [`crate::sorter::SortJob`]s here — `Engine::submit` itself is
/// defined there, next to the job builder.
pub struct Engine {
    inner: Arc<EngineInner>,
    /// Lane threads, joined at `shutdown`.
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        assert!(cfg.params.p >= 1, "engine needs at least one processor per crew");
        let mut cfg = cfg;
        cfg.crews = cfg.crews.max(1);
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.queue_depth = cfg.queue_depth.max(1);
        let p = cfg.params.p;
        let crews = cfg.crews;
        let inner = Arc::new(EngineInner {
            sched: Mutex::new(SchedState {
                queue: VecDeque::new(),
                free_crews: (0..crews).rev().collect(),
                shutdown: false,
                hold: false,
                completed: 0,
                shared_batches: 0,
                batched_jobs: 0,
            }),
            space: Condvar::new(),
            lanes: (0..crews * p)
                .map(|_| Lane {
                    order: Mutex::new(None),
                    ready: Condvar::new(),
                })
                .collect(),
            scratch: ScratchPool::new(crews.max(2)),
            stop: AtomicBool::new(false),
            cfg,
        });
        let mut threads = Vec::with_capacity(crews * p);
        for idx in 0..crews * p {
            let inner = Arc::clone(&inner);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("bsp-lane-{}-{}", idx / p, idx % p))
                    .spawn(move || lane_main(inner, idx))
                    .expect("spawn engine lane"),
            );
        }
        Engine {
            inner,
            threads: Mutex::new(threads),
        }
    }

    /// The engine's machine parameters (`params.p` = processors per
    /// job).
    pub fn params(&self) -> &BspParams {
        &self.inner.cfg.params
    }

    /// Worker crews (jobs that can run concurrently).
    pub fn crews(&self) -> usize {
        self.inner.cfg.crews
    }

    /// Jobs currently queued (admitted, not yet dispatched).
    pub fn queued(&self) -> usize {
        self.inner.sched.lock().unwrap().queue.len()
    }

    /// Cumulative scheduling counters.
    pub fn stats(&self) -> EngineStats {
        let sched = self.inner.sched.lock().unwrap();
        EngineStats {
            completed: sched.completed,
            shared_batches: sched.shared_batches,
            batched_jobs: sched.batched_jobs,
            scratch_reuses: self.inner.scratch.reuses.load(Ordering::Relaxed),
        }
    }

    /// Submit an SPMD program over key domain `K`.  Returns immediately
    /// with a [`JobHandle`]; rejects with [`RuntimeError::QueueFull`]
    /// when the queue is at its admission bound.  `n_hint` is the job's
    /// total problem size — the small-job batching policy keys on it.
    pub fn submit_program<K, T, F>(
        &self,
        n_hint: usize,
        program: F,
    ) -> Result<JobHandle<T>, RuntimeError>
    where
        K: Key,
        T: Send + 'static,
        F: Fn(&mut BspCtx<K>) -> T + Send + Sync + 'static,
    {
        self.enqueue_spmd(n_hint, program, false)
    }

    /// As [`Engine::submit_program`] but waits for queue room instead
    /// of rejecting (still fails on shutdown).
    pub fn submit_program_blocking<K, T, F>(
        &self,
        n_hint: usize,
        program: F,
    ) -> Result<JobHandle<T>, RuntimeError>
    where
        K: Key,
        T: Send + 'static,
        F: Fn(&mut BspCtx<K>) -> T + Send + Sync + 'static,
    {
        self.enqueue_spmd(n_hint, program, true)
    }

    fn enqueue_spmd<K, T, F>(
        &self,
        n_hint: usize,
        program: F,
        block: bool,
    ) -> Result<JobHandle<T>, RuntimeError>
    where
        K: Key,
        T: Send + 'static,
        F: Fn(&mut BspCtx<K>) -> T + Send + Sync + 'static,
    {
        let p = self.inner.cfg.params.p;
        let handle = HandleShared::new();
        let job = Arc::new(SpmdJob {
            p,
            n_hint,
            program,
            world: OnceLock::new(),
            started: OnceLock::new(),
            outputs: Mutex::new((0..p).map(|_| None).collect()),
            poison: Mutex::new(None),
            remaining: AtomicUsize::new(p),
            handle: Arc::clone(&handle),
        });
        self.inner.enqueue(job, block)?;
        Ok(JobHandle { shared: handle })
    }

    /// Run a one-lane closure through the same queue / admission /
    /// handle machinery (the simulator-backend path of the `Sorter`).
    /// The closure must produce a finished [`BspRun`].
    pub fn submit_task<T, G>(&self, task: G, block: bool) -> Result<JobHandle<T>, RuntimeError>
    where
        T: Send + 'static,
        G: FnOnce() -> BspRun<T> + Send + 'static,
    {
        let handle = HandleShared::new();
        let job = Arc::new(ClosureJob {
            task: Mutex::new(Some(task)),
            result: Mutex::new(None),
            handle: Arc::clone(&handle),
        });
        self.inner.enqueue(job, block)?;
        Ok(JobHandle { shared: handle })
    }

    /// Drain and stop: running jobs complete, queued jobs fail with
    /// [`RuntimeError::EngineShutdown`], lane threads park out and are
    /// joined.  Subsequent submissions are rejected.  Idempotent.
    pub fn shutdown(&self) {
        {
            let mut sched = self.inner.sched.lock().unwrap();
            sched.shutdown = true;
            while let Some(job) = sched.queue.pop_front() {
                job.fail(RuntimeError::EngineShutdown);
            }
        }
        // Unblock admission waiters (they observe `shutdown`) …
        self.inner.space.notify_all();
        // … and parked lanes.  `stop` is published before each
        // mutex-held wakeup, so a lane either sees it under its mailbox
        // lock or is already waiting and receives the notify.
        self.inner.stop.store(true, Ordering::Release);
        for lane in &self.inner.lanes {
            let _guard = lane.order.lock().unwrap();
            lane.ready.notify_all();
        }
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }

    /// Test hook: suspend dispatch so submissions pile up in the queue.
    #[cfg(test)]
    fn hold(&self) {
        self.inner.sched.lock().unwrap().hold = true;
    }

    /// Test hook: resume dispatch after [`Engine::hold`].
    #[cfg(test)]
    fn release(&self) {
        let mut sched = self.inner.sched.lock().unwrap();
        sched.hold = false;
        self.inner.dispatch_locked(&mut sched);
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;
    use crate::bsp::BspMachine;

    fn engine(p: usize, crews: usize) -> Engine {
        Engine::new(EngineConfig::new(cray_t3d(p)).with_crews(crews))
    }

    #[test]
    fn submit_runs_an_spmd_program() {
        let eng = engine(4, 1);
        let handle = eng
            .submit_program::<i32, _, _>(1 << 20, |ctx| {
                ctx.charge(10.0);
                ctx.sync("only");
                ctx.pid() * 2
            })
            .unwrap();
        let run = handle.join().unwrap();
        assert_eq!(run.outputs, vec![0, 2, 4, 6]);
        assert_eq!(run.ledger.supersteps.len(), 1);
        assert_eq!(run.ledger.supersteps[0].reporters, 4);
        eng.shutdown();
    }

    #[test]
    fn jobs_run_fifo_on_a_persistent_team() {
        let eng = engine(2, 1);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                eng.submit_program::<i32, _, _>(usize::MAX, move |ctx| ctx.pid() + 10 * i)
                    .unwrap()
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let run = h.join().unwrap();
            assert_eq!(run.outputs, vec![10 * i, 10 * i + 1]);
        }
        assert_eq!(eng.stats().completed, 8);
        eng.shutdown();
    }

    #[test]
    fn messages_flow_between_lanes_of_a_crew() {
        let eng = engine(4, 2);
        let run = eng
            .submit_program::<u64, _, _>(usize::MAX, |ctx| {
                let p = ctx.nprocs();
                let dst = (ctx.pid() + 1) % p;
                ctx.send(dst, Payload::Keys(vec![ctx.pid() as u64 + 7]));
                ctx.sync("ring");
                ctx.take_inbox().pop().unwrap().1.into_keys()[0]
            })
            .unwrap()
            .join()
            .unwrap();
        assert_eq!(run.outputs, vec![10, 7, 8, 9]);
        eng.shutdown();
    }

    #[test]
    fn held_small_jobs_dispatch_as_one_shared_batch() {
        // Three small jobs with *different* superstep counts share one
        // barrier (exercises SharedBarrier::leave): held back so they
        // queue up, then released onto three free crews at once.
        let eng =
            Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(3).with_batching(1 << 10, 3));
        eng.hold();
        let handles: Vec<_> = (0..3)
            .map(|i| {
                eng.submit_program::<i32, _, _>(64, move |ctx| {
                    for _ in 0..=i {
                        ctx.sync("step");
                    }
                    ctx.pid()
                })
                .unwrap()
            })
            .collect();
        eng.release();
        for (i, h) in handles.into_iter().enumerate() {
            let run = h.join().unwrap();
            assert_eq!(run.outputs, vec![0, 1]);
            assert_eq!(run.ledger.supersteps.len(), i + 1, "per-job ledgers stay separate");
        }
        let stats = eng.stats();
        assert_eq!(stats.shared_batches, 1);
        assert_eq!(stats.batched_jobs, 3);
        eng.shutdown();
    }

    #[test]
    fn large_jobs_are_never_batched() {
        let eng =
            Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(2).with_batching(1 << 10, 4));
        eng.hold();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                eng.submit_program::<i32, _, _>(1 << 20, |ctx| {
                    ctx.sync("solo");
                    ctx.pid()
                })
                .unwrap()
            })
            .collect();
        eng.release();
        for h in handles {
            h.join().unwrap();
        }
        let stats = eng.stats();
        assert_eq!(stats.shared_batches, 0);
        assert_eq!(stats.batched_jobs, 0);
        eng.shutdown();
    }

    #[test]
    fn admission_rejects_beyond_queue_depth_with_the_depth_in_the_error() {
        let eng = Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(1).with_queue_depth(2));
        eng.hold();
        for _ in 0..2 {
            eng.submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid()).unwrap();
        }
        let err = eng
            .submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid())
            .unwrap_err();
        match &err {
            RuntimeError::QueueFull { depth } => assert_eq!(*depth, 2),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert!(err.to_string().contains('2'), "{err}");
        eng.release();
        eng.shutdown();
    }

    #[test]
    fn shutdown_fails_queued_jobs_and_rejects_new_ones() {
        let eng = Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(1).with_queue_depth(8));
        eng.hold();
        let h = eng.submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid()).unwrap();
        eng.shutdown();
        assert!(matches!(h.join(), Err(RuntimeError::EngineShutdown)));
        let err = eng
            .submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid())
            .unwrap_err();
        assert!(matches!(err, RuntimeError::EngineShutdown));
    }

    #[test]
    fn job_panic_is_reported_and_the_team_survives() {
        let eng = engine(2, 1);
        let h = eng
            .submit_program::<i32, _, _>(usize::MAX, |ctx| -> usize {
                panic!("kaboom {}", ctx.pid());
            })
            .unwrap();
        match h.join() {
            Err(RuntimeError::JobPanicked(msg)) => assert!(msg.contains("kaboom"), "{msg}"),
            other => panic!("expected JobPanicked, got {other:?}"),
        }
        let run = eng
            .submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid())
            .unwrap()
            .join()
            .unwrap();
        assert_eq!(run.outputs, vec![0, 1]);
        eng.shutdown();
    }

    #[test]
    fn blocking_submit_waits_for_room() {
        let eng = Arc::new(
            Engine::new(EngineConfig::new(cray_t3d(2)).with_crews(1).with_queue_depth(1)),
        );
        eng.hold();
        eng.submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid()).unwrap();
        let eng2 = Arc::clone(&eng);
        let submitter = std::thread::spawn(move || {
            eng2.submit_program_blocking::<i32, _, _>(usize::MAX, |ctx| ctx.pid())
                .unwrap()
                .join()
                .unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        eng.release();
        let run = submitter.join().unwrap();
        assert_eq!(run.outputs, vec![0, 1]);
        eng.shutdown();
    }

    #[test]
    fn slot_buffers_are_recycled_across_jobs() {
        let eng = engine(2, 1);
        for _ in 0..3 {
            eng.submit_program::<i32, _, _>(usize::MAX, |ctx| {
                ctx.send((ctx.pid() + 1) % 2, Payload::Keys(vec![1, 2, 3]));
                ctx.sync("x");
                ctx.take_inbox().len()
            })
            .unwrap()
            .join()
            .unwrap();
        }
        assert!(
            eng.stats().scratch_reuses >= 2,
            "later jobs should reuse the first job's slot buffers"
        );
        eng.shutdown();
    }

    #[test]
    fn closure_jobs_share_the_queue() {
        let eng = engine(2, 1);
        let h = eng
            .submit_task(|| BspMachine::new(cray_t3d(2)).run(|ctx| ctx.pid()), true)
            .unwrap();
        let run = h.join().unwrap();
        assert_eq!(run.outputs, vec![0, 1]);
        eng.shutdown();
    }

    #[test]
    fn handles_report_completion() {
        let eng = engine(2, 1);
        let h = eng.submit_program::<i32, _, _>(usize::MAX, |ctx| ctx.pid()).unwrap();
        let run = loop {
            if h.is_done() {
                break h.join().unwrap();
            }
            std::thread::yield_now();
        };
        assert_eq!(run.outputs.len(), 2);
        eng.shutdown();
    }
}
