//! Message payloads exchanged between BSP processors.
//!
//! Word accounting follows the paper: keys and counters are one word
//! each (the T3D's communication data type is a 64-bit integer, §6);
//! tagged sample records carry `(key, processor id, array index)` and are
//! charged **three** words — §6.1: duplicate handling "may triple in the
//! worst case the sample size as it attaches to each sample key an
//! integer processor identifier and an integer array index".

/// A sample/splitter record: a key augmented with its §5.1.1 tags.
///
/// Ordering is lexicographic `(key, proc, idx)` — exactly the tie-break
/// rule of the duplicate handling method: equal keys compare by owning
/// processor, then by position in that processor's local (sorted) array.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SampleRec {
    pub key: i32,
    pub proc: u32,
    pub idx: u32,
}

impl SampleRec {
    pub fn new(key: i32, proc: usize, idx: usize) -> Self {
        SampleRec {
            key,
            proc: proc as u32,
            idx: idx as u32,
        }
    }

    /// The number of communication words a record costs (§6.1).
    pub const WORDS: u64 = 3;
}

/// Payload variants; one enum keeps the engine monomorphic and the hot
/// key-routing path copy-free (the `Vec` moves through the slot matrix).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Plain keys — the routing hot path.
    Keys(Vec<i32>),
    /// Tagged sample/splitter records (3 words each).
    Recs(Vec<SampleRec>),
    /// Counters/offsets for prefix operations.
    U64s(Vec<u64>),
}

impl Payload {
    /// Communication size in words, per the paper's charging policy.
    #[inline]
    pub fn words(&self) -> u64 {
        match self {
            Payload::Keys(v) => v.len() as u64,
            Payload::Recs(v) => v.len() as u64 * SampleRec::WORDS,
            Payload::U64s(v) => v.len() as u64,
        }
    }

    /// True when the payload carries no items (an empty routing slice).
    #[inline]
    pub fn is_empty(&self) -> bool {
        match self {
            Payload::Keys(v) => v.is_empty(),
            Payload::Recs(v) => v.is_empty(),
            Payload::U64s(v) => v.is_empty(),
        }
    }

    pub fn into_keys(self) -> Vec<i32> {
        match self {
            Payload::Keys(v) => v,
            other => panic!("expected Keys payload, got {other:?}"),
        }
    }

    pub fn into_recs(self) -> Vec<SampleRec> {
        match self {
            Payload::Recs(v) => v,
            other => panic!("expected Recs payload, got {other:?}"),
        }
    }

    pub fn into_u64s(self) -> Vec<u64> {
        match self {
            Payload::U64s(v) => v,
            other => panic!("expected U64s payload, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_rec_order_is_key_proc_idx() {
        let a = SampleRec::new(5, 0, 9);
        let b = SampleRec::new(5, 1, 0);
        let c = SampleRec::new(5, 1, 1);
        let d = SampleRec::new(6, 0, 0);
        assert!(a < b && b < c && c < d);
    }

    #[test]
    fn words_charging_policy() {
        assert_eq!(Payload::Keys(vec![1, 2, 3]).words(), 3);
        assert_eq!(Payload::Recs(vec![SampleRec::new(1, 0, 0)]).words(), 3);
        assert_eq!(Payload::U64s(vec![1, 2]).words(), 2);
    }

    #[test]
    fn emptiness_per_variant() {
        assert!(Payload::Keys(vec![]).is_empty());
        assert!(Payload::Recs(vec![]).is_empty());
        assert!(Payload::U64s(vec![]).is_empty());
        assert!(!Payload::Keys(vec![1]).is_empty());
    }

    #[test]
    #[should_panic(expected = "expected Keys")]
    fn wrong_variant_panics() {
        Payload::U64s(vec![]).into_keys();
    }
}
