//! Message payloads exchanged between BSP processors.
//!
//! Word accounting follows the paper: counters are one word each (the
//! T3D's communication data type is a 64-bit integer, §6) and a key costs
//! its domain's fixed wire width ([`Key::WORDS`], one word for every
//! built-in domain); tagged sample records carry `(key, processor id,
//! array index)` and are charged `Key::WORDS + 2` words — §6.1: duplicate
//! handling "may triple in the worst case the sample size as it attaches
//! to each sample key an integer processor identifier and an integer
//! array index".
//!
//! Both [`SampleRec`] and [`Payload`] default their key domain to `i32`
//! (the paper's experiments), so monomorphic call sites read exactly as
//! they did before the stack was generified.

use crate::key::Key;

/// A sample/splitter record: a key augmented with its §5.1.1 tags.
///
/// Ordering is lexicographic `(key, proc, idx)` — exactly the tie-break
/// rule of the duplicate handling method: equal keys compare by owning
/// processor, then by position in that processor's local (sorted) array.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SampleRec<K = i32> {
    pub key: K,
    pub proc: u32,
    pub idx: u32,
}

impl<K: Key> SampleRec<K> {
    pub fn new(key: K, proc: usize, idx: usize) -> Self {
        SampleRec {
            key,
            proc: proc as u32,
            idx: idx as u32,
        }
    }

    /// The number of communication words a record costs (§6.1): the key
    /// width plus the two tag words.
    pub const WORDS: u64 = K::WORDS + 2;

    /// The greatest record of the domain — the padding/empty-run
    /// sentinel (maximal key, maximal tags).
    pub fn max_rec() -> Self {
        SampleRec {
            key: K::max_key(),
            proc: u32::MAX,
            idx: u32::MAX,
        }
    }
}

/// Payload variants; one enum keeps the engine monomorphic per key
/// domain and the hot key-routing path copy-free (the `Vec` moves
/// through the slot matrix).
#[derive(Clone, Debug)]
pub enum Payload<K = i32> {
    /// Plain keys — the routing hot path.
    Keys(Vec<K>),
    /// Tagged sample/splitter records (`Key::WORDS + 2` words each).
    Recs(Vec<SampleRec<K>>),
    /// Counters/offsets for prefix operations.
    U64s(Vec<u64>),
}

impl<K: Key> Payload<K> {
    /// Communication size in words, per the paper's charging policy.
    #[inline]
    pub fn words(&self) -> u64 {
        match self {
            Payload::Keys(v) => v.len() as u64 * K::WORDS,
            Payload::Recs(v) => v.len() as u64 * SampleRec::<K>::WORDS,
            Payload::U64s(v) => v.len() as u64,
        }
    }

    /// True when the payload carries no items (an empty routing slice).
    #[inline]
    pub fn is_empty(&self) -> bool {
        match self {
            Payload::Keys(v) => v.is_empty(),
            Payload::Recs(v) => v.is_empty(),
            Payload::U64s(v) => v.is_empty(),
        }
    }

    pub fn into_keys(self) -> Vec<K> {
        match self {
            Payload::Keys(v) => v,
            other => panic!("expected Keys payload, got {other:?}"),
        }
    }

    pub fn into_recs(self) -> Vec<SampleRec<K>> {
        match self {
            Payload::Recs(v) => v,
            other => panic!("expected Recs payload, got {other:?}"),
        }
    }

    pub fn into_u64s(self) -> Vec<u64> {
        match self {
            Payload::U64s(v) => v,
            other => panic!("expected U64s payload, got {other:?}"),
        }
    }

    /// Flatten this payload into the engine's 64-bit wire words — the
    /// exact sequence [`Payload::words`] prices.  In-process the engine
    /// moves the typed vectors directly (shared memory needs no
    /// serialization); a network transport would ship these words, and
    /// the charging policy is defined against them.
    pub fn encode_wire(&self) -> Vec<u64> {
        match self {
            Payload::Keys(v) => crate::key::encode_all(v),
            Payload::Recs(v) => {
                let mut out = Vec::with_capacity(v.len() * SampleRec::<K>::WORDS as usize);
                for r in v {
                    r.key.encode(&mut out);
                    out.push(r.proc as u64);
                    out.push(r.idx as u64);
                }
                out
            }
            Payload::U64s(v) => v.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::{F64, Record};

    #[test]
    fn sample_rec_order_is_key_proc_idx() {
        let a = SampleRec::new(5, 0, 9);
        let b = SampleRec::new(5, 1, 0);
        let c = SampleRec::new(5, 1, 1);
        let d = SampleRec::new(6, 0, 0);
        assert!(a < b && b < c && c < d);
    }

    #[test]
    fn words_charging_policy() {
        assert_eq!(Payload::Keys(vec![1, 2, 3]).words(), 3);
        assert_eq!(Payload::Recs(vec![SampleRec::new(1, 0, 0)]).words(), 3);
        assert_eq!(Payload::<i32>::U64s(vec![1, 2]).words(), 2);
    }

    #[test]
    fn words_charging_policy_other_domains() {
        // Every built-in domain is one wire word per key, so records stay
        // at the paper's 3-word charge.
        assert_eq!(Payload::Keys(vec![1u64, 2]).words(), 2);
        assert_eq!(Payload::Keys(vec![F64(1.0)]).words(), 1);
        assert_eq!(
            Payload::Recs(vec![SampleRec::new(Record { key: 1, payload: 2 }, 0, 0)]).words(),
            3
        );
    }

    #[test]
    fn emptiness_per_variant() {
        assert!(Payload::<i32>::Keys(vec![]).is_empty());
        assert!(Payload::<i32>::Recs(vec![]).is_empty());
        assert!(Payload::<i32>::U64s(vec![]).is_empty());
        assert!(!Payload::Keys(vec![1]).is_empty());
    }

    #[test]
    fn wire_encoding_matches_word_charges() {
        // `words()` prices exactly the wire sequence `encode_wire`
        // produces, for every variant and domain width.
        let pk = Payload::Keys(vec![3i32, -1, 7]);
        assert_eq!(pk.encode_wire().len() as u64, pk.words());
        let pr = Payload::Recs(vec![SampleRec::new(Record { key: 9, payload: 4 }, 1, 2)]);
        assert_eq!(pr.encode_wire().len() as u64, pr.words());
        let pu = Payload::<i32>::U64s(vec![5, 6]);
        assert_eq!(pu.encode_wire().len() as u64, pu.words());
        // And the wire round-trips back into the keys.
        let keys = vec![F64(1.5), F64(-0.0)];
        let wire = Payload::Keys(keys.clone()).encode_wire();
        assert_eq!(crate::key::decode_all::<F64>(&wire), keys);
    }

    #[test]
    fn max_rec_dominates() {
        assert!(SampleRec::new(i32::MAX, usize::MAX, usize::MAX) <= SampleRec::max_rec());
        assert!(SampleRec::new(41, 7, 7) < SampleRec::max_rec());
    }

    #[test]
    #[should_panic(expected = "expected Keys")]
    fn wrong_variant_panics() {
        Payload::<i32>::U64s(vec![]).into_keys();
    }
}
