//! The SPMD superstep engine: `p` OS threads as BSP processors.
//!
//! A program is a closure `Fn(&mut BspCtx) -> T` executed by every
//! processor.  Within a superstep a processor computes on local data,
//! charges its operation count (the paper's charging policy, §1.1), and
//! stages messages with [`BspCtx::send`]; [`BspCtx::sync`] is the
//! superstep boundary — a two-barrier protocol delivers all staged
//! messages (sorted by sender, which the routing step of the sorts relies
//! on for stability) and reduces the per-processor accounting into the
//! shared [`Ledger`].
//!
//! The engine executes *really* (threads + message passing, so wall-clock
//! and correctness are genuine) and *predictively* (each superstep is
//! priced `max{L, x + g·h}` under the configured [`BspParams`], which is
//! how the paper's Cray T3D numbers are reproduced on different hardware —
//! DESIGN.md §2).

use std::collections::HashMap;
use std::sync::{Barrier, Mutex};
use std::time::Instant;

use super::ledger::{Ledger, PhaseRecord, SuperstepRecord};
use super::msg::Payload;
use super::params::BspParams;

/// The default phase label before any `phase()` call.
pub const PHASE_INIT: &str = "Ph1:Init";

struct World {
    p: usize,
    /// Staging mailboxes, indexed by destination processor.
    mailboxes: Vec<Mutex<Vec<(usize, Payload)>>>,
    barrier: Barrier,
    ledger: Mutex<LedgerBuilder>,
}

#[derive(Default)]
struct LedgerBuilder {
    supersteps: Vec<SuperstepRecord>,
    phases: HashMap<String, PhaseRecord>,
}

/// Per-processor handle passed to the SPMD closure.
pub struct BspCtx<'w> {
    pid: usize,
    world: &'w World,
    inbox: Vec<(usize, Payload)>,
    superstep: usize,
    // charges since last sync
    ops: f64,
    sent_words: u64,
    // phase accounting
    phase: String,
    phase_ops: HashMap<String, f64>,
    phase_wall: HashMap<String, f64>,
    phase_mark: Instant,
    sync_mark: Instant,
}

impl<'w> BspCtx<'w> {
    /// This processor's identifier in `[0, nprocs)`.
    pub fn pid(&self) -> usize {
        self.pid
    }

    /// Number of BSP processors.
    pub fn nprocs(&self) -> usize {
        self.world.p
    }

    /// Charge `ops` basic operations (comparisons) to this processor in
    /// the current superstep and phase (§1.1 charging policy).
    pub fn charge(&mut self, ops: f64) {
        self.ops += ops;
        *self.phase_ops.entry(self.phase.clone()).or_default() += ops;
    }

    /// Stage a message for `dst`; delivered at the next `sync`.
    pub fn send(&mut self, dst: usize, payload: Payload) {
        debug_assert!(dst < self.world.p, "send to invalid pid {dst}");
        self.sent_words += payload.words();
        self.world.mailboxes[dst].lock().unwrap().push((self.pid, payload));
    }

    /// Enter a named phase (Ph1–Ph7 in the tables).  Wall-clock and op
    /// charges accrue to the active phase.
    pub fn phase(&mut self, name: &str) {
        let now = Instant::now();
        let elapsed = now.duration_since(self.phase_mark).as_secs_f64() * 1e6;
        *self.phase_wall.entry(self.phase.clone()).or_default() += elapsed;
        self.phase_mark = now;
        self.phase = name.to_string();
    }

    /// Superstep boundary: deliver staged messages, record accounting.
    ///
    /// Every processor must call `sync` the same number of times with the
    /// same `label` (SPMD discipline, checked in debug builds via the
    /// reporter count).
    pub fn sync(&mut self, label: &str) {
        let wall_us = self.sync_mark.elapsed().as_secs_f64() * 1e6;

        // Barrier 1: all sends for this superstep are staged.
        self.world.barrier.wait();

        // Take and order this processor's inbox.
        let mut msgs = std::mem::take(&mut *self.world.mailboxes[self.pid].lock().unwrap());
        msgs.sort_by_key(|(src, _)| *src);
        let recv_words: u64 = msgs.iter().map(|(_, p)| p.words()).sum();
        self.inbox = msgs;

        // Report into the shared ledger.
        {
            let mut builder = self.world.ledger.lock().unwrap();
            if builder.supersteps.len() <= self.superstep {
                builder.supersteps.resize_with(self.superstep + 1, Default::default);
            }
            let rec = &mut builder.supersteps[self.superstep];
            if rec.reporters == 0 {
                rec.label = label.to_string();
                rec.phase = self.phase.clone();
            }
            rec.reporters += 1;
            rec.max_ops = rec.max_ops.max(self.ops);
            rec.h_words = rec.h_words.max(self.sent_words.max(recv_words));
            rec.total_words += self.sent_words;
            rec.wall_us = rec.wall_us.max(wall_us);
            // Count this superstep against the active phase (h volume is
            // attributed post-hoc in `BspMachine::run`).
            let first_reporter = rec.reporters == 1;
            let phase = builder.phases.entry(self.phase.clone()).or_default();
            if first_reporter {
                phase.supersteps += 1;
            }
        }

        // Barrier 2: nobody stages next-superstep messages into a mailbox
        // that hasn't been drained yet.
        self.world.barrier.wait();

        self.ops = 0.0;
        self.sent_words = 0;
        self.superstep += 1;
        self.sync_mark = Instant::now();
    }

    /// The messages delivered at the last `sync`, ordered by sender id.
    pub fn take_inbox(&mut self) -> Vec<(usize, Payload)> {
        std::mem::take(&mut self.inbox)
    }

    /// Convenience: exchange one payload with every processor
    /// (all-to-all); returns the received payloads by sender.
    pub fn all_to_all(&mut self, parts: Vec<Payload>, label: &str) -> Vec<(usize, Payload)> {
        assert_eq!(parts.len(), self.nprocs());
        for (dst, payload) in parts.into_iter().enumerate() {
            self.send(dst, payload);
        }
        self.sync(label);
        self.take_inbox()
    }

    /// Flush end-of-run phase accounting (called by the engine).
    fn finish(&mut self) {
        let now = Instant::now();
        let elapsed = now.duration_since(self.phase_mark).as_secs_f64() * 1e6;
        *self.phase_wall.entry(self.phase.clone()).or_default() += elapsed;
        let mut builder = self.world.ledger.lock().unwrap();
        for (name, ops) in &self.phase_ops {
            let rec = builder.phases.entry(name.clone()).or_default();
            rec.max_ops = rec.max_ops.max(*ops);
        }
        for (name, wall) in &self.phase_wall {
            let rec = builder.phases.entry(name.clone()).or_default();
            rec.wall_us = rec.wall_us.max(*wall);
        }
    }
}

/// Result of a BSP run: the per-processor outputs and the cost ledger.
#[derive(Debug)]
pub struct BspRun<T> {
    pub outputs: Vec<T>,
    pub ledger: Ledger,
}

/// A BSP machine: parameters + the ability to run SPMD programs.
pub struct BspMachine {
    pub params: BspParams,
}

impl BspMachine {
    pub fn new(params: BspParams) -> Self {
        BspMachine { params }
    }

    /// Execute `program` on `p` processors (threads); returns outputs in
    /// pid order plus the superstep/phase ledger.
    pub fn run<T, F>(&self, program: F) -> BspRun<T>
    where
        T: Send,
        F: Fn(&mut BspCtx) -> T + Sync,
    {
        let p = self.params.p;
        let world = World {
            p,
            mailboxes: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
            barrier: Barrier::new(p),
            ledger: Mutex::new(LedgerBuilder::default()),
        };
        let started = Instant::now();
        let mut outputs: Vec<Option<T>> = (0..p).map(|_| None).collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(p);
            for pid in 0..p {
                let world_ref = &world;
                let program_ref = &program;
                handles.push(scope.spawn(move || {
                    let now = Instant::now();
                    let mut ctx = BspCtx {
                        pid,
                        world: world_ref,
                        inbox: Vec::new(),
                        superstep: 0,
                        ops: 0.0,
                        sent_words: 0,
                        phase: PHASE_INIT.to_string(),
                        phase_ops: HashMap::new(),
                        phase_wall: HashMap::new(),
                        phase_mark: now,
                        sync_mark: now,
                    };
                    let out = program_ref(&mut ctx);
                    ctx.finish();
                    (pid, out)
                }));
            }
            for h in handles {
                let (pid, out) = h.join().expect("BSP processor thread panicked");
                outputs[pid] = Some(out);
            }
        });

        let builder = world.ledger.into_inner().unwrap();
        let mut ledger = Ledger {
            supersteps: builder.supersteps,
            phases: builder.phases.into_iter().collect(),
            wall_us: started.elapsed().as_secs_f64() * 1e6,
        };
        // Attribute superstep h-volumes to phases post-hoc (max over the
        // per-superstep h of each phase is less meaningful than the sum).
        for s in &ledger.supersteps {
            if let Some(phase) = ledger.phases.get_mut(&s.phase) {
                phase.h_words += s.h_words;
            }
        }
        BspRun {
            outputs: outputs.into_iter().map(|o| o.unwrap()).collect(),
            ledger,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    fn machine(p: usize) -> BspMachine {
        BspMachine::new(cray_t3d(p))
    }

    #[test]
    fn pid_and_nprocs() {
        let run = machine(4).run(|ctx| (ctx.pid(), ctx.nprocs()));
        assert_eq!(run.outputs, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn ring_exchange_delivers_in_sender_order() {
        let run = machine(8).run(|ctx| {
            let p = ctx.nprocs();
            let dst = (ctx.pid() + 1) % p;
            ctx.send(dst, Payload::Keys(vec![ctx.pid() as i32]));
            ctx.sync("ring");
            let inbox = ctx.take_inbox();
            assert_eq!(inbox.len(), 1);
            let (src, payload) = &inbox[0];
            (*src, payload.clone().into_keys()[0])
        });
        for (pid, (src, val)) in run.outputs.iter().enumerate() {
            let expect = (pid + 8 - 1) % 8;
            assert_eq!(*src, expect);
            assert_eq!(*val, expect as i32);
        }
    }

    #[test]
    fn all_to_all_is_complete_and_ordered() {
        let run = machine(5).run(|ctx| {
            let parts = (0..5)
                .map(|dst| Payload::Keys(vec![(ctx.pid() * 10 + dst) as i32]))
                .collect();
            let recv = ctx.all_to_all(parts, "a2a");
            recv.into_iter()
                .map(|(src, p)| (src, p.into_keys()[0]))
                .collect::<Vec<_>>()
        });
        for (pid, inbox) in run.outputs.iter().enumerate() {
            assert_eq!(inbox.len(), 5);
            for (i, (src, val)) in inbox.iter().enumerate() {
                assert_eq!(*src, i, "inbox must be sorted by sender");
                assert_eq!(*val as usize, i * 10 + pid);
            }
        }
    }

    #[test]
    fn ledger_records_h_relation() {
        let run = machine(4).run(|ctx| {
            // Everyone sends 100 keys to processor 0.
            ctx.send(0, Payload::Keys(vec![1; 100]));
            ctx.sync("fan-in");
            ctx.take_inbox().len()
        });
        assert_eq!(run.ledger.supersteps.len(), 1);
        let s = &run.ledger.supersteps[0];
        // h = max over procs of max(sent, recv) = 400 received at proc 0.
        assert_eq!(s.h_words, 400);
        assert_eq!(s.total_words, 400);
        assert_eq!(s.reporters, 4);
    }

    #[test]
    fn charges_are_max_reduced() {
        let run = machine(4).run(|ctx| {
            ctx.charge((ctx.pid() as f64 + 1.0) * 1000.0);
            ctx.sync("compute");
        });
        assert_eq!(run.ledger.supersteps[0].max_ops, 4000.0);
        let _ = run;
    }

    #[test]
    fn multiple_supersteps_accumulate() {
        let run = machine(3).run(|ctx| {
            for step in 0..5 {
                ctx.charge(10.0);
                ctx.send((ctx.pid() + 1) % 3, Payload::U64s(vec![step]));
                ctx.sync("loop");
                ctx.take_inbox();
            }
        });
        assert_eq!(run.ledger.supersteps.len(), 5);
        for s in &run.ledger.supersteps {
            assert_eq!(s.max_ops, 10.0);
            assert_eq!(s.h_words, 1);
        }
        let _ = run;
    }

    #[test]
    fn phases_attribute_ops_and_supersteps() {
        let run = machine(2).run(|ctx| {
            ctx.phase("Ph2:SeqSort");
            ctx.charge(500.0);
            ctx.sync("sort");
            ctx.phase("Ph5:Routing");
            ctx.send(1 - ctx.pid(), Payload::Keys(vec![0; 64]));
            ctx.sync("route");
            ctx.take_inbox();
        });
        let phases = &run.ledger.phases;
        assert!(phases.contains_key("Ph2:SeqSort"));
        assert!(phases.contains_key("Ph5:Routing"));
        assert_eq!(phases["Ph2:SeqSort"].max_ops, 500.0);
        assert_eq!(phases["Ph5:Routing"].h_words, 64);
    }

    #[test]
    fn predicted_cost_uses_machine_params() {
        let machine = BspMachine::new(cray_t3d(16));
        let run = machine.run(|ctx| {
            ctx.charge(7_000.0); // 1000 µs of compute at 7 comps/µs
            ctx.sync("c");
        });
        let us = run.ledger.predicted_us(&machine.params);
        assert!((us - 1000.0).abs() < 1e-9, "us={us}");
    }

    #[test]
    fn empty_superstep_floors_at_l() {
        let machine = BspMachine::new(cray_t3d(128));
        let run = machine.run(|ctx| ctx.sync("noop"));
        assert_eq!(run.ledger.predicted_us(&machine.params), 762.0);
        let _ = run;
    }
}
