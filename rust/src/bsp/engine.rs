//! The SPMD superstep engine: `p` OS threads as BSP processors.
//!
//! A program is a closure `Fn(&mut BspCtx) -> T` executed by every
//! processor.  Within a superstep a processor computes on local data,
//! charges its operation count (the paper's charging policy, §1.1), and
//! stages messages with [`BspCtx::send`]; [`BspCtx::sync`] is the
//! superstep boundary — a two-barrier protocol delivers all staged
//! messages (sorted by sender, which the routing step of the sorts relies
//! on for stability) and reduces the per-processor accounting into the
//! shared [`Ledger`].
//!
//! Hot-path design (this is the substrate every comparison loop and
//! routing superstep runs through):
//!
//! * **Slot-matrix mailboxes** — staging is a p×p single-writer slot
//!   matrix: slot `(src, dst)` is written only by processor `src` and
//!   drained only by `dst`, with the sync barriers providing the
//!   happens-before edges.  `send` takes no lock, and the dst-major
//!   layout makes sender-ordered delivery a straight row scan instead of
//!   a take-the-lock-and-sort.
//! * **Interned phase labels** — phase names are registered once per run
//!   in a `PhaseInterner`; `charge`/`phase` accounting is an array add
//!   indexed by the interned id: no allocation, no string hashing.
//!
//! The engine executes *really* (threads + message passing, so wall-clock
//! and correctness are genuine) and *predictively* (each superstep is
//! priced `max{L, x + g·h}` under the configured [`BspParams`], which is
//! how the paper's Cray T3D numbers are reproduced on different hardware —
//! DESIGN.md §2).
//!
//! Programs are written against the [`BspScope`] trait, implemented by
//! [`BspCtx`] (the whole machine) and by `bsp::group::GroupCtx` (one
//! processor group of a partitioned machine): the same superstep
//! machinery serves whole-machine and group-local synchronization, the
//! latter over per-group barriers and group-scoped slot-matrix views.

use std::cell::UnsafeCell;
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::time::Instant;

use crate::key::Key;

use super::ledger::{Ledger, PhaseRecord, SuperstepRecord};
use super::msg::Payload;
use super::params::BspParams;

/// The default phase label before any `phase()` call.
pub const PHASE_INIT: &str = "Ph1:Init";

/// p×p single-writer staging slots: slot `(src, dst)` is owned for
/// writing by processor `src` between superstep boundaries and drained by
/// `dst` inside `sync`.  Stored dst-major so a receiver's inbox is one
/// contiguous row scan that is already in sender order — no lock, no
/// sort.  Drained slot buffers keep their capacity, so repeated
/// all-to-all rounds reuse their staging storage.
struct SlotMatrix<K: Key> {
    p: usize,
    slots: Vec<UnsafeCell<Vec<Payload<K>>>>,
}

// SAFETY: access to each slot is partitioned by the engine's two-barrier
// protocol — outside a sync window a slot is touched only by its writer
// (thread `src`); between barrier 1 and barrier 2 of `sync` only by its
// reader (thread `dst`).  The barriers provide the happens-before edges,
// and `Payload<K>` is `Send` (`Key` requires `Send`), so handing the
// vectors across threads is sound.
unsafe impl<K: Key> Sync for SlotMatrix<K> {}

impl<K: Key> SlotMatrix<K> {
    /// Build a matrix over `p * p` fresh (or recycled) slot buffers: the
    /// engine pool hands back the buffers of a finished job so the next
    /// job of the same key domain starts with warmed allocations.  Each
    /// buffer is cleared; its capacity survives.
    fn from_buffers(p: usize, mut bufs: Vec<Vec<Payload<K>>>) -> SlotMatrix<K> {
        bufs.resize_with(p * p, Vec::new);
        SlotMatrix {
            p,
            slots: bufs
                .into_iter()
                .map(|mut b| {
                    b.clear();
                    UnsafeCell::new(b)
                })
                .collect(),
        }
    }

    /// Take the slot buffers back out (capacity preserved) so the engine
    /// pool can recycle them into the next job's matrix.
    ///
    /// SAFETY: every processor of the run must have finished — the
    /// caller must hold a happens-before edge from each processor's last
    /// slot access (the pool's `remaining` counter provides it).
    unsafe fn take_buffers(&self) -> Vec<Vec<Payload<K>>> {
        self.slots.iter().map(|s| std::mem::take(&mut *s.get())).collect()
    }

    /// Stage a payload from `src` to `dst`.
    ///
    /// SAFETY: the caller must be the engine thread `src`, outside the
    /// drain window of a `sync` (the single-writer rule above).
    unsafe fn push(&self, src: usize, dst: usize, payload: Payload<K>) {
        (*self.slots[dst * self.p + src].get()).push(payload);
    }

    /// Move every message addressed to `dst` into `inbox`, in sender
    /// order.
    ///
    /// SAFETY: the caller must be the engine thread `dst`, between the
    /// two barriers of a `sync`.
    unsafe fn drain_row(&self, dst: usize, inbox: &mut Vec<(usize, Payload<K>)>) {
        for src in 0..self.p {
            let slot = &mut *self.slots[dst * self.p + src].get();
            for payload in slot.drain(..) {
                inbox.push((src, payload));
            }
        }
    }

    /// As [`SlotMatrix::drain_row`] but restricted to the slots written
    /// by `members` — the group-scoped view of the same p×p matrix used
    /// by group-local supersteps.  `members` must be sorted ascending, so
    /// delivery stays in (global) sender order.
    ///
    /// SAFETY: the caller must be the engine thread `dst`, between the
    /// two barriers of a *group* sync whose group is exactly `members`;
    /// during a group superstep only group members write slots addressed
    /// to `dst` (the group communication discipline, `bsp::group`), and
    /// non-member slots are untouched here, so the single-writer
    /// partition holds slot by slot.
    unsafe fn drain_row_subset(
        &self,
        dst: usize,
        members: &[usize],
        inbox: &mut Vec<(usize, Payload<K>)>,
    ) {
        for &src in members {
            let slot = &mut *self.slots[dst * self.p + src].get();
            for payload in slot.drain(..) {
                inbox.push((src, payload));
            }
        }
    }
}

/// Phase labels interned to dense ids, registered once per run, so the
/// per-charge accounting is an array index instead of a string clone and
/// hash.  `intern` is called only from [`BspCtx::phase`] (rare); the hot
/// paths use the returned id.  Shared with the deterministic simulator
/// backend (`bsp::sim`), which runs the same accounting single-threaded.
pub(super) struct PhaseInterner {
    names: Mutex<Vec<String>>,
}

impl PhaseInterner {
    pub(super) fn new() -> PhaseInterner {
        PhaseInterner {
            names: Mutex::new(vec![PHASE_INIT.to_string()]),
        }
    }

    pub(super) fn intern(&self, name: &str) -> usize {
        let mut names = self.names.lock().unwrap();
        match names.iter().position(|n| n == name) {
            Some(id) => id,
            None => {
                names.push(name.to_string());
                names.len() - 1
            }
        }
    }

    pub(super) fn into_names(self) -> Vec<String> {
        self.names.into_inner().unwrap()
    }

    /// Drain the interned names through a shared reference — the engine
    /// pool finalizes a job's ledger while other handles to the job's
    /// world are still alive.  Leaves the interner empty; call exactly
    /// once, at end of run.
    pub(super) fn take_names(&self) -> Vec<String> {
        std::mem::take(&mut *self.names.lock().unwrap())
    }
}

/// A reusable barrier whose participant count can *shrink* while other
/// threads wait: when a job of a shared-superstep batch finishes, each
/// of its processors [`SharedBarrier::leave`]s, and the remaining jobs
/// keep synchronizing among themselves.  `std::sync::Barrier` fixes its
/// count at construction, which is why the engine pool's batching
/// (`bsp::service`) needs its own.
///
/// Correctness invariant (generation lockstep): every active participant
/// arrives exactly once per generation, and `leave` is called exactly
/// once per departing participant, strictly after its final arrival has
/// been released.  Under that discipline a generation is released
/// exactly when all currently-active participants have arrived.
pub(super) struct SharedBarrier {
    state: Mutex<BarrierState>,
    cond: Condvar,
}

struct BarrierState {
    participants: usize,
    arrived: usize,
    generation: u64,
}

impl SharedBarrier {
    pub(super) fn new(participants: usize) -> SharedBarrier {
        SharedBarrier {
            state: Mutex::new(BarrierState {
                participants,
                arrived: 0,
                generation: 0,
            }),
            cond: Condvar::new(),
        }
    }

    /// Block until every active participant has arrived.  Returns `true`
    /// on exactly one arriving thread per generation (the leader).
    pub(super) fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        st.arrived += 1;
        if st.arrived >= st.participants {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cond.notify_all();
            true
        } else {
            let gen = st.generation;
            while st.generation == gen {
                st = self.cond.wait(st).unwrap();
            }
            false
        }
    }

    /// Permanently remove one participant (a processor whose job is
    /// done).  If the departure leaves every remaining participant
    /// already arrived, the pending generation is released on its
    /// behalf.
    pub(super) fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        st.participants -= 1;
        if st.participants > 0 && st.arrived >= st.participants {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cond.notify_all();
        }
    }
}

/// The shared state of one BSP run: mailboxes, world barrier, phase
/// interner, ledger.  `pub(super)` so the engine pool (`bsp::service`)
/// can build one `World` per job — over a possibly *shared* barrier
/// (batched jobs synchronize their supersteps together) and recycled
/// slot buffers — and finalize its ledger through a shared reference.
pub(super) struct World<K: Key> {
    pub(super) p: usize,
    slots: SlotMatrix<K>,
    /// The world barrier both `sync` barriers of a whole-machine
    /// superstep go through.  `Arc` because a shared-superstep batch
    /// hands the *same* barrier to several jobs' worlds; a processor
    /// that finishes its job `leave`s so the rest keep going.
    pub(super) barrier: Arc<SharedBarrier>,
    phases: PhaseInterner,
    ledger: Mutex<LedgerBuilder>,
    /// First SPMD violation observed (sync label mismatch).  Checked by
    /// every processor after barrier 2 so all threads fail together
    /// instead of stranding the others on a barrier (debug builds).
    spmd_violation: Mutex<Option<String>>,
}

impl<K: Key> World<K> {
    pub(super) fn new(p: usize, barrier: Arc<SharedBarrier>) -> World<K> {
        World::with_scratch(p, barrier, Vec::new())
    }

    /// As [`World::new`] but recycling `scratch` as slot-matrix storage
    /// (buffers are cleared; their capacity survives across jobs).
    pub(super) fn with_scratch(
        p: usize,
        barrier: Arc<SharedBarrier>,
        scratch: Vec<Vec<Payload<K>>>,
    ) -> World<K> {
        World {
            p,
            slots: SlotMatrix::from_buffers(p, scratch),
            barrier,
            phases: PhaseInterner::new(),
            ledger: Mutex::new(LedgerBuilder::default()),
            spmd_violation: Mutex::new(None),
        }
    }

    /// Materialize the run's [`Ledger`] (resolving interned phase names
    /// through the shared [`finalize_ledger`]) once every processor has
    /// finished.  Drains the builder; call exactly once per run.
    pub(super) fn finalize(&self, wall_us: f64) -> Ledger {
        let builder = std::mem::take(&mut *self.ledger.lock().unwrap());
        let names = self.phases.take_names();
        finalize_ledger(builder, names, wall_us)
    }

    /// Reclaim the slot-matrix buffers for the engine pool's scratch
    /// store.
    ///
    /// SAFETY: every processor of the run must have finished, with a
    /// happens-before edge to the caller (see
    /// [`SlotMatrix::take_buffers`]).
    pub(super) unsafe fn reclaim_buffers(&self) -> Vec<Vec<Payload<K>>> {
        self.slots.take_buffers()
    }
}

/// Superstep accounting under construction: like [`SuperstepRecord`] but
/// with the phase as an interned id; names are resolved once at run end.
/// `pub(super)` so the simulator backend (`bsp::sim`) builds the *same*
/// records through the *same* finalization ([`finalize_ledger`]).
#[derive(Default)]
pub(super) struct SuperstepBuild {
    pub(super) label: String,
    pub(super) phase_id: usize,
    pub(super) max_ops: f64,
    pub(super) h_words: u64,
    pub(super) total_words: u64,
    pub(super) wall_us: f64,
    pub(super) reporters: usize,
    /// Expected reporters: the whole machine for global supersteps, the
    /// group size for group-scoped ones.
    pub(super) procs: usize,
}

#[derive(Default)]
pub(super) struct LedgerBuilder {
    pub(super) supersteps: Vec<SuperstepBuild>,
    /// Group-scoped superstep accumulators, keyed by
    /// `(communicator id, group-superstep index, group leader pid)`.
    /// Within one communicator, `(index, leader)` is collision-free
    /// (disjoint groups have distinct leaders and members of a group
    /// share the index); the communicator id keeps *sequential*
    /// communicators — whose per-thread indices may have diverged —
    /// from merging unrelated groups' records.  Records of one
    /// `(communicator, index)` pair ran concurrently on disjoint
    /// groups (one "round").
    pub(super) group_steps: std::collections::BTreeMap<(usize, usize, usize), SuperstepBuild>,
    /// Phase accumulators indexed by interned phase id.
    pub(super) phases: Vec<PhaseRecord>,
}

/// A group-scoped view for one `sync`: which processors participate,
/// which barrier gates them, and who the group leader (smallest member)
/// is.  Constructed by `bsp::group::GroupCtx`; the engine itself stays
/// agnostic of how the machine was partitioned.
pub(super) struct GroupScope<'a> {
    /// Process-unique id of the communicator this group belongs to.
    pub(super) comm_id: usize,
    /// Global pids of the group, sorted ascending.
    pub(super) members: &'a [usize],
    /// `members[0]` — the ledger key for this group's records.
    pub(super) leader: usize,
    /// Barrier sized to the group, owned by the `Communicator`.
    pub(super) barrier: &'a Barrier,
    /// The group's superstep counter, owned by the `Communicator` and
    /// advanced once per group sync by the barrier leader.  Every
    /// member reads the same value for the same physical superstep (the
    /// group barrier orders the leader's post-sync increment before any
    /// member's next-sync read), so records key correctly even when
    /// sibling groups run different superstep counts and threads are
    /// later regrouped by another communicator.
    pub(super) step: &'a std::sync::atomic::AtomicUsize,
}

/// Per-processor handle passed to the SPMD closure.
///
/// Generic over the payload key domain `K` (default `i32`, the paper's
/// experiments): one BSP run moves keys of exactly one domain.
pub struct BspCtx<'w, K: Key = i32> {
    pid: usize,
    world: &'w World<K>,
    inbox: Vec<(usize, Payload<K>)>,
    superstep: usize,
    // charges since last sync
    ops: f64,
    sent_words: u64,
    // phase accounting, indexed by interned phase id
    phase_id: usize,
    phase_ops: Vec<f64>,
    phase_wall: Vec<f64>,
    phase_mark: Instant,
    sync_mark: Instant,
}

impl<'w, K: Key> BspCtx<'w, K> {
    /// This processor's identifier in `[0, nprocs)`.
    pub fn pid(&self) -> usize {
        self.pid
    }

    /// Number of BSP processors.
    pub fn nprocs(&self) -> usize {
        self.world.p
    }

    /// Charge `ops` basic operations (comparisons) to this processor in
    /// the current superstep and phase (§1.1 charging policy).
    ///
    /// O(1), allocation-free: the phase is an interned id, so this is
    /// two float adds — it sits inside every comparison loop.
    #[inline]
    pub fn charge(&mut self, ops: f64) {
        self.ops += ops;
        self.phase_ops[self.phase_id] += ops;
    }

    /// Stage a message for `dst`; delivered at the next `sync`.
    ///
    /// Contention-free: the `(pid, dst)` slot has a single writer, so no
    /// lock is taken and no other processor's sends are waited on.
    #[inline]
    pub fn send(&mut self, dst: usize, payload: Payload<K>) {
        debug_assert!(dst < self.world.p, "send to invalid pid {dst}");
        self.sent_words += payload.words();
        // SAFETY: this thread is the unique writer of slot (pid, dst)
        // until the next sync barrier; see `SlotMatrix`.
        unsafe { self.world.slots.push(self.pid, dst, payload) };
    }

    /// Enter a named phase (Ph1–Ph7 in the tables).  Wall-clock and op
    /// charges accrue to the active phase.  The label is interned on
    /// first sight; subsequent uses of the same label are O(#phases).
    pub fn phase(&mut self, name: &str) {
        let now = Instant::now();
        let elapsed = now.duration_since(self.phase_mark).as_secs_f64() * 1e6;
        self.phase_wall[self.phase_id] += elapsed;
        self.phase_mark = now;
        self.phase_id = self.world.phases.intern(name);
        if self.phase_ops.len() <= self.phase_id {
            self.phase_ops.resize(self.phase_id + 1, 0.0);
            self.phase_wall.resize(self.phase_id + 1, 0.0);
        }
    }

    /// Superstep boundary: deliver staged messages, record accounting.
    ///
    /// Every processor must call `sync` the same number of times with the
    /// same `label` (SPMD discipline).  In debug builds a label mismatch
    /// is detected and *all* processors panic together after barrier 2
    /// (a lone panic would strand the rest on the barrier).
    pub fn sync(&mut self, label: &str) {
        self.sync_scoped(label, None);
    }

    /// The superstep boundary shared by whole-machine and group-scoped
    /// syncs: [`BspCtx::sync`] passes `None` (all `p` processors, the
    /// world barrier, the full slot row); `bsp::group::GroupCtx` passes a
    /// [`GroupScope`] (group members only, the group's own barrier, the
    /// member-restricted slot view) so a sub-machine synchronizes without
    /// involving — or waiting on — its sibling groups.
    pub(super) fn sync_scoped(&mut self, label: &str, scope: Option<&GroupScope<'_>>) {
        let wall_us = self.sync_mark.elapsed().as_secs_f64() * 1e6;

        // Fail fast on an already-published SPMD violation *before*
        // blocking on a barrier: with group scoping, the offending
        // group panics among itself after its barrier 2, and a sibling
        // heading into a whole-machine sync would otherwise wait
        // forever on the dead threads (best-effort — a violation
        // published after this check is caught at the post-barrier
        // check of a later sync).
        if cfg!(debug_assertions) {
            let poison = self.world.spmd_violation.lock().unwrap().clone();
            if let Some(msg) = poison {
                panic!("SPMD sync label mismatch: {msg}");
            }
        }

        // Barrier 1: all sends for this superstep are staged.  A group
        // sync waits only on its own members; a whole-machine sync goes
        // through the world's (possibly batch-shared) barrier.
        match scope {
            Some(s) => {
                s.barrier.wait();
            }
            None => {
                self.world.barrier.wait();
            }
        }

        // The group's superstep index, read after barrier 1: the leader
        // of the *previous* group sync incremented it before entering
        // this sync's barrier, so every member observes the same value
        // (the barrier supplies the happens-before edge; `Relaxed`
        // suffices).
        let group_step = scope
            .map(|s| s.step.load(std::sync::atomic::Ordering::Relaxed))
            .unwrap_or(0);

        // Drain this processor's slot row (or its group-scoped slice);
        // the dst-major layout delivers in sender order by construction —
        // no lock, no sort.
        self.inbox.clear();
        // SAFETY: between the two barriers the drained slots are touched
        // only by this thread; their writers (all of them group members
        // under the group communication discipline) stage again only
        // after barrier 2.
        match scope {
            Some(s) => unsafe {
                self.world.slots.drain_row_subset(self.pid, s.members, &mut self.inbox)
            },
            None => unsafe { self.world.slots.drain_row(self.pid, &mut self.inbox) },
        }
        let recv_words: u64 = self.inbox.iter().map(|(_, p)| p.words()).sum();

        // Report into the shared ledger.  Once per superstep per
        // processor — not a hot path; `charge`/`send` stay lock-free.
        {
            let mut guard = self.world.ledger.lock().unwrap();
            let builder = &mut *guard;
            if builder.phases.len() <= self.phase_id {
                builder.phases.resize_with(self.phase_id + 1, Default::default);
            }
            let (rec, procs, step) = match scope {
                Some(s) => (
                    builder
                        .group_steps
                        .entry((s.comm_id, group_step, s.leader))
                        .or_default(),
                    s.members.len(),
                    group_step,
                ),
                None => {
                    if builder.supersteps.len() <= self.superstep {
                        builder.supersteps.resize_with(self.superstep + 1, Default::default);
                    }
                    (&mut builder.supersteps[self.superstep], self.world.p, self.superstep)
                }
            };
            if rec.reporters == 0 {
                rec.label = label.to_string();
                rec.phase_id = self.phase_id;
                rec.procs = procs;
            } else if cfg!(debug_assertions) && rec.label != label {
                let mut poison = self.world.spmd_violation.lock().unwrap();
                if poison.is_none() {
                    *poison = Some(format!(
                        "superstep {}: processor {} reported label {:?}, \
                         another processor reported {:?}",
                        step, self.pid, label, rec.label
                    ));
                }
            }
            rec.reporters += 1;
            rec.max_ops = rec.max_ops.max(self.ops);
            rec.h_words = rec.h_words.max(self.sent_words.max(recv_words));
            rec.total_words += self.sent_words;
            rec.wall_us = rec.wall_us.max(wall_us);
            // Count this superstep against the active phase (h volume is
            // attributed post-hoc in `BspMachine::run`).
            let first_reporter = rec.reporters == 1;
            if first_reporter {
                builder.phases[self.phase_id].supersteps += 1;
            }
        }

        // Barrier 2: nobody stages next-superstep messages into a slot
        // that has not been drained yet.  Exactly one member of a group
        // sync is the barrier leader; it advances the group's superstep
        // counter, and the advance happens-before every member's read at
        // the next sync (they must pass that sync's barrier 1 first,
        // which the leader also enters only after the increment).
        match scope {
            Some(s) => {
                if s.barrier.wait().is_leader() {
                    s.step.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            None => {
                self.world.barrier.wait();
            }
        }

        if cfg!(debug_assertions) {
            let poison = self.world.spmd_violation.lock().unwrap().clone();
            if let Some(msg) = poison {
                panic!("SPMD sync label mismatch: {msg}");
            }
        }

        self.ops = 0.0;
        self.sent_words = 0;
        if scope.is_none() {
            self.superstep += 1;
        }
        self.sync_mark = Instant::now();
    }

    /// The messages delivered at the last `sync`, ordered by sender id.
    pub fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)> {
        std::mem::take(&mut self.inbox)
    }

    /// Convenience: exchange one payload with every processor
    /// (all-to-all); returns the received payloads by sender.
    pub fn all_to_all(&mut self, parts: Vec<Payload<K>>, label: &str) -> Vec<(usize, Payload<K>)> {
        assert_eq!(parts.len(), self.nprocs());
        for (dst, payload) in parts.into_iter().enumerate() {
            self.send(dst, payload);
        }
        self.sync(label);
        self.take_inbox()
    }

    /// Flush end-of-run phase accounting (called by the engine).
    fn finish(&mut self) {
        let elapsed = self.phase_mark.elapsed().as_secs_f64() * 1e6;
        self.phase_wall[self.phase_id] += elapsed;
        let mut guard = self.world.ledger.lock().unwrap();
        let builder = &mut *guard;
        if builder.phases.len() < self.phase_ops.len() {
            builder.phases.resize_with(self.phase_ops.len(), Default::default);
        }
        for (id, (&ops, &wall)) in self.phase_ops.iter().zip(self.phase_wall.iter()).enumerate() {
            let rec = &mut builder.phases[id];
            rec.max_ops = rec.max_ops.max(ops);
            rec.wall_us = rec.wall_us.max(wall);
        }
    }
}

/// A (possibly group-scoped) view of the BSP machine against which SPMD
/// programs run.
///
/// The sorting algorithms and collective primitives are generic over
/// this trait, so the *same* program text executes against the whole
/// machine ([`BspCtx`]) or against one processor group of a partitioned
/// machine (`bsp::group::GroupCtx`) — the mechanism behind the two-level
/// sorts (`sort::multilevel`): level 2 reuses the one-level algorithms
/// verbatim, scoped to a sub-machine.
///
/// Within a scope, `pid`/`nprocs`/`send` destinations are *scope-local*
/// ranks in `[0, nprocs)`; `sync` synchronizes exactly the scope's
/// participants and delivers only messages staged within the scope.
pub trait BspScope<K: Key> {
    /// This processor's rank within the scope, in `[0, nprocs)`.
    fn pid(&self) -> usize;
    /// Number of processors in the scope.
    fn nprocs(&self) -> usize;
    /// Charge `ops` basic operations to the current superstep and phase
    /// (§1.1 charging policy).
    fn charge(&mut self, ops: f64);
    /// Enter a named phase; wall-clock and charges accrue to it.
    fn phase(&mut self, name: &str);
    /// Stage a message for scope rank `dst`; delivered at the next
    /// `sync` of this scope.
    fn send(&mut self, dst: usize, payload: Payload<K>);
    /// Superstep boundary of the scope (SPMD discipline: every scope
    /// participant calls it with the same `label`).
    fn sync(&mut self, label: &str);
    /// The messages delivered at the last `sync`, ordered by scope rank
    /// of the sender.
    fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)>;

    /// Convenience: exchange one payload with every scope participant
    /// (all-to-all); returns the received payloads by sender rank.
    fn all_to_all(&mut self, parts: Vec<Payload<K>>, label: &str) -> Vec<(usize, Payload<K>)> {
        assert_eq!(parts.len(), self.nprocs());
        for (dst, payload) in parts.into_iter().enumerate() {
            self.send(dst, payload);
        }
        self.sync(label);
        self.take_inbox()
    }
}

impl<K: Key> BspScope<K> for BspCtx<'_, K> {
    fn pid(&self) -> usize {
        BspCtx::pid(self)
    }
    fn nprocs(&self) -> usize {
        BspCtx::nprocs(self)
    }
    fn charge(&mut self, ops: f64) {
        BspCtx::charge(self, ops)
    }
    fn phase(&mut self, name: &str) {
        BspCtx::phase(self, name)
    }
    fn send(&mut self, dst: usize, payload: Payload<K>) {
        BspCtx::send(self, dst, payload)
    }
    fn sync(&mut self, label: &str) {
        BspCtx::sync(self, label)
    }
    fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)> {
        BspCtx::take_inbox(self)
    }
    fn all_to_all(&mut self, parts: Vec<Payload<K>>, label: &str) -> Vec<(usize, Payload<K>)> {
        BspCtx::all_to_all(self, parts, label)
    }
}

/// Result of a BSP run: the per-processor outputs and the cost ledger.
#[derive(Debug)]
pub struct BspRun<T> {
    pub outputs: Vec<T>,
    pub ledger: Ledger,
}

/// A BSP machine: parameters + the ability to run SPMD programs.
pub struct BspMachine {
    pub params: BspParams,
}

impl BspMachine {
    pub fn new(params: BspParams) -> Self {
        BspMachine { params }
    }

    /// Execute `program` on `p` processors (threads) with the default
    /// `i32` key domain (the paper's experiments); returns outputs in
    /// pid order plus the superstep/phase ledger.
    pub fn run<T, F>(&self, program: F) -> BspRun<T>
    where
        T: Send,
        F: Fn(&mut BspCtx) -> T + Sync,
    {
        #[allow(deprecated)]
        self.run_keys::<i32, T, F>(program)
    }

    /// As [`BspMachine::run`] but with an explicit payload key domain
    /// `K` — historically the entry point of the generic sorting stack
    /// (`machine.run_keys::<u64, _, _>(…)`).
    ///
    /// Deprecated: this spins up `p` threads for one sort and tears them
    /// down again.  Route through the persistent engine pool instead
    /// ([`crate::sorter::Sorter`], or `Engine::submit` directly), which
    /// parks its worker team between jobs and recycles mailbox storage.
    /// The wrapper stays — with bit-identical outputs and charged
    /// ledger — for the paper-reproduction scripts and existing tests.
    #[deprecated(note = "use Engine::submit")]
    pub fn run_keys<K, T, F>(&self, program: F) -> BspRun<T>
    where
        K: Key,
        T: Send,
        F: Fn(&mut BspCtx<K>) -> T + Sync,
    {
        let p = self.params.p;
        let world: World<K> = World::new(p, Arc::new(SharedBarrier::new(p)));
        let started = Instant::now();
        let mut outputs: Vec<Option<T>> = (0..p).map(|_| None).collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(p);
            for pid in 0..p {
                let world_ref = &world;
                let program_ref = &program;
                handles
                    .push(scope.spawn(move || (pid, run_proc_body(world_ref, pid, program_ref))));
            }
            for h in handles {
                let (pid, out) = h.join().expect("BSP processor thread panicked");
                outputs[pid] = Some(out);
            }
        });

        let ledger = world.finalize(started.elapsed().as_secs_f64() * 1e6);
        BspRun {
            outputs: outputs.into_iter().map(|o| o.unwrap()).collect(),
            ledger,
        }
    }
}

/// The body every BSP processor runs: build the per-processor context,
/// execute the SPMD `program`, flush end-of-run phase accounting,
/// return the processor's output.  Shared by the one-shot
/// [`BspMachine`] path and the persistent engine pool (`bsp::service`)
/// so both charge identically — the pool adds job bookkeeping (barrier
/// departure, completion counting) *around* this body, never inside it.
pub(super) fn run_proc_body<K, T, F>(world: &World<K>, pid: usize, program: &F) -> T
where
    K: Key,
    F: Fn(&mut BspCtx<K>) -> T,
{
    let now = Instant::now();
    let mut ctx = BspCtx {
        pid,
        world,
        inbox: Vec::new(),
        superstep: 0,
        ops: 0.0,
        sent_words: 0,
        phase_id: 0,
        phase_ops: vec![0.0],
        phase_wall: vec![0.0],
        phase_mark: now,
        sync_mark: now,
    };
    let out = program(&mut ctx);
    ctx.finish();
    out
}

/// Materialize a finished [`LedgerBuilder`] into the public [`Ledger`]:
/// resolve interned phase names, assign dense `round` indices to
/// group-scoped records, and attribute superstep h-volumes to phases.
///
/// Shared by both execution backends — the threaded engine
/// ([`BspMachine::run_keys`]) and the deterministic simulator
/// (`bsp::sim::SimMachine`) — so predicted-vs-charged accounting is
/// identical regardless of whether the records were reported by `p`
/// concurrently-running threads or by one thread stepping `p` virtual
/// processors.
pub(super) fn finalize_ledger(builder: LedgerBuilder, names: Vec<String>, wall_us: f64) -> Ledger {
    let mut phase_recs = builder.phases;
    phase_recs.resize_with(names.len(), Default::default);
    let mut supersteps: Vec<SuperstepRecord> = builder
        .supersteps
        .into_iter()
        .map(|b| SuperstepRecord {
            label: b.label,
            phase: names[b.phase_id].clone(),
            max_ops: b.max_ops,
            h_words: b.h_words,
            total_words: b.total_words,
            wall_us: b.wall_us,
            reporters: b.reporters,
            procs: b.procs,
            round: None,
            io_blocks: 0,
        })
        .collect();
    // Group-scoped records follow the whole-machine ones.  Distinct
    // `(communicator, group step)` pairs get dense `round` indices
    // in key order: siblings of one round (same communicator, same
    // step, different leaders) are adjacent and priced as
    // concurrent; steps of different communicators never share a
    // round, so sequential group phases add instead of max-reducing.
    let mut round_ids: std::collections::BTreeMap<(usize, usize), usize> =
        std::collections::BTreeMap::new();
    for &(comm, step, _leader) in builder.group_steps.keys() {
        let next = round_ids.len();
        round_ids.entry((comm, step)).or_insert(next);
    }
    for ((comm, step, _leader), b) in builder.group_steps {
        supersteps.push(SuperstepRecord {
            label: b.label,
            phase: names[b.phase_id].clone(),
            max_ops: b.max_ops,
            h_words: b.h_words,
            total_words: b.total_words,
            wall_us: b.wall_us,
            reporters: b.reporters,
            procs: b.procs,
            round: Some(round_ids[&(comm, step)]),
            io_blocks: 0,
        });
    }
    debug_assert!(
        supersteps.iter().all(|s| s.reporters == s.procs),
        "SPMD violation: a superstep was not reported by all its participants"
    );
    let mut ledger = Ledger {
        supersteps,
        phases: names.into_iter().zip(phase_recs).collect(),
        wall_us,
    };
    // Attribute superstep h-volumes to phases post-hoc (max over the
    // per-superstep h of each phase is less meaningful than the sum).
    for s in &ledger.supersteps {
        if let Some(phase) = ledger.phases.get_mut(&s.phase) {
            phase.h_words += s.h_words;
        }
    }
    ledger
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    fn machine(p: usize) -> BspMachine {
        BspMachine::new(cray_t3d(p))
    }

    #[test]
    fn pid_and_nprocs() {
        let run = machine(4).run(|ctx| (ctx.pid(), ctx.nprocs()));
        assert_eq!(run.outputs, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn ring_exchange_delivers_in_sender_order() {
        let run = machine(8).run(|ctx| {
            let p = ctx.nprocs();
            let dst = (ctx.pid() + 1) % p;
            ctx.send(dst, Payload::Keys(vec![ctx.pid() as i32]));
            ctx.sync("ring");
            let inbox = ctx.take_inbox();
            assert_eq!(inbox.len(), 1);
            let (src, payload) = &inbox[0];
            (*src, payload.clone().into_keys()[0])
        });
        for (pid, (src, val)) in run.outputs.iter().enumerate() {
            let expect = (pid + 8 - 1) % 8;
            assert_eq!(*src, expect);
            assert_eq!(*val, expect as i32);
        }
    }

    #[test]
    fn all_to_all_is_complete_and_ordered() {
        let run = machine(5).run(|ctx| {
            let parts = (0..5)
                .map(|dst| Payload::Keys(vec![(ctx.pid() * 10 + dst) as i32]))
                .collect();
            let recv = ctx.all_to_all(parts, "a2a");
            recv.into_iter()
                .map(|(src, p)| (src, p.into_keys()[0]))
                .collect::<Vec<_>>()
        });
        for (pid, inbox) in run.outputs.iter().enumerate() {
            assert_eq!(inbox.len(), 5);
            for (i, (src, val)) in inbox.iter().enumerate() {
                assert_eq!(*src, i, "inbox must be sorted by sender");
                assert_eq!(*val as usize, i * 10 + pid);
            }
        }
    }

    #[test]
    fn multiple_sends_to_one_dst_keep_order() {
        // A processor may stage several payloads for the same
        // destination in one superstep; they must arrive contiguously
        // and in push order (the helman baseline relies on this).
        let run = machine(3).run(|ctx| {
            ctx.send(0, Payload::Keys(vec![ctx.pid() as i32]));
            ctx.send(0, Payload::U64s(vec![ctx.pid() as u64 + 100]));
            ctx.sync("pairs");
            ctx.take_inbox()
        });
        let inbox = &run.outputs[0];
        assert_eq!(inbox.len(), 6);
        for src in 0..3usize {
            let (s0, first) = &inbox[2 * src];
            let (s1, second) = &inbox[2 * src + 1];
            assert_eq!((*s0, *s1), (src, src));
            assert!(matches!(first, Payload::Keys(v) if v[0] == src as i32));
            assert!(matches!(second, Payload::U64s(v) if v[0] == src as u64 + 100));
        }
    }

    #[test]
    fn ledger_records_h_relation() {
        let run = machine(4).run(|ctx| {
            // Everyone sends 100 keys to processor 0.
            ctx.send(0, Payload::Keys(vec![1; 100]));
            ctx.sync("fan-in");
            ctx.take_inbox().len()
        });
        assert_eq!(run.ledger.supersteps.len(), 1);
        let s = &run.ledger.supersteps[0];
        // h = max over procs of max(sent, recv) = 400 received at proc 0.
        assert_eq!(s.h_words, 400);
        assert_eq!(s.total_words, 400);
        assert_eq!(s.reporters, 4);
    }

    #[test]
    fn charges_are_max_reduced() {
        let run = machine(4).run(|ctx| {
            ctx.charge((ctx.pid() as f64 + 1.0) * 1000.0);
            ctx.sync("compute");
        });
        assert_eq!(run.ledger.supersteps[0].max_ops, 4000.0);
        let _ = run;
    }

    #[test]
    fn multiple_supersteps_accumulate() {
        let run = machine(3).run(|ctx| {
            for step in 0..5 {
                ctx.charge(10.0);
                ctx.send((ctx.pid() + 1) % 3, Payload::U64s(vec![step]));
                ctx.sync("loop");
                ctx.take_inbox();
            }
        });
        assert_eq!(run.ledger.supersteps.len(), 5);
        for s in &run.ledger.supersteps {
            assert_eq!(s.max_ops, 10.0);
            assert_eq!(s.h_words, 1);
        }
        let _ = run;
    }

    #[test]
    fn phases_attribute_ops_and_supersteps() {
        let run = machine(2).run(|ctx| {
            ctx.phase("Ph2:SeqSort");
            ctx.charge(500.0);
            ctx.sync("sort");
            ctx.phase("Ph5:Routing");
            ctx.send(1 - ctx.pid(), Payload::Keys(vec![0; 64]));
            ctx.sync("route");
            ctx.take_inbox();
        });
        let phases = &run.ledger.phases;
        assert!(phases.contains_key("Ph2:SeqSort"));
        assert!(phases.contains_key("Ph5:Routing"));
        assert_eq!(phases["Ph2:SeqSort"].max_ops, 500.0);
        assert_eq!(phases["Ph5:Routing"].h_words, 64);
    }

    #[test]
    fn reentering_a_phase_accumulates_into_one_id() {
        let run = machine(4).run(|ctx| {
            ctx.phase("Ph2:SeqSort");
            ctx.charge(10.0);
            ctx.phase("Ph4:Prefix");
            ctx.charge(1.0);
            ctx.phase("Ph2:SeqSort"); // back again: same interned id
            ctx.charge(5.0);
            ctx.sync("s");
        });
        assert_eq!(run.ledger.phases["Ph2:SeqSort"].max_ops, 15.0);
        assert_eq!(run.ledger.phases["Ph4:Prefix"].max_ops, 1.0);
    }

    #[test]
    fn predicted_cost_uses_machine_params() {
        let machine = BspMachine::new(cray_t3d(16));
        let run = machine.run(|ctx| {
            ctx.charge(7_000.0); // 1000 µs of compute at 7 comps/µs
            ctx.sync("c");
        });
        let us = run.ledger.predicted_us(&machine.params);
        assert!((us - 1000.0).abs() < 1e-9, "us={us}");
    }

    #[test]
    fn empty_superstep_floors_at_l() {
        let machine = BspMachine::new(cray_t3d(128));
        let run = machine.run(|ctx| ctx.sync("noop"));
        assert_eq!(run.ledger.predicted_us(&machine.params), 762.0);
        let _ = run;
    }

    #[test]
    fn stress_p64_multi_superstep_all_to_all() {
        // Exercises the slot matrix at p = 64 across several supersteps:
        // 4096 slots staged and drained per round, with sender order and
        // exact payload delivery checked at every processor.
        let p = 64usize;
        let rounds = 4u64;
        let run = machine(p).run(|ctx| {
            let pid = ctx.pid();
            for round in 0..rounds {
                let parts: Vec<Payload> = (0..p)
                    .map(|dst| {
                        Payload::U64s(vec![round * 1_000_000 + (pid * 1000 + dst) as u64])
                    })
                    .collect();
                let inbox = ctx.all_to_all(parts, "stress");
                assert_eq!(inbox.len(), p);
                for (i, (src, payload)) in inbox.into_iter().enumerate() {
                    assert_eq!(src, i, "inbox must arrive in sender order");
                    let vals = payload.into_u64s();
                    assert_eq!(vals, vec![round * 1_000_000 + (src * 1000 + pid) as u64]);
                }
            }
            pid
        });
        assert_eq!(run.ledger.supersteps.len(), rounds as usize);
        for s in &run.ledger.supersteps {
            assert_eq!(s.reporters, p);
            assert_eq!(s.label, "stress");
            assert_eq!(s.total_words, (p * p) as u64);
            // h = p words in and out at every processor.
            assert_eq!(s.h_words, p as u64);
        }
    }

    #[test]
    #[allow(deprecated)]
    fn run_keys_routes_other_domains() {
        // The engine is generic over the key domain: a u64 ring exchange
        // behaves exactly like the i32 one.
        let run = machine(4).run_keys::<u64, _, _>(|ctx| {
            let dst = (ctx.pid() + 1) % ctx.nprocs();
            ctx.send(dst, Payload::Keys(vec![ctx.pid() as u64 + 10]));
            ctx.sync("ring64");
            ctx.take_inbox().pop().unwrap().1.into_keys()[0]
        });
        assert_eq!(run.outputs, vec![13, 10, 11, 12]);
    }

    #[test]
    fn shared_barrier_shrinks_as_participants_leave() {
        // Two "jobs" of two threads each share one barrier (the batched
        // shared-superstep shape): the short job syncs once and leaves,
        // the long one keeps syncing among its own survivors.  A buggy
        // `leave` strands the long job on an unreachable generation,
        // which the test harness surfaces as a hang.
        let barrier = Arc::new(SharedBarrier::new(4));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let b = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                let rounds = if t < 2 { 1 } else { 3 };
                for _ in 0..rounds {
                    b.wait();
                }
                b.leave();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "BSP processor thread panicked")]
    fn spmd_label_mismatch_is_detected_in_debug() {
        machine(2).run(|ctx| {
            let label = if ctx.pid() == 0 { "left" } else { "right" };
            ctx.sync(label);
        });
    }
}
